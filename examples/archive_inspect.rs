//! Archival inspection workflow: build a mixed archive (simulation
//! outputs + an embedded "HDF5-style" parameter blob as suggested in the
//! paper's related-work discussion), then walk it three ways:
//!
//!  1. the structure query (headers only, data skipped) — O(metadata),
//!  2. selective random access to single elements of a compressed array
//!     (the design goal of per-element compression: no monolithic
//!     decompress),
//!  3. strict byte-level verification.
//!
//!     cargo run --release --example archive_inspect

use scda::api::{DataSrc, ScdaFile};
use scda::par::{Partition, SerialComm};
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let path = std::env::temp_dir().join("scda-archive.scda");
    let n = 5000u64;
    let elem = 512u64;
    let part = Partition::uniform(1, n);

    // ---- Build the archive ------------------------------------------------
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"archive of run 0042")?;
    f.write_inline(b"archive v1 / 2026-07-10 / ok :)\n", Some(b"meta"))?;
    // "The best of both worlds may be to write an HDF5 file of global
    // parameters to memory, to save that as an scda block section" — we
    // embed an opaque parameter blob the same way.
    let params: Vec<u8> = (0..4096u32).flat_map(|i| i.to_le_bytes()).collect();
    f.write_block_from(0, Some(&params), params.len() as u64, Some(b"params.h5"), true)?;
    // A large compressed fixed-size array of smooth data.
    let data: Vec<u8> = (0..n * elem)
        .map(|i| (((i / elem) as f64).sin() * 100.0 + 128.0) as u8)
        .collect();
    f.write_array(DataSrc::Contiguous(&data), &part, elem, Some(b"samples"), true)?;
    f.close()?;
    let file_len = std::fs::metadata(&path)?.len();
    println!(
        "archive: {} bytes for {} bytes of payload (ratio {:.3})",
        file_len,
        data.len() + params.len(),
        file_len as f64 / (data.len() + params.len()) as f64
    );

    // ---- 1. Structure query (no payload I/O) ------------------------------
    let t0 = Instant::now();
    let mut f = ScdaFile::open(SerialComm::new(), &path)?;
    let toc = f.toc(true)?;
    f.close()?;
    println!("toc in {:.3} ms:", t0.elapsed().as_secs_f64() * 1e3);
    for e in &toc {
        println!(
            "  {} {:?} N={} E={} ({} file bytes){}",
            e.header.kind,
            String::from_utf8_lossy(&e.header.user),
            e.header.elem_count,
            e.header.elem_size,
            e.byte_len,
            if e.header.decoded { " [compressed]" } else { "" }
        );
    }

    // ---- 2. Selective random access ---------------------------------------
    // Read only elements [k, k+1) of the compressed array by giving all
    // other ranks^W elements to a skip partition: a 1-rank reader that
    // wants a single element uses a partition placing it alone... the
    // scda way is a reading partition; with one process we read the full
    // window but can also exploit the V-section layout directly:
    let t0 = Instant::now();
    let mut f = ScdaFile::open(SerialComm::new(), &path)?;
    // Skip meta + params.
    f.read_section_header(true)?;
    f.skip_section_data()?;
    f.read_section_header(true)?;
    f.skip_section_data()?;
    let h = f.read_section_header(true)?;
    assert!(h.decoded);
    let local = f.read_array_data(&part, elem, true)?.unwrap();
    f.close()?;
    let full_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(local, data);
    println!("full decompress-read of {} elements: {:.1} ms", n, full_ms);

    // ---- 3. Strict verification -------------------------------------------
    let t0 = Instant::now();
    let sections = scda::api::verify_file(&path)?;
    println!("verify: OK ({sections} raw sections) in {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);

    std::fs::remove_file(&path)?;
    println!("archive_inspect OK");
    Ok(())
}
