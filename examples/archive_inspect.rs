//! Archival inspection workflow on the archive catalog layer: build a
//! mixed archive of *named datasets* (simulation outputs + an embedded
//! "HDF5-style" parameter blob as suggested in the paper's related-work
//! discussion), then walk it four ways:
//!
//!  1. the catalog listing (what `scda ls` prints) — loaded through the
//!     O(1) footer index, no section scan,
//!  2. random access to one named dataset (`open_dataset` seeks straight
//!     to the section; per-element compression then decodes only what is
//!     read),
//!  3. the classic structure query (`toc`), which transparently takes
//!     the catalog fast path on indexed files,
//!  4. strict byte-level verification — the catalog trailer is ordinary
//!     scda, so the file verifies unchanged.
//!
//!     cargo run --release --example archive_inspect

use scda::api::ScdaFile;
use scda::api::DataSrc;
use scda::archive::Archive;
use scda::par::{Partition, SerialComm};
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let path = std::env::temp_dir().join("scda-archive.scda");
    let n = 5000u64;
    let elem = 512u64;
    let part = Partition::uniform(1, n);

    // ---- Build the archive ------------------------------------------------
    let mut ar = Archive::create(SerialComm::new(), &path, b"archive of run 0042")?;
    ar.write_inline_from("meta", 0, Some(b"archive v1 / 2026-07-10 / ok :)\n"))?;
    // "The best of both worlds may be to write an HDF5 file of global
    // parameters to memory, to save that as an scda block section" — we
    // embed an opaque parameter blob the same way, now addressable by
    // name.
    let params: Vec<u8> = (0..4096u32).flat_map(|i| i.to_le_bytes()).collect();
    ar.write_block_from("params.h5", 0, Some(&params), params.len() as u64, true)?;
    // A large compressed fixed-size array of smooth data.
    let data: Vec<u8> = (0..n * elem)
        .map(|i| (((i / elem) as f64).sin() * 100.0 + 128.0) as u8)
        .collect();
    ar.write_array("samples", DataSrc::Contiguous(&data), &part, elem, true)?;
    ar.finish()?;
    let file_len = std::fs::metadata(&path)?.len();
    println!(
        "archive: {} bytes for {} bytes of payload (ratio {:.3})",
        file_len,
        data.len() + params.len(),
        file_len as f64 / (data.len() + params.len()) as f64
    );

    // ---- 1. Catalog listing (O(1) footer index) ---------------------------
    let t0 = Instant::now();
    let mut ar = Archive::open(SerialComm::new(), &path)?;
    println!(
        "catalog in {:.3} ms ({}):",
        t0.elapsed().as_secs_f64() * 1e3,
        if ar.is_indexed() { "footer index" } else { "scan fallback" }
    );
    for d in ar.datasets() {
        println!(
            "  {} {} N={} E={} ({} file bytes @ {}){}",
            d.kind,
            d.name,
            d.elem_count,
            d.elem_size,
            d.byte_len,
            d.offset,
            if d.encoded { " [compressed]" } else { "" }
        );
    }

    // ---- 2. Random access by name -----------------------------------------
    let t0 = Instant::now();
    let blob = ar.read_block("params.h5", 0)?.unwrap();
    assert_eq!(blob, params);
    println!("read params.h5 by name: {} bytes in {:.3} ms", blob.len(), t0.elapsed().as_secs_f64() * 1e3);
    let t0 = Instant::now();
    let local = ar.read_array("samples", &part, elem)?;
    assert_eq!(local, data);
    println!("read samples by name: {} elements in {:.1} ms", n, t0.elapsed().as_secs_f64() * 1e3);
    ar.close()?;

    // ---- 3. Structure query (catalog fast path) ---------------------------
    let t0 = Instant::now();
    let mut f = ScdaFile::open(SerialComm::new(), &path)?;
    let toc = f.toc(true)?;
    f.close()?;
    println!(
        "toc in {:.3} ms: {} logical sections (datasets + catalog + index)",
        t0.elapsed().as_secs_f64() * 1e3,
        toc.len()
    );

    // ---- 4. Strict verification -------------------------------------------
    let t0 = Instant::now();
    let sections = scda::api::verify_file(&path)?;
    println!("verify: OK ({sections} raw sections) in {:.1} ms", t0.elapsed().as_secs_f64() * 1e3);

    std::fs::remove_file(&path)?;
    println!("archive_inspect OK");
    Ok(())
}
