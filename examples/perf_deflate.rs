//! §Perf micro-benchmark: per-element deflate throughput by element size
//! (the compression convention hot path). See EXPERIMENTS.md §Perf.
// quick micro-benchmark for encode_element before optimization
use scda::codec::{encode_element, CodecOptions};
fn main() {
    let data: Vec<u8> = scda::bench_support::corpus(1 << 20).remove(3).1;
    for elem in [256usize, 4096, 65536] {
        let t0 = std::time::Instant::now();
        let mut total = 0usize;
        for _ in 0..4 {
            for e in data.chunks(elem) {
                total += encode_element(e, CodecOptions::default()).len();
            }
        }
        let s = t0.elapsed().as_secs_f64();
        println!("elem {elem:>6}: {:.1} MiB/s (total {total})", 4.0 / s);
    }
}
