//! §Perf micro-benchmark: write-path breakdown (scda vs fsync vs baseline).
//! See EXPERIMENTS.md §Perf.
// Write-path breakdown: where do the milliseconds go for a 64 MiB array?
use scda::api::{DataSrc, ScdaFile};
use scda::par::{run_parallel, Communicator, Partition};
use std::sync::Arc;
use std::time::Instant;
fn main() {
    let total: u64 = 64 << 20;
    let elem = 64 * 1024u64;
    let n = total / elem;
    let payload: Arc<Vec<u8>> = Arc::new(vec![0xA5u8; total as usize]);
    for p in [1usize, 4] {
        let part = Arc::new(Partition::uniform(p, n));
        let path = Arc::new(std::env::temp_dir().join(format!("perfw-{p}.scda")));
        for label in ["scda", "scda-nosync", "baseline"] {
            let (pp, pl, pa) = (Arc::clone(&path), Arc::clone(&payload), Arc::clone(&part));
            let lab = label.to_string();
            let t0 = Instant::now();
            run_parallel(p, move |comm| {
                let r = pa.local_range(comm.rank());
                let local = &pl[(r.start * elem) as usize..(r.end * elem) as usize];
                match lab.as_str() {
                    "baseline" => std::fs::write(format!("{}.{}", pp.display(), comm.rank()), local).unwrap(),
                    _ => {
                        let mut f = ScdaFile::create(comm, &*pp, b"w").unwrap();
                        f.write_array(DataSrc::Contiguous(local), &pa, elem, Some(b"x"), false).unwrap();
                        if lab == "scda-nosync" { drop(f); } else { f.close().unwrap(); }
                    }
                }
            });
            println!("P={p} {label:>12}: {:.1} ms  ({:.0} MiB/s)", t0.elapsed().as_secs_f64()*1e3, 64.0/t0.elapsed().as_secs_f64());
        }
    }
}
