//! Checkpoint/restart across different process counts: write an AMR
//! checkpoint on `P_w` simulated ranks, restart it on several other
//! process counts (including byte-balanced repartitioning), and verify
//! the restored fields bit-for-bit.
//!
//!     cargo run --release --example checkpoint_restart [P_w]

use scda::coordinator::checkpoint::{read_checkpoint, write_checkpoint, Field, FieldPayload};
use scda::coordinator::{by_bytes, Metrics};
use scda::mesh::{self, fields};
use scda::par::{run_parallel, Communicator, Partition};
use scda::runtime::{NativeTransform, PrecondService, Preconditioner};
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let write_ranks: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let path = Arc::new(std::env::temp_dir().join("scda-ckpt-example.scda"));

    // The mesh and its fields (globally known for verification).
    let leaves = Arc::new(mesh::ring_mesh(4, 7, (0.4, 0.6), 0.25));
    let n = leaves.len() as u64;
    let global_rho = Arc::new(fields::local_fixed_field(&leaves, 0..leaves.len(), 5));
    let (gs, gd) = fields::local_hp_field(&leaves, 0..leaves.len(), 6);
    let global_hp_sizes = Arc::new(gs);
    let global_hp = Arc::new(gd);
    println!("mesh: {n} elements; rho {} B; hp {} B", global_rho.len(), global_hp.len());

    // ---- Write on P_w ranks ----------------------------------------------
    let part = Arc::new(Partition::uniform(write_ranks, n));
    let metrics = Arc::new(Metrics::new());
    let pre = Arc::new(PrecondService::spawn(Preconditioner::native));
    {
        let (path, leaves, part, metrics, pre) =
            (Arc::clone(&path), Arc::clone(&leaves), Arc::clone(&part), Arc::clone(&metrics), Arc::clone(&pre));
        run_parallel(write_ranks, move |comm| {
            let r = part.local_range(comm.rank());
            let range = r.start as usize..r.end as usize;
            let fields = vec![
                Field {
                    name: "rho".into(),
                    encode: true,
                    precondition: true,
                    payload: FieldPayload::Fixed {
                        elem_size: 40,
                        data: fields::local_fixed_field(&leaves, range.clone(), 5),
                    },
                },
                Field {
                    name: "hp".into(),
                    encode: true,
                    precondition: false,
                    payload: {
                        let (sizes, data) = fields::local_hp_field(&leaves, range, 6);
                        FieldPayload::Var { sizes, data }
                    },
                },
            ];
            write_checkpoint(comm, &path, "ckpt-example", 7, &part, &fields, &*pre, &metrics).unwrap();
        });
    }
    let file_bytes = std::fs::metadata(&*path)?.len();
    let raw_bytes = global_rho.len() + global_hp.len();
    println!(
        "checkpoint: {file_bytes} B on disk for {raw_bytes} B of field data (ratio {:.3})",
        file_bytes as f64 / raw_bytes as f64
    );
    println!("{}", metrics.report());

    // ---- Restart on several process counts -------------------------------
    for restart_ranks in [1usize, 2, 3, 7] {
        // Count-balanced partition...
        let rpart = Arc::new(Partition::uniform(restart_ranks, n));
        verify_restart(&path, restart_ranks, &rpart, &global_rho, &global_hp_sizes, &global_hp);
        // ...and a byte-balanced one (hp sizes are level-skewed).
        let bpart = Arc::new(by_bytes(&global_hp_sizes, restart_ranks));
        verify_restart(&path, restart_ranks, &bpart, &global_rho, &global_hp_sizes, &global_hp);
        println!("restart on {restart_ranks:>2} ranks: OK (count- and byte-balanced)");
    }

    std::fs::remove_file(&*path)?;
    println!("checkpoint_restart OK");
    Ok(())
}

fn verify_restart(
    path: &Arc<std::path::PathBuf>,
    ranks: usize,
    part: &Arc<Partition>,
    global_rho: &Arc<Vec<u8>>,
    global_hp_sizes: &Arc<Vec<u64>>,
    global_hp: &Arc<Vec<u8>>,
) {
    let (path, part, rho, hps, hp) = (
        Arc::clone(path),
        Arc::clone(part),
        Arc::clone(global_rho),
        Arc::clone(global_hp_sizes),
        Arc::clone(global_hp),
    );
    run_parallel(ranks, move |comm| {
        let rank = comm.rank();
        let (info, restored) = read_checkpoint(comm, &path, &part, &NativeTransform).unwrap();
        assert_eq!(info.app, "ckpt-example");
        assert_eq!(info.step, 7);
        let r = part.local_range(rank);
        match &restored[0].payload {
            FieldPayload::Fixed { elem_size, data } => {
                assert_eq!(*elem_size, 40);
                assert_eq!(data, &rho[(r.start * 40) as usize..(r.end * 40) as usize]);
            }
            _ => panic!("rho must be fixed"),
        }
        match &restored[1].payload {
            FieldPayload::Var { sizes, data } => {
                assert_eq!(sizes, &hps[r.start as usize..r.end as usize]);
                let lo: u64 = hps[..r.start as usize].iter().sum();
                let len: u64 = sizes.iter().sum();
                assert_eq!(data, &hp[lo as usize..(lo + len) as usize]);
            }
            _ => panic!("hp must be var"),
        }
    });
}
