//! Quickstart: write an scda file with all four section types, read it
//! back under a different partition, and verify every byte.
//!
//!     cargo run --release --example quickstart

use scda::api::{DataSrc, ScdaFile};
use scda::par::{run_parallel, Communicator, Partition, SerialComm};
use std::sync::Arc;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let path = std::env::temp_dir().join("scda-quickstart.scda");

    // ---- Write in serial -------------------------------------------------
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"quickstart")?;
    // 32 bytes of inline status — visible verbatim in a text editor.
    f.write_inline(b"run 0042 / state OK / restart 1\n", Some(b"status"))?;
    // A global configuration block.
    f.write_block(b"dt=1e-3\nscheme=rk4\nlevels=3..7\n", Some(b"config"))?;
    // A fixed-size array: 1000 elements x 8 bytes.
    let n = 1000u64;
    let part = Partition::uniform(1, n);
    let data: Vec<u8> = (0..n * 8).map(|i| (i % 251) as u8).collect();
    f.write_array(DataSrc::Contiguous(&data), &part, 8, Some(b"field:u64"), false)?;
    // The same array, compressed per element (§3 convention).
    f.write_array(DataSrc::Contiguous(&data), &part, 8, Some(b"field:u64:z"), true)?;
    f.close()?;
    println!("wrote {} ({} bytes)", path.display(), std::fs::metadata(&path)?.len());

    // ---- Strict structural verification ----------------------------------
    let sections = scda::api::verify_file(&path)?;
    println!("verify: OK ({sections} raw sections)");

    // ---- Read back on 3 simulated ranks with a different partition -------
    let path2 = Arc::new(path.clone());
    let expected = Arc::new(data);
    run_parallel(3, move |comm| {
        let rank = comm.rank();
        let part = Partition::uniform(3, n);
        let mut f = ScdaFile::open(comm, &*path2).unwrap();
        // Sections must be consumed in order; headers tell us what's next.
        let h = f.read_section_header(false).unwrap();
        assert_eq!(h.user, b"status");
        let inline = f.read_inline_data(0, true).unwrap();
        if rank == 0 {
            print!("status: {}", String::from_utf8_lossy(&inline.unwrap()));
        }
        let h = f.read_section_header(false).unwrap();
        assert_eq!(h.user, b"config");
        f.read_block_data(0, rank == 0).unwrap();
        // Raw array: each rank reads its own window.
        let h = f.read_section_header(false).unwrap();
        assert_eq!((h.elem_count, h.elem_size), (n, 8));
        let local = f.read_array_data(&part, 8, true).unwrap().unwrap();
        let r = part.local_range(rank);
        assert_eq!(local, &expected[(r.start * 8) as usize..(r.end * 8) as usize]);
        // Compressed array: transparently decoded.
        let h = f.read_section_header(true).unwrap();
        assert!(h.decoded);
        let local_z = f.read_array_data(&part, 8, true).unwrap().unwrap();
        assert_eq!(local_z, local);
        assert!(f.at_end().unwrap());
        f.close().unwrap();
        println!("rank {rank}: verified {} bytes", local.len() * 2);
    });

    std::fs::remove_file(&path)?;
    println!("quickstart OK");
    Ok(())
}
