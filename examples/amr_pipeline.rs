//! END-TO-END DRIVER (DESIGN.md §Experiments E2E): the full stack on a
//! real small workload.
//!
//! A ~1M-element adaptive Morton-ordered quadtree carries four fields
//! (two smooth f64 fixed-size fields, one u32 index field, one hp-style
//! variable-size coefficient field). The run:
//!
//!   1. generates the mesh and fields (workload substrate),
//!   2. writes one scda checkpoint on P ranks through the staged pipeline
//!      (precondition via PJRT artifacts when present — L1/L2 — with the
//!      native fallback otherwise; per-element deflate — §3 convention;
//!      parallel single-file windows — §2),
//!   3. verifies serial-equivalence: the P-rank file hash equals the
//!      1-rank file hash (the paper's headline property),
//!   4. restarts on a different process count and verifies bit-exactness,
//!   5. reports the headline metrics: equivalence, compression ratio,
//!      write/read bandwidth, per-stage timings.
//!
//!     cargo run --release --example amr_pipeline [--ranks P] [--base L]
//!
//! Results are recorded in EXPERIMENTS.md §E2E.

use scda::cli::args::Args;
use scda::coordinator::checkpoint::{read_checkpoint, write_checkpoint, Field, FieldPayload};
use scda::coordinator::Metrics;
use scda::mesh::{self, fields};
use scda::par::{run_parallel, Communicator, Partition};
use scda::runtime::{PrecondService, Transform};
use std::sync::Arc;
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args = Args::parse(std::env::args().skip(1)).unwrap();
    let ranks: usize = args.get_parse("ranks", 4).unwrap();
    let base: u8 = args.get_parse("base", if args.flag("quick") { 6 } else { 9 }).unwrap();
    let max: u8 = base + 2;

    // ---- 1. Workload -----------------------------------------------------
    let t0 = Instant::now();
    let leaves = Arc::new(mesh::ring_mesh(base, max, (0.5, 0.5), 0.3));
    let n = leaves.len() as u64;
    println!(
        "mesh: {n} elements (levels {base}..{max}), generated in {:.2}s",
        t0.elapsed().as_secs_f64()
    );

    let pre = Arc::new(PrecondService::auto(scda::cli::artifacts_dir()));
    println!("precondition backend: {} (L1/L2 via PJRT when 'pjrt')", pre.name());

    // ---- 2+3. Write on P ranks and on 1 rank; compare hashes -------------
    let mut hashes = Vec::new();
    let mut raw_bytes = 0u64;
    let mut file_bytes = 0u64;
    let mut write_secs = 0.0f64;
    for p in [ranks, 1] {
        let path = Arc::new(std::env::temp_dir().join(format!("scda-e2e-{p}.scda")));
        let part = Arc::new(Partition::uniform(p, n));
        let metrics = Arc::new(Metrics::new());
        let t0 = Instant::now();
        {
            let (path, leaves, part, metrics, pre) =
                (Arc::clone(&path), Arc::clone(&leaves), Arc::clone(&part), Arc::clone(&metrics), Arc::clone(&pre));
            run_parallel(p, move |comm| {
                let r = part.local_range(comm.rank());
                let range = r.start as usize..r.end as usize;
                let (hp_sizes, hp_data) = fields::local_hp_field(&leaves, range.clone(), 6);
                let idx: Vec<u8> = leaves[range.clone()]
                    .iter()
                    .flat_map(|q| {
                        let (x, y) = (q.x, q.y);
                        [x.to_le_bytes(), y.to_le_bytes()].concat()
                    })
                    .collect();
                let flds = vec![
                    Field {
                        name: "rho:f32x512".into(),
                        encode: true,
                        precondition: true,
                        payload: FieldPayload::Fixed {
                            elem_size: 2048,
                            data: fields::local_fixed_field_f32(&leaves, range.clone(), 512),
                        },
                    },
                    Field {
                        name: "energy:f32x256".into(),
                        encode: true,
                        precondition: true,
                        payload: FieldPayload::Fixed {
                            elem_size: 1024,
                            data: fields::local_fixed_field_f32(&leaves, range.clone(), 256),
                        },
                    },
                    // Tiny structural elements: per-element compression
                    // would only add framing overhead, so store raw (the
                    // paper's overhead trade-off, measured by bench t4).
                    Field {
                        name: "anchor:u32x2".into(),
                        encode: false,
                        precondition: false,
                        payload: FieldPayload::Fixed { elem_size: 8, data: idx },
                    },
                    Field {
                        name: "hp:coeffs".into(),
                        encode: true,
                        precondition: false,
                        payload: FieldPayload::Var { sizes: hp_sizes, data: hp_data },
                    },
                ];
                write_checkpoint(comm, &path, "amr-e2e", 100, &part, &flds, &*pre, &metrics).unwrap();
            });
        }
        let secs = t0.elapsed().as_secs_f64();
        let fbytes = std::fs::metadata(&*path)?.len();
        let rbytes = metrics.bytes_in.load(std::sync::atomic::Ordering::Relaxed);
        println!(
            "write P={p}: {:.2}s, {:.1} MiB raw -> {:.1} MiB file (ratio {:.3}), {:.0} MiB/s effective",
            secs,
            rbytes as f64 / 1048576.0,
            fbytes as f64 / 1048576.0,
            fbytes as f64 / rbytes as f64,
            rbytes as f64 / 1048576.0 / secs,
        );
        if p == ranks {
            println!("{}", metrics.report());
            raw_bytes = rbytes;
            file_bytes = fbytes;
            write_secs = secs;
        }
        hashes.push(sha256_file(&path)?);
        if p == 1 {
            std::fs::remove_file(&*path)?;
        }
    }
    assert_eq!(hashes[0], hashes[1], "SERIAL-EQUIVALENCE VIOLATED");
    println!("serial-equivalence: P={ranks} file SHA-256 == serial file SHA-256 ({})", hex(&hashes[0][..8]));

    // ---- 4. Restart on a different P, verify bit-exactness ---------------
    let path = Arc::new(std::env::temp_dir().join(format!("scda-e2e-{ranks}.scda")));
    let restart_ranks = ranks + 1;
    let rpart = Arc::new(Partition::uniform(restart_ranks, n));
    let t0 = Instant::now();
    {
        let (path, leaves, rpart, pre) =
            (Arc::clone(&path), Arc::clone(&leaves), Arc::clone(&rpart), Arc::clone(&pre));
        run_parallel(restart_ranks, move |comm| {
            let rank = comm.rank();
            let (info, restored) = read_checkpoint(comm, &path, &rpart, &*pre).unwrap();
            assert_eq!(info.step, 100);
            let r = rpart.local_range(rank);
            let range = r.start as usize..r.end as usize;
            match &restored[0].payload {
                FieldPayload::Fixed { data, .. } => {
                    assert_eq!(data, &fields::local_fixed_field_f32(&leaves, range.clone(), 512));
                }
                _ => unreachable!(),
            }
            match &restored[3].payload {
                FieldPayload::Var { sizes, data } => {
                    let (es, ed) = fields::local_hp_field(&leaves, range, 6);
                    assert_eq!(sizes, &es);
                    assert_eq!(data, &ed);
                }
                _ => unreachable!(),
            }
        });
    }
    let read_secs = t0.elapsed().as_secs_f64();
    println!(
        "restart P={restart_ranks}: {:.2}s ({:.0} MiB/s effective), fields bit-exact",
        read_secs,
        raw_bytes as f64 / 1048576.0 / read_secs
    );

    // ---- 4b. Chunk-scale spectral snapshot: the PJRT (L1/L2) hot path ----
    // Patch-sized elements (1 MiB f32 each) exercise the AOT-compiled
    // shuffle/delta graphs at their design granularity.
    let spath = Arc::new(std::env::temp_dir().join("scda-e2e-spectrum.scda"));
    let patches = 8u64;
    let patch_words = 262_144usize; // 1 MiB per patch
    let t0 = Instant::now();
    {
        let (spath, pre) = (Arc::clone(&spath), Arc::clone(&pre));
        run_parallel(ranks.min(patches as usize), move |comm| {
            let p = Partition::uniform(comm.size(), patches);
            let r = p.local_range(comm.rank());
            let mut data = Vec::with_capacity((r.end - r.start) as usize * patch_words * 4);
            for patch in r.clone() {
                for i in 0..patch_words {
                    let v = ((i as f32) * 1e-3 + patch as f32).sin() * 10.0;
                    data.extend_from_slice(&v.to_le_bytes());
                }
            }
            let mut transformed = Vec::with_capacity(data.len());
            for chunk in data.chunks(patch_words * 4) {
                transformed.extend_from_slice(&pre.forward(chunk).unwrap().0);
            }
            let mut f = scda::api::ScdaFile::create(comm, &*spath, b"spectrum").unwrap();
            f.write_array(
                scda::api::DataSrc::Contiguous(&transformed),
                &p,
                patch_words as u64 * 4,
                Some(b"spectrum:f32"),
                true,
            )
            .unwrap();
            f.close().unwrap();
        });
    }
    let spec_secs = t0.elapsed().as_secs_f64();
    let spec_raw = patches as f64 * patch_words as f64 * 4.0;
    let spec_file = std::fs::metadata(&*spath)?.len();
    println!(
        "spectral snapshot ({} backend): {:.1} MiB in {:.2}s = {:.0} MiB/s; ratio {:.3}",
        pre.name(),
        spec_raw / 1048576.0,
        spec_secs,
        spec_raw / 1048576.0 / spec_secs,
        spec_file as f64 / spec_raw
    );
    std::fs::remove_file(&*spath)?;

    // ---- 5. Headline summary ---------------------------------------------
    println!("\n=== E2E HEADLINE ===");
    println!("elements                 {n}");
    println!("serial-equivalent        yes (SHA-256 equal across P)");
    println!("compression ratio        {:.3} (per-element, random access preserved)", file_bytes as f64 / raw_bytes as f64);
    println!("write bandwidth (raw)    {:.0} MiB/s on {ranks} ranks", raw_bytes as f64 / 1048576.0 / write_secs);
    println!("restart bandwidth (raw)  {:.0} MiB/s on {restart_ranks} ranks", raw_bytes as f64 / 1048576.0 / read_secs);
    std::fs::remove_file(&*path)?;
    Ok(())
}

fn sha256_file(path: &std::path::Path) -> std::io::Result<[u8; 32]> {
    Ok(scda::bench_support::sha256(&std::fs::read(path)?))
}

fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}
