//! Observability integration tests: the tracer must watch without
//! touching. A traced collective write produces byte-identical files to
//! an untraced one; the cross-rank merge at close puts every rank's
//! spans — correctly tagged, locally monotonic — on rank 0's timeline
//! at 1/2/4 ranks; the read service records serve and cache-fill spans
//! when configured with a tracer and none when not.

use scda::api::{DataSrc, IoTuning};
use scda::archive::Archive;
use scda::obs::{Span, SpanKind, Tracer};
use scda::par::{run_parallel, Communicator, Partition};
use scda::runtime::{ArchiveReadService, ReadRequest, ReadServiceConfig};
use std::path::PathBuf;
use std::sync::Arc;

const N: u64 = 2048;
const E: u64 = 16;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-obs");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

fn payload(r: std::ops::Range<u64>) -> Vec<u8> {
    (r.start * E..r.end * E).map(|i| ((i * 13) % 251) as u8).collect()
}

/// A collective write of two arrays on `ranks` ranks; every rank
/// installs a tracer when `traced`, none does otherwise. Returns rank
/// 0's merged timeline (empty when untraced).
fn write_archive(path: &PathBuf, ranks: usize, traced: bool) -> Vec<Span> {
    let part = Arc::new(Partition::uniform(ranks, N));
    let part2 = Arc::clone(&part);
    let pathc = path.clone();
    let timelines: Vec<Vec<Span>> = run_parallel(ranks, move |comm| {
        let rank = comm.rank();
        let tracer = traced.then(|| Arc::new(Tracer::for_rank(rank)));
        let mut ar = Archive::create(comm, &pathc, b"obs-test").unwrap();
        ar.file_mut().set_sync_on_close(false);
        // Small stripes so every rank owns some of this small file's
        // stripes and issues pwrites of its own (default 1 MiB stripes
        // would elect a single owner for the whole file).
        ar.file_mut().set_io_tuning(IoTuning::collective().with_stripe_size(4 << 10)).unwrap();
        ar.file_mut().set_tracer(tracer.clone()).unwrap();
        let data = payload(part2.local_range(rank));
        ar.write_array("obs/a", DataSrc::Contiguous(&data), &part2, E, false).unwrap();
        ar.write_array("obs/az", DataSrc::Contiguous(&data), &part2, E, true).unwrap();
        ar.finish().unwrap();
        tracer.and_then(|t| t.merged()).unwrap_or_default()
    });
    timelines.into_iter().next().unwrap()
}

/// Tracing must not perturb the bytes: the format stays
/// serial-equivalent and deterministic with the recorder attached.
#[test]
fn traced_write_is_byte_identical_to_untraced() {
    let traced = tmp("traced");
    let plain = tmp("plain");
    let spans = write_archive(&traced, 4, true);
    let no_spans = write_archive(&plain, 4, false);
    assert!(no_spans.is_empty());
    assert!(!spans.is_empty());
    let a = std::fs::read(&traced).unwrap();
    let b = std::fs::read(&plain).unwrap();
    assert_eq!(a, b, "tracer changed the file bytes");
    std::fs::remove_file(&traced).unwrap();
    std::fs::remove_file(&plain).unwrap();
}

/// The close-time allgather merge: rank 0 holds one ordered timeline
/// with every rank's spans, correct rank tags, and locally monotonic
/// timestamps, at each rank count.
#[test]
fn merged_timeline_covers_every_rank() {
    for ranks in [1usize, 2, 4] {
        let path = tmp(&format!("merge-{ranks}"));
        let spans = write_archive(&path, ranks, true);
        assert!(!spans.is_empty(), "ranks={ranks}: no merged timeline on rank 0");

        // Every rank contributed, and no span claims a foreign rank.
        for r in 0..ranks as u32 {
            assert!(
                spans.iter().any(|s| s.rank == r),
                "ranks={ranks}: rank {r} missing from the merged timeline"
            );
        }
        assert!(spans.iter().all(|s| (s.rank as usize) < ranks));

        // Every rank staged, issued pwrites and wrote sections; the
        // shuffle exchange spans appear once there is more than one
        // rank to exchange with.
        for r in 0..ranks as u32 {
            for kind in [SpanKind::Stage, SpanKind::Pwrite, SpanKind::SectionWrite] {
                assert!(
                    spans.iter().any(|s| s.rank == r && s.kind == kind),
                    "ranks={ranks}: rank {r} recorded no {} span",
                    kind.name()
                );
            }
        }
        if ranks > 1 {
            assert!(spans.iter().any(|s| s.kind == SpanKind::Exchange));
        }

        // The merge is globally start-ordered, which makes each rank's
        // sub-sequence locally monotonic too; spans never end before
        // they start.
        for w in spans.windows(2) {
            assert!(w[0].t_start_ns <= w[1].t_start_ns);
        }
        for s in &spans {
            assert!(s.t_end_ns >= s.t_start_ns);
            assert!(s.id != 0);
        }
        std::fs::remove_file(&path).unwrap();
    }
}

/// A traced read service records serve spans (tagged with the session
/// id) and cache fills; the default (untraced) service records nothing
/// and serves identical bytes.
#[test]
fn read_service_records_serve_and_cache_fill_spans() {
    let path = tmp("service");
    write_archive(&path, 2, false);

    let tracer = Arc::new(Tracer::for_rank(0));
    let cfg = ReadServiceConfig {
        cache_budget: 1 << 20,
        tracer: Some(Arc::clone(&tracer)),
        ..Default::default()
    };
    let svc = ArchiveReadService::open_with(&path, cfg).unwrap();
    let mut sess = svc.session().unwrap();
    let req = |first| ReadRequest { dataset: "obs/a".into(), first, count: 64 };
    let traced_bytes: Vec<_> =
        [0u64, 512, 0].iter().map(|&f| sess.serve(&req(f)).unwrap()).collect();
    sess.close().unwrap();

    let spans = tracer.snapshot();
    let serves: Vec<_> = spans.iter().filter(|s| s.kind == SpanKind::Serve).collect();
    assert_eq!(serves.len(), 3);
    for s in &serves {
        assert_eq!(s.bytes, 64 * E);
        assert_eq!(s.detail, 0, "serve span carries the session id");
    }
    assert!(spans.iter().any(|s| s.kind == SpanKind::CacheFill));
    assert!(tracer.hist(SpanKind::Serve).count() >= 3);

    // Same service without a tracer: same answers, no recorder involved.
    let svc2 = ArchiveReadService::open_with(&path, ReadServiceConfig::default()).unwrap();
    let mut sess2 = svc2.session().unwrap();
    let plain_bytes: Vec<_> =
        [0u64, 512, 0].iter().map(|&f| sess2.serve(&req(f)).unwrap()).collect();
    sess2.close().unwrap();
    for (a, b) in traced_bytes.iter().zip(&plain_bytes) {
        match (a, b) {
            (scda::runtime::ReadResponse::Array(x), scda::runtime::ReadResponse::Array(y)) => {
                assert_eq!(x, y)
            }
            _ => panic!("mixed response kinds"),
        }
    }
    std::fs::remove_file(&path).unwrap();
}

/// Recovery phases report through the tracer without changing what
/// recovery does.
#[test]
fn recovery_records_phase_spans() {
    let path = tmp("recover");
    write_archive(&path, 2, false);
    let len = std::fs::metadata(&path).unwrap().len();
    let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
    f.set_len(len - 50).unwrap();
    drop(f);

    let tracer = Arc::new(Tracer::for_rank(0));
    let report = scda::archive::recover_with(&path, Some(&tracer)).unwrap();
    assert!(report.recovered_len < len);
    for kind in [SpanKind::RecoverWalk, SpanKind::RecoverRebuild, SpanKind::RecoverVerify] {
        assert_eq!(
            tracer.snapshot().iter().filter(|s| s.kind == kind).count(),
            1,
            "expected exactly one {} span",
            kind.name()
        );
    }
    scda::api::verify_file(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
}
