//! Fault-plane integration tests: deterministic injected I/O faults
//! driven through the public API. Covers the three tentpole guarantees —
//! transient faults are absorbed by bounded retry, persistent faults
//! surface as the *same* `ScdaError` on every rank of the collective
//! (flush, section_end via writes, and close), and torn writes surface
//! rather than silently shortening data — plus the drop-error sink's
//! eviction accounting.

use scda::api::{DataSrc, ScdaFile};
use scda::error::ScdaErrorKind;
use scda::io::{drop_error_stats, take_drop_error, FaultPlan};
use scda::par::{run_parallel, Communicator, Partition, SerialComm};
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-io-faults");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

#[test]
fn transient_write_faults_are_absorbed_by_retry() {
    let path = tmp("transient");
    let data: Vec<u8> = (0..256u32).map(|i| (i % 251) as u8).collect();
    let part = Partition::uniform(1, 32);
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"transient").unwrap();
    // The first flush-time pwrite fails twice with EINTR, then succeeds:
    // the engine's bounded retry absorbs it and the file closes clean.
    f.set_fault_plan(Some(FaultPlan::transient(0, 2)));
    f.write_array(DataSrc::Contiguous(&data), &part, 8, Some(b"field"), false).unwrap();
    f.close().unwrap();
    scda::api::verify_file(&path).unwrap();
    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    f.read_section_header(false).unwrap();
    let got = f.read_array_data(&part, 8, true).unwrap().unwrap();
    assert_eq!(got, data);
    f.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn transient_read_faults_are_absorbed_by_retry() {
    let path = tmp("transient-read");
    let data: Vec<u8> = (0..256u32).map(|i| (i % 241) as u8).collect();
    let part = Partition::uniform(1, 32);
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"tr").unwrap();
    f.write_array(DataSrc::Contiguous(&data), &part, 8, Some(b"field"), false).unwrap();
    f.close().unwrap();
    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    // Direct engine: its read path runs the bounded retry; the sieved
    // default serves reads from a buffered window instead, which is the
    // wrong surface for exercising per-syscall transients.
    f.set_io_tuning(scda::api::IoTuning::direct()).unwrap();
    f.set_fault_plan(Some(FaultPlan::transient(0, 2).on_reads()));
    f.read_section_header(false).unwrap();
    let got = f.read_array_data(&part, 8, true).unwrap().unwrap();
    assert_eq!(got, data);
    f.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn torn_write_surfaces_as_io_error() {
    let path = tmp("torn");
    let data = vec![7u8; 512];
    let part = Partition::uniform(1, 64);
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"torn").unwrap();
    // The first flush-time pwrite keeps only 3 bytes and errors.
    f.set_fault_plan(Some(FaultPlan::torn(0, 3)));
    f.write_array(DataSrc::Contiguous(&data), &part, 8, Some(b"field"), false).unwrap();
    let err = f.close().unwrap_err();
    assert_eq!(err.kind(), ScdaErrorKind::Io);
    assert!(err.to_string().contains("torn"), "error names the tear: {err}");
    std::fs::remove_file(&path).unwrap();
}

/// The collective error agreement: a persistent write fault on ONE rank
/// must surface as the SAME error code on EVERY rank, from `flush` and
/// again from `close` — never an error on the faulty rank and `Ok` (or a
/// different error) elsewhere.
fn same_error_on_all_ranks(ranks: usize, faulty: usize) {
    let path = Arc::new(tmp(&format!("agree-{ranks}-{faulty}")));
    let n = (ranks * 16) as u64;
    let data: Arc<Vec<u8>> = Arc::new((0..n * 8).map(|i| (i % 251) as u8).collect());
    let part = Partition::uniform(ranks, n);
    let pathc = Arc::clone(&path);
    let outcomes: Vec<(i32, i32)> = run_parallel(ranks, move |comm| {
        let rank = comm.rank();
        let mut f = ScdaFile::create(comm, &*pathc, b"agree").unwrap();
        // Collective-looking arm: every rank arms the same plan; the
        // rank filter confines the trips to `faulty`'s handle.
        f.set_fault_plan(Some(FaultPlan::persistent(0).on_rank(faulty)));
        let r = part.local_range(rank);
        let local = &data[(r.start * 8) as usize..(r.end * 8) as usize];
        // Small writes stage (default aggregating engine), so the
        // injected pwrite failure fires inside the collective flush.
        f.write_array(DataSrc::Contiguous(local), &part, 8, Some(b"field"), false).unwrap();
        let flush_code = f.flush().map_err(|e| e.code()).err().unwrap_or(0);
        let close_code = f.close().map_err(|e| e.code()).err().unwrap_or(0);
        (flush_code, close_code)
    });
    let first = outcomes[0];
    assert_ne!(first.0, 0, "flush must fail (rank {faulty} write fault)");
    assert_ne!(first.1, 0, "close must re-surface the sticky error");
    for (rank, o) in outcomes.iter().enumerate() {
        assert_eq!(*o, first, "rank {rank} disagrees with rank 0 on the error codes");
    }
    std::fs::remove_file(&*path).unwrap();
}

#[test]
fn persistent_fault_same_code_on_all_ranks_p2() {
    same_error_on_all_ranks(2, 1);
}

#[test]
fn persistent_fault_same_code_on_all_ranks_p4() {
    same_error_on_all_ranks(4, 2);
}

#[test]
fn persistent_fault_on_rank0_agrees_too() {
    same_error_on_all_ranks(4, 0);
}

#[test]
fn faultless_ranks_ignore_a_filtered_plan() {
    let path = tmp("filtered");
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"f").unwrap();
    // Rank filter 3 on a 1-rank communicator: never fires.
    f.set_fault_plan(Some(FaultPlan::persistent(0).on_rank(3)));
    f.write_block(b"unaffected", Some(b"b")).unwrap();
    f.close().unwrap();
    scda::api::verify_file(&path).unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn drop_error_sink_counts_evictions() {
    let before = drop_error_stats();
    // Overfill the sink well past its capacity: every drop below records
    // one flush error (persistent fault, file dropped without close).
    let n = 80usize;
    for i in 0..n {
        let path = tmp(&format!("evict-{i}"));
        let mut f = ScdaFile::create(SerialComm::new(), &path, b"e").unwrap();
        f.set_fault_plan(Some(FaultPlan::persistent(0)));
        f.write_block(b"doomed bytes", Some(b"d")).unwrap();
        drop(f);
        std::fs::remove_file(&path).ok();
    }
    let after = drop_error_stats();
    // The sink is process-global and other tests may drain it
    // concurrently, so assert the two monotone facts: evictions moved
    // (n far exceeds the cap) and pending stayed within the cap.
    assert!(
        after.evicted > before.evicted,
        "eviction counter must advance ({} -> {})",
        before.evicted,
        after.evicted
    );
    assert!(after.pending <= 64, "sink stays bounded, got {}", after.pending);
    // Drain what we can so later tests start from a smaller sink.
    while take_drop_error().is_some() {}
}
