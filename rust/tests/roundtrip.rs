//! End-to-end write/read roundtrips over all four section types, raw and
//! encoded, in serial and across thread-rank groups, with read partitions
//! differing from write partitions.

use scda::api::{DataSrc, ScdaFile, SectionHeader};
use scda::format::section::SectionKind;
use scda::par::{run_parallel, Communicator, Partition, SerialComm};
use scda::testutil::Rng;
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-roundtrip");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

#[test]
fn serial_all_section_types_raw() {
    let path = tmp("serial-raw");
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"roundtrip test").unwrap();
    f.write_inline(b"0123456789abcdef0123456789abcdef", Some(b"inline")).unwrap();
    f.write_block(b"a global configuration block", Some(b"block")).unwrap();
    let part = Partition::uniform(1, 5);
    let data: Vec<u8> = (0..40).collect();
    f.write_array(DataSrc::Contiguous(&data), &part, 8, Some(b"array"), false).unwrap();
    let sizes = [3u64, 0, 7, 1, 4];
    let vdata: Vec<u8> = (0..15).collect();
    f.write_varray(DataSrc::Contiguous(&vdata), &part, &sizes, Some(b"varray"), false).unwrap();
    f.close().unwrap();

    // Strict structural verification of every byte.
    assert_eq!(scda::api::verify_file(&path).unwrap(), 4);

    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    assert_eq!(f.header_user_string().unwrap(), b"roundtrip test");

    let h = f.read_section_header(false).unwrap();
    assert_eq!(
        h,
        SectionHeader { kind: SectionKind::Inline, user: b"inline".to_vec(), elem_count: 0, elem_size: 0, decoded: false }
    );
    let inline = f.read_inline_data(0, true).unwrap().unwrap();
    assert_eq!(&inline[..], b"0123456789abcdef0123456789abcdef");

    let h = f.read_section_header(false).unwrap();
    assert_eq!(h.kind, SectionKind::Block);
    assert_eq!(h.elem_size, 28);
    let block = f.read_block_data(0, true).unwrap().unwrap();
    assert_eq!(block, b"a global configuration block");

    let h = f.read_section_header(false).unwrap();
    assert_eq!((h.kind, h.elem_count, h.elem_size), (SectionKind::Array, 5, 8));
    let arr = f.read_array_data(&part, 8, true).unwrap().unwrap();
    assert_eq!(arr, data);

    let h = f.read_section_header(false).unwrap();
    assert_eq!((h.kind, h.elem_count), (SectionKind::Varray, 5));
    let rsizes = f.read_varray_sizes(&part).unwrap();
    assert_eq!(rsizes, sizes);
    let v = f.read_varray_data(&part, &rsizes, true).unwrap().unwrap();
    assert_eq!(v, vdata);

    assert!(f.at_end().unwrap());
    f.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn serial_encoded_sections_roundtrip() {
    let path = tmp("serial-enc");
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"encoded").unwrap();
    let blob: Vec<u8> = b"compressible ".repeat(500);
    f.write_block_from(0, Some(&blob), blob.len() as u64, Some(b"zblock"), true).unwrap();
    let part = Partition::uniform(1, 16);
    let adata: Vec<u8> = (0..16 * 100).map(|i| (i / 100) as u8).collect();
    f.write_array(DataSrc::Contiguous(&adata), &part, 100, Some(b"zarray"), true).unwrap();
    let vsizes: Vec<u64> = (0..16u64).map(|i| i * 10).collect();
    let vtotal: usize = vsizes.iter().sum::<u64>() as usize;
    let vdata: Vec<u8> = (0..vtotal).map(|i| (i % 7) as u8).collect();
    f.write_varray(DataSrc::Contiguous(&vdata), &part, &vsizes, Some(b"zvarray"), true).unwrap();
    f.close().unwrap();

    assert_eq!(scda::api::verify_file(&path).unwrap(), 6); // 3 logical = 6 raw sections

    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    let h = f.read_section_header(true).unwrap();
    assert_eq!((h.kind, h.elem_size, h.decoded), (SectionKind::Block, blob.len() as u64, true));
    assert_eq!(h.user, b"zblock");
    assert_eq!(f.read_block_data(0, true).unwrap().unwrap(), blob);

    let h = f.read_section_header(true).unwrap();
    assert_eq!((h.kind, h.elem_count, h.elem_size, h.decoded), (SectionKind::Array, 16, 100, true));
    assert_eq!(f.read_array_data(&part, 100, true).unwrap().unwrap(), adata);

    let h = f.read_section_header(true).unwrap();
    assert_eq!((h.kind, h.elem_count, h.decoded), (SectionKind::Varray, 16, true));
    let rsizes = f.read_varray_sizes(&part).unwrap();
    assert_eq!(rsizes, vsizes);
    assert_eq!(f.read_varray_data(&part, &rsizes, true).unwrap().unwrap(), vdata);
    assert!(f.at_end().unwrap());
    f.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn encoded_sections_read_raw_when_decode_false() {
    // Table 2, row "input 0 / compression header": the two raw sections
    // are visible individually and readable raw.
    let path = tmp("raw-view");
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"").unwrap();
    let blob = b"payload".repeat(100);
    f.write_block_from(0, Some(&blob), blob.len() as u64, Some(b"user"), true).unwrap();
    f.close().unwrap();

    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    let h = f.read_section_header(false).unwrap();
    assert_eq!((h.kind, h.decoded), (SectionKind::Inline, false));
    assert_eq!(h.user, b"B compressed scda 00");
    let meta = f.read_inline_data(0, true).unwrap().unwrap();
    assert!(meta.starts_with(b"U 700 ")); // uncompressed size entry
    let h = f.read_section_header(false).unwrap();
    assert_eq!((h.kind, h.decoded), (SectionKind::Block, false));
    let raw = f.read_block_data(0, true).unwrap().unwrap();
    assert!(raw.is_ascii()); // base64 armored
    assert_ne!(raw, blob);
    assert!(f.at_end().unwrap());
    f.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn decode_true_on_plain_sections_reads_raw() {
    // Table 2, row "input 1 / non-compression header": output false.
    let path = tmp("decode-noop");
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"").unwrap();
    f.write_block(b"plain", Some(b"user")).unwrap();
    f.close().unwrap();
    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    let h = f.read_section_header(true).unwrap();
    assert!(!h.decoded);
    assert_eq!(f.read_block_data(0, true).unwrap().unwrap(), b"plain");
    f.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn parallel_write_read_different_partitions() {
    let path = Arc::new(tmp("par"));
    let n = 1000u64;
    let elem = 12u64;
    let data: Arc<Vec<u8>> = Arc::new((0..n * elem).map(|i| (i % 251) as u8).collect());
    // Write on 4 ranks with an uneven partition.
    let wpart = Partition::from_counts(&[100, 0, 650, 250]);
    {
        let path = Arc::clone(&path);
        let data = Arc::clone(&data);
        let wpart2 = wpart.clone();
        run_parallel(4, move |comm| {
            let rank = comm.rank();
            let mut f = ScdaFile::create(comm, &*path, b"parallel").unwrap();
            let r = wpart2.local_range(rank);
            let local = &data[(r.start * elem) as usize..(r.end * elem) as usize];
            f.write_array(DataSrc::Contiguous(local), &wpart2, elem, Some(b"field"), false).unwrap();
            f.close().unwrap();
        });
    }
    // Read on 7 ranks with a uniform partition; each rank checks its piece.
    {
        let path = Arc::clone(&path);
        let data = Arc::clone(&data);
        run_parallel(7, move |comm| {
            let rank = comm.rank();
            let rpart = Partition::uniform(7, n);
            let mut f = ScdaFile::open(comm, &*path).unwrap();
            let h = f.read_section_header(false).unwrap();
            assert_eq!(h.elem_count, n);
            let local = f.read_array_data(&rpart, elem, true).unwrap().unwrap();
            let r = rpart.local_range(rank);
            assert_eq!(local, &data[(r.start * elem) as usize..(r.end * elem) as usize]);
            f.close().unwrap();
        });
    }
    std::fs::remove_file(&*path).unwrap();
}

#[test]
fn parallel_varray_with_skips_and_indirect() {
    let path = Arc::new(tmp("par-varray"));
    let n = 257u64;
    let mut rng = Rng::new(2024);
    let sizes: Arc<Vec<u64>> = Arc::new((0..n).map(|_| rng.below(40)).collect());
    let total: u64 = sizes.iter().sum();
    let data: Arc<Vec<u8>> = Arc::new((0..total).map(|i| (i % 13) as u8).collect());
    let offsets: Arc<Vec<u64>> = Arc::new(
        sizes
            .iter()
            .scan(0u64, |acc, &s| {
                let o = *acc;
                *acc += s;
                Some(o)
            })
            .collect(),
    );
    {
        // Write with indirect addressing on 3 ranks.
        let (path, sizes, data, offsets) = (Arc::clone(&path), Arc::clone(&sizes), Arc::clone(&data), Arc::clone(&offsets));
        run_parallel(3, move |comm| {
            let rank = comm.rank();
            let part = Partition::uniform(3, n);
            let r = part.local_range(rank);
            let slices: Vec<&[u8]> = (r.start..r.end)
                .map(|i| {
                    let o = offsets[i as usize] as usize;
                    &data[o..o + sizes[i as usize] as usize]
                })
                .collect();
            let local_sizes: Vec<u64> = sizes[r.start as usize..r.end as usize].to_vec();
            let mut f = ScdaFile::create(comm, &*path, b"v").unwrap();
            f.write_varray(DataSrc::Indirect(&slices), &part, &local_sizes, Some(b"hp-data"), false).unwrap();
            f.close().unwrap();
        });
    }
    {
        // Read on 5 ranks; rank 2 skips its data (NULL read).
        let (path, sizes, data) = (Arc::clone(&path), Arc::clone(&sizes), Arc::clone(&data));
        run_parallel(5, move |comm| {
            let rank = comm.rank();
            let part = Partition::uniform(5, n);
            let mut f = ScdaFile::open(comm, &*path).unwrap();
            let h = f.read_section_header(false).unwrap();
            assert_eq!(h.elem_count, n);
            let rsizes = f.read_varray_sizes(&part).unwrap();
            let r = part.local_range(rank);
            assert_eq!(rsizes, &sizes[r.start as usize..r.end as usize]);
            let want = rank != 2;
            let out = f.read_varray_data(&part, &rsizes, want).unwrap();
            if want {
                let start: u64 = sizes[..r.start as usize].iter().sum();
                let len: u64 = rsizes.iter().sum();
                assert_eq!(out.unwrap(), &data[start as usize..(start + len) as usize]);
            } else {
                assert!(out.is_none());
            }
            f.close().unwrap();
        });
    }
    std::fs::remove_file(&*path).unwrap();
}

#[test]
fn parallel_encoded_array_roundtrip() {
    let path = Arc::new(tmp("par-enc"));
    let n = 64u64;
    let elem = 512u64;
    let data: Arc<Vec<u8>> = Arc::new((0..n * elem).map(|i| ((i / 97) % 251) as u8).collect());
    {
        let (path, data) = (Arc::clone(&path), Arc::clone(&data));
        run_parallel(4, move |comm| {
            let rank = comm.rank();
            let part = Partition::uniform(4, n);
            let r = part.local_range(rank);
            let local = &data[(r.start * elem) as usize..(r.end * elem) as usize];
            let mut f = ScdaFile::create(comm, &*path, b"enc").unwrap();
            f.write_array(DataSrc::Contiguous(local), &part, elem, Some(b"zfield"), true).unwrap();
            f.close().unwrap();
        });
    }
    {
        let (path, data) = (Arc::clone(&path), Arc::clone(&data));
        run_parallel(2, move |comm| {
            let rank = comm.rank();
            let part = Partition::uniform(2, n);
            let mut f = ScdaFile::open(comm, &*path).unwrap();
            let h = f.read_section_header(true).unwrap();
            assert!(h.decoded);
            assert_eq!((h.elem_count, h.elem_size), (n, elem));
            let local = f.read_array_data(&part, elem, true).unwrap().unwrap();
            let r = part.local_range(rank);
            assert_eq!(local, &data[(r.start * elem) as usize..(r.end * elem) as usize]);
            f.close().unwrap();
        });
    }
    std::fs::remove_file(&*path).unwrap();
}

#[test]
fn toc_lists_logical_and_raw_views() {
    let path = tmp("toc");
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"toc").unwrap();
    f.write_inline(&[b'x'; 32], Some(b"one")).unwrap();
    f.write_block_from(0, Some(b"data"), 4, Some(b"two"), true).unwrap();
    let part = Partition::uniform(1, 3);
    f.write_array(DataSrc::Contiguous(&[0u8; 12]), &part, 4, Some(b"three"), false).unwrap();
    f.close().unwrap();

    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    let toc = f.toc(true).unwrap();
    assert_eq!(toc.len(), 3);
    assert_eq!(toc[0].header.kind, SectionKind::Inline);
    assert_eq!(toc[1].header.kind, SectionKind::Block);
    assert!(toc[1].header.decoded);
    assert_eq!(toc[2].header.kind, SectionKind::Array);
    // Sections tile the file exactly.
    let flen = std::fs::metadata(&path).unwrap().len();
    assert_eq!(toc.last().unwrap().offset + toc.last().unwrap().byte_len, flen);
    f.close().unwrap();

    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    let raw = f.toc(false).unwrap();
    assert_eq!(raw.len(), 4); // convention pair visible raw
    f.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn empty_sections_roundtrip() {
    let path = tmp("empty");
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"").unwrap();
    f.write_block(b"", Some(b"empty block")).unwrap();
    let part = Partition::uniform(1, 0);
    f.write_array(DataSrc::Contiguous(&[]), &part, 8, Some(b"empty array"), false).unwrap();
    f.write_varray(DataSrc::Contiguous(&[]), &part, &[], Some(b"empty varray"), false).unwrap();
    // Zero-size elements in a non-empty varray.
    let part3 = Partition::uniform(1, 3);
    f.write_varray(DataSrc::Contiguous(&[]), &part3, &[0, 0, 0], Some(b"zeros"), false).unwrap();
    f.close().unwrap();

    assert_eq!(scda::api::verify_file(&path).unwrap(), 4);

    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    let h = f.read_section_header(false).unwrap();
    assert_eq!(h.elem_size, 0);
    assert_eq!(f.read_block_data(0, true).unwrap().unwrap(), b"");
    let h = f.read_section_header(false).unwrap();
    assert_eq!(h.elem_count, 0);
    assert_eq!(f.read_array_data(&part, 8, true).unwrap().unwrap(), b"");
    f.read_section_header(false).unwrap();
    let s = f.read_varray_sizes(&part).unwrap();
    assert!(s.is_empty());
    assert_eq!(f.read_varray_data(&part, &s, true).unwrap().unwrap(), b"");
    f.read_section_header(false).unwrap();
    let s = f.read_varray_sizes(&part3).unwrap();
    assert_eq!(s, &[0, 0, 0]);
    assert_eq!(f.read_varray_data(&part3, &s, true).unwrap().unwrap(), b"");
    assert!(f.at_end().unwrap());
    f.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn mime_style_files_roundtrip_and_verify() {
    // §2.1: "The type of line break written may be chosen by the user to
    // MIME or Unix. On reading, this choice (or lack of it) has no effect."
    let path = tmp("mime");
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"mime style").unwrap();
    f.set_style(scda::format::LineStyle::Mime);
    f.write_inline(&[b'm'; 32], Some(b"inline")).unwrap();
    f.write_block(b"carriage returns everywhere", Some(b"block")).unwrap();
    let part = Partition::uniform(1, 6);
    f.write_array(DataSrc::Contiguous(&[9u8; 48]), &part, 8, Some(b"arr"), true).unwrap();
    f.write_varray(DataSrc::Contiguous(&[1, 2, 3]), &part, &[1, 1, 1, 0, 0, 0], Some(b"v"), true).unwrap();
    f.close().unwrap();

    // Strict verification accepts the MIME form.
    assert_eq!(scda::api::verify_file(&path).unwrap(), 6);
    // The bytes differ from a Unix-style file of the same content...
    let mime_bytes = std::fs::read(&path).unwrap();
    assert!(mime_bytes.windows(2).any(|w| w == b"\r\n"));

    // ...but reading is style-oblivious.
    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    let h = f.read_section_header(false).unwrap();
    assert_eq!(h.user, b"inline");
    assert_eq!(f.read_inline_data(0, true).unwrap().unwrap(), [b'm'; 32]);
    f.read_section_header(false).unwrap();
    assert_eq!(f.read_block_data(0, true).unwrap().unwrap(), b"carriage returns everywhere");
    let h = f.read_section_header(true).unwrap();
    assert!(h.decoded);
    assert_eq!(f.read_array_data(&part, 8, true).unwrap().unwrap(), vec![9u8; 48]);
    let h = f.read_section_header(true).unwrap();
    assert!(h.decoded);
    let sizes = f.read_varray_sizes(&part).unwrap();
    assert_eq!(sizes, [1, 1, 1, 0, 0, 0]);
    assert_eq!(f.read_varray_data(&part, &sizes, true).unwrap().unwrap(), vec![1, 2, 3]);
    assert!(f.at_end().unwrap());
    f.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn mixed_styles_within_one_file() {
    // Nothing in the format requires a single style per file; a writer
    // may switch styles between sections and readers must not care.
    let path = tmp("mixed-style");
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"").unwrap();
    f.write_block(b"unix section", Some(b"u")).unwrap();
    f.set_style(scda::format::LineStyle::Mime);
    f.write_block(b"mime section", Some(b"m")).unwrap();
    f.close().unwrap();
    assert_eq!(scda::api::verify_file(&path).unwrap(), 2);
    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    f.read_section_header(false).unwrap();
    assert_eq!(f.read_block_data(0, true).unwrap().unwrap(), b"unix section");
    f.read_section_header(false).unwrap();
    assert_eq!(f.read_block_data(0, true).unwrap().unwrap(), b"mime section");
    f.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}
