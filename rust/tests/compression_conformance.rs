//! Conformance of the from-scratch codec against an independent
//! implementation (miniz_oxide via flate2) and randomized stress of the
//! §3.1 element framing across styles and levels.
//!
//! Requires the `conformance` feature (flate2 is an optional, registry-
//! fetched dependency; the default offline build skips this file).
#![cfg(feature = "conformance")]

use scda::codec::{decode_element, encode_element, zlib_compress, zlib_decompress, CodecOptions};
use scda::format::padding::LineStyle;
use scda::testutil::Rng;
use std::io::{Read, Write};

fn corpus(rng: &mut Rng) -> Vec<Vec<u8>> {
    vec![
        vec![],
        vec![0u8; 1],
        rng.bytes(17, 256),
        rng.bytes(10_000, 256),  // incompressible
        rng.bytes(100_000, 5),   // highly compressible
        vec![0u8; 250_000],
        {
            // structured floats
            (0..30_000u32).flat_map(|i| ((i as f32 * 0.01).sin()).to_le_bytes()).collect()
        },
        b"line\n".repeat(5000),
    ]
}

#[test]
fn flate2_inflates_our_streams_at_all_levels() {
    let mut rng = Rng::new(1);
    for data in corpus(&mut rng) {
        for level in [0u8, 1, 3, 6, 9] {
            let z = zlib_compress(&data, level);
            let mut dec = flate2::read::ZlibDecoder::new(&z[..]);
            let mut out = Vec::new();
            dec.read_to_end(&mut out)
                .unwrap_or_else(|e| panic!("flate2 rejected level {level} len {}: {e}", data.len()));
            assert_eq!(out, data);
        }
    }
}

#[test]
fn we_inflate_flate2_streams_at_all_levels() {
    let mut rng = Rng::new(2);
    for data in corpus(&mut rng) {
        for level in [0u32, 1, 6, 9] {
            let mut enc = flate2::write::ZlibEncoder::new(Vec::new(), flate2::Compression::new(level));
            enc.write_all(&data).unwrap();
            let z = enc.finish().unwrap();
            assert_eq!(zlib_decompress(&z, Some(data.len())).unwrap(), data, "level {level}");
        }
    }
}

#[test]
fn our_ratio_is_competitive_with_miniz() {
    // On the AMR corpus our from-scratch deflate must land within 20% of
    // miniz's compressed size at best level (sanity on the encoder's
    // Huffman + matching quality).
    for (name, data) in scda::bench_support::corpus(1 << 20) {
        let ours = zlib_compress(&data, 9).len();
        let mut enc = flate2::write::ZlibEncoder::new(Vec::new(), flate2::Compression::best());
        enc.write_all(&data).unwrap();
        let theirs = enc.finish().unwrap().len();
        assert!(
            (ours as f64) < (theirs as f64) * 1.2 + 256.0,
            "{name}: ours {ours} vs miniz {theirs}"
        );
    }
}

#[test]
fn element_framing_randomized() {
    let mut rng = Rng::new(3);
    for _ in 0..200 {
        let len = rng.below(5000) as usize;
        let alphabet = [1u16, 4, 64, 256][rng.below(4) as usize];
        let data = rng.bytes(len, alphabet);
        let style = if rng.bool() { LineStyle::Unix } else { LineStyle::Mime };
        let level = rng.below(10) as u8;
        let enc = encode_element(&data, CodecOptions { level, style, ..CodecOptions::default() });
        assert!(enc.is_ascii());
        assert_eq!(decode_element(&enc).unwrap(), data);
    }
}

#[test]
fn framing_interop_with_python_zlib_layout() {
    // The frame layout is be64 size + 'z' + zlib; craft one with flate2
    // (as python's zlib would) and decode with our stack.
    let data = b"made by a foreign zlib".to_vec();
    let mut enc = flate2::write::ZlibEncoder::new(Vec::new(), flate2::Compression::default());
    enc.write_all(&data).unwrap();
    let z = enc.finish().unwrap();
    let mut stage1 = (data.len() as u64).to_be_bytes().to_vec();
    stage1.push(b'z');
    stage1.extend_from_slice(&z);
    let framed = scda::codec::base64::encode_lines(&stage1, LineStyle::Mime);
    assert_eq!(decode_element(&framed).unwrap(), data);
}
