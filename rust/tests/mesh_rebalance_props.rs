//! Property tests for the mesh and rebalance modules — the AMR substrate
//! the churn scenario (`tests/amr_scenario.rs`) stands on:
//!
//! * `check_mesh` accepts every `refine_mesh` output (arbitrary seeded
//!   indicators, moving-front ring meshes, degenerate cases);
//! * Morton order is preserved under `rebalance::exchange` at 1/2/4/8
//!   ranks — the exchanged stream is exactly the global leaf-order
//!   stream re-windowed, never reordered;
//! * `by_bytes` partitions are balanced within one max-element weight of
//!   the ideal share.

use scda::coordinator::rebalance::{by_bytes, by_count, exchange};
use scda::mesh::{check_mesh, refine_mesh, ring_mesh, Quadrant};
use scda::par::{run_parallel, Communicator, Partition};
use scda::runtime::scenario;
use scda::testutil::Rng;
use std::sync::Arc;

fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// A deterministic but structure-free refinement indicator: hash the
/// quadrant coordinates with the seed and refine on a coin flip. This
/// explores refinement patterns no geometric front would produce.
fn seeded_indicator(seed: u64) -> impl Fn(&Quadrant) -> bool {
    move |q: &Quadrant| {
        let h = splitmix(seed ^ ((q.x as u64) << 33) ^ ((q.y as u64) << 2) ^ q.level as u64);
        h & 3 != 0 // refine with probability 3/4 — deep but not uniform
    }
}

#[test]
fn check_mesh_accepts_every_refine_mesh_output() {
    // Arbitrary seeded indicators across depths.
    for seed in [1u64, 7, 42, 0x5cda, 0xdead_beef] {
        for max_level in 1..=6u8 {
            let leaves = refine_mesh(max_level, seeded_indicator(seed ^ max_level as u64));
            assert!(
                check_mesh(&leaves),
                "seed {seed:#x} max_level {max_level}: invalid mesh ({} leaves)",
                leaves.len()
            );
            assert!(leaves.iter().all(|q| q.level <= max_level));
        }
    }
    // The scenario's own moving fronts.
    for cycle in 1..=8u64 {
        let (center, radius) = scenario::front(42, cycle);
        let leaves = ring_mesh(2, 5, center, radius);
        assert!(check_mesh(&leaves), "cycle {cycle}: ring mesh invalid");
    }
    // Degenerate ends: never refine (root only) and always refine.
    let root = refine_mesh(0, |_| true);
    assert_eq!(root.len(), 1);
    assert!(check_mesh(&root));
    assert!(check_mesh(&refine_mesh(4, |_| true)));
}

/// Global variable-size payload stream in leaf order: element `i` gets
/// `1 + (i % 19)` bytes of per-element deterministic content. Any window
/// of it is recomputable from the index alone.
fn global_stream(n: usize) -> (Vec<u64>, Vec<u8>) {
    let sizes: Vec<u64> = (0..n as u64).map(|i| 1 + (i % 19)).collect();
    let mut data = Vec::new();
    for (i, &s) in sizes.iter().enumerate() {
        for j in 0..s {
            data.push((splitmix(i as u64 ^ (j << 32)) & 0xff) as u8);
        }
    }
    (sizes, data)
}

#[test]
fn exchange_preserves_morton_order_at_every_rank_count() {
    let leaves = ring_mesh(2, 4, (0.4, 0.6), 0.2);
    let n = leaves.len();
    let (sizes, data) = global_stream(n);
    let weights = sizes.clone();
    let sizes = Arc::new(sizes);
    let data = Arc::new(data);
    for &ranks in &[1usize, 2, 4, 8] {
        let part_old = by_count(n as u64, ranks);
        let part_new = by_bytes(&weights, ranks);
        let sizes = Arc::clone(&sizes);
        let data = Arc::clone(&data);
        let results = run_parallel(ranks, move |comm| {
            let rank = comm.rank();
            let old = part_old.local_range(rank);
            let boff: u64 = sizes[..old.start as usize].iter().sum();
            let blen: u64 = sizes[old.start as usize..old.end as usize].iter().sum();
            let local_old = &data[boff as usize..(boff + blen) as usize];
            let local_sizes = &sizes[old.start as usize..old.end as usize];
            let (got_sizes, got_data) = exchange(&comm, &part_old, &part_new, local_sizes, local_old);
            // The exchanged window must be exactly the global stream's
            // slice for this rank's new window — same order, same bytes.
            let new = part_new.local_range(rank);
            let noff: u64 = sizes[..new.start as usize].iter().sum();
            let nlen: u64 = sizes[new.start as usize..new.end as usize].iter().sum();
            assert_eq!(got_sizes, sizes[new.start as usize..new.end as usize], "rank {rank} sizes");
            assert_eq!(got_data, data[noff as usize..(noff + nlen) as usize], "rank {rank} bytes");
            (got_sizes, got_data)
        });
        // Rank-ordered concatenation reassembles the global stream: the
        // exchange is a pure re-windowing of the Morton-order sequence.
        let mut cat_sizes = Vec::new();
        let mut cat_data = Vec::new();
        for (s, d) in results {
            cat_sizes.extend(s);
            cat_data.extend(d);
        }
        assert_eq!(cat_sizes, *sizes, "ranks {ranks}: size stream reordered");
        assert_eq!(cat_data, *data, "ranks {ranks}: byte stream reordered");
    }
}

#[test]
fn by_bytes_is_balanced_within_one_max_element_weight() {
    let mut rng = Rng::new(0xba1a);
    for case in 0..32u64 {
        let n = 1 + rng.below(400) as usize;
        let weights: Vec<u64> = (0..n).map(|_| rng.below(1 << (1 + case % 12))).collect();
        let total: u64 = weights.iter().sum();
        let wmax = weights.iter().copied().max().unwrap_or(0);
        for ranks in 1..=8usize {
            let part = by_bytes(&weights, ranks);
            assert_eq!(part.total(), n as u64, "case {case} ranks {ranks}: lost elements");
            for rank in 0..ranks {
                let r = part.local_range(rank);
                let load: u64 = weights[r.start as usize..r.end as usize].iter().sum();
                let bound = total.div_ceil(ranks as u64) + wmax;
                assert!(
                    load <= bound,
                    "case {case} ranks {ranks} rank {rank}: load {load} > bound {bound}"
                );
            }
        }
    }
    // Degenerate: all-zero weights still partition every element.
    let zeros = vec![0u64; 17];
    for ranks in 1..=8usize {
        assert_eq!(by_bytes(&zeros, ranks).total(), 17);
    }
    // Empty input yields an empty but well-formed partition.
    let empty = by_bytes(&[], 4);
    assert_eq!(empty.total(), 0);
    assert_eq!(empty.num_ranks(), 4);
}

#[test]
fn scenario_weights_drive_a_balanced_partition() {
    // The real workload: the scenario's per-leaf checkpoint weights must
    // satisfy the same bound on the meshes the churn driver produces.
    let cfg = scda::runtime::ScenarioConfig::default();
    for cycle in 1..=4u64 {
        let leaves = scenario::mesh_at(&cfg, cycle);
        let weights = scenario::element_weights(&leaves, cfg.fixed_k, cfg.max_degree);
        let total: u64 = weights.iter().sum();
        let wmax = weights.iter().copied().max().unwrap();
        for &ranks in &[2usize, 4, 8] {
            let part = by_bytes(&weights, ranks);
            let bound = total.div_ceil(ranks as u64) + wmax;
            for rank in 0..ranks {
                let r = part.local_range(rank);
                let load: u64 = weights[r.start as usize..r.end as usize].iter().sum();
                assert!(load <= bound, "cycle {cycle} P{ranks} rank {rank}");
            }
        }
        // A uniform partition of the same stream must also be valid.
        assert_eq!(Partition::uniform(3, leaves.len() as u64).total(), leaves.len() as u64);
    }
}
