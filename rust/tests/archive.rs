//! Archive catalog layer: named datasets round-trip through the catalog,
//! the footer index makes `open_dataset` O(1) in the section count
//! (asserted via the `IoStats` syscall counters), plain scda files fall
//! back to the scan, and the `toc()` fast path agrees with the linear
//! scan it replaces.

use scda::api::{DataSrc, IoTuning, ScdaFile};
use scda::archive::Archive;
use scda::error::{corrupt, usage};
use scda::par::{Partition, SerialComm};
use scda::ScdaErrorKind;
use std::path::{Path, PathBuf};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-archive-test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

fn payload(n: usize, seed: u8) -> Vec<u8> {
    (0..n).map(|i| (i as u8).wrapping_mul(31).wrapping_add(seed)).collect()
}

#[test]
fn named_datasets_roundtrip_all_kinds() {
    let path = tmp("kinds");
    let part = Partition::uniform(1, 10);
    let arr = payload(10 * 16, 1);
    let sizes: Vec<u64> = (1..=10u64).collect();
    let var = payload(55, 2);
    let block = payload(500, 3);
    let inline = [7u8; 32];

    let mut ar = Archive::create(SerialComm::new(), &path, b"kinds").unwrap();
    ar.write_inline_from("meta", 0, Some(&inline)).unwrap();
    ar.write_block_from("params", 0, Some(&block), block.len() as u64, false).unwrap();
    ar.write_block_from("params.z", 0, Some(&block), block.len() as u64, true).unwrap();
    ar.write_array("fixed", DataSrc::Contiguous(&arr), &part, 16, false).unwrap();
    ar.write_array("fixed.z", DataSrc::Contiguous(&arr), &part, 16, true).unwrap();
    ar.write_varray("var", DataSrc::Contiguous(&var), &part, &sizes, false).unwrap();
    ar.write_varray("var.z", DataSrc::Contiguous(&var), &part, &sizes, true).unwrap();
    ar.finish().unwrap();

    // A catalog-bearing archive is a plain scda file: the strict
    // verifier accepts it unchanged (acceptance criterion).
    scda::api::verify_file(&path).unwrap();

    let mut ar = Archive::open(SerialComm::new(), &path).unwrap();
    assert!(ar.is_indexed(), "catalog should load through the footer index");
    let names: Vec<&str> = ar.datasets().iter().map(|d| d.name.as_str()).collect();
    assert_eq!(names, ["meta", "params", "params.z", "fixed", "fixed.z", "var", "var.z"]);
    // Datasets read back by name, in arbitrary order.
    assert_eq!(ar.read_varray("var.z", &part).unwrap(), (sizes.clone(), var.clone()));
    assert_eq!(ar.read_inline("meta", 0).unwrap(), Some(inline));
    assert_eq!(ar.read_array("fixed.z", &part, 16).unwrap(), arr);
    assert_eq!(ar.read_block("params", 0).unwrap().unwrap(), block);
    assert_eq!(ar.read_block("params.z", 0).unwrap().unwrap(), block);
    assert_eq!(ar.read_array("fixed", &part, 16).unwrap(), arr);
    assert_eq!(ar.read_varray("var", &part).unwrap(), (sizes.clone(), var.clone()));
    // Encoded datasets are flagged.
    assert!(ar.get("fixed.z").unwrap().encoded);
    assert!(!ar.get("fixed").unwrap().encoded);
    ar.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

fn build_numbered(path: &Path, datasets: usize) -> Vec<u8> {
    let part = Partition::uniform(1, 8);
    let data = payload(8 * 32, 9);
    let mut ar = Archive::create(SerialComm::new(), path, b"o1").unwrap();
    ar.file_mut().set_sync_on_close(false);
    for d in 0..datasets {
        ar.write_array(&format!("ds/{d}"), DataSrc::Contiguous(&data), &part, 32, false).unwrap();
    }
    ar.finish().unwrap();
    data
}

/// Open + read one named dataset under the direct engine (one pread per
/// logical access, so the counter is the access count). Returns reads.
fn count_reads(path: &Path, name: &str, data: &[u8], use_index: bool) -> u64 {
    let part = Partition::uniform(1, 8);
    let mut ar = Archive::open_with(SerialComm::new(), path, IoTuning::direct(), use_index).unwrap();
    assert_eq!(ar.is_indexed(), use_index);
    assert_eq!(ar.read_array(name, &part, 32).unwrap(), data);
    let reads = ar.file().io_stats().read_calls;
    ar.close().unwrap();
    reads
}

#[test]
fn open_dataset_is_o1_in_section_count() {
    let small = tmp("o1-small");
    let large = tmp("o1-large");
    let data_s = build_numbered(&small, 4);
    let data_l = build_numbered(&large, 64);

    // Acceptance criterion: the indexed path performs O(1) header reads —
    // the syscall count for open + read of the LAST dataset is identical
    // at 4 and at 64 sections (and small in absolute terms).
    let small_reads = count_reads(&small, "ds/3", &data_s, true);
    let large_reads = count_reads(&large, "ds/63", &data_l, true);
    assert_eq!(
        small_reads, large_reads,
        "indexed access must not depend on section count ({small_reads} vs {large_reads})"
    );
    assert!(small_reads <= 8, "indexed open+read should be a handful of preads, got {small_reads}");

    // The scan fallback is the contrast: linear in the section count.
    let small_scan = count_reads(&small, "ds/3", &data_s, false);
    let large_scan = count_reads(&large, "ds/63", &data_l, false);
    assert!(
        large_scan >= small_scan + 60,
        "scan reads should grow with sections ({small_scan} -> {large_scan})"
    );
    std::fs::remove_file(&small).unwrap();
    std::fs::remove_file(&large).unwrap();
}

#[test]
fn toc_fast_path_agrees_with_scan() {
    let path = tmp("tocfast");
    build_numbered(&path, 6);
    // Catalog-served toc (the file carries an index and the cursor is at
    // the first section).
    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    let fast = f.toc(true).unwrap();
    assert!(f.at_end().unwrap());
    f.close().unwrap();
    // Force the linear scan through the archive's escape hatch.
    let ar = Archive::open_with(SerialComm::new(), &path, IoTuning::default(), false).unwrap();
    assert!(!ar.is_indexed());
    let scanned: Vec<_> = ar.datasets().to_vec();
    ar.close().unwrap();
    // The fast path lists the six datasets plus the two trailer sections.
    assert_eq!(fast.len(), scanned.len() + 2);
    for (t, d) in fast.iter().zip(&scanned) {
        assert_eq!(t.header.user, d.name.as_bytes());
        assert_eq!(t.offset, d.offset);
        assert_eq!(t.byte_len, d.byte_len);
        assert_eq!(t.header.elem_count, d.elem_count);
        assert_eq!(t.header.elem_size, d.elem_size);
        assert_eq!(t.header.decoded, d.encoded);
    }
    assert_eq!(fast[6].header.user, b"scda:catalog");
    assert_eq!(fast[7].header.user, b"scda:index");
    // The trailer entries tile the file end exactly.
    let flen = std::fs::metadata(&path).unwrap().len();
    assert_eq!(fast[7].offset + fast[7].byte_len, flen);
    assert_eq!(fast[6].offset + fast[6].byte_len, fast[7].offset);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn plain_scda_files_fall_back_to_scan() {
    let path = tmp("plain");
    let part = Partition::uniform(1, 4);
    let data = payload(4 * 8, 5);
    // Written through the raw API: no catalog, no index.
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"plain").unwrap();
    f.write_array(DataSrc::Contiguous(&data), &part, 8, Some(b"named"), false).unwrap();
    f.write_block(b"blob", Some(b"")).unwrap(); // unnameable: empty user string
    f.close().unwrap();

    let mut ar = Archive::open(SerialComm::new(), &path).unwrap();
    assert!(!ar.is_indexed());
    // The named section is discovered; the anonymous one is skipped.
    assert_eq!(ar.datasets().len(), 1);
    assert_eq!(ar.read_array("named", &part, 8).unwrap(), data);
    ar.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn name_errors_have_stable_codes() {
    let path = tmp("names");
    let part = Partition::uniform(1, 2);
    let data = payload(2 * 4, 6);
    let mut ar = Archive::create(SerialComm::new(), &path, b"names").unwrap();
    ar.write_array("ok", DataSrc::Contiguous(&data), &part, 4, false).unwrap();
    // Duplicate, reserved, whitespace and empty names are usage errors
    // before anything reaches the file.
    for bad in ["ok", "scda:catalog", "scda:index", "has space", ""] {
        let err = ar.write_array(bad, DataSrc::Contiguous(&data), &part, 4, false).unwrap_err();
        assert_eq!(err.code(), 3000 + usage::BAD_DATASET_NAME, "{bad:?}");
    }
    ar.finish().unwrap();

    let mut ar = Archive::open(SerialComm::new(), &path).unwrap();
    let err = ar.open_dataset("missing").unwrap_err();
    assert_eq!(err.code(), 3000 + usage::NO_SUCH_DATASET);
    // Kind-mismatched typed reads are usage errors, not data corruption.
    let err = ar.read_block("ok", 0).unwrap_err();
    assert_eq!(err.kind(), ScdaErrorKind::Usage);
    // The file is still readable afterwards.
    assert_eq!(ar.read_array("ok", &part, 4).unwrap(), data);
    ar.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn catalog_trailer_is_ascii() {
    let path = tmp("ascii");
    build_numbered(&path, 3);
    // Locate the trailer via the toc and check every byte is ASCII: the
    // catalog layer must not make an ASCII file binary.
    let bytes = std::fs::read(&path).unwrap();
    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    let toc = f.toc(true).unwrap();
    f.close().unwrap();
    let catalog = &toc[toc.len() - 2];
    let index = &toc[toc.len() - 1];
    for e in [catalog, index] {
        let range = e.offset as usize..(e.offset + e.byte_len) as usize;
        assert!(bytes[range].is_ascii(), "{:?} section contains non-ASCII bytes", e.header.user);
    }
    assert_eq!(corrupt::BAD_CATALOG, 14, "stable code for catalog corruption");
    std::fs::remove_file(&path).unwrap();
}
