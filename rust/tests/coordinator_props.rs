//! Property tests on coordinator invariants (hand-rolled proptest-style
//! sweeps with `testutil::Rng`): pipeline ordering/backpressure under
//! randomized workloads, byte-balanced rebalancing quality, write
//! coalescing correctness against a reference file image, and
//! checkpoint manifests as pure functions of collective inputs.

use scda::coordinator::{by_bytes, map_ordered, PipelineOpts, WriteCoalescer};
use scda::par::{Communicator, ParallelFile, Partition, SerialComm};
use scda::testutil::Rng;

#[test]
fn prop_pipeline_is_a_pure_ordered_map() {
    let mut rng = Rng::new(0xF00D);
    for case in 0..20 {
        let n = rng.below(500) as usize;
        let workers = rng.range(1, 8) as usize;
        let depth = rng.below(8) as usize;
        let items: Vec<u64> = (0..n as u64).map(|_| rng.next_u64()).collect();
        let expect: Vec<u64> = items.iter().map(|x| x.wrapping_mul(31).rotate_left(7)).collect();
        let got: Vec<u64> = map_ordered(
            items.into_iter(),
            |x| x.wrapping_mul(31).rotate_left(7),
            PipelineOpts { workers, depth },
        )
        .collect();
        assert_eq!(got, expect, "case {case} workers {workers} depth {depth}");
    }
}

#[test]
fn prop_by_bytes_is_contiguous_complete_and_balanced() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..100 {
        let n = rng.below(2000) as usize;
        let ranks = rng.range(1, 16) as usize;
        // Mix of uniform and heavy-tailed sizes.
        let sizes: Vec<u64> = (0..n)
            .map(|_| if rng.below(10) == 0 { rng.below(10_000) } else { rng.below(50) })
            .collect();
        let part = by_bytes(&sizes, ranks);
        // Complete and contiguous by construction of Partition; check totals.
        assert_eq!(part.total(), n as u64);
        assert_eq!(part.num_ranks(), ranks);
        // Quality: max rank load <= ideal + max element size (the bound
        // for contiguous linear partitions).
        let total: u64 = sizes.iter().sum();
        let ideal = total as f64 / ranks as f64;
        let max_elem = sizes.iter().copied().max().unwrap_or(0);
        for r in 0..ranks {
            let range = part.local_range(r);
            let load: u64 = sizes[range.start as usize..range.end as usize].iter().sum();
            assert!(
                load as f64 <= ideal + max_elem as f64 + 1.0,
                "rank {r} load {load} ideal {ideal} max_elem {max_elem}"
            );
        }
    }
}

#[test]
fn prop_write_coalescer_equals_direct_writes() {
    let mut rng = Rng::new(0xC0DE);
    let dir = std::env::temp_dir().join("scda-coalprop");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..20 {
        let comm = SerialComm::new();
        assert_eq!(comm.rank(), 0);
        let pa = dir.join(format!("a-{case}-{}", std::process::id()));
        let pb = dir.join(format!("b-{case}-{}", std::process::id()));
        let fa = ParallelFile::create(&comm, &pa).unwrap();
        let fb = ParallelFile::create(&comm, &pb).unwrap();
        let mut co = WriteCoalescer::new(&fa);
        co.high_water = rng.range(64, 4096) as usize;
        // Random writes into a 16 KiB window; sequential semantics: the
        // coalescer must match issuing the same writes directly in order.
        let mut n_writes = 0;
        for _ in 0..rng.range(1, 60) {
            let off = rng.below(16 * 1024);
            let len = rng.range(1, 200) as usize;
            let data = rng.bytes(len, 256);
            co.write_at(off, &data).unwrap();
            fb.write_at(off, &data).unwrap();
            n_writes += 1;
        }
        co.flush().unwrap();
        assert!(co.flushes <= n_writes);
        let la = fa.len().unwrap();
        let lb = fb.len().unwrap();
        assert_eq!(la, lb, "case {case}");
        if la > 0 {
            assert_eq!(fa.read_vec(0, la as usize).unwrap(), fb.read_vec(0, lb as usize).unwrap(), "case {case}");
        }
        std::fs::remove_file(&pa).unwrap();
        std::fs::remove_file(&pb).unwrap();
    }
}

#[test]
fn prop_partition_roundtrip_owner_consistency() {
    // Routing invariant: owner_of is the inverse of local_range for every
    // element, for arbitrary partitions including empty ranks.
    let mut rng = Rng::new(0xAB);
    for _ in 0..200 {
        let total = rng.below(300);
        let ranks = rng.range(1, 12) as usize;
        let part = Partition::from_counts(&rng.partition(total, ranks));
        for rank in 0..ranks {
            for idx in part.local_range(rank) {
                assert_eq!(part.owner_of(idx), rank);
            }
        }
        let sum: u64 = (0..ranks).map(|r| part.count(r)).sum();
        assert_eq!(sum, total);
    }
}

#[test]
fn prop_transform_stream_stability_under_chunk_reslicing() {
    // Coordinator invariant for preconditioned payloads: transforming a
    // concatenation element-by-element equals concatenating transforms
    // (the whole reason checkpoints can decode per element on restart).
    use scda::runtime::{NativeTransform, Transform};
    let t = NativeTransform;
    let mut rng = Rng::new(0x77);
    for _ in 0..30 {
        let n_elems = rng.range(1, 10) as usize;
        let sizes: Vec<usize> = (0..n_elems).map(|_| rng.below(5000) as usize).collect();
        let elems: Vec<Vec<u8>> = sizes.iter().map(|&s| rng.bytes(s, 256)).collect();
        let per_elem: Vec<u8> = elems.iter().flat_map(|e| t.forward(e).unwrap().0).collect();
        // Roundtrip element-wise.
        let mut at = 0;
        for e in &elems {
            let back = t.inverse(&per_elem[at..at + e.len()]).unwrap();
            assert_eq!(&back, e);
            at += e.len();
        }
    }
}
