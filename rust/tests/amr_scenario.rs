//! End-to-end AMR churn soak: the scenario driver's full property sweep.
//!
//! For writer rank counts P ∈ {1, 2, 4, 8} the driver refines a moving
//! front, rebalances by payload bytes, and checkpoints — and this test
//! asserts the paper's claims on top of it:
//!
//! * an *uncrashed* run's archive is byte-identical at every writer P
//!   (serial equivalence — which is also what licenses the driver's
//!   serial crash replay);
//! * every bisected crash point recovers to exactly the committed-prefix
//!   dataset set, and each surviving *complete* step restores
//!   byte-identically on a different rank count P' ≠ P against an
//!   independently recomputed reference;
//! * `check_mesh` holds for every cycle's mesh (the driver additionally
//!   enforces it collectively after each refine);
//! * a torn tail *inside* an hp varray convention pair leaves the prior
//!   step's datasets intact.
//!
//! `SCDA_BENCH_QUICK=1` shrinks the sweeps for CI.

use scda::archive::{recover, restart, Archive};
use scda::bench_support::quick;
use scda::coordinator::FieldPayload;
use scda::mesh::check_mesh;
use scda::mesh::fields::{local_fixed_field, local_hp_field};
use scda::par::{run_parallel, Communicator, Partition, SerialComm};
use scda::runtime::scenario::{self, ScenarioConfig};
use scda::runtime::Identity;
use std::path::{Path, PathBuf};

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-amr-soak");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

/// The soak workload: small enough to sweep, churny enough that every
/// cycle's rebalance actually moves elements.
fn soak_cfg(writers: usize) -> ScenarioConfig {
    ScenarioConfig {
        cycles: if quick() { 2 } else { 3 },
        base_level: 1,
        max_level: 3,
        writers,
        restore_ranks: 3, // never equals a swept writer count
        crash_seed: None,
        ..Default::default()
    }
}

/// Breadth-first midpoint bisection of `[lo, hi)` (see
/// `tests/recover_soak.rs`): coarse coverage first, seams early.
fn bisect_offsets(lo: u64, hi: u64, budget: usize) -> Vec<u64> {
    let mut out = Vec::new();
    let mut queue = std::collections::VecDeque::from([(lo, hi)]);
    while out.len() < budget {
        let Some((a, b)) = queue.pop_front() else { break };
        if b <= a + 1 {
            continue;
        }
        let mid = a + (b - a) / 2;
        out.push(mid);
        queue.push_back((a, mid));
        queue.push_back((mid, b));
    }
    out
}

/// Dataset extents `(name, end_offset)` in file order.
fn extents(path: &Path) -> Vec<(String, u64)> {
    let ar = Archive::open(SerialComm::new(), path).unwrap();
    let e = ar.datasets().iter().map(|d| (d.name.clone(), d.offset + d.byte_len)).collect();
    ar.close().unwrap();
    e
}

/// Steps whose complete dataset set (info, manifest, both fields)
/// survived in the archive at `path`.
fn complete_steps(path: &Path) -> Vec<u64> {
    let ar = Archive::open(SerialComm::new(), path).unwrap();
    let steps = restart::list_steps(&ar)
        .into_iter()
        .filter(|&s| {
            ar.get(&restart::info_name(s)).is_some()
                && ar.get(&restart::manifest_name(s)).is_some()
                && ar.get(&restart::field_name(s, scenario::FIXED_FIELD)).is_some()
                && ar.get(&restart::field_name(s, scenario::HP_FIELD)).is_some()
        })
        .collect();
    ar.close().unwrap();
    steps
}

/// Restore `steps` on `ranks` reader ranks and verify each rank's window
/// of both fields byte-for-byte against an independent recomputation
/// from `(seed, step)` alone.
fn restore_and_verify(path: &Path, cfg: &ScenarioConfig, steps: &[u64], ranks: usize) {
    let cfg = *cfg;
    let path = path.to_path_buf();
    let steps = steps.to_vec();
    run_parallel(ranks, move |comm| {
        let rank = comm.rank();
        let mut ar = Archive::open(comm, &path).unwrap();
        for &step in &steps {
            let leaves = scenario::mesh_at(&cfg, step);
            let part = Partition::uniform(ranks, leaves.len() as u64);
            let r = part.local_range(rank);
            let window = r.start as usize..r.end as usize;
            let (info, fields) = restart::read_step(&mut ar, Some(step), &part, &Identity)
                .unwrap_or_else(|e| panic!("step {step} on P'={ranks}: {e}"));
            assert_eq!(info.step, step);
            assert_eq!(fields.len(), 2, "step {step}: field count");
            let fixed_ref = local_fixed_field(&leaves, window.clone(), cfg.fixed_k);
            let (hp_sizes_ref, hp_ref) = local_hp_field(&leaves, window, cfg.max_degree);
            for f in &fields {
                match (&*f.name, &f.payload) {
                    (scenario::FIXED_FIELD, FieldPayload::Fixed { elem_size, data }) => {
                        assert_eq!(*elem_size, (cfg.fixed_k * 8) as u64, "step {step} rho elem");
                        assert_eq!(*data, fixed_ref, "step {step} rank {rank}: rho bytes");
                    }
                    (scenario::HP_FIELD, FieldPayload::Var { sizes, data }) => {
                        assert_eq!(*sizes, hp_sizes_ref, "step {step} rank {rank}: hp sizes");
                        assert_eq!(*data, hp_ref, "step {step} rank {rank}: hp bytes");
                    }
                    (name, _) => panic!("step {step}: unexpected field {name}"),
                }
            }
        }
        ar.close().unwrap();
    });
}

#[test]
fn uncrashed_archive_is_byte_identical_at_every_writer_p() {
    let mut baseline: Option<Vec<u8>> = None;
    for &writers in &[1usize, 2, 4, 8] {
        let cfg = soak_cfg(writers);
        // Every cycle's mesh is valid — checked here independently of
        // the driver's own collective check.
        for cycle in 1..=cfg.cycles as u64 {
            assert!(check_mesh(&scenario::mesh_at(&cfg, cycle)), "cycle {cycle}");
        }
        let path = tmp(&format!("ident-{writers}"));
        // run_scenario's restore leg already verifies every step on
        // P' = 3 against the recomputed reference.
        let report = scenario::run_scenario(&path, &cfg).unwrap();
        assert_eq!(report.restore.steps, cfg.cycles as u64);
        let bytes = std::fs::read(&path).unwrap();
        match &baseline {
            None => baseline = Some(bytes),
            Some(b) => assert_eq!(
                &bytes, b,
                "P={writers} archive differs from P=1 (serial equivalence broken)"
            ),
        }
        std::fs::remove_file(&path).unwrap();
    }
}

#[test]
fn crash_bisection_sweep_recovers_committed_prefix_and_restores_on_other_p() {
    for &writers in &[1usize, 2, 4, 8] {
        let cfg = soak_cfg(writers);
        let path = tmp(&format!("sweep-{writers}"));
        scenario::run_scenario(&path, &cfg).unwrap();
        let good = std::fs::read(&path).unwrap();
        let ext = extents(&path);
        let len = good.len() as u64;
        let budget = if quick() { 8 } else { 20 };
        let mut cuts = bisect_offsets(128, len, budget);
        // Dataset seams: the offsets most likely to expose an off-by-one
        // in trailer reconstruction.
        for (_, end) in &ext {
            cuts.extend([end.saturating_sub(1), *end, end + 1]);
        }
        cuts.retain(|&c| (128..len).contains(&c));
        cuts.sort_unstable();
        cuts.dedup();
        let scratch = tmp(&format!("sweep-{writers}-cut"));
        let mut restored_any = false;
        for &cut in &cuts {
            std::fs::write(&scratch, &good[..cut as usize]).unwrap();
            let rep = recover(&scratch)
                .unwrap_or_else(|e| panic!("P={writers} cut {cut}: recover failed: {e}"));
            // Exactly the datasets whose full extent precedes the cut.
            let expected: Vec<&str> =
                ext.iter().filter(|(_, end)| *end <= cut).map(|(n, _)| n.as_str()).collect();
            assert_eq!(rep.datasets, expected, "P={writers} cut {cut}: survivor set");
            scda::api::verify_file(&scratch)
                .unwrap_or_else(|e| panic!("P={writers} cut {cut}: unclean after recovery: {e}"));
            // Every complete surviving step restores byte-identically on
            // P' = 3 ≠ P.
            let steps = complete_steps(&scratch);
            assert!(steps.len() as u32 <= cfg.cycles, "P={writers} cut {cut}");
            if !steps.is_empty() {
                restore_and_verify(&scratch, &cfg, &steps, cfg.restore_ranks);
                restored_any = true;
            }
        }
        assert!(restored_any, "P={writers}: no cut ever left a restorable step");
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_file(&scratch).unwrap();
    }
}

#[test]
fn seeded_in_driver_crash_recovers_and_verifies() {
    let seeds: &[u64] = if quick() { &[0xC4A5] } else { &[0xC4A5, 7, 131] };
    for &writers in &[2usize, 4] {
        for &seed in seeds {
            let cfg = ScenarioConfig {
                crash_seed: Some(seed),
                crash_max_trigger: 48,
                ..soak_cfg(writers)
            };
            let path = tmp(&format!("drv-{writers}-{seed}"));
            let report = scenario::run_scenario(&path, &cfg)
                .unwrap_or_else(|e| panic!("P={writers} seed {seed:#x}: {e}"));
            let rec = report.recover.expect("crash leg ran");
            assert!(rec.steps_survived <= cfg.cycles as u64, "P={writers} seed {seed:#x}");
            // The driver already restored every surviving complete step
            // on P' = 3 and compared bytes; the crash file must also be
            // verify-clean now.
            let crash = scenario::crash_path(&path);
            scda::api::verify_file(&crash).unwrap();
            std::fs::remove_file(&path).unwrap();
            std::fs::remove_file(&crash).unwrap();
        }
    }
}

/// Satellite: a torn tail *inside* the step-2 hp varray's convention
/// pair (sizes row + payload of an encoded V section) must leave every
/// step-1 dataset intact and restorable.
#[test]
fn torn_hp_convention_pair_preserves_prior_step() {
    let cfg = soak_cfg(2);
    let path = tmp("hp-pair");
    scenario::run_scenario(&path, &cfg).unwrap();
    let good = std::fs::read(&path).unwrap();
    let ext = extents(&path);
    let hp2 = restart::field_name(2, scenario::HP_FIELD);
    let (hp_start, hp_end) = {
        let ar = Archive::open(SerialComm::new(), &path).unwrap();
        let d = ar.get(&hp2).unwrap_or_else(|| panic!("{hp2} missing"));
        let se = (d.offset, d.offset + d.byte_len);
        assert!(d.encoded, "hp field should be an encoded convention pair");
        ar.close().unwrap();
        se
    };
    let scratch = tmp("hp-pair-cut");
    for cut in [hp_start + 1, hp_start + (hp_end - hp_start) / 2, hp_end - 1] {
        std::fs::write(&scratch, &good[..cut as usize]).unwrap();
        recover(&scratch).unwrap_or_else(|e| panic!("cut {cut}: {e}"));
        scda::api::verify_file(&scratch).unwrap();
        // All of step 1 survives; step 2 is incomplete (its hp is torn).
        let steps = complete_steps(&scratch);
        assert!(steps.contains(&1), "cut {cut}: step 1 lost ({steps:?})");
        assert!(!steps.contains(&2), "cut {cut}: torn step 2 reported complete");
        // Step 1's datasets are byte-identical to the uncut archive's.
        let survivors = extents(&scratch);
        for (name, end) in &survivors {
            let orig = ext.iter().find(|(n, _)| n == name).unwrap();
            assert_eq!(*end, orig.1, "cut {cut}: {name} extent moved");
        }
        restore_and_verify(&scratch, &cfg, &[1], cfg.restore_ranks);
    }
    std::fs::remove_file(&path).unwrap();
    std::fs::remove_file(&scratch).unwrap();
}
