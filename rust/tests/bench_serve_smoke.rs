//! Read-service bench smoke: exercises the concurrent shared-cache vs
//! per-session-sieve harness end to end and records `BENCH_serve.json`
//! so the serve trajectory is tracked from this PR onward.
//!
//! The quick bench is `#[ignore]`d so `cargo test -q` stays fast; run
//! with `cargo test --test bench_serve_smoke -- --ignored`.

use scda::bench_support::{bench_serve_json_path, serve_bench};

#[test]
fn serve_bench_harness_roundtrips_tiny_workload() {
    // Non-ignored correctness pass at a size too small to be a
    // benchmark: both modes must serve the same bytes (asserted inside
    // `run_one`), preads must cover the workload, and the report must
    // carry the sweep's field set.
    let dir = std::env::temp_dir().join("scda-serve-smoke");
    std::fs::create_dir_all(&dir).unwrap();
    let profiles = serve_bench::run(4, 256, 32, 40, 8);
    assert_eq!(profiles.len(), serve_bench::SESSIONS.len() * serve_bench::BUDGETS.len());
    for p in &profiles {
        assert_eq!(p.requests, p.sessions as u64 * 40);
        assert!(p.unique_bytes > 0);
        assert!(p.shared_preads > 0 && p.baseline_preads > 0);
        assert!(p.cache_hits + p.cache_misses > 0, "shared run touched the cache: {p:?}");
    }
    // The shared pool dedupes across sessions: at 8 sessions the cache
    // absorbs re-reads, so shared preads stay below the baseline's.
    let p8 = profiles
        .iter()
        .find(|p| p.sessions == 8 && p.budget_bytes == serve_bench::BUDGETS[1])
        .unwrap();
    assert!(
        p8.shared_preads < p8.baseline_preads,
        "shared {} vs baseline {}",
        p8.shared_preads,
        p8.baseline_preads
    );
    let r = serve_bench::report(&profiles, 4, 256, 32, 40).render();
    assert!(r.contains("\"bench\": \"serve\""));
    for s in serve_bench::SESSIONS {
        for b in serve_bench::BUDGETS {
            assert!(r.contains(&format!("\"serve_s{s}_b{b}\"")), "missing entry s{s} b{b}");
        }
    }
    for field in ["shared_rps", "shared_p50_us", "shared_p99_us", "baseline_preads", "single_flight_waits"] {
        assert!(r.contains(&format!("\"{field}\"")), "missing field {field}");
    }
}

#[test]
#[ignore = "perf smoke; run with -- --ignored"]
fn serve_bench_quick_records_json() {
    let profiles = serve_bench::run_quick();
    for p in &profiles {
        assert!(p.shared_rps > 0.0 && p.baseline_rps > 0.0);
        assert!(p.shared_p50_us <= p.shared_p99_us);
    }
    let path = bench_serve_json_path();
    serve_bench::report(&profiles, 8, 2048, 64, 200).write(&path).unwrap();
    let written = std::fs::read_to_string(&path).unwrap();
    assert!(written.contains("\"bench\": \"serve\""));
    for p in &profiles {
        println!(
            "serve quick: s={} b={} shared {:.0} req/s / {} preads, baseline {:.0} req/s / {} preads ({:.2}x)",
            p.sessions, p.budget_bytes, p.shared_rps, p.shared_preads, p.baseline_rps,
            p.baseline_preads, p.speedup()
        );
    }
    println!("wrote {}", path.display());
}
