//! Archive bench smoke: exercises the indexed-vs-scan measurement
//! harness end to end and records `BENCH_archive.json` so the catalog
//! random-access trajectory is tracked from this PR onward.
//!
//! The quick bench is `#[ignore]`d so `cargo test -q` stays fast; run
//! with `cargo test --test bench_archive_smoke -- --ignored`.

use scda::bench_support::{archive_bench, bench_archive_json_path};

#[test]
fn archive_bench_harness_roundtrips_tiny_workload() {
    // Non-ignored correctness pass at a size too small to be a
    // benchmark: checks the access accounting and the report shape
    // without timing assertions.
    let profiles =
        vec![archive_bench::random_access(4, 8, 64, 1), archive_bench::random_access(32, 8, 64, 1)];
    // The O(1) shape: indexed reads identical at both section counts,
    // scan reads growing with them.
    assert_eq!(profiles[0].indexed_reads, profiles[1].indexed_reads);
    assert!(profiles[1].scan_reads > profiles[0].scan_reads + 20);
    let r = archive_bench::report(&profiles).render();
    assert!(r.contains("\"bench\": \"archive\""));
    assert!(r.contains("\"open_dataset_4\""));
    assert!(r.contains("\"open_dataset_32\""));
    assert!(r.contains("\"indexed_reads\""));
    assert!(r.contains("\"scan_reads\""));
}

#[test]
#[ignore = "perf smoke; run with -- --ignored"]
fn archive_bench_quick_records_json() {
    let profiles = archive_bench::run_quick();
    for p in &profiles {
        assert!(p.indexed_ms > 0.0 && p.scan_ms > 0.0);
    }
    let path = bench_archive_json_path();
    archive_bench::report(&profiles).write(&path).unwrap();
    let written = std::fs::read_to_string(&path).unwrap();
    assert!(written.contains("\"bench\": \"archive\""));
    for p in &profiles {
        println!(
            "archive quick: S={} indexed {:.3} ms / {} preads, scan {:.3} ms / {} preads ({:.1}x)",
            p.datasets, p.indexed_ms, p.indexed_reads, p.scan_ms, p.scan_reads, p.speedup()
        );
    }
    println!("wrote {}", path.display());
}
