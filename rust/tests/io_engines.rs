//! The I/O engine contract (see `crate::io`): every engine — direct,
//! aggregated, collective, each with sync and async flush — produces
//! byte-identical files at 1, 2, 4 and 8 ranks across interleaved
//! sections; the collective engine's write-syscall count is independent
//! of section interleaving; retuning mid-write is invisible in the
//! bytes; and background-flush errors are surfaced, not dropped — at
//! `flush`/`close` for live handles, via `take_drop_error` for dropped
//! ones.

use scda::api::{DataSrc, IoTuning, ScdaFile};
use scda::par::{run_parallel, Communicator, IoStats, Partition, SerialComm};
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-io-engines");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

/// An interleaved section stream: inline, block, fixed array, then
/// `sections` varrays of small indirect elements — every rank's extents
/// interleave with every other rank's in each section.
fn write_workload(
    path: &Arc<PathBuf>,
    ranks: usize,
    sections: usize,
    elems_total: usize,
    elem_bytes: usize,
    tuning: IoTuning,
) -> Vec<IoStats> {
    let path = Arc::clone(path);
    run_parallel(ranks, move |comm| {
        let rank = comm.rank();
        let part = Partition::uniform(ranks, elems_total as u64);
        let local = part.count(rank) as usize;
        let first = part.offset(rank) as usize;
        let mut f = ScdaFile::create(comm, &**path, b"io-engines").unwrap();
        f.set_sync_on_close(false);
        f.set_io_tuning(tuning).unwrap();
        f.write_inline(&[b'i'; 32], Some(b"inline")).unwrap();
        let block: Vec<u8> = (0..300usize).map(|i| (i % 251) as u8).collect();
        f.write_block_from(0, Some(&block), 300, Some(b"block"), false).unwrap();
        let adata: Vec<u8> = (0..local * 8).map(|i| ((first * 8 + i) % 251) as u8).collect();
        f.write_array(DataSrc::Contiguous(&adata), &part, 8, Some(b"arr"), false).unwrap();
        let owned: Vec<Vec<u8>> =
            (0..local).map(|i| vec![((first + i) % 251) as u8; elem_bytes]).collect();
        let views: Vec<&[u8]> = owned.iter().map(|e| e.as_slice()).collect();
        let sizes = vec![elem_bytes as u64; local];
        for _ in 0..sections {
            f.write_varray(DataSrc::Indirect(&views), &part, &sizes, Some(b"var"), false).unwrap();
        }
        f.flush().unwrap();
        let st = f.io_stats();
        f.close().unwrap();
        st
    })
}

/// The acceptance property: every engine configuration is byte-identical
/// to the direct reference path at 1, 2, 4 and 8 ranks.
#[test]
fn all_engines_byte_identical_to_direct_at_1_2_4_8_ranks() {
    let configs: Vec<(&str, IoTuning)> = vec![
        ("aggregated", IoTuning::default()),
        ("aggregated_async", IoTuning::default().with_async_flush(true)),
        ("collective", IoTuning::collective().with_stripe_size(4 << 10)),
        ("collective_async", IoTuning::collective().with_stripe_size(4 << 10).with_async_flush(true)),
    ];
    for ranks in [1usize, 2, 4, 8] {
        let pd = Arc::new(tmp(&format!("ref-{ranks}")));
        write_workload(&pd, ranks, 4, 64, 48, IoTuning::direct());
        let reference = std::fs::read(&*pd).unwrap();
        scda::api::verify_bytes(&reference).unwrap();
        for (name, tuning) in &configs {
            let pe = Arc::new(tmp(&format!("{name}-{ranks}")));
            write_workload(&pe, ranks, 4, 64, 48, *tuning);
            let got = std::fs::read(&*pe).unwrap();
            assert_eq!(got, reference, "{name} differs from direct at ranks={ranks}");
            std::fs::remove_file(&*pe).unwrap();
        }
        std::fs::remove_file(&*pd).unwrap();
    }
}

/// Two-phase payoff: the collective engine's write-syscall count is a
/// pure function of the file size (one `pwrite` per 4 KiB stripe, plus
/// the one pre-retune header flush), independent of how many sections
/// interleave the ranks and of the rank count itself — while the direct
/// path's count tracks both.
#[test]
fn collective_write_calls_independent_of_section_interleaving() {
    const STRIPE: u64 = 4 << 10;
    let tuning = IoTuning::collective().with_stripe_size(STRIPE as usize);
    let count = |path: &Arc<PathBuf>, ranks, sections, elems, t: IoTuning| {
        let st = write_workload(path, ranks, sections, elems, 64, t);
        let len = std::fs::metadata(&***path).unwrap().len();
        std::fs::remove_file(&***path).unwrap();
        (st.iter().map(|s| s.write_calls).sum::<u64>(), len)
    };
    // Same section shape, increasing interleaving (P = 2, 4, 8): the
    // file bytes are identical (serial equivalence), and so must be the
    // collective syscall total — at P >= 2 adjacent stripes never share
    // an owner, so each touched stripe is exactly one pwrite.
    let mut per_p = Vec::new();
    for ranks in [2usize, 4, 8] {
        let p = Arc::new(tmp(&format!("ilv-p{ranks}")));
        per_p.push(count(&p, ranks, 4, 128, tuning));
    }
    assert_eq!(per_p[0], per_p[1], "collective calls must not depend on the rank count");
    assert_eq!(per_p[1], per_p[2], "collective calls must not depend on the rank count");
    // Two section interleavings of the same payload at P = 4: the counts
    // equal the stripe-count formula for each file — syscalls are a
    // function of file size, never of access pattern. (The +1 is the
    // file-header extent flushed by the default engine before the
    // mid-file retune to the collective one.)
    for (i, (sections, elems)) in [(4usize, 128usize), (8, 64)].into_iter().enumerate() {
        let pc = Arc::new(tmp(&format!("ilv-col-{i}")));
        let (calls, len) = count(&pc, 4, sections, elems, tuning);
        assert_eq!(calls, len.div_ceil(STRIPE) + 1, "shape {i}: one pwrite per touched stripe");
        let pd = Arc::new(tmp(&format!("ilv-dir-{i}")));
        let (direct_calls, _) = count(&pd, 4, sections, elems, IoTuning::direct());
        assert!(
            calls * 10 <= direct_calls,
            "shape {i}: collective {calls} vs direct {direct_calls}"
        );
    }
}

/// Retuning between engines mid-file is invisible in the bytes.
#[test]
fn mid_write_engine_retune_keeps_bytes_identical() {
    let part = Partition::uniform(1, 8);
    let sizes = vec![5u64; 8];
    let payload: Vec<u8> = (0..40u8).collect();
    let mut files = Vec::new();
    for (i, retune) in [(0, true), (1, false)] {
        let path = tmp(&format!("retune-{i}"));
        let mut f = ScdaFile::create(SerialComm::new(), &path, b"retune").unwrap();
        f.set_sync_on_close(false);
        if !retune {
            f.set_io_tuning(IoTuning::direct()).unwrap();
        }
        f.write_varray(DataSrc::Contiguous(&payload), &part, &sizes, Some(b"v1"), false).unwrap();
        if retune {
            // Aggregating -> collective(async) -> direct, one section each.
            f.set_io_tuning(IoTuning::collective().with_stripe_size(4096).with_async_flush(true))
                .unwrap();
        }
        f.write_varray(DataSrc::Contiguous(&payload), &part, &sizes, Some(b"v2"), false).unwrap();
        if retune {
            f.set_io_tuning(IoTuning::direct()).unwrap();
        }
        f.write_varray(DataSrc::Contiguous(&payload), &part, &sizes, Some(b"v3"), false).unwrap();
        f.close().unwrap();
        files.push(path);
    }
    assert_eq!(std::fs::read(&files[0]).unwrap(), std::fs::read(&files[1]).unwrap());
    for p in files {
        std::fs::remove_file(&p).unwrap();
    }
}

/// Reading through every engine returns the same payloads as direct.
#[test]
fn engine_reads_match_direct_including_varray_into() {
    let path = Arc::new(tmp("reads"));
    write_workload(&path, 2, 4, 64, 48, IoTuning::default());
    let read_all = |tuning: IoTuning| -> Vec<Vec<u8>> {
        let mut f = ScdaFile::open(SerialComm::new(), &*path).unwrap();
        f.set_io_tuning(tuning).unwrap();
        let part = Partition::uniform(1, 64);
        let mut out = Vec::new();
        f.read_section_header(false).unwrap();
        out.push(f.read_inline_data(0, true).unwrap().unwrap().to_vec());
        f.read_section_header(false).unwrap();
        out.push(f.read_block_data(0, true).unwrap().unwrap());
        f.read_section_header(false).unwrap();
        let mut abuf = vec![0u8; 64 * 8];
        f.read_array_data_into(&part, 8, &mut abuf).unwrap();
        out.push(abuf);
        for _ in 0..4 {
            f.read_section_header(false).unwrap();
            let sizes = f.read_varray_sizes(&part).unwrap();
            // The caller-buffer varray read is the unit under test here.
            let mut vbuf = vec![0u8; sizes.iter().sum::<u64>() as usize];
            f.read_varray_data_into(&part, &sizes, &mut vbuf).unwrap();
            out.push(vbuf);
        }
        assert!(f.at_end().unwrap());
        f.close().unwrap();
        out
    };
    let direct = read_all(IoTuning::direct());
    assert_eq!(read_all(IoTuning::default()), direct);
    assert_eq!(read_all(IoTuning::collective()), direct);
    std::fs::remove_file(&*path).unwrap();
}

/// `read_varray_data_into` is strict about buffer size and call order.
#[test]
fn read_varray_data_into_validates_and_handles_decoded() {
    let path = tmp("varray-into");
    let part = Partition::uniform(1, 6);
    let sizes: Vec<u64> = vec![3, 0, 7, 11, 2, 9];
    let total: u64 = sizes.iter().sum();
    let payload: Vec<u8> = (0..total as u8).collect();
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"vi").unwrap();
    f.set_sync_on_close(false);
    f.write_varray(DataSrc::Contiguous(&payload), &part, &sizes, Some(b"raw"), false).unwrap();
    f.write_varray(DataSrc::Contiguous(&payload), &part, &sizes, Some(b"enc"), true).unwrap();
    f.close().unwrap();

    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    // Raw section into the caller's buffer.
    f.read_section_header(false).unwrap();
    let got_sizes = f.read_varray_sizes(&part).unwrap();
    assert_eq!(got_sizes, sizes);
    let mut buf = vec![0u8; total as usize];
    f.read_varray_data_into(&part, &got_sizes, &mut buf).unwrap();
    assert_eq!(buf, payload);
    // Decoded (convention 10) section through the same API.
    let h = f.read_section_header(true).unwrap();
    assert!(h.decoded);
    let got_sizes = f.read_varray_sizes(&part).unwrap();
    assert_eq!(got_sizes, sizes, "decoded sizes are the uncompressed ones");
    buf.fill(0);
    f.read_varray_data_into(&part, &got_sizes, &mut buf).unwrap();
    assert_eq!(buf, payload);
    assert!(f.at_end().unwrap());
    f.close().unwrap();

    // Wrong buffer size is a usage error; before sizes is a usage error.
    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    f.read_section_header(false).unwrap();
    let mut short = vec![0u8; 3];
    assert_eq!(
        f.read_varray_data_into(&part, &sizes, &mut short).unwrap_err().kind(),
        scda::ScdaErrorKind::Usage
    );
    std::fs::remove_file(&path).unwrap();
}

/// A failed background flush surfaces at the next collective barrier
/// (`flush`), is consumed exactly once, and never panics.
#[test]
fn background_flush_error_surfaces_at_flush() {
    for tuning in [
        IoTuning::default().with_async_flush(true),
        IoTuning::collective().with_stripe_size(4096).with_async_flush(true),
    ] {
        let path = tmp("bg-error");
        let part = Partition::uniform(1, 8);
        let sizes = vec![16u64; 8];
        let payload = vec![0xA5u8; 128];
        let mut f = ScdaFile::create(SerialComm::new(), &path, b"bg").unwrap();
        f.set_sync_on_close(false);
        f.set_io_tuning(tuning).unwrap();
        f.write_varray(DataSrc::Contiguous(&payload), &part, &sizes, Some(b"v"), false).unwrap();
        // Everything below the staging capacity is still staged: poison
        // the file so the background pwrites fail.
        f.inject_write_failure(0);
        let err = f.flush().unwrap_err();
        assert_eq!(err.kind(), scda::ScdaErrorKind::Io);
        // Surfaced once: the deferred-error slot is now empty. (The
        // global drop-error sink is left alone here — polling it would
        // race with the dedicated drop-path test on another thread; the
        // no-re-report property is covered by the per-file slot being
        // empty when the handle drops.)
        assert!(f.take_error().is_none());
        f.inject_write_failure(u64::MAX);
        drop(f);
        std::fs::remove_file(&path).ok();
    }
}

/// Dropping a write-mode file whose staged flush then fails records the
/// error for `take_drop_error` instead of swallowing it.
#[test]
fn dropped_file_with_failed_flush_records_error() {
    let path = tmp("drop-error");
    let part = Partition::uniform(1, 4);
    let sizes = vec![32u64; 4];
    let payload = vec![0x5Au8; 128];
    {
        let mut f = ScdaFile::create(SerialComm::new(), &path, b"drop").unwrap();
        f.set_sync_on_close(false);
        f.write_varray(DataSrc::Contiguous(&payload), &part, &sizes, Some(b"v"), false).unwrap();
        f.inject_write_failure(0);
        // Dropped without close: the staged extents fail to drain.
    }
    let e = scda::io::take_drop_error().expect("drop path must record the failed flush");
    assert_eq!(e.kind(), scda::ScdaErrorKind::Io);
    assert!(scda::io::take_drop_error().is_none(), "recorded exactly once");
    // A clean close afterwards leaves nothing behind.
    {
        let mut f = ScdaFile::create(SerialComm::new(), &path, b"drop").unwrap();
        f.set_sync_on_close(false);
        f.write_varray(DataSrc::Contiguous(&payload), &part, &sizes, Some(b"v"), false).unwrap();
        f.close().unwrap();
    }
    assert!(scda::io::take_drop_error().is_none());
    std::fs::remove_file(&path).unwrap();
}
