//! Serial equivalence and partition independence of the archive layer:
//! a file written through `Archive` on 1/2/4/8 ranks is byte-identical
//! to the serial archive image (the catalog is a pure function of
//! collective inputs), `open_dataset` round-trips under mismatched
//! writer/reader rank counts, and versioned checkpoint steps restore by
//! name on any rank count — including files written by the pre-archive
//! checkpoint layout (scan fallback).

use scda::api::{DataSrc, ScdaFile};
use scda::archive::{restart, Archive};
use scda::bench_support::sha256;
use scda::coordinator::checkpoint::{read_checkpoint, Field, FieldPayload};
use scda::coordinator::Metrics;
use scda::par::{run_parallel, Communicator, Partition, SerialComm};
use scda::runtime::Identity;
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-archive-eq");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

const N: u64 = 60;
const E: u64 = 16;

fn global_fixed() -> Vec<u8> {
    (0..N * E).map(|i| (i * 11 % 253) as u8).collect()
}

fn global_sizes() -> Vec<u64> {
    (0..N).map(|i| (i * 7) % 23).collect()
}

fn global_var() -> Vec<u8> {
    let total: u64 = global_sizes().iter().sum();
    (0..total).map(|i| (i * 5 % 249) as u8).collect()
}

/// Write the reference archive on `ranks` ranks: one raw array, one
/// encoded array, one varray, all named.
fn write_archive(path: &PathBuf, ranks: usize) {
    let path = path.clone();
    let (fixed, sizes, var) = (Arc::new(global_fixed()), Arc::new(global_sizes()), Arc::new(global_var()));
    run_parallel(ranks, move |comm| {
        let part = Partition::uniform(ranks, N);
        let r = part.local_range(comm.rank());
        let local_fixed = &fixed[(r.start * E) as usize..(r.end * E) as usize];
        let local_sizes = &sizes[r.start as usize..r.end as usize];
        let lo: u64 = sizes[..r.start as usize].iter().sum();
        let len: u64 = local_sizes.iter().sum();
        let local_var = &var[lo as usize..(lo + len) as usize];
        let mut ar = Archive::create(comm, &path, b"eq").unwrap();
        ar.write_array("grid", DataSrc::Contiguous(local_fixed), &part, E, false).unwrap();
        ar.write_array("grid.z", DataSrc::Contiguous(local_fixed), &part, E, true).unwrap();
        ar.write_varray("hp", DataSrc::Contiguous(local_var), &part, local_sizes, false).unwrap();
        ar.finish().unwrap();
    });
}

#[test]
fn archive_bytes_identical_at_any_writer_rank_count() {
    let mut hashes = Vec::new();
    for ranks in [1usize, 2, 4, 8] {
        let path = tmp(&format!("id-{ranks}"));
        write_archive(&path, ranks);
        scda::api::verify_file(&path).unwrap();
        hashes.push(sha256(&std::fs::read(&path).unwrap()));
        std::fs::remove_file(&path).unwrap();
    }
    assert!(hashes.windows(2).all(|h| h[0] == h[1]), "archive bytes depend on writer rank count");
}

#[test]
fn open_dataset_roundtrips_on_mismatched_rank_counts() {
    let path = tmp("mismatch");
    write_archive(&path, 3);
    for reader_ranks in [1usize, 2, 5, 8] {
        let p = path.clone();
        let windows = run_parallel(reader_ranks, move |comm| {
            let part = Partition::uniform(reader_ranks, N);
            let mut ar = Archive::open(comm, &p).unwrap();
            assert!(ar.is_indexed());
            // By-name access, out of file order, on a partition the
            // writer never saw.
            let enc = ar.read_array("grid.z", &part, E).unwrap();
            let raw = ar.read_array("grid", &part, E).unwrap();
            let (sizes, var) = ar.read_varray("hp", &part).unwrap();
            assert_eq!(enc, raw);
            ar.close().unwrap();
            (raw, sizes, var)
        });
        let mut fixed = Vec::new();
        let mut sizes = Vec::new();
        let mut var = Vec::new();
        for (f, s, v) in windows {
            fixed.extend_from_slice(&f);
            sizes.extend_from_slice(&s);
            var.extend_from_slice(&v);
        }
        assert_eq!(fixed, global_fixed(), "reader ranks {reader_ranks}");
        assert_eq!(sizes, global_sizes(), "reader ranks {reader_ranks}");
        assert_eq!(var, global_var(), "reader ranks {reader_ranks}");
    }
    std::fs::remove_file(&path).unwrap();
}

fn step_fields(seed: u8, part: &Partition, rank: usize) -> Vec<Field> {
    let r = part.local_range(rank);
    let data: Vec<u8> =
        ((r.start * 8)..(r.end * 8)).map(|i| (i as u8).wrapping_mul(3).wrapping_add(seed)).collect();
    vec![Field {
        name: "rho".into(),
        encode: seed % 2 == 0,
        precondition: false,
        payload: FieldPayload::Fixed { elem_size: 8, data },
    }]
}

#[test]
fn versioned_steps_restore_by_name_on_any_rank_count() {
    let path = tmp("steps");
    {
        let p = path.clone();
        run_parallel(4, move |comm| {
            let part = Partition::uniform(4, N);
            let mut ar = Archive::create(comm, &p, b"multi-step").unwrap();
            for (step, seed) in [(10u64, 1u8), (20, 2)] {
                let fields = step_fields(seed, &part, ar.file().comm().rank());
                restart::write_step(&mut ar, "steps-app", step, &part, &fields, &Identity, &Metrics::new())
                    .unwrap();
            }
            ar.finish().unwrap();
        });
    }
    scda::api::verify_file(&path).unwrap();

    // Restore on 3 ranks: latest step by default, an older step by
    // number, a single field by name.
    let p = path.clone();
    let outputs = run_parallel(3, move |comm| {
        let part = Partition::uniform(3, N);
        let rank = comm.rank();
        let mut ar = Archive::open(comm, &p).unwrap();
        assert_eq!(restart::list_steps(&ar), vec![10, 20]);
        let (latest, fields20) = restart::read_step(&mut ar, None, &part, &Identity).unwrap();
        assert_eq!((latest.step, latest.app.as_str()), (20, "steps-app"));
        let (old, fields10) = restart::read_step(&mut ar, Some(10), &part, &Identity).unwrap();
        assert_eq!(old.step, 10);
        let single = restart::read_field(&mut ar, 10, &old.fields[0], &part, &Identity).unwrap();
        ar.close().unwrap();
        let expect10 = step_fields(1, &part, rank);
        let expect20 = step_fields(2, &part, rank);
        assert_eq!(fields10[0].payload, expect10[0].payload);
        assert_eq!(fields20[0].payload, expect20[0].payload);
        assert_eq!(single.payload, expect10[0].payload);
        true
    });
    assert!(outputs.into_iter().all(|ok| ok));
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn legacy_checkpoint_layout_restores_through_the_scan_fallback() {
    // A checkpoint in the pre-archive layout: inline scda:ckpt, block
    // scda:manifest, bare-named field sections, no catalog trailer.
    let path = tmp("legacy");
    let n = 12u64;
    let data: Vec<u8> = (0..n * 8).map(|i| (i % 251) as u8).collect();
    let data2: Vec<u8> = (0..n * 8).map(|i| (i % 241) as u8).rev().collect();
    {
        let part = Partition::uniform(1, n);
        let mut f = ScdaFile::create(SerialComm::new(), &path, b"legacy ckpt").unwrap();
        let mut inline = format!("step {:>20} ok", 5).into_bytes();
        inline.resize(31, b' ');
        inline.push(b'\n');
        f.write_inline(&inline, Some(b"scda:ckpt")).unwrap();
        // Two fields sharing one name: legal under the old writer, and
        // the sequential legacy restore must keep them apart.
        let manifest = format!(
            "scda-checkpoint 1\napp legacy-app\nstep 5\n\
             field name=rho kind=fixed elem=8 n={n} encode=0 precond=0\n\
             field name=rho kind=fixed elem=8 n={n} encode=0 precond=0\n"
        );
        f.write_block(manifest.as_bytes(), Some(b"scda:manifest")).unwrap();
        f.write_array(DataSrc::Contiguous(&data), &part, 8, Some(b"rho"), false).unwrap();
        f.write_array(DataSrc::Contiguous(&data2), &part, 8, Some(b"rho"), false).unwrap();
        f.close().unwrap();
    }
    for ranks in [1usize, 2] {
        let p = path.clone();
        let (d, d2) = (data.clone(), data2.clone());
        let windows = run_parallel(ranks, move |comm| {
            let part = Partition::uniform(ranks, n);
            let r = part.local_range(comm.rank());
            let (info, fields) = read_checkpoint(comm, &p, &part, &Identity).unwrap();
            assert_eq!((info.app.as_str(), info.step), ("legacy-app", 5));
            let window = (r.start * 8) as usize..(r.end * 8) as usize;
            for (field, global) in fields.iter().zip([&d, &d2]) {
                match &field.payload {
                    FieldPayload::Fixed { elem_size: 8, data } => {
                        assert_eq!(data, &global[window.clone()]);
                    }
                    other => panic!("bad payload {other:?}"),
                }
            }
            true
        });
        assert!(windows.into_iter().all(|ok| ok));
    }
    std::fs::remove_file(&path).unwrap();
}
