//! Coordinator-level checkpoint/restart: write on P_w, restart on P_r
//! with count- and byte-balanced partitions, preconditioned and encoded
//! fields, manifest integrity.

use scda::coordinator::checkpoint::{open_checkpoint, read_checkpoint, write_checkpoint, Field, FieldPayload};
use scda::coordinator::{by_bytes, Metrics};
use scda::mesh::{self, fields};
use scda::par::{run_parallel, Communicator, Partition, SerialComm};
use scda::runtime::NativeTransform;
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-ckpt-test");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

struct Workload {
    n: u64,
    rho: Vec<u8>,
    hp_sizes: Vec<u64>,
    hp: Vec<u8>,
}

fn workload() -> Workload {
    let leaves = mesh::ring_mesh(3, 6, (0.5, 0.5), 0.3);
    let n = leaves.len() as u64;
    let rho = fields::local_fixed_field(&leaves, 0..leaves.len(), 4);
    let (hp_sizes, hp) = fields::local_hp_field(&leaves, 0..leaves.len(), 5);
    Workload { n, rho, hp_sizes, hp }
}

fn write_on(path: &PathBuf, ranks: usize, w: &Arc<Workload>, encode: bool, precondition: bool) {
    let part = Arc::new(Partition::uniform(ranks, w.n));
    let metrics = Arc::new(Metrics::new());
    let (path, w2, part2, m2) = (path.clone(), Arc::clone(w), Arc::clone(&part), Arc::clone(&metrics));
    run_parallel(ranks, move |comm| {
        let r = part2.local_range(comm.rank());
        let flds = vec![
            Field {
                name: "rho".into(),
                encode,
                precondition,
                payload: FieldPayload::Fixed {
                    elem_size: 32,
                    data: w2.rho[(r.start * 32) as usize..(r.end * 32) as usize].to_vec(),
                },
            },
            Field {
                name: "hp".into(),
                encode,
                precondition,
                payload: {
                    let sizes = w2.hp_sizes[r.start as usize..r.end as usize].to_vec();
                    let lo: u64 = w2.hp_sizes[..r.start as usize].iter().sum();
                    let len: u64 = sizes.iter().sum();
                    FieldPayload::Var { sizes, data: w2.hp[lo as usize..(lo + len) as usize].to_vec() }
                },
            },
        ];
        write_checkpoint(comm, &path, "test-app", 33, &part2, &flds, &NativeTransform, &m2).unwrap();
    });
}

fn verify_on(path: &PathBuf, part: Arc<Partition>, w: &Arc<Workload>) {
    let ranks = part.num_ranks();
    let (path, w2) = (path.clone(), Arc::clone(w));
    run_parallel(ranks, move |comm| {
        let rank = comm.rank();
        let (info, restored) = read_checkpoint(comm, &path, &part, &NativeTransform).unwrap();
        assert_eq!((info.app.as_str(), info.step), ("test-app", 33));
        assert_eq!(info.fields.len(), 2);
        let r = part.local_range(rank);
        match &restored[0].payload {
            FieldPayload::Fixed { elem_size: 32, data } => {
                assert_eq!(data, &w2.rho[(r.start * 32) as usize..(r.end * 32) as usize]);
            }
            other => panic!("bad rho payload {other:?}"),
        }
        match &restored[1].payload {
            FieldPayload::Var { sizes, data } => {
                assert_eq!(sizes, &w2.hp_sizes[r.start as usize..r.end as usize]);
                let lo: u64 = w2.hp_sizes[..r.start as usize].iter().sum();
                let len: u64 = sizes.iter().sum();
                assert_eq!(data, &w2.hp[lo as usize..(lo + len) as usize]);
            }
            other => panic!("bad hp payload {other:?}"),
        }
    });
}

#[test]
fn restart_matrix_over_ranks_and_policies() {
    let w = Arc::new(workload());
    for (encode, precondition) in [(false, false), (true, false), (true, true)] {
        let path = tmp(&format!("matrix-{encode}-{precondition}"));
        write_on(&path, 3, &w, encode, precondition);
        scda::api::verify_file(&path).unwrap();
        for p_r in [1usize, 2, 5] {
            verify_on(&path, Arc::new(Partition::uniform(p_r, w.n)), &w);
        }
        // Byte-balanced restart partition over the skewed hp sizes.
        verify_on(&path, Arc::new(by_bytes(&w.hp_sizes, 4)), &w);
        std::fs::remove_file(&path).unwrap();
    }
}

#[test]
fn checkpoints_are_serial_equivalent() {
    let w = Arc::new(workload());
    let mut hashes = Vec::new();
    for ranks in [1usize, 2, 4, 6] {
        let path = tmp(&format!("sereq-{ranks}"));
        write_on(&path, ranks, &w, true, true);
        hashes.push(scda::bench_support::sha256(&std::fs::read(&path).unwrap()));
        std::fs::remove_file(&path).unwrap();
    }
    assert!(hashes.windows(2).all(|h| h[0] == h[1]), "checkpoint bytes depend on job size");
}

#[test]
fn manifest_probe_without_reading_fields() {
    let w = Arc::new(workload());
    let path = tmp("probe");
    write_on(&path, 2, &w, true, false);
    let (f, info) = open_checkpoint(SerialComm::new(), &path).unwrap();
    f.close().unwrap();
    assert_eq!(info.fields.len(), 2);
    assert_eq!(info.fields[0].name, "rho");
    assert_eq!(info.fields[0].fixed_elem, Some(32));
    assert_eq!(info.fields[0].elem_count, w.n);
    assert_eq!(info.fields[1].fixed_elem, None);
    assert!(info.fields[0].encode);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn non_checkpoint_file_rejected() {
    let path = tmp("notckpt");
    let mut f = scda::api::ScdaFile::create(SerialComm::new(), &path, b"plain").unwrap();
    f.write_block(b"data", Some(b"whatever")).unwrap();
    f.close().unwrap();
    let err = match open_checkpoint(SerialComm::new(), &path) {
        Err(e) => e,
        Ok(_) => panic!("plain file accepted as checkpoint"),
    };
    assert_eq!(err.kind(), scda::ScdaErrorKind::CorruptFile);
    std::fs::remove_file(&path).unwrap();
}
