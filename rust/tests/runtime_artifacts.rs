//! PJRT-vs-native equivalence: the AOT-compiled JAX/Pallas graphs must
//! produce byte-identical transforms to the native fallback. Skips (with
//! a loud message) when `artifacts/` has not been built.

use scda::runtime::{native_forward, Preconditioner, CHUNK, TILE};
use scda::testutil::Rng;
use std::path::PathBuf;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn pjrt() -> Option<Preconditioner> {
    match Preconditioner::pjrt(&artifacts_dir()) {
        Ok(p) => Some(p),
        Err(e) => {
            eprintln!("SKIP: no AOT artifacts ({e}); run `make artifacts`");
            None
        }
    }
}

#[test]
fn pjrt_forward_matches_native() {
    let Some(p) = pjrt() else { return };
    assert_eq!(p.backend_name(), "pjrt");
    let native = Preconditioner::native();
    let mut rng = Rng::new(0xA0);
    for len in [4 * CHUNK, 4 * CHUNK + 40, 16, 4 * TILE, 123, 0, 9 * CHUNK + 3] {
        let data = rng.bytes(len, 256);
        let (t_pjrt, ent_pjrt) = p.forward(&data).unwrap();
        let (t_native, ent_native) = native.forward(&data).unwrap();
        assert_eq!(t_pjrt, t_native, "forward bytes differ at len {len}");
        // The entropy heuristic samples the (PJRT-side zero-padded) chunk,
        // so exact agreement only holds for full chunks.
        if len >= 4 * CHUNK {
            assert!((ent_pjrt - ent_native).abs() < 0.05, "entropy {ent_pjrt} vs {ent_native}");
        } else {
            assert!((0.0..=8.01).contains(&ent_pjrt));
        }
    }
}

#[test]
fn pjrt_inverse_matches_native_and_roundtrips() {
    let Some(p) = pjrt() else { return };
    let mut rng = Rng::new(0xA1);
    for len in [4 * CHUNK, 1000, 4 * CHUNK * 2 + 17] {
        let data = rng.bytes(len, 256);
        let (t, _) = p.forward(&data).unwrap();
        assert_eq!(p.inverse(&t).unwrap(), data, "pjrt roundtrip at len {len}");
    }
}

#[test]
fn pjrt_entropy_is_sane() {
    let Some(p) = pjrt() else { return };
    // Constant input -> near-zero entropy after transform.
    let zeros = vec![0u8; 4 * CHUNK];
    let (_, ent) = p.forward(&zeros).unwrap();
    assert!(ent < 0.1, "constant input entropy {ent}");
    // Uniform noise -> near 8 bits/byte.
    let mut rng = Rng::new(0xA2);
    let noise = rng.bytes(4 * CHUNK, 256);
    let (_, ent) = p.forward(&noise).unwrap();
    assert!(ent > 7.5, "noise entropy {ent}");
}

#[test]
fn native_chunk_equals_kernel_contract() {
    // Pin the kernel contract: d[i] = x[i] ^ x[i-1] tile-locally, planes
    // in little-endian significance order. A hand-computed vector guards
    // against accidental contract drift on either side of the AOT fence.
    let x = [0x01020304u32, 0x01020305, 0xff000000];
    let (planes, _) = native_forward(&x);
    let n = 3;
    assert_eq!(planes.len(), 4 * n);
    // d = [0x01020304, 0x00000001, 0xfe020305]
    assert_eq!(&planes[..n], &[0x04, 0x01, 0x05]); // plane 0 (LSB)
    assert_eq!(&planes[n..2 * n], &[0x03, 0x00, 0x03]);
    assert_eq!(&planes[2 * n..3 * n], &[0x02, 0x00, 0x02]);
    assert_eq!(&planes[3 * n..], &[0x01, 0x00, 0xfe]);
}
