//! Cross-implementation interop: the independent pure-Python scda
//! implementation (python/scda_py) and this crate must (a) produce
//! byte-identical files for identical raw-section scripts, and (b) read
//! each other's files — including compressed sections, where the deflate
//! streams differ (both legal) but the decoded payloads must match.
//!
//! Skips cleanly if no python interpreter is available.

use scda::api::{DataSrc, ScdaFile};
use scda::par::{Partition, SerialComm};
use std::path::PathBuf;
use std::process::Command;

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn python() -> Option<&'static str> {
    for cand in ["python3", "python"] {
        if Command::new(cand).arg("--version").output().map(|o| o.status.success()).unwrap_or(false) {
            return Some(cand);
        }
    }
    eprintln!("SKIP: no python interpreter for interop tests");
    None
}

fn run_py(code: &str) -> String {
    let py = python().expect("checked by caller");
    let out = Command::new(py)
        .current_dir(repo_root().join("python"))
        .arg("-c")
        .arg(code)
        .output()
        .expect("spawn python");
    assert!(
        out.status.success(),
        "python failed:\nstdout: {}\nstderr: {}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-interop");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

#[test]
fn raw_files_are_byte_identical_across_implementations() {
    if python().is_none() {
        return;
    }
    let rust_path = tmp("rust-raw");
    // NOTE: vendor strings differ by design; write the python vendor from
    // rust? No — the vendor string is implementation-specific, so compare
    // everything *after* the header's vendor field by re-writing with the
    // same inputs and comparing section bytes (offset 32 onward covers
    // the F row + all sections; vendor lives in bytes 8..32).
    let mut f = ScdaFile::create(SerialComm::new(), &rust_path, b"interop").unwrap();
    f.write_inline(&[b'x'; 32], Some(b"i1")).unwrap();
    f.write_block(b"shared block payload", Some(b"b1")).unwrap();
    let part = Partition::uniform(1, 5);
    let arr: Vec<u8> = (0..35).collect();
    f.write_array(DataSrc::Contiguous(&arr), &part, 7, Some(b"a1"), false).unwrap();
    f.write_varray(DataSrc::Contiguous(&[1, 2, 3, 4, 5, 6]), &part, &[1, 0, 2, 3, 0], Some(b"v1"), false)
        .unwrap();
    f.close().unwrap();

    let py_path = tmp("py-raw");
    run_py(&format!(
        r#"
from scda_py import ScdaWriter
w = ScdaWriter({py_path:?}, b"interop")
w.write_inline(b"x" * 32, b"i1")
w.write_block(b"shared block payload", b"b1")
w.write_array(bytes(range(35)), 5, 7, b"a1")
w.write_varray([bytes([1]), b"", bytes([2, 3]), bytes([4, 5, 6]), b""], b"v1")
w.close()
"#
    ));
    let rust_bytes = std::fs::read(&rust_path).unwrap();
    let py_bytes = std::fs::read(&py_path).unwrap();
    assert_eq!(rust_bytes.len(), py_bytes.len());
    assert_eq!(&rust_bytes[..8], &py_bytes[..8], "magic differs");
    assert_eq!(&rust_bytes[32..], &py_bytes[32..], "section bytes differ (beyond vendor field)");
    // Both verify strictly.
    scda::api::verify_file(&rust_path).unwrap();
    scda::api::verify_file(&py_path).unwrap();
    std::fs::remove_file(&rust_path).unwrap();
    std::fs::remove_file(&py_path).unwrap();
}

#[test]
fn rust_reads_python_written_compressed_file() {
    if python().is_none() {
        return;
    }
    let path = tmp("py-z");
    run_py(&format!(
        r#"
from scda_py import ScdaWriter
w = ScdaWriter({path:?}, b"from python")
w.write_block(b"Z" * 5000, b"zb", encode=True)
w.write_array(bytes(i % 7 for i in range(1200)), 12, 100, b"za", encode=True)
w.write_varray([b"a" * n for n in (0, 10, 500)], b"zv", encode=True)
w.close()
"#
    ));
    scda::api::verify_file(&path).unwrap();
    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    assert_eq!(f.header_user_string().unwrap(), b"from python");
    let h = f.read_section_header(true).unwrap();
    assert!(h.decoded);
    assert_eq!(f.read_block_data(0, true).unwrap().unwrap(), vec![b'Z'; 5000]);
    let h = f.read_section_header(true).unwrap();
    assert_eq!((h.elem_count, h.elem_size, h.decoded), (12, 100, true));
    let part = Partition::uniform(1, 12);
    let a = f.read_array_data(&part, 100, true).unwrap().unwrap();
    assert_eq!(a, (0..1200u32).map(|i| (i % 7) as u8).collect::<Vec<_>>());
    let h = f.read_section_header(true).unwrap();
    assert_eq!((h.elem_count, h.decoded), (3, true));
    let p3 = Partition::uniform(1, 3);
    let sizes = f.read_varray_sizes(&p3).unwrap();
    assert_eq!(sizes, [0, 10, 500]);
    let v = f.read_varray_data(&p3, &sizes, true).unwrap().unwrap();
    assert_eq!(v, vec![b'a'; 510]);
    f.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn python_reads_rust_written_compressed_file() {
    if python().is_none() {
        return;
    }
    let path = tmp("rust-z");
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"from rust").unwrap();
    f.write_block_from(0, Some(&vec![b'Q'; 3000]), 3000, Some(b"zb"), true).unwrap();
    let part = Partition::uniform(1, 8);
    let data: Vec<u8> = (0..8 * 64).map(|i| (i / 64) as u8).collect();
    f.write_array(DataSrc::Contiguous(&data), &part, 64, Some(b"za"), true).unwrap();
    let vp = Partition::uniform(1, 3);
    f.write_varray(DataSrc::Contiguous(&vec![b'w'; 77]), &vp, &[7, 0, 70], Some(b"zv"), true).unwrap();
    f.close().unwrap();

    let out = run_py(&format!(
        r#"
from scda_py import ScdaReader
r = ScdaReader({path:?})
assert r.user == b"from rust", r.user
k, u, data = r.next_section()
assert (k, u) == ("B", b"zb") and data == b"Q" * 3000, (k, u, len(data))
k, u, elems = r.next_section()
assert (k, u) == ("A", b"za") and len(elems) == 8
assert b"".join(elems) == bytes(i // 64 for i in range(8 * 64))
k, u, elems = r.next_section()
assert (k, u) == ("V", b"zv") and [len(e) for e in elems] == [7, 0, 70]
assert b"".join(elems) == b"w" * 77
assert r.at_end()
print("PY-READ-OK")
"#
    ));
    assert!(out.contains("PY-READ-OK"));
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn python_reads_rust_preconditioned_file_and_vice_versa() {
    if python().is_none() {
        return;
    }
    // Rust writes SPEC §5.4 'p' frames (shuffle width 4 + delta); the
    // foreign reader must self-configure from the descriptor byte.
    let path = tmp("rust-p");
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"from rust").unwrap();
    f.set_precondition(Some(scda::codec::Precond::new(4, true).unwrap()));
    let part = Partition::uniform(1, 16);
    let data: Vec<u8> = (0..16u32 * 25).flat_map(|i| (1000 + 3 * i).to_le_bytes()).collect();
    f.write_array(DataSrc::Contiguous(&data), &part, 100, Some(b"pa"), true).unwrap();
    f.write_block_from(0, Some(&data), data.len() as u64, Some(b"pb"), true).unwrap();
    f.close().unwrap();
    let out = run_py(&format!(
        r#"
from scda_py import ScdaReader
r = ScdaReader({path:?})
expect = b"".join((1000 + 3 * i).to_bytes(4, "little") for i in range(16 * 25))
k, u, elems = r.next_section()
assert (k, u) == ("A", b"pa") and b"".join(elems) == expect, (k, u)
k, u, data = r.next_section()
assert (k, u) == ("B", b"pb") and data == expect, (k, u)
assert r.at_end()
print("PY-P-READ-OK")
"#
    ));
    assert!(out.contains("PY-P-READ-OK"));
    std::fs::remove_file(&path).unwrap();

    // And the reverse: python-written 'p' frames decode transparently
    // here, with the same payload bytes.
    let path = tmp("py-p");
    run_py(&format!(
        r#"
from scda_py import ScdaWriter
data = b"".join((1000 + 3 * i).to_bytes(4, "little") for i in range(16 * 25))
w = ScdaWriter({path:?}, b"from python")
w.write_array(data, 16, 100, b"pa", encode=True, precondition=(4, True))
w.write_block(data, b"pb", encode=True, precondition=(8, False))
w.close()
"#
    ));
    scda::api::verify_file(&path).unwrap();
    let expect: Vec<u8> = (0..16u32 * 25).flat_map(|i| (1000 + 3 * i).to_le_bytes()).collect();
    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    let h = f.read_section_header(true).unwrap();
    assert!(h.decoded);
    let a = f.read_array_data(&part, 100, true).unwrap().unwrap();
    assert_eq!(a, expect);
    let h = f.read_section_header(true).unwrap();
    assert!(h.decoded);
    assert_eq!(f.read_block_data(0, true).unwrap().unwrap(), expect);
    f.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn python_verifies_rust_checkpoint_structure() {
    if python().is_none() {
        return;
    }
    // A full coordinator checkpoint must be legible to the foreign
    // implementation section-by-section.
    let path = tmp("rust-ckpt");
    let leaves = scda::mesh::ring_mesh(2, 4, (0.5, 0.5), 0.3);
    let n = leaves.len() as u64;
    let part = Partition::uniform(1, n);
    let data = scda::mesh::fields::local_fixed_field(&leaves, 0..leaves.len(), 3);
    let fields = vec![scda::coordinator::checkpoint::Field {
        name: "rho".into(),
        encode: true,
        precondition: false,
        payload: scda::coordinator::checkpoint::FieldPayload::Fixed { elem_size: 24, data },
    }];
    scda::coordinator::checkpoint::write_checkpoint(
        SerialComm::new(),
        &path,
        "interop-app",
        9,
        &part,
        &fields,
        &scda::runtime::Identity,
        &scda::coordinator::Metrics::new(),
    )
    .unwrap();
    // The checkpoint is a named-dataset archive: versioned step datasets
    // followed by the catalog block and the footer index, all ordinary
    // sections the foreign reader walks like any other — including the
    // ASCII catalog text and the ASCII decimal index payload.
    let out = run_py(&format!(
        r#"
from scda_py import ScdaReader
r = ScdaReader({path:?})
k, u, _ = r.next_section()
assert (k, u) == ("I", b"ckpt/9.info")
k, u, manifest = r.next_section()
assert (k, u) == ("B", b"ckpt/9.manifest")
assert b"app interop-app" in manifest and b"step 9" in manifest
k, u, elems = r.next_section()
assert (k, u) == ("A", b"ckpt/9/rho") and len(elems) == {n}
k, u, catalog = r.next_section()
assert (k, u) == ("B", b"scda:catalog")
assert catalog.startswith(b"scda-catalog 1")
assert b"name=ckpt/9/rho" in catalog and b"kind=A" in catalog
k, u, idx = r.next_section()
assert (k, u) == ("I", b"scda:index")
catalog_off = int(idx.decode().strip())
assert catalog_off > 128
assert r.at_end()
print("PY-CKPT-OK")
"#
    ));
    assert!(out.contains("PY-CKPT-OK"));
    std::fs::remove_file(&path).unwrap();
}
