//! AMR churn bench smoke: exercises the `amr_bench` harness end to end
//! and records `BENCH_amr.json` so the scenario trajectory (per-phase
//! throughput, recover cost, catalog reopen cost) is tracked from this
//! PR onward.
//!
//! The quick bench is `#[ignore]`d so `cargo test -q` stays fast; run
//! with `cargo test --test bench_amr_smoke -- --ignored`.

use scda::bench_support::{amr_bench, bench_amr_json_path};
use scda::runtime::scenario::{crash_path, run_scenario, ScenarioConfig};
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-amr-smoke");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

fn tiny() -> ScenarioConfig {
    ScenarioConfig {
        cycles: 2,
        base_level: 1,
        max_level: 3,
        writers: 2,
        restore_ranks: 3,
        crash_seed: None,
        ..Default::default()
    }
}

/// Non-ignored determinism pass at a size too small to be a benchmark:
/// the whole driver — mesh, rebalance, checkpoint — is a pure function
/// of the config, and the archive is writer-count-invariant.
#[test]
fn amr_workload_is_deterministic_and_writer_invariant() {
    let a = tmp("det-a");
    let b = tmp("det-b");
    run_scenario(&a, &tiny()).unwrap();
    run_scenario(&b, &tiny()).unwrap();
    let bytes_a = std::fs::read(&a).unwrap();
    assert_eq!(bytes_a, std::fs::read(&b).unwrap(), "same config, different bytes");
    // One writer rank produces the identical archive (serial
    // equivalence is what licenses the bench's serial crash replay).
    let c = tmp("det-c");
    run_scenario(&c, &ScenarioConfig { writers: 1, ..tiny() }).unwrap();
    assert_eq!(bytes_a, std::fs::read(&c).unwrap(), "P=1 vs P=2 bytes differ");
    for p in [&a, &b, &c] {
        std::fs::remove_file(p).unwrap();
    }
}

/// Non-ignored shape pass: the profile the recorder writes always
/// carries the fixed entry set `check_bench_reports.py` gates on.
#[test]
fn amr_bench_harness_roundtrips_tiny_workload() {
    let path = tmp("shape");
    let cfg = ScenarioConfig { crash_seed: Some(0xC4A5), ..tiny() };
    let profile = amr_bench::run(&path, cfg, 1).unwrap();
    assert_eq!(profile.report.cycles.len(), 2);
    assert!(profile.report.recover.is_some());
    assert!(profile.reopen_first_ms >= 0.0 && profile.reopen_last_ms >= 0.0);
    let r = profile.report().render();
    assert!(r.contains("\"bench\": \"amr\""));
    for entry in
        ["refine", "rebalance", "checkpoint", "restore", "recover", "reopen_first", "reopen_last"]
    {
        assert!(r.contains(&format!("\"{entry}\"")), "missing entry {entry}");
    }
    for field in ["elements_per_s", "mib_per_s", "moved_bytes", "truncated_bytes", "open_ms"] {
        assert!(r.contains(&format!("\"{field}\"")), "missing field {field}");
    }
    std::fs::remove_file(&path).unwrap();
    let _ = std::fs::remove_file(crash_path(&path));
}

#[test]
#[ignore = "perf smoke; run with -- --ignored"]
fn amr_bench_quick_records_json() {
    let profile = amr_bench::run_quick();
    let rec = profile.report.recover.as_ref().expect("quick bench arms the crash leg");
    assert!(rec.steps_survived <= profile.cfg.cycles as u64);
    let path = bench_amr_json_path();
    profile.report().write(&path).unwrap();
    let written = std::fs::read_to_string(&path).unwrap();
    assert!(written.contains("\"bench\": \"amr\""));
    for c in &profile.report.cycles {
        println!(
            "amr quick: cycle {} n={} payload {} B moved {} B refine {:.2} ms rebalance {:.2} ms write {:.2} ms",
            c.cycle, c.elements, c.payload_bytes, c.moved_bytes,
            c.refine_s * 1e3, c.rebalance_s * 1e3, c.write_s * 1e3
        );
    }
    println!(
        "amr quick: restore P'={} {:.2} ms, recover {:.2} ms, reopen {:.3} → {:.3} ms",
        profile.report.restore.ranks, profile.report.restore.seconds * 1e3,
        rec.seconds * 1e3, profile.reopen_first_ms, profile.reopen_last_ms
    );
    println!("wrote {}", path.display());
}
