//! Crash/restore soak: the recovery property the crash-consistency
//! subsystem promises. For every injected crash point — a bisected sweep
//! of truncation offsets over the whole file, plus in-engine
//! `FaultPlan::crash` power cuts — `recover` must yield a verify-clean
//! archive containing *exactly* the datasets fully committed before the
//! crash, with byte-identical content, restorable by name on a different
//! rank count. Never a panic, never wrong data.
//!
//! The `#[ignore]`d recorder emits `BENCH_recover.json` (see
//! `tools/check_bench_reports.py`); `SCDA_BENCH_QUICK=1` shrinks the
//! sweep for CI.

use scda::api::{DataSrc, IoTuning};
use scda::archive::{recover, Archive, RecoveryAction};
use scda::bench_support::{bench_recover_json_path, quick, BenchReport, JsonVal};
use scda::format::section::SectionKind;
use scda::io::FaultPlan;
use scda::par::{run_parallel, Communicator, Partition, SerialComm};
use std::path::{Path, PathBuf};
use std::sync::Arc;

const ELEM: u64 = 8;
const N: u64 = 96;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-recover-soak");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

fn fixed_data() -> Vec<u8> {
    (0..N * ELEM).map(|i| (i * 7 % 251) as u8).collect()
}

fn var_sizes() -> Vec<u64> {
    (0..N).map(|i| 1 + (i % 23)).collect()
}

fn var_data(total: u64) -> Vec<u8> {
    (0..total).map(|i| (i * 3 % 253) as u8).collect()
}

/// Write the soak archive on `writers` ranks: one of each section kind
/// (the fixed array twice — raw and compressed, so the sweep crosses a
/// convention-9 pair too). Deterministic content at every rank count.
fn write_archive(path: &Path, writers: usize) {
    let part = Partition::uniform(writers, N);
    let data = Arc::new(fixed_data());
    let sizes = Arc::new(var_sizes());
    let vtotal: u64 = sizes.iter().sum();
    let vdata = Arc::new(var_data(vtotal));
    let path = path.to_path_buf();
    run_parallel(writers, move |comm| {
        let rank = comm.rank();
        let mut ar = Archive::create(comm, &path, b"soak").unwrap();
        let r = part.local_range(rank);
        let local = &data[(r.start * ELEM) as usize..(r.end * ELEM) as usize];
        ar.write_inline_from("stamp", 0, Some(&[42u8; 32])).unwrap();
        ar.write_array("plain", DataSrc::Contiguous(local), &part, ELEM, false).unwrap();
        ar.write_block_from("manifest", 0, Some(b"soak manifest v1"), 16, false).unwrap();
        ar.write_array("packed", DataSrc::Contiguous(local), &part, ELEM, true).unwrap();
        let ls = &sizes[r.start as usize..r.end as usize];
        let voff: u64 = sizes[..r.start as usize].iter().sum();
        let vlen: u64 = ls.iter().sum();
        ar.write_varray(
            "var",
            DataSrc::Contiguous(&vdata[voff as usize..(voff + vlen) as usize]),
            &part,
            ls,
            false,
        )
        .unwrap();
        ar.finish().unwrap();
    });
}

/// Every dataset's full content, serially, in file order.
fn read_all(path: &Path) -> Vec<(String, Vec<u8>)> {
    let mut ar = Archive::open(SerialComm::new(), path).unwrap();
    let metas: Vec<(String, SectionKind, u64, u64)> =
        ar.datasets().iter().map(|d| (d.name.clone(), d.kind, d.elem_count, d.elem_size)).collect();
    let mut out = Vec::new();
    for (name, kind, n, e) in metas {
        let bytes = match kind {
            SectionKind::Inline => ar.read_inline(&name, 0).unwrap().unwrap().to_vec(),
            SectionKind::Block => ar.read_block(&name, 0).unwrap().unwrap(),
            SectionKind::Array => ar.read_array(&name, &Partition::uniform(1, n), e).unwrap(),
            SectionKind::Varray => ar.read_varray(&name, &Partition::uniform(1, n)).unwrap().1,
        };
        out.push((name, bytes));
    }
    ar.close().unwrap();
    out
}

/// Breadth-first midpoint bisection of `[lo, hi)`: covers the whole file
/// coarsely first, then refines — the offsets most likely to expose
/// boundary bugs (section starts, row/payload seams) appear early.
fn bisect_offsets(lo: u64, hi: u64, budget: usize) -> Vec<u64> {
    let mut out = Vec::new();
    let mut queue = std::collections::VecDeque::from([(lo, hi)]);
    while out.len() < budget {
        let Some((a, b)) = queue.pop_front() else { break };
        if b <= a + 1 {
            continue;
        }
        let mid = a + (b - a) / 2;
        out.push(mid);
        queue.push_back((a, mid));
        queue.push_back((mid, b));
    }
    out
}

/// Truncate a copy of `good` at `cut`, recover it, and assert the full
/// property: verify-clean, exactly the committed prefix of datasets,
/// byte-identical content. Returns how many datasets survived.
fn check_truncation(
    good: &[u8],
    cut: u64,
    baseline: &[(String, Vec<u8>)],
    extents: &[(String, u64)],
    scratch: &Path,
) -> usize {
    std::fs::write(scratch, &good[..cut as usize]).unwrap();
    let rep = recover(scratch).unwrap_or_else(|e| panic!("cut {cut}: recover failed: {e}"));
    scda::api::verify_file(scratch).unwrap_or_else(|e| panic!("cut {cut}: recovered file unclean: {e}"));
    // Exactly the datasets whose full extent precedes the cut.
    let expected: Vec<&str> =
        extents.iter().filter(|(_, end)| *end <= cut).map(|(n, _)| n.as_str()).collect();
    assert_eq!(rep.datasets, expected, "cut {cut}: survivor set");
    let recovered = read_all(scratch);
    assert_eq!(recovered.len(), expected.len(), "cut {cut}: reopened dataset count");
    for (i, (name, bytes)) in recovered.iter().enumerate() {
        assert_eq!(name, &baseline[i].0, "cut {cut}: dataset order");
        assert_eq!(bytes, &baseline[i].1, "cut {cut}: dataset {name} content differs");
    }
    recovered.len()
}

/// Restore the raw fixed array by name on `readers` ranks and check each
/// rank's window — recovery must preserve partition independence.
fn restore_parallel(path: &Path, readers: usize, expect: &[u8]) {
    let path = path.to_path_buf();
    let expect = expect.to_vec();
    run_parallel(readers, move |comm| {
        let rank = comm.rank();
        let mut ar = Archive::open(comm, &path).unwrap();
        let n = ar.get("plain").expect("plain survived").elem_count;
        let part = Partition::uniform(readers, n);
        let got = ar.read_array("plain", &part, ELEM).unwrap();
        let r = part.local_range(rank);
        assert_eq!(got, &expect[(r.start * ELEM) as usize..(r.end * ELEM) as usize]);
        ar.close().unwrap();
    });
}

#[test]
fn truncation_sweep_recovers_committed_prefix() {
    for &writers in &[1usize, 2, 4, 8] {
        let path = tmp(&format!("sweep-{writers}"));
        write_archive(&path, writers);
        let good = std::fs::read(&path).unwrap();
        let baseline = read_all(&path);
        let extents: Vec<(String, u64)> = {
            let ar = Archive::open(SerialComm::new(), &path).unwrap();
            let e = ar.datasets().iter().map(|d| (d.name.clone(), d.offset + d.byte_len)).collect();
            ar.close().unwrap();
            e
        };
        let len = good.len() as u64;
        let budget = if quick() { 16 } else { 48 };
        let mut cuts = bisect_offsets(128, len, budget);
        // Boundary offsets: dataset seams (±1), the trailer, the ends.
        cuts.extend([129, len - 1, len.saturating_sub(96), len.saturating_sub(97)]);
        for (_, end) in &extents {
            cuts.extend([end.saturating_sub(1), *end, end + 1]);
        }
        cuts.retain(|&c| (128..len).contains(&c));
        cuts.sort_unstable();
        cuts.dedup();
        let scratch = tmp(&format!("sweep-{writers}-cut"));
        let mut survived_any = false;
        for &cut in &cuts {
            let survived = check_truncation(&good, cut, &baseline, &extents, &scratch);
            survived_any |= survived > 0;
        }
        assert!(survived_any, "sweep at {writers} writers never salvaged a dataset");
        // Restore on a different rank count from a recovered mid-file cut
        // (after the raw array's extent, so "plain" survives).
        let plain_end = extents.iter().find(|(n, _)| n == "plain").unwrap().1;
        std::fs::write(&scratch, &good[..(plain_end + 1) as usize]).unwrap();
        recover(&scratch).unwrap();
        restore_parallel(&scratch, writers + 1, &fixed_data());
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_file(&scratch).unwrap();
    }
}

#[test]
fn truncation_below_the_header_is_unrecoverable_not_a_panic() {
    let path = tmp("short");
    write_archive(&path, 1);
    let good = std::fs::read(&path).unwrap();
    let scratch = tmp("short-cut");
    for cut in [0usize, 1, 64, 127] {
        std::fs::write(&scratch, &good[..cut]).unwrap();
        let err = recover(&scratch).unwrap_err();
        assert_eq!(err.kind(), scda::error::ScdaErrorKind::CorruptFile, "cut {cut}");
    }
    std::fs::remove_file(&path).unwrap();
    std::fs::remove_file(&scratch).unwrap();
}

/// In-engine power cuts: a seeded `FaultPlan::crash` truncates the file
/// at the torn byte mid-write-stream (direct engine, so the stream is
/// many small pwrites and the trigger lands mid-file). The failed writer
/// must surface an error, and recovery must salvage a committed prefix
/// with intact content.
#[test]
fn injected_crash_then_recover_salvages_committed_prefix() {
    let intact = tmp("crash-intact");
    write_archive(&intact, 1);
    let baseline = read_all(&intact);
    let seeds: &[u64] = if quick() { &[1, 7] } else { &[1, 7, 23, 41, 97, 131] };
    for &seed in seeds {
        let path = tmp(&format!("crash-{seed}"));
        let part = Partition::uniform(1, N);
        let data = fixed_data();
        let sizes = var_sizes();
        let vtotal: u64 = sizes.iter().sum();
        let vdata = var_data(vtotal);
        let mut ar = Archive::create(SerialComm::new(), &path, b"soak").unwrap();
        ar.file_mut().set_io_tuning(IoTuning::direct()).unwrap();
        ar.file_mut().set_fault_plan(Some(FaultPlan::seeded_crash(seed, 8)));
        // Keep writing through the crash — a real application's writes
        // after the power cut also go nowhere. Every error is collected,
        // none may panic.
        let mut errs = 0usize;
        errs += ar.write_inline_from("stamp", 0, Some(&[42u8; 32])).is_err() as usize;
        errs += ar.write_array("plain", DataSrc::Contiguous(&data), &part, ELEM, false).is_err() as usize;
        errs += ar.write_block_from("manifest", 0, Some(b"soak manifest v1"), 16, false).is_err() as usize;
        errs += ar.write_array("packed", DataSrc::Contiguous(&data), &part, ELEM, true).is_err() as usize;
        errs += ar.write_varray("var", DataSrc::Contiguous(&vdata), &part, &sizes, false).is_err() as usize;
        let fin = ar.finish();
        assert!(errs > 0 || fin.is_err(), "seed {seed}: the crash never surfaced");
        let rep = recover(&path).unwrap_or_else(|e| panic!("seed {seed}: recover failed: {e}"));
        assert_eq!(rep.action, RecoveryAction::Rebuilt, "seed {seed}");
        scda::api::verify_file(&path).unwrap();
        // Survivors are a file-order prefix of the committed datasets
        // with byte-identical content.
        let recovered = read_all(&path);
        assert!(recovered.len() <= baseline.len(), "seed {seed}");
        for (i, (name, bytes)) in recovered.iter().enumerate() {
            assert_eq!(name, &baseline[i].0, "seed {seed}: dataset order");
            assert_eq!(bytes, &baseline[i].1, "seed {seed}: dataset {name} content");
        }
        std::fs::remove_file(&path).unwrap();
    }
    std::fs::remove_file(&intact).unwrap();
}

#[test]
#[ignore = "perf smoke; run with -- --ignored"]
fn recover_bench_quick_records_json() {
    use std::time::Instant;
    let mut report = BenchReport::new("recover");
    report.meta("quick", JsonVal::Bool(quick()));
    report.meta("elements", JsonVal::Int(N as i64));
    for &writers in &[1usize, 2, 4] {
        let path = tmp(&format!("bench-{writers}"));
        write_archive(&path, writers);
        let good = std::fs::read(&path).unwrap();
        let len = good.len() as u64;
        let cuts = bisect_offsets(128, len, if quick() { 8 } else { 24 });
        let scratch = tmp(&format!("bench-{writers}-cut"));
        let (mut rebuilt, mut intact) = (0i64, 0i64);
        let t0 = Instant::now();
        for &cut in &cuts {
            std::fs::write(&scratch, &good[..cut as usize]).unwrap();
            match recover(&scratch).unwrap().action {
                RecoveryAction::Rebuilt => rebuilt += 1,
                RecoveryAction::Intact => intact += 1,
            }
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        report.entry(vec![
            ("name", JsonVal::Str(format!("truncation sweep p{writers}"))),
            ("writers", JsonVal::Int(writers as i64)),
            ("file_bytes", JsonVal::Int(len as i64)),
            ("cuts", JsonVal::Int(cuts.len() as i64)),
            ("rebuilt", JsonVal::Int(rebuilt)),
            ("intact", JsonVal::Int(intact)),
            ("recover_ms_total", JsonVal::Num(ms)),
            ("recover_ms_mean", JsonVal::Num(ms / cuts.len().max(1) as f64)),
        ]);
        println!(
            "recover quick: P={writers} {} cuts over {len} bytes in {ms:.3} ms ({rebuilt} rebuilt, {intact} intact)",
            cuts.len()
        );
        std::fs::remove_file(&path).unwrap();
        std::fs::remove_file(&scratch).unwrap();
    }
    let out = bench_recover_json_path();
    report.write(&out).unwrap();
    let written = std::fs::read_to_string(&out).unwrap();
    assert!(written.contains("\"bench\": \"recover\""));
    println!("wrote {}", out.display());
}
