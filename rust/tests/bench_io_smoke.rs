//! Quick-mode I/O bench smoke: exercises the aggregated-vs-direct
//! measurement harness end to end and records `BENCH_io.json` so the
//! raw-I/O perf trajectory is tracked from this PR onward.
//!
//! `#[ignore]`d by default so `cargo test -q` stays fast and
//! timing-insensitive; run explicitly with
//! `cargo test --test bench_io_smoke -- --ignored`.

use scda::bench_support::{bench_io_json_path, io_bench};

#[test]
#[ignore = "perf smoke; run with -- --ignored"]
fn io_bench_quick_records_json() {
    // Small quick-mode workload: 2 ranks, 4 varray sections of 64 x 4 KiB
    // indirect elements per rank.
    let p = io_bench::run(2, 4, 64, 4 << 10, 2);
    assert!(p.write_direct_mib_s > 0.0 && p.write_agg_mib_s > 0.0);
    assert!(p.read_direct_mib_s > 0.0 && p.read_sieved_mib_s > 0.0);
    // The acceptance shape: aggregation collapses the per-element write
    // storm by at least 5x.
    assert!(p.write_syscall_reduction() >= 5.0, "only {:.1}x fewer writes", p.write_syscall_reduction());
    let path = bench_io_json_path();
    p.report().write(&path).unwrap();
    let written = std::fs::read_to_string(&path).unwrap();
    assert!(written.contains("\"bench\": \"io\""));
    assert!(written.contains("varray_write"));
    assert!(written.contains("varray_read"));
    println!(
        "io quick: write {:.0} -> {:.0} MiB/s ({} -> {} syscalls, {:.0}x), read {:.0} -> {:.0} MiB/s \
         ({} -> {} syscalls); wrote {}",
        p.write_direct_mib_s,
        p.write_agg_mib_s,
        p.write_calls_direct,
        p.write_calls_agg,
        p.write_syscall_reduction(),
        p.read_direct_mib_s,
        p.read_sieved_mib_s,
        p.read_calls_direct,
        p.read_calls_sieved,
        path.display(),
    );
}

#[test]
fn io_bench_harness_roundtrips_tiny_workload() {
    // Non-ignored correctness pass through the same harness at a size too
    // small to be a benchmark: verifies the workload roundtrip, the
    // syscall accounting, and the report shape without timing assertions.
    let p = io_bench::run(1, 2, 16, 1 << 10, 1);
    assert_eq!(p.ranks, 1);
    assert_eq!(p.sections, 2);
    assert!(p.write_calls_agg >= 1);
    assert!(p.write_calls_direct > p.write_calls_agg);
    assert!(p.read_calls_sieved <= p.read_calls_direct);
    // The acceptance shape: the report covers all three engines, sync
    // and async.
    let names: Vec<&str> = p.engines.iter().map(|e| e.name.as_str()).collect();
    for expected in ["direct", "aggregated", "aggregated_async", "collective", "collective_async"] {
        assert!(names.contains(&expected), "engine sweep missing {expected}: {names:?}");
    }
    for e in &p.engines {
        assert!(e.write_calls >= 1, "{}: no writes counted", e.name);
        assert!(e.write_mib_s > 0.0, "{}: no throughput", e.name);
    }
    let r = p.report().render();
    assert!(r.contains("\"aggregated_write_calls\""));
    assert!(r.contains("\"sieved_read_calls\""));
    assert!(r.contains("\"syscall_reduction\""));
    assert!(r.contains("\"engine_collective\""));
    assert!(r.contains("\"engine_collective_async\""));
    assert!(r.contains("\"engine_direct\""));
}
