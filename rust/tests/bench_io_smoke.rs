//! Quick-mode I/O bench smoke: exercises the aggregated-vs-direct
//! measurement harness end to end and records `BENCH_io.json` so the
//! raw-I/O perf trajectory is tracked from this PR onward.
//!
//! `#[ignore]`d by default so `cargo test -q` stays fast and
//! timing-insensitive; run explicitly with
//! `cargo test --test bench_io_smoke -- --ignored`.

use scda::bench_support::{bench_io_json_path, io_bench};

#[test]
#[ignore = "perf smoke; run with -- --ignored"]
fn io_bench_quick_records_json() {
    // Small quick-mode workload: 2 ranks, 4 varray sections of 64 x 4 KiB
    // indirect elements per rank.
    let p = io_bench::run(2, 4, 64, 4 << 10, 2);
    assert!(p.write_direct_mib_s > 0.0 && p.write_agg_mib_s > 0.0);
    assert!(p.read_direct_mib_s > 0.0 && p.read_sieved_mib_s > 0.0);
    // The acceptance shape: aggregation collapses the per-element write
    // storm by at least 5x.
    assert!(p.write_syscall_reduction() >= 5.0, "only {:.1}x fewer writes", p.write_syscall_reduction());
    // Read-side sweep at 2 ranks: the gather actually exchanged.
    let col = p.read_engines.iter().find(|e| e.name == "collective").expect("collective read profile");
    assert!(col.read_exchanges >= 1, "gather never ran");
    assert!(col.gathered_bytes > 0, "nothing crossed ranks in the gather");
    let dir = p.read_engines.iter().find(|e| e.name == "direct").unwrap();
    assert!(
        col.read_calls <= dir.read_calls,
        "gathered reads ({}) exceed direct reads ({})",
        col.read_calls,
        dir.read_calls
    );
    let path = bench_io_json_path();
    p.report().write(&path).unwrap();
    let written = std::fs::read_to_string(&path).unwrap();
    assert!(written.contains("\"bench\": \"io\""));
    assert!(written.contains("varray_write"));
    assert!(written.contains("varray_read"));
    assert!(written.contains("read_engine_collective"));
    println!(
        "io quick: write {:.0} -> {:.0} MiB/s ({} -> {} syscalls, {:.0}x), read {:.0} -> {:.0} MiB/s \
         ({} -> {} syscalls); wrote {}",
        p.write_direct_mib_s,
        p.write_agg_mib_s,
        p.write_calls_direct,
        p.write_calls_agg,
        p.write_syscall_reduction(),
        p.read_direct_mib_s,
        p.read_sieved_mib_s,
        p.read_calls_direct,
        p.read_calls_sieved,
        path.display(),
    );
}

#[test]
fn io_bench_harness_roundtrips_tiny_workload() {
    // Non-ignored correctness pass through the same harness at a size too
    // small to be a benchmark: verifies the workload roundtrip, the
    // syscall accounting, and the report shape without timing assertions.
    let p = io_bench::run(1, 2, 16, 1 << 10, 1);
    assert_eq!(p.ranks, 1);
    assert_eq!(p.sections, 2);
    assert!(p.write_calls_agg >= 1);
    assert!(p.write_calls_direct > p.write_calls_agg);
    assert!(p.read_calls_sieved <= p.read_calls_direct);
    // The acceptance shape: the report covers all three engines, sync
    // and async.
    let names: Vec<&str> = p.engines.iter().map(|e| e.name.as_str()).collect();
    for expected in ["direct", "aggregated", "aggregated_async", "collective", "collective_async"] {
        assert!(names.contains(&expected), "engine sweep missing {expected}: {names:?}");
    }
    for e in &p.engines {
        assert!(e.write_calls >= 1, "{}: no writes counted", e.name);
        assert!(e.write_mib_s > 0.0, "{}: no throughput", e.name);
    }
    // The read-side sweep covers the three read routes with sane
    // counters (ranks = 1 here: the gather degenerates to local preads,
    // which must still be counted).
    let rnames: Vec<&str> = p.read_engines.iter().map(|e| e.name.as_str()).collect();
    for expected in ["direct", "aggregated", "collective"] {
        assert!(rnames.contains(&expected), "read sweep missing {expected}: {rnames:?}");
    }
    for e in &p.read_engines {
        assert!(e.read_calls >= 1, "{}: no reads counted", e.name);
        assert!(e.read_mib_s > 0.0, "{}: no read throughput", e.name);
    }
    let col = p.read_engines.iter().find(|e| e.name == "collective").unwrap();
    assert!(col.gather_preads >= 1, "the gather issues owner-side preads even on one rank");
    let r = p.report().render();
    assert!(r.contains("\"aggregated_write_calls\""));
    assert!(r.contains("\"sieved_read_calls\""));
    assert!(r.contains("\"syscall_reduction\""));
    assert!(r.contains("\"engine_collective\""));
    assert!(r.contains("\"engine_collective_async\""));
    assert!(r.contains("\"engine_direct\""));
    assert!(r.contains("\"read_engine_direct\""));
    assert!(r.contains("\"read_engine_collective\""));
    assert!(r.contains("\"gather_preads\""));
}
