//! Serial equivalence of the parallel per-element codec pipeline: the
//! encoded section bytes and the decoded payloads must be bit-identical
//! to the serial codec path at every worker count and under every
//! partition — the paper's core invariant (T1) extended to the codec
//! layer. Covers A/B/V sections, empty elements, empty sections, and
//! level 0 (the no-zlib fallback).

use scda::api::{CodecParallel, DataSrc, ScdaFile};
use scda::par::{run_parallel, CodecPool, Communicator, Partition, SerialComm};
use scda::testutil::Rng;
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-pipe-eq");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

/// Element sizes exercising the interesting shapes: empty elements,
/// one-byte elements, sizes straddling the base64 line length and the
/// parallel chunking threshold.
fn varray_sizes(rng: &mut Rng, n: u64) -> Vec<u64> {
    (0..n)
        .map(|i| match i % 5 {
            0 => 0,
            1 => 1,
            2 => rng.below(57),
            3 => rng.range(57, 2000),
            _ => rng.range(2000, 40_000),
        })
        .collect()
}

/// Write one file holding an encoded A section, an encoded B section,
/// and an encoded V section, from `ranks` ranks under `part`s, with the
/// given codec parallelism and level. Returns the file bytes.
fn write_encoded_file(
    name: &str,
    level: u8,
    ranks: usize,
    par_factory: impl Fn() -> CodecParallel + Send + Sync + 'static,
    arr: Arc<Vec<u8>>,
    elem: u64,
    apart: Arc<Partition>,
    vdata: Arc<Vec<u8>>,
    vsizes: Arc<Vec<u64>>,
    vpart: Arc<Partition>,
    block: Arc<Vec<u8>>,
) -> Vec<u8> {
    let path = tmp(name);
    {
        let path = path.clone();
        run_parallel(ranks, move |comm| {
            let rank = comm.rank();
            let mut f = ScdaFile::create(comm, &path, b"pipe-eq").unwrap();
            f.set_level(level);
            f.set_codec_parallel(par_factory());
            // A section.
            let r = apart.local_range(rank);
            let local = &arr[(r.start * elem) as usize..(r.end * elem) as usize];
            f.write_array(DataSrc::Contiguous(local), &apart, elem, Some(b"a"), true).unwrap();
            // B section (root-held).
            f.write_block_from(0, Some(&block), block.len() as u64, Some(b"b"), true).unwrap();
            // V section, including empty elements.
            let r = vpart.local_range(rank);
            let local_sizes = &vsizes[r.start as usize..r.end as usize];
            let start: u64 = vsizes[..r.start as usize].iter().sum();
            let len: u64 = local_sizes.iter().sum();
            let local = &vdata[start as usize..(start + len) as usize];
            f.write_varray(DataSrc::Contiguous(local), &vpart, local_sizes, Some(b"v"), true).unwrap();
            // Empty V section (zero elements).
            let empty = Partition::uniform(vpart.num_ranks(), 0);
            f.write_varray(DataSrc::Contiguous(&[]), &empty, &[], Some(b"empty"), true).unwrap();
            f.close().unwrap();
        });
    }
    let bytes = std::fs::read(&path).unwrap();
    std::fs::remove_file(&path).ok();
    bytes
}

#[test]
fn encoded_bytes_identical_across_worker_counts_and_partitions() {
    let mut rng = Rng::new(0xC0DEC);
    for (case, level) in [(0usize, 9u8), (1, 9), (2, 0), (3, 6)] {
        let elem = [64u64, 1, 4096, 997][case % 4];
        let an = rng.range(20, 200);
        let arr = Arc::new(rng.bytes((an * elem) as usize, 7));
        let vn = rng.range(10, 120);
        let vsizes = Arc::new(varray_sizes(&mut rng, vn));
        let vdata = Arc::new(rng.bytes(vsizes.iter().sum::<u64>() as usize, 13));
        let block = Arc::new(rng.bytes(10_000, 5));

        // Reference: one rank, strictly serial codec.
        let reference = write_encoded_file(
            &format!("ref-{case}"),
            level,
            1,
            || CodecParallel::Serial,
            Arc::clone(&arr),
            elem,
            Arc::new(Partition::uniform(1, an)),
            Arc::clone(&vdata),
            Arc::clone(&vsizes),
            Arc::new(Partition::uniform(1, vn)),
            Arc::clone(&block),
        );

        for ranks in [1usize, 2, 3] {
            let apart = Arc::new(Partition::from_counts(&rng.partition(an, ranks)));
            let vpart = Arc::new(Partition::from_counts(&rng.partition(vn, ranks)));
            for lanes in [1usize, 2, 8] {
                // One caller-owned pool shared by all ranks of the group.
                let pool = Arc::new(CodecPool::new(lanes));
                let pool2 = Arc::clone(&pool);
                let got = write_encoded_file(
                    &format!("got-{case}-{ranks}-{lanes}"),
                    level,
                    ranks,
                    move || CodecParallel::Pool(Arc::clone(&pool2)),
                    Arc::clone(&arr),
                    elem,
                    Arc::clone(&apart),
                    Arc::clone(&vdata),
                    Arc::clone(&vsizes),
                    Arc::clone(&vpart),
                    Arc::clone(&block),
                );
                assert_eq!(
                    got, reference,
                    "case {case} level {level}: bytes differ at ranks={ranks} lanes={lanes}"
                );
            }
        }
    }
}

#[test]
fn decoded_payloads_identical_across_worker_counts_and_partitions() {
    let mut rng = Rng::new(0xDEC0DE);
    let elem = 512u64;
    let an = 150u64;
    let arr = Arc::new(rng.bytes((an * elem) as usize, 6));
    let vn = 90u64;
    let vsizes = Arc::new(varray_sizes(&mut rng, vn));
    let vdata = Arc::new(rng.bytes(vsizes.iter().sum::<u64>() as usize, 9));
    let block = Arc::new(rng.bytes(5000, 4));

    // Write once (serial reference path).
    let path = tmp("decode-src");
    {
        let mut f = ScdaFile::create(SerialComm::new(), &path, b"pipe-eq").unwrap();
        f.set_codec_parallel(CodecParallel::Serial);
        f.write_array(DataSrc::Contiguous(&arr), &Partition::uniform(1, an), elem, Some(b"a"), true).unwrap();
        f.write_block_from(0, Some(&block), block.len() as u64, Some(b"b"), true).unwrap();
        f.write_varray(DataSrc::Contiguous(&vdata), &Partition::uniform(1, vn), &vsizes, Some(b"v"), true)
            .unwrap();
        f.close().unwrap();
    }

    // Read back under differing partitions and worker counts; the
    // stitched plaintext must equal the original data bit-for-bit.
    for ranks in [1usize, 2, 4] {
        let apart = Arc::new(Partition::from_counts(&rng.partition(an, ranks)));
        let vpart = Arc::new(Partition::from_counts(&rng.partition(vn, ranks)));
        for lanes in [1usize, 2, 8] {
            let pool = Arc::new(CodecPool::new(lanes));
            let (arr2, vdata2, vsizes2, block2, path2) =
                (Arc::clone(&arr), Arc::clone(&vdata), Arc::clone(&vsizes), Arc::clone(&block), path.clone());
            let (apart2, vpart2) = (Arc::clone(&apart), Arc::clone(&vpart));
            run_parallel(ranks, move |comm| {
                let rank = comm.rank();
                let mut f = ScdaFile::open(comm, &path2).unwrap();
                f.set_codec_parallel(CodecParallel::Pool(Arc::clone(&pool)));
                let h = f.read_section_header(true).unwrap();
                assert!(h.decoded);
                let got = f.read_array_data(&apart2, elem, true).unwrap().unwrap();
                let r = apart2.local_range(rank);
                assert_eq!(got, arr2[(r.start * elem) as usize..(r.end * elem) as usize], "A lanes mismatch");
                let h = f.read_section_header(true).unwrap();
                assert!(h.decoded);
                let b = f.read_block_data(0, true).unwrap();
                if rank == 0 {
                    assert_eq!(b.unwrap(), *block2);
                }
                let h = f.read_section_header(true).unwrap();
                assert!(h.decoded);
                let sizes = f.read_varray_sizes(&vpart2).unwrap();
                let r = vpart2.local_range(rank);
                assert_eq!(sizes, vsizes2[r.start as usize..r.end as usize]);
                let got = f.read_varray_data(&vpart2, &sizes, true).unwrap().unwrap();
                let start: u64 = vsizes2[..r.start as usize].iter().sum();
                let len: u64 = sizes.iter().sum();
                assert_eq!(got, vdata2[start as usize..(start + len) as usize], "V lanes mismatch");
                f.close().unwrap();
            });
        }
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn shared_pool_default_matches_serial_bytes() {
    // The default configuration (shared global pool) must also be
    // byte-identical to the serial path.
    let mut rng = Rng::new(0x51AB);
    let elem = 300u64;
    let n = 64u64;
    let data = rng.bytes((n * elem) as usize, 11);
    let part = Partition::uniform(1, n);
    let write = |par: CodecParallel, name: &str| -> Vec<u8> {
        let path = tmp(name);
        let mut f = ScdaFile::create(SerialComm::new(), &path, b"pipe-eq").unwrap();
        f.set_codec_parallel(par);
        f.write_array(DataSrc::Contiguous(&data), &part, elem, Some(b"a"), true).unwrap();
        f.close().unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::remove_file(&path).ok();
        bytes
    };
    let serial = write(CodecParallel::Serial, "shared-serial");
    let shared = write(CodecParallel::Shared, "shared-pool");
    assert_eq!(serial, shared);
}
