//! Corruption and truncation properties of catalog-bearing archives:
//! clipping the file at section boundaries leaves a *valid* scda prefix
//! (sections tile), clipping anywhere else fails `verify_bytes` with a
//! corrupt-file code, and damaging the catalog or footer index makes
//! `Archive::open` (or the subsequent named reads) fail with
//! `corrupt::*` codes — never panic, never silently misread.

use scda::api::{verify_bytes, DataSrc, ScdaFile};
use scda::archive::Archive;
use scda::error::corrupt;
use scda::par::{Partition, SerialComm};
use scda::ScdaErrorKind;
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-archive-props");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

/// Build a small catalog-bearing archive; returns (bytes, dataset names,
/// reference payloads, logical section boundaries including trailer).
fn build() -> (Vec<u8>, Vec<(String, Vec<u8>)>, Vec<u64>) {
    let path = tmp("subject");
    let part = Partition::uniform(1, 6);
    let arr: Vec<u8> = (0..6 * 24u32).map(|i| (i * 7 % 251) as u8).collect();
    let sizes: Vec<u64> = vec![3, 0, 9, 1, 4, 2];
    let var: Vec<u8> = (0..19u8).map(|i| i.wrapping_mul(13)).collect();
    let mut ar = Archive::create(SerialComm::new(), &path, b"props").unwrap();
    ar.write_array("a/raw", DataSrc::Contiguous(&arr), &part, 24, false).unwrap();
    ar.write_array("a/enc", DataSrc::Contiguous(&arr), &part, 24, true).unwrap();
    ar.write_varray("v/raw", DataSrc::Contiguous(&var), &part, &sizes, false).unwrap();
    ar.finish().unwrap();
    let bytes = std::fs::read(&path).unwrap();
    // Logical boundaries from the toc (offset of each section + EOF).
    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    let toc = f.toc(true).unwrap();
    f.close().unwrap();
    let mut bounds: Vec<u64> = toc.iter().map(|e| e.offset).collect();
    bounds.push(bytes.len() as u64);
    std::fs::remove_file(&path).unwrap();
    let refs = vec![
        ("a/raw".to_string(), arr.clone()),
        ("a/enc".to_string(), arr),
        ("v/raw".to_string(), var),
    ];
    (bytes, refs, bounds)
}

/// Open the image (written to a temp file) as an archive and read every
/// cataloged dataset, comparing against the reference payloads. Returns
/// `Ok(true)` for a full round-trip, `Ok(false)` for a graceful error,
/// and panics only if the archive layer itself panicked (which the test
/// is asserting never happens).
fn open_and_read_all(image: &[u8], refs: &[(String, Vec<u8>)]) -> bool {
    let path = tmp("probe");
    std::fs::write(&path, image).unwrap();
    let result = read_back(&path, refs);
    std::fs::remove_file(&path).ok();
    result.unwrap_or(false)
}

fn read_back(path: &std::path::Path, refs: &[(String, Vec<u8>)]) -> scda::Result<bool> {
    let part = Partition::uniform(1, 6);
    let mut ar = Archive::open(SerialComm::new(), path)?;
    let names: Vec<String> = ar.datasets().iter().map(|d| d.name.clone()).collect();
    for name in &names {
        let reference = refs.iter().find(|(n, _)| n == name);
        match name.as_str() {
            "v/raw" => {
                let (_, data) = ar.read_varray(name, &part)?;
                if reference.map(|(_, r)| r != &data).unwrap_or(true) {
                    return Ok(false);
                }
            }
            _ => {
                let data = ar.read_array(name, &part, 24)?;
                if reference.map(|(_, r)| r != &data).unwrap_or(true) {
                    return Ok(false);
                }
            }
        }
    }
    Ok(names.len() == refs.len())
}

#[test]
fn truncation_at_every_boundary_and_within() {
    let (bytes, refs, bounds) = build();
    assert_eq!(verify_bytes(&bytes).unwrap(), 6, "3 datasets = 4 raw sections + trailer pair");
    assert!(open_and_read_all(&bytes, &refs), "pristine archive must round-trip");

    for (i, &b) in bounds.iter().enumerate() {
        // Clip exactly at a logical section boundary: the prefix is a
        // structurally valid scda file (sections tile), just shorter —
        // and the archive layer degrades to the scan, never panics.
        let clipped = &bytes[..b as usize];
        if b > 128 {
            assert!(verify_bytes(clipped).is_ok(), "boundary clip {i} at {b} should stay valid");
        }
        let _ = open_and_read_all(clipped, &refs); // must not panic

        // Clip strictly inside the section that starts at this boundary:
        // structural truncation, detected with a corrupt-file code.
        for delta in [1u64, 17, 63] {
            let cut = b + delta;
            if cut >= bytes.len() as u64 {
                continue;
            }
            let clipped = &bytes[..cut as usize];
            let err = verify_bytes(clipped).unwrap_err();
            assert_eq!(err.kind(), ScdaErrorKind::CorruptFile, "cut at {cut}");
            assert!(
                (1000..2000).contains(&err.code()),
                "cut at {cut} gave non-corrupt code {}",
                err.code()
            );
            assert!(!open_and_read_all(clipped, &refs), "cut at {cut} must not round-trip");
        }
    }
}

#[test]
fn catalog_and_index_flips_fail_with_catalog_codes() {
    let (bytes, refs, bounds) = build();
    let n = bounds.len();
    // bounds[n-3] is the catalog section, bounds[n-2] the index section.
    let catalog_off = bounds[n - 3] as usize;
    let index_off = bounds[n - 2] as usize;

    // Targeted: an index payload that is not a number.
    let mut img = bytes.clone();
    img[index_off + 64..index_off + 96].copy_from_slice(&[b'x'; 32]);
    assert_eq!(open_err(&img, "nonnumeric").code(), 1000 + corrupt::BAD_CATALOG);

    // Targeted: an index pointing outside the section region.
    let mut img = bytes.clone();
    let huge = format!("{:>31}\n", u64::MAX);
    img[index_off + 64..index_off + 96].copy_from_slice(huge.as_bytes());
    assert_eq!(open_err(&img, "outofrange").code(), 1000 + corrupt::BAD_CATALOG);

    // Targeted: an in-range index pointing at bytes that are not a
    // section header (mid-catalog garbage) — still the *index's* fault,
    // still BAD_CATALOG, not a misleading bad-section diagnosis.
    let mut img = bytes.clone();
    let shifted = format!("{:>31}\n", catalog_off as u64 + 7);
    img[index_off + 64..index_off + 96].copy_from_slice(shifted.as_bytes());
    assert_eq!(open_err(&img, "middata").code(), 1000 + corrupt::BAD_CATALOG);

    // Targeted: a garbled catalog head (the index is fine, the catalog
    // text it names is not).
    let mut img = bytes.clone();
    // First payload byte of the catalog block: 64-byte type row + 32-byte
    // E entry.
    img[catalog_off + 96] ^= 0x55;
    assert_eq!(open_err(&img, "head").code(), 1000 + corrupt::BAD_CATALOG);

    // Exhaustive: flip every byte of the trailer region (catalog section
    // + index section). Every flip must either surface as a graceful
    // error somewhere between open and the named reads, or leave the
    // archive fully round-tripping (flips in `z=` flags, say, change
    // advisory metadata only) — never panic, never misread data.
    for pos in catalog_off..bytes.len() {
        let mut img = bytes.clone();
        img[pos] ^= 0x01;
        let _ok_or_graceful = open_and_read_all(&img, &refs);
    }

    // Flips in the *section machinery* of the trailer (type rows, count
    // entries, padding) must additionally fail strict verification.
    for pos in [catalog_off, catalog_off + 1, index_off, index_off + 1] {
        let mut img = bytes.clone();
        img[pos] ^= 0x55;
        assert!(verify_bytes(&img).is_err(), "header flip at {pos} passed verify");
    }
}

/// Mid-file section-header corruption vs `recover`: flip bytes inside
/// *interior* section headers (not just the trailer). Recovery must
/// either produce a verify-clean archive whose served datasets are
/// byte-identical to the references, or fail with a corrupt-file code —
/// never panic, never wrong data.
#[test]
fn recover_survives_mid_file_header_corruption() {
    let (bytes, refs, bounds) = build();
    let path = tmp("recover-flip");
    // Interior section starts: every logical boundary except EOF. The
    // flips land in the 64-byte type row: magic, kind letter, length
    // digits, user string.
    for (i, &b) in bounds[..bounds.len() - 1].iter().enumerate() {
        for (off, mask) in [(0usize, 0x01u8), (1, 0x80), (8, 0x55), (33, 0x20), (63, 0x04)] {
            let pos = b as usize + off;
            if pos >= bytes.len() {
                continue;
            }
            let mut img = bytes.clone();
            img[pos] ^= mask;
            std::fs::write(&path, &img).unwrap();
            match scda::archive::recover(&path) {
                Ok(_) => {
                    scda::api::verify_file(&path).unwrap_or_else(|e| {
                        panic!("boundary {i} flip at {pos}: recover said Ok but verify fails: {e}")
                    });
                    no_wrong_data(&path, &refs);
                }
                Err(e) => assert_eq!(
                    e.kind(),
                    ScdaErrorKind::CorruptFile,
                    "boundary {i} flip at {pos}: non-corrupt error {e}"
                ),
            }
        }
    }
    std::fs::remove_file(&path).ok();
}

/// Every dataset the (possibly recovered) archive still serves must match
/// its reference byte-for-byte. Graceful errors — at open or at any read
/// — are acceptable outcomes for a damaged file; wrong bytes are not.
fn no_wrong_data(path: &std::path::Path, refs: &[(String, Vec<u8>)]) {
    let part = Partition::uniform(1, 6);
    let Ok(mut ar) = Archive::open(SerialComm::new(), path) else { return };
    let names: Vec<String> = ar.datasets().iter().map(|d| d.name.clone()).collect();
    for name in &names {
        let Some((_, reference)) = refs.iter().find(|(n, _)| n == name) else { continue };
        let got = if name == "v/raw" {
            ar.read_varray(name, &part).map(|(_, d)| d)
        } else {
            ar.read_array(name, &part, 24)
        };
        if let Ok(data) = got {
            assert_eq!(&data, reference, "dataset {name} served wrong bytes after recovery");
        }
    }
}

/// Write the image under a distinct name, open it as an archive, return
/// the error, and clean the file up.
fn open_err(image: &[u8], label: &str) -> scda::ScdaError {
    let path = tmp(&format!("flip-{label}"));
    std::fs::write(&path, image).unwrap();
    let err = Archive::open(SerialComm::new(), &path).unwrap_err();
    std::fs::remove_file(&path).ok();
    err
}
