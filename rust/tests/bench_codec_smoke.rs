//! Quick-mode codec bench smoke: exercises the measurement harness end
//! to end and records `BENCH_codec.json` so the perf trajectory is
//! tracked from this PR onward.
//!
//! `#[ignore]`d by default so `cargo test -q` stays fast and
//! timing-insensitive; run explicitly with
//! `cargo test --test bench_codec_smoke -- --ignored`.

use scda::bench_support::{bench_json_path, codec_bench};

#[test]
#[ignore = "perf smoke; run with -- --ignored"]
fn codec_bench_quick_records_json() {
    // Small quick-mode workload: 2 MiB, 32 KiB elements, 4 lanes.
    let t = codec_bench::run(4, 2 << 20, 32 << 10, 2);
    assert!(t.write_serial > 0.0 && t.write_pooled > 0.0);
    assert!(t.read_serial > 0.0 && t.read_pooled > 0.0);
    let path = bench_json_path();
    t.report().write(&path).unwrap();
    let written = std::fs::read_to_string(&path).unwrap();
    assert!(written.contains("\"bench\": \"codec\""));
    assert!(written.contains("encoded_write"));
    assert!(written.contains("encoded_read"));
    assert!(written.contains("precond_frames"));
    // The §5.4 stage must actually shrink the AMR f64 frames.
    assert!(
        t.precond.size_ratio() > 1.0,
        "preconditioning grew the encoded bytes: {} -> {}",
        t.precond.plain_bytes,
        t.precond.precond_bytes
    );
    println!(
        "codec quick: write {:.0} -> {:.0} MiB/s ({:.2}x), read {:.0} -> {:.0} MiB/s ({:.2}x); wrote {}",
        t.write_serial,
        t.write_pooled,
        t.write_speedup(),
        t.read_serial,
        t.read_pooled,
        t.read_speedup(),
        path.display(),
    );
}

#[test]
fn codec_bench_harness_roundtrips_tiny_workload() {
    // Non-ignored correctness pass through the same harness at a size
    // too small to be a benchmark: verifies the encode/decode round
    // trip and the report shape without timing assertions.
    let t = codec_bench::run(2, 256 << 10, 16 << 10, 1);
    assert_eq!(t.lanes, 2);
    assert_eq!(t.elem_bytes, 16 << 10);
    let r = t.report().render();
    assert!(r.contains("\"pooled_mib_per_s\""));
    assert!(r.contains("\"speedup\""));
    // The precond entry carries real byte counts (size, not timing, so
    // it is exact even at this scale).
    assert!(r.contains("\"precond_encoded_bytes\""));
    assert!(t.precond.plain_bytes > 0 && t.precond.precond_bytes > 0);
}
