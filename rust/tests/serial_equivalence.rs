//! T1 — the paper's core claim: "the format is designed such that the file
//! contents are invariant under linear (i.e., unpermuted), parallel
//! repartition of the data prior to writing. The file contents are
//! indistinguishable from writing in serial."
//!
//! Property test: a randomized script of sections is written (a) in serial
//! and (b) on every P in a set of process counts under random partitions;
//! all resulting files must be byte-identical.

use scda::api::{DataSrc, ScdaFile};
use scda::par::{run_parallel, Communicator, Partition, SerialComm};
use scda::testutil::Rng;
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-sereq");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

/// One section of a randomized write script (global data + user string).
#[derive(Debug, Clone)]
enum Cmd {
    Inline { data: Vec<u8>, user: Vec<u8> },
    Block { data: Vec<u8>, user: Vec<u8>, encode: bool },
    Array { data: Vec<u8>, n: u64, e: u64, user: Vec<u8>, encode: bool },
    Varray { data: Vec<u8>, sizes: Vec<u64>, user: Vec<u8>, encode: bool },
}

fn random_script(rng: &mut Rng, sections: usize) -> Vec<Cmd> {
    let mut script = Vec::new();
    for _ in 0..sections {
        let user = rng.user_string();
        match rng.below(4) {
            0 => script.push(Cmd::Inline { data: rng.bytes(32, 256), user }),
            1 => {
                let len = rng.below(5000) as usize;
                script.push(Cmd::Block { data: rng.bytes(len, 64), user, encode: rng.bool() })
            }
            2 => {
                let n = rng.below(300);
                let e = rng.range(1, 64);
                script.push(Cmd::Array {
                    data: rng.bytes((n * e) as usize, 16),
                    n,
                    e,
                    user,
                    encode: rng.bool(),
                })
            }
            _ => {
                let n = rng.below(200);
                let sizes: Vec<u64> = (0..n).map(|_| rng.below(100)).collect();
                let total: u64 = sizes.iter().sum();
                script.push(Cmd::Varray { data: rng.bytes(total as usize, 16), sizes, user, encode: rng.bool() })
            }
        }
    }
    script
}

/// Execute the script on an open file; array data is contributed by this
/// rank's window of the given partitions (one partition per array cmd).
fn run_script<C: scda::par::Communicator>(
    f: &mut ScdaFile<C>,
    script: &[Cmd],
    parts: &[Partition],
    rank: usize,
) {
    let mut pi = 0usize;
    for cmd in script {
        match cmd {
            Cmd::Inline { data, user } => f.write_inline(data, Some(user)).unwrap(),
            Cmd::Block { data, user, encode } => {
                f.write_block_from(0, Some(data), data.len() as u64, Some(user), *encode).unwrap()
            }
            Cmd::Array { data, e, user, encode, .. } => {
                let part = &parts[pi];
                pi += 1;
                let r = part.local_range(rank);
                let local = &data[(r.start * e) as usize..(r.end * e) as usize];
                f.write_array(DataSrc::Contiguous(local), part, *e, Some(user), *encode).unwrap();
            }
            Cmd::Varray { data, sizes, user, encode } => {
                let part = &parts[pi];
                pi += 1;
                let r = part.local_range(rank);
                let local_sizes = &sizes[r.start as usize..r.end as usize];
                let start: u64 = sizes[..r.start as usize].iter().sum();
                let len: u64 = local_sizes.iter().sum();
                let local = &data[start as usize..(start + len) as usize];
                f.write_varray(DataSrc::Contiguous(local), part, local_sizes, Some(user), *encode).unwrap();
            }
        }
    }
}

/// Partitions for the script's array-ish commands under P ranks.
fn partitions_for(rng: &mut Rng, script: &[Cmd], ranks: usize) -> Vec<Partition> {
    script
        .iter()
        .filter_map(|cmd| match cmd {
            Cmd::Array { n, .. } => Some(*n),
            Cmd::Varray { sizes, .. } => Some(sizes.len() as u64),
            _ => None,
        })
        .map(|n| Partition::from_counts(&rng.partition(n, ranks)))
        .collect()
}

#[test]
fn file_bytes_invariant_under_repartition() {
    let mut rng = Rng::new(0x5cda);
    for case in 0..6 {
        let script = Arc::new(random_script(&mut rng, 6));
        // Serial reference.
        let ref_path = tmp(&format!("ref-{case}"));
        {
            let mut f = ScdaFile::create(SerialComm::new(), &ref_path, b"sereq").unwrap();
            let parts = partitions_for(&mut rng, &script, 1);
            run_script(&mut f, &script, &parts, 0);
            f.close().unwrap();
        }
        let reference = std::fs::read(&ref_path).unwrap();
        scda::api::verify_bytes(&reference).unwrap();

        for ranks in [2usize, 3, 5, 8] {
            let par_path = Arc::new(tmp(&format!("par-{case}-{ranks}")));
            let parts = Arc::new(partitions_for(&mut rng, &script, ranks));
            let script2 = Arc::clone(&script);
            let pp = Arc::clone(&par_path);
            let parts2 = Arc::clone(&parts);
            run_parallel(ranks, move |comm| {
                let rank = comm.rank();
                let mut f = ScdaFile::create(comm, &*pp, b"sereq").unwrap();
                run_script(&mut f, &script2, &parts2, rank);
                f.close().unwrap();
            });
            let written = std::fs::read(&*par_path).unwrap();
            assert_eq!(
                written, reference,
                "case {case}: file bytes differ between serial and P={ranks}"
            );
            std::fs::remove_file(&*par_path).unwrap();
        }
        std::fs::remove_file(&ref_path).unwrap();
    }
}

#[test]
fn root_placement_does_not_change_bytes() {
    // Inline/block data may live on any root rank; the bytes must not
    // depend on which.
    let mut images = Vec::new();
    for root in 0..4usize {
        let path = Arc::new(tmp(&format!("root-{root}")));
        let pp = Arc::clone(&path);
        run_parallel(4, move |comm| {
            let rank = comm.rank();
            let mut f = ScdaFile::create(comm, &*pp, b"roots").unwrap();
            let inline = [b'q'; 32];
            f.write_inline_from(root, if rank == root { Some(&inline) } else { None }, Some(b"i")).unwrap();
            let block = b"root-independent".to_vec();
            f.write_block_from(root, if rank == root { Some(&block) } else { None }, block.len() as u64, Some(b"b"), true)
                .unwrap();
            f.close().unwrap();
        });
        images.push(std::fs::read(&*path).unwrap());
        std::fs::remove_file(&*path).unwrap();
    }
    for w in images.windows(2) {
        assert_eq!(w[0], w[1]);
    }
}

#[test]
fn reading_is_partition_free() {
    // Write once on 3 ranks; read the same array on 1..=6 ranks under
    // random partitions; reassembled bytes must match.
    let n = 444u64;
    let e = 7u64;
    let mut rng = Rng::new(7777);
    let data: Arc<Vec<u8>> = Arc::new(rng.bytes((n * e) as usize, 256));
    let path = Arc::new(tmp("readfree"));
    {
        let (pp, dd) = (Arc::clone(&path), Arc::clone(&data));
        run_parallel(3, move |comm| {
            let part = Partition::uniform(3, n);
            let r = part.local_range(comm.rank());
            let local = &dd[(r.start * e) as usize..(r.end * e) as usize];
            let mut f = ScdaFile::create(comm, &*pp, b"").unwrap();
            f.write_array(DataSrc::Contiguous(local), &part, e, Some(b"x"), false).unwrap();
            f.close().unwrap();
        });
    }
    for ranks in 1..=6usize {
        let part = Arc::new(Partition::from_counts(&rng.partition(n, ranks)));
        let (pp, dd, part2) = (Arc::clone(&path), Arc::clone(&data), Arc::clone(&part));
        let pieces = run_parallel(ranks, move |comm| {
            let mut f = ScdaFile::open(comm, &*pp).unwrap();
            f.read_section_header(false).unwrap();
            let out = f.read_array_data(&part2, e, true).unwrap().unwrap();
            f.close().unwrap();
            out
        });
        let reassembled: Vec<u8> = pieces.concat();
        assert_eq!(&reassembled, &*data, "ranks={ranks}");
    }
    std::fs::remove_file(&*path).unwrap();
}
