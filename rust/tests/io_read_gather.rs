//! The collective read gather — the read-side dual of the two-phase
//! collective write (`crate::io::collective`): payload reads route to
//! stripe-owner ranks, so read syscalls track the *bytes touched*, not
//! the rank count or the section interleaving, while every engine's
//! reads stay byte-identical to the direct reference path.

use scda::api::{DataSrc, EngineStats, IoTuning, ScdaFile};
use scda::coordinator::checkpoint::{read_checkpoint, read_checkpoint_tuned, write_checkpoint};
use scda::coordinator::Metrics;
use scda::format::section::SectionKind;
use scda::par::{run_parallel, Communicator, Partition, SerialComm};
use scda::runtime::NativeTransform;
use std::path::PathBuf;
use std::sync::Arc;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-read-gather");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

/// The interleaved workload of `io_engines.rs`: inline, block, fixed
/// array (8-byte elements), then `sections` varrays of `elem_bytes`
/// elements. Written serially — serial equivalence makes the bytes
/// identical to any parallel writer, so the write side stays out of
/// this test's way.
fn write_workload(path: &PathBuf, sections: usize, elems_total: usize, elem_bytes: usize) {
    let part = Partition::uniform(1, elems_total as u64);
    let mut f = ScdaFile::create(SerialComm::new(), path, b"read-gather").unwrap();
    f.set_sync_on_close(false);
    f.set_io_tuning(IoTuning::direct()).unwrap();
    f.write_inline(&[b'i'; 32], Some(b"inline")).unwrap();
    let block: Vec<u8> = (0..300usize).map(|i| (i % 251) as u8).collect();
    f.write_block_from(0, Some(&block), 300, Some(b"block"), false).unwrap();
    let adata: Vec<u8> = (0..elems_total * 8).map(|i| (i % 251) as u8).collect();
    f.write_array(DataSrc::Contiguous(&adata), &part, 8, Some(b"arr"), false).unwrap();
    let vdata: Vec<u8> = (0..elems_total * elem_bytes).map(|i| (i * 7 % 251) as u8).collect();
    let sizes = vec![elem_bytes as u64; elems_total];
    for _ in 0..sections {
        f.write_varray(DataSrc::Contiguous(&vdata), &part, &sizes, Some(b"var"), false).unwrap();
    }
    f.close().unwrap();
}

/// Read the whole workload back on `ranks` ranks; returns each rank's
/// concatenated payloads and engine counters.
fn read_all(
    path: &Arc<PathBuf>,
    ranks: usize,
    sections: usize,
    elems_total: usize,
    tuning: IoTuning,
) -> Vec<(Vec<u8>, EngineStats)> {
    let path = Arc::clone(path);
    run_parallel(ranks, move |comm| {
        let part = Partition::uniform(ranks, elems_total as u64);
        let mut f = ScdaFile::open(comm, &**path).unwrap();
        f.set_io_tuning(tuning).unwrap();
        let mut acc = Vec::new();
        f.read_section_header(false).unwrap();
        if let Some(d) = f.read_inline_data(0, true).unwrap() {
            acc.extend_from_slice(&d);
        }
        f.read_section_header(false).unwrap();
        if let Some(d) = f.read_block_data(0, true).unwrap() {
            acc.extend_from_slice(&d);
        }
        f.read_section_header(false).unwrap();
        acc.extend(f.read_array_data(&part, 8, true).unwrap().unwrap());
        for _ in 0..sections {
            f.read_section_header(false).unwrap();
            let sizes = f.read_varray_sizes(&part).unwrap();
            acc.extend(f.read_varray_data(&part, &sizes, true).unwrap().unwrap());
        }
        assert!(f.at_end().unwrap());
        let st = f.engine_stats();
        f.close().unwrap();
        (acc, st)
    })
}

/// Read-side byte identity: at 1, 2, 4 and 8 ranks, every engine's
/// reads return exactly what the direct reference path returns.
#[test]
fn read_side_byte_identity_vs_direct_at_1_2_4_8_ranks() {
    let (sections, elems) = (3usize, 64usize);
    let path = Arc::new(tmp("identity"));
    write_workload(&path, sections, elems, 48);
    let configs: Vec<(&str, IoTuning)> = vec![
        ("aggregated", IoTuning::default()),
        ("collective", IoTuning::collective()),
        ("collective_small_stripes", IoTuning::collective().with_stripe_size(4 << 10)),
    ];
    for ranks in [1usize, 2, 4, 8] {
        let reference: Vec<Vec<u8>> = read_all(&path, ranks, sections, elems, IoTuning::direct())
            .into_iter()
            .map(|(d, _)| d)
            .collect();
        for (name, tuning) in &configs {
            let got: Vec<Vec<u8>> =
                read_all(&path, ranks, sections, elems, *tuning).into_iter().map(|(d, _)| d).collect();
            assert_eq!(got, reference, "{name} reads differ from direct at ranks={ranks}");
        }
    }
    std::fs::remove_file(&*path).unwrap();
}

/// The bytes-touched formula: one owner-side pread per stripe touched
/// by each collective data window (adjacent stripes never share an
/// owner at P >= 2), summed over the array payload and every varray
/// payload region.
fn expected_gather_preads(path: &PathBuf, stripe: u64, elem_bytes: u64) -> u64 {
    let mut f = ScdaFile::open(SerialComm::new(), path).unwrap();
    f.set_io_tuning(IoTuning::direct()).unwrap();
    let toc = f.toc(false).unwrap();
    f.close().unwrap();
    let stripes = |off: u64, len: u64| {
        if len == 0 {
            0
        } else {
            (off + len - 1) / stripe - off / stripe + 1
        }
    };
    let mut total = 0u64;
    for e in &toc {
        match e.header.kind {
            // Raw A prefix: 64-byte type row + N row + E row.
            SectionKind::Array => total += stripes(e.offset + 128, e.header.elem_count * e.header.elem_size),
            // Raw V: 64 + 32 (N row) + N size rows precede the payload.
            SectionKind::Varray => {
                total += stripes(e.offset + 96 + e.header.elem_count * 32, e.header.elem_count * elem_bytes)
            }
            _ => {}
        }
    }
    total
}

/// The acceptance invariant: the collective read-gather syscall count
/// is identical at P = 2, 4 and 8 and across section interleavings of
/// the same payload — it equals the touched-stripe formula, a pure
/// function of the bytes read.
#[test]
fn gather_preads_track_bytes_touched_not_ranks_or_interleaving() {
    const STRIPE: u64 = 4 << 10;
    let tuning = IoTuning::collective().with_stripe_size(STRIPE as usize);
    let mut per_shape = Vec::new();
    // Two interleavings of the same varray payload: 4 sections x 128
    // elements vs 8 sections x 64 elements, 64-byte elements.
    for (shape, (sections, elems)) in [(4usize, 128usize), (8, 64)].into_iter().enumerate() {
        let path = Arc::new(tmp(&format!("invariance-{shape}")));
        write_workload(&path, sections, elems, 64);
        let expected = expected_gather_preads(&path, STRIPE, 64);
        let mut per_p = Vec::new();
        for ranks in [2usize, 4, 8] {
            let stats = read_all(&path, ranks, sections, elems, tuning);
            let preads: u64 = stats.iter().map(|(_, e)| e.gather_preads).sum();
            let exchanges: u64 = stats.iter().map(|(_, e)| e.read_exchanges).sum();
            // One gather per collective data read on every rank: the
            // array window plus one per varray section.
            assert_eq!(exchanges, ((1 + sections) * ranks) as u64, "shape {shape} ranks {ranks}");
            per_p.push(preads);
        }
        assert_eq!(per_p[0], per_p[1], "shape {shape}: preads must not depend on the rank count");
        assert_eq!(per_p[1], per_p[2], "shape {shape}: preads must not depend on the rank count");
        assert_eq!(per_p[0], expected, "shape {shape}: one pread per touched stripe");
        per_shape.push((per_p[0], expected));
        std::fs::remove_file(&*path).unwrap();
    }
    // Across interleavings the count follows the formula, never the
    // shape: both shapes hold the same payload, and each matches its
    // own touched-stripe count exactly.
    for (got, expected) in per_shape {
        assert_eq!(got, expected);
    }
}

/// Lockstep toc scans route their header and size-row reads through the
/// collective gather: every rank requests the identical windows, owners
/// read each once, and the summed owner-side preads are invariant in
/// the rank count — instead of every rank paying its own header preads.
#[test]
fn toc_scan_dedupes_header_preads_across_ranks() {
    let (sections, elems) = (4usize, 64usize);
    let path = Arc::new(tmp("toc-dedup"));
    write_workload(&path, sections, elems, 48);
    let tuning = IoTuning::collective().with_stripe_size(4 << 10);
    let mut sums = Vec::new();
    for ranks in [2usize, 4] {
        let p = Arc::clone(&path);
        let stats = run_parallel(ranks, move |comm| {
            let mut f = ScdaFile::open(comm, &**p).unwrap();
            f.set_io_tuning(tuning).unwrap();
            let toc = f.toc(false).unwrap();
            assert_eq!(toc.len(), 3 + sections);
            let st = f.engine_stats();
            f.close().unwrap();
            st
        });
        assert!(stats.iter().all(|s| s.read_exchanges > 0), "scan reads went through the gather");
        sums.push(stats.iter().map(|s| s.gather_preads).sum::<u64>());
    }
    assert_eq!(sums[0], sums[1], "toc preads must not scale with the rank count");
    std::fs::remove_file(&*path).unwrap();
}

/// The gather moves bytes between ranks and beats the per-rank direct
/// syscall count on interleaved reads.
#[test]
fn gather_ships_fragments_and_cuts_read_calls() {
    let (sections, elems) = (4usize, 128usize);
    let path = Arc::new(tmp("volume"));
    write_workload(&path, sections, elems, 64);
    let ranks = 4;
    let gathered_stats = read_all(&path, ranks, sections, elems, IoTuning::collective().with_stripe_size(4 << 10));
    let gathered: u64 = gathered_stats.iter().map(|(_, e)| e.gathered_bytes).sum();
    assert!(gathered > 0, "interleaved windows must cross ranks");
    let preads: u64 = gathered_stats.iter().map(|(_, e)| e.gather_preads).sum();
    // The direct path issues one pread per logical access per rank;
    // the gather's data-path count must be far below it.
    let direct_data_reads = (ranks * (1 + sections)) as u64;
    assert!(
        preads <= direct_data_reads * 2,
        "gather preads ({preads}) should stay near the per-window stripe count"
    );
    std::fs::remove_file(&*path).unwrap();
}

/// Restore through the collective read tuning: same fields as the
/// default path, with the gather volume recorded in the metrics.
#[test]
fn checkpoint_restores_identically_through_the_gather() {
    let path = tmp("ckpt-gather");
    let leaves = scda::mesh::ring_mesh(3, 5, (0.5, 0.5), 0.3);
    let n = leaves.len() as u64;
    let rho = scda::mesh::fields::local_fixed_field(&leaves, 0..leaves.len(), 4);
    let write_part = Arc::new(Partition::uniform(3, n));
    let (p2, part2, rho2) = (path.clone(), Arc::clone(&write_part), rho.clone());
    run_parallel(3, move |comm| {
        let r = part2.local_range(comm.rank());
        let flds = vec![scda::coordinator::Field {
            name: "rho".into(),
            encode: false,
            precondition: false,
            payload: scda::coordinator::FieldPayload::Fixed {
                elem_size: 32,
                data: rho2[(r.start * 32) as usize..(r.end * 32) as usize].to_vec(),
            },
        }];
        write_checkpoint(comm, &p2, "gather-test", 1, &part2, &flds, &NativeTransform, &Metrics::new())
            .unwrap();
    });
    let read_part = Arc::new(Partition::uniform(4, n));
    let (pa, pb) = (path.clone(), path.clone());
    let (parta, partb) = (Arc::clone(&read_part), Arc::clone(&read_part));
    let default_fields = run_parallel(4, move |comm| {
        read_checkpoint(comm, &pa, &parta, &NativeTransform).unwrap().1
    });
    let metrics = Arc::new(Metrics::new());
    let m2 = Arc::clone(&metrics);
    let gathered_fields = run_parallel(4, move |comm| {
        read_checkpoint_tuned(
            comm,
            &pb,
            &partb,
            &NativeTransform,
            &m2,
            IoTuning::collective().with_stripe_size(4 << 10),
        )
        .unwrap()
        .1
    });
    for (d, g) in default_fields.iter().zip(&gathered_fields) {
        assert_eq!(d.len(), g.len());
        for (fd, fg) in d.iter().zip(g) {
            assert_eq!(fd.name, fg.name);
            assert_eq!(fd.payload, fg.payload);
        }
    }
    use std::sync::atomic::Ordering;
    assert!(metrics.read_calls.load(Ordering::Relaxed) > 0);
    assert!(
        metrics.bytes_gathered.load(Ordering::Relaxed) > 0,
        "a 4-rank restore through 4 KiB stripes must ship fragments"
    );
    std::fs::remove_file(&path).unwrap();
}
