//! Catalog-seeded range reads (`Archive::read_range` /
//! `read_varray_range`): equivalence with full-read-then-slice under
//! mismatched writer/reader partitions, compressed (convention)
//! payloads, and the `IoStats` byte-accounting guarantees — a raw array
//! range touches no size rows at all, and varray/encoded ranges read
//! only the size rows `[0, first + count)`, never a row at or past the
//! range end, never payload outside the window.

use scda::api::{DataSrc, IoTuning};
use scda::archive::Archive;
use scda::format::section::SECTION_PREFIX_MAX;
use scda::par::{run_parallel, Communicator, Partition, SerialComm};
use std::path::PathBuf;
use std::sync::Arc;

const N: u64 = 512;
const E: u64 = 32;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-archive-range");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

fn array_payload() -> Vec<u8> {
    (0..N * E).map(|i| ((i * 11) % 251) as u8).collect()
}

fn varray_payload() -> (Vec<u64>, Vec<u8>) {
    let sizes: Vec<u64> = (0..N).map(|i| (i * 7) % 5 + 1).collect();
    let mut data = Vec::new();
    for (i, &s) in sizes.iter().enumerate() {
        for j in 0..s {
            data.push(((i as u64 * 3 + j) % 251) as u8);
        }
    }
    (sizes, data)
}

/// Write the test archive on 3 ranks: raw + encoded fixed arrays, raw +
/// encoded varrays (serial equivalence makes the bytes independent of
/// the writing partition; the mismatched-partition tests read it back
/// at 1, 2 and 4 ranks).
fn build(path: &Arc<PathBuf>) {
    let p = Arc::clone(path);
    run_parallel(3, move |comm| {
        let part = Partition::uniform(3, N);
        let r = part.local_range(comm.rank());
        let adata = array_payload();
        let (vsizes, vdata) = varray_payload();
        let aw = &adata[(r.start * E) as usize..(r.end * E) as usize];
        let lsizes = &vsizes[r.start as usize..r.end as usize];
        let lo: u64 = vsizes[..r.start as usize].iter().sum();
        let len: u64 = lsizes.iter().sum();
        let vw = &vdata[lo as usize..(lo + len) as usize];
        let mut ar = Archive::create(comm, &**p, b"range-test").unwrap();
        ar.file_mut().set_sync_on_close(false);
        ar.write_array("a", DataSrc::Contiguous(aw), &part, E, false).unwrap();
        ar.write_array("az", DataSrc::Contiguous(aw), &part, E, true).unwrap();
        ar.write_varray("v", DataSrc::Contiguous(vw), &part, lsizes, false).unwrap();
        ar.write_varray("vz", DataSrc::Contiguous(vw), &part, lsizes, true).unwrap();
        ar.finish().unwrap();
    });
}

fn slice_fixed(first: u64, count: u64) -> Vec<u8> {
    array_payload()[(first * E) as usize..((first + count) * E) as usize].to_vec()
}

fn slice_var(first: u64, count: u64) -> (Vec<u64>, Vec<u8>) {
    let (sizes, data) = varray_payload();
    let lo: u64 = sizes[..first as usize].iter().sum();
    let sz = sizes[first as usize..(first + count) as usize].to_vec();
    let len: u64 = sz.iter().sum();
    (sz, data[lo as usize..(lo + len) as usize].to_vec())
}

/// Range reads equal full-read-then-slice for raw and encoded datasets,
/// over boundary and interior ranges, on a serial reader.
#[test]
fn range_reads_equal_full_read_then_slice() {
    let path = Arc::new(tmp("equiv"));
    build(&path);
    let mut ar = Archive::open(SerialComm::new(), &*path).unwrap();
    for (first, count) in [(0u64, 0u64), (0, 1), (0, N), (17, 3), (N - 5, 5), (N / 2, 20)] {
        for name in ["a", "az"] {
            let got = ar.read_range(name, first, count).unwrap();
            assert_eq!(got, slice_fixed(first, count), "{name} [{first}, +{count})");
        }
        for name in ["v", "vz"] {
            let (gs, gd) = ar.read_varray_range(name, first, count).unwrap();
            let (es, ed) = slice_var(first, count);
            assert_eq!(gs, es, "{name} sizes [{first}, +{count})");
            assert_eq!(gd, ed, "{name} data [{first}, +{count})");
        }
    }
    ar.close().unwrap();
    std::fs::remove_file(&*path).unwrap();
}

/// Mismatched writer/reader partitions: written on 3 ranks, the range
/// arrives identically on every rank of 2- and 4-rank readers — and
/// through the collective read gather, where the identical requests
/// dedupe into one stripe-owner read set.
#[test]
fn range_reads_on_mismatched_partitions_and_engines() {
    let path = Arc::new(tmp("parts"));
    build(&path);
    let cases: Vec<(usize, IoTuning)> = vec![
        (2, IoTuning::default()),
        (4, IoTuning::default()),
        (4, IoTuning::collective().with_stripe_size(4 << 10)),
        (4, IoTuning::direct()),
    ];
    for (ranks, tuning) in cases {
        let p = Arc::clone(&path);
        let results = run_parallel(ranks, move |comm| {
            let mut ar = Archive::open_with(comm, &**p, tuning, true).unwrap();
            let a = ar.read_range("az", 100, 7).unwrap();
            let v = ar.read_varray_range("vz", 200, 9).unwrap();
            ar.close().unwrap();
            (a, v)
        });
        let ea = slice_fixed(100, 7);
        let ev = slice_var(200, 9);
        for (rank, (a, v)) in results.iter().enumerate() {
            assert_eq!(a, &ea, "rank {rank} of {ranks} ({tuning:?})");
            assert_eq!(v, &ev, "rank {rank} of {ranks} ({tuning:?})");
        }
    }
    std::fs::remove_file(&*path).unwrap();
}

/// The `IoStats` accounting guarantees, measured under the direct
/// engine (one pread per logical access, so the counters *are* the
/// access shape).
#[test]
fn range_reads_touch_only_the_window() {
    let path = Arc::new(tmp("iostats"));
    build(&path);
    let mut ar = Archive::open_with(SerialComm::new(), &*path, IoTuning::direct(), true).unwrap();

    // Raw fixed array, mid-section range: exactly two preads — the
    // section prefix and the range's own bytes. No size rows exist, no
    // payload outside [first·E, (first+count)·E) is touched.
    let before = ar.file().io_stats();
    let got = ar.read_range("a", 200, 16).unwrap();
    assert_eq!(got, slice_fixed(200, 16));
    let d = ar.file().io_stats().since(&before);
    assert_eq!(d.read_calls, 2, "prefix + payload window only");
    assert_eq!(d.read_bytes, (SECTION_PREFIX_MAX as u64) + 16 * E, "not one byte outside the range");

    // Raw varray, range at the start: prefix + the 8 size rows of the
    // window + the window's payload — the 504 size rows past the range
    // end are never read.
    let (vsizes, _) = varray_payload();
    let w8: u64 = vsizes[..8].iter().sum();
    let before = ar.file().io_stats();
    let (gs, gd) = ar.read_varray_range("v", 0, 8).unwrap();
    assert_eq!((gs, gd), slice_var(0, 8));
    let d = ar.file().io_stats().since(&before);
    assert_eq!(d.read_calls, 3, "prefix + row window + payload window");
    assert_eq!(d.read_bytes, (SECTION_PREFIX_MAX as u64) + 8 * 32 + w8);

    // Raw varray, interior range: rows [0, first+count) for the
    // locating prefix sum, the window's payload, nothing else — far
    // below the section's full extent.
    let entry_len = ar.get("v").unwrap().byte_len;
    let w: u64 = vsizes[256..264].iter().sum();
    let before = ar.file().io_stats();
    ar.read_varray_range("v", 256, 8).unwrap();
    let d = ar.file().io_stats().since(&before);
    assert_eq!(d.read_bytes, (SECTION_PREFIX_MAX as u64) + 264 * 32 + w);
    assert!(d.read_bytes < entry_len, "a range read must not read the section");

    // Encoded array (convention 9), range at the start: the compressed
    // rows and payload of [0, 8) only — a small fraction of the pair.
    let az_len = ar.get("az").unwrap().byte_len;
    let before = ar.file().io_stats();
    let got = ar.read_range("az", 0, 8).unwrap();
    assert_eq!(got, slice_fixed(0, 8));
    let d = ar.file().io_stats().since(&before);
    assert_eq!(d.read_calls, 5, "I prefix + U entry + V prefix + row window + compressed window");
    assert!(d.read_bytes < az_len / 4, "read {} of {az_len} section bytes", d.read_bytes);

    ar.close().unwrap();
    std::fs::remove_file(&*path).unwrap();
}

/// Partitioned range reads: `read_range_partitioned` hands each rank its
/// own window of the range — equal to full-`read_range`-then-slice by
/// the rank's `local_range` — for raw and encoded arrays and varrays,
/// across engines.
#[test]
fn partitioned_range_reads_equal_sliced_full_range() {
    let path = Arc::new(tmp("part-range"));
    build(&path);
    let (first, count) = (100u64, 18u64);
    let cases: Vec<(usize, IoTuning)> = vec![
        (2, IoTuning::default()),
        (4, IoTuning::default()),
        (4, IoTuning::collective().with_stripe_size(4 << 10)),
        (4, IoTuning::direct()),
    ];
    for (ranks, tuning) in cases {
        let p = Arc::clone(&path);
        let results = run_parallel(ranks, move |comm| {
            let part = Partition::uniform(ranks, count);
            let mut ar = Archive::open_with(comm, &**p, tuning, true).unwrap();
            let a = ar.read_range_partitioned("a", first, count, &part).unwrap();
            let az = ar.read_range_partitioned("az", first, count, &part).unwrap();
            let v = ar.read_varray_range_partitioned("v", first, count, &part).unwrap();
            let vz = ar.read_varray_range_partitioned("vz", first, count, &part).unwrap();
            ar.close().unwrap();
            (a, az, v, vz)
        });
        let part = Partition::uniform(ranks, count);
        let ea = slice_fixed(first, count);
        let (es, ed) = slice_var(first, count);
        for (rank, (a, az, v, vz)) in results.iter().enumerate() {
            let r = part.local_range(rank);
            let want_a = &ea[(r.start * E) as usize..(r.end * E) as usize];
            assert_eq!(a, want_a, "rank {rank}/{ranks} a ({tuning:?})");
            assert_eq!(az, want_a, "rank {rank}/{ranks} az ({tuning:?})");
            let want_s = &es[r.start as usize..r.end as usize];
            let skip: u64 = es[..r.start as usize].iter().sum();
            let len: u64 = want_s.iter().sum();
            let want_d = &ed[skip as usize..(skip + len) as usize];
            for (name, (gs, gd)) in [("v", v), ("vz", vz)] {
                assert_eq!(gs, want_s, "rank {rank}/{ranks} {name} sizes ({tuning:?})");
                assert_eq!(gd, want_d, "rank {rank}/{ranks} {name} data ({tuning:?})");
            }
        }
    }
    std::fs::remove_file(&*path).unwrap();
}

/// Partition/communicator and partition/range mismatches fail with the
/// documented usage code and leave the archive usable.
#[test]
fn partitioned_range_read_validates_the_partition() {
    let path = Arc::new(tmp("part-range-err"));
    build(&path);
    let mut ar = Archive::open(SerialComm::new(), &*path).unwrap();
    let wrong_ranks = Partition::uniform(2, 10);
    let err = ar.read_range_partitioned("a", 0, 10, &wrong_ranks).unwrap_err();
    assert_eq!(err.code(), 3000 + scda::error::usage::PARTITION_MISMATCH);
    let wrong_total = Partition::uniform(1, 11);
    let err = ar.read_range_partitioned("a", 0, 10, &wrong_total).unwrap_err();
    assert_eq!(err.code(), 3000 + scda::error::usage::PARTITION_MISMATCH);
    let err = ar.read_varray_range_partitioned("v", 0, 10, &wrong_total).unwrap_err();
    assert_eq!(err.code(), 3000 + scda::error::usage::PARTITION_MISMATCH);
    // Still usable, and the 1-rank partitioned read degenerates to the
    // plain range read.
    let part = Partition::uniform(1, 4);
    assert_eq!(ar.read_range_partitioned("a", 0, 4, &part).unwrap(), slice_fixed(0, 4));
    ar.close().unwrap();
    std::fs::remove_file(&*path).unwrap();
}

/// Usage errors carry the documented codes and leave the archive
/// usable.
#[test]
fn range_read_errors_are_clean() {
    let path = Arc::new(tmp("errors"));
    build(&path);
    let mut ar = Archive::open(SerialComm::new(), &*path).unwrap();
    let oob = ar.read_range("a", N - 4, 10).unwrap_err();
    assert_eq!(oob.code(), 3000 + scda::error::usage::BAD_RANGE);
    let overflow = ar.read_range("a", u64::MAX, 2).unwrap_err();
    assert_eq!(overflow.code(), 3000 + scda::error::usage::BAD_RANGE);
    let wrong = ar.read_range("v", 0, 1).unwrap_err();
    assert_eq!(wrong.code(), 3000 + scda::error::usage::WRONG_SECTION);
    let wrong = ar.read_varray_range("a", 0, 1).unwrap_err();
    assert_eq!(wrong.code(), 3000 + scda::error::usage::WRONG_SECTION);
    let missing = ar.read_range("nope", 0, 1).unwrap_err();
    assert_eq!(missing.code(), 3000 + scda::error::usage::NO_SUCH_DATASET);
    // The archive stays usable after every failure.
    assert_eq!(ar.read_range("a", 0, 4).unwrap(), slice_fixed(0, 4));
    ar.close().unwrap();
    std::fs::remove_file(&*path).unwrap();
}
