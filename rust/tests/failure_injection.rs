//! Failure injection: corrupt scda files byte-by-byte and assert the
//! reader reports the right §A.6 error group (never panics, never
//! returns wrong data silently), plus call-sequence misuse checks.

use scda::api::{DataSrc, ScdaFile};
use scda::error::ScdaErrorKind;
use scda::par::{Partition, SerialComm};
use std::path::PathBuf;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-failures");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

/// A well-formed file with one of each section type (one encoded).
fn build_sample(path: &PathBuf) -> Vec<u8> {
    let mut f = ScdaFile::create(SerialComm::new(), path, b"victim").unwrap();
    f.write_inline(&[b'i'; 32], Some(b"inline")).unwrap();
    f.write_block(b"block data here", Some(b"block")).unwrap();
    let part = Partition::uniform(1, 4);
    f.write_array(DataSrc::Contiguous(&[7u8; 32]), &part, 8, Some(b"arr"), false).unwrap();
    f.write_block_from(0, Some(b"compress me".repeat(20).as_slice()), 220, Some(b"zb"), true).unwrap();
    f.close().unwrap();
    std::fs::read(path).unwrap()
}

fn read_all(path: &PathBuf) -> scda::Result<Vec<u8>> {
    let mut f = ScdaFile::open(SerialComm::new(), path)?;
    let mut out = Vec::new();
    // Header strings are data too (vendor/user are arbitrary bytes the
    // format carries verbatim) — include them in the digest so flips
    // there count as visible changes, not silent ones.
    out.extend_from_slice(f.header_vendor_string().unwrap_or(b""));
    out.extend_from_slice(f.header_user_string().unwrap_or(b""));
    while !f.at_end()? {
        let h = f.read_section_header(true)?;
        out.extend_from_slice(&h.user);
        use scda::format::section::SectionKind::*;
        match h.kind {
            Inline => out.extend_from_slice(&f.read_inline_data(0, true)?.unwrap()),
            Block => out.extend_from_slice(&f.read_block_data(0, true)?.unwrap()),
            Array => {
                let p = Partition::uniform(1, h.elem_count);
                out.extend_from_slice(&f.read_array_data(&p, h.elem_size, true)?.unwrap());
            }
            Varray => {
                let p = Partition::uniform(1, h.elem_count);
                let s = f.read_varray_sizes(&p)?;
                out.extend_from_slice(&f.read_varray_data(&p, &s, true)?.unwrap());
            }
        }
    }
    f.close()?;
    Ok(out)
}

#[test]
fn bitflip_sweep_never_panics_and_flags_corruption() {
    let path = tmp("sweep");
    let good = build_sample(&path);
    let baseline = read_all(&path).unwrap();
    // Flip a byte at a spread of positions covering header, section rows,
    // count entries, payloads and padding.
    let mut detected = 0usize;
    let mut silent_change = 0usize;
    let mut pad_only = 0usize;
    let positions: Vec<usize> = (0..good.len()).step_by(13).collect();
    for &pos in &positions {
        let mut bad = good.clone();
        bad[pos] ^= 0xff;
        std::fs::write(&path, &bad).unwrap();
        match read_all(&path) {
            Err(_) => detected += 1,
            Ok(data) => {
                if data != baseline {
                    // A flip inside raw payload bytes legitimately changes
                    // data without structural corruption.
                    silent_change += 1;
                } else {
                    // Unchanged data with a clean read can only be a flip
                    // inside padding, which the spec says readers ignore —
                    // but strict verification must still flag it.
                    assert!(scda::api::verify_bytes(&bad).is_err(), "flip at {pos} fully invisible");
                    pad_only += 1;
                }
            }
        }
    }
    // Structural corruption dominates in this layout: most flips must be
    // *detected*; every flip is detected, visible in the data, or caught
    // by strict verification (padding) — none is silently absorbed.
    assert!(detected * 2 > positions.len(), "only {detected}/{} flips detected", positions.len());
    assert_eq!(detected + silent_change + pad_only, positions.len());
    std::fs::write(&path, &good).unwrap();
    assert_eq!(read_all(&path).unwrap(), baseline);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn truncation_sweep_is_detected() {
    let path = tmp("trunc");
    let good = build_sample(&path);
    for cut in [0usize, 1, 64, 127, 200, good.len() - 40, good.len() - 1] {
        std::fs::write(&path, &good[..cut]).unwrap();
        let r = read_all(&path);
        assert!(r.is_err(), "truncation at {cut} not detected");
        assert_eq!(r.unwrap_err().kind(), ScdaErrorKind::CorruptFile, "cut {cut}");
    }
    // Exactly 128 bytes is a *valid* file: a header with zero sections
    // ("zero or more data sections", §2).
    std::fs::write(&path, &good[..128]).unwrap();
    // read_all digests the header strings; zero sections follow.
    assert_eq!(read_all(&path).unwrap(), b"scda-rs 0.1victim");
    assert_eq!(scda::api::verify_bytes(&good[..128]).unwrap(), 0);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn corrupted_compressed_payload_fails_checksum() {
    let path = tmp("zcorrupt");
    let good = build_sample(&path);
    // The encoded block is the last logical section; flip one byte of its
    // base64 payload (near the end, before final padding ~39 bytes).
    let mut bad = good.clone();
    let pos = good.len() - 60;
    bad[pos] = if bad[pos] == b'A' { b'B' } else { b'A' };
    std::fs::write(&path, &bad).unwrap();
    let err = read_all(&path).unwrap_err();
    assert_eq!(err.kind(), ScdaErrorKind::CorruptFile);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn wrong_magic_and_version() {
    let path = tmp("magic");
    let good = build_sample(&path);
    let mut bad = good.clone();
    bad[0] = b'x';
    std::fs::write(&path, &bad).unwrap();
    let err = ScdaFile::open(SerialComm::new(), &path).unwrap_err();
    assert_eq!(err.code(), 1000 + scda::error::corrupt::BAD_MAGIC);
    // Version below the defined range.
    let mut bad = good.clone();
    bad[5] = b'0';
    bad[6] = b'1';
    std::fs::write(&path, &bad).unwrap();
    let err = ScdaFile::open(SerialComm::new(), &path).unwrap_err();
    assert_eq!(err.code(), 1000 + scda::error::corrupt::BAD_VERSION);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn call_sequence_misuse_is_usage_error() {
    let path = tmp("misuse");
    build_sample(&path);
    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    // Data call before any header.
    let err = f.read_inline_data(0, true).unwrap_err();
    assert_eq!(err.kind(), ScdaErrorKind::Usage);
    // Header then mismatched data call.
    let h = f.read_section_header(false).unwrap();
    assert_eq!(h.user, b"inline");
    let err = f.read_block_data(0, true).unwrap_err();
    assert_eq!(err.kind(), ScdaErrorKind::Usage);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn partition_mismatch_is_usage_error() {
    let path = tmp("badpart");
    build_sample(&path);
    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    f.read_section_header(false).unwrap();
    f.skip_section_data().unwrap();
    f.read_section_header(false).unwrap();
    f.skip_section_data().unwrap();
    let h = f.read_section_header(false).unwrap();
    assert_eq!(h.elem_count, 4);
    // Partition sums to 5, not 4.
    let bad = Partition::uniform(1, 5);
    let err = f.read_array_data(&bad, 8, true).unwrap_err();
    assert_eq!(err.kind(), ScdaErrorKind::Usage);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn write_mode_misuse() {
    let path = tmp("wmode");
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"").unwrap();
    // Reading from a write-mode file.
    let err = f.read_section_header(false).unwrap_err();
    assert_eq!(err.kind(), ScdaErrorKind::Usage);
    // Inline data of the wrong length.
    let err = f.write_inline(b"short", None).unwrap_err();
    assert_eq!(err.code(), 3000 + scda::error::usage::INLINE_SIZE);
    // User string too long.
    let err = f.write_block_from(0, Some(b"x"), 1, Some(&[b'u'; 59]), false).unwrap_err();
    assert_eq!(err.kind(), ScdaErrorKind::Usage);
    f.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn missing_file_is_io_error() {
    let err = ScdaFile::open(SerialComm::new(), "/nonexistent/dir/f.scda").unwrap_err();
    assert_eq!(err.kind(), ScdaErrorKind::Io);
    assert!(scda::ferror_string(err.code()).is_some());
}
