//! The I/O aggregation contract (see `crate::io`): with aggregation on,
//! a representative A/V/B section sequence reaches the file in a small,
//! fixed number of writes per rank, and the file bytes are identical to
//! the unaggregated (direct) path at 1, 2 and 4 ranks. The syscall
//! counts come from the instrumented `ParallelFile` counters
//! (`ScdaFile::io_stats`).

use scda::api::{DataSrc, IoTuning, ScdaFile};
use scda::par::{run_parallel, Communicator, IoStats, Partition, SerialComm};
use std::path::PathBuf;
use std::sync::Arc;

const SECTIONS: usize = 4;
const ELEMS_TOTAL: usize = 64;
const ELEM_BYTES: usize = 48;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-io-coalescing");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

/// The representative workload: one inline, one block, one fixed array,
/// then `SECTIONS` varrays of small *indirect* elements (the per-element
/// write storm on the direct path). Returns per-rank syscall stats.
fn write_workload(path: &Arc<PathBuf>, ranks: usize, tuning: IoTuning) -> Vec<IoStats> {
    let path = Arc::clone(path);
    run_parallel(ranks, move |comm| {
        let rank = comm.rank();
        let part = Partition::uniform(ranks, ELEMS_TOTAL as u64);
        let local = part.count(rank) as usize;
        let first = part.offset(rank) as usize;
        let mut f = ScdaFile::create(comm, &**path, b"io-coalescing").unwrap();
        f.set_sync_on_close(false);
        f.set_io_tuning(tuning).unwrap();
        f.write_inline(&[b'i'; 32], Some(b"inline")).unwrap();
        let block: Vec<u8> = (0..500usize).map(|i| i as u8).collect();
        f.write_block_from(0, Some(&block), 500, Some(b"block"), false).unwrap();
        // A section: one contiguous local window per rank.
        let adata: Vec<u8> = (0..local * 8).map(|i| ((first * 8 + i) % 251) as u8).collect();
        f.write_array(DataSrc::Contiguous(&adata), &part, 8, Some(b"arr"), false).unwrap();
        // V sections: indirectly addressed small elements.
        let owned: Vec<Vec<u8>> = (0..local).map(|i| vec![((first + i) % 251) as u8; ELEM_BYTES]).collect();
        let views: Vec<&[u8]> = owned.iter().map(|e| e.as_slice()).collect();
        let sizes = vec![ELEM_BYTES as u64; local];
        for _ in 0..SECTIONS {
            f.write_varray(DataSrc::Indirect(&views), &part, &sizes, Some(b"var"), false).unwrap();
        }
        // Flush so the counters cover the whole file before snapshotting.
        f.flush().unwrap();
        let st = f.io_stats();
        f.close().unwrap();
        st
    })
}

#[test]
fn aggregated_writes_are_coalesced_and_byte_identical() {
    for ranks in [1usize, 2, 4] {
        let pa = Arc::new(tmp(&format!("agg-{ranks}")));
        let pd = Arc::new(tmp(&format!("dir-{ranks}")));
        let agg_stats = write_workload(&pa, ranks, IoTuning::default());
        let dir_stats = write_workload(&pd, ranks, IoTuning::direct());
        // Byte identity against the unaggregated path.
        let a = std::fs::read(&*pa).unwrap();
        let d = std::fs::read(&*pd).unwrap();
        assert_eq!(a, d, "aggregated file differs from direct at ranks={ranks}");
        scda::api::verify_bytes(&a).unwrap();
        // Coalescing: a fixed small number of writes per rank (each rank's
        // extents merge into at most a few runs per section), and >= 5x
        // fewer write syscalls in total than the direct path.
        let bound = (3 * SECTIONS + 8) as u64;
        for (r, st) in agg_stats.iter().enumerate() {
            assert!(st.write_calls <= bound, "rank {r}/{ranks}: {} writes > {bound}", st.write_calls);
        }
        let agg_total: u64 = agg_stats.iter().map(|s| s.write_calls).sum();
        let dir_total: u64 = dir_stats.iter().map(|s| s.write_calls).sum();
        assert!(
            dir_total >= 5 * agg_total,
            "ranks={ranks}: direct {dir_total} writes vs aggregated {agg_total} (< 5x)"
        );
        std::fs::remove_file(&*pa).unwrap();
        std::fs::remove_file(&*pd).unwrap();
    }
}

/// Read the whole workload back serially, returning every payload.
fn read_all(path: &Arc<PathBuf>, tuning: IoTuning) -> (Vec<Vec<u8>>, IoStats) {
    let path: &PathBuf = path;
    let mut f = ScdaFile::open(SerialComm::new(), path).unwrap();
    f.set_io_tuning(tuning).unwrap();
    let part = Partition::uniform(1, ELEMS_TOTAL as u64);
    let mut out = Vec::new();
    let h = f.read_section_header(false).unwrap();
    assert_eq!(h.user, b"inline");
    out.push(f.read_inline_data(0, true).unwrap().unwrap().to_vec());
    let h = f.read_section_header(false).unwrap();
    assert_eq!(h.user, b"block");
    out.push(f.read_block_data(0, true).unwrap().unwrap());
    let h = f.read_section_header(false).unwrap();
    assert_eq!(h.user, b"arr");
    out.push(f.read_array_data(&part, 8, true).unwrap().unwrap());
    for _ in 0..SECTIONS {
        let h = f.read_section_header(false).unwrap();
        assert_eq!(h.user, b"var");
        let sizes = f.read_varray_sizes(&part).unwrap();
        out.push(f.read_varray_data(&part, &sizes, true).unwrap().unwrap());
    }
    assert!(f.at_end().unwrap());
    let st = f.io_stats();
    f.close().unwrap();
    (out, st)
}

#[test]
fn read_sieve_matches_direct_and_reduces_syscalls() {
    let path = Arc::new(tmp("sieve"));
    write_workload(&path, 2, IoTuning::default());
    let (sieved, st_s) = read_all(&path, IoTuning::default());
    let (direct, st_d) = read_all(&path, IoTuning::direct());
    assert_eq!(sieved, direct);
    assert!(
        st_s.read_calls < st_d.read_calls,
        "sieved {} reads, direct {}",
        st_s.read_calls,
        st_d.read_calls
    );
    // The whole file fits one sieve window: a single pread serves it.
    assert!(st_s.read_calls <= 2, "{} reads through the sieve", st_s.read_calls);
    // Cached file length: exactly the one open-time fstat on either
    // path, never one per section.
    assert_eq!((st_s.stat_calls, st_d.stat_calls), (1, 1));
    std::fs::remove_file(&*path).unwrap();
}

#[test]
fn read_array_data_into_fills_caller_buffer() {
    let path = tmp("into");
    let n = 32u64;
    let elem = 16u64;
    let part = Partition::uniform(1, n);
    let data: Vec<u8> = (0..(n * elem) as usize).map(|i| (i % 253) as u8).collect();
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"into").unwrap();
    f.set_sync_on_close(false);
    f.write_array(DataSrc::Contiguous(&data), &part, elem, Some(b"raw"), false).unwrap();
    f.write_array(DataSrc::Contiguous(&data), &part, elem, Some(b"enc"), true).unwrap();
    f.close().unwrap();

    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    // Raw section straight into the caller's (reusable) buffer.
    let mut buf = vec![0u8; (n * elem) as usize];
    f.read_section_header(false).unwrap();
    f.read_array_data_into(&part, elem, &mut buf).unwrap();
    assert_eq!(buf, data);
    // Decoded section through the same API.
    buf.fill(0);
    let h = f.read_section_header(true).unwrap();
    assert!(h.decoded);
    f.read_array_data_into(&part, elem, &mut buf).unwrap();
    assert_eq!(buf, data);
    assert!(f.at_end().unwrap());
    // Wrong buffer size is a usage error.
    f.close().unwrap();
    let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
    f.read_section_header(false).unwrap();
    let mut short = vec![0u8; 8];
    assert_eq!(
        f.read_array_data_into(&part, elem, &mut short).unwrap_err().kind(),
        scda::ScdaErrorKind::Usage
    );
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn retuning_mid_write_keeps_bytes_identical() {
    // Flip aggregation off halfway through: bytes must match a file
    // written fully direct (the tuning is invisible in the bytes).
    let p1 = tmp("retune-a");
    let p2 = tmp("retune-b");
    let part = Partition::uniform(1, 8);
    let sizes = vec![5u64; 8];
    let payload: Vec<u8> = (0..40u8).collect();
    for (path, retune) in [(&p1, true), (&p2, false)] {
        let mut f = ScdaFile::create(SerialComm::new(), path, b"retune").unwrap();
        f.set_sync_on_close(false);
        if !retune {
            f.set_io_tuning(IoTuning::direct()).unwrap();
        }
        f.write_varray(DataSrc::Contiguous(&payload), &part, &sizes, Some(b"v1"), false).unwrap();
        if retune {
            f.set_io_tuning(IoTuning::direct()).unwrap();
        }
        f.write_varray(DataSrc::Contiguous(&payload), &part, &sizes, Some(b"v2"), false).unwrap();
        f.close().unwrap();
    }
    assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());
    std::fs::remove_file(&p1).unwrap();
    std::fs::remove_file(&p2).unwrap();
}
