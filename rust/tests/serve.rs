//! Concurrent read-service integration tests: N client sessions over
//! one [`ArchiveReadService`] must serve byte-identical answers to
//! direct `Archive::read_range` calls — overlapping and disjoint
//! request mixes, budgets small enough to force eviction — while
//! concurrent misses on one hot page collapse to a single `pread` and
//! adaptive-window state stays private to each session.

use scda::api::{DataSrc, IoTuning};
use scda::archive::Archive;
use scda::par::{CodecPool, Partition, SerialComm};
use scda::runtime::{ArchiveReadService, ReadRequest, ReadResponse, ReadServiceConfig};
use std::path::PathBuf;
use std::sync::{Arc, Barrier};

const N: u64 = 4096;
const E: u64 = 16;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("scda-serve");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{name}-{}.scda", std::process::id()))
}

fn array_payload() -> Vec<u8> {
    (0..N * E).map(|i| ((i * 13) % 251) as u8).collect()
}

fn varray_payload() -> (Vec<u64>, Vec<u8>) {
    let sizes: Vec<u64> = (0..N).map(|i| i % 7 + 1).collect();
    let mut data = Vec::new();
    for (i, &s) in sizes.iter().enumerate() {
        for j in 0..s {
            data.push(((i as u64 * 5 + j) % 251) as u8);
        }
    }
    (sizes, data)
}

/// One serial writer: a raw array, an encoded array and a varray —
/// every range-addressable shape the service dispatches on.
fn build(path: &PathBuf) {
    let part = Partition::uniform(1, N);
    let a = array_payload();
    let (vsizes, vdata) = varray_payload();
    let mut ar = Archive::create(SerialComm::new(), path, b"serve-test").unwrap();
    ar.file_mut().set_sync_on_close(false);
    ar.write_array("a", DataSrc::Contiguous(&a), &part, E, false).unwrap();
    ar.write_array("az", DataSrc::Contiguous(&a), &part, E, true).unwrap();
    ar.write_varray("v", DataSrc::Contiguous(&vdata), &part, &vsizes, false).unwrap();
    ar.finish().unwrap();
}

/// Direct (service-free) answer for one request.
fn direct(ar: &mut Archive<SerialComm>, req: &ReadRequest) -> ReadResponse {
    if req.dataset == "v" {
        let (sizes, data) = ar.read_varray_range(&req.dataset, req.first, req.count).unwrap();
        ReadResponse::Varray { sizes, data }
    } else {
        ReadResponse::Array(ar.read_range(&req.dataset, req.first, req.count).unwrap())
    }
}

#[test]
fn served_ranges_match_direct_reads_across_sessions() {
    let path = tmp("identity");
    build(&path);

    // Overlapping mix: every session serves this same list. Disjoint
    // mix: session s gets its own stripe of each dataset.
    let overlap: Vec<ReadRequest> = vec![
        ReadRequest { dataset: "a".into(), first: 100, count: 32 },
        ReadRequest { dataset: "az".into(), first: 100, count: 32 },
        ReadRequest { dataset: "v".into(), first: 7, count: 21 },
        ReadRequest { dataset: "a".into(), first: N - 40, count: 40 },
        ReadRequest { dataset: "az".into(), first: 0, count: 1 },
    ];
    let mut dar = Archive::open(SerialComm::new(), &path).unwrap();
    let overlap_want: Vec<ReadResponse> = overlap.iter().map(|r| direct(&mut dar, r)).collect();

    for sessions in [1usize, 2, 4, 8] {
        let stripe = N / sessions as u64;
        let lists: Vec<Vec<ReadRequest>> = (0..sessions as u64)
            .map(|s| {
                let mut l = overlap.clone();
                for ds in ["a", "az", "v"] {
                    l.push(ReadRequest {
                        dataset: ds.into(),
                        first: s * stripe,
                        count: stripe.min(64),
                    });
                }
                l
            })
            .collect();
        let want: Vec<Vec<ReadResponse>> =
            lists.iter().map(|l| l.iter().map(|r| direct(&mut dar, r)).collect()).collect();

        // 4 KiB pages under a 16 KiB budget: far smaller than the
        // archive, so serving must evict and refill correctly.
        let cfg = ReadServiceConfig {
            tuning: IoTuning::default(),
            page_bytes: 4 << 10,
            cache_budget: 16 << 10,
            ..Default::default()
        };
        let svc = ArchiveReadService::open_with(&path, cfg).unwrap();
        let workers: Vec<_> =
            lists.iter().map(|l| (svc.session().unwrap(), l.as_slice())).collect();
        let got: Vec<Vec<ReadResponse>> = std::thread::scope(|sc| {
            let handles: Vec<_> = workers
                .into_iter()
                .map(|(mut sess, list)| {
                    sc.spawn(move || {
                        list.iter().map(|r| sess.serve(r).unwrap()).collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (s, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(&g[..overlap.len()], &overlap_want[..], "{sessions} sessions, session {s}, overlapping mix");
            assert_eq!(g, w, "{sessions} sessions, session {s}");
        }
        let st = svc.cache_stats().unwrap();
        assert!(st.evictions > 0, "16 KiB budget over a bigger archive must evict: {st:?}");
        assert!(
            st.resident_bytes <= 16 << 10,
            "resident {} exceeds budget",
            st.resident_bytes
        );
        assert_eq!(svc.sessions_opened(), sessions as u64);
    }
    dar.close().unwrap();
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn concurrent_sessions_hot_page_fills_once() {
    // An archive smaller than one default cache page: every byte of it
    // lives on page 0, so *all* concurrent serving across 8 sessions
    // must boil down to exactly one fill pread.
    let path = tmp("hot");
    let n = 512u64;
    let part = Partition::uniform(1, n);
    let data: Vec<u8> = (0..n * 8).map(|i| ((i * 3) % 251) as u8).collect();
    let mut ar = Archive::create(SerialComm::new(), &path, b"hot").unwrap();
    ar.file_mut().set_sync_on_close(false);
    ar.write_array("t", DataSrc::Contiguous(&data), &part, 8, false).unwrap();
    ar.finish().unwrap();

    let svc = ArchiveReadService::open(&path).unwrap();
    let preads0 = svc.io_stats().read_calls;
    let req = ReadRequest { dataset: "t".into(), first: 40, count: 16 };
    let sessions: Vec<_> = (0..8).map(|_| svc.session().unwrap()).collect();
    let barrier = Arc::new(Barrier::new(sessions.len()));
    let want = ReadResponse::Array(data[40 * 8..56 * 8].to_vec());
    std::thread::scope(|sc| {
        for mut sess in sessions {
            let barrier = Arc::clone(&barrier);
            let req = req.clone();
            let want = want.clone();
            sc.spawn(move || {
                barrier.wait();
                assert_eq!(sess.serve(&req).unwrap(), want);
            });
        }
    });
    let st = svc.cache_stats().unwrap();
    assert_eq!(
        svc.io_stats().read_calls - preads0,
        1,
        "8 sessions, one page: one pread ({st:?})"
    );
    assert_eq!(st.misses, 1, "only the first toucher misses: {st:?}");
    assert_eq!(st.fill_preads, 1, "{st:?}");
    assert!(
        st.hits + st.single_flight_waits >= 7,
        "the other sessions hit or waited: {st:?}"
    );
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn session_window_adaptivity_stays_private() {
    let path = tmp("adapt");
    build(&path);
    // Every `serve` re-reads the dataset's section header, so a session
    // whose *payloads* sit far from the header alternates
    // header <-> payload refills — a jump streak that shrinks its
    // window. A session whose requests fit in the header's own window
    // never refills again. The shrink must stay private to the jumpy
    // session.
    let mut tuning = IoTuning::default();
    tuning.sieve_window = 16 << 10;
    let cfg = ReadServiceConfig {
        tuning,
        page_bytes: 4 << 10,
        cache_budget: 1 << 20,
        ..Default::default()
    };
    let svc = ArchiveReadService::open_with(&path, cfg).unwrap();

    let mut jumpy = svc.session().unwrap();
    let mut local = svc.session().unwrap();
    // Payload offsets ~32-57 KiB into "a": far beyond the 16 KiB window
    // that buffered the section header, so each serve jumps twice.
    for first in [3500u64, 3600, 3000, 2000, 3900] {
        jumpy.serve(&ReadRequest { dataset: "a".into(), first, count: 2 }).unwrap();
    }
    // Header and first payload bytes share one window: one refill ever.
    for _ in 0..5 {
        local.serve(&ReadRequest { dataset: "a".into(), first: 0, count: 4 }).unwrap();
    }
    let jumpy_st = jumpy.archive().file().engine_stats();
    let local_st = local.archive().file().engine_stats();
    assert!(jumpy_st.sieve_shrinks >= 1, "jumpy session shrank its window: {jumpy_st:?}");
    assert_eq!(local_st.sieve_shrinks, 0, "local session kept its window: {local_st:?}");
    assert_eq!(local_st.sieve_grows, 0, "{local_st:?}");
    // Both routed through the one shared pool — and the local session's
    // header page was already resident from the jumpy session's serves.
    assert!(jumpy_st.cache_misses + jumpy_st.cache_hits > 0, "{jumpy_st:?}");
    assert!(local_st.cache_hits > 0, "local refill lands on shared pages: {local_st:?}");
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn private_flush_pool_writes_identical_bytes() {
    // Satellite: async flush draining through a per-file codec pool
    // must produce the same bytes as the shared-pool (and sync) paths.
    let part = Partition::uniform(1, N);
    let a = array_payload();
    let write = |path: &PathBuf, pool: bool| {
        let mut ar = Archive::create(SerialComm::new(), path, b"pool-test").unwrap();
        ar.file_mut().set_sync_on_close(false);
        ar.file_mut().set_io_tuning(IoTuning::default().with_async_flush(pool)).unwrap();
        if pool {
            ar.file_mut().set_flush_pool(Some(Arc::new(CodecPool::new(2)))).unwrap();
        }
        ar.write_array("a", DataSrc::Contiguous(&a), &part, E, true).unwrap();
        ar.write_array("b", DataSrc::Contiguous(&a), &part, E, false).unwrap();
        ar.finish().unwrap();
    };
    let sync_path = tmp("pool-sync");
    let pool_path = tmp("pool-async");
    write(&sync_path, false);
    write(&pool_path, true);
    let sync_bytes = std::fs::read(&sync_path).unwrap();
    let pool_bytes = std::fs::read(&pool_path).unwrap();
    assert_eq!(sync_bytes, pool_bytes, "private flush pool changed the bytes");
    // And the result still serves.
    let svc = ArchiveReadService::open(&pool_path).unwrap();
    let mut s = svc.session().unwrap();
    let got = s.serve(&ReadRequest { dataset: "b".into(), first: 3, count: 5 }).unwrap();
    assert_eq!(got, ReadResponse::Array(a[3 * E as usize..8 * E as usize].to_vec()));
    std::fs::remove_file(&sync_path).unwrap();
    std::fs::remove_file(&pool_path).unwrap();
}
