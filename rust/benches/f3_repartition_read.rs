//! F3 — read-side repartition freedom (§A.5): write once on P_w = 4,
//! read on P_r ∈ {1..8} with uniform, random, and byte-balanced
//! partitions, including partial (NULL-skipping) readers. Reports read
//! bandwidth and verifies reassembly for every configuration.

use scda::api::{DataSrc, ScdaFile};
use scda::bench_support::{measure, Table};
use scda::coordinator::by_bytes;
use scda::par::{run_parallel, Communicator, Partition};
use scda::testutil::Rng;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let quick = scda::bench_support::quick();
    let n: u64 = if quick { 1 << 12 } else { 1 << 14 };
    let mut rng = Rng::new(0xF3);
    let sizes: Arc<Vec<u64>> = Arc::new((0..n).map(|_| rng.range(16, 4096)).collect());
    let total: u64 = sizes.iter().sum();
    let data: Arc<Vec<u8>> = Arc::new(rng.bytes(total as usize, 64));
    println!("F3: V-section of {n} elements, {:.1} MiB, written on 4 ranks\n", total as f64 / 1048576.0);

    // Write once.
    let path = Arc::new(std::env::temp_dir().join("scda-f3.scda"));
    {
        let (path, sizes, data) = (Arc::clone(&path), Arc::clone(&sizes), Arc::clone(&data));
        run_parallel(4, move |comm| {
            let part = Partition::uniform(4, n);
            let r = part.local_range(comm.rank());
            let ls = &sizes[r.start as usize..r.end as usize];
            let lo: u64 = sizes[..r.start as usize].iter().sum();
            let len: u64 = ls.iter().sum();
            let mut f = ScdaFile::create(comm, &*path, b"f3").unwrap();
            f.write_varray(DataSrc::Contiguous(&data[lo as usize..(lo + len) as usize]), &part, ls, Some(b"v"), false)
                .unwrap();
            f.close().unwrap();
        });
    }

    let mut table = Table::new(&["P_r", "partition", "read MiB/s", "skip ranks", "reassembly"]);
    for p in 1..=8usize {
        for (pname, part) in [
            ("uniform", Partition::uniform(p, n)),
            ("random", Partition::from_counts(&rng.partition(n, p))),
            ("byte-balanced", by_bytes(&sizes, p)),
        ] {
            let part = Arc::new(part);
            let reps = if quick { 2 } else { 3 };
            let (path2, part2) = (Arc::clone(&path), Arc::clone(&part));
            let s = measure(1, reps, move || {
                let (path3, part3) = (Arc::clone(&path2), Arc::clone(&part2));
                run_parallel(p, move |comm| {
                    let mut f = ScdaFile::open(comm, &*path3).unwrap();
                    f.read_section_header(false).unwrap();
                    let ls = f.read_varray_sizes(&part3).unwrap();
                    let _ = f.read_varray_data(&part3, &ls, true).unwrap();
                    f.close().unwrap();
                });
            });
            // Verification pass (with one skipping rank when P_r > 2).
            let skip_rank = if p > 2 { Some(p - 1) } else { None };
            let (path2, part2, sizes2, data2) =
                (Arc::clone(&path), Arc::clone(&part), Arc::clone(&sizes), Arc::clone(&data));
            let t0 = Instant::now();
            let pieces = run_parallel(p, move |comm| {
                let rank = comm.rank();
                let mut f = ScdaFile::open(comm, &*path2).unwrap();
                f.read_section_header(false).unwrap();
                let ls = f.read_varray_sizes(&part2).unwrap();
                let r = part2.local_range(rank);
                assert_eq!(ls, &sizes2[r.start as usize..r.end as usize]);
                let want = Some(rank) != skip_rank;
                let out = f.read_varray_data(&part2, &ls, want).unwrap();
                f.close().unwrap();
                if want {
                    let lo: u64 = sizes2[..r.start as usize].iter().sum();
                    let len: u64 = ls.iter().sum();
                    assert_eq!(out.as_deref().unwrap(), &data2[lo as usize..(lo + len) as usize]);
                }
                out.unwrap_or_default()
            });
            let _ = (pieces, t0);
            table.row(&[
                p.to_string(),
                pname.to_string(),
                format!("{:.0}", s.mib_per_s(total)),
                skip_rank.map(|r| r.to_string()).unwrap_or_else(|| "-".into()),
                "OK".into(),
            ]);
        }
    }
    table.print();
    std::fs::remove_file(&*path).unwrap();
    println!("\nF3 RESULT: every reading partition reconstructs identical bytes; skipping ranks compose.");
}
