//! A1 — ablations over the design choices DESIGN.md calls out:
//!
//! a) deflate level sweep (the convention permits "any legal compression
//!    level" — where is the ratio/speed knee on checkpoint data?);
//! b) write aggregation (WriteCoalescer) on small-write workloads, vs
//!    direct pwrites (the V-section row pattern);
//! c) preconditioner tile locality: TILE-local delta (our choice, which
//!    buys chunking invariance and parallel decode) vs a hypothetical
//!    global delta — measuring the ratio cost of the tile seams.

use scda::bench_support::{corpus, measure, Table};
use scda::codec::zlib_compress;
use scda::coordinator::WriteCoalescer;
use scda::par::{Communicator, ParallelFile, SerialComm};
use scda::runtime::native_forward;

fn main() {
    let quick = scda::bench_support::quick();
    let len = if quick { 1 << 20 } else { 4 << 20 };

    // ---- a) level sweep ---------------------------------------------------
    println!("A1a: deflate level sweep on the AMR corpus ({} MiB, shuffled)\n", len >> 20);
    let amr = corpus(len).remove(3).1;
    let (shuffled, _) = scda::runtime::Preconditioner::native().forward(&amr).unwrap();
    let mut table = Table::new(&["level", "ratio", "MiB/s"]);
    for level in [0u8, 1, 3, 6, 9] {
        let d = shuffled.clone();
        let s = measure(1, if quick { 2 } else { 3 }, move || {
            std::hint::black_box(zlib_compress(&d, level).len());
        });
        let ratio = zlib_compress(&shuffled, level).len() as f64 / shuffled.len() as f64;
        table.row(&[level.to_string(), format!("{ratio:.3}"), format!("{:.0}", s.mib_per_s(len as u64))]);
    }
    table.print();
    println!("\nA1a: on shuffled checkpoint data the ratio saturates at low levels — level 1 gives the");
    println!("same ratio several times faster; default stays 9 (the paper recommends best compression),");
    println!("but the coordinator exposes set_level() and this table is the tuning guide.\n");

    // ---- b) write coalescing on the V-row pattern --------------------------
    println!("A1b: 32 B count-row writes (V-section header pattern), coalesced vs direct\n");
    let dir = std::env::temp_dir().join("scda-a1");
    std::fs::create_dir_all(&dir).unwrap();
    let rows = if quick { 20_000u64 } else { 100_000 };
    let comm = SerialComm::new();
    assert_eq!(comm.size(), 1);
    let mut table = Table::new(&["strategy", "rows", "secs", "write syscalls (<=)"]);
    {
        let path = dir.join(format!("direct-{}", std::process::id()));
        let f = ParallelFile::create(&comm, &path).unwrap();
        let row = [b'E'; 32];
        let s = measure(0, 1, || {
            for i in 0..rows {
                f.write_at(i * 32, &row).unwrap();
            }
        });
        table.row(&["direct pwrite".into(), rows.to_string(), format!("{:.3}", s.median), rows.to_string()]);
        std::fs::remove_file(&path).unwrap();
    }
    {
        let path = dir.join(format!("coal-{}", std::process::id()));
        let f = ParallelFile::create(&comm, &path).unwrap();
        let row = [b'E'; 32];
        let mut flushes = 0;
        let s = measure(0, 1, || {
            let mut co = WriteCoalescer::new(&f);
            for i in 0..rows {
                co.write_at(i * 32, &row).unwrap();
            }
            co.flush().unwrap();
            flushes = co.flushes;
        });
        table.row(&["coalesced".into(), rows.to_string(), format!("{:.3}", s.median), flushes.to_string()]);
        std::fs::remove_file(&path).unwrap();
    }
    table.print();
    println!("\nA1b: aggregation collapses the row stream to O(bytes/8MiB) syscalls — the MPI-IO");
    println!("collective-buffering effect; the API writer already batches rows, so this is the");
    println!("bound for adversarial small-write users.\n");

    // ---- c) tile-local vs global delta -------------------------------------
    println!("A1c: ratio cost of tile-local delta seams (TILE = 2048 u32)\n");
    let words: Vec<u32> = amr
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect();
    // Our transform (tile-local).
    let (tile_local, _) = native_forward(&words);
    // Hypothetical global delta (single scan, no seams) + same plane split.
    let mut global = vec![0u8; 4 * words.len()];
    {
        let n = words.len();
        let mut prev = 0u32;
        for (i, &v) in words.iter().enumerate() {
            let d = v ^ prev;
            prev = v;
            global[i] = d as u8;
            global[n + i] = (d >> 8) as u8;
            global[2 * n + i] = (d >> 16) as u8;
            global[3 * n + i] = (d >> 24) as u8;
        }
    }
    let r_tile = zlib_compress(&tile_local, 6).len() as f64 / amr.len() as f64;
    let r_global = zlib_compress(&global, 6).len() as f64 / amr.len() as f64;
    let mut table = Table::new(&["variant", "ratio", "parallel-decodable"]);
    table.row(&["tile-local (ours)".into(), format!("{r_tile:.4}"), "yes (per 8 KiB tile)".into()]);
    table.row(&["global delta".into(), format!("{r_global:.4}"), "no (serial scan)".into()]);
    table.print();
    println!(
        "\nA1c: seams cost {:.2}% ratio — the price of chunking invariance and parallel decode.",
        (r_tile / r_global - 1.0) * 100.0
    );
}
