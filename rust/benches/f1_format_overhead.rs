//! F1 — format overhead curve: file bytes vs payload bytes for each
//! section type across payload sizes (§2.1's padding plus headers).
//! The format's overhead is deterministic; this bench *computes and
//! verifies* it against real files.

use scda::api::{DataSrc, ScdaFile};
use scda::bench_support::Table;
use scda::par::{Partition, SerialComm};

fn file_len_with(payload: usize, write: impl FnOnce(&mut ScdaFile<SerialComm>, &[u8])) -> u64 {
    let path = std::env::temp_dir().join(format!("scda-f1-{payload}-{}.scda", std::process::id()));
    let data = vec![0x42u8; payload];
    let mut f = ScdaFile::create(SerialComm::new(), &path, b"f1").unwrap();
    write(&mut f, &data);
    f.close().unwrap();
    let len = std::fs::metadata(&path).unwrap().len() - 128; // exclude file header
    std::fs::remove_file(&path).unwrap();
    len
}

fn main() {
    println!("F1: section bytes in file vs payload bytes (128 B file header excluded)\n");
    let mut table = Table::new(&["payload B", "B-section", "A-section (64 B elems)", "V-section (64 B elems)", "overhead%% (B)"]);
    for payload in [0usize, 1, 32, 100, 1024, 65536, 1 << 20] {
        let b = file_len_with(payload, |f, d| {
            f.write_block(d, Some(b"x")).unwrap();
        });
        let elems = payload.div_ceil(64) as u64;
        let a = file_len_with(payload.div_ceil(64) * 64, |f, d| {
            let part = Partition::uniform(1, elems);
            f.write_array(DataSrc::Contiguous(d), &part, 64, Some(b"x"), false).unwrap();
        });
        let v = file_len_with(payload.div_ceil(64) * 64, |f, d| {
            let part = Partition::uniform(1, elems);
            let sizes = vec![64u64; elems as usize];
            f.write_varray(DataSrc::Contiguous(d), &part, &sizes, Some(b"x"), false).unwrap();
        });
        table.row(&[
            payload.to_string(),
            b.to_string(),
            a.to_string(),
            v.to_string(),
            format!("{:.2}", if payload > 0 { (b as f64 / payload as f64 - 1.0) * 100.0 } else { f64::INFINITY }),
        ]);
    }
    table.print();
    println!("\nF1 shape check: B overhead = 96 B header + <=38 B padding (flat);");
    println!("A adds one 32 B count row; V adds 32 B per element (the metadata cost of variable sizes).");

    // Verify the closed-form total_len model against the real files.
    use scda::format::section::SectionMeta;
    for payload in [0u128, 1, 100, 65536] {
        let model = SectionMeta::block("x", payload).total_len(None);
        let real = file_len_with(payload as usize, |f, d| f.write_block(d, Some(b"x")).unwrap());
        assert_eq!(model as u64, real, "model mismatch at {payload}");
    }
    println!("closed-form size model verified against real files.");

    // --- encoded-section throughput (the codec pipeline's hot path) ---
    // Quick numbers here so a single f1 run records the codec trajectory;
    // t4 measures the same shape at full size.
    let t = scda::bench_support::codec_bench::run_quick();
    println!(
        "\nF1 codec pipeline quick check ({} MiB, {} lanes): encoded write {:.0} -> {:.0} MiB/s ({:.2}x), read {:.0} -> {:.0} MiB/s ({:.2}x)",
        t.payload_bytes >> 20,
        t.lanes,
        t.write_serial,
        t.write_pooled,
        t.write_speedup(),
        t.read_serial,
        t.read_pooled,
        t.read_speedup(),
    );
    let json = scda::bench_support::bench_json_path();
    t.report().write(&json).unwrap();
    println!("wrote {}", json.display());

    // --- raw I/O syscall shape (write aggregation + read sieving) ---
    let io = scda::bench_support::io_bench::run_quick();
    println!(
        "\nF1 I/O aggregation quick check ({} MiB, {} ranks, {} sections): write {:.0} -> {:.0} MiB/s, \
         {} -> {} write syscalls ({:.0}x fewer); read {:.0} -> {:.0} MiB/s, {} -> {} read syscalls",
        io.payload_bytes >> 20,
        io.ranks,
        io.sections,
        io.write_direct_mib_s,
        io.write_agg_mib_s,
        io.write_calls_direct,
        io.write_calls_agg,
        io.write_syscall_reduction(),
        io.read_direct_mib_s,
        io.read_sieved_mib_s,
        io.read_calls_direct,
        io.read_calls_sieved,
    );
    println!("\nF1 engine sweep (write side):");
    for e in &io.engines {
        println!(
            "  {:>17}: {:>7.0} MiB/s, {:>5} write syscalls, {:>8} B shipped",
            e.name, e.write_mib_s, e.write_calls, e.shipped_bytes
        );
    }
    let io_json = scda::bench_support::bench_io_json_path();
    io.report().write(&io_json).unwrap();
    println!("wrote {}", io_json.display());
}
