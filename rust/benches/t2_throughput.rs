//! T2 — parallel single-file throughput vs process count, against the
//! file-per-rank baseline (the pattern scda's single-file design
//! replaces). Reports write and read bandwidth per P for a fixed total
//! payload; the paper's claim is that one partition-independent file
//! costs ~nothing over P private files on the same storage.

use scda::api::{DataSrc, ScdaFile};
use scda::bench_support::{measure, Table};
use scda::par::{run_parallel, Communicator, Partition};
use std::sync::Arc;

fn main() {
    let quick = scda::bench_support::quick();
    let total_bytes: u64 = if quick { 16 << 20 } else { 256 << 20 };
    let elem = 64u64 * 1024;
    let n = total_bytes / elem;
    let reps = if quick { 2 } else { 3 };
    println!("T2: {} MiB total, {} elements x {} KiB, {} reps (median)\n", total_bytes >> 20, n, elem >> 10, reps);

    let payload: Arc<Vec<u8>> = Arc::new(vec![0xA5u8; total_bytes as usize]);
    let dir = std::env::temp_dir().join("scda-t2");
    std::fs::create_dir_all(&dir).unwrap();

    let mut table = Table::new(&[
        "P",
        "scda write MiB/s",
        "scda +fsync MiB/s",
        "scda read MiB/s",
        "enc write MiB/s",
        "enc read MiB/s",
        "file-per-rank write MiB/s",
        "files",
    ]);
    for p in [1usize, 2, 4, 8, 16] {
        let part = Arc::new(Partition::uniform(p, n));
        // --- scda single-file write ---
        let path = Arc::new(dir.join(format!("t2-{p}.scda")));
        let w = {
            let (path, payload, part) = (Arc::clone(&path), Arc::clone(&payload), Arc::clone(&part));
            measure(1, reps, move || {
                let (path, payload, part) = (Arc::clone(&path), Arc::clone(&payload), Arc::clone(&part));
                run_parallel(p, move |comm| {
                    let r = part.local_range(comm.rank());
                    let local = &payload[(r.start * elem) as usize..(r.end * elem) as usize];
                    let mut f = ScdaFile::create(comm, &*path, b"t2").unwrap();
                    // The file-per-rank baseline (std::fs::write) does not
                    // fsync; match its durability for a fair comparison.
                    f.set_sync_on_close(false);
                    f.write_array(DataSrc::Contiguous(local), &part, elem, Some(b"payload"), false).unwrap();
                    f.close().unwrap();
                });
            })
        };
        // --- scda durable write (fsync on close) ---
        let wd = {
            let (path, payload, part) = (Arc::clone(&path), Arc::clone(&payload), Arc::clone(&part));
            measure(1, reps, move || {
                let (path, payload, part) = (Arc::clone(&path), Arc::clone(&payload), Arc::clone(&part));
                run_parallel(p, move |comm| {
                    let r = part.local_range(comm.rank());
                    let local = &payload[(r.start * elem) as usize..(r.end * elem) as usize];
                    let mut f = ScdaFile::create(comm, &*path, b"t2").unwrap();
                    f.write_array(DataSrc::Contiguous(local), &part, elem, Some(b"payload"), false).unwrap();
                    f.close().unwrap();
                });
            })
        };
        // --- scda read ---
        let r = {
            let (path, part) = (Arc::clone(&path), Arc::clone(&part));
            measure(1, reps, move || {
                let (path, part) = (Arc::clone(&path), Arc::clone(&part));
                run_parallel(p, move |comm| {
                    let mut f = ScdaFile::open(comm, &*path).unwrap();
                    f.read_section_header(false).unwrap();
                    let _ = f.read_array_data(&part, elem, true).unwrap();
                    f.close().unwrap();
                });
            })
        };
        std::fs::remove_file(&*path).ok();
        // --- encoded write/read: the per-element codec pipeline on every
        // rank (each rank fans its elements out to the shared pool) ---
        let epath = Arc::new(dir.join(format!("t2-enc-{p}.scda")));
        let we = {
            let (epath, payload, part) = (Arc::clone(&epath), Arc::clone(&payload), Arc::clone(&part));
            measure(1, reps, move || {
                let (epath, payload, part) = (Arc::clone(&epath), Arc::clone(&payload), Arc::clone(&part));
                run_parallel(p, move |comm| {
                    let r = part.local_range(comm.rank());
                    let local = &payload[(r.start * elem) as usize..(r.end * elem) as usize];
                    let mut f = ScdaFile::create(comm, &*epath, b"t2").unwrap();
                    f.set_sync_on_close(false);
                    f.write_array(DataSrc::Contiguous(local), &part, elem, Some(b"payload"), true).unwrap();
                    f.close().unwrap();
                });
            })
        };
        let re = {
            let (epath, part) = (Arc::clone(&epath), Arc::clone(&part));
            measure(1, reps, move || {
                let (epath, part) = (Arc::clone(&epath), Arc::clone(&part));
                run_parallel(p, move |comm| {
                    let mut f = ScdaFile::open(comm, &*epath).unwrap();
                    let h = f.read_section_header(true).unwrap();
                    assert!(h.decoded);
                    let _ = f.read_array_data(&part, elem, true).unwrap();
                    f.close().unwrap();
                });
            })
        };
        std::fs::remove_file(&*epath).ok();
        // --- baseline: one private file per rank (not serial-equivalent,
        // not partition-independent; P files to manage downstream) ---
        let dirb = dir.clone();
        let payload2 = Arc::clone(&payload);
        let part2 = Arc::clone(&part);
        let b = measure(1, reps, move || {
            let (dirb, payload2, part2) = (dirb.clone(), Arc::clone(&payload2), Arc::clone(&part2));
            run_parallel(p, move |comm| {
                let rank = comm.rank();
                let r = part2.local_range(rank);
                let local = &payload2[(r.start * elem) as usize..(r.end * elem) as usize];
                std::fs::write(dirb.join(format!("t2-baseline-{rank}.bin")), local).unwrap();
            });
        });
        for rank in 0..p {
            std::fs::remove_file(dir.join(format!("t2-baseline-{rank}.bin"))).ok();
        }
        table.row(&[
            p.to_string(),
            format!("{:.0}", w.mib_per_s(total_bytes)),
            format!("{:.0}", wd.mib_per_s(total_bytes)),
            format!("{:.0}", r.mib_per_s(total_bytes)),
            format!("{:.0}", we.mib_per_s(total_bytes)),
            format!("{:.0}", re.mib_per_s(total_bytes)),
            format!("{:.0}", b.mib_per_s(total_bytes)),
            format!("1 vs {p}"),
        ]);
    }
    table.print();
    println!("\nT2 note: identical storage substrate; scda additionally guarantees one partition-independent file.");

    // --- small-element varray I/O: where write aggregation pays ---
    // The table above writes one huge contiguous A window per rank (already
    // ~one syscall); the aggregation win is on metadata-interleaved
    // sections with small indirect elements. Full-size comparison here,
    // recorded to BENCH_io.json.
    let (sections, elems, ebytes, ioreps) = if quick { (8, 128, 4 << 10, 2) } else { (16, 512, 8 << 10, 3) };
    let mut iot = Table::new(&[
        "P",
        "direct write MiB/s",
        "agg write MiB/s",
        "direct read MiB/s",
        "sieved read MiB/s",
        "write syscalls direct/agg",
        "read syscalls direct/sieved",
    ]);
    let mut last = None;
    for p in [1usize, 4] {
        let io = scda::bench_support::io_bench::run(p, sections, elems, ebytes, ioreps);
        iot.row(&[
            p.to_string(),
            format!("{:.0}", io.write_direct_mib_s),
            format!("{:.0}", io.write_agg_mib_s),
            format!("{:.0}", io.read_direct_mib_s),
            format!("{:.0}", io.read_sieved_mib_s),
            format!("{}/{} ({:.0}x)", io.write_calls_direct, io.write_calls_agg, io.write_syscall_reduction()),
            format!("{}/{} ({:.0}x)", io.read_calls_direct, io.read_calls_sieved, io.read_syscall_reduction()),
        ]);
        last = Some(io);
    }
    println!("\nT2b: {sections} varray sections of {elems} x {} KiB indirect elements per rank\n", ebytes >> 10);
    iot.print();
    if let Some(io) = last {
        let mut et = Table::new(&["engine", "write MiB/s", "write syscalls", "shipped MiB"]);
        for e in &io.engines {
            et.row(&[
                e.name.clone(),
                format!("{:.0}", e.write_mib_s),
                e.write_calls.to_string(),
                format!("{:.2}", e.shipped_bytes as f64 / (1024.0 * 1024.0)),
            ]);
        }
        println!("\nT2c: engine sweep at P=4 (direct / aggregated / collective, sync and async)\n");
        et.print();
        let mut rt = Table::new(&["engine", "read MiB/s", "read syscalls", "gathered MiB", "gather preads"]);
        for e in &io.read_engines {
            rt.row(&[
                e.name.clone(),
                format!("{:.0}", e.read_mib_s),
                e.read_calls.to_string(),
                format!("{:.2}", e.gathered_bytes as f64 / (1024.0 * 1024.0)),
                e.gather_preads.to_string(),
            ]);
        }
        println!("\nT2d: read-side engine sweep (direct / sieved / collective gather)\n");
        rt.print();
        let io_json = scda::bench_support::bench_io_json_path();
        io.report().write(&io_json).unwrap();
        println!("\nwrote {}", io_json.display());
    }
}
