//! T1 — serial-equivalence at scale (DESIGN.md §Experiments).
//!
//! Writes the same mixed-section workload in serial and under every
//! process count P ∈ {1,2,3,4,7,8,16,32} with randomized partitions,
//! SHA-256s each file, and reports the hashes plus write wall time.
//! PASS = one identical hash per row.

use scda::api::{DataSrc, ScdaFile};
use scda::bench_support::{hex, sha256, Table};
use scda::par::{run_parallel, Communicator, Partition};
use scda::testutil::Rng;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let quick = scda::bench_support::quick();
    let n: u64 = if quick { 1 << 12 } else { 1 << 16 };
    let elem = 48u64;
    let mut rng = Rng::new(0x71);
    let data: Arc<Vec<u8>> = Arc::new(rng.bytes((n * elem) as usize, 64));
    let vsizes: Arc<Vec<u64>> = Arc::new((0..n).map(|_| rng.below(100)).collect());
    let vtotal: u64 = vsizes.iter().sum();
    let vdata: Arc<Vec<u8>> = Arc::new(rng.bytes(vtotal as usize, 16));

    println!("T1: serial-equivalence, N={n} elements (A: {elem} B fixed; V: {vtotal} B total)\n");
    let mut table = Table::new(&["P", "partition", "write secs", "file SHA-256 (first 16 hex)"]);
    let mut reference: Option<[u8; 32]> = None;
    let mut ok = true;
    for p in [1usize, 2, 3, 4, 7, 8, 16, 32] {
        for style in ["uniform", "random", "skewed"] {
            let part = match style {
                "uniform" => Partition::uniform(p, n),
                "random" => Partition::from_counts(&rng.partition(n, p)),
                _ => Partition::root_only(p, n),
            };
            let part = Arc::new(part);
            let path = Arc::new(std::env::temp_dir().join(format!("scda-t1-{p}-{style}.scda")));
            let (pp, dd, vv, vs, pa) =
                (Arc::clone(&path), Arc::clone(&data), Arc::clone(&vdata), Arc::clone(&vsizes), Arc::clone(&part));
            let t0 = Instant::now();
            run_parallel(p, move |comm| {
                let rank = comm.rank();
                let r = pa.local_range(rank);
                let mut f = ScdaFile::create(comm, &*pp, b"t1").unwrap();
                f.write_inline(&[b'#'; 32], Some(b"t1:inline")).unwrap();
                f.write_block_from(0, Some(b"global state"), 12, Some(b"t1:block"), false).unwrap();
                let local = &dd[(r.start * elem) as usize..(r.end * elem) as usize];
                f.write_array(DataSrc::Contiguous(local), &pa, elem, Some(b"t1:array"), false).unwrap();
                let ls = &vs[r.start as usize..r.end as usize];
                let lo: u64 = vs[..r.start as usize].iter().sum();
                let len: u64 = ls.iter().sum();
                f.write_varray(
                    DataSrc::Contiguous(&vv[lo as usize..(lo + len) as usize]),
                    &pa,
                    ls,
                    Some(b"t1:varray"),
                    false,
                )
                .unwrap();
                f.close().unwrap();
            });
            let secs = t0.elapsed().as_secs_f64();
            let h = sha256(&std::fs::read(&*path).unwrap());
            let matches = match &reference {
                None => {
                    reference = Some(h);
                    true
                }
                Some(r) => *r == h,
            };
            ok &= matches;
            table.row(&[
                p.to_string(),
                style.to_string(),
                format!("{secs:.3}"),
                format!("{}{}", hex(&h[..8]), if matches { "" } else { "  << MISMATCH" }),
            ]);
            std::fs::remove_file(&*path).unwrap();
        }
    }
    table.print();
    println!("\nT1 RESULT: {}", if ok { "PASS — file bytes invariant under repartition" } else { "FAIL" });
    assert!(ok);
}
