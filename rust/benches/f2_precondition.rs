//! F2 — the L1/L2 preconditioner's effect on deflate ratio and speed:
//! shuffle/delta ON vs OFF across the corpus, plus PJRT-vs-native
//! transform throughput at chunk granularity (the AOT hot path).

use scda::bench_support::{corpus, measure, Table};
use scda::codec::zlib_compress;
use scda::runtime::{Preconditioner, CHUNK};

fn main() {
    let quick = scda::bench_support::quick();
    let len = if quick { 1 << 20 } else { 8 << 20 };
    let reps = if quick { 2 } else { 3 };
    let native = Preconditioner::native();

    println!("F2a: deflate (level 6) ratio with and without shuffle/delta, {} MiB inputs\n", len >> 20);
    let mut table = Table::new(&["corpus", "raw ratio", "shuffled ratio", "improvement", "entropy est (bits/B)"]);
    for (name, data) in corpus(len) {
        let raw = zlib_compress(&data, 6).len() as f64 / data.len() as f64;
        let (t, ent) = native.forward(&data).unwrap();
        let sh = zlib_compress(&t, 6).len() as f64 / data.len() as f64;
        table.row(&[
            name.to_string(),
            format!("{raw:.3}"),
            format!("{sh:.3}"),
            format!("{:+.1}%", (1.0 - sh / raw) * 100.0),
            format!("{ent:.2}"),
        ]);
    }
    table.print();
    println!("\nF2a shape check: improvement on smooth numeric data (amr-f64), ~0 on text/random\n");

    println!("F2b: transform throughput at chunk granularity ({} KiB chunks)\n", CHUNK * 4 / 1024);
    let data = corpus(4 * CHUNK * 4).remove(3).1; // amr-f64, 4 chunks
    let mut table = Table::new(&["backend", "fwd MiB/s", "inv MiB/s", "bit-identical"]);
    let mut rows: Vec<(String, f64, f64, bool)> = Vec::new();
    let (ref_t, _) = native.forward(&data).unwrap();
    {
        let d = data.clone();
        let p = Preconditioner::native();
        let fwd = measure(1, reps, move || {
            std::hint::black_box(p.forward(&d).unwrap().0.len());
        });
        let p = Preconditioner::native();
        let t = ref_t.clone();
        let inv = measure(1, reps, move || {
            std::hint::black_box(p.inverse(&t).unwrap().len());
        });
        rows.push(("native".into(), fwd.mib_per_s(data.len() as u64), inv.mib_per_s(data.len() as u64), true));
    }
    match Preconditioner::pjrt(&scda::cli::artifacts_dir()) {
        Ok(p) => {
            let ident = p.forward(&data).unwrap().0 == ref_t;
            let d = data.clone();
            let p1 = Preconditioner::pjrt(&scda::cli::artifacts_dir()).unwrap();
            let fwd = measure(1, reps, move || {
                std::hint::black_box(p1.forward(&d).unwrap().0.len());
            });
            let p2 = Preconditioner::pjrt(&scda::cli::artifacts_dir()).unwrap();
            let t = ref_t.clone();
            let inv = measure(1, reps, move || {
                std::hint::black_box(p2.inverse(&t).unwrap().len());
            });
            rows.push(("pjrt (interpret)".into(), fwd.mib_per_s(data.len() as u64), inv.mib_per_s(data.len() as u64), ident));
        }
        Err(e) => println!("(PJRT unavailable: {e}; run `make artifacts`)"),
    }
    for (name, f, i, ident) in rows {
        table.row(&[name, format!("{f:.0}"), format!("{i:.0}"), ident.to_string()]);
    }
    table.print();
    println!("\nF2b note: interpret-mode Pallas is a correctness vehicle, not a TPU perf proxy —");
    println!("see EXPERIMENTS.md §Perf for the VMEM/roofline estimate of the real-TPU kernel.");
}
