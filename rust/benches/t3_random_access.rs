//! T3 — selective random access under compression: per-element (scda §3)
//! vs monolithic whole-array deflate (the baseline that "inhibits random
//! and selective access", §1). Measures the latency of extracting k
//! random elements from a compressed array of N elements.
//!
//! Expected shape: per-element access is O(element) — flat in N — while
//! monolithic requires inflating the whole array prefix: O(N). The
//! crossover: monolithic only wins when reading ~everything.

use scda::bench_support::{measure, Table};
use scda::codec::{decode_element, encode_element, zlib_compress, zlib_decompress, CodecOptions};
use scda::mesh::{fields, ring_mesh};
use scda::testutil::Rng;

fn main() {
    let quick = scda::bench_support::quick();
    let elem = 4096usize;
    let reps = if quick { 3 } else { 5 };
    let mesh = ring_mesh(6, 9, (0.5, 0.5), 0.3);

    println!("T3: extract k random elements of {elem} B from a compressed N-element array\n");
    let mut table = Table::new(&[
        "N",
        "k",
        "per-elem ms",
        "monolithic ms",
        "speedup",
        "per-elem ratio",
        "monolithic ratio",
    ]);
    let ns: &[usize] = if quick { &[256, 1024] } else { &[256, 1024, 4096, 16384] };
    for &n in ns {
        // Build payload: n elements of smooth AMR floats.
        let mut payload = Vec::with_capacity(n * elem);
        for (i, q) in mesh.iter().cycle().take(n).enumerate() {
            let mut e = fields::fixed_payload_f32(q, elem / 4);
            e[0] = i as u8; // decorrelate slightly
            payload.extend_from_slice(&e);
        }
        // Per-element encoding (scda convention).
        let opts = CodecOptions::default();
        let encoded: Vec<Vec<u8>> = payload.chunks(elem).map(|e| encode_element(e, opts)).collect();
        let per_elem_bytes: usize = encoded.iter().map(|e| e.len()).sum();
        // Monolithic encoding.
        let mono = zlib_compress(&payload, 9);

        for k in [1usize, 16] {
            let mut rng = Rng::new(n as u64 + k as u64);
            let idx: Vec<usize> = (0..k).map(|_| rng.below(n as u64) as usize).collect();
            let idx2 = idx.clone();
            let enc = encoded.clone();
            let s_pe = measure(1, reps, move || {
                for &i in &idx2 {
                    let e = decode_element(&enc[i]).unwrap();
                    std::hint::black_box(&e);
                }
            });
            let mono2 = mono.clone();
            let idx3 = idx.clone();
            let s_mono = measure(1, reps, move || {
                // Monolithic: must inflate the whole array to reach
                // arbitrary elements (deflate has no random entry points).
                let all = zlib_decompress(&mono2, Some(n * elem)).unwrap();
                for &i in &idx3 {
                    std::hint::black_box(&all[i * elem..(i + 1) * elem]);
                }
            });
            table.row(&[
                n.to_string(),
                k.to_string(),
                format!("{:.3}", s_pe.median * 1e3),
                format!("{:.3}", s_mono.median * 1e3),
                format!("{:.1}x", s_mono.median / s_pe.median),
                format!("{:.3}", per_elem_bytes as f64 / payload.len() as f64),
                format!("{:.3}", mono.len() as f64 / payload.len() as f64),
            ]);
        }
    }
    table.print();
    println!("\nT3 shape check: per-elem latency ~flat in N; monolithic grows ~linearly (who wins: per-element, by O(N/k)).");

    // --- selective access also needs cheap metadata scans: toc() with
    // the read sieve vs direct per-row reads ---
    println!("\nT3b: full-file section scan (toc) of S small V sections, read sieve vs direct\n");
    let mut scan_table = Table::new(&["S", "direct ms", "sieved ms", "direct preads", "sieved preads", "fstats"]);
    let scan_sizes: &[usize] = if quick { &[64, 256] } else { &[64, 256, 1024] };
    for &s in scan_sizes {
        let p = scda::bench_support::io_bench::toc_scan(s, reps);
        scan_table.row(&[
            s.to_string(),
            format!("{:.3}", p.direct_ms),
            format!("{:.3}", p.sieved_ms),
            p.direct_read_calls.to_string(),
            p.sieved_read_calls.to_string(),
            p.stat_calls.to_string(),
        ]);
    }
    scan_table.print();
    println!("\nT3b shape check: sieved preads ~= bytes/window (flat-ish); direct grows with S; fstats stay O(1) (cached length).");

    // --- write-side engine sweep at quick size: random access cares
    // about syscall counts, and the collective engine pins them to the
    // stripe count regardless of section interleaving ---
    let io = scda::bench_support::io_bench::run_quick();
    println!("\nT3c: engine write sweep ({} MiB, {} ranks):", io.payload_bytes >> 20, io.ranks);
    for e in &io.engines {
        println!(
            "  {:>17}: {:>7.0} MiB/s, {:>5} write syscalls, {:>8} B shipped",
            e.name, e.write_mib_s, e.write_calls, e.shipped_bytes
        );
    }

    // --- named-dataset random access: the archive catalog's O(1) footer
    // index vs the linear scan it replaces (BENCH_archive.json) ---
    println!("\nT3d: archive open_dataset(last) over S named datasets, indexed vs scan\n");
    let mut ar_table =
        Table::new(&["S", "indexed ms", "scan ms", "speedup", "indexed preads", "scan preads"]);
    let sweep: &[usize] = if quick { &[8, 64] } else { &[8, 64, 512, 2048] };
    let profiles: Vec<_> = sweep
        .iter()
        .map(|&s| scda::bench_support::archive_bench::random_access(s, 32, 256, reps))
        .collect();
    for p in &profiles {
        ar_table.row(&[
            p.datasets.to_string(),
            format!("{:.3}", p.indexed_ms),
            format!("{:.3}", p.scan_ms),
            format!("{:.1}x", p.speedup()),
            p.indexed_reads.to_string(),
            p.scan_reads.to_string(),
        ]);
    }
    ar_table.print();
    println!(
        "\nT3d shape check: indexed preads flat in S (O(1) footer -> catalog -> section); scan preads grow ~linearly."
    );
    let path = scda::bench_support::bench_archive_json_path();
    if let Err(e) = scda::bench_support::archive_bench::report(&profiles).write(&path) {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("wrote {}", path.display());
    }
}
