//! T4 — compression ratio and speed: per-element convention (§3) vs
//! monolithic deflate vs no compression, across the corpus and element
//! sizes. Quantifies the paper's stated "downside to include more
//! overhead than monolithic compression" and where the per-element
//! framing (base64 4/3 + zlib header + size rows) amortizes.

use scda::bench_support::{corpus, measure, Table};
use scda::codec::{encode_element, zlib_compress, CodecOptions};

fn main() {
    let quick = scda::bench_support::quick();
    let len = if quick { 1 << 20 } else { 8 << 20 };
    let reps = if quick { 2 } else { 3 };
    println!("T4: ratios over {} MiB per corpus entry (level 9)\n", len >> 20);

    let mut table = Table::new(&[
        "corpus",
        "elem B",
        "per-elem ratio",
        "mono ratio",
        "overhead vs mono",
        "per-elem MiB/s",
        "mono MiB/s",
    ]);
    for (name, data) in corpus(len) {
        // Monolithic reference.
        let d2 = data.clone();
        let s_mono = measure(0, reps, move || {
            std::hint::black_box(zlib_compress(&d2, 9).len());
        });
        let mono_len = zlib_compress(&data, 9).len();
        for elem in [256usize, 4096, 65536] {
            let opts = CodecOptions::default();
            let d3 = data.clone();
            let s_pe = measure(0, reps, move || {
                let mut total = 0usize;
                for e in d3.chunks(elem) {
                    total += encode_element(e, opts).len();
                }
                std::hint::black_box(total);
            });
            let pe_len: usize = data.chunks(elem).map(|e| encode_element(e, opts).len()).sum::<usize>()
                + 32 * data.len().div_ceil(elem); // V-section size rows
            table.row(&[
                name.to_string(),
                elem.to_string(),
                format!("{:.3}", pe_len as f64 / data.len() as f64),
                format!("{:.3}", mono_len as f64 / data.len() as f64),
                format!("{:.2}x", pe_len as f64 / mono_len as f64),
                format!("{:.0}", s_pe.mib_per_s(data.len() as u64)),
                format!("{:.0}", s_mono.mib_per_s(data.len() as u64)),
            ]);
        }
    }
    table.print();
    println!("\nT4 shape check: per-element ratio approaches monolithic as elem size grows;");
    println!("the 4/3 base64 factor is the floor of the per-element overhead (paper §3.1).");

    // --- codec pipeline: encoded section throughput, serial vs pooled ---
    let t = if quick {
        scda::bench_support::codec_bench::run_quick()
    } else {
        scda::bench_support::codec_bench::run(4, 32 << 20, 64 << 10, reps)
    };
    println!(
        "\nT4 codec pipeline ({} MiB compressible, {} KiB elems, {} lanes):",
        t.payload_bytes >> 20,
        t.elem_bytes >> 10,
        t.lanes
    );
    let mut pt = Table::new(&["path", "serial MiB/s", "pooled MiB/s", "speedup"]);
    pt.row(&[
        "encoded write_array".into(),
        format!("{:.0}", t.write_serial),
        format!("{:.0}", t.write_pooled),
        format!("{:.2}x", t.write_speedup()),
    ]);
    pt.row(&[
        "encoded read_array".into(),
        format!("{:.0}", t.read_serial),
        format!("{:.0}", t.read_pooled),
        format!("{:.2}x", t.read_speedup()),
    ]);
    pt.print();
    let json = scda::bench_support::bench_json_path();
    t.report().write(&json).unwrap();
    println!("wrote {}", json.display());
}
