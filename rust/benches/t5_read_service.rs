//! T5 — concurrent read service: one archive, many readers. N client
//! sessions over one [`ArchiveReadService`] fire a zipfian request mix;
//! the shared page cache (hits, single-flight miss collapse, budgeted
//! eviction) is measured against the per-session-sieve baseline where
//! every session refills privately.
//!
//! Expected shape: at >=4 sessions shared-cache req/s beats the
//! baseline, and shared preads track the workload's *unique* bytes —
//! flat in session count — while baseline preads grow ~linearly with
//! sessions (every session re-reads the hot set).

use scda::bench_support::{serve_bench, Table};
use scda::coordinator::Metrics;

fn main() {
    let quick = scda::bench_support::quick();
    // Workload: datasets x (elems x elem_bytes) arrays, per-session
    // request count, elements per request.
    let (datasets, elems, elem_bytes, per_session, count) =
        if quick { (8, 2048, 64, 200, 16) } else { (8, 16384, 256, 2000, 32) };

    println!(
        "T5: {} sessions x {} budgets, zipfian {per_session} reqs/session of {count} x {elem_bytes} B over {datasets} datasets\n",
        serve_bench::SESSIONS.len(),
        serve_bench::BUDGETS.len(),
    );

    let profiles = serve_bench::run(datasets, elems, elem_bytes, per_session, count);

    let mut table = Table::new(&[
        "sessions",
        "budget",
        "shared req/s",
        "base req/s",
        "speedup",
        "shared p50/p99 us",
        "base p50/p99 us",
        "shared preads",
        "base preads",
        "unique KiB",
    ]);
    for p in &profiles {
        table.row(&[
            p.sessions.to_string(),
            format!("{} KiB", p.budget_bytes >> 10),
            format!("{:.0}", p.shared_rps),
            format!("{:.0}", p.baseline_rps),
            format!("{:.2}x", p.speedup()),
            format!("{:.1}/{:.1}", p.shared_p50_us, p.shared_p99_us),
            format!("{:.1}/{:.1}", p.baseline_p50_us, p.baseline_p99_us),
            p.shared_preads.to_string(),
            p.baseline_preads.to_string(),
            (p.unique_bytes >> 10).to_string(),
        ]);
    }
    table.print();
    println!(
        "\nT5 shape check: shared preads ~flat in sessions (track unique bytes); baseline preads grow with sessions; speedup >= 1 at >=4 sessions."
    );

    // Satellite: the cache counters flow through the standard Metrics
    // report — fold in the busiest cell (once, via the absorb helpers)
    // and render it.
    if let Some(p) = profiles.iter().max_by_key(|p| (p.sessions, p.budget_bytes)) {
        let m = Metrics::new();
        m.absorb_cache(&scda::io::CacheStats {
            hits: p.cache_hits,
            misses: p.cache_misses,
            evictions: p.cache_evictions,
            single_flight_waits: p.single_flight_waits,
            ..Default::default()
        });
        Metrics::add(&m.read_calls, p.shared_preads);
        println!(
            "\ncache counters at s{} b{} via Metrics:\n{}",
            p.sessions,
            p.budget_bytes,
            m.report()
        );
    }

    let path = scda::bench_support::bench_serve_json_path();
    if let Err(e) =
        serve_bench::report(&profiles, datasets, elems, elem_bytes, per_session).write(&path)
    {
        eprintln!("warning: could not write {}: {e}", path.display());
    } else {
        println!("wrote {}", path.display());
    }
}
