//! Morton (Z-order) space-filling-curve indexing for quadtree quadrants —
//! the "contiguous indexed partitions, such as those arising from
//! space-filling-curve partitions" the paper names as its canonical mesh
//! workload (p4est-style).

/// Maximum refinement level representable (30 keeps 2*level+5 bits in u64).
pub const MAX_LEVEL: u8 = 30;

/// Interleave the low 32 bits of `x` and `y` (x in even bit positions).
#[inline]
pub fn interleave2(x: u32, y: u32) -> u64 {
    (spread(x as u64)) | (spread(y as u64) << 1)
}

#[inline]
fn spread(mut v: u64) -> u64 {
    v &= 0xffff_ffff;
    v = (v | (v << 16)) & 0x0000_ffff_0000_ffff;
    v = (v | (v << 8)) & 0x00ff_00ff_00ff_00ff;
    v = (v | (v << 4)) & 0x0f0f_0f0f_0f0f_0f0f;
    v = (v | (v << 2)) & 0x3333_3333_3333_3333;
    v = (v | (v << 1)) & 0x5555_5555_5555_5555;
    v
}

#[inline]
fn compact(mut v: u64) -> u32 {
    v &= 0x5555_5555_5555_5555;
    v = (v | (v >> 1)) & 0x3333_3333_3333_3333;
    v = (v | (v >> 2)) & 0x0f0f_0f0f_0f0f_0f0f;
    v = (v | (v >> 4)) & 0x00ff_00ff_00ff_00ff;
    v = (v | (v >> 8)) & 0x0000_ffff_0000_ffff;
    v = (v | (v >> 16)) & 0x0000_0000_ffff_ffff;
    v as u32
}

/// Inverse of [`interleave2`].
#[inline]
pub fn deinterleave2(m: u64) -> (u32, u32) {
    (compact(m), compact(m >> 1))
}

/// A quadtree quadrant addressed by its level and integer anchor
/// coordinates on the level grid (`0 <= x, y < 2^level`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Quadrant {
    pub x: u32,
    pub y: u32,
    pub level: u8,
}

impl Quadrant {
    pub const ROOT: Quadrant = Quadrant { x: 0, y: 0, level: 0 };

    /// Child `c in 0..4` in Morton order.
    pub fn child(&self, c: u8) -> Quadrant {
        debug_assert!(c < 4 && self.level < MAX_LEVEL);
        Quadrant {
            x: (self.x << 1) | (c as u32 & 1),
            y: (self.y << 1) | ((c as u32 >> 1) & 1),
            level: self.level + 1,
        }
    }

    /// Total SFC ordering key: depth-first Morton position, comparable
    /// across levels (ancestors sort before descendants' successors).
    pub fn sfc_key(&self) -> u128 {
        // Normalize coordinates to MAX_LEVEL resolution, then append the
        // level so a parent sorts immediately before its first child.
        // (The normalized Morton index needs 2 * MAX_LEVEL = 60 bits, so
        // the level tag pushes the key into u128 territory.)
        let shift = (MAX_LEVEL - self.level) as u32;
        let m = interleave2(self.x << shift, self.y << shift);
        ((m as u128) << 5) | self.level as u128
    }

    /// Center coordinates in the unit square.
    pub fn center(&self) -> (f64, f64) {
        let h = 1.0 / (1u64 << self.level) as f64;
        ((self.x as f64 + 0.5) * h, (self.y as f64 + 0.5) * h)
    }

    /// Side length in the unit square.
    pub fn side(&self) -> f64 {
        1.0 / (1u64 << self.level) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn interleave_roundtrips() {
        let mut rng = Rng::new(1);
        for _ in 0..1000 {
            let x = rng.next_u64() as u32;
            let y = rng.next_u64() as u32;
            assert_eq!(deinterleave2(interleave2(x, y)), (x, y));
        }
        assert_eq!(interleave2(0, 0), 0);
        assert_eq!(interleave2(1, 0), 1);
        assert_eq!(interleave2(0, 1), 2);
        assert_eq!(interleave2(u32::MAX, u32::MAX), u64::MAX);
    }

    #[test]
    fn morton_order_is_z_pattern() {
        // At level 1 the Morton order of (x, y) anchors is
        // (0,0), (1,0), (0,1), (1,1).
        let keys: Vec<u128> = [(0u32, 0u32), (1, 0), (0, 1), (1, 1)]
            .iter()
            .map(|&(x, y)| Quadrant { x, y, level: 1 }.sfc_key())
            .collect();
        assert!(keys.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn children_sort_after_parent_and_before_uncle() {
        let p = Quadrant { x: 1, y: 1, level: 2 };
        let parent_key = p.sfc_key();
        let mut prev = parent_key;
        for c in 0..4 {
            let k = p.child(c).sfc_key();
            assert!(k > prev);
            prev = k;
        }
        // Next quadrant at the parent's level.
        let uncle = Quadrant { x: 2, y: 1, level: 2 };
        assert!(prev < uncle.sfc_key());
    }

    #[test]
    fn geometry() {
        let q = Quadrant { x: 3, y: 1, level: 2 };
        assert_eq!(q.side(), 0.25);
        assert_eq!(q.center(), (0.875, 0.375));
        let c = q.child(3);
        assert_eq!((c.x, c.y, c.level), (7, 3, 3));
    }
}
