//! Synthetic per-element field data over an AMR mesh: smooth f64/f32
//! fields (the compressible case the precondition filter targets) and
//! hp-style variable-size payloads (the V-section workload).

use crate::mesh::morton::Quadrant;

/// Sample a smooth scalar function at a quadrant center.
pub fn smooth_scalar(q: &Quadrant) -> f64 {
    let (x, y) = q.center();
    (2.0 * std::f64::consts::PI * x).sin() * (3.0 * std::f64::consts::PI * y).cos()
        + 0.1 * (8.0 * x * y)
        + 10.0
}

/// Fixed-size payload: `k` f64 samples per element (function + simple
/// derived quantities) — a typical conservative-variable block.
pub fn fixed_payload(q: &Quadrant, k: usize) -> Vec<u8> {
    let base = smooth_scalar(q);
    let mut out = Vec::with_capacity(k * 8);
    for j in 0..k {
        let v = base * (1.0 + 0.001 * j as f64) + j as f64;
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Fixed-size payload of `k` f32 samples — the preconditioner's design
/// dtype (the shuffle/delta kernel works on u32 words, which is exactly
/// one f32; f64 fields need a stride-2 variant, see DESIGN.md §Future).
pub fn fixed_payload_f32(q: &Quadrant, k: usize) -> Vec<u8> {
    let base = smooth_scalar(q) as f32;
    let mut out = Vec::with_capacity(k * 4);
    for j in 0..k {
        let v = base * (1.0 + 0.001 * j as f32) + j as f32;
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Assemble this rank's contiguous payload for a fixed-size f32 field.
pub fn local_fixed_field_f32(leaves: &[Quadrant], range: std::ops::Range<usize>, k: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity((range.end - range.start) * k * 4);
    for q in &leaves[range] {
        out.extend_from_slice(&fixed_payload_f32(q, k));
    }
    out
}

/// hp-adaptive payload size: a degree-`p` element carries `(p+1)^2`
/// coefficients; degree grows with refinement level (capped). This is the
/// paper's "data of hp-adaptive element methods" varray workload.
pub fn hp_payload_size(q: &Quadrant, max_degree: u32) -> u64 {
    let p = (q.level as u32 + 1).min(max_degree);
    ((p + 1) * (p + 1)) as u64 * 8
}

/// Variable-size payload: smooth coefficients of the hp expansion.
pub fn hp_payload(q: &Quadrant, max_degree: u32) -> Vec<u8> {
    let n = hp_payload_size(q, max_degree) as usize / 8;
    let base = smooth_scalar(q);
    let mut out = Vec::with_capacity(n * 8);
    for j in 0..n {
        // Spectral-like decay of coefficients.
        let v = base / (1.0 + j as f64).powi(2);
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Assemble this rank's contiguous payload for a fixed-size field.
pub fn local_fixed_field(leaves: &[Quadrant], range: std::ops::Range<usize>, k: usize) -> Vec<u8> {
    let mut out = Vec::with_capacity((range.end - range.start) * k * 8);
    for q in &leaves[range] {
        out.extend_from_slice(&fixed_payload(q, k));
    }
    out
}

/// Assemble this rank's sizes + payload for the hp varray field.
pub fn local_hp_field(leaves: &[Quadrant], range: std::ops::Range<usize>, max_degree: u32) -> (Vec<u64>, Vec<u8>) {
    let mut sizes = Vec::with_capacity(range.end - range.start);
    let mut data = Vec::new();
    for q in &leaves[range] {
        sizes.push(hp_payload_size(q, max_degree));
        data.extend_from_slice(&hp_payload(q, max_degree));
    }
    (sizes, data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mesh::amr::ring_mesh;

    #[test]
    fn payload_sizes_consistent() {
        let mesh = ring_mesh(2, 5, (0.5, 0.5), 0.25);
        for q in &mesh {
            assert_eq!(fixed_payload(q, 5).len(), 40);
            assert_eq!(hp_payload(q, 6).len() as u64, hp_payload_size(q, 6));
        }
    }

    #[test]
    fn local_assembly_matches_per_element() {
        let mesh = ring_mesh(2, 4, (0.3, 0.6), 0.2);
        let k = 3;
        let all = local_fixed_field(&mesh, 0..mesh.len(), k);
        let mut manual = Vec::new();
        for q in &mesh {
            manual.extend_from_slice(&fixed_payload(q, k));
        }
        assert_eq!(all, manual);
        let (sizes, data) = local_hp_field(&mesh, 0..mesh.len(), 5);
        assert_eq!(sizes.len(), mesh.len());
        assert_eq!(data.len() as u64, sizes.iter().sum::<u64>());
    }

    #[test]
    fn smooth_field_is_deterministic() {
        let mesh = ring_mesh(2, 4, (0.5, 0.5), 0.3);
        assert_eq!(local_fixed_field(&mesh, 0..10, 4), local_fixed_field(&mesh, 0..10, 4));
    }
}
