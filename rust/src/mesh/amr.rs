//! Adaptive quadtree meshes in Morton order: the synthetic stand-in for
//! the paper's p4est/t8code workloads. A refinement indicator drives
//! depth-first subdivision; the resulting leaf sequence *is* the
//! space-filling-curve order, so contiguous partitions of it are exactly
//! the "contiguous indexed partitions" scda assumes.

use crate::mesh::morton::Quadrant;

/// Depth-first adaptive refinement: `refine(q)` decides subdivision;
/// leaves are appended in Morton order.
pub fn refine_mesh(max_level: u8, refine: impl Fn(&Quadrant) -> bool) -> Vec<Quadrant> {
    let mut leaves = Vec::new();
    fn walk(q: Quadrant, max_level: u8, refine: &impl Fn(&Quadrant) -> bool, out: &mut Vec<Quadrant>) {
        if q.level < max_level && refine(&q) {
            for c in 0..4 {
                walk(q.child(c), max_level, refine, out);
            }
        } else {
            out.push(q);
        }
    }
    walk(Quadrant::ROOT, max_level, &refine, &mut leaves);
    leaves
}

/// The standard demo mesh: uniform base level plus extra refinement in an
/// annulus around a circle (mimics a shock/interface tracker). Element
/// count grows roughly as `4^base + ring resolution`.
pub fn ring_mesh(base_level: u8, max_level: u8, center: (f64, f64), radius: f64) -> Vec<Quadrant> {
    refine_mesh(max_level, |q| {
        if q.level < base_level {
            return true;
        }
        let (cx, cy) = q.center();
        let d = ((cx - center.0).powi(2) + (cy - center.1).powi(2)).sqrt();
        // Refine when the quadrant may intersect the circle line.
        (d - radius).abs() < q.side() * 0.75
    })
}

/// Verify Morton ordering (strictly ascending SFC keys) and geometric
/// tiling (leaf areas sum to 1). Used by tests and `scda demo-write`.
pub fn check_mesh(leaves: &[Quadrant]) -> bool {
    let ordered = leaves.windows(2).all(|w| w[0].sfc_key() < w[1].sfc_key());
    let area: f64 = leaves.iter().map(|q| q.side() * q.side()).sum();
    ordered && (area - 1.0).abs() < 1e-9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_mesh_has_4_pow_level_leaves() {
        for level in 0..=4u8 {
            let leaves = refine_mesh(level, |_| true);
            assert_eq!(leaves.len(), 4usize.pow(level as u32));
            assert!(check_mesh(&leaves));
        }
    }

    #[test]
    fn ring_mesh_is_adaptive_ordered_and_tiling() {
        let leaves = ring_mesh(3, 7, (0.5, 0.5), 0.3);
        assert!(check_mesh(&leaves));
        // Adaptive: multiple levels present.
        let min = leaves.iter().map(|q| q.level).min().unwrap();
        let max = leaves.iter().map(|q| q.level).max().unwrap();
        assert!(min >= 3 && max == 7, "levels {min}..{max}");
        // More than uniform base, less than uniform max.
        assert!(leaves.len() > 4usize.pow(3));
        assert!(leaves.len() < 4usize.pow(7));
    }

    #[test]
    fn indicator_false_keeps_root() {
        let leaves = refine_mesh(5, |_| false);
        assert_eq!(leaves.len(), 1);
        assert_eq!(leaves[0], Quadrant::ROOT);
    }
}
