//! AMR workload substrate: Morton-order quadtrees and synthetic fields —
//! the mesh-shaped data the paper's motivating applications (p4est,
//! t8code, ForestClaw) write through scda.

pub mod amr;
pub mod fields;
pub mod morton;

pub use amr::{check_mesh, refine_mesh, ring_mesh};
pub use morton::Quadrant;
