//! DEFLATE decompressor (RFC 1951), the read side of the compression
//! convention. Accepts streams produced by any conforming compressor
//! (ours, zlib, miniz), validating block structure strictly.

use crate::codec::bitio::BitReader;
use crate::codec::deflate::{CLCL_ORDER, DIST_TABLE, LENGTH_TABLE};
use crate::codec::huffman::HuffDecoder;
use crate::error::{corrupt, Result, ScdaError};

/// Inflate a raw DEFLATE stream. `expected_size`, when known (the scda
/// convention always records it), preallocates and bounds the output;
/// exceeding it is a corruption error.
pub fn inflate(data: &[u8], expected_size: Option<usize>) -> Result<Vec<u8>> {
    Ok(inflate_with_consumed(data, expected_size)?.0)
}

/// Number of bytes of `data` consumed by the deflate stream (for embedded
/// streams followed by a trailer, e.g. the zlib Adler-32).
pub fn inflate_with_consumed(data: &[u8], expected_size: Option<usize>) -> Result<(Vec<u8>, usize)> {
    let mut out: Vec<u8> = Vec::new();
    let consumed = inflate_into(data, expected_size, &mut out)?;
    Ok((out, consumed))
}

/// [`inflate_with_consumed`] appending to `out`, which may already hold
/// unrelated bytes (the codec pipeline's reusable chunk buffers): all
/// size accounting and back-reference windows are relative to the
/// position where this stream's output begins, so prior contents are
/// never read or altered. Returns the number of `data` bytes consumed.
pub fn inflate_into(data: &[u8], expected_size: Option<usize>, out: &mut Vec<u8>) -> Result<usize> {
    // Re-run header parsing but track position: simplest correct approach
    // is to parse once with a reader we keep.
    let mut r = BitReader::new(data);
    let base = out.len();
    out.reserve(expected_size.unwrap_or(0).min(1 << 30));
    let limit = expected_size.map(|s| s as u64);
    loop {
        let bfinal = r.read_bits(1)?;
        let btype = r.read_bits(2)?;
        match btype {
            0b00 => {
                let hdr = r.read_aligned_bytes(4)?;
                let len = u16::from_le_bytes([hdr[0], hdr[1]]);
                let nlen = u16::from_le_bytes([hdr[2], hdr[3]]);
                if len != !nlen {
                    return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "stored block LEN/NLEN mismatch"));
                }
                let bytes = r.read_aligned_bytes(len as usize)?;
                check_limit((out.len() - base) as u64 + bytes.len() as u64, limit)?;
                out.extend_from_slice(bytes);
            }
            0b01 => {
                let (lit, dist) = fixed_decoders();
                inflate_block(&mut r, lit, dist, out, base, limit)?;
            }
            0b10 => {
                let (lit, dist) = read_dynamic_header(&mut r)?;
                inflate_block(&mut r, &lit, &dist, out, base, limit)?;
            }
            _ => return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "reserved block type 11")),
        }
        if bfinal == 1 {
            break;
        }
    }
    let consumed = r.byte_position();
    if let Some(s) = expected_size {
        if out.len() - base != s {
            return Err(ScdaError::corrupt(
                corrupt::SIZE_MISMATCH,
                format!("inflated {} bytes, expected {}", out.len() - base, s),
            ));
        }
    }
    Ok(consumed)
}

fn check_limit(total: u64, limit: Option<u64>) -> Result<()> {
    if let Some(l) = limit {
        if total > l {
            return Err(ScdaError::corrupt(
                corrupt::SIZE_MISMATCH,
                "inflated data exceeds recorded uncompressed size",
            ));
        }
    }
    // Hard backstop against decompression bombs when no size is known.
    if total > 1 << 40 {
        return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "refusing to inflate beyond 1 TiB"));
    }
    Ok(())
}

/// The RFC 1951 fixed-code decoders, built once per process: fixed
/// blocks are the common case for small per-element frames, and the LUT
/// construction is the dominant cost of decoding such a frame.
fn fixed_decoders() -> (&'static HuffDecoder, &'static HuffDecoder) {
    static FIXED: std::sync::OnceLock<(HuffDecoder, HuffDecoder)> = std::sync::OnceLock::new();
    let (lit, dist) = FIXED.get_or_init(|| {
        let mut lit = vec![8u8; 288];
        lit[144..256].iter_mut().for_each(|x| *x = 9);
        lit[256..280].iter_mut().for_each(|x| *x = 7);
        // The fixed tables are well-formed by construction; unwrap is fine.
        (HuffDecoder::new(&lit).unwrap(), HuffDecoder::new(&[5u8; 30]).unwrap())
    });
    (lit, dist)
}

fn read_dynamic_header(r: &mut BitReader<'_>) -> Result<(HuffDecoder, HuffDecoder)> {
    let hlit = r.read_bits(5)? as usize + 257;
    let hdist = r.read_bits(5)? as usize + 1;
    let hclen = r.read_bits(4)? as usize + 4;
    if hlit > 286 || hdist > 30 {
        return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "dynamic header HLIT/HDIST out of range"));
    }
    let mut cl_len = [0u8; 19];
    for i in 0..hclen {
        cl_len[CLCL_ORDER[i]] = r.read_bits(3)? as u8;
    }
    let cl_dec = HuffDecoder::new(&cl_len)?;
    let mut lengths = vec![0u8; hlit + hdist];
    let mut i = 0usize;
    while i < lengths.len() {
        let sym = cl_dec.decode(r)?;
        match sym {
            0..=15 => {
                lengths[i] = sym as u8;
                i += 1;
            }
            16 => {
                if i == 0 {
                    return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "repeat with no previous length"));
                }
                let rep = 3 + r.read_bits(2)? as usize;
                let v = lengths[i - 1];
                fill(&mut lengths, &mut i, v, rep)?;
            }
            17 => {
                let rep = 3 + r.read_bits(3)? as usize;
                fill(&mut lengths, &mut i, 0, rep)?;
            }
            18 => {
                let rep = 11 + r.read_bits(7)? as usize;
                fill(&mut lengths, &mut i, 0, rep)?;
            }
            _ => return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "invalid code-length symbol")),
        }
    }
    if lengths[256] == 0 {
        return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "dynamic code lacks end-of-block symbol"));
    }
    let lit = HuffDecoder::new(&lengths[..hlit])?;
    let dist = HuffDecoder::new(&lengths[hlit..])?;
    Ok((lit, dist))
}

fn fill(lengths: &mut [u8], i: &mut usize, v: u8, rep: usize) -> Result<()> {
    if *i + rep > lengths.len() {
        return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "code-length repeat overruns header"));
    }
    lengths[*i..*i + rep].iter_mut().for_each(|x| *x = v);
    *i += rep;
    Ok(())
}

fn inflate_block(
    r: &mut BitReader<'_>,
    lit: &HuffDecoder,
    dist: &HuffDecoder,
    out: &mut Vec<u8>,
    stream_base: usize,
    limit: Option<u64>,
) -> Result<()> {
    loop {
        let sym = lit.decode(r)?;
        match sym {
            0..=255 => {
                check_limit((out.len() - stream_base) as u64 + 1, limit)?;
                out.push(sym as u8);
            }
            256 => return Ok(()),
            257..=285 => {
                let (base, extra) = LENGTH_TABLE[sym as usize - 257];
                let len = base as usize + r.read_bits(extra as u32)? as usize;
                let dsym = dist.decode(r)?;
                if dsym as usize >= DIST_TABLE.len() {
                    return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "invalid distance symbol"));
                }
                let (dbase, dextra) = DIST_TABLE[dsym as usize];
                let d = dbase as usize + r.read_bits(dextra as u32)? as usize;
                if d > out.len() - stream_base {
                    return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "distance reaches before stream start"));
                }
                check_limit((out.len() - stream_base) as u64 + len as u64, limit)?;
                let start = out.len() - d;
                // Overlapping copy must proceed byte-wise (RLE semantics).
                if d >= len {
                    out.extend_from_within(start..start + len);
                } else {
                    for k in 0..len {
                        let b = out[start + k];
                        out.push(b);
                    }
                }
            }
            _ => return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "literal/length symbol 286/287")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::deflate::deflate;

    fn roundtrip(data: &[u8], level: u8) {
        let compressed = deflate(data, level);
        let out = inflate(&compressed, Some(data.len())).unwrap();
        assert_eq!(out, data, "level {level} len {}", data.len());
        let out2 = inflate(&compressed, None).unwrap();
        assert_eq!(out2, data);
    }

    fn corpus() -> Vec<Vec<u8>> {
        let mut x = 88172645463325252u64;
        let mut rnd = |n: usize, alphabet: u64| -> Vec<u8> {
            (0..n)
                .map(|_| {
                    x ^= x << 13;
                    x ^= x >> 7;
                    x ^= x << 17;
                    (x % alphabet) as u8
                })
                .collect()
        };
        vec![
            b"".to_vec(),
            b"a".to_vec(),
            b"hello hello hello hello".to_vec(),
            vec![0u8; 100_000],
            (0u32..70_000).map(|i| (i % 251) as u8).collect(),
            rnd(300_000, 256), // incompressible -> stored blocks
            rnd(300_000, 4),   // tiny alphabet -> heavy matching
            b"The scda format is serial-equivalent by design. ".repeat(2000),
        ]
    }

    #[test]
    fn roundtrips_all_levels() {
        for data in corpus() {
            for level in [0u8, 1, 6, 9] {
                roundtrip(&data, level);
            }
        }
    }

    #[test]
    fn multi_segment_inputs() {
        // > SEGMENT bytes forces multiple blocks incl. final-flag logic.
        let data: Vec<u8> = (0..600_000u32).map(|i| ((i / 7) % 256) as u8).collect();
        for level in [0u8, 6] {
            roundtrip(&data, level);
        }
    }

    #[test]
    fn wrong_expected_size_detected() {
        let c = deflate(b"abcdef", 6);
        let err = inflate(&c, Some(5)).unwrap_err();
        assert_eq!(err.kind(), crate::error::ScdaErrorKind::CorruptFile);
        let err = inflate(&c, Some(7)).unwrap_err();
        assert_eq!(err.code(), 1000 + corrupt::SIZE_MISMATCH);
    }

    #[test]
    fn garbage_rejected() {
        assert!(inflate(&[], None).is_err());
        assert!(inflate(&[0x07], None).is_err()); // btype 11
        assert!(inflate(&[0xff, 0xff, 0xff], None).is_err());
        // Stored block with corrupted NLEN.
        let mut c = deflate(&vec![9u8; 10], 0);
        c[2] ^= 0xff;
        assert!(inflate(&c, None).is_err());
    }

    #[test]
    fn truncation_rejected() {
        let data = b"some reasonably compressible data data data data".repeat(10);
        let c = deflate(&data, 6);
        for cut in [1, c.len() / 2, c.len() - 1] {
            assert!(inflate(&c[..cut], Some(data.len())).is_err(), "cut {cut}");
        }
    }

    #[test]
    fn inflate_into_preserves_prior_contents() {
        // The pipeline appends many elements into one chunk buffer; the
        // decoder must neither read nor disturb bytes before its base.
        let a = b"first element first element first element".to_vec();
        let b = b"second element second element".to_vec();
        let ca = deflate(&a, 9);
        let cb = deflate(&b, 9);
        let mut out = Vec::new();
        inflate_into(&ca, Some(a.len()), &mut out).unwrap();
        inflate_into(&cb, Some(b.len()), &mut out).unwrap();
        assert_eq!(out, [a.clone(), b].concat());
        // A back-reference that would reach before the base is corrupt
        // even when earlier bytes exist in the buffer.
        let mut prefixed = vec![0xEEu8; 64];
        inflate_into(&ca, Some(a.len()), &mut prefixed).unwrap();
        assert_eq!(&prefixed[..64], &[0xEEu8; 64][..]);
        assert_eq!(&prefixed[64..], &a[..]);
    }

    #[test]
    fn consumed_reports_stream_end() {
        let data = b"trailing bytes follow".to_vec();
        let mut c = deflate(&data, 6);
        let stream_len = c.len();
        c.extend_from_slice(&[0xAA; 4]); // fake adler trailer
        let (out, consumed) = inflate_with_consumed(&c, Some(data.len())).unwrap();
        assert_eq!(out, data);
        assert_eq!(consumed, stream_len);
    }
}
