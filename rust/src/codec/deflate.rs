//! DEFLATE compressor (RFC 1951), written from scratch for the compression
//! convention of §3.1 ("an RFC 1950/1951 deflate stream using any legal
//! compression level").
//!
//! Strategy: the input is processed in segments; each segment is LZ77-
//! tokenized ([`crate::codec::lz77`]) and emitted as one block, choosing
//! per block among *stored*, *fixed-Huffman*, and *dynamic-Huffman*
//! encodings by exact bit cost. Level 0 hardcodes stored blocks — the
//! paper's zlib-free fallback.

use crate::codec::bitio::BitWriter;
use crate::codec::huffman::{build_lengths, lengths_to_codes};
use crate::codec::lz77::{Matcher, MatchParams, Token, MAX_MATCH, MIN_MATCH};

/// Length code table: (symbol - 257) -> (base length, extra bits).
pub const LENGTH_TABLE: [(u16, u8); 29] = [
    (3, 0), (4, 0), (5, 0), (6, 0), (7, 0), (8, 0), (9, 0), (10, 0),
    (11, 1), (13, 1), (15, 1), (17, 1),
    (19, 2), (23, 2), (27, 2), (31, 2),
    (35, 3), (43, 3), (51, 3), (59, 3),
    (67, 4), (83, 4), (99, 4), (115, 4),
    (131, 5), (163, 5), (195, 5), (227, 5),
    (258, 0),
];

/// Distance code table: symbol -> (base distance, extra bits).
pub const DIST_TABLE: [(u16, u8); 30] = [
    (1, 0), (2, 0), (3, 0), (4, 0), (5, 1), (7, 1), (9, 2), (13, 2),
    (17, 3), (25, 3), (33, 4), (49, 4), (65, 5), (97, 5), (129, 6), (193, 6),
    (257, 7), (385, 7), (513, 8), (769, 8), (1025, 9), (1537, 9),
    (2049, 10), (3073, 10), (4097, 11), (6145, 11), (8193, 12), (12289, 12),
    (16385, 13), (24577, 13),
];

/// Order in which code-length code lengths are transmitted (RFC 1951).
pub const CLCL_ORDER: [usize; 19] = [16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15];

const NUM_LIT: usize = 286; // 0..=285 (286/287 never emitted)
const NUM_DIST: usize = 30;
const STORED_MAX: usize = 65_535;
/// Input bytes per block. Matches do not cross segment boundaries, which
/// costs a little ratio but bounds memory and lets per-block Huffman
/// tables adapt.
const SEGMENT: usize = 256 * 1024;

/// Direct length -> symbol lookup (259 entries, built once).
static LEN_SYM: [u8; 259] = {
    let mut t = [0u8; 259];
    let mut sym = 0usize;
    let mut len = 3usize;
    while len <= 258 {
        while sym + 1 < 29 && LENGTH_TABLE[sym + 1].0 as usize <= len {
            sym += 1;
        }
        t[len] = sym as u8;
        len += 1;
    }
    t[258] = 28; // length 258 uses symbol 285 (0 extra bits)
    t
};

#[inline]
pub fn length_to_symbol(len: usize) -> (u16, u32, u8) {
    debug_assert!((MIN_MATCH..=MAX_MATCH).contains(&len));
    let sym = LEN_SYM[len] as usize;
    let (base, extra) = LENGTH_TABLE[sym];
    (257 + sym as u16, (len - base as usize) as u32, extra)
}

/// Linear scan of [`DIST_TABLE`] — the reference used to build the
/// lookup tables below at compile time (and to cross-check them in tests).
const fn dist_sym_scan(dist: usize) -> u8 {
    let mut s = DIST_TABLE.len() - 1;
    loop {
        if DIST_TABLE[s].0 as usize <= dist {
            return s as u8;
        }
        s -= 1;
    }
}

/// Direct distance -> symbol lookup, zlib-style: a 512-entry table indexed
/// by `dist - 1` for short distances, and a high table indexed by
/// `(dist - 1) >> 7` for the rest (every symbol range above 512 is a
/// multiple of 128 wide, so the 7-bit shift never straddles a symbol).
static DIST_SYM_LOW: [u8; 512] = {
    let mut t = [0u8; 512];
    let mut d = 1usize;
    while d <= 512 {
        t[d - 1] = dist_sym_scan(d);
        d += 1;
    }
    t
};

static DIST_SYM_HIGH: [u8; 256] = {
    let mut t = [0u8; 256];
    let mut i = 0usize;
    while i < 256 {
        t[i] = dist_sym_scan((i << 7) + 1);
        i += 1;
    }
    t
};

#[inline]
pub fn dist_to_symbol(dist: usize) -> (u16, u32, u8) {
    debug_assert!((1..=32768).contains(&dist));
    let sym = if dist <= 512 { DIST_SYM_LOW[dist - 1] } else { DIST_SYM_HIGH[(dist - 1) >> 7] } as usize;
    let (base, extra) = DIST_TABLE[sym];
    (sym as u16, (dist - base as usize) as u32, extra)
}

/// Fixed-Huffman literal/length code lengths (RFC 1951 §3.2.6).
fn fixed_lit_lengths() -> Vec<u8> {
    let mut l = vec![8u8; 288];
    l[144..256].iter_mut().for_each(|x| *x = 9);
    l[256..280].iter_mut().for_each(|x| *x = 7);
    l
}

fn fixed_dist_lengths() -> Vec<u8> {
    vec![5u8; 30]
}

struct FixedTables {
    lit_len: Vec<u8>,
    dist_len: Vec<u8>,
    lit_codes: Vec<u16>,
    dist_codes: Vec<u16>,
}

/// The fixed code tables are level- and data-independent; build them once
/// per process instead of once per element.
fn fixed_tables() -> &'static FixedTables {
    static T: std::sync::OnceLock<FixedTables> = std::sync::OnceLock::new();
    T.get_or_init(|| {
        let lit_len = fixed_lit_lengths();
        let dist_len = fixed_dist_lengths();
        let lit_codes = lengths_to_codes(&lit_len).expect("fixed code");
        let dist_codes = lengths_to_codes(&dist_len).expect("fixed code");
        FixedTables { lit_len, dist_len, lit_codes, dist_codes }
    })
}

/// Everything block encoding needs to know about a token run, gathered in
/// a single pass: symbol histograms (end-of-block included) and the total
/// extra-bits cost, which is the same under any Huffman code.
struct TokenStats {
    lit: [u32; NUM_LIT],
    dist: [u32; NUM_DIST],
    extra_bits: u64,
}

fn analyze_tokens(tokens: &[Token]) -> TokenStats {
    let mut lit = [0u32; NUM_LIT];
    let mut dist = [0u32; NUM_DIST];
    let mut extra_bits = 0u64;
    for t in tokens {
        match *t {
            Token::Literal(b) => lit[b as usize] += 1,
            Token::Match { len, dist: d } => {
                let (ls, _, le) = length_to_symbol(len as usize);
                let (ds, _, de) = dist_to_symbol(d as usize);
                lit[ls as usize] += 1;
                dist[ds as usize] += 1;
                extra_bits += le as u64 + de as u64;
            }
        }
    }
    lit[256] += 1; // end-of-block
    TokenStats { lit, dist, extra_bits }
}

/// Code-dependent bit cost from a histogram: `sum(freq * len)`. Combined
/// with [`TokenStats::extra_bits`] this reproduces the exact per-token
/// cost without a second pass over the token stream.
fn code_bits(freqs: &[u32], lens: &[u8]) -> u64 {
    freqs.iter().zip(lens).map(|(&f, &l)| f as u64 * l as u64).sum()
}

/// Run-length encode the concatenated code lengths with symbols 16/17/18.
/// Returns (cl_symbol, extra_value, extra_bits) triples.
fn rle_code_lengths(lengths: &[u8]) -> Vec<(u8, u32, u8)> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < lengths.len() {
        let v = lengths[i];
        let mut run = 1;
        while i + run < lengths.len() && lengths[i + run] == v {
            run += 1;
        }
        if v == 0 {
            let mut left = run;
            while left >= 11 {
                let take = left.min(138);
                out.push((18, (take - 11) as u32, 7));
                left -= take;
            }
            if left >= 3 {
                out.push((17, (left - 3) as u32, 3));
                left = 0;
            }
            for _ in 0..left {
                out.push((0, 0, 0));
            }
        } else {
            out.push((v, 0, 0));
            let mut left = run - 1;
            while left >= 3 {
                let take = left.min(6);
                out.push((16, (take - 3) as u32, 2));
                left -= take;
            }
            for _ in 0..left {
                out.push((v, 0, 0));
            }
        }
        i += run;
    }
    out
}

/// Force at least two non-zero frequencies so both trees are complete
/// codes — mirrors zlib, and keeps strict inflaters (including CPython's)
/// happy with our dynamic headers.
fn force_two(freqs: &mut [u32]) {
    let mut used = freqs.iter().filter(|&&f| f > 0).count();
    let mut i = 0;
    while used < 2 && i < freqs.len() {
        if freqs[i] == 0 {
            freqs[i] = 1;
            used += 1;
        }
        i += 1;
    }
}

struct DynHeader {
    lit_len: Vec<u8>,
    dist_len: Vec<u8>,
    cl_len: Vec<u8>,
    cl_seq: Vec<(u8, u32, u8)>,
    hlit: usize,
    hdist: usize,
    hclen: usize,
    header_bits: u64,
}

fn build_dynamic_header(lit_freq: &mut [u32; NUM_LIT], dist_freq: &mut [u32; NUM_DIST]) -> DynHeader {
    force_two(&mut lit_freq[..]); // literal tree always has 256 anyway
    force_two(&mut dist_freq[..]);
    let lit_len = build_lengths(&lit_freq[..], 15);
    let dist_len = build_lengths(&dist_freq[..], 15);
    let hlit = (257..=NUM_LIT).rev().find(|&n| n == 257 || lit_len[n - 1] != 0).unwrap_or(257);
    let hdist = (1..=NUM_DIST).rev().find(|&n| n == 1 || dist_len[n - 1] != 0).unwrap_or(1);
    let mut all = Vec::with_capacity(hlit + hdist);
    all.extend_from_slice(&lit_len[..hlit]);
    all.extend_from_slice(&dist_len[..hdist]);
    let cl_seq = rle_code_lengths(&all);
    let mut cl_freq = [0u32; 19];
    for &(sym, _, _) in &cl_seq {
        cl_freq[sym as usize] += 1;
    }
    force_two(&mut cl_freq);
    let cl_len = build_lengths(&cl_freq, 7);
    let hclen = (4..=19).rev().find(|&n| n == 4 || cl_len[CLCL_ORDER[n - 1]] != 0).unwrap_or(4);
    let mut header_bits = 5 + 5 + 4 + 3 * hclen as u64;
    for &(sym, _, extra) in &cl_seq {
        header_bits += cl_len[sym as usize] as u64 + extra as u64;
    }
    DynHeader { lit_len, dist_len, cl_len, cl_seq, hlit, hdist, hclen, header_bits }
}

fn write_tokens(w: &mut BitWriter, tokens: &[Token], lit_codes: &[u16], lit_len: &[u8], dist_codes: &[u16], dist_len: &[u8]) {
    for t in tokens {
        match *t {
            Token::Literal(b) => {
                w.write_code(lit_codes[b as usize] as u32, lit_len[b as usize] as u32);
            }
            Token::Match { len, dist } => {
                let (ls, lex, leb) = length_to_symbol(len as usize);
                w.write_code(lit_codes[ls as usize] as u32, lit_len[ls as usize] as u32);
                if leb > 0 {
                    w.write_bits(lex, leb as u32);
                }
                let (ds, dex, deb) = dist_to_symbol(dist as usize);
                w.write_code(dist_codes[ds as usize] as u32, dist_len[ds as usize] as u32);
                if deb > 0 {
                    w.write_bits(dex, deb as u32);
                }
            }
        }
    }
    // end of block
    w.write_code(lit_codes[256] as u32, lit_len[256] as u32);
}

fn write_stored(w: &mut BitWriter, data: &[u8], final_chunk: bool) {
    let mut chunks = data.chunks(STORED_MAX).peekable();
    if data.is_empty() {
        // A stored block of zero length is legal and serves as an empty
        // (possibly final) block.
        w.write_bits(final_chunk as u32, 1);
        w.write_bits(0b00, 2);
        w.align_byte();
        w.write_bytes(&0u16.to_le_bytes());
        w.write_bytes(&0xffffu16.to_le_bytes());
        return;
    }
    while let Some(chunk) = chunks.next() {
        let last = chunks.peek().is_none() && final_chunk;
        w.write_bits(last as u32, 1);
        w.write_bits(0b00, 2); // BTYPE=00
        w.align_byte();
        let len = chunk.len() as u16;
        w.write_bytes(&len.to_le_bytes());
        w.write_bytes(&(!len).to_le_bytes());
        w.write_bytes(chunk);
    }
}

/// Compress `data` into a raw DEFLATE stream at the given level (0..=9).
///
/// The LZ77 matcher's hash table and chain buffers are reused through a
/// thread-local (per-element compression calls this at high frequency —
/// the original allocate-per-call cost dominated small-element encodes;
/// see EXPERIMENTS.md §Perf).
pub fn deflate(data: &[u8], level: u8) -> Vec<u8> {
    with_default_matcher(|m| {
        let mut out = Vec::new();
        deflate_into(m, data, level, &mut out);
        out
    })
}

/// Run `f` with this thread's reusable matcher (hash table + chains
/// allocated once per thread).
pub fn with_default_matcher<R>(f: impl FnOnce(&mut Matcher) -> R) -> R {
    thread_local! {
        static MATCHER: std::cell::RefCell<Matcher> =
            std::cell::RefCell::new(Matcher::new(MatchParams::from_level(6)));
    }
    MATCHER.with(|m| f(&mut m.borrow_mut()))
}

/// [`deflate`] with an explicit matcher (no thread-local), for callers
/// that manage reuse themselves.
pub fn deflate_with(matcher: &mut Matcher, data: &[u8], level: u8) -> Vec<u8> {
    let mut out = Vec::new();
    deflate_into(matcher, data, level, &mut out);
    out
}

/// [`deflate`] appending to `out`, reusing both the matcher and the
/// output allocation (the codec pipeline's write-into contract). The
/// matcher's effort is set from `level`; its buffers persist across
/// calls, so per-element encodes pay no setup allocations.
pub fn deflate_into(matcher: &mut Matcher, data: &[u8], level: u8, out: &mut Vec<u8>) {
    matcher.set_params(MatchParams::from_level(level));
    let mut w = BitWriter::with_buffer(std::mem::take(out));
    if level == 0 {
        write_stored(&mut w, data, true);
        *out = w.finish();
        return;
    }
    let ft = fixed_tables();

    if data.is_empty() {
        // Single final fixed block with only end-of-block.
        w.write_bits(1, 1);
        w.write_bits(0b01, 2);
        w.write_code(ft.lit_codes[256] as u32, ft.lit_len[256] as u32);
        *out = w.finish();
        return;
    }

    let mut tokens: Vec<Token> = Vec::new();
    let nseg = data.len().div_ceil(SEGMENT);
    for (si, seg) in data.chunks(SEGMENT).enumerate() {
        let is_final = si + 1 == nseg;
        tokens.clear();
        matcher.tokenize(seg, |t| tokens.push(t));
        let stats = analyze_tokens(&tokens);
        let mut lit_freq = stats.lit;
        let mut dist_freq = stats.dist;
        let dh = build_dynamic_header(&mut lit_freq, &mut dist_freq);
        // Costs from the (pre-force_two) histograms: one pass over the
        // token stream covers both candidate codes.
        let dyn_bits = dh.header_bits
            + code_bits(&stats.lit, &dh.lit_len)
            + code_bits(&stats.dist, &dh.dist_len)
            + stats.extra_bits;
        let fixed_bits =
            code_bits(&stats.lit, &ft.lit_len) + code_bits(&stats.dist, &ft.dist_len) + stats.extra_bits;
        // Stored cost: 3 bits + align (<=7) + 32 bit LEN/NLEN per 64 KiB + bytes.
        let stored_bits = (seg.len() as u64) * 8 + 40 * seg.len().div_ceil(STORED_MAX).max(1) as u64;

        if stored_bits < dyn_bits.min(fixed_bits) {
            write_stored(&mut w, seg, is_final);
        } else if fixed_bits <= dyn_bits {
            w.write_bits(is_final as u32, 1);
            w.write_bits(0b01, 2);
            write_tokens(&mut w, &tokens, &ft.lit_codes, &ft.lit_len, &ft.dist_codes, &ft.dist_len);
        } else {
            w.write_bits(is_final as u32, 1);
            w.write_bits(0b10, 2);
            w.write_bits((dh.hlit - 257) as u32, 5);
            w.write_bits((dh.hdist - 1) as u32, 5);
            w.write_bits((dh.hclen - 4) as u32, 4);
            for i in 0..dh.hclen {
                w.write_bits(dh.cl_len[CLCL_ORDER[i]] as u32, 3);
            }
            let cl_codes = lengths_to_codes(&dh.cl_len).expect("cl code");
            for &(sym, extra_val, extra_bits) in &dh.cl_seq {
                w.write_code(cl_codes[sym as usize] as u32, dh.cl_len[sym as usize] as u32);
                if extra_bits > 0 {
                    w.write_bits(extra_val, extra_bits as u32);
                }
            }
            let lit_codes = lengths_to_codes(&dh.lit_len).expect("lit code");
            let dist_codes = lengths_to_codes(&dh.dist_len).expect("dist code");
            write_tokens(&mut w, &tokens, &lit_codes, &dh.lit_len, &dist_codes, &dh.dist_len);
        }
    }
    *out = w.finish();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn length_symbol_table() {
        assert_eq!(length_to_symbol(3), (257, 0, 0));
        assert_eq!(length_to_symbol(4), (258, 0, 0));
        assert_eq!(length_to_symbol(10), (264, 0, 0));
        assert_eq!(length_to_symbol(11), (265, 0, 1));
        assert_eq!(length_to_symbol(12), (265, 1, 1));
        assert_eq!(length_to_symbol(257), (284, 30, 5));
        assert_eq!(length_to_symbol(258), (285, 0, 0));
    }

    #[test]
    fn dist_symbol_table() {
        assert_eq!(dist_to_symbol(1), (0, 0, 0));
        assert_eq!(dist_to_symbol(4), (3, 0, 0));
        assert_eq!(dist_to_symbol(5), (4, 0, 1));
        assert_eq!(dist_to_symbol(6), (4, 1, 1));
        assert_eq!(dist_to_symbol(24577), (29, 0, 13));
        assert_eq!(dist_to_symbol(32768), (29, 8191, 13));
    }

    #[test]
    fn dist_lut_matches_table_scan_everywhere() {
        for dist in 1usize..=32768 {
            let (sym, extra_val, extra_bits) = dist_to_symbol(dist);
            let scan = dist_sym_scan(dist) as u16;
            assert_eq!(sym, scan, "dist {dist}");
            let (base, eb) = DIST_TABLE[sym as usize];
            assert_eq!(extra_bits, eb, "dist {dist}");
            assert_eq!(extra_val as usize, dist - base as usize, "dist {dist}");
            // Within the symbol's extra-bit range.
            assert!(extra_val < (1u32 << eb.max(1)) || eb == 0 && extra_val == 0, "dist {dist}");
        }
    }

    #[test]
    fn analyze_matches_two_pass_costs() {
        // The fused single-pass stats must reproduce the old two-pass
        // (count + cost) bit accounting for both candidate codes.
        let data = b"fused histogram and bit-cost accounting ".repeat(50);
        let mut m = Matcher::new(MatchParams::from_level(9));
        let mut tokens = Vec::new();
        m.tokenize(&data, |t| tokens.push(t));
        let stats = analyze_tokens(&tokens);
        let ft = fixed_tables();
        // Reference: walk the tokens again.
        let mut bits = 0u64;
        for t in &tokens {
            match *t {
                Token::Literal(b) => bits += ft.lit_len[b as usize] as u64,
                Token::Match { len, dist } => {
                    let (ls, _, le) = length_to_symbol(len as usize);
                    let (ds, _, de) = dist_to_symbol(dist as usize);
                    bits += ft.lit_len[ls as usize] as u64 + le as u64;
                    bits += ft.dist_len[ds as usize] as u64 + de as u64;
                }
            }
        }
        bits += ft.lit_len[256] as u64;
        assert_eq!(code_bits(&stats.lit, &ft.lit_len) + code_bits(&stats.dist, &ft.dist_len) + stats.extra_bits, bits);
        let total: u32 = stats.lit.iter().chain(stats.dist.iter()).sum();
        assert_eq!(total as usize, tokens.len() + 1 + tokens.iter().filter(|t| matches!(t, Token::Match { .. })).count());
    }

    #[test]
    fn rle_examples() {
        // 4 zeros -> one 17 with extra 1.
        assert_eq!(rle_code_lengths(&[0, 0, 0, 0]), vec![(17, 1, 3)]);
        // 2 zeros -> two literal zeros.
        assert_eq!(rle_code_lengths(&[0, 0]), vec![(0, 0, 0), (0, 0, 0)]);
        // value + 4 repeats -> value, 16(x3), value... no: 5 total = v + rep 4 -> (16,1,2) covers 4.
        assert_eq!(rle_code_lengths(&[5, 5, 5, 5, 5]), vec![(5, 0, 0), (16, 1, 2)]);
        // 139 zeros -> 18(138) + 0.
        let v = vec![0u8; 139];
        assert_eq!(rle_code_lengths(&v), vec![(18, 127, 7), (0, 0, 0)]);
        // long nonzero run: 1 + 6 + 6 ... values
        assert_eq!(rle_code_lengths(&[7; 14]), vec![(7, 0, 0), (16, 3, 2), (16, 3, 2), (7, 0, 0)]);
    }

    // Full roundtrip tests live next to the inflater in inflate.rs and in
    // the zlib module; conformance against miniz/CPython is exercised by
    // rust/tests/compression_conformance.rs and python interop tests.
}
