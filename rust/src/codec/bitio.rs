//! LSB-first bit I/O as used by DEFLATE (RFC 1951 §3.1.1): data elements
//! are packed starting from the least-significant bit of each byte; Huffman
//! codes are packed most-significant-bit first (i.e. bit-reversed before
//! writing through this LSB-first writer).

use crate::error::{corrupt, Result, ScdaError};

/// Bit-level writer accumulating into a byte vector.
#[derive(Debug, Default)]
pub struct BitWriter {
    out: Vec<u8>,
    bitbuf: u64,
    bitcount: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// A writer that appends to `buf` (which may already hold bytes, e.g.
    /// a zlib header), reusing its allocation. Part of the codec layer's
    /// write-into contract: `finish` hands the same buffer back.
    pub fn with_buffer(buf: Vec<u8>) -> Self {
        BitWriter { out: buf, bitbuf: 0, bitcount: 0 }
    }

    /// Write the low `n` bits of `value`, LSB first. `n <= 57` per call.
    #[inline]
    pub fn write_bits(&mut self, value: u32, n: u32) {
        debug_assert!(n <= 32);
        debug_assert!(n == 32 || value < (1u32 << n));
        self.bitbuf |= (value as u64) << self.bitcount;
        self.bitcount += n;
        while self.bitcount >= 8 {
            self.out.push((self.bitbuf & 0xff) as u8);
            self.bitbuf >>= 8;
            self.bitcount -= 8;
        }
    }

    /// Write a Huffman code of `len` bits: DEFLATE packs codes MSB-first,
    /// so the canonical code is bit-reversed into the LSB-first stream.
    #[inline]
    pub fn write_code(&mut self, code: u32, len: u32) {
        self.write_bits(reverse_bits(code, len), len);
    }

    /// Pad with zero bits to the next byte boundary.
    pub fn align_byte(&mut self) {
        if self.bitcount > 0 {
            self.out.push((self.bitbuf & 0xff) as u8);
            self.bitbuf = 0;
            self.bitcount = 0;
        }
    }

    /// Append raw bytes; the stream must be byte-aligned.
    pub fn write_bytes(&mut self, bytes: &[u8]) {
        debug_assert_eq!(self.bitcount, 0, "write_bytes requires byte alignment");
        self.out.extend_from_slice(bytes);
    }

    pub fn finish(mut self) -> Vec<u8> {
        self.align_byte();
        self.out
    }

    pub fn len_bytes(&self) -> usize {
        self.out.len() + if self.bitcount > 0 { 1 } else { 0 }
    }
}

/// Reverse the low `n` bits of `v`.
#[inline]
pub fn reverse_bits(v: u32, n: u32) -> u32 {
    v.reverse_bits() >> (32 - n)
}

/// Bit-level reader over a byte slice.
#[derive(Debug)]
pub struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
    bitbuf: u64,
    bitcount: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        BitReader { data, pos: 0, bitbuf: 0, bitcount: 0 }
    }

    /// Top up the 64-bit reservoir. The steady state is one unaligned
    /// 8-byte load shifted into place (filling at least 32 bits whenever
    /// the buffer was at most half full); the byte-at-a-time loop only
    /// runs within the final 7 bytes of the stream.
    #[inline]
    fn refill(&mut self) {
        if self.pos + 8 <= self.data.len() {
            let word = u64::from_le_bytes(self.data[self.pos..self.pos + 8].try_into().unwrap());
            self.bitbuf |= word << self.bitcount;
            // Whole bytes that fit in the 64-bit buffer above bitcount.
            let take = (63 - self.bitcount) >> 3;
            self.pos += take as usize;
            self.bitcount += take * 8;
            return;
        }
        while self.bitcount <= 56 && self.pos < self.data.len() {
            self.bitbuf |= (self.data[self.pos] as u64) << self.bitcount;
            self.pos += 1;
            self.bitcount += 8;
        }
    }

    /// Read `n` bits LSB-first. Fails at end of input.
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Result<u32> {
        debug_assert!(n <= 32);
        if self.bitcount < n {
            self.refill();
            if self.bitcount < n {
                return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "deflate stream ends mid-symbol"));
            }
        }
        let mask = if n == 32 { u64::MAX >> 32 } else { (1u64 << n) - 1 };
        let v = (self.bitbuf & mask) as u32;
        self.bitbuf >>= n;
        self.bitcount -= n;
        Ok(v)
    }

    /// Peek up to `n` bits without consuming (may return fewer near EOF;
    /// missing high bits read as zero).
    #[inline]
    pub fn peek_bits(&mut self, n: u32) -> u32 {
        if self.bitcount < n {
            self.refill();
        }
        let mask = if n >= 32 { u64::MAX >> 32 } else { (1u64 << n) - 1 };
        (self.bitbuf & mask) as u32
    }

    /// Consume `n` bits previously peeked (must be available).
    #[inline]
    pub fn consume(&mut self, n: u32) -> Result<()> {
        if self.bitcount < n {
            return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "deflate stream ends mid-symbol"));
        }
        self.bitbuf >>= n;
        self.bitcount -= n;
        Ok(())
    }

    /// Number of whole bits still available (including unread bytes).
    pub fn bits_remaining(&self) -> usize {
        self.bitcount as usize + 8 * (self.data.len() - self.pos)
    }

    /// Discard bits to the next byte boundary and return the byte offset
    /// into the underlying slice.
    pub fn align_byte(&mut self) -> usize {
        let drop = self.bitcount % 8;
        self.bitbuf >>= drop;
        self.bitcount -= drop;
        // Bytes buffered but unconsumed:
        let buffered = (self.bitcount / 8) as usize;
        self.pos - buffered
    }

    /// Read `len` raw bytes after aligning to a byte boundary.
    pub fn read_aligned_bytes(&mut self, len: usize) -> Result<&'a [u8]> {
        let start = self.align_byte();
        if start + len > self.data.len() {
            return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "stored block overruns stream"));
        }
        // Reset buffering to read from `start`.
        self.pos = start + len;
        self.bitbuf = 0;
        self.bitcount = 0;
        Ok(&self.data[start..start + len])
    }

    /// Byte offset of the next unconsumed bit's byte (after alignment).
    pub fn byte_position(&mut self) -> usize {
        self.align_byte()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_bits() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bits(0xffff, 16);
        w.write_bits(0, 1);
        w.write_bits(0b1100_1010, 8);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(3).unwrap(), 0b101);
        assert_eq!(r.read_bits(16).unwrap(), 0xffff);
        assert_eq!(r.read_bits(1).unwrap(), 0);
        assert_eq!(r.read_bits(8).unwrap(), 0b1100_1010);
        assert!(r.read_bits(8).is_err());
    }

    #[test]
    fn reverse_bits_examples() {
        assert_eq!(reverse_bits(0b1, 1), 0b1);
        assert_eq!(reverse_bits(0b100, 3), 0b001);
        assert_eq!(reverse_bits(0b0111, 4), 0b1110);
        for n in 1..=16u32 {
            for v in [0u32, 1, 3, (1 << n) - 1] {
                if v < (1 << n) {
                    assert_eq!(reverse_bits(reverse_bits(v, n), n), v);
                }
            }
        }
    }

    #[test]
    fn aligned_byte_reads() {
        let mut w = BitWriter::new();
        w.write_bits(0b11, 2);
        w.align_byte();
        w.write_bytes(b"abc");
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(2).unwrap(), 0b11);
        assert_eq!(r.read_aligned_bytes(3).unwrap(), b"abc");
        assert_eq!(r.bits_remaining(), 0);
    }

    #[test]
    fn peek_and_consume() {
        let mut w = BitWriter::new();
        w.write_bits(0xabcd, 16);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.peek_bits(4), 0xd);
        assert_eq!(r.peek_bits(16), 0xabcd);
        r.consume(4).unwrap();
        assert_eq!(r.read_bits(12).unwrap(), 0xabc);
    }

    #[test]
    fn peek_past_eof_zero_fills() {
        let bytes = [0xffu8];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.peek_bits(16), 0x00ff);
        assert_eq!(r.read_bits(8).unwrap(), 0xff);
        assert!(r.read_bits(1).is_err());
    }
}
