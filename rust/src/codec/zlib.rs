//! zlib stream format (RFC 1950): 2-byte header, DEFLATE body, Adler-32
//! trailer. §3.1 of the paper requires exactly this framing ("an RFC
//! 1950/1951 deflate stream using any legal compression level") and names
//! the Adler-32 as one of the three redundant read-side checks.

use crate::codec::adler32::adler32;
use crate::codec::deflate::{deflate_into, with_default_matcher};
use crate::codec::inflate::inflate_into;
use crate::codec::lz77::Matcher;
use crate::error::{corrupt, Result, ScdaError};

/// Compress `data` into a zlib stream (the paper recommends zlib's best
/// compression; our default level is 9 accordingly).
pub fn zlib_compress(data: &[u8], level: u8) -> Vec<u8> {
    with_default_matcher(|m| {
        let mut out = Vec::with_capacity(data.len() / 2 + 64);
        zlib_compress_into(data, level, m, &mut out);
        out
    })
}

/// [`zlib_compress`] appending to `out` with an explicit matcher — the
/// per-worker write-into path of the codec pipeline: no allocation beyond
/// growing `out`, and the header/body/trailer stream directly into it.
pub fn zlib_compress_into(data: &[u8], level: u8, matcher: &mut Matcher, out: &mut Vec<u8>) {
    // CMF: CM=8 (deflate), CINFO=7 (32K window) -> 0x78.
    let cmf: u8 = 0x78;
    // FLG: FLEVEL per level, FDICT=0, FCHECK makes (CMF<<8 | FLG) % 31 == 0.
    let flevel: u8 = match level {
        0..=1 => 0,
        2..=5 => 1,
        6..=7 => 2,
        _ => 3,
    };
    let mut flg: u8 = flevel << 6;
    let rem = ((cmf as u16) << 8 | flg as u16) % 31;
    if rem != 0 {
        flg += (31 - rem) as u8;
    }
    out.push(cmf);
    out.push(flg);
    deflate_into(matcher, data, level, out);
    out.extend_from_slice(&adler32(data).to_be_bytes());
}

/// Decompress a zlib stream, verifying header consistency and the Adler-32
/// trailer. `expected_size` bounds and verifies the output when known.
pub fn zlib_decompress(data: &[u8], expected_size: Option<usize>) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    zlib_decompress_into(data, expected_size, &mut out)?;
    Ok(out)
}

/// [`zlib_decompress`] appending to `out`; returns the number of bytes
/// appended. `out` may already hold earlier elements (the pipeline's
/// chunk buffers) — back-references and the Adler-32 are confined to this
/// stream's own bytes, and on error `out`'s length is restored.
pub fn zlib_decompress_into(data: &[u8], expected_size: Option<usize>, out: &mut Vec<u8>) -> Result<usize> {
    let restore = out.len();
    let r = zlib_decompress_into_inner(data, expected_size, out);
    if r.is_err() {
        out.truncate(restore);
    }
    r
}

fn zlib_decompress_into_inner(data: &[u8], expected_size: Option<usize>, out: &mut Vec<u8>) -> Result<usize> {
    if data.len() < 6 {
        return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "zlib stream shorter than minimal framing"));
    }
    let cmf = data[0];
    let flg = data[1];
    if cmf & 0x0f != 8 {
        return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "zlib CM is not deflate"));
    }
    if (cmf >> 4) > 7 {
        return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "zlib CINFO window exceeds 32K"));
    }
    if ((cmf as u16) << 8 | flg as u16) % 31 != 0 {
        return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "zlib header check bits invalid"));
    }
    if flg & 0x20 != 0 {
        return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "zlib preset dictionary unsupported"));
    }
    let start = out.len();
    let consumed = inflate_into(&data[2..], expected_size, out)?;
    let trailer_at = 2 + consumed;
    if trailer_at + 4 > data.len() {
        return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "zlib stream missing Adler-32 trailer"));
    }
    let stored = u32::from_be_bytes(data[trailer_at..trailer_at + 4].try_into().unwrap());
    let actual = adler32(&out[start..]);
    if stored != actual {
        return Err(ScdaError::corrupt(
            corrupt::BAD_CHECKSUM,
            format!("Adler-32 mismatch: stored {stored:#010x}, computed {actual:#010x}"),
        ));
    }
    Ok(out.len() - start)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_levels() {
        let data = b"serial-equivalent parallel I/O ".repeat(500);
        for level in [0u8, 1, 6, 9] {
            let z = zlib_compress(&data, level);
            // Header check bits valid by construction.
            assert_eq!(((z[0] as u16) << 8 | z[1] as u16) % 31, 0);
            assert_eq!(z[0], 0x78);
            assert_eq!(zlib_decompress(&z, Some(data.len())).unwrap(), data);
            assert_eq!(zlib_decompress(&z, None).unwrap(), data);
        }
    }

    #[test]
    fn adler_mismatch_detected() {
        let data = b"check the checksum";
        let mut z = zlib_compress(data, 9);
        let n = z.len();
        z[n - 1] ^= 0x01;
        let err = zlib_decompress(&z, Some(data.len())).unwrap_err();
        assert_eq!(err.code(), 1000 + corrupt::BAD_CHECKSUM);
    }

    #[test]
    fn header_corruption_detected() {
        let data = b"xyz";
        let z = zlib_compress(data, 9);
        let mut bad = z.clone();
        bad[0] = 0x79; // CM=9
        assert!(zlib_decompress(&bad, None).is_err());
        let mut bad = z.clone();
        bad[1] ^= 0x1f; // break FCHECK
        assert!(zlib_decompress(&bad, None).is_err());
        let mut bad = z;
        bad[1] |= 0x20; // FDICT
        assert!(zlib_decompress(&bad, None).is_err());
    }

    #[test]
    fn short_input_rejected() {
        assert!(zlib_decompress(&[], None).is_err());
        assert!(zlib_decompress(&[0x78, 0x9c, 0x03], None).is_err());
    }

    #[test]
    #[cfg(feature = "conformance")]
    fn matches_flate2_both_directions() {
        // Our compressor -> flate2 decompressor and vice versa. This is the
        // in-process conformance oracle; CPython's zlib is exercised by the
        // interop integration tests.
        use std::io::{Read, Write};
        let data: Vec<u8> = (0..100_000u32).map(|i| (i.wrapping_mul(i) >> 3) as u8).collect();
        for level in [0u8, 6, 9] {
            let ours = zlib_compress(&data, level);
            let mut d = flate2::read::ZlibDecoder::new(&ours[..]);
            let mut out = Vec::new();
            d.read_to_end(&mut out).expect("flate2 must accept our zlib stream");
            assert_eq!(out, data);
        }
        let mut e = flate2::write::ZlibEncoder::new(Vec::new(), flate2::Compression::best());
        e.write_all(&data).unwrap();
        let theirs = e.finish().unwrap();
        assert_eq!(zlib_decompress(&theirs, Some(data.len())).unwrap(), data);
    }
}
