//! Canonical Huffman codes for DEFLATE (RFC 1951 §3.2.2).
//!
//! * [`lengths_to_codes`] — assign canonical codes from code lengths, the
//!   procedure printed verbatim in the RFC.
//! * [`build_lengths`] — length-limited Huffman code construction from
//!   symbol frequencies via the package-merge algorithm (optimal under the
//!   15-bit DEFLATE limit).
//! * [`HuffDecoder`] — table-driven decoder: a two-level lookup table (a
//!   [`ROOT_BITS`]-bit root plus per-prefix overflow subtables) resolving
//!   every symbol in at most two indexed loads, never a scan.
//! * [`BitwiseDecoder`] — the one-bit-at-a-time canonical decoder, kept as
//!   the reference the LUT decoder is differentially tested against.

use crate::error::{corrupt, Result, ScdaError};
use crate::codec::bitio::{reverse_bits, BitReader};

/// Maximum code length in DEFLATE.
pub const MAX_BITS: usize = 15;

/// Assign canonical codes to `lengths` (0 = symbol unused). Returns codes
/// aligned with `lengths` (MSB-first values as in the RFC; writers must
/// bit-reverse, which [`crate::codec::bitio::BitWriter::write_code`] does).
pub fn lengths_to_codes(lengths: &[u8]) -> Result<Vec<u16>> {
    let mut bl_count = [0u32; MAX_BITS + 1];
    for &l in lengths {
        if l as usize > MAX_BITS {
            return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "code length exceeds 15"));
        }
        bl_count[l as usize] += 1;
    }
    bl_count[0] = 0;
    let mut next_code = [0u32; MAX_BITS + 2];
    let mut code = 0u32;
    for bits in 1..=MAX_BITS {
        code = (code + bl_count[bits - 1]) << 1;
        next_code[bits] = code;
    }
    // Over-subscribed codes would overflow the code space; detect.
    let mut kraft: u64 = 0;
    for &l in lengths {
        if l > 0 {
            kraft += 1u64 << (MAX_BITS - l as usize);
        }
    }
    if kraft > 1 << MAX_BITS {
        return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "over-subscribed Huffman code"));
    }
    let mut codes = vec![0u16; lengths.len()];
    for (i, &l) in lengths.iter().enumerate() {
        if l > 0 {
            codes[i] = next_code[l as usize] as u16;
            next_code[l as usize] += 1;
        }
    }
    Ok(codes)
}

/// Build optimal length-limited code lengths for the given frequencies,
/// capped at `limit` bits. Symbols with zero frequency get length 0. If
/// fewer than two symbols occur, the single present symbol is assigned
/// length 1 (DEFLATE requires at least one bit per code).
///
/// Fast path: plain array-based Huffman (two-queue construction, no
/// allocations beyond three scratch vectors). Only when the resulting
/// depth exceeds `limit` — rare outside adversarial frequency skews —
/// does the optimal package-merge fallback run.
pub fn build_lengths(freqs: &[u32], limit: usize) -> Vec<u8> {
    if let Some(lengths) = huffman_lengths_fast(freqs, limit) {
        return lengths;
    }
    build_lengths_package_merge(freqs, limit)
}

/// Two-queue Huffman over the used symbols; `None` if any code length
/// would exceed `limit`.
fn huffman_lengths_fast(freqs: &[u32], limit: usize) -> Option<Vec<u8>> {
    let n = freqs.len();
    let mut used: Vec<u32> = (0..n as u32).filter(|&i| freqs[i as usize] > 0).collect();
    let mut lengths = vec![0u8; n];
    match used.len() {
        0 => return Some(lengths),
        1 => {
            lengths[used[0] as usize] = 1;
            return Some(lengths);
        }
        _ => {}
    }
    used.sort_unstable_by_key(|&i| freqs[i as usize]);
    let m = used.len();
    // Nodes: 0..m leaves (sorted), m.. internal. parent[] links upward.
    let total_nodes = 2 * m - 1;
    let mut weight: Vec<u64> = used.iter().map(|&i| freqs[i as usize] as u64).collect();
    weight.resize(total_nodes, 0);
    let mut parent = vec![0u32; total_nodes];
    let (mut leaf_at, mut node_at) = (0usize, m);
    let mut next = m;
    while next < total_nodes {
        // Pick the two smallest among remaining leaves and internal nodes.
        let pick = |leaf_at: &mut usize, node_at: &mut usize| -> usize {
            if *leaf_at < m && (*node_at >= next || weight[*leaf_at] <= weight[*node_at]) {
                *leaf_at += 1;
                *leaf_at - 1
            } else {
                *node_at += 1;
                *node_at - 1
            }
        };
        let a = pick(&mut leaf_at, &mut node_at);
        let b = pick(&mut leaf_at, &mut node_at);
        weight[next] = weight[a] + weight[b];
        parent[a] = next as u32;
        parent[b] = next as u32;
        next += 1;
    }
    // Depths: root (last node) has depth 0; walk down in reverse order.
    let mut depth = vec![0u8; total_nodes];
    for i in (0..total_nodes - 1).rev() {
        depth[i] = depth[parent[i] as usize] + 1;
        if i < m && depth[i] as usize > limit {
            return None;
        }
    }
    for (j, &sym) in used.iter().enumerate() {
        lengths[sym as usize] = depth[j];
    }
    Some(lengths)
}

/// Optimal length-limited construction (package-merge), used as the
/// fallback when the unconstrained tree exceeds the depth limit.
fn build_lengths_package_merge(freqs: &[u32], limit: usize) -> Vec<u8> {
    debug_assert!(limit <= MAX_BITS);
    let n = freqs.len();
    let mut used: Vec<usize> = (0..n).filter(|&i| freqs[i] > 0).collect();
    // Package-merge requires the leaf list sorted by weight.
    used.sort_by_key(|&i| freqs[i]);
    let mut lengths = vec![0u8; n];
    match used.len() {
        0 => return lengths,
        1 => {
            lengths[used[0]] = 1;
            return lengths;
        }
        _ => {}
    }
    // Package-merge over the used symbols.
    // items: (weight, set of leaf indices) — we track leaf multiplicity via
    // counting how many times each leaf appears among chosen packages.
    #[derive(Clone)]
    struct Node {
        weight: u64,
        leaves: Vec<u32>, // indices into `used`
    }
    let leaves: Vec<Node> = used
        .iter()
        .enumerate()
        .map(|(j, &i)| Node { weight: freqs[i] as u64, leaves: vec![j as u32] })
        .collect();
    let mut prev: Vec<Node> = Vec::new();
    for _level in 0..limit {
        // merge leaves with packaged pairs from prev, sorted by weight
        let mut merged: Vec<Node> = Vec::with_capacity(leaves.len() + prev.len() / 2);
        let mut pairs = prev.chunks_exact(2).map(|p| {
            let mut l = p[0].leaves.clone();
            l.extend_from_slice(&p[1].leaves);
            Node { weight: p[0].weight + p[1].weight, leaves: l }
        });
        let mut li = leaves.iter();
        let (mut a, mut b) = (li.next(), pairs.next());
        loop {
            match (a, b.as_ref()) {
                (Some(x), Some(y)) => {
                    if x.weight <= y.weight {
                        merged.push(x.clone());
                        a = li.next();
                    } else {
                        merged.push(b.take().unwrap());
                        b = pairs.next();
                    }
                }
                (Some(x), None) => {
                    merged.push(x.clone());
                    a = li.next();
                }
                (None, Some(_)) => {
                    merged.push(b.take().unwrap());
                    b = pairs.next();
                }
                (None, None) => break,
            }
        }
        prev = merged;
    }
    // Take the first 2*(m-1) items; each leaf occurrence increments length.
    let m = used.len();
    let mut lens = vec![0u32; m];
    for node in prev.iter().take(2 * (m - 1)) {
        for &j in &node.leaves {
            lens[j as usize] += 1;
        }
    }
    for (j, &i) in used.iter().enumerate() {
        debug_assert!(lens[j] >= 1 && lens[j] as usize <= limit);
        lengths[i] = lens[j] as u8;
    }
    lengths
}

/// Root table width of the two-level decoder. 9 bits covers every code
/// of the DEFLATE fixed tables and the vast majority of dynamic codes in
/// one lookup; longer codes take exactly one more.
pub const ROOT_BITS: u32 = 9;

/// Entry packing of the decode table (`u32`):
/// bits 0..=15  — symbol (direct) or subtable base index (indirect),
/// bits 16..=20 — code length in bits (direct) or subtable width (indirect),
/// bit 31       — indirect flag. Zero is "invalid code".
const SUBTABLE_FLAG: u32 = 1 << 31;

#[inline]
fn pack(len: u32, payload: u32) -> u32 {
    debug_assert!(len <= 31 && payload <= 0xFFFF);
    (len << 16) | payload
}

/// Table-driven canonical Huffman decoder: a `1 << ROOT_BITS` root table
/// with per-prefix overflow subtables appended to the same vector, so
/// decoding is one load for codes of at most [`ROOT_BITS`] bits and two
/// loads otherwise — a symbol per lookup, never a linear scan.
pub struct HuffDecoder {
    table: Vec<u32>,
}

impl HuffDecoder {
    /// Build a decoder from code lengths.
    pub fn new(lengths: &[u8]) -> Result<Self> {
        let codes = lengths_to_codes(lengths)?;
        let root = 1usize << ROOT_BITS;
        let mut table = vec![0u32; root];
        // Pass 1: direct entries, and the widest overflow length under
        // each root prefix (the subtable's index width).
        let mut sub_max = std::collections::BTreeMap::<u32, u32>::new();
        for (sym, (&len, &code)) in lengths.iter().zip(codes.iter()).enumerate() {
            if len == 0 {
                continue;
            }
            let len = len as u32;
            let rev = reverse_bits(code as u32, len);
            if len <= ROOT_BITS {
                // Fill all root slots whose low `len` bits equal `rev`.
                let step = 1u32 << len;
                let mut idx = rev;
                while (idx as usize) < root {
                    table[idx as usize] = pack(len, sym as u32);
                    idx += step;
                }
            } else {
                let prefix = rev & (root as u32 - 1);
                let e = sub_max.entry(prefix).or_insert(0);
                *e = (*e).max(len - ROOT_BITS);
            }
        }
        // Pass 2: allocate one subtable per overflow prefix.
        for (&prefix, &bits) in &sub_max {
            let base = table.len() as u32;
            debug_assert!(base <= 0xFFFF, "decode table exceeds 16-bit base indexing");
            table[prefix as usize] = SUBTABLE_FLAG | pack(bits, base);
            table.resize(table.len() + (1usize << bits), 0);
        }
        // Pass 3: fill overflow entries.
        for (sym, (&len, &code)) in lengths.iter().zip(codes.iter()).enumerate() {
            let len = len as u32;
            if len <= ROOT_BITS {
                continue;
            }
            let rev = reverse_bits(code as u32, len);
            let prefix = rev & (root as u32 - 1);
            let bits = sub_max[&prefix];
            let base = (table[prefix as usize] & 0xFFFF) as usize;
            let high = rev >> ROOT_BITS; // the code's len - ROOT_BITS tail bits
            let step = 1u32 << (len - ROOT_BITS);
            let mut idx = high;
            while idx < (1u32 << bits) {
                table[base + idx as usize] = pack(len, sym as u32);
                idx += step;
            }
        }
        Ok(HuffDecoder { table })
    }

    /// Decode one symbol from the reader.
    #[inline]
    pub fn decode(&self, r: &mut BitReader<'_>) -> Result<u16> {
        let peek = r.peek_bits(ROOT_BITS);
        let mut e = self.table[peek as usize];
        if e & SUBTABLE_FLAG != 0 {
            let bits = (e >> 16) & 0x1F;
            let base = (e & 0xFFFF) as usize;
            let idx = (r.peek_bits(ROOT_BITS + bits) >> ROOT_BITS) as usize;
            e = self.table[base + idx];
        }
        let len = e >> 16;
        if len == 0 {
            return Err(ScdaError::corrupt(
                corrupt::BAD_ZLIB,
                "invalid Huffman code in deflate stream",
            ));
        }
        r.consume(len)?;
        Ok((e & 0xFFFF) as u16)
    }
}

/// The pre-LUT reference decoder: canonical decode one bit at a time
/// using per-length code ranges (RFC 1951's textbook procedure). Kept so
/// the LUT decoder has an independently-derived implementation to be
/// differentially tested against; not used on any hot path.
pub struct BitwiseDecoder {
    /// `first_code[l]` — canonical (MSB-first) code value of the first
    /// code of length `l`; `first_sym[l]` — its index into `syms`.
    first_code: [u32; MAX_BITS + 1],
    first_sym: [u32; MAX_BITS + 1],
    count: [u32; MAX_BITS + 1],
    /// Symbols ordered by (length, code) — canonical order.
    syms: Vec<u16>,
    max_len: u32,
}

impl BitwiseDecoder {
    pub fn new(lengths: &[u8]) -> Result<Self> {
        let codes = lengths_to_codes(lengths)?; // validates over-subscription
        let mut count = [0u32; MAX_BITS + 1];
        for &l in lengths {
            count[l as usize] += 1;
        }
        count[0] = 0;
        let mut order: Vec<u16> = (0..lengths.len() as u16)
            .filter(|&s| lengths[s as usize] > 0)
            .collect();
        order.sort_by_key(|&s| (lengths[s as usize], codes[s as usize]));
        let mut first_code = [0u32; MAX_BITS + 1];
        let mut first_sym = [0u32; MAX_BITS + 1];
        let mut max_len = 0u32;
        let mut at = 0u32;
        for l in 1..=MAX_BITS {
            first_sym[l] = at;
            if count[l] > 0 {
                // Canonical: the first code of each length is what
                // lengths_to_codes assigned to the first symbol of it.
                first_code[l] = codes[order[at as usize] as usize] as u32;
                max_len = l as u32;
            }
            at += count[l];
        }
        Ok(BitwiseDecoder { first_code, first_sym, count, syms: order, max_len })
    }

    /// Decode one symbol, reading a single bit per iteration.
    pub fn decode(&self, r: &mut BitReader<'_>) -> Result<u16> {
        let mut code = 0u32;
        for len in 1..=self.max_len {
            code = (code << 1) | r.read_bits(1)?;
            let l = len as usize;
            if self.count[l] > 0
                && code >= self.first_code[l]
                && code - self.first_code[l] < self.count[l]
            {
                return Ok(self.syms[(self.first_sym[l] + code - self.first_code[l]) as usize]);
            }
        }
        Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "invalid Huffman code in deflate stream"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::bitio::BitWriter;

    #[test]
    fn rfc_example_codes() {
        // RFC 1951 §3.2.2 example: lengths (3,3,3,3,3,2,4,4) ->
        // codes 010,011,100,101,110,00,1110,1111.
        let lengths = [3u8, 3, 3, 3, 3, 2, 4, 4];
        let codes = lengths_to_codes(&lengths).unwrap();
        assert_eq!(codes, vec![0b010, 0b011, 0b100, 0b101, 0b110, 0b00, 0b1110, 0b1111]);
    }

    #[test]
    fn build_lengths_simple() {
        // Highly skewed frequencies yield shorter codes for frequent syms.
        let freqs = [100u32, 10, 10, 1];
        let lens = build_lengths(&freqs, 15);
        assert!(lens[0] <= lens[1] && lens[1] <= lens[3]);
        // Kraft equality for an optimal complete code.
        let kraft: f64 = lens.iter().filter(|&&l| l > 0).map(|&l| 2f64.powi(-(l as i32))).sum();
        assert!((kraft - 1.0).abs() < 1e-9);
    }

    #[test]
    fn build_lengths_respects_limit() {
        // Fibonacci-like frequencies force long codes without a limit.
        let mut freqs = vec![0u32; 20];
        let (mut a, mut b) = (1u32, 1u32);
        for f in freqs.iter_mut() {
            *f = a;
            let c = a + b;
            a = b;
            b = c;
        }
        for limit in [7usize, 9, 15] {
            let lens = build_lengths(&freqs, limit);
            assert!(lens.iter().all(|&l| (l as usize) <= limit));
            let kraft: f64 = lens.iter().filter(|&&l| l > 0).map(|&l| 2f64.powi(-(l as i32))).sum();
            assert!(kraft <= 1.0 + 1e-9, "limit={limit} kraft={kraft}");
            lengths_to_codes(&lens).unwrap();
        }
    }

    #[test]
    fn single_symbol_gets_one_bit() {
        let mut freqs = vec![0u32; 10];
        freqs[7] = 42;
        let lens = build_lengths(&freqs, 15);
        assert_eq!(lens[7], 1);
        assert_eq!(lens.iter().filter(|&&l| l > 0).count(), 1);
    }

    #[test]
    fn decoder_roundtrips_all_symbols() {
        let lengths = [3u8, 3, 3, 3, 3, 2, 4, 4];
        let codes = lengths_to_codes(&lengths).unwrap();
        let dec = HuffDecoder::new(&lengths).unwrap();
        let mut w = BitWriter::new();
        let syms: Vec<u16> = (0..8).chain((0..8).rev()).collect();
        for &s in &syms {
            w.write_code(codes[s as usize] as u32, lengths[s as usize] as u32);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &s in &syms {
            assert_eq!(dec.decode(&mut r).unwrap(), s);
        }
    }

    #[test]
    fn decoder_handles_long_codes() {
        // Create codes longer than PEEK_BITS: 600 symbols, near-uniform.
        let freqs = vec![1u32; 600];
        let lens = build_lengths(&freqs, 15);
        assert!(lens.iter().any(|&l| l as u32 > 9));
        let codes = lengths_to_codes(&lens).unwrap();
        let dec = HuffDecoder::new(&lens).unwrap();
        let mut w = BitWriter::new();
        for s in (0..600u16).step_by(7) {
            w.write_code(codes[s as usize] as u32, lens[s as usize] as u32);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for s in (0..600u16).step_by(7) {
            assert_eq!(dec.decode(&mut r).unwrap(), s);
        }
    }

    #[test]
    fn oversubscribed_rejected() {
        let lengths = [1u8, 1, 1];
        assert!(lengths_to_codes(&lengths).is_err());
    }

    #[test]
    fn lut_decoder_matches_bitwise_reference() {
        // Differential test: random valid codes (built from random
        // frequency profiles, so lengths always satisfy Kraft), random
        // symbol streams; the two-level LUT decoder must agree with the
        // one-bit-at-a-time reference symbol for symbol.
        let mut rng = crate::testutil::Rng::new(0xD1FF);
        for trial in 0..64 {
            let nsyms = 2 + (rng.next_u64() % 600) as usize;
            let mut freqs = vec![0u32; nsyms];
            for f in freqs.iter_mut() {
                // Skewed profile: many zeros, a few heavy symbols, so
                // trials mix short codes, >ROOT_BITS codes, and holes.
                *f = match rng.next_u64() % 4 {
                    0 => 0,
                    1 => 1,
                    2 => (rng.next_u64() % 100) as u32,
                    _ => (rng.next_u64() % 10_000) as u32,
                };
            }
            if freqs.iter().all(|&f| f == 0) {
                freqs[0] = 1;
            }
            let lens = build_lengths(&freqs, 15);
            let codes = lengths_to_codes(&lens).unwrap();
            let present: Vec<u16> =
                (0..nsyms as u16).filter(|&s| lens[s as usize] > 0).collect();
            let stream: Vec<u16> = (0..200)
                .map(|_| present[(rng.next_u64() as usize) % present.len()])
                .collect();
            let mut w = BitWriter::new();
            for &s in &stream {
                w.write_code(codes[s as usize] as u32, lens[s as usize] as u32);
            }
            let bytes = w.finish();
            let lut = HuffDecoder::new(&lens).unwrap();
            let bitwise = BitwiseDecoder::new(&lens).unwrap();
            let mut ra = BitReader::new(&bytes);
            let mut rb = BitReader::new(&bytes);
            for (k, &s) in stream.iter().enumerate() {
                let a = lut.decode(&mut ra).unwrap();
                let b = bitwise.decode(&mut rb).unwrap();
                assert_eq!(a, b, "trial {trial} sym {k}");
                assert_eq!(a, s, "trial {trial} sym {k}");
            }
        }
    }
}
