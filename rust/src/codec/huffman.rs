//! Canonical Huffman codes for DEFLATE (RFC 1951 §3.2.2).
//!
//! * [`lengths_to_codes`] — assign canonical codes from code lengths, the
//!   procedure printed verbatim in the RFC.
//! * [`build_lengths`] — length-limited Huffman code construction from
//!   symbol frequencies via the package-merge algorithm (optimal under the
//!   15-bit DEFLATE limit).
//! * [`HuffDecoder`] — table-driven decoder: a single-level lookup table of
//!   `PEEK_BITS` bits with an overflow path for longer codes.

use crate::error::{corrupt, Result, ScdaError};
use crate::codec::bitio::{reverse_bits, BitReader};

/// Maximum code length in DEFLATE.
pub const MAX_BITS: usize = 15;

/// Assign canonical codes to `lengths` (0 = symbol unused). Returns codes
/// aligned with `lengths` (MSB-first values as in the RFC; writers must
/// bit-reverse, which [`crate::codec::bitio::BitWriter::write_code`] does).
pub fn lengths_to_codes(lengths: &[u8]) -> Result<Vec<u16>> {
    let mut bl_count = [0u32; MAX_BITS + 1];
    for &l in lengths {
        if l as usize > MAX_BITS {
            return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "code length exceeds 15"));
        }
        bl_count[l as usize] += 1;
    }
    bl_count[0] = 0;
    let mut next_code = [0u32; MAX_BITS + 2];
    let mut code = 0u32;
    for bits in 1..=MAX_BITS {
        code = (code + bl_count[bits - 1]) << 1;
        next_code[bits] = code;
    }
    // Over-subscribed codes would overflow the code space; detect.
    let mut kraft: u64 = 0;
    for &l in lengths {
        if l > 0 {
            kraft += 1u64 << (MAX_BITS - l as usize);
        }
    }
    if kraft > 1 << MAX_BITS {
        return Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "over-subscribed Huffman code"));
    }
    let mut codes = vec![0u16; lengths.len()];
    for (i, &l) in lengths.iter().enumerate() {
        if l > 0 {
            codes[i] = next_code[l as usize] as u16;
            next_code[l as usize] += 1;
        }
    }
    Ok(codes)
}

/// Build optimal length-limited code lengths for the given frequencies,
/// capped at `limit` bits. Symbols with zero frequency get length 0. If
/// fewer than two symbols occur, the single present symbol is assigned
/// length 1 (DEFLATE requires at least one bit per code).
///
/// Fast path: plain array-based Huffman (two-queue construction, no
/// allocations beyond three scratch vectors). Only when the resulting
/// depth exceeds `limit` — rare outside adversarial frequency skews —
/// does the optimal package-merge fallback run.
pub fn build_lengths(freqs: &[u32], limit: usize) -> Vec<u8> {
    if let Some(lengths) = huffman_lengths_fast(freqs, limit) {
        return lengths;
    }
    build_lengths_package_merge(freqs, limit)
}

/// Two-queue Huffman over the used symbols; `None` if any code length
/// would exceed `limit`.
fn huffman_lengths_fast(freqs: &[u32], limit: usize) -> Option<Vec<u8>> {
    let n = freqs.len();
    let mut used: Vec<u32> = (0..n as u32).filter(|&i| freqs[i as usize] > 0).collect();
    let mut lengths = vec![0u8; n];
    match used.len() {
        0 => return Some(lengths),
        1 => {
            lengths[used[0] as usize] = 1;
            return Some(lengths);
        }
        _ => {}
    }
    used.sort_unstable_by_key(|&i| freqs[i as usize]);
    let m = used.len();
    // Nodes: 0..m leaves (sorted), m.. internal. parent[] links upward.
    let total_nodes = 2 * m - 1;
    let mut weight: Vec<u64> = used.iter().map(|&i| freqs[i as usize] as u64).collect();
    weight.resize(total_nodes, 0);
    let mut parent = vec![0u32; total_nodes];
    let (mut leaf_at, mut node_at) = (0usize, m);
    let mut next = m;
    while next < total_nodes {
        // Pick the two smallest among remaining leaves and internal nodes.
        let pick = |leaf_at: &mut usize, node_at: &mut usize| -> usize {
            if *leaf_at < m && (*node_at >= next || weight[*leaf_at] <= weight[*node_at]) {
                *leaf_at += 1;
                *leaf_at - 1
            } else {
                *node_at += 1;
                *node_at - 1
            }
        };
        let a = pick(&mut leaf_at, &mut node_at);
        let b = pick(&mut leaf_at, &mut node_at);
        weight[next] = weight[a] + weight[b];
        parent[a] = next as u32;
        parent[b] = next as u32;
        next += 1;
    }
    // Depths: root (last node) has depth 0; walk down in reverse order.
    let mut depth = vec![0u8; total_nodes];
    for i in (0..total_nodes - 1).rev() {
        depth[i] = depth[parent[i] as usize] + 1;
        if i < m && depth[i] as usize > limit {
            return None;
        }
    }
    for (j, &sym) in used.iter().enumerate() {
        lengths[sym as usize] = depth[j];
    }
    Some(lengths)
}

/// Optimal length-limited construction (package-merge), used as the
/// fallback when the unconstrained tree exceeds the depth limit.
fn build_lengths_package_merge(freqs: &[u32], limit: usize) -> Vec<u8> {
    debug_assert!(limit <= MAX_BITS);
    let n = freqs.len();
    let mut used: Vec<usize> = (0..n).filter(|&i| freqs[i] > 0).collect();
    // Package-merge requires the leaf list sorted by weight.
    used.sort_by_key(|&i| freqs[i]);
    let mut lengths = vec![0u8; n];
    match used.len() {
        0 => return lengths,
        1 => {
            lengths[used[0]] = 1;
            return lengths;
        }
        _ => {}
    }
    // Package-merge over the used symbols.
    // items: (weight, set of leaf indices) — we track leaf multiplicity via
    // counting how many times each leaf appears among chosen packages.
    #[derive(Clone)]
    struct Node {
        weight: u64,
        leaves: Vec<u32>, // indices into `used`
    }
    let leaves: Vec<Node> = used
        .iter()
        .enumerate()
        .map(|(j, &i)| Node { weight: freqs[i] as u64, leaves: vec![j as u32] })
        .collect();
    let mut prev: Vec<Node> = Vec::new();
    for _level in 0..limit {
        // merge leaves with packaged pairs from prev, sorted by weight
        let mut merged: Vec<Node> = Vec::with_capacity(leaves.len() + prev.len() / 2);
        let mut pairs = prev.chunks_exact(2).map(|p| {
            let mut l = p[0].leaves.clone();
            l.extend_from_slice(&p[1].leaves);
            Node { weight: p[0].weight + p[1].weight, leaves: l }
        });
        let mut li = leaves.iter();
        let (mut a, mut b) = (li.next(), pairs.next());
        loop {
            match (a, b.as_ref()) {
                (Some(x), Some(y)) => {
                    if x.weight <= y.weight {
                        merged.push(x.clone());
                        a = li.next();
                    } else {
                        merged.push(b.take().unwrap());
                        b = pairs.next();
                    }
                }
                (Some(x), None) => {
                    merged.push(x.clone());
                    a = li.next();
                }
                (None, Some(_)) => {
                    merged.push(b.take().unwrap());
                    b = pairs.next();
                }
                (None, None) => break,
            }
        }
        prev = merged;
    }
    // Take the first 2*(m-1) items; each leaf occurrence increments length.
    let m = used.len();
    let mut lens = vec![0u32; m];
    for node in prev.iter().take(2 * (m - 1)) {
        for &j in &node.leaves {
            lens[j as usize] += 1;
        }
    }
    for (j, &i) in used.iter().enumerate() {
        debug_assert!(lens[j] >= 1 && lens[j] as usize <= limit);
        lengths[i] = lens[j] as u8;
    }
    lengths
}

const PEEK_BITS: u32 = 9;

/// Table-driven canonical Huffman decoder.
pub struct HuffDecoder {
    /// Primary table indexed by `PEEK_BITS` reversed bits:
    /// `(symbol, len)` for codes of length <= PEEK_BITS, or a sentinel for
    /// longer codes resolved through `long`.
    table: Vec<(u16, u8)>,
    /// Sorted (reversed_code, len, symbol) for codes longer than PEEK_BITS.
    long: Vec<(u32, u8, u16)>,
    max_len: u8,
}

impl HuffDecoder {
    /// Build a decoder from code lengths.
    pub fn new(lengths: &[u8]) -> Result<Self> {
        let codes = lengths_to_codes(lengths)?;
        let mut table = vec![(u16::MAX, 0u8); 1 << PEEK_BITS];
        let mut long = Vec::new();
        let mut max_len = 0u8;
        for (sym, (&len, &code)) in lengths.iter().zip(codes.iter()).enumerate() {
            if len == 0 {
                continue;
            }
            max_len = max_len.max(len);
            let rev = reverse_bits(code as u32, len as u32);
            if (len as u32) <= PEEK_BITS {
                // Fill all table slots whose low `len` bits equal `rev`.
                let step = 1u32 << len;
                let mut idx = rev;
                while idx < (1 << PEEK_BITS) {
                    table[idx as usize] = (sym as u16, len);
                    idx += step;
                }
            } else {
                long.push((rev, len, sym as u16));
            }
        }
        long.sort_unstable();
        Ok(HuffDecoder { table, long, max_len })
    }

    /// Decode one symbol from the reader.
    #[inline]
    pub fn decode(&self, r: &mut BitReader<'_>) -> Result<u16> {
        let peek = r.peek_bits(PEEK_BITS);
        let (sym, len) = self.table[peek as usize];
        if len > 0 {
            r.consume(len as u32)?;
            return Ok(sym);
        }
        // Long path: try lengths PEEK_BITS+1..=max_len.
        let peek_long = r.peek_bits(self.max_len as u32);
        for &(rev, len, sym) in &self.long {
            let mask = (1u32 << len) - 1;
            if peek_long & mask == rev {
                r.consume(len as u32)?;
                return Ok(sym);
            }
        }
        Err(ScdaError::corrupt(corrupt::BAD_ZLIB, "invalid Huffman code in deflate stream"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::bitio::BitWriter;

    #[test]
    fn rfc_example_codes() {
        // RFC 1951 §3.2.2 example: lengths (3,3,3,3,3,2,4,4) ->
        // codes 010,011,100,101,110,00,1110,1111.
        let lengths = [3u8, 3, 3, 3, 3, 2, 4, 4];
        let codes = lengths_to_codes(&lengths).unwrap();
        assert_eq!(codes, vec![0b010, 0b011, 0b100, 0b101, 0b110, 0b00, 0b1110, 0b1111]);
    }

    #[test]
    fn build_lengths_simple() {
        // Highly skewed frequencies yield shorter codes for frequent syms.
        let freqs = [100u32, 10, 10, 1];
        let lens = build_lengths(&freqs, 15);
        assert!(lens[0] <= lens[1] && lens[1] <= lens[3]);
        // Kraft equality for an optimal complete code.
        let kraft: f64 = lens.iter().filter(|&&l| l > 0).map(|&l| 2f64.powi(-(l as i32))).sum();
        assert!((kraft - 1.0).abs() < 1e-9);
    }

    #[test]
    fn build_lengths_respects_limit() {
        // Fibonacci-like frequencies force long codes without a limit.
        let mut freqs = vec![0u32; 20];
        let (mut a, mut b) = (1u32, 1u32);
        for f in freqs.iter_mut() {
            *f = a;
            let c = a + b;
            a = b;
            b = c;
        }
        for limit in [7usize, 9, 15] {
            let lens = build_lengths(&freqs, limit);
            assert!(lens.iter().all(|&l| (l as usize) <= limit));
            let kraft: f64 = lens.iter().filter(|&&l| l > 0).map(|&l| 2f64.powi(-(l as i32))).sum();
            assert!(kraft <= 1.0 + 1e-9, "limit={limit} kraft={kraft}");
            lengths_to_codes(&lens).unwrap();
        }
    }

    #[test]
    fn single_symbol_gets_one_bit() {
        let mut freqs = vec![0u32; 10];
        freqs[7] = 42;
        let lens = build_lengths(&freqs, 15);
        assert_eq!(lens[7], 1);
        assert_eq!(lens.iter().filter(|&&l| l > 0).count(), 1);
    }

    #[test]
    fn decoder_roundtrips_all_symbols() {
        let lengths = [3u8, 3, 3, 3, 3, 2, 4, 4];
        let codes = lengths_to_codes(&lengths).unwrap();
        let dec = HuffDecoder::new(&lengths).unwrap();
        let mut w = BitWriter::new();
        let syms: Vec<u16> = (0..8).chain((0..8).rev()).collect();
        for &s in &syms {
            w.write_code(codes[s as usize] as u32, lengths[s as usize] as u32);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &s in &syms {
            assert_eq!(dec.decode(&mut r).unwrap(), s);
        }
    }

    #[test]
    fn decoder_handles_long_codes() {
        // Create codes longer than PEEK_BITS: 600 symbols, near-uniform.
        let freqs = vec![1u32; 600];
        let lens = build_lengths(&freqs, 15);
        assert!(lens.iter().any(|&l| l as u32 > 9));
        let codes = lengths_to_codes(&lens).unwrap();
        let dec = HuffDecoder::new(&lens).unwrap();
        let mut w = BitWriter::new();
        for s in (0..600u16).step_by(7) {
            w.write_code(codes[s as usize] as u32, lens[s as usize] as u32);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for s in (0..600u16).step_by(7) {
            assert_eq!(dec.decode(&mut r).unwrap(), s);
        }
    }

    #[test]
    fn oversubscribed_rejected() {
        let lengths = [1u8, 1, 1];
        assert!(lengths_to_codes(&lengths).is_err());
    }
}
