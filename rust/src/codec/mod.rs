//! Compression substrate for the scda convention (§3), implemented from
//! scratch: Adler-32, LSB-first bit I/O, canonical/length-limited Huffman
//! codes, an LZ77 hash-chain matcher, a DEFLATE encoder/decoder, the zlib
//! (RFC 1950) wrapper, 76-column base64, and the two-stage element framing.
//!
//! Conformance is cross-checked against miniz_oxide (via flate2, tests
//! only) and CPython's zlib (interop integration tests): streams we write
//! inflate elsewhere, streams zlib writes inflate here.

pub mod adler32;
pub mod base64;
pub mod bitio;
pub mod deflate;
pub mod frame;
pub mod huffman;
pub mod inflate;
pub mod lz77;
pub mod precondition;
pub mod zlib;

pub use adler32::adler32;
pub use deflate::{deflate, deflate_into};
pub use frame::{
    decode_element, decode_element_into, encode_element, encode_element_into, peek_uncompressed_size,
    with_scratch, CodecOptions, CodecScratch,
};
pub use inflate::{inflate, inflate_into};
pub use precondition::Precond;
pub use zlib::{zlib_compress, zlib_compress_into, zlib_decompress, zlib_decompress_into};
