//! Adler-32 checksum (RFC 1950 §2.2), the integrity check inside every
//! zlib stream written by the compression convention (§3.1). One of the
//! paper's "three redundant checks" on reading compressed data.

const MOD_ADLER: u32 = 65_521;
/// Largest n such that 255 n (n+1) / 2 + (n+1)(MOD-1) stays below 2^32:
/// lets us defer the expensive modulo to every NMAX bytes (zlib's trick).
const NMAX: usize = 5552;

/// Streaming Adler-32 state.
#[derive(Debug, Clone)]
pub struct Adler32 {
    a: u32,
    b: u32,
}

impl Default for Adler32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Adler32 {
    pub fn new() -> Self {
        Adler32 { a: 1, b: 0 }
    }

    pub fn update(&mut self, data: &[u8]) {
        for chunk in data.chunks(NMAX) {
            for &byte in chunk {
                self.a += byte as u32;
                self.b += self.a;
            }
            self.a %= MOD_ADLER;
            self.b %= MOD_ADLER;
        }
    }

    pub fn finish(&self) -> u32 {
        (self.b << 16) | self.a
    }
}

/// One-shot Adler-32 of `data`.
pub fn adler32(data: &[u8]) -> u32 {
    let mut s = Adler32::new();
    s.update(data);
    s.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // RFC 1950: checksum of the empty stream is 1.
        assert_eq!(adler32(b""), 1);
        // Classic test vector.
        assert_eq!(adler32(b"Wikipedia"), 0x11E6_0398);
        assert_eq!(adler32(b"a"), 0x0062_0062);
        assert_eq!(adler32(b"abc"), 0x024d_0127);
        assert_eq!(adler32(b"message digest"), 0x29750586);
    }

    #[test]
    fn streaming_equals_oneshot() {
        let data: Vec<u8> = (0..100_000u32).map(|i| i.wrapping_mul(2_654_435_761) as u8).collect();
        let mut s = Adler32::new();
        for chunk in data.chunks(777) {
            s.update(chunk);
        }
        assert_eq!(s.finish(), adler32(&data));
    }

    #[test]
    fn deferred_modulo_is_safe_on_all_ones() {
        let data = vec![0xffu8; 4 * NMAX + 13];
        // Cross-check against a naive mod-every-byte implementation.
        let (mut a, mut b) = (1u64, 0u64);
        for &x in &data {
            a = (a + x as u64) % MOD_ADLER as u64;
            b = (b + a) % MOD_ADLER as u64;
        }
        assert_eq!(adler32(&data), ((b as u32) << 16) | a as u32);
    }
}
