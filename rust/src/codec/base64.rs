//! Base64 with the line discipline of §3.1: the encoded stream is broken
//! into lines of 76 code bytes followed by a two-byte line break — `"\r\n"`
//! for MIME style, `"=\n"` for Unix style — and "the same two bytes are
//! added after the last line of encoding if it is short of 76 bytes".
//! (Reading accepts either style, and a final full line also carries the
//! terminator so the compressed size is a pure function of the payload.)

use crate::error::{corrupt, Result, ScdaError};
use crate::format::limits::BASE64_LINE_COLS;
use crate::format::padding::LineStyle;

const ALPHABET: &[u8; 64] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

fn decode_table() -> [i8; 256] {
    let mut t = [-1i8; 256];
    let mut i = 0u8;
    while (i as usize) < 64 {
        t[ALPHABET[i as usize] as usize] = i as i8;
        i += 1;
    }
    t
}

/// Raw base64 encoding without line breaks (RFC 4648 with `=` padding).
pub fn encode_plain(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let v = ((b[0] as u32) << 16) | ((b[1] as u32) << 8) | b[2] as u32;
        out.push(ALPHABET[(v >> 18) as usize & 63]);
        out.push(ALPHABET[(v >> 12) as usize & 63]);
        out.push(if chunk.len() > 1 { ALPHABET[(v >> 6) as usize & 63] } else { b'=' });
        out.push(if chunk.len() > 2 { ALPHABET[v as usize & 63] } else { b'=' });
    }
    out
}

/// Encode with the §3.1 line discipline. The result length — the
/// convention's "compressed size" — is deterministic:
/// `ceil(n/3)*4` code bytes plus 2 bytes per (possibly partial) line.
pub fn encode_lines(data: &[u8], style: LineStyle) -> Vec<u8> {
    let mut out = Vec::with_capacity(encoded_len(data.len()));
    encode_lines_into(data, style, &mut out);
    out
}

/// [`encode_lines`] appending to `out` — no intermediate code buffer: the
/// base64 groups stream directly into the caller's buffer with line
/// terminators interleaved (the codec pipeline's write-into contract).
pub fn encode_lines_into(data: &[u8], style: LineStyle, out: &mut Vec<u8>) {
    let brk: &[u8; 2] = match style {
        LineStyle::Unix => b"=\n",
        LineStyle::Mime => b"\r\n",
    };
    out.reserve(encoded_len(data.len()));
    if data.is_empty() {
        // Zero-byte payload: a single empty line still gets its terminator
        // so that even empty data is visibly delimited.
        out.extend_from_slice(brk);
        return;
    }
    let mut col = 0usize;
    for chunk in data.chunks(3) {
        let b = [chunk[0], *chunk.get(1).unwrap_or(&0), *chunk.get(2).unwrap_or(&0)];
        let v = ((b[0] as u32) << 16) | ((b[1] as u32) << 8) | b[2] as u32;
        let quad = [
            ALPHABET[(v >> 18) as usize & 63],
            ALPHABET[(v >> 12) as usize & 63],
            if chunk.len() > 1 { ALPHABET[(v >> 6) as usize & 63] } else { b'=' },
            if chunk.len() > 2 { ALPHABET[v as usize & 63] } else { b'=' },
        ];
        for code in quad {
            if col == BASE64_LINE_COLS {
                out.extend_from_slice(brk);
                col = 0;
            }
            out.push(code);
            col += 1;
        }
    }
    // Every line carries a terminator, including a final full one.
    out.extend_from_slice(brk);
}

/// Exact encoded length produced by [`encode_lines`] for `n` input bytes.
pub fn encoded_len(n: usize) -> usize {
    let code = n.div_ceil(3) * 4;
    code + 2 * code.div_ceil(BASE64_LINE_COLS).max(1)
}

/// Decode a §3.1 base64 stream.
///
/// The line geometry is fully determined by the total length `L`: every
/// line, including the last (possibly partial or empty) one, carries a
/// 2-byte terminator, so `lines = ceil(L / 78)` and the number of code
/// bytes is `L - 2 * lines`. The terminator bytes themselves are "arbitrary"
/// per the spec and are not interpreted; code bytes are strict RFC 4648.
pub fn decode_lines(data: &[u8]) -> Result<Vec<u8>> {
    let mut out = Vec::new();
    decode_lines_into(data, &mut out)?;
    Ok(out)
}

/// [`decode_lines`] appending to `out` (the codec pipeline's reusable
/// stage buffers). On error, `out` may hold a partial decode; callers
/// that reuse buffers clear them per element.
pub fn decode_lines_into(data: &[u8], out: &mut Vec<u8>) -> Result<()> {
    if data.len() < 2 {
        return Err(ScdaError::corrupt(corrupt::BAD_BASE64, "base64 stream shorter than one terminator"));
    }
    let lines = data.len().div_ceil(BASE64_LINE_COLS + 2);
    let code_len = data
        .len()
        .checked_sub(2 * lines)
        .ok_or_else(|| ScdaError::corrupt(corrupt::BAD_BASE64, "base64 stream length inconsistent"))?;
    if code_len % 4 != 0 {
        return Err(ScdaError::corrupt(
            corrupt::BAD_BASE64,
            format!("base64 code length {code_len} not a multiple of 4"),
        ));
    }
    let table = decode_table();
    out.reserve(code_len / 4 * 3);
    let mut quad = [0u8; 4];
    let mut qi = 0usize;
    let mut pad = 0usize;
    let mut consumed_code = 0usize;
    let mut i = 0usize;
    while consumed_code < code_len {
        // Skip the 2-byte terminator after each full line.
        if consumed_code > 0 && consumed_code % BASE64_LINE_COLS == 0 && i % (BASE64_LINE_COLS + 2) != 0 {
            i += 2;
            continue;
        }
        let b = data[i];
        i += 1;
        consumed_code += 1;
        let v = table[b as usize];
        if v >= 0 {
            if pad > 0 {
                return Err(ScdaError::corrupt(corrupt::BAD_BASE64, "base64 code byte after padding"));
            }
            quad[qi] = v as u8;
            qi += 1;
        } else if b == b'=' && qi >= 2 && consumed_code + (3 - qi) >= code_len {
            // Pad only legal in the trailing positions of the final group.
            pad += 1;
            quad[qi] = 0;
            qi += 1;
        } else {
            return Err(ScdaError::corrupt(
                corrupt::BAD_BASE64,
                format!("invalid base64 byte {b:#04x} at offset {}", i - 1),
            ));
        }
        if qi == 4 {
            out.push((quad[0] << 2) | (quad[1] >> 4));
            if pad < 2 {
                out.push((quad[1] << 4) | (quad[2] >> 2));
            }
            if pad < 1 {
                out.push((quad[2] << 6) | quad[3]);
            }
            qi = 0;
        }
    }
    if qi != 0 {
        return Err(ScdaError::corrupt(corrupt::BAD_BASE64, "base64 stream ends mid-group"));
    }
    if i + 2 != data.len() {
        return Err(ScdaError::corrupt(corrupt::BAD_BASE64, "base64 stream length inconsistent"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plain_rfc_vectors() {
        assert_eq!(encode_plain(b""), b"");
        assert_eq!(encode_plain(b"f"), b"Zg==");
        assert_eq!(encode_plain(b"fo"), b"Zm8=");
        assert_eq!(encode_plain(b"foo"), b"Zm9v");
        assert_eq!(encode_plain(b"foob"), b"Zm9vYg==");
        assert_eq!(encode_plain(b"fooba"), b"Zm9vYmE=");
        assert_eq!(encode_plain(b"foobar"), b"Zm9vYmFy");
    }

    #[test]
    fn lines_are_76_plus_terminator() {
        let data = vec![0xabu8; 100]; // 136 code chars -> 1 full + 1 partial line
        for style in [LineStyle::Unix, LineStyle::Mime] {
            let enc = encode_lines(&data, style);
            assert_eq!(enc.len(), encoded_len(100));
            let term: &[u8] = match style {
                LineStyle::Unix => b"=\n",
                LineStyle::Mime => b"\r\n",
            };
            assert_eq!(&enc[76..78], term);
            assert_eq!(&enc[enc.len() - 2..], term);
        }
    }

    #[test]
    fn full_line_also_terminated() {
        // 57 bytes -> exactly 76 code chars -> one line + terminator.
        let data = vec![7u8; 57];
        let enc = encode_lines(&data, LineStyle::Unix);
        assert_eq!(enc.len(), 78);
        assert_eq!(encoded_len(57), 78);
        assert_eq!(decode_lines(&enc).unwrap(), data);
    }

    #[test]
    fn roundtrip_sizes() {
        let mut x = 0xdeadbeefu64;
        for n in [0usize, 1, 2, 3, 4, 56, 57, 58, 75, 76, 100, 1000, 10_000] {
            let data: Vec<u8> = (0..n)
                .map(|_| {
                    x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
                    (x >> 33) as u8
                })
                .collect();
            for style in [LineStyle::Unix, LineStyle::Mime] {
                let enc = encode_lines(&data, style);
                assert_eq!(enc.len(), encoded_len(n), "n={n}");
                assert_eq!(decode_lines(&enc).unwrap(), data, "n={n}");
            }
        }
    }

    #[test]
    fn empty_payload_is_single_terminator() {
        let enc = encode_lines(b"", LineStyle::Unix);
        assert_eq!(enc, b"=\n");
        assert_eq!(encoded_len(0), 2);
        assert_eq!(decode_lines(&enc).unwrap(), b"");
        assert_eq!(decode_lines(b"\r\n").unwrap(), b"");
    }

    #[test]
    fn corruption_detected() {
        let enc = encode_lines(b"hello world", LineStyle::Unix);
        let mut bad = enc.clone();
        bad[0] = b'!';
        assert!(decode_lines(&bad).is_err());
        // Truncation mid-group.
        assert!(decode_lines(&enc[..enc.len() - 3]).is_err());
    }

    #[test]
    fn cross_style_decoding() {
        let data = vec![42u8; 200];
        let unix = encode_lines(&data, LineStyle::Unix);
        let mime = encode_lines(&data, LineStyle::Mime);
        assert_eq!(decode_lines(&unix).unwrap(), data);
        assert_eq!(decode_lines(&mime).unwrap(), data);
        assert_ne!(unix, mime);
        assert_eq!(unix.len(), mime.len());
    }
}
