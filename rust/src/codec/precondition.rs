//! Optional, format-visible preconditioning inside the compression
//! convention's element frame (SPEC §5.4): a byte-plane shuffle by the
//! element width, optionally followed by a per-plane byte delta, applied
//! to the payload *before* the zlib stage and inverted after inflation.
//!
//! For fixed-width numeric data the shuffle groups bytes of equal
//! significance (near-constant exponent/high bytes become long runs) and
//! the delta turns smooth fields into near-zero planes — both cheaper
//! for DEFLATE to model and faster to match. The transform is exactly
//! length-preserving and self-describing: the frame marker byte `'p'`
//! plus a one-byte descriptor replace the plain `'z'` marker, so readers
//! need no out-of-band configuration (the catalog's `p=` key is advisory
//! convenience for tools, not required for decoding).
//!
//! Byte-exact definition (all arithmetic on bytes, wrapping):
//! * let `w` be the element width and `rows = len / w`; the first
//!   `rows * w` bytes are the body, the `len % w` tail passes through raw;
//! * shuffle: output plane `k` (of `w`, each `rows` long, plane-major)
//!   holds the bytes `body[j*w + k]` for `j = 0..rows`;
//! * delta (if enabled, applied after the shuffle, per plane): each plane
//!   byte is replaced by its wrapping difference from the previous byte
//!   of the same plane, the first byte unchanged.
//! Decode inverts in the opposite order: per-plane wrapping prefix sum,
//! then un-shuffle. Distinct from the coordinator-level runtime
//! preconditioner ([`crate::runtime::precond`]): this stage lives inside
//! the frame bytes and changes what is stored; that one is an I/O-path
//! transform outside the format.

use std::fmt;
use std::str::FromStr;

use crate::error::{corrupt, Result, ScdaError};

/// Per-dataset preconditioning parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Precond {
    /// Element width in bytes (1..=[`Precond::MAX_WIDTH`]). Width 1 makes
    /// the shuffle the identity; the delta can still apply.
    pub width: u8,
    /// Apply the per-plane byte delta after the shuffle.
    pub delta: bool,
}

impl Precond {
    /// Largest supported element width. 32 covers every scalar plus
    /// complex128 and small fixed-size records.
    pub const MAX_WIDTH: u8 = 32;

    /// Descriptor-byte flag for the delta stage.
    const DELTA_FLAG: u8 = 0x80;

    pub fn new(width: u8, delta: bool) -> Result<Self> {
        if width == 0 || width > Self::MAX_WIDTH {
            return Err(ScdaError::corrupt(
                corrupt::BAD_CONVENTION,
                format!("preconditioning width {width} outside 1..={}", Self::MAX_WIDTH),
            ));
        }
        Ok(Precond { width, delta })
    }

    /// The one-byte wire descriptor following the `'p'` frame marker:
    /// low 7 bits = width, high bit = delta.
    pub fn descriptor(self) -> u8 {
        self.width | if self.delta { Self::DELTA_FLAG } else { 0 }
    }

    /// Parse a wire descriptor (the read side self-configures from it).
    pub fn from_descriptor(b: u8) -> Result<Self> {
        Precond::new(b & !Self::DELTA_FLAG, b & Self::DELTA_FLAG != 0)
    }

    /// Forward transform, appending exactly `data.len()` bytes to `out`.
    pub fn forward_into(self, data: &[u8], out: &mut Vec<u8>) {
        let w = self.width as usize;
        let rows = data.len() / w;
        let body = rows * w;
        let start = out.len();
        out.reserve(data.len());
        if w == 1 {
            out.extend_from_slice(&data[..body]);
        } else {
            for k in 0..w {
                out.extend((0..rows).map(|j| data[j * w + k]));
            }
        }
        if self.delta {
            for plane in out[start..start + body].chunks_exact_mut(rows.max(1)) {
                let mut prev = 0u8;
                for b in plane.iter_mut() {
                    let cur = *b;
                    *b = cur.wrapping_sub(prev);
                    prev = cur;
                }
            }
        }
        out.extend_from_slice(&data[body..]);
    }

    /// Exact inverse of [`Self::forward_into`], in place. `tmp` is scratch
    /// reused across calls (cleared here).
    pub fn inverse_in_place(self, buf: &mut [u8], tmp: &mut Vec<u8>) {
        let w = self.width as usize;
        let rows = buf.len() / w;
        let body = rows * w;
        if self.delta {
            for plane in buf[..body].chunks_exact_mut(rows.max(1)) {
                let mut acc = 0u8;
                for b in plane.iter_mut() {
                    acc = acc.wrapping_add(*b);
                    *b = acc;
                }
            }
        }
        if w > 1 && rows > 0 {
            tmp.clear();
            tmp.extend_from_slice(&buf[..body]);
            for k in 0..w {
                for j in 0..rows {
                    buf[j * w + k] = tmp[k * rows + j];
                }
            }
        }
    }
}

/// Catalog/CLI token form: decimal width, optional trailing `d` for
/// delta — `"8d"`, `"4"`. No spaces (catalog tokens are space-split).
impl fmt::Display for Precond {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}{}", self.width, if self.delta { "d" } else { "" })
    }
}

impl FromStr for Precond {
    type Err = ScdaError;

    fn from_str(s: &str) -> Result<Self> {
        let (digits, delta) = match s.strip_suffix('d') {
            Some(rest) => (rest, true),
            None => (s, false),
        };
        let width: u8 = digits.parse().map_err(|_| {
            ScdaError::corrupt(corrupt::BAD_CONVENTION, format!("bad preconditioning spec {s:?}"))
        })?;
        Precond::new(width, delta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    fn roundtrip(p: Precond, data: &[u8]) {
        let mut t = Vec::new();
        p.forward_into(data, &mut t);
        assert_eq!(t.len(), data.len(), "{p} len {}", data.len());
        let mut tmp = Vec::new();
        p.inverse_in_place(&mut t, &mut tmp);
        assert_eq!(t, data, "{p} len {}", data.len());
    }

    #[test]
    fn roundtrips_all_widths_and_lengths() {
        let mut rng = Rng::new(42);
        for width in [1u8, 2, 3, 4, 7, 8, 16, 32] {
            for delta in [false, true] {
                let p = Precond::new(width, delta).unwrap();
                for len in [0usize, 1, 2, 7, 8, 9, 63, 64, 65, 1000, 4096 + 5] {
                    roundtrip(p, &rng.bytes(len, 256));
                }
            }
        }
    }

    #[test]
    fn structured_payloads_roundtrip_and_compress_better() {
        // A smooth little-endian u32 ramp: after shuffle+delta the high
        // planes are almost all zero, so deflate does strictly better.
        let data: Vec<u8> = (0..20_000u32).flat_map(|i| (1000 + 3 * i).to_le_bytes()).collect();
        let p = Precond::new(4, true).unwrap();
        roundtrip(p, &data);
        let mut t = Vec::new();
        p.forward_into(&data, &mut t);
        let raw = crate::codec::zlib_compress(&data, 6).len();
        let pre = crate::codec::zlib_compress(&t, 6).len();
        assert!(pre < raw, "preconditioned {pre} vs raw {raw}");
    }

    #[test]
    fn tail_bytes_pass_through() {
        let p = Precond::new(8, true).unwrap();
        let data: Vec<u8> = (0..8 * 5 + 3).map(|i| i as u8).collect();
        let mut t = Vec::new();
        p.forward_into(&data, &mut t);
        assert_eq!(&t[8 * 5..], &data[8 * 5..]);
    }

    #[test]
    fn width_one_shuffle_is_identity() {
        let data = b"width one leaves byte order alone".to_vec();
        let p = Precond::new(1, false).unwrap();
        let mut t = Vec::new();
        p.forward_into(&data, &mut t);
        assert_eq!(t, data);
        // With delta, width 1 is a plain byte delta over the whole buffer.
        let p = Precond::new(1, true).unwrap();
        let mut t = Vec::new();
        p.forward_into(&data, &mut t);
        assert_eq!(t[0], data[0]);
        assert_eq!(t[1], data[1].wrapping_sub(data[0]));
        let mut tmp = Vec::new();
        p.inverse_in_place(&mut t, &mut tmp);
        assert_eq!(t, data);
    }

    #[test]
    fn descriptor_and_string_forms_roundtrip() {
        for width in 1..=Precond::MAX_WIDTH {
            for delta in [false, true] {
                let p = Precond::new(width, delta).unwrap();
                assert_eq!(Precond::from_descriptor(p.descriptor()).unwrap(), p);
                assert_eq!(p.to_string().parse::<Precond>().unwrap(), p);
            }
        }
        assert!(Precond::new(0, false).is_err());
        assert!(Precond::new(33, true).is_err());
        assert!(Precond::from_descriptor(0).is_err());
        assert!("".parse::<Precond>().is_err());
        assert!("4x".parse::<Precond>().is_err());
        assert!("d".parse::<Precond>().is_err());
    }
}
