//! Greedy-with-lazy-evaluation LZ77 match finder over a 32 KiB window,
//! hash-chained as in zlib. Produces the token stream consumed by the
//! DEFLATE block encoder.

/// Maximum backward distance (RFC 1951).
pub const MAX_DIST: usize = 32 * 1024;
/// Minimum and maximum match lengths.
pub const MIN_MATCH: usize = 3;
pub const MAX_MATCH: usize = 258;

const MAX_HASH_BITS: u32 = 15;
const MIN_HASH_BITS: u32 = 9;

/// One LZ77 token: a literal byte or a (length, distance) back-reference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Token {
    Literal(u8),
    Match { len: u16, dist: u16 },
}

/// Effort knobs, roughly zlib's levels.
#[derive(Debug, Clone, Copy)]
pub struct MatchParams {
    /// Upper bound on hash-chain traversal per position.
    pub max_chain: usize,
    /// Stop searching early once a match of this length is found.
    pub good_len: usize,
    /// Enable one-step lazy matching.
    pub lazy: bool,
}

impl MatchParams {
    pub fn from_level(level: u8) -> Self {
        match level {
            0 | 1 => MatchParams { max_chain: 4, good_len: 8, lazy: false },
            2..=5 => MatchParams { max_chain: 32, good_len: 32, lazy: true },
            6..=7 => MatchParams { max_chain: 128, good_len: 128, lazy: true },
            _ => MatchParams { max_chain: 1024, good_len: MAX_MATCH, lazy: true },
        }
    }
}

#[inline]
fn hash3(data: &[u8], i: usize, bits: u32) -> usize {
    // Multiplicative hash of 3 bytes (sufficient: chains verify bytes).
    let v = (data[i] as u32) | ((data[i + 1] as u32) << 8) | ((data[i + 2] as u32) << 16);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - bits)) as usize
}

/// Hash-chain match finder with reusable buffers.
///
/// The hash table is sized to the input (2^9..2^15 entries) so that
/// compressing many small elements — the scda per-element convention's
/// hot path — does not pay a fixed 32K-entry reset per element, and the
/// buffers are reused across calls (see `deflate`'s thread-local).
pub struct Matcher {
    head: Vec<i32>,
    prev: Vec<i32>,
    hash_bits: u32,
    params: MatchParams,
}

impl Matcher {
    pub fn new(params: MatchParams) -> Self {
        Matcher { head: Vec::new(), prev: Vec::new(), hash_bits: 0, params }
    }

    /// Reconfigure the effort level (used by the thread-local reuse path).
    pub fn set_params(&mut self, params: MatchParams) {
        self.params = params;
    }

    fn prepare(&mut self, len: usize) {
        let bits = (usize::BITS - len.max(2).leading_zeros()).clamp(MIN_HASH_BITS, MAX_HASH_BITS);
        if self.hash_bits != bits || self.head.len() != 1 << bits {
            self.hash_bits = bits;
            self.head.clear();
            self.head.resize(1 << bits, -1);
        } else {
            self.head.iter_mut().for_each(|h| *h = -1);
        }
        self.prev.clear();
        self.prev.resize(len, -1);
    }

    #[inline]
    fn longest_match(&self, data: &[u8], pos: usize, best_so_far: usize) -> Option<(usize, usize)> {
        let max_len = (data.len() - pos).min(MAX_MATCH);
        if max_len < MIN_MATCH {
            return None;
        }
        let mut best_len = best_so_far.max(MIN_MATCH - 1);
        let mut best_dist = 0usize;
        let mut cand = self.head[hash3(data, pos, self.hash_bits)];
        let min_pos = pos.saturating_sub(MAX_DIST) as i32;
        let mut chain = self.params.max_chain;
        while cand >= min_pos && chain > 0 {
            let c = cand as usize;
            debug_assert!(c < pos);
            // Quick reject: compare the byte that would extend the match.
            if best_len < max_len
                && data[c + best_len] == data[pos + best_len]
                && data[c] == data[pos]
            {
                let mut l = 0usize;
                while l < max_len && data[c + l] == data[pos + l] {
                    l += 1;
                }
                if l > best_len {
                    best_len = l;
                    best_dist = pos - c;
                    if l >= self.params.good_len || l == max_len {
                        break;
                    }
                }
            }
            cand = self.prev[c];
            chain -= 1;
        }
        if best_dist > 0 && best_len >= MIN_MATCH {
            Some((best_len, best_dist))
        } else {
            None
        }
    }

    /// Tokenize `data`, invoking `emit` for each token in order.
    /// `data.len()` must fit in i32 (callers segment at 256 KiB).
    pub fn tokenize(&mut self, data: &[u8], mut emit: impl FnMut(Token)) {
        let n = data.len();
        debug_assert!(n <= i32::MAX as usize);
        self.prepare(n);
        let bits = self.hash_bits;

        let insert = |head: &mut Vec<i32>, prev: &mut Vec<i32>, data: &[u8], i: usize| {
            if i + MIN_MATCH <= data.len() {
                let h = hash3(data, i, bits);
                prev[i] = head[h];
                head[h] = i as i32;
            }
        };

        let mut i = 0usize;
        while i < n {
            let cur = self.longest_match(data, i, 0);
            match cur {
                None => {
                    emit(Token::Literal(data[i]));
                    insert(&mut self.head, &mut self.prev, data, i);
                    i += 1;
                }
                Some((len, dist)) => {
                    // Lazy evaluation: if the next position holds a strictly
                    // better match, emit a literal here instead.
                    let mut take = (len, dist);
                    let mut start = i;
                    if self.params.lazy && len < self.params.good_len && i + 1 < n {
                        insert(&mut self.head, &mut self.prev, data, i);
                        if let Some((nlen, ndist)) = self.longest_match(data, i + 1, len) {
                            if nlen > len {
                                emit(Token::Literal(data[i]));
                                take = (nlen, ndist);
                                start = i + 1;
                            }
                        }
                    } else if self.params.lazy {
                        insert(&mut self.head, &mut self.prev, data, i);
                    } else {
                        insert(&mut self.head, &mut self.prev, data, i);
                    }
                    let (mlen, mdist) = take;
                    emit(Token::Match { len: mlen as u16, dist: mdist as u16 });
                    // Insert hash entries for covered positions.
                    let end = start + mlen;
                    let from = if start == i { start + 1 } else { start };
                    for j in from..end.min(n.saturating_sub(MIN_MATCH - 1)) {
                        insert(&mut self.head, &mut self.prev, data, j);
                    }
                    i = end;
                }
            }
        }
    }
}

/// Reconstruct the original bytes from a token stream (used by tests and
/// as the reference semantics of [`Token`]).
pub fn detokenize(tokens: &[Token]) -> Vec<u8> {
    let mut out = Vec::new();
    for t in tokens {
        match *t {
            Token::Literal(b) => out.push(b),
            Token::Match { len, dist } => {
                let start = out.len() - dist as usize;
                for k in 0..len as usize {
                    let b = out[start + k];
                    out.push(b);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tokens_for(data: &[u8], level: u8) -> Vec<Token> {
        let mut m = Matcher::new(MatchParams::from_level(level));
        let mut v = Vec::new();
        m.tokenize(data, |t| v.push(t));
        v
    }

    #[test]
    fn tokens_reconstruct_input() {
        let cases: Vec<Vec<u8>> = vec![
            b"".to_vec(),
            b"a".to_vec(),
            b"abcabcabcabcabc".to_vec(),
            b"aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa".to_vec(),
            (0..255u8).collect(),
            b"the quick brown fox jumps over the lazy dog the quick brown fox".to_vec(),
        ];
        for level in [1u8, 5, 9] {
            for data in &cases {
                assert_eq!(detokenize(&tokens_for(data, level)), *data);
            }
        }
    }

    #[test]
    fn repetitive_input_compresses_to_matches() {
        let data = b"abcdefgh".repeat(100);
        let toks = tokens_for(&data, 9);
        let matches = toks.iter().filter(|t| matches!(t, Token::Match { .. })).count();
        assert!(matches >= 1);
        // Token count far below byte count.
        assert!(toks.len() < data.len() / 4, "{} tokens for {} bytes", toks.len(), data.len());
        assert_eq!(detokenize(&toks), data);
    }

    #[test]
    fn long_runs_use_max_match() {
        let data = vec![b'x'; 4096];
        let toks = tokens_for(&data, 9);
        assert!(toks
            .iter()
            .any(|t| matches!(t, Token::Match { len, .. } if *len as usize == MAX_MATCH)));
        assert_eq!(detokenize(&toks), data);
    }

    #[test]
    fn distances_respect_window() {
        let mut data = b"UNIQUEPREFIX".to_vec();
        data.extend(std::iter::repeat(b'.').take(MAX_DIST + 100));
        data.extend_from_slice(b"UNIQUEPREFIX");
        let toks = tokens_for(&data, 9);
        for t in &toks {
            if let Token::Match { dist, .. } = t {
                assert!((*dist as usize) <= MAX_DIST);
            }
        }
        assert_eq!(detokenize(&toks), data);
    }

    #[test]
    fn pseudorandom_roundtrip() {
        let mut x = 0x1234_5678_9abc_def0u64;
        let data: Vec<u8> = (0..50_000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                (x & 0x3f) as u8 // small alphabet -> plenty of matches
            })
            .collect();
        for level in [1u8, 6, 9] {
            assert_eq!(detokenize(&tokens_for(&data, level)), data);
        }
    }
}
