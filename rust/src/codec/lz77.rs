//! Greedy-with-lazy-evaluation LZ77 match finder over a 32 KiB window,
//! hash-chained as in zlib. Produces the token stream consumed by the
//! DEFLATE block encoder.
//!
//! The hot loops are word-wide: candidates are found through a 4-byte
//! hash and verified/extended eight bytes at a time (`u64` loads + XOR +
//! `trailing_zeros`), and positions covered by an emitted match enter
//! the hash table head-only (findable, but not chain-linked), so long
//! matches cost O(len/8) compares and O(1) table work per position.

/// Maximum backward distance (RFC 1951).
pub const MAX_DIST: usize = 32 * 1024;
/// Minimum and maximum match lengths.
pub const MIN_MATCH: usize = 3;
pub const MAX_MATCH: usize = 258;

/// Bytes folded into the hash. Four (not `MIN_MATCH`) trades the last
/// possible 3-byte match at a window tail for a far more selective
/// table; chains verify actual bytes either way.
const HASH_BYTES: usize = 4;

const MAX_HASH_BITS: u32 = 15;
const MIN_HASH_BITS: u32 = 9;

/// One LZ77 token: a literal byte or a (length, distance) back-reference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Token {
    Literal(u8),
    Match { len: u16, dist: u16 },
}

/// Effort knobs, roughly zlib's levels.
#[derive(Debug, Clone, Copy)]
pub struct MatchParams {
    /// Upper bound on hash-chain traversal per position.
    pub max_chain: usize,
    /// Stop searching early once a match of this length is found.
    pub good_len: usize,
    /// Enable one-step lazy matching.
    pub lazy: bool,
}

impl MatchParams {
    pub fn from_level(level: u8) -> Self {
        match level {
            0 | 1 => MatchParams { max_chain: 4, good_len: 8, lazy: false },
            2..=5 => MatchParams { max_chain: 32, good_len: 32, lazy: true },
            6..=7 => MatchParams { max_chain: 128, good_len: 128, lazy: true },
            _ => MatchParams { max_chain: 1024, good_len: MAX_MATCH, lazy: true },
        }
    }
}

#[inline]
fn hash4(data: &[u8], i: usize, bits: u32) -> usize {
    // Multiplicative hash of 4 bytes (sufficient: chains verify bytes).
    let v = u32::from_le_bytes(data[i..i + HASH_BYTES].try_into().unwrap());
    (v.wrapping_mul(0x9E37_79B1) >> (32 - bits)) as usize
}

/// Length of the common prefix of `data[a..]` and `data[b..]`, capped at
/// `max_len`, compared a word at a time. Requires `b + max_len <= n` and
/// `a < b` (so both sides stay in bounds).
#[inline]
fn match_len(data: &[u8], a: usize, b: usize, max_len: usize) -> usize {
    let mut l = 0usize;
    while l + 8 <= max_len {
        let x = u64::from_le_bytes(data[a + l..a + l + 8].try_into().unwrap());
        let y = u64::from_le_bytes(data[b + l..b + l + 8].try_into().unwrap());
        let xor = x ^ y;
        if xor != 0 {
            return l + (xor.trailing_zeros() >> 3) as usize;
        }
        l += 8;
    }
    while l < max_len && data[a + l] == data[b + l] {
        l += 1;
    }
    l
}

/// Hash-chain match finder with reusable buffers.
///
/// The hash table is sized to the input (2^9..2^15 entries) so that
/// compressing many small elements — the scda per-element convention's
/// hot path — does not pay a fixed 32K-entry reset per element, and the
/// buffers are reused across calls (see `deflate`'s thread-local).
pub struct Matcher {
    head: Vec<i32>,
    prev: Vec<i32>,
    hash_bits: u32,
    params: MatchParams,
}

impl Matcher {
    pub fn new(params: MatchParams) -> Self {
        Matcher { head: Vec::new(), prev: Vec::new(), hash_bits: 0, params }
    }

    /// Reconfigure the effort level (used by the thread-local reuse path).
    pub fn set_params(&mut self, params: MatchParams) {
        self.params = params;
    }

    fn prepare(&mut self, len: usize) {
        let bits = (usize::BITS - len.max(2).leading_zeros()).clamp(MIN_HASH_BITS, MAX_HASH_BITS);
        if self.hash_bits != bits || self.head.len() != 1 << bits {
            self.hash_bits = bits;
            self.head.clear();
            self.head.resize(1 << bits, -1);
        } else {
            self.head.iter_mut().for_each(|h| *h = -1);
        }
        self.prev.clear();
        self.prev.resize(len, -1);
    }

    #[inline]
    fn longest_match(&self, data: &[u8], pos: usize, best_so_far: usize) -> Option<(usize, usize)> {
        let max_len = (data.len() - pos).min(MAX_MATCH);
        if max_len < HASH_BYTES {
            return None;
        }
        let mut best_len = best_so_far.max(MIN_MATCH - 1);
        let mut best_dist = 0usize;
        let mut cand = self.head[hash4(data, pos, self.hash_bits)];
        let min_pos = pos.saturating_sub(MAX_DIST) as i32;
        let mut chain = self.params.max_chain;
        while cand >= min_pos && chain > 0 {
            let c = cand as usize;
            debug_assert!(c < pos);
            // Quick reject: compare the byte that would extend the match.
            if best_len < max_len
                && data[c + best_len] == data[pos + best_len]
                && data[c] == data[pos]
            {
                let l = match_len(data, c, pos, max_len);
                if l > best_len {
                    best_len = l;
                    best_dist = pos - c;
                    if l >= self.params.good_len || l == max_len {
                        break;
                    }
                }
            }
            cand = self.prev[c];
            chain -= 1;
        }
        if best_dist > 0 && best_len >= MIN_MATCH {
            Some((best_len, best_dist))
        } else {
            None
        }
    }

    /// Tokenize `data`, invoking `emit` for each token in order.
    /// `data.len()` must fit in i32 (callers segment at 256 KiB).
    pub fn tokenize(&mut self, data: &[u8], mut emit: impl FnMut(Token)) {
        let n = data.len();
        debug_assert!(n <= i32::MAX as usize);
        self.prepare(n);
        let bits = self.hash_bits;

        // Full insert: the entry joins its bucket's chain.
        let insert = |head: &mut Vec<i32>, prev: &mut Vec<i32>, data: &[u8], i: usize| {
            if i + HASH_BYTES <= data.len() {
                let h = hash4(data, i, bits);
                prev[i] = head[h];
                head[h] = i as i32;
            }
        };
        // Head-only insert for positions covered by an emitted match:
        // the entry is findable as the bucket head but is not linked to
        // the chain behind it (`prev` stays -1), so a covered span costs
        // one store per position instead of a chain splice.
        let insert_head = |head: &mut Vec<i32>, data: &[u8], i: usize| {
            if i + HASH_BYTES <= data.len() {
                head[hash4(data, i, bits)] = i as i32;
            }
        };

        let mut i = 0usize;
        while i < n {
            match self.longest_match(data, i, 0) {
                None => {
                    emit(Token::Literal(data[i]));
                    insert(&mut self.head, &mut self.prev, data, i);
                    i += 1;
                }
                Some((len, dist)) => {
                    let mut take = (len, dist);
                    let mut start = i;
                    // The match position always enters the chain; lazy
                    // evaluation only decides whether to also probe i+1
                    // for a strictly better match (emitting a literal
                    // here if so).
                    insert(&mut self.head, &mut self.prev, data, i);
                    if self.params.lazy && len < self.params.good_len && i + 1 < n {
                        if let Some((nlen, ndist)) = self.longest_match(data, i + 1, len) {
                            if nlen > len {
                                emit(Token::Literal(data[i]));
                                take = (nlen, ndist);
                                start = i + 1;
                            }
                        }
                    }
                    let (mlen, mdist) = take;
                    emit(Token::Match { len: mlen as u16, dist: mdist as u16 });
                    // Covered positions get head-only entries.
                    let end = start + mlen;
                    let from = if start == i { start + 1 } else { start };
                    for j in from..end.min(n.saturating_sub(HASH_BYTES - 1)) {
                        insert_head(&mut self.head, data, j);
                    }
                    i = end;
                }
            }
        }
    }
}

/// Reconstruct the original bytes from a token stream (used by tests and
/// as the reference semantics of [`Token`]).
pub fn detokenize(tokens: &[Token]) -> Vec<u8> {
    let mut out = Vec::new();
    for t in tokens {
        match *t {
            Token::Literal(b) => out.push(b),
            Token::Match { len, dist } => {
                let start = out.len() - dist as usize;
                for k in 0..len as usize {
                    let b = out[start + k];
                    out.push(b);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tokens_for(data: &[u8], level: u8) -> Vec<Token> {
        let mut m = Matcher::new(MatchParams::from_level(level));
        let mut v = Vec::new();
        m.tokenize(data, |t| v.push(t));
        v
    }

    #[test]
    fn tokens_reconstruct_input() {
        let cases: Vec<Vec<u8>> = vec![
            b"".to_vec(),
            b"a".to_vec(),
            b"abcabcabcabcabc".to_vec(),
            b"aaaaaaaaaaaaaaaaaaaaaaaaaaaaaaaa".to_vec(),
            (0..255u8).collect(),
            b"the quick brown fox jumps over the lazy dog the quick brown fox".to_vec(),
        ];
        for level in [1u8, 5, 9] {
            for data in &cases {
                assert_eq!(detokenize(&tokens_for(data, level)), *data);
            }
        }
    }

    #[test]
    fn match_len_is_exact_at_every_boundary() {
        // Agreement lengths 0..=40 cross both the word loop and the tail
        // loop; the divergence byte must be found exactly.
        for agree in 0..=40usize {
            let mut data = vec![0xAAu8; agree + 1];
            data.extend_from_slice(&vec![0xAAu8; agree]);
            data.push(0x55);
            // data[0..agree] == data[agree+1..2*agree+1], diverging after.
            let max = data.len() - (agree + 1);
            assert_eq!(match_len(&data, 0, agree + 1, max.min(agree + 1)), agree.min(max));
        }
    }

    #[test]
    fn repetitive_input_compresses_to_matches() {
        let data = b"abcdefgh".repeat(100);
        let toks = tokens_for(&data, 9);
        let matches = toks.iter().filter(|t| matches!(t, Token::Match { .. })).count();
        assert!(matches >= 1);
        // Token count far below byte count.
        assert!(toks.len() < data.len() / 4, "{} tokens for {} bytes", toks.len(), data.len());
        assert_eq!(detokenize(&toks), data);
    }

    #[test]
    fn long_runs_use_max_match() {
        let data = vec![b'x'; 4096];
        let toks = tokens_for(&data, 9);
        assert!(toks
            .iter()
            .any(|t| matches!(t, Token::Match { len, .. } if *len as usize == MAX_MATCH)));
        assert_eq!(detokenize(&toks), data);
    }

    #[test]
    fn distances_respect_window() {
        let mut data = b"UNIQUEPREFIX".to_vec();
        data.extend(std::iter::repeat(b'.').take(MAX_DIST + 100));
        data.extend_from_slice(b"UNIQUEPREFIX");
        let toks = tokens_for(&data, 9);
        for t in &toks {
            if let Token::Match { dist, .. } = t {
                assert!((*dist as usize) <= MAX_DIST);
            }
        }
        assert_eq!(detokenize(&toks), data);
    }

    #[test]
    fn pseudorandom_roundtrip() {
        let mut x = 0x1234_5678_9abc_def0u64;
        let data: Vec<u8> = (0..50_000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                (x & 0x3f) as u8 // small alphabet -> plenty of matches
            })
            .collect();
        for level in [1u8, 6, 9] {
            assert_eq!(detokenize(&tokens_for(&data, level)), data);
        }
    }
}
