//! The two-stage compression algorithm of §3.1, applied to a data block or
//! to each array element individually:
//!
//! stage 1 — concatenate
//!   1. the uncompressed size as an 8-byte big-endian unsigned integer,
//!   2. the byte `'z'`,
//!   3. the data as an RFC 1950/1951 deflate stream (any legal level);
//!
//! stage 2 — base64-encode stage 1 to 76-column lines (§ [`crate::codec::base64`]).
//!
//! Reading reverses the stages and performs the paper's three redundant
//! checks: the Adler-32 inside zlib, the uncompressed-size comparison, and
//! the `'z'` marker byte ("verifying that the ninth byte of the decoded
//! base64 data is indeed 'z'").
//!
//! When the optional preconditioning stage (SPEC §5.4) is enabled, the
//! marker byte is `'p'` followed by a one-byte transform descriptor, and
//! the zlib stream holds the transformed payload; decode self-configures
//! from the descriptor, so the knob exists only on the write side.

use crate::codec::base64::{decode_lines, encode_lines_into, encoded_len};
use crate::codec::lz77::{MatchParams, Matcher};
use crate::codec::precondition::Precond;
use crate::codec::zlib::{zlib_compress_into, zlib_decompress_into};
use crate::error::{corrupt, Result, ScdaError};
use crate::format::padding::LineStyle;

/// Compression settings for the convention layer.
#[derive(Debug, Clone, Copy)]
pub struct CodecOptions {
    /// Deflate effort 0..=9; the paper recommends zlib's best compression,
    /// and level 0 (stored) is the hardcodable no-zlib fallback.
    pub level: u8,
    /// Line-break style for base64 lines and surrounding padding.
    pub style: LineStyle,
    /// Optional shuffle/delta preconditioning inside the frame (`'p'`
    /// marker). `None` writes the plain `'z'` frame.
    pub precondition: Option<Precond>,
}

impl Default for CodecOptions {
    fn default() -> Self {
        CodecOptions { level: 9, style: LineStyle::Unix, precondition: None }
    }
}

/// Reusable per-worker state for element encode/decode: the LZ77 matcher
/// (hash table + chains) and the stage-1 buffer (size + marker + zlib
/// stream). One scratch per codec lane means zero steady-state
/// allocations on the per-element hot path; [`with_scratch`] supplies a
/// thread-local instance, which on the persistent worker pool *is*
/// per-worker state surviving across jobs.
#[derive(Default)]
pub struct CodecScratch {
    matcher: Option<Matcher>,
    stage1: Vec<u8>,
    /// Scratch for the preconditioning transform (forward staging on
    /// encode, plane staging on the in-place inverse).
    precond: Vec<u8>,
}

impl CodecScratch {
    pub fn new() -> Self {
        CodecScratch::default()
    }
}

/// Run `f` with this thread's codec scratch.
pub fn with_scratch<R>(f: impl FnOnce(&mut CodecScratch) -> R) -> R {
    thread_local! {
        static SCRATCH: std::cell::RefCell<CodecScratch> = std::cell::RefCell::new(CodecScratch::new());
    }
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

/// Apply both stages to one datum; the result's length is the datum's
/// "compressed size" in the enclosing scda section.
pub fn encode_element(data: &[u8], opts: CodecOptions) -> Vec<u8> {
    with_scratch(|scratch| {
        let mut out = Vec::new();
        encode_element_into(data, opts, scratch, &mut out);
        out
    })
}

/// [`encode_element`] appending to `out` with explicit scratch — the
/// codec pipeline's write-into contract: the only allocations are growth
/// of `out` and of the reused scratch buffers. Output bytes are a pure
/// function of `(data, opts)`, independent of scratch history — the
/// invariant that makes parallel per-element encoding bit-identical to
/// the serial path.
pub fn encode_element_into(data: &[u8], opts: CodecOptions, scratch: &mut CodecScratch, out: &mut Vec<u8>) {
    let CodecScratch { matcher, stage1, precond } = scratch;
    let matcher = matcher.get_or_insert_with(|| Matcher::new(MatchParams::from_level(9)));
    stage1.clear();
    stage1.reserve(10 + data.len() / 2 + 64);
    stage1.extend_from_slice(&(data.len() as u64).to_be_bytes());
    match opts.precondition {
        None => {
            stage1.push(b'z');
            zlib_compress_into(data, opts.level, matcher, stage1);
        }
        Some(p) => {
            stage1.push(b'p');
            stage1.push(p.descriptor());
            precond.clear();
            p.forward_into(data, precond);
            zlib_compress_into(precond, opts.level, matcher, stage1);
        }
    }
    out.reserve(encoded_len(stage1.len()));
    encode_lines_into(stage1, opts.style, out);
}

/// Invert [`encode_element`]. The compressed length is known from file
/// context (the enclosing section's size entries), hence `encoded` is the
/// exact stream. Verifies all three redundant checks.
pub fn decode_element(encoded: &[u8]) -> Result<Vec<u8>> {
    with_scratch(|scratch| {
        let mut out = Vec::new();
        decode_element_into(encoded, scratch, &mut out)?;
        Ok(out)
    })
}

/// [`decode_element`] appending to `out` (which may hold previously
/// decoded elements) with explicit scratch; returns the number of bytes
/// appended. On error `out`'s length is restored (capacity may grow).
pub fn decode_element_into(encoded: &[u8], scratch: &mut CodecScratch, out: &mut Vec<u8>) -> Result<usize> {
    let CodecScratch { stage1, precond, .. } = scratch;
    stage1.clear();
    crate::codec::base64::decode_lines_into(encoded, stage1)?;
    if stage1.len() < 9 {
        return Err(ScdaError::corrupt(
            corrupt::BAD_CONVENTION,
            "decoded compression frame shorter than size+marker",
        ));
    }
    let usize_bytes: [u8; 8] = stage1[..8].try_into().unwrap();
    let uncompressed = u64::from_be_bytes(usize_bytes);
    // The marker byte selects the frame variant: plain zlib ('z') or
    // preconditioned ('p' + descriptor, SPEC §5.4).
    let (transform, body_at) = match stage1[8] {
        b'z' => (None, 9usize),
        b'p' => {
            if stage1.len() < 10 {
                return Err(ScdaError::corrupt(
                    corrupt::BAD_CONVENTION,
                    "preconditioned frame lacks descriptor byte",
                ));
            }
            (Some(Precond::from_descriptor(stage1[9])?), 10usize)
        }
        other => {
            return Err(ScdaError::corrupt(
                corrupt::BAD_CONVENTION,
                format!("ninth byte of compression frame is {other:#04x}, expected 'z' or 'p'"),
            ));
        }
    };
    let expected = usize::try_from(uncompressed).map_err(|_| {
        ScdaError::corrupt(corrupt::COUNT_OVERFLOW, "uncompressed size exceeds addressable memory")
    })?;
    // zlib's own Adler-32 verification plus the size comparison happen here.
    let base = out.len();
    let appended = zlib_decompress_into(&stage1[body_at..], Some(expected), out)?;
    debug_assert_eq!(appended, expected);
    if let Some(p) = transform {
        p.inverse_in_place(&mut out[base..], precond);
    }
    Ok(appended)
}

/// Uncompressed size recorded in an encoded element without inflating it
/// (used by skip paths and `scda info`).
pub fn peek_uncompressed_size(encoded: &[u8]) -> Result<u64> {
    let stage1 = decode_lines(encoded)?;
    if stage1.len() < 9 || !matches!(stage1[8], b'z' | b'p') {
        return Err(ScdaError::corrupt(corrupt::BAD_CONVENTION, "malformed compression frame"));
    }
    Ok(u64::from_be_bytes(stage1[..8].try_into().unwrap()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(level: u8, style: LineStyle) -> CodecOptions {
        CodecOptions { level, style, precondition: None }
    }

    #[test]
    fn roundtrip_various_payloads() {
        let payloads: Vec<Vec<u8>> = vec![
            vec![],
            b"x".to_vec(),
            b"ASCII armored user data\n".to_vec(),
            vec![0u8; 10_000],
            (0..60_000u32).map(|i| (i % 256) as u8).collect(),
        ];
        for style in [LineStyle::Unix, LineStyle::Mime] {
            for level in [0u8, 6, 9] {
                for p in &payloads {
                    let enc = encode_element(p, opts(level, style));
                    assert_eq!(decode_element(&enc).unwrap(), *p);
                    assert_eq!(peek_uncompressed_size(&enc).unwrap(), p.len() as u64);
                }
            }
        }
    }

    #[test]
    fn into_variants_append_and_reuse_scratch() {
        // One scratch, many elements, one output buffer: the _into
        // contract. The bytes must equal per-element encode_element
        // results concatenated (scratch history leaks nothing).
        let elements: Vec<Vec<u8>> = vec![
            vec![],
            b"abc".to_vec(),
            vec![7u8; 5000],
            (0..4096u32).flat_map(|i| i.to_le_bytes()).collect(),
        ];
        for level in [0u8, 9] {
            let o = opts(level, LineStyle::Unix);
            let mut scratch = CodecScratch::new();
            let mut joined = Vec::new();
            let mut sizes = Vec::new();
            for e in &elements {
                let before = joined.len();
                encode_element_into(e, o, &mut scratch, &mut joined);
                sizes.push(joined.len() - before);
            }
            let reference: Vec<u8> = elements.iter().flat_map(|e| encode_element(e, o)).collect();
            assert_eq!(joined, reference, "level {level}");
            // Decode them back out of the joined stream with one scratch
            // into one buffer.
            let mut decoded = Vec::new();
            let mut at = 0usize;
            for (e, s) in elements.iter().zip(&sizes) {
                let n = decode_element_into(&joined[at..at + s], &mut scratch, &mut decoded).unwrap();
                assert_eq!(n, e.len());
                at += s;
            }
            assert_eq!(decoded, elements.concat());
        }
    }

    #[test]
    fn decode_into_restores_length_on_error() {
        let good = encode_element(b"good data here", CodecOptions::default());
        let mut out = b"prefix".to_vec();
        let mut scratch = CodecScratch::new();
        // Corrupt the zlib body (flip a bit past the frame header).
        let mut stage1 = crate::codec::base64::decode_lines(&good).unwrap();
        let n = stage1.len();
        stage1[n - 1] ^= 0x01; // adler trailer
        let bad = crate::codec::base64::encode_lines(&stage1, LineStyle::Unix);
        assert!(decode_element_into(&bad, &mut scratch, &mut out).is_err());
        assert_eq!(out, b"prefix");
        // And a clean decode into the same buffer still appends.
        decode_element_into(&good, &mut scratch, &mut out).unwrap();
        assert_eq!(&out[..6], b"prefix");
        assert_eq!(&out[6..], b"good data here");
    }

    #[test]
    fn encoded_stream_is_ascii() {
        // "the result is in ASCII (as long as the line breaks are)".
        let data: Vec<u8> = (0..=255u8).collect();
        let enc = encode_element(&data, CodecOptions::default());
        assert!(enc.iter().all(|&b| b.is_ascii()));
    }

    #[test]
    fn marker_byte_checked() {
        let enc = encode_element(b"data", CodecOptions::default());
        let mut stage1 = crate::codec::base64::decode_lines(&enc).unwrap();
        stage1[8] = b'q';
        let bad = crate::codec::base64::encode_lines(&stage1, LineStyle::Unix);
        let err = decode_element(&bad).unwrap_err();
        assert_eq!(err.code(), 1000 + corrupt::BAD_CONVENTION);
    }

    #[test]
    fn recorded_size_checked() {
        let enc = encode_element(b"data", CodecOptions::default());
        let mut stage1 = crate::codec::base64::decode_lines(&enc).unwrap();
        stage1[7] = 99; // claim 99 bytes uncompressed
        let bad = crate::codec::base64::encode_lines(&stage1, LineStyle::Unix);
        assert!(decode_element(&bad).is_err());
    }

    #[test]
    fn level_zero_is_conforming() {
        // "it is possible to conform by using level 0 (no compression)".
        let data = b"no zlib available on this machine".to_vec();
        let enc = encode_element(&data, opts(0, LineStyle::Unix));
        assert_eq!(decode_element(&enc).unwrap(), data);
        // Level 0 output is larger than input (stored + framing overhead).
        assert!(enc.len() > data.len());
    }

    #[test]
    fn compresses_compressible_data() {
        let data = vec![b'a'; 100_000];
        let enc = encode_element(&data, CodecOptions::default());
        assert!(enc.len() < data.len() / 50, "len {}", enc.len());
    }

    #[test]
    fn preconditioned_frames_roundtrip() {
        let payloads: Vec<Vec<u8>> = vec![
            vec![],
            b"x".to_vec(),
            (0..10_000u32).flat_map(|i| (7 * i).to_le_bytes()).collect(),
            (0..4096u64).flat_map(|i| (i as f64).sqrt().to_le_bytes()).collect(),
            vec![0xEE; 777], // length not a multiple of any width > 1
        ];
        for width in [1u8, 2, 4, 8] {
            for delta in [false, true] {
                let o = CodecOptions {
                    precondition: Some(Precond::new(width, delta).unwrap()),
                    ..CodecOptions::default()
                };
                for p in &payloads {
                    let enc = encode_element(p, o);
                    assert!(enc.iter().all(|&b| b.is_ascii()));
                    assert_eq!(decode_element(&enc).unwrap(), *p, "w={width} d={delta}");
                    assert_eq!(peek_uncompressed_size(&enc).unwrap(), p.len() as u64);
                }
            }
        }
    }

    #[test]
    fn preconditioned_frame_descriptor_is_wire_visible() {
        // The tenth stage-1 byte is the descriptor; readers self-configure
        // from it, so a truncated descriptor must be rejected cleanly.
        let o = CodecOptions {
            precondition: Some(Precond::new(8, true).unwrap()),
            ..CodecOptions::default()
        };
        let enc = encode_element(b"0123456789abcdef", o);
        let stage1 = crate::codec::base64::decode_lines(&enc).unwrap();
        assert_eq!(stage1[8], b'p');
        assert_eq!(stage1[9], Precond::new(8, true).unwrap().descriptor());
        let truncated = crate::codec::base64::encode_lines(&stage1[..9], LineStyle::Unix);
        assert!(decode_element(&truncated).is_err());
        // A zero descriptor (width 0) is invalid.
        let mut bad = stage1.clone();
        bad[9] = 0;
        let bad = crate::codec::base64::encode_lines(&bad, LineStyle::Unix);
        assert!(decode_element(&bad).is_err());
    }
}
