//! The two-stage compression algorithm of §3.1, applied to a data block or
//! to each array element individually:
//!
//! stage 1 — concatenate
//!   1. the uncompressed size as an 8-byte big-endian unsigned integer,
//!   2. the byte `'z'`,
//!   3. the data as an RFC 1950/1951 deflate stream (any legal level);
//!
//! stage 2 — base64-encode stage 1 to 76-column lines (§ [`crate::codec::base64`]).
//!
//! Reading reverses the stages and performs the paper's three redundant
//! checks: the Adler-32 inside zlib, the uncompressed-size comparison, and
//! the `'z'` marker byte ("verifying that the ninth byte of the decoded
//! base64 data is indeed 'z'").

use crate::codec::base64::{decode_lines, encode_lines};
use crate::codec::zlib::{zlib_compress, zlib_decompress};
use crate::error::{corrupt, Result, ScdaError};
use crate::format::padding::LineStyle;

/// Compression settings for the convention layer.
#[derive(Debug, Clone, Copy)]
pub struct CodecOptions {
    /// Deflate effort 0..=9; the paper recommends zlib's best compression,
    /// and level 0 (stored) is the hardcodable no-zlib fallback.
    pub level: u8,
    /// Line-break style for base64 lines and surrounding padding.
    pub style: LineStyle,
}

impl Default for CodecOptions {
    fn default() -> Self {
        CodecOptions { level: 9, style: LineStyle::Unix }
    }
}

/// Apply both stages to one datum; the result's length is the datum's
/// "compressed size" in the enclosing scda section.
pub fn encode_element(data: &[u8], opts: CodecOptions) -> Vec<u8> {
    let mut stage1 = Vec::with_capacity(9 + data.len() / 2 + 64);
    stage1.extend_from_slice(&(data.len() as u64).to_be_bytes());
    stage1.push(b'z');
    stage1.extend_from_slice(&zlib_compress(data, opts.level));
    encode_lines(&stage1, opts.style)
}

/// Invert [`encode_element`]. The compressed length is known from file
/// context (the enclosing section's size entries), hence `encoded` is the
/// exact stream. Verifies all three redundant checks.
pub fn decode_element(encoded: &[u8]) -> Result<Vec<u8>> {
    let stage1 = decode_lines(encoded)?;
    if stage1.len() < 9 {
        return Err(ScdaError::corrupt(
            corrupt::BAD_CONVENTION,
            "decoded compression frame shorter than size+marker",
        ));
    }
    let usize_bytes: [u8; 8] = stage1[..8].try_into().unwrap();
    let uncompressed = u64::from_be_bytes(usize_bytes);
    if stage1[8] != b'z' {
        return Err(ScdaError::corrupt(
            corrupt::BAD_CONVENTION,
            format!("ninth byte of compression frame is {:#04x}, expected 'z'", stage1[8]),
        ));
    }
    let expected = usize::try_from(uncompressed).map_err(|_| {
        ScdaError::corrupt(corrupt::COUNT_OVERFLOW, "uncompressed size exceeds addressable memory")
    })?;
    // zlib's own Adler-32 verification plus the size comparison happen here.
    let out = zlib_decompress(&stage1[9..], Some(expected))?;
    debug_assert_eq!(out.len(), expected);
    Ok(out)
}

/// Uncompressed size recorded in an encoded element without inflating it
/// (used by skip paths and `scda info`).
pub fn peek_uncompressed_size(encoded: &[u8]) -> Result<u64> {
    let stage1 = decode_lines(encoded)?;
    if stage1.len() < 9 || stage1[8] != b'z' {
        return Err(ScdaError::corrupt(corrupt::BAD_CONVENTION, "malformed compression frame"));
    }
    Ok(u64::from_be_bytes(stage1[..8].try_into().unwrap()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts(level: u8, style: LineStyle) -> CodecOptions {
        CodecOptions { level, style }
    }

    #[test]
    fn roundtrip_various_payloads() {
        let payloads: Vec<Vec<u8>> = vec![
            vec![],
            b"x".to_vec(),
            b"ASCII armored user data\n".to_vec(),
            vec![0u8; 10_000],
            (0..60_000u32).map(|i| (i % 256) as u8).collect(),
        ];
        for style in [LineStyle::Unix, LineStyle::Mime] {
            for level in [0u8, 6, 9] {
                for p in &payloads {
                    let enc = encode_element(p, opts(level, style));
                    assert_eq!(decode_element(&enc).unwrap(), *p);
                    assert_eq!(peek_uncompressed_size(&enc).unwrap(), p.len() as u64);
                }
            }
        }
    }

    #[test]
    fn encoded_stream_is_ascii() {
        // "the result is in ASCII (as long as the line breaks are)".
        let data: Vec<u8> = (0..=255u8).collect();
        let enc = encode_element(&data, CodecOptions::default());
        assert!(enc.iter().all(|&b| b.is_ascii()));
    }

    #[test]
    fn marker_byte_checked() {
        let enc = encode_element(b"data", CodecOptions::default());
        let mut stage1 = crate::codec::base64::decode_lines(&enc).unwrap();
        stage1[8] = b'q';
        let bad = crate::codec::base64::encode_lines(&stage1, LineStyle::Unix);
        let err = decode_element(&bad).unwrap_err();
        assert_eq!(err.code(), 1000 + corrupt::BAD_CONVENTION);
    }

    #[test]
    fn recorded_size_checked() {
        let enc = encode_element(b"data", CodecOptions::default());
        let mut stage1 = crate::codec::base64::decode_lines(&enc).unwrap();
        stage1[7] = 99; // claim 99 bytes uncompressed
        let bad = crate::codec::base64::encode_lines(&stage1, LineStyle::Unix);
        assert!(decode_element(&bad).is_err());
    }

    #[test]
    fn level_zero_is_conforming() {
        // "it is possible to conform by using level 0 (no compression)".
        let data = b"no zlib available on this machine".to_vec();
        let enc = encode_element(&data, opts(0, LineStyle::Unix));
        assert_eq!(decode_element(&enc).unwrap(), data);
        // Level 0 output is larger than input (stored + framing overhead).
        assert!(enc.len() > data.len());
    }

    #[test]
    fn compresses_compressible_data() {
        let data = vec![b'a'; 100_000];
        let enc = encode_element(&data, CodecOptions::default());
        assert!(enc.len() < data.len() / 50, "len {}", enc.len());
    }
}
