//! Error model for the scda API, following §A.6 of the paper.
//!
//! The paper mandates that file errors never crash a batch job: every API
//! call reports a *code* that can be translated to a human-readable string
//! (`scda_ferror_string`). We map the paper's three checked runtime error
//! groups onto [`ScdaErrorKind`]:
//!
//! 1. **corrupt file contents** — [`ScdaErrorKind::CorruptFile`],
//! 2. **file system errors** — [`ScdaErrorKind::Io`] (wrapping
//!    `std::io::Error`, the stand-in for MPI I/O error classes / `errno`),
//! 3. **semantically invalid input parameters or call sequence** —
//!    [`ScdaErrorKind::Usage`].
//!
//! In idiomatic Rust the code travels inside a `Result`; the numeric code of
//! the C API is preserved via [`ScdaError::code`] and the reverse mapping
//! [`ferror_string`].

use std::fmt;

/// The three checked error groups of §A.6, plus `Ok` for code 0.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ScdaErrorKind {
    /// Invalid file section metadata, bad magic, malformed padding, or a
    /// violation of the compression convention (§3) announced by a matching
    /// magic user string.
    CorruptFile,
    /// Any error reported by the file system access functions.
    Io,
    /// Parameters without legal meaning, or improperly composed calls
    /// (e.g. reading array data before its section header).
    Usage,
}

impl ScdaErrorKind {
    /// Base numeric code for the group (codes within a group are
    /// `base + detail`).
    pub fn base_code(self) -> i32 {
        match self {
            ScdaErrorKind::CorruptFile => 1000,
            ScdaErrorKind::Io => 2000,
            ScdaErrorKind::Usage => 3000,
        }
    }
}

/// An scda error: group, stable numeric code, and a rendered message.
#[derive(Debug)]
pub struct ScdaError {
    kind: ScdaErrorKind,
    detail: i32,
    message: String,
    source: Option<std::io::Error>,
}

impl ScdaError {
    pub fn corrupt(detail: i32, message: impl Into<String>) -> Self {
        ScdaError { kind: ScdaErrorKind::CorruptFile, detail, message: message.into(), source: None }
    }

    pub fn usage(detail: i32, message: impl Into<String>) -> Self {
        ScdaError { kind: ScdaErrorKind::Usage, detail, message: message.into(), source: None }
    }

    pub fn io(err: std::io::Error, context: impl Into<String>) -> Self {
        ScdaError {
            kind: ScdaErrorKind::Io,
            detail: err.raw_os_error().unwrap_or(0),
            message: context.into(),
            source: Some(err),
        }
    }

    pub fn kind(&self) -> ScdaErrorKind {
        self.kind
    }

    /// The stable numeric error code (0 is reserved for success and never
    /// produced by an `ScdaError`).
    pub fn code(&self) -> i32 {
        self.kind.base_code() + self.detail.clamp(0, 999)
    }

    pub fn message(&self) -> &str {
        &self.message
    }

    /// True for retryable I/O failures (`EINTR`-shaped): the engines'
    /// bounded-backoff retry (`crate::io::fault::retry_transient`)
    /// absorbs these; every other error passes through immediately.
    pub fn is_transient_io(&self) -> bool {
        self.kind == ScdaErrorKind::Io
            && (self.detail == 4 // EINTR
                || self.source.as_ref().is_some_and(|e| e.kind() == std::io::ErrorKind::Interrupted))
    }

    /// Reconstruct a typed error from its wire form `(code, message)` —
    /// the collective error-agreement transport: a rank that received a
    /// peer's error code re-raises it locally so every rank surfaces the
    /// *same* `ScdaError`. Codes outside the three groups degrade to a
    /// usage error (never a panic on a malformed frame).
    pub fn rebuild(code: i32, message: impl Into<String>) -> ScdaError {
        let message = message.into();
        match code {
            1000..=1999 => ScdaError::corrupt(code - 1000, message),
            2000..=2999 => ScdaError {
                kind: ScdaErrorKind::Io,
                detail: code - 2000,
                message,
                source: Some(std::io::Error::from_raw_os_error(code - 2000)),
            },
            3000..=3999 => ScdaError::usage(code - 3000, message),
            _ => ScdaError::usage(usage::NOT_COLLECTIVE, message),
        }
    }
}

impl fmt::Display for ScdaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let group = match self.kind {
            ScdaErrorKind::CorruptFile => "corrupt file",
            ScdaErrorKind::Io => "file system",
            ScdaErrorKind::Usage => "usage",
        };
        write!(f, "scda error {} [{}]: {}", self.code(), group, self.message)?;
        if let Some(src) = &self.source {
            write!(f, ": {src}")?;
        }
        Ok(())
    }
}

impl std::error::Error for ScdaError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.source.as_ref().map(|e| e as _)
    }
}

impl From<std::io::Error> for ScdaError {
    fn from(e: std::io::Error) -> Self {
        ScdaError::io(e, "I/O operation failed")
    }
}

/// Result alias used throughout the crate.
pub type Result<T> = std::result::Result<T, ScdaError>;

// Detail codes for corrupt-file errors (stable across releases; used by
// failure-injection tests to assert we detect *which* corruption occurred).
pub mod corrupt {
    pub const BAD_MAGIC: i32 = 1;
    pub const BAD_VERSION: i32 = 2;
    pub const BAD_STRING_PADDING: i32 = 3;
    pub const BAD_DATA_PADDING: i32 = 4;
    pub const BAD_COUNT_ENTRY: i32 = 5;
    pub const BAD_SECTION_TYPE: i32 = 6;
    pub const TRUNCATED: i32 = 7;
    pub const BAD_CONVENTION: i32 = 8;
    pub const BAD_BASE64: i32 = 9;
    pub const BAD_ZLIB: i32 = 10;
    pub const BAD_CHECKSUM: i32 = 11;
    pub const SIZE_MISMATCH: i32 = 12;
    pub const COUNT_OVERFLOW: i32 = 13;
    /// The archive catalog section (`scda:catalog`) or the footer index
    /// that locates it is malformed, or disagrees with the sections it
    /// describes (see `crate::archive`).
    pub const BAD_CATALOG: i32 = 14;
    /// Data read back (or moved through a rebalance exchange) differs
    /// from the independently recomputed reference — the AMR scenario
    /// driver's end-to-end verification failed
    /// (see `crate::runtime::scenario`).
    pub const SCENARIO_MISMATCH: i32 = 15;
}

// Detail codes for usage errors.
pub mod usage {
    pub const BAD_MODE: i32 = 1;
    pub const STRING_TOO_LONG: i32 = 2;
    pub const INLINE_SIZE: i32 = 3;
    pub const PARTITION_MISMATCH: i32 = 4;
    pub const CALL_SEQUENCE: i32 = 5;
    pub const COUNT_TOO_LARGE: i32 = 6;
    pub const NOT_COLLECTIVE: i32 = 7;
    pub const WRONG_SECTION: i32 = 8;
    pub const BUFFER_SIZE: i32 = 9;
    pub const NO_SUCH_DATASET: i32 = 10;
    pub const BAD_DATASET_NAME: i32 = 11;
    /// An element range (`first`, `count`) reaches outside the dataset
    /// (see `crate::archive::Archive::read_range`).
    pub const BAD_RANGE: i32 = 12;
    /// A driver configuration is internally inconsistent (zero ranks or
    /// cycles, refinement floor above the cap, a crash plan that never
    /// fires — see `crate::runtime::scenario::ScenarioConfig`).
    pub const BAD_CONFIG: i32 = 13;
}

/// Translate an error code to a string, mirroring `scda_ferror_string`
/// (§A.6.1). Returns `None` for codes that are not valid scda codes;
/// code 0 translates to `"success"`.
pub fn ferror_string(code: i32) -> Option<&'static str> {
    Some(match code {
        0 => "success",
        c if c == 1000 + corrupt::BAD_MAGIC => "corrupt file: bad magic bytes",
        c if c == 1000 + corrupt::BAD_VERSION => "corrupt file: unsupported format version",
        c if c == 1000 + corrupt::BAD_STRING_PADDING => "corrupt file: malformed string padding",
        c if c == 1000 + corrupt::BAD_DATA_PADDING => "corrupt file: malformed data padding",
        c if c == 1000 + corrupt::BAD_COUNT_ENTRY => "corrupt file: malformed count entry",
        c if c == 1000 + corrupt::BAD_SECTION_TYPE => "corrupt file: unknown section type",
        c if c == 1000 + corrupt::TRUNCATED => "corrupt file: unexpected end of file",
        c if c == 1000 + corrupt::BAD_CONVENTION => "corrupt file: compression convention violated",
        c if c == 1000 + corrupt::BAD_BASE64 => "corrupt file: invalid base64 stream",
        c if c == 1000 + corrupt::BAD_ZLIB => "corrupt file: invalid zlib stream",
        c if c == 1000 + corrupt::BAD_CHECKSUM => "corrupt file: checksum mismatch",
        c if c == 1000 + corrupt::SIZE_MISMATCH => "corrupt file: uncompressed size mismatch",
        c if c == 1000 + corrupt::COUNT_OVERFLOW => "corrupt file: count exceeds 26 decimal digits",
        c if c == 1000 + corrupt::BAD_CATALOG => "corrupt file: malformed archive catalog",
        c if c == 1000 + corrupt::SCENARIO_MISMATCH => "corrupt data: scenario verification mismatch",
        c if (1000..2000).contains(&c) => "corrupt file contents",
        c if (2000..3000).contains(&c) => "file system error",
        c if c == 3000 + usage::BAD_MODE => "usage: invalid open mode",
        c if c == 3000 + usage::STRING_TOO_LONG => "usage: user string exceeds maximum length",
        c if c == 3000 + usage::INLINE_SIZE => "usage: inline data must be exactly 32 bytes",
        c if c == 3000 + usage::PARTITION_MISMATCH => "usage: partition does not sum to element count",
        c if c == 3000 + usage::CALL_SEQUENCE => "usage: improperly composed call sequence",
        c if c == 3000 + usage::COUNT_TOO_LARGE => "usage: count exceeds 26 decimal digits",
        c if c == 3000 + usage::NOT_COLLECTIVE => "usage: collective parameter mismatch",
        c if c == 3000 + usage::WRONG_SECTION => "usage: call does not match current section type",
        c if c == 3000 + usage::BUFFER_SIZE => "usage: buffer size inconsistent with metadata",
        c if c == 3000 + usage::NO_SUCH_DATASET => "usage: no dataset with that name in the archive",
        c if c == 3000 + usage::BAD_DATASET_NAME => "usage: invalid or duplicate dataset name",
        c if c == 3000 + usage::BAD_RANGE => "usage: element range outside the dataset",
        c if c == 3000 + usage::BAD_CONFIG => "usage: inconsistent driver configuration",
        c if (3000..4000).contains(&c) => "semantically invalid input or call sequence",
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_roundtrip_to_strings() {
        assert_eq!(ferror_string(0), Some("success"));
        let e = ScdaError::corrupt(corrupt::BAD_MAGIC, "x");
        assert_eq!(ferror_string(e.code()), Some("corrupt file: bad magic bytes"));
        let u = ScdaError::usage(usage::INLINE_SIZE, "x");
        assert!(ferror_string(u.code()).unwrap().contains("32 bytes"));
        assert_eq!(ferror_string(-1), None);
        assert_eq!(ferror_string(99999), None);
    }

    #[test]
    fn io_errors_carry_source() {
        let ioe = std::io::Error::new(std::io::ErrorKind::PermissionDenied, "denied");
        let e = ScdaError::io(ioe, "opening checkpoint");
        assert_eq!(e.kind(), ScdaErrorKind::Io);
        assert!(e.to_string().contains("opening checkpoint"));
        assert!(std::error::Error::source(&e).is_some());
    }

    #[test]
    fn rebuild_roundtrips_codes_across_groups() {
        for code in [1000 + corrupt::TRUNCATED, 2004, 2000, 3000 + usage::BAD_RANGE] {
            let e = ScdaError::rebuild(code, "peer error");
            assert_eq!(e.code(), code, "code {code}");
            assert!(e.to_string().contains("peer error"));
        }
        // EINTR-shaped rebuilds stay recognizably transient.
        assert!(ScdaError::rebuild(2004, "x").is_transient_io());
        assert!(!ScdaError::rebuild(2005, "x").is_transient_io());
        // Out-of-range codes degrade to usage, never panic.
        assert_eq!(ScdaError::rebuild(17, "x").kind(), ScdaErrorKind::Usage);
    }

    #[test]
    fn group_ranges_have_fallback_strings() {
        assert_eq!(ferror_string(1999), Some("corrupt file contents"));
        assert_eq!(ferror_string(2500), Some("file system error"));
        assert_eq!(ferror_string(3999), Some("semantically invalid input or call sequence"));
    }
}
