//! C ABI mirroring the paper's Appendix A verbatim — the interface a C
//! simulation code (libsc/p4est-style) links against. Serial-communicator
//! backed: the C caller owns process-level parallelism (each process
//! writes its window through the partition arguments exactly as in §A.4,
//! with the collective agreement contract on the caller).
//!
//! Conventions:
//! * every function sets `*err` to an §A.6 error code (0 = success);
//! * `NULL` data pointers mean "skip" exactly where the paper allows it;
//! * strings are raw byte buffers with explicit lengths (the format does
//!   not interpret them; no NUL-termination requirements).
//!
//! Memory rules: `scda_fopen_*` returns an owned handle; every path ends
//! in `scda_fclose`, which frees it (also on error paths, matching "the
//! file context is deallocated regardless").

use std::ffi::{c_char, c_int};

use crate::api::{DataSrc, ScdaFile};
use crate::error::{usage, ScdaError};
use crate::par::{Partition, SerialComm};

/// Opaque file context (`f` in the paper).
pub struct ScdaHandle {
    file: Option<ScdaFile<SerialComm>>,
}

fn set_err(err: *mut c_int, code: c_int) {
    if !err.is_null() {
        unsafe { *err = code };
    }
}

fn fail(err: *mut c_int, e: &ScdaError) {
    set_err(err, e.code());
}

unsafe fn slice<'a>(ptr: *const u8, len: usize) -> &'a [u8] {
    if ptr.is_null() || len == 0 {
        &[]
    } else {
        std::slice::from_raw_parts(ptr, len)
    }
}

unsafe fn path_from(ptr: *const c_char) -> Option<std::path::PathBuf> {
    if ptr.is_null() {
        return None;
    }
    let cstr = std::ffi::CStr::from_ptr(ptr);
    Some(std::path::PathBuf::from(std::ffi::OsStr::new(
        std::str::from_utf8(cstr.to_bytes()).ok()?,
    )))
}

/// `scda_fopen(..., 'w'|'r', ...)`. `mode` is the ASCII letter. Returns
/// NULL on error with `*err` set. The user string applies in write mode.
///
/// # Safety
/// `filename` must be a valid NUL-terminated path; `userstr` (may be
/// NULL) must reference `userlen` readable bytes; `err` may be NULL.
#[no_mangle]
pub unsafe extern "C" fn scda_fopen(
    filename: *const c_char,
    mode: c_char,
    userstr: *const u8,
    userlen: usize,
    err: *mut c_int,
) -> *mut ScdaHandle {
    set_err(err, 0);
    let Some(path) = path_from(filename) else {
        set_err(err, 3000 + usage::BAD_MODE);
        return std::ptr::null_mut();
    };
    let result = match mode as u8 {
        b'w' => ScdaFile::create(SerialComm::new(), &path, slice(userstr, userlen)),
        b'r' => ScdaFile::open(SerialComm::new(), &path),
        _ => {
            set_err(err, 3000 + usage::BAD_MODE);
            return std::ptr::null_mut();
        }
    };
    match result {
        Ok(file) => Box::into_raw(Box::new(ScdaHandle { file: Some(file) })),
        Err(e) => {
            fail(err, &e);
            std::ptr::null_mut()
        }
    }
}

/// `scda_fclose`. Frees the handle regardless of outcome; returns 0 on
/// success.
///
/// # Safety
/// `f` must be a handle from `scda_fopen` not yet closed.
#[no_mangle]
pub unsafe extern "C" fn scda_fclose(f: *mut ScdaHandle, err: *mut c_int) -> c_int {
    set_err(err, 0);
    if f.is_null() {
        set_err(err, 3000 + usage::CALL_SEQUENCE);
        return -1;
    }
    let mut handle = Box::from_raw(f);
    match handle.file.take().map(|file| file.close()) {
        Some(Ok(())) => 0,
        Some(Err(e)) => {
            fail(err, &e);
            -1
        }
        None => {
            set_err(err, 3000 + usage::CALL_SEQUENCE);
            -1
        }
    }
}

unsafe fn with_file<R>(
    f: *mut ScdaHandle,
    err: *mut c_int,
    op: impl FnOnce(&mut ScdaFile<SerialComm>) -> crate::error::Result<R>,
) -> Option<R> {
    set_err(err, 0);
    let Some(handle) = f.as_mut() else {
        set_err(err, 3000 + usage::CALL_SEQUENCE);
        return None;
    };
    let Some(file) = handle.file.as_mut() else {
        set_err(err, 3000 + usage::CALL_SEQUENCE);
        return None;
    };
    match op(file) {
        Ok(r) => Some(r),
        Err(e) => {
            fail(err, &e);
            None
        }
    }
}

/// `scda_fwrite_inline` (§A.4.1): exactly 32 bytes.
///
/// # Safety
/// Pointers must reference the stated lengths; see module docs.
#[no_mangle]
pub unsafe extern "C" fn scda_fwrite_inline(
    f: *mut ScdaHandle,
    dbytes: *const u8,
    userstr: *const u8,
    userlen: usize,
    err: *mut c_int,
) -> c_int {
    let user = slice(userstr, userlen).to_vec();
    let data = slice(dbytes, 32).to_vec();
    match with_file(f, err, |file| file.write_inline(&data, Some(&user))) {
        Some(()) => 0,
        None => -1,
    }
}

/// `scda_fwrite_block` (§A.4.2).
///
/// # Safety
/// `dbytes` must reference `len` bytes; see module docs.
#[no_mangle]
pub unsafe extern "C" fn scda_fwrite_block(
    f: *mut ScdaHandle,
    dbytes: *const u8,
    len: u64,
    userstr: *const u8,
    userlen: usize,
    encode: c_int,
    err: *mut c_int,
) -> c_int {
    let user = slice(userstr, userlen).to_vec();
    let data = slice(dbytes, len as usize).to_vec();
    match with_file(f, err, |file| {
        file.write_block_from(0, Some(&data), len, Some(&user), encode != 0)
    }) {
        Some(()) => 0,
        None => -1,
    }
}

/// `scda_fwrite_array` (§A.4.3), serial view: the caller is the only
/// process, so `N_p = N` and `dbytes` holds all `N * E` bytes.
///
/// # Safety
/// `dbytes` must reference `n * elem_size` bytes.
#[no_mangle]
pub unsafe extern "C" fn scda_fwrite_array(
    f: *mut ScdaHandle,
    dbytes: *const u8,
    n: u64,
    elem_size: u64,
    userstr: *const u8,
    userlen: usize,
    encode: c_int,
    err: *mut c_int,
) -> c_int {
    let user = slice(userstr, userlen).to_vec();
    let data = slice(dbytes, (n * elem_size) as usize);
    let part = Partition::uniform(1, n);
    match with_file(f, err, |file| {
        file.write_array(DataSrc::Contiguous(data), &part, elem_size, Some(&user), encode != 0)
    }) {
        Some(()) => 0,
        None => -1,
    }
}

/// `scda_fwrite_varray` (§A.4.4), serial view: `sizes` holds all `N`
/// element byte counts, `dbytes` their concatenation.
///
/// # Safety
/// `sizes` must reference `n` u64s; `dbytes` their sum in bytes.
#[no_mangle]
pub unsafe extern "C" fn scda_fwrite_varray(
    f: *mut ScdaHandle,
    dbytes: *const u8,
    n: u64,
    sizes: *const u64,
    userstr: *const u8,
    userlen: usize,
    encode: c_int,
    err: *mut c_int,
) -> c_int {
    let user = slice(userstr, userlen).to_vec();
    let sz: &[u64] =
        if sizes.is_null() { &[] } else { std::slice::from_raw_parts(sizes, n as usize) };
    let total: u64 = sz.iter().sum();
    let data = slice(dbytes, total as usize);
    let part = Partition::uniform(1, n);
    match with_file(f, err, |file| {
        file.write_varray(DataSrc::Contiguous(data), &part, sz, Some(&user), encode != 0)
    }) {
        Some(()) => 0,
        None => -1,
    }
}

/// `scda_fread_section_header` (§A.5.1). Outputs: `*kind` is the section
/// letter ('I','B','A','V'); `*n`, `*e` per Table in §A.5.1; the user
/// string is copied into `userstr` (capacity `*userlen`, actual written
/// back); `*decode` is in/out per Table 2.
///
/// # Safety
/// All out-pointers must be valid; `userstr` must have `*userlen` bytes.
#[no_mangle]
pub unsafe extern "C" fn scda_fread_section_header(
    f: *mut ScdaHandle,
    kind: *mut c_char,
    n: *mut u64,
    e: *mut u64,
    userstr: *mut u8,
    userlen: *mut usize,
    decode: *mut c_int,
    err: *mut c_int,
) -> c_int {
    let want_decode = !decode.is_null() && *decode != 0;
    match with_file(f, err, |file| file.read_section_header(want_decode)) {
        Some(h) => {
            if !kind.is_null() {
                *kind = h.kind.letter() as c_char;
            }
            if !n.is_null() {
                *n = h.elem_count;
            }
            if !e.is_null() {
                *e = h.elem_size;
            }
            if !decode.is_null() {
                *decode = h.decoded as c_int;
            }
            if !userstr.is_null() && !userlen.is_null() {
                let cap = *userlen;
                let take = h.user.len().min(cap);
                std::ptr::copy_nonoverlapping(h.user.as_ptr(), userstr, take);
                *userlen = take;
            }
            0
        }
        None => -1,
    }
}

/// `scda_fread_inline_data` (§A.5.2): 32 bytes into `dbytes` (NULL skips).
///
/// # Safety
/// `dbytes`, when non-NULL, must have 32 writable bytes.
#[no_mangle]
pub unsafe extern "C" fn scda_fread_inline_data(f: *mut ScdaHandle, dbytes: *mut u8, err: *mut c_int) -> c_int {
    let want = !dbytes.is_null();
    match with_file(f, err, |file| file.read_inline_data(0, want)) {
        Some(Some(data)) => {
            std::ptr::copy_nonoverlapping(data.as_ptr(), dbytes, 32);
            0
        }
        Some(None) => 0,
        None => -1,
    }
}

/// `scda_fread_block_data` (§A.5.3): `n` bytes into `dbytes` (NULL skips).
///
/// # Safety
/// `dbytes`, when non-NULL, must have `n` writable bytes.
#[no_mangle]
pub unsafe extern "C" fn scda_fread_block_data(
    f: *mut ScdaHandle,
    dbytes: *mut u8,
    n: u64,
    err: *mut c_int,
) -> c_int {
    let want = !dbytes.is_null();
    match with_file(f, err, |file| {
        let out = file.read_block_data(0, want)?;
        if let Some(data) = &out {
            if data.len() as u64 != n {
                return Err(ScdaError::usage(
                    usage::BUFFER_SIZE,
                    format!("buffer of {n} bytes for a {}-byte block", data.len()),
                ));
            }
        }
        Ok(out)
    }) {
        Some(Some(data)) => {
            std::ptr::copy_nonoverlapping(data.as_ptr(), dbytes, data.len());
            0
        }
        Some(None) => 0,
        None => -1,
    }
}

/// `scda_fread_array_data` (§A.5.4), serial view (`N_p = N`).
///
/// # Safety
/// `dbytes`, when non-NULL, must have `n * elem_size` writable bytes.
#[no_mangle]
pub unsafe extern "C" fn scda_fread_array_data(
    f: *mut ScdaHandle,
    dbytes: *mut u8,
    n: u64,
    elem_size: u64,
    err: *mut c_int,
) -> c_int {
    let want = !dbytes.is_null();
    let part = Partition::uniform(1, n);
    match with_file(f, err, |file| file.read_array_data(&part, elem_size, want)) {
        Some(Some(data)) => {
            std::ptr::copy_nonoverlapping(data.as_ptr(), dbytes, data.len());
            0
        }
        Some(None) => 0,
        None => -1,
    }
}

/// `scda_fread_varray_sizes` (§A.5.5): `n` u64 sizes into `sizes`.
///
/// # Safety
/// `sizes` must have `n` writable u64 slots.
#[no_mangle]
pub unsafe extern "C" fn scda_fread_varray_sizes(
    f: *mut ScdaHandle,
    sizes: *mut u64,
    n: u64,
    err: *mut c_int,
) -> c_int {
    let part = Partition::uniform(1, n);
    match with_file(f, err, |file| file.read_varray_sizes(&part)) {
        Some(out) => {
            if !sizes.is_null() {
                std::ptr::copy_nonoverlapping(out.as_ptr(), sizes, out.len());
            }
            0
        }
        None => -1,
    }
}

/// `scda_fread_varray_data` (§A.5.6).
///
/// # Safety
/// `sizes` must hold the values from `scda_fread_varray_sizes`; `dbytes`,
/// when non-NULL, must have their sum in writable bytes.
#[no_mangle]
pub unsafe extern "C" fn scda_fread_varray_data(
    f: *mut ScdaHandle,
    dbytes: *mut u8,
    n: u64,
    sizes: *const u64,
    err: *mut c_int,
) -> c_int {
    let part = Partition::uniform(1, n);
    let sz: &[u64] =
        if sizes.is_null() { &[] } else { std::slice::from_raw_parts(sizes, n as usize) };
    let want = !dbytes.is_null();
    match with_file(f, err, |file| file.read_varray_data(&part, sz, want)) {
        Some(Some(data)) => {
            std::ptr::copy_nonoverlapping(data.as_ptr(), dbytes, data.len());
            0
        }
        Some(None) => 0,
        None => -1,
    }
}

/// `scda_ferror_string` (§A.6.1): translate `err` into `buf` (capacity
/// `*buflen`; written length returned through it). Returns 0 for valid
/// codes (including 0) and a negative value otherwise.
///
/// # Safety
/// `buf` must have `*buflen` writable bytes; `buflen` must be valid.
#[no_mangle]
pub unsafe extern "C" fn scda_ferror_string(err: c_int, buf: *mut c_char, buflen: *mut usize) -> c_int {
    let Some(msg) = crate::error::ferror_string(err) else {
        return -1;
    };
    if !buf.is_null() && !buflen.is_null() {
        let take = msg.len().min(*buflen);
        std::ptr::copy_nonoverlapping(msg.as_ptr() as *const c_char, buf, take);
        *buflen = take;
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::ffi::CString;

    fn tmp(name: &str) -> CString {
        let dir = std::env::temp_dir().join("scda-capi");
        std::fs::create_dir_all(&dir).unwrap();
        CString::new(dir.join(format!("{name}-{}.scda", std::process::id())).to_str().unwrap()).unwrap()
    }

    #[test]
    fn c_api_roundtrip_all_sections() {
        unsafe {
            let path = tmp("roundtrip");
            let mut err: c_int = -1;
            let f = scda_fopen(path.as_ptr(), b'w' as c_char, b"capi".as_ptr(), 4, &mut err);
            assert_eq!(err, 0);
            assert!(!f.is_null());
            let inline = [b'z'; 32];
            assert_eq!(scda_fwrite_inline(f, inline.as_ptr(), b"i".as_ptr(), 1, &mut err), 0);
            let block = b"global block";
            assert_eq!(scda_fwrite_block(f, block.as_ptr(), 12, b"b".as_ptr(), 1, 1, &mut err), 0);
            let arr: Vec<u8> = (0..60).collect();
            assert_eq!(scda_fwrite_array(f, arr.as_ptr(), 10, 6, b"a".as_ptr(), 1, 0, &mut err), 0);
            let sizes = [3u64, 0, 5];
            let vdata: Vec<u8> = (0..8).collect();
            assert_eq!(scda_fwrite_varray(f, vdata.as_ptr(), 3, sizes.as_ptr(), b"v".as_ptr(), 1, 0, &mut err), 0);
            assert_eq!(scda_fclose(f, &mut err), 0);
            assert_eq!(err, 0);

            // Read it back through the C surface.
            let f = scda_fopen(path.as_ptr(), b'r' as c_char, std::ptr::null(), 0, &mut err);
            assert_eq!(err, 0);
            let mut kind: c_char = 0;
            let (mut n, mut e) = (0u64, 0u64);
            let mut user = [0u8; 58];
            let mut userlen = user.len();
            let mut decode: c_int = 1;
            assert_eq!(
                scda_fread_section_header(f, &mut kind, &mut n, &mut e, user.as_mut_ptr(), &mut userlen, &mut decode, &mut err),
                0
            );
            assert_eq!(kind as u8, b'I');
            assert_eq!(&user[..userlen], b"i");
            let mut got = [0u8; 32];
            assert_eq!(scda_fread_inline_data(f, got.as_mut_ptr(), &mut err), 0);
            assert_eq!(got, inline);

            let mut userlen = user.len();
            let mut decode: c_int = 1;
            scda_fread_section_header(f, &mut kind, &mut n, &mut e, user.as_mut_ptr(), &mut userlen, &mut decode, &mut err);
            assert_eq!((kind as u8, decode), (b'B', 1)); // compressed + decoded
            assert_eq!(e, 12);
            let mut bbuf = vec![0u8; e as usize];
            assert_eq!(scda_fread_block_data(f, bbuf.as_mut_ptr(), e, &mut err), 0);
            assert_eq!(&bbuf, block);

            let mut userlen = user.len();
            let mut decode: c_int = 0;
            scda_fread_section_header(f, &mut kind, &mut n, &mut e, user.as_mut_ptr(), &mut userlen, &mut decode, &mut err);
            assert_eq!((kind as u8, n, e), (b'A', 10, 6));
            let mut abuf = vec![0u8; 60];
            assert_eq!(scda_fread_array_data(f, abuf.as_mut_ptr(), n, e, &mut err), 0);
            assert_eq!(abuf, arr);

            let mut userlen = user.len();
            let mut decode: c_int = 0;
            scda_fread_section_header(f, &mut kind, &mut n, &mut e, user.as_mut_ptr(), &mut userlen, &mut decode, &mut err);
            assert_eq!((kind as u8, n), (b'V', 3));
            let mut rsizes = vec![0u64; 3];
            assert_eq!(scda_fread_varray_sizes(f, rsizes.as_mut_ptr(), 3, &mut err), 0);
            assert_eq!(rsizes, sizes);
            let mut vbuf = vec![0u8; 8];
            assert_eq!(scda_fread_varray_data(f, vbuf.as_mut_ptr(), 3, rsizes.as_ptr(), &mut err), 0);
            assert_eq!(vbuf, vdata);
            assert_eq!(scda_fclose(f, &mut err), 0);
            std::fs::remove_file(std::str::from_utf8(path.as_bytes()).unwrap()).unwrap();
        }
    }

    #[test]
    fn c_api_errors_and_skips() {
        unsafe {
            let mut err: c_int = 0;
            // Bad mode.
            let path = tmp("errors");
            let f = scda_fopen(path.as_ptr(), b'x' as c_char, std::ptr::null(), 0, &mut err);
            assert!(f.is_null());
            assert_eq!(err, 3000 + usage::BAD_MODE);
            // Missing file.
            let missing = CString::new("/nonexistent/x.scda").unwrap();
            let f = scda_fopen(missing.as_ptr(), b'r' as c_char, std::ptr::null(), 0, &mut err);
            assert!(f.is_null());
            assert!((2000..3000).contains(&err));
            // Error string translation.
            let mut buf = [0i8; 128];
            let mut len = buf.len();
            assert_eq!(scda_ferror_string(err, buf.as_mut_ptr(), &mut len), 0);
            assert!(len > 0);
            assert_eq!(scda_ferror_string(-7, buf.as_mut_ptr(), &mut len), -1);
            // NULL skip on read.
            let f = scda_fopen(path.as_ptr(), b'w' as c_char, std::ptr::null(), 0, &mut err);
            assert_eq!(err, 0);
            scda_fwrite_block(f, b"skipme".as_ptr(), 6, std::ptr::null(), 0, 0, &mut err);
            scda_fclose(f, &mut err);
            let f = scda_fopen(path.as_ptr(), b'r' as c_char, std::ptr::null(), 0, &mut err);
            let mut decode: c_int = 0;
            let mut kind: c_char = 0;
            let (mut n, mut e) = (0u64, 0u64);
            scda_fread_section_header(f, &mut kind, &mut n, &mut e, std::ptr::null_mut(), std::ptr::null_mut(), &mut decode, &mut err);
            assert_eq!(scda_fread_block_data(f, std::ptr::null_mut(), e, &mut err), 0); // NULL = skip
            scda_fclose(f, &mut err);
            std::fs::remove_file(std::str::from_utf8(path.as_bytes()).unwrap()).unwrap();
        }
    }

    #[test]
    fn double_close_and_null_handle_are_clean_errors() {
        unsafe {
            let mut err: c_int = 0;
            assert_eq!(scda_fclose(std::ptr::null_mut(), &mut err), -1);
            assert_eq!(err, 3000 + usage::CALL_SEQUENCE);
            assert_eq!(scda_fwrite_inline(std::ptr::null_mut(), [0u8; 32].as_ptr(), std::ptr::null(), 0, &mut err), -1);
        }
    }
}
