//! `scda` — the command-line front end. See `scda help`.

fn main() {
    let code = scda::cli::run(std::env::args().skip(1));
    std::process::exit(code);
}
