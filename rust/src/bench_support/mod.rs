//! Benchmark harness support (the offline environment lacks criterion):
//! wall-clock measurement with warmup and repetition statistics, table
//! rendering matching the experiment ids in DESIGN.md §Experiments, and
//! shared workload corpora.

pub mod sha256;

pub use sha256::{hex, sha256};

use std::time::Instant;

/// Measurement of repeated runs (seconds).
#[derive(Debug, Clone)]
pub struct Sample {
    pub reps: usize,
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub max: f64,
}

/// Run `f` `reps` times after `warmup` runs; report statistics.
pub fn measure(warmup: usize, reps: usize, mut f: impl FnMut()) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / reps as f64;
    Sample { reps, min: times[0], median: times[reps / 2], mean, max: times[reps - 1] }
}

impl Sample {
    /// Throughput in MiB/s for `bytes` processed per rep (median-based).
    pub fn mib_per_s(&self, bytes: u64) -> f64 {
        bytes as f64 / (1024.0 * 1024.0) / self.median
    }
}

/// Simple fixed-width table printer (markdown-flavored) so bench output
/// can be pasted into EXPERIMENTS.md verbatim.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:>w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Workload corpora shared by the compression/precondition benches; each
/// is (name, bytes) with deterministic contents.
pub fn corpus(len: usize) -> Vec<(&'static str, Vec<u8>)> {
    use crate::mesh::{fields, ring_mesh};
    use crate::testutil::Rng;
    let mut rng = Rng::new(0xC0FFEE);
    let mut out = Vec::new();
    out.push(("zeros", vec![0u8; len]));
    out.push(("random", rng.bytes(len, 256)));
    out.push(("text", {
        let phrase = b"The scda format is serial-equivalent by design. ";
        phrase.iter().cycle().take(len).copied().collect()
    }));
    // Smooth AMR f64 field bytes — the paper's target workload.
    let mesh = ring_mesh(5, 8, (0.5, 0.5), 0.3);
    let mut amr = Vec::with_capacity(len);
    'outer: loop {
        for q in &mesh {
            amr.extend_from_slice(&fields::fixed_payload(q, 5));
            if amr.len() >= len {
                break 'outer;
            }
        }
    }
    amr.truncate(len);
    out.push(("amr-f64", amr));
    out
}

/// `SCDA_BENCH_QUICK=1` shrinks workloads for CI-style smoke runs.
pub fn quick() -> bool {
    std::env::var_os("SCDA_BENCH_QUICK").is_some()
}

// ---------------------------------------------------------------------
// Machine-readable bench output (offline environment: no serde — a
// minimal JSON emitter suffices for the flat report shape).
// ---------------------------------------------------------------------

/// A JSON scalar for [`BenchReport`] fields.
#[derive(Debug, Clone)]
pub enum JsonVal {
    Num(f64),
    Int(i64),
    Str(String),
    Bool(bool),
}

impl JsonVal {
    fn render(&self) -> String {
        match self {
            // JSON has no NaN/Inf; clamp to null.
            JsonVal::Num(v) if !v.is_finite() => "null".into(),
            JsonVal::Num(v) => format!("{v:.3}"),
            JsonVal::Int(v) => v.to_string(),
            JsonVal::Str(s) => {
                let mut out = String::with_capacity(s.len() + 2);
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
                out
            }
            JsonVal::Bool(b) => b.to_string(),
        }
    }
}

fn render_fields(fields: &[(String, JsonVal)], indent: &str) -> String {
    let inner: Vec<String> =
        fields.iter().map(|(k, v)| format!("{indent}{}: {}", JsonVal::Str(k.clone()).render(), v.render())).collect();
    inner.join(",\n")
}

/// One benchmark report: top-level metadata plus a flat list of entries,
/// written as pretty-printed JSON so perf trajectories can be tracked
/// across PRs (`BENCH_codec.json`).
#[derive(Debug, Default)]
pub struct BenchReport {
    pub bench: String,
    pub meta: Vec<(String, JsonVal)>,
    pub entries: Vec<Vec<(String, JsonVal)>>,
}

impl BenchReport {
    pub fn new(bench: &str) -> Self {
        BenchReport { bench: bench.to_string(), meta: Vec::new(), entries: Vec::new() }
    }

    pub fn meta(&mut self, key: &str, val: JsonVal) -> &mut Self {
        self.meta.push((key.to_string(), val));
        self
    }

    pub fn entry(&mut self, fields: Vec<(&str, JsonVal)>) -> &mut Self {
        self.entries.push(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect());
        self
    }

    pub fn render(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"bench\": {},\n", JsonVal::Str(self.bench.clone()).render()));
        if !self.meta.is_empty() {
            out.push_str(&render_fields(&self.meta, "  "));
            out.push_str(",\n");
        }
        out.push_str("  \"entries\": [\n");
        let rows: Vec<String> =
            self.entries.iter().map(|e| format!("    {{\n{}\n    }}", render_fields(e, "      "))).collect();
        out.push_str(&rows.join(",\n"));
        out.push_str("\n  ]\n}\n");
        out
    }

    /// Write to `path`, creating parent directories as needed.
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.render())
    }
}

/// Where codec bench numbers land (`SCDA_BENCH_JSON` overrides).
pub fn bench_json_path() -> std::path::PathBuf {
    std::env::var_os("SCDA_BENCH_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("BENCH_codec.json"))
}

/// Where I/O bench numbers land (`SCDA_BENCH_IO_JSON` overrides).
pub fn bench_io_json_path() -> std::path::PathBuf {
    std::env::var_os("SCDA_BENCH_IO_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("BENCH_io.json"))
}

/// Where archive bench numbers land (`SCDA_BENCH_ARCHIVE_JSON` overrides).
pub fn bench_archive_json_path() -> std::path::PathBuf {
    std::env::var_os("SCDA_BENCH_ARCHIVE_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("BENCH_archive.json"))
}

/// Where crash/recovery soak numbers land (`SCDA_BENCH_RECOVER_JSON`
/// overrides).
pub fn bench_recover_json_path() -> std::path::PathBuf {
    std::env::var_os("SCDA_BENCH_RECOVER_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("BENCH_recover.json"))
}

/// Where read-service bench numbers land (`SCDA_BENCH_SERVE_JSON`
/// overrides).
pub fn bench_serve_json_path() -> std::path::PathBuf {
    std::env::var_os("SCDA_BENCH_SERVE_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("BENCH_serve.json"))
}

/// Where AMR scenario bench numbers land (`SCDA_BENCH_AMR_JSON`
/// overrides).
pub fn bench_amr_json_path() -> std::path::PathBuf {
    std::env::var_os("SCDA_BENCH_AMR_JSON")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("BENCH_amr.json"))
}

/// Encoded write/read throughput of the per-element codec pipeline,
/// serial vs pooled — the perf-trajectory numbers this PR's acceptance
/// criterion tracks. Shared by the f1/t4 benches and the ignored-by-
/// default smoke test so every consumer reports the same shape.
pub mod codec_bench {
    use super::{measure, JsonVal};
    use crate::api::{CodecParallel, DataSrc, ScdaFile};
    use crate::par::{CodecPool, Partition, SerialComm};
    use std::sync::Arc;

    /// Median MiB/s (of uncompressed payload) for one configuration.
    #[derive(Debug, Clone)]
    pub struct CodecThroughput {
        pub lanes: usize,
        pub payload_bytes: u64,
        pub elem_bytes: u64,
        pub write_serial: f64,
        pub write_pooled: f64,
        pub read_serial: f64,
        pub read_pooled: f64,
        /// Encoded-size effect of the §5.4 preconditioning stage.
        pub precond: PrecondGain,
    }

    /// Encoded-size gain of the SPEC §5.4 preconditioning stage (8-byte
    /// shuffle + per-plane delta) on the AMR f64 corpus — deterministic
    /// byte counts, not timings, so the entry is stable across machines.
    #[derive(Debug, Clone)]
    pub struct PrecondGain {
        pub payload_bytes: u64,
        pub plain_bytes: u64,
        pub precond_bytes: u64,
    }

    impl PrecondGain {
        /// How many times smaller the preconditioned frames are.
        pub fn size_ratio(&self) -> f64 {
            self.plain_bytes as f64 / self.precond_bytes as f64
        }

        /// Encode the AMR f64 corpus element-wise with and without the
        /// `Precond::new(8, true)` transform and compare total frame
        /// bytes.
        pub fn measure(total_bytes: usize, elem_bytes: usize) -> PrecondGain {
            use crate::codec::frame::{encode_element, CodecOptions};
            let data = super::corpus(total_bytes)
                .into_iter()
                .find(|(n, _)| *n == "amr-f64")
                .expect("amr corpus")
                .1;
            let pre = CodecOptions {
                precondition: Some(crate::codec::Precond::new(8, true).unwrap()),
                ..CodecOptions::default()
            };
            let (mut plain_bytes, mut precond_bytes) = (0u64, 0u64);
            for chunk in data.chunks(elem_bytes.max(1)) {
                plain_bytes += encode_element(chunk, CodecOptions::default()).len() as u64;
                precond_bytes += encode_element(chunk, pre).len() as u64;
            }
            PrecondGain { payload_bytes: data.len() as u64, plain_bytes, precond_bytes }
        }
    }

    impl CodecThroughput {
        pub fn write_speedup(&self) -> f64 {
            self.write_pooled / self.write_serial
        }

        pub fn read_speedup(&self) -> f64 {
            self.read_pooled / self.read_serial
        }

        /// The standard `BENCH_codec.json` report for these numbers.
        pub fn report(&self) -> super::BenchReport {
            let mut r = super::BenchReport::new("codec");
            r.meta("quick", JsonVal::Bool(super::quick()))
                .meta("lanes", JsonVal::Int(self.lanes as i64))
                .meta("payload_bytes", JsonVal::Int(self.payload_bytes as i64))
                .meta("elem_bytes", JsonVal::Int(self.elem_bytes as i64));
            for (name, serial, pooled) in [
                ("encoded_write", self.write_serial, self.write_pooled),
                ("encoded_read", self.read_serial, self.read_pooled),
            ] {
                r.entry(vec![
                    ("name", JsonVal::Str(name.into())),
                    ("serial_mib_per_s", JsonVal::Num(serial)),
                    ("pooled_mib_per_s", JsonVal::Num(pooled)),
                    ("speedup", JsonVal::Num(pooled / serial)),
                ]);
            }
            let g = &self.precond;
            r.entry(vec![
                ("name", JsonVal::Str("precond_frames".into())),
                ("payload_bytes", JsonVal::Int(g.payload_bytes as i64)),
                ("plain_encoded_bytes", JsonVal::Int(g.plain_bytes as i64)),
                ("precond_encoded_bytes", JsonVal::Int(g.precond_bytes as i64)),
                ("size_ratio", JsonVal::Num(g.size_ratio())),
            ]);
            r
        }
    }

    /// A compressible payload (the convention's favorable case: deflate
    /// does real work, so the codec — not the disk — is the bottleneck).
    pub fn compressible_payload(len: usize) -> Vec<u8> {
        let phrase = b"The scda per-element codec pipeline is serial-equivalent by construction. ";
        phrase.iter().cycle().take(len).copied().collect()
    }

    fn roundtrip_file(
        path: &std::path::Path,
        data: &[u8],
        part: &Partition,
        elem: u64,
        par: &CodecParallel,
        write: bool,
    ) {
        if write {
            let mut f = ScdaFile::create(SerialComm::new(), path, b"codec-bench").unwrap();
            f.set_sync_on_close(false);
            f.set_codec_parallel(par.clone());
            f.write_array(DataSrc::Contiguous(data), part, elem, Some(b"payload"), true).unwrap();
            f.close().unwrap();
        } else {
            let mut f = ScdaFile::open(SerialComm::new(), path).unwrap();
            f.set_codec_parallel(par.clone());
            let h = f.read_section_header(true).unwrap();
            assert!(h.decoded);
            let got = f.read_array_data(part, elem, true).unwrap().unwrap();
            assert_eq!(got.len(), data.len());
            f.close().unwrap();
        }
    }

    /// Measure encoded `write_array`/`read_array` throughput for the
    /// serial codec path and a `lanes`-wide pool on one rank.
    pub fn run(lanes: usize, total_bytes: usize, elem_bytes: usize, reps: usize) -> CodecThroughput {
        let data = compressible_payload(total_bytes);
        let elem = elem_bytes as u64;
        let n = (total_bytes as u64) / elem;
        let data = &data[..(n * elem) as usize];
        let part = Partition::uniform(1, n);
        let pool = CodecParallel::Pool(Arc::new(CodecPool::new(lanes)));
        let serial = CodecParallel::Serial;
        let dir = std::env::temp_dir().join("scda-codec-bench");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("codec-{}.scda", std::process::id()));

        let mut mib = |par: &CodecParallel, write: bool| {
            let s = measure(1, reps, || roundtrip_file(&path, data, &part, elem, par, write));
            s.mib_per_s(data.len() as u64)
        };
        // Writes leave the file in place for the read measurements; the
        // file bytes are identical under both codec paths (the pipeline's
        // serial-equivalence invariant), so read order doesn't matter.
        let write_serial = mib(&serial, true);
        let read_serial = mib(&serial, false);
        let write_pooled = mib(&pool, true);
        let read_pooled = mib(&pool, false);
        std::fs::remove_file(&path).ok();
        // Deterministic size numbers for the §5.4 stage: measured on a
        // corpus slice no larger than 1 MiB (the ratio converges fast).
        let precond = PrecondGain::measure(total_bytes.min(1 << 20), elem_bytes);
        CodecThroughput {
            lanes,
            payload_bytes: data.len() as u64,
            elem_bytes: elem,
            write_serial,
            write_pooled,
            read_serial,
            read_pooled,
            precond,
        }
    }

    /// Quick-mode defaults: 8 MiB of compressible payload, 64 KiB
    /// elements, 4 codec lanes.
    pub fn run_quick() -> CodecThroughput {
        run(4, 8 << 20, 64 << 10, 3)
    }
}

/// Raw I/O throughput and syscall shape of the section paths across the
/// three engines ([`crate::io`]): direct (one syscall per logical
/// access), aggregated (per-rank staging, the default) and collective
/// (two-phase stripe exchange), each sync and async — the numbers
/// `BENCH_io.json` tracks. The workload is the aggregation-adversarial
/// one: multi-section varrays of small *indirectly addressed* elements,
/// so the direct path pays one `pwrite` per element and the staged paths
/// one per contiguous run. Shared by the f1/t2/t3 benches and the
/// ignored-by-default smoke test.
pub mod io_bench {
    use super::{measure, JsonVal};
    use crate::api::{DataSrc, EngineStats, IoTuning, ScdaFile};
    use crate::par::{run_parallel, Communicator, IoStats, Partition, SerialComm};
    use std::path::PathBuf;
    use std::sync::Arc;

    /// One engine configuration's write-side numbers.
    #[derive(Debug, Clone)]
    pub struct EngineProfile {
        /// "direct", "aggregated", "aggregated_async", "collective",
        /// "collective_async".
        pub name: String,
        pub write_mib_s: f64,
        /// Write syscalls summed over all ranks for one whole-file pass.
        pub write_calls: u64,
        /// Bytes shipped between ranks (collective two-phase only).
        pub shipped_bytes: u64,
        /// Collective exchanges summed over all ranks (0 for per-rank
        /// engines).
        pub exchanges: u64,
        /// Largest single-exchange shipped volume seen on any rank (the
        /// per-exchange history peak; 0 for per-rank engines).
        pub shipped_exchange_max: u64,
    }

    /// One engine configuration's read-side numbers (the read sweep
    /// skips the `*_async` configs: background flush is write-side
    /// only).
    #[derive(Debug, Clone)]
    pub struct ReadEngineProfile {
        /// "direct", "aggregated" (sieved) or "collective" (gathered).
        pub name: String,
        pub read_mib_s: f64,
        /// Read syscalls summed over all ranks for one whole-file pass.
        pub read_calls: u64,
        /// Collective read gathers summed over all ranks (0 for
        /// per-rank engines).
        pub read_exchanges: u64,
        /// Bytes served to other ranks' read windows (gather volume).
        pub gathered_bytes: u64,
        /// Owner-side preads issued by the gather — the count that
        /// tracks bytes touched, not rank count.
        pub gather_preads: u64,
    }

    /// The engine configurations the sweep covers (name, tuning).
    pub fn engine_configs() -> Vec<(&'static str, IoTuning)> {
        vec![
            ("direct", IoTuning::direct()),
            ("aggregated", IoTuning::default()),
            ("aggregated_async", IoTuning::default().with_async_flush(true)),
            ("collective", IoTuning::collective()),
            ("collective_async", IoTuning::collective().with_async_flush(true)),
        ]
    }

    /// One aggregated-vs-direct comparison (syscalls from an instrumented
    /// pass, MiB/s medians from `reps` timed passes), plus the full
    /// per-engine sweep in `engines`.
    #[derive(Debug, Clone)]
    pub struct IoProfile {
        pub ranks: usize,
        pub sections: usize,
        pub payload_bytes: u64,
        pub write_direct_mib_s: f64,
        pub write_agg_mib_s: f64,
        pub read_direct_mib_s: f64,
        pub read_sieved_mib_s: f64,
        /// Syscalls summed over all ranks for one whole-file pass.
        pub write_calls_direct: u64,
        pub write_calls_agg: u64,
        pub read_calls_direct: u64,
        pub read_calls_sieved: u64,
        /// Write-side numbers for every engine configuration
        /// ([`engine_configs`]).
        pub engines: Vec<EngineProfile>,
        /// Read-side numbers per engine (direct / sieved / gathered).
        pub read_engines: Vec<ReadEngineProfile>,
    }

    impl IoProfile {
        /// How many times fewer write syscalls aggregation issues.
        pub fn write_syscall_reduction(&self) -> f64 {
            self.write_calls_direct as f64 / self.write_calls_agg.max(1) as f64
        }

        pub fn read_syscall_reduction(&self) -> f64 {
            self.read_calls_direct as f64 / self.read_calls_sieved.max(1) as f64
        }

        /// The standard `BENCH_io.json` report for these numbers.
        pub fn report(&self) -> super::BenchReport {
            let mut r = super::BenchReport::new("io");
            r.meta("quick", JsonVal::Bool(super::quick()))
                .meta("ranks", JsonVal::Int(self.ranks as i64))
                .meta("sections", JsonVal::Int(self.sections as i64))
                .meta("payload_bytes", JsonVal::Int(self.payload_bytes as i64));
            r.entry(vec![
                ("name", JsonVal::Str("varray_write".into())),
                ("direct_mib_per_s", JsonVal::Num(self.write_direct_mib_s)),
                ("aggregated_mib_per_s", JsonVal::Num(self.write_agg_mib_s)),
                ("speedup", JsonVal::Num(self.write_agg_mib_s / self.write_direct_mib_s)),
                ("direct_write_calls", JsonVal::Int(self.write_calls_direct as i64)),
                ("aggregated_write_calls", JsonVal::Int(self.write_calls_agg as i64)),
                ("syscall_reduction", JsonVal::Num(self.write_syscall_reduction())),
            ]);
            r.entry(vec![
                ("name", JsonVal::Str("varray_read".into())),
                ("direct_mib_per_s", JsonVal::Num(self.read_direct_mib_s)),
                ("sieved_mib_per_s", JsonVal::Num(self.read_sieved_mib_s)),
                ("speedup", JsonVal::Num(self.read_sieved_mib_s / self.read_direct_mib_s)),
                ("direct_read_calls", JsonVal::Int(self.read_calls_direct as i64)),
                ("sieved_read_calls", JsonVal::Int(self.read_calls_sieved as i64)),
                ("syscall_reduction", JsonVal::Num(self.read_syscall_reduction())),
            ]);
            for e in &self.engines {
                r.entry(vec![
                    ("name", JsonVal::Str(format!("engine_{}", e.name))),
                    ("engine", JsonVal::Str(e.name.clone())),
                    ("write_mib_per_s", JsonVal::Num(e.write_mib_s)),
                    ("write_calls", JsonVal::Int(e.write_calls as i64)),
                    ("shipped_bytes", JsonVal::Int(e.shipped_bytes as i64)),
                    ("exchanges", JsonVal::Int(e.exchanges as i64)),
                    ("shipped_exchange_max", JsonVal::Int(e.shipped_exchange_max as i64)),
                ]);
            }
            for e in &self.read_engines {
                r.entry(vec![
                    ("name", JsonVal::Str(format!("read_engine_{}", e.name))),
                    ("engine", JsonVal::Str(e.name.clone())),
                    ("read_mib_per_s", JsonVal::Num(e.read_mib_s)),
                    ("read_calls", JsonVal::Int(e.read_calls as i64)),
                    ("read_exchanges", JsonVal::Int(e.read_exchanges as i64)),
                    ("gathered_bytes", JsonVal::Int(e.gathered_bytes as i64)),
                    ("gather_preads", JsonVal::Int(e.gather_preads as i64)),
                ]);
            }
            r
        }
    }

    fn pattern_elem(rank: usize, i: usize, len: usize) -> Vec<u8> {
        (0..len).map(|b| (rank * 131 + i * 7 + b) as u8).collect()
    }

    /// Write the whole benchmark file once; per-rank (syscall, engine)
    /// stats.
    pub fn write_once(
        path: &Arc<PathBuf>,
        ranks: usize,
        sections: usize,
        elems_per_rank: usize,
        elem_bytes: usize,
        tuning: IoTuning,
    ) -> Vec<(IoStats, EngineStats)> {
        let path = Arc::clone(path);
        run_parallel(ranks, move |comm| {
            let rank = comm.rank();
            let part = Partition::uniform(ranks, (ranks * elems_per_rank) as u64);
            let owned: Vec<Vec<u8>> = (0..elems_per_rank).map(|i| pattern_elem(rank, i, elem_bytes)).collect();
            let views: Vec<&[u8]> = owned.iter().map(|e| e.as_slice()).collect();
            let sizes = vec![elem_bytes as u64; elems_per_rank];
            let mut f = ScdaFile::create(comm, &**path, b"io-bench").unwrap();
            f.set_sync_on_close(false);
            f.set_io_tuning(tuning).unwrap();
            for _ in 0..sections {
                f.write_varray(DataSrc::Indirect(&views), &part, &sizes, Some(b"w"), false).unwrap();
            }
            f.flush().unwrap();
            let st = (f.io_stats(), f.engine_stats());
            f.close().unwrap();
            st
        })
    }

    /// Read the whole benchmark file once; per-rank syscall stats.
    pub fn read_once(
        path: &Arc<PathBuf>,
        ranks: usize,
        sections: usize,
        elems_per_rank: usize,
        elem_bytes: usize,
        tuning: IoTuning,
    ) -> Vec<IoStats> {
        read_once_stats(path, ranks, sections, elems_per_rank, elem_bytes, tuning)
            .into_iter()
            .map(|(s, _)| s)
            .collect()
    }

    /// [`read_once`] that also snapshots each rank's engine counters
    /// (gather preads, exchanges, gathered bytes) for the read sweep.
    pub fn read_once_stats(
        path: &Arc<PathBuf>,
        ranks: usize,
        sections: usize,
        elems_per_rank: usize,
        elem_bytes: usize,
        tuning: IoTuning,
    ) -> Vec<(IoStats, EngineStats)> {
        let path = Arc::clone(path);
        run_parallel(ranks, move |comm| {
            let part = Partition::uniform(ranks, (ranks * elems_per_rank) as u64);
            let mut f = ScdaFile::open(comm, &**path).unwrap();
            f.set_io_tuning(tuning).unwrap();
            for _ in 0..sections {
                f.read_section_header(false).unwrap();
                let sizes = f.read_varray_sizes(&part).unwrap();
                let data = f.read_varray_data(&part, &sizes, true).unwrap().unwrap();
                assert_eq!(data.len(), elems_per_rank * elem_bytes);
            }
            let st = (f.io_stats(), f.engine_stats());
            f.close().unwrap();
            st
        })
    }

    /// Measure write/read MiB/s and syscall counts for both tunings.
    pub fn run(ranks: usize, sections: usize, elems_per_rank: usize, elem_bytes: usize, reps: usize) -> IoProfile {
        let dir = std::env::temp_dir().join("scda-io-bench");
        std::fs::create_dir_all(&dir).unwrap();
        let path = Arc::new(dir.join(format!("io-{ranks}-{}.scda", std::process::id())));
        let payload = (sections * ranks * elems_per_rank * elem_bytes) as u64;
        let agg = IoTuning::default();
        let direct = IoTuning::direct();

        // Instrumented passes for the syscall shape (file bytes are
        // identical under every engine; rust/tests/io_engines.rs asserts
        // that, so the read passes below see the same file).
        let sum_w = |v: &[(IoStats, EngineStats)]| v.iter().map(|(s, _)| s.write_calls).sum::<u64>();
        let sum_ship = |v: &[(IoStats, EngineStats)]| v.iter().map(|(_, e)| e.shipped_bytes).sum::<u64>();
        let sum_r = |v: &[IoStats]| v.iter().map(|s| s.read_calls).sum::<u64>();
        let write_calls_agg = sum_w(&write_once(&path, ranks, sections, elems_per_rank, elem_bytes, agg));
        let read_calls_sieved = sum_r(&read_once(&path, ranks, sections, elems_per_rank, elem_bytes, agg));
        let write_calls_direct = sum_w(&write_once(&path, ranks, sections, elems_per_rank, elem_bytes, direct));
        let read_calls_direct = sum_r(&read_once(&path, ranks, sections, elems_per_rank, elem_bytes, direct));

        // Timed passes.
        let mib = |write: bool, tuning: IoTuning| {
            let s = measure(1, reps, || {
                if write {
                    write_once(&path, ranks, sections, elems_per_rank, elem_bytes, tuning);
                } else {
                    read_once(&path, ranks, sections, elems_per_rank, elem_bytes, tuning);
                }
            });
            s.mib_per_s(payload)
        };
        let write_direct_mib_s = mib(true, direct);
        let read_direct_mib_s = mib(false, direct);
        let write_agg_mib_s = mib(true, agg);
        let read_sieved_mib_s = mib(false, agg);

        // Full engine sweep (write side): syscall counts, shipped bytes
        // and the per-exchange history shape from an instrumented pass,
        // MiB/s from timed passes.
        let sum_ex = |v: &[(IoStats, EngineStats)]| v.iter().map(|(_, e)| e.exchanges).sum::<u64>();
        let max_ex_ship = |v: &[(IoStats, EngineStats)]| {
            v.iter().flat_map(|(_, e)| e.shipped_per_exchange.iter().copied()).max().unwrap_or(0)
        };
        let mut engines = Vec::new();
        for (name, tuning) in engine_configs() {
            let (write_mib_s, write_calls, shipped_bytes, exchanges, shipped_exchange_max) = match name {
                "direct" => (write_direct_mib_s, write_calls_direct, 0, 0, 0),
                "aggregated" => (write_agg_mib_s, write_calls_agg, 0, 0, 0),
                _ => {
                    let st = write_once(&path, ranks, sections, elems_per_rank, elem_bytes, tuning);
                    (mib(true, tuning), sum_w(&st), sum_ship(&st), sum_ex(&st), max_ex_ship(&st))
                }
            };
            engines.push(EngineProfile {
                name: name.to_string(),
                write_mib_s,
                write_calls,
                shipped_bytes,
                exchanges,
                shipped_exchange_max,
            });
        }

        // Read-side engine sweep over the same file (the engine
        // property tests pin its bytes identical under every writer):
        // the collective read gather vs the per-rank routes. Background
        // flush is write-side only (`*_async` configs skipped), and the
        // per-rank engines reuse the counts already measured above —
        // their gather counters are definitionally zero.
        let zero_gather = |name: &str, read_mib_s: f64, read_calls: u64| ReadEngineProfile {
            name: name.to_string(),
            read_mib_s,
            read_calls,
            read_exchanges: 0,
            gathered_bytes: 0,
            gather_preads: 0,
        };
        let mut read_engines = Vec::new();
        for (name, tuning) in engine_configs() {
            if name.ends_with("_async") {
                continue;
            }
            read_engines.push(match name {
                "direct" => zero_gather(name, read_direct_mib_s, read_calls_direct),
                "aggregated" => zero_gather(name, read_sieved_mib_s, read_calls_sieved),
                _ => {
                    let st = read_once_stats(&path, ranks, sections, elems_per_rank, elem_bytes, tuning);
                    ReadEngineProfile {
                        name: name.to_string(),
                        read_mib_s: mib(false, tuning),
                        read_calls: st.iter().map(|(s, _)| s.read_calls).sum(),
                        read_exchanges: st.iter().map(|(_, e)| e.read_exchanges).sum(),
                        gathered_bytes: st.iter().map(|(_, e)| e.gathered_bytes).sum(),
                        gather_preads: st.iter().map(|(_, e)| e.gather_preads).sum(),
                    }
                }
            });
        }
        std::fs::remove_file(&*path).ok();
        IoProfile {
            ranks,
            sections,
            payload_bytes: payload,
            write_direct_mib_s,
            write_agg_mib_s,
            read_direct_mib_s,
            read_sieved_mib_s,
            write_calls_direct,
            write_calls_agg,
            read_calls_direct,
            read_calls_sieved,
            engines,
            read_engines,
        }
    }

    /// Quick-mode defaults: 2 ranks, 8 varray sections of 64 x 4 KiB
    /// indirect elements per rank (4 MiB total payload).
    pub fn run_quick() -> IoProfile {
        run(2, 8, 64, 4 << 10, 2)
    }

    /// Sequential metadata scan (`toc`) of a many-section file, sieved vs
    /// direct: the read-sieve shape for the t3 selective-access story.
    #[derive(Debug, Clone)]
    pub struct ScanProfile {
        pub sections: usize,
        pub direct_ms: f64,
        pub sieved_ms: f64,
        pub direct_read_calls: u64,
        pub sieved_read_calls: u64,
        pub stat_calls: u64,
    }

    pub fn toc_scan(sections: usize, reps: usize) -> ScanProfile {
        let dir = std::env::temp_dir().join("scda-io-bench");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("scan-{sections}-{}.scda", std::process::id()));
        {
            let mut f = ScdaFile::create(SerialComm::new(), &path, b"scan").unwrap();
            f.set_sync_on_close(false);
            let part = Partition::uniform(1, 4);
            let sizes = vec![8u64; 4];
            let data = vec![0xABu8; 32];
            for _ in 0..sections {
                f.write_varray(DataSrc::Contiguous(&data), &part, &sizes, Some(b"s"), false).unwrap();
            }
            f.close().unwrap();
        }
        let pass = |tuning: IoTuning| {
            let s = measure(1, reps, || {
                let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
                f.set_io_tuning(tuning).unwrap();
                assert_eq!(f.toc(false).unwrap().len(), sections);
                f.close().unwrap();
            });
            let mut f = ScdaFile::open(SerialComm::new(), &path).unwrap();
            f.set_io_tuning(tuning).unwrap();
            f.toc(false).unwrap();
            let st = f.io_stats();
            f.close().unwrap();
            (s.median * 1e3, st)
        };
        let (direct_ms, st_d) = pass(IoTuning::direct());
        let (sieved_ms, st_s) = pass(IoTuning::default());
        std::fs::remove_file(&path).ok();
        ScanProfile {
            sections,
            direct_ms,
            sieved_ms,
            direct_read_calls: st_d.read_calls,
            sieved_read_calls: st_s.read_calls,
            stat_calls: st_d.stat_calls.max(st_s.stat_calls),
        }
    }
}

/// Named-dataset random access through the archive catalog layer
/// ([`crate::archive`]): open-plus-read latency and syscall shape of the
/// O(1) footer index vs the linear section scan it replaces, swept over
/// section count — the `BENCH_archive.json` numbers the t3 bench (and
/// the archive smoke test) record. Syscall counts come from an
/// instrumented pass under [`IoTuning::direct`] (one pread per logical
/// access, so the counters *are* the access count); latencies are medians
/// over `reps` timed passes under the default tuning.
pub mod archive_bench {
    use super::{measure, JsonVal};
    use crate::api::{DataSrc, IoTuning};
    use crate::archive::Archive;
    use crate::par::{Partition, SerialComm};
    use std::path::Path;

    /// Indexed-vs-scan numbers for one section count.
    #[derive(Debug, Clone)]
    pub struct AccessProfile {
        /// Named array datasets in the file (the scan cost driver).
        pub datasets: usize,
        /// Median ms to open the archive and read one named dataset.
        pub indexed_ms: f64,
        pub scan_ms: f64,
        /// Read syscalls for that open+read under the direct engine.
        pub indexed_reads: u64,
        pub scan_reads: u64,
    }

    impl AccessProfile {
        pub fn speedup(&self) -> f64 {
            self.scan_ms / self.indexed_ms
        }
    }

    fn build(path: &Path, datasets: usize, elems: u64, elem_bytes: u64) {
        let part = Partition::uniform(1, elems);
        let payload: Vec<u8> = (0..elems * elem_bytes).map(|i| (i % 251) as u8).collect();
        let mut ar = Archive::create(SerialComm::new(), path, b"archive-bench").unwrap();
        ar.file_mut().set_sync_on_close(false);
        for d in 0..datasets {
            ar.write_array(&format!("ds/{d}"), DataSrc::Contiguous(&payload), &part, elem_bytes, false)
                .unwrap();
        }
        ar.finish().unwrap();
    }

    fn access(
        path: &Path,
        name: &str,
        part: &Partition,
        elem_bytes: u64,
        tuning: IoTuning,
        use_index: bool,
    ) -> u64 {
        let mut ar = Archive::open_with(SerialComm::new(), path, tuning, use_index).unwrap();
        assert_eq!(ar.is_indexed(), use_index);
        let got = ar.read_array(name, part, elem_bytes).unwrap();
        assert_eq!(got.len() as u64, part.total() * elem_bytes);
        let reads = ar.file().io_stats().read_calls;
        ar.close().unwrap();
        reads
    }

    /// Measure one section count: open + read the *last* dataset (the
    /// scan's worst case, the index's indifferent case).
    pub fn random_access(datasets: usize, elems: u64, elem_bytes: u64, reps: usize) -> AccessProfile {
        let dir = std::env::temp_dir().join("scda-archive-bench");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("ar-{datasets}-{}.scda", std::process::id()));
        build(&path, datasets, elems, elem_bytes);
        let part = Partition::uniform(1, elems);
        let name = format!("ds/{}", datasets - 1);
        // Syscall shape under the direct engine: counters == accesses.
        let indexed_reads = access(&path, &name, &part, elem_bytes, IoTuning::direct(), true);
        let scan_reads = access(&path, &name, &part, elem_bytes, IoTuning::direct(), false);
        // Latency under the default tuning (what a consumer gets).
        let ms = |use_index: bool| {
            let s = measure(1, reps, || {
                access(&path, &name, &part, elem_bytes, IoTuning::default(), use_index);
            });
            s.median * 1e3
        };
        let indexed_ms = ms(true);
        let scan_ms = ms(false);
        std::fs::remove_file(&path).ok();
        AccessProfile { datasets, indexed_ms, scan_ms, indexed_reads, scan_reads }
    }

    /// The standard `BENCH_archive.json` report for a sweep.
    pub fn report(profiles: &[AccessProfile]) -> super::BenchReport {
        let mut r = super::BenchReport::new("archive");
        r.meta("quick", JsonVal::Bool(super::quick()));
        for p in profiles {
            r.entry(vec![
                ("name", JsonVal::Str(format!("open_dataset_{}", p.datasets))),
                ("datasets", JsonVal::Int(p.datasets as i64)),
                ("indexed_ms", JsonVal::Num(p.indexed_ms)),
                ("scan_ms", JsonVal::Num(p.scan_ms)),
                ("speedup", JsonVal::Num(p.speedup())),
                ("indexed_reads", JsonVal::Int(p.indexed_reads as i64)),
                ("scan_reads", JsonVal::Int(p.scan_reads as i64)),
            ]);
        }
        r
    }

    /// Quick-mode sweep: 8/64 datasets of 32 x 256 B elements.
    pub fn run_quick() -> Vec<AccessProfile> {
        [8usize, 64].iter().map(|&s| random_access(s, 32, 256, 2)).collect()
    }
}

pub mod serve_bench {
    //! Concurrent read-service bench: N client sessions over one
    //! archive, zipfian request mix, shared page cache vs the
    //! per-session-sieve baseline (`BENCH_serve.json`).

    use super::JsonVal;
    use crate::api::DataSrc;
    use crate::archive::Archive;
    use crate::obs::Hist;
    use crate::par::{Partition, SerialComm};
    use crate::runtime::{ArchiveReadService, ReadRequest, ReadResponse, ReadServiceConfig};
    use crate::testutil::Rng;
    use std::path::Path;
    use std::time::Instant;

    /// Session counts swept by [`run`]/[`run_quick`]. Quick and full
    /// modes share the grid so `BENCH_serve.json` keeps one shape.
    pub const SESSIONS: [usize; 4] = [1, 2, 4, 8];
    /// Cache budgets swept: one small enough to force eviction on the
    /// bench archive, one that holds it whole.
    pub const BUDGETS: [usize; 2] = [512 * 1024, 32 * 1024 * 1024];

    /// Shared-cache vs per-session-sieve numbers for one
    /// (sessions, budget) cell of the sweep.
    #[derive(Debug, Clone)]
    pub struct ServeProfile {
        pub sessions: usize,
        pub budget_bytes: usize,
        /// Total requests served (all sessions).
        pub requests: u64,
        /// Distinct payload bytes the workload touches — the floor any
        /// cache-perfect reader must pread.
        pub unique_bytes: u64,
        pub shared_rps: f64,
        pub shared_p50_us: f64,
        pub shared_p99_us: f64,
        /// `pread` syscalls issued by the shared-cache run (one shared
        /// descriptor, so this is the whole fleet's count).
        pub shared_preads: u64,
        pub cache_hits: u64,
        pub cache_misses: u64,
        pub cache_evictions: u64,
        pub single_flight_waits: u64,
        pub baseline_rps: f64,
        pub baseline_p50_us: f64,
        pub baseline_p99_us: f64,
        pub baseline_preads: u64,
    }

    impl ServeProfile {
        /// Shared-cache throughput gain over private sieves.
        pub fn speedup(&self) -> f64 {
            self.shared_rps / self.baseline_rps
        }
    }

    /// Zipf(s=1) CDF over `n` ranks: hot-key skew for the request mix.
    struct Zipf {
        cdf: Vec<f64>,
    }

    impl Zipf {
        fn new(n: usize) -> Zipf {
            let mut cdf = Vec::with_capacity(n);
            let mut acc = 0.0;
            for k in 0..n {
                acc += 1.0 / (k + 1) as f64;
                cdf.push(acc);
            }
            Zipf { cdf }
        }

        fn sample(&self, rng: &mut Rng) -> usize {
            let total = *self.cdf.last().unwrap();
            let u = rng.below(1 << 30) as f64 / (1u64 << 30) as f64 * total;
            self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
        }
    }

    fn build(path: &Path, datasets: usize, elems: u64, elem_bytes: u64) {
        let part = Partition::uniform(1, elems);
        let payload: Vec<u8> = (0..elems * elem_bytes).map(|i| (i % 251) as u8).collect();
        let mut ar = Archive::create(SerialComm::new(), path, b"serve-bench").unwrap();
        ar.file_mut().set_sync_on_close(false);
        for d in 0..datasets {
            ar.write_array(&format!("ds/{d}"), DataSrc::Contiguous(&payload), &part, elem_bytes, false)
                .unwrap();
        }
        ar.finish().unwrap();
    }

    /// Per-session deterministic zipfian request lists, plus the
    /// workload's unique payload footprint in bytes. Ranks map to
    /// (dataset, block) keys round-robin so the hot set spans datasets;
    /// blocks are disjoint and equal-sized, so the footprint is just
    /// the distinct-key count.
    fn gen_workload(
        sessions: usize,
        per_session: usize,
        datasets: usize,
        elems: u64,
        elem_bytes: u64,
        count: u64,
    ) -> (Vec<Vec<ReadRequest>>, u64) {
        let blocks = (elems / count).max(1);
        let zipf = Zipf::new((datasets as u64 * blocks) as usize);
        let mut touched = std::collections::HashSet::new();
        let mut reqs = Vec::with_capacity(sessions);
        for s in 0..sessions {
            let mut rng = Rng::new(0x5eed + s as u64);
            let mut list = Vec::with_capacity(per_session);
            for _ in 0..per_session {
                let key = zipf.sample(&mut rng) as u64;
                touched.insert(key);
                list.push(ReadRequest {
                    dataset: format!("ds/{}", key % datasets as u64),
                    first: key / datasets as u64 * count,
                    count,
                });
            }
            reqs.push(list);
        }
        (reqs, touched.len() as u64 * count * elem_bytes)
    }

    struct RunStats {
        rps: f64,
        p50_us: f64,
        p99_us: f64,
        preads: u64,
        bytes_served: u64,
        cache: Option<crate::io::CacheStats>,
    }

    /// Serve every session's request list concurrently (one thread per
    /// session), recording per-request latencies into one shared
    /// [`Hist`] — the same definition of p50/p99 the tracer's per-kind
    /// histograms report, so "p99" means one thing everywhere (upper
    /// bucket edge, within an octave; see `crate::obs::hist`).
    /// `budget == 0` is the baseline: no shared cache, each session on
    /// its private sieve.
    fn serve_once(path: &Path, budget: usize, reqs: &[Vec<ReadRequest>]) -> RunStats {
        let cfg = ReadServiceConfig { cache_budget: budget, ..Default::default() };
        let svc = ArchiveReadService::open_with(path, cfg).unwrap();
        let preads0 = svc.io_stats().read_calls;
        let workers: Vec<_> =
            reqs.iter().map(|list| (svc.session().unwrap(), list.as_slice())).collect();
        let hist = Hist::new();
        let t0 = Instant::now();
        let per_thread: Vec<u64> = std::thread::scope(|sc| {
            let handles: Vec<_> = workers
                .into_iter()
                .map(|(mut sess, list)| {
                    let hist = &hist;
                    sc.spawn(move || {
                        let mut bytes = 0u64;
                        for req in list {
                            let t = Instant::now();
                            match sess.serve(req).unwrap() {
                                ReadResponse::Array(v) => bytes += v.len() as u64,
                                ReadResponse::Varray { data, .. } => bytes += data.len() as u64,
                            }
                            hist.record(t.elapsed().as_nanos() as u64);
                        }
                        bytes
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let wall = t0.elapsed().as_secs_f64().max(1e-9);
        let bytes_served = per_thread.into_iter().sum();
        RunStats {
            rps: hist.count() as f64 / wall,
            p50_us: hist.p50_us(),
            p99_us: hist.p99_us(),
            preads: svc.io_stats().read_calls - preads0,
            bytes_served,
            cache: svc.cache_stats(),
        }
    }

    /// Measure one (sessions, budget) cell: the same deterministic
    /// request lists served with the shared cache and by the
    /// per-session-sieve baseline.
    pub fn run_one(
        path: &Path,
        sessions: usize,
        budget: usize,
        datasets: usize,
        elems: u64,
        elem_bytes: u64,
        per_session: usize,
        count: u64,
    ) -> ServeProfile {
        let (reqs, unique_bytes) =
            gen_workload(sessions, per_session, datasets, elems, elem_bytes, count);
        let shared = serve_once(path, budget, &reqs);
        let base = serve_once(path, 0, &reqs);
        assert_eq!(shared.bytes_served, base.bytes_served, "modes served identical payloads");
        let cs = shared.cache.expect("shared run has a cache");
        ServeProfile {
            sessions,
            budget_bytes: budget,
            requests: (sessions * per_session) as u64,
            unique_bytes,
            shared_rps: shared.rps,
            shared_p50_us: shared.p50_us,
            shared_p99_us: shared.p99_us,
            shared_preads: shared.preads,
            cache_hits: cs.hits,
            cache_misses: cs.misses,
            cache_evictions: cs.evictions,
            single_flight_waits: cs.single_flight_waits,
            baseline_rps: base.rps,
            baseline_p50_us: base.p50_us,
            baseline_p99_us: base.p99_us,
            baseline_preads: base.preads,
        }
    }

    /// The full [`SESSIONS`] x [`BUDGETS`] sweep against one archive of
    /// `datasets` arrays of `elems` x `elem_bytes` B, `per_session`
    /// zipfian requests of `count` elements each.
    pub fn run(
        datasets: usize,
        elems: u64,
        elem_bytes: u64,
        per_session: usize,
        count: u64,
    ) -> Vec<ServeProfile> {
        let dir = std::env::temp_dir().join("scda-serve-bench");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("serve-{}.scda", std::process::id()));
        build(&path, datasets, elems, elem_bytes);
        let mut out = Vec::new();
        for &s in &SESSIONS {
            for &b in &BUDGETS {
                out.push(run_one(&path, s, b, datasets, elems, elem_bytes, per_session, count));
            }
        }
        std::fs::remove_file(&path).ok();
        out
    }

    /// Quick-mode sweep: 8 datasets of 2048 x 64 B, 200 requests per
    /// session — the same grid as the full run, so the committed
    /// `BENCH_serve.json` keeps its shape under `SCDA_BENCH_QUICK`.
    pub fn run_quick() -> Vec<ServeProfile> {
        run(8, 2048, 64, 200, 16)
    }

    /// The standard `BENCH_serve.json` report for a sweep.
    pub fn report(
        profiles: &[ServeProfile],
        datasets: usize,
        elems: u64,
        elem_bytes: u64,
        per_session: usize,
    ) -> super::BenchReport {
        let mut r = super::BenchReport::new("serve");
        r.meta("quick", JsonVal::Bool(super::quick()));
        r.meta("datasets", JsonVal::Int(datasets as i64));
        r.meta("elems", JsonVal::Int(elems as i64));
        r.meta("elem_bytes", JsonVal::Int(elem_bytes as i64));
        r.meta("requests_per_session", JsonVal::Int(per_session as i64));
        for p in profiles {
            r.entry(vec![
                ("name", JsonVal::Str(format!("serve_s{}_b{}", p.sessions, p.budget_bytes))),
                ("sessions", JsonVal::Int(p.sessions as i64)),
                ("budget_bytes", JsonVal::Int(p.budget_bytes as i64)),
                ("requests", JsonVal::Int(p.requests as i64)),
                ("unique_bytes", JsonVal::Int(p.unique_bytes as i64)),
                ("shared_rps", JsonVal::Num(p.shared_rps)),
                ("shared_p50_us", JsonVal::Num(p.shared_p50_us)),
                ("shared_p99_us", JsonVal::Num(p.shared_p99_us)),
                ("shared_preads", JsonVal::Int(p.shared_preads as i64)),
                ("cache_hits", JsonVal::Int(p.cache_hits as i64)),
                ("cache_misses", JsonVal::Int(p.cache_misses as i64)),
                ("cache_evictions", JsonVal::Int(p.cache_evictions as i64)),
                ("single_flight_waits", JsonVal::Int(p.single_flight_waits as i64)),
                ("baseline_rps", JsonVal::Num(p.baseline_rps)),
                ("baseline_p50_us", JsonVal::Num(p.baseline_p50_us)),
                ("baseline_p99_us", JsonVal::Num(p.baseline_p99_us)),
                ("baseline_preads", JsonVal::Int(p.baseline_preads as i64)),
                ("speedup", JsonVal::Num(p.speedup())),
            ]);
        }
        r
    }
}

pub mod amr_bench {
    //! End-to-end AMR churn bench: the [`crate::runtime::scenario`]
    //! driver (refine → rebalance → checkpoint → seeded crash →
    //! recover → restore-on-P') with per-phase throughput, plus the
    //! catalog-reopen-cost-vs-step-count probe — the numbers
    //! `BENCH_amr.json` tracks.

    use super::{measure, JsonVal};
    use crate::coordinator::open_checkpoint;
    use crate::error::Result;
    use crate::par::SerialComm;
    use crate::runtime::scenario::{crash_path, run_scenario, ScenarioConfig, ScenarioReport};
    use std::path::{Path, PathBuf};

    /// One full scenario run plus the reopen probes.
    #[derive(Debug)]
    pub struct AmrProfile {
        pub cfg: ScenarioConfig,
        pub report: ScenarioReport,
        /// Median ms to reopen a 1-step archive and read its manifest.
        pub reopen_first_ms: f64,
        /// Same probe against the full `cfg.cycles`-step archive.
        pub reopen_last_ms: f64,
    }

    fn reopen_ms(path: &Path, reps: usize) -> f64 {
        let s = measure(1, reps.max(1), || {
            let (ar, _info) = open_checkpoint(SerialComm::new(), path).unwrap();
            ar.close().unwrap();
        });
        s.median * 1e3
    }

    /// Run the scenario against `path` and probe catalog reopen cost at
    /// 1 step (a sacrificial `<path>.one` sibling, removed afterwards)
    /// and at `cfg.cycles` steps (the archive itself).
    pub fn run(path: &Path, cfg: ScenarioConfig, reps: usize) -> Result<AmrProfile> {
        let report = run_scenario(path, &cfg)?;
        let mut one = path.as_os_str().to_os_string();
        one.push(".one");
        let one = PathBuf::from(one);
        let one_cfg =
            ScenarioConfig { cycles: 1, crash_seed: None, traced: false, ..cfg };
        run_scenario(&one, &one_cfg)?;
        let reopen_first_ms = reopen_ms(&one, reps);
        let reopen_last_ms = reopen_ms(path, reps);
        let _ = std::fs::remove_file(&one);
        Ok(AmrProfile { cfg, report, reopen_first_ms, reopen_last_ms })
    }

    /// Quick-mode defaults: 2 writer ranks, restore on 3, seeded crash
    /// armed; under `SCDA_BENCH_QUICK` the mesh and cycle count shrink
    /// but the report keeps its shape. Runs against a temp path and
    /// cleans up after itself.
    pub fn run_quick() -> AmrProfile {
        let q = super::quick();
        let cfg = ScenarioConfig {
            cycles: if q { 2 } else { 4 },
            base_level: if q { 2 } else { 3 },
            max_level: if q { 4 } else { 6 },
            writers: 2,
            restore_ranks: 3,
            crash_seed: Some(0xC4A5),
            ..ScenarioConfig::default()
        };
        let mut path = std::env::temp_dir();
        path.push(format!("scda-amr-bench-{}.scda", std::process::id()));
        let profile = run(&path, cfg, if q { 2 } else { 5 }).expect("amr bench scenario");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(crash_path(&path));
        profile
    }

    impl AmrProfile {
        /// The standard `BENCH_amr.json` report: per-phase throughput,
        /// crash/recover accounting and the reopen-cost pair. Entry
        /// names are fixed so quick and full runs share a shape.
        pub fn report(&self) -> super::BenchReport {
            let c = &self.report.cycles;
            let elements: u64 = c.iter().map(|s| s.elements).sum();
            let payload: u64 = c.iter().map(|s| s.payload_bytes).sum();
            let moved: u64 = c.iter().map(|s| s.moved_bytes).sum();
            let refine_s: f64 = c.iter().map(|s| s.refine_s).sum();
            let rebalance_s: f64 = c.iter().map(|s| s.rebalance_s).sum();
            let write_s: f64 = c.iter().map(|s| s.write_s).sum();
            let per_s = |n: u64, s: f64| n as f64 / s.max(1e-9);
            let mib_s = |b: u64, s: f64| b as f64 / (1024.0 * 1024.0) / s.max(1e-9);
            let mut r = super::BenchReport::new("amr");
            r.meta("quick", JsonVal::Bool(super::quick()))
                .meta("cycles", JsonVal::Int(self.cfg.cycles as i64))
                .meta("writers", JsonVal::Int(self.cfg.writers as i64))
                .meta("restore_ranks", JsonVal::Int(self.cfg.restore_ranks as i64))
                .meta("base_level", JsonVal::Int(self.cfg.base_level as i64))
                .meta("max_level", JsonVal::Int(self.cfg.max_level as i64))
                .meta("seed", JsonVal::Int(self.cfg.seed as i64))
                .meta("encode", JsonVal::Bool(self.cfg.encode));
            r.entry(vec![
                ("name", JsonVal::Str("refine".into())),
                ("elements", JsonVal::Int(elements as i64)),
                ("seconds", JsonVal::Num(refine_s)),
                ("elements_per_s", JsonVal::Num(per_s(elements, refine_s))),
            ]);
            r.entry(vec![
                ("name", JsonVal::Str("rebalance".into())),
                ("elements", JsonVal::Int(elements as i64)),
                ("moved_bytes", JsonVal::Int(moved as i64)),
                ("seconds", JsonVal::Num(rebalance_s)),
                ("elements_per_s", JsonVal::Num(per_s(elements, rebalance_s))),
            ]);
            r.entry(vec![
                ("name", JsonVal::Str("checkpoint".into())),
                ("payload_bytes", JsonVal::Int(payload as i64)),
                ("file_bytes", JsonVal::Int(self.report.file_bytes as i64)),
                ("seconds", JsonVal::Num(write_s)),
                ("mib_per_s", JsonVal::Num(mib_s(payload, write_s))),
            ]);
            let rs = &self.report.restore;
            r.entry(vec![
                ("name", JsonVal::Str("restore".into())),
                ("ranks", JsonVal::Int(rs.ranks as i64)),
                ("steps", JsonVal::Int(rs.steps as i64)),
                ("payload_bytes", JsonVal::Int(rs.payload_bytes as i64)),
                ("seconds", JsonVal::Num(rs.seconds)),
                ("mib_per_s", JsonVal::Num(mib_s(rs.payload_bytes, rs.seconds))),
            ]);
            let (rec_ms, rec_cut, rec_steps, rec_sets) = match &self.report.recover {
                Some(rec) => {
                    (rec.seconds * 1e3, rec.truncated_bytes, rec.steps_survived, rec.datasets)
                }
                None => (0.0, 0, 0, 0),
            };
            r.entry(vec![
                ("name", JsonVal::Str("recover".into())),
                ("ms", JsonVal::Num(rec_ms)),
                ("truncated_bytes", JsonVal::Int(rec_cut as i64)),
                ("steps_survived", JsonVal::Int(rec_steps as i64)),
                ("datasets", JsonVal::Int(rec_sets as i64)),
            ]);
            r.entry(vec![
                ("name", JsonVal::Str("reopen_first".into())),
                ("steps", JsonVal::Int(1)),
                ("open_ms", JsonVal::Num(self.reopen_first_ms)),
            ]);
            r.entry(vec![
                ("name", JsonVal::Str("reopen_last".into())),
                ("steps", JsonVal::Int(self.cfg.cycles as i64)),
                ("open_ms", JsonVal::Num(self.reopen_last_ms)),
            ]);
            r
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_reports_sane_stats() {
        let s = measure(1, 5, || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert_eq!(s.reps, 5);
        assert!(s.min >= 0.001);
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.mib_per_s(1024 * 1024) > 0.0);
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new(&["a", "bee"]);
        t.row(&["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("| a | bee |") || r.contains("|   a | bee |") || r.contains("| a |"));
        assert!(r.lines().count() == 3);
    }

    #[test]
    fn bench_report_renders_valid_json_shape() {
        let mut r = BenchReport::new("codec");
        r.meta("quick", JsonVal::Bool(true)).meta("lanes", JsonVal::Int(4));
        r.entry(vec![
            ("name", JsonVal::Str("encoded \"write\"".into())),
            ("serial_mib_per_s", JsonVal::Num(10.5)),
            ("speedup", JsonVal::Num(f64::NAN)),
        ]);
        let s = r.render();
        assert!(s.contains("\"bench\": \"codec\""));
        assert!(s.contains("\"lanes\": 4"));
        assert!(s.contains("\\\"write\\\""));
        assert!(s.contains("\"speedup\": null"));
        assert!(s.contains("\"serial_mib_per_s\": 10.500"));
        // Balanced braces/brackets (cheap structural sanity).
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn corpus_is_deterministic_and_sized() {
        let a = corpus(10_000);
        let b = corpus(10_000);
        assert_eq!(a.len(), 4);
        for ((n1, d1), (n2, d2)) in a.iter().zip(b.iter()) {
            assert_eq!(n1, n2);
            assert_eq!(d1, d2);
            assert_eq!(d1.len(), 10_000);
        }
    }
}
