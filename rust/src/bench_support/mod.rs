//! Benchmark harness support (the offline environment lacks criterion):
//! wall-clock measurement with warmup and repetition statistics, table
//! rendering matching the experiment ids in DESIGN.md §Experiments, and
//! shared workload corpora.

pub mod sha256;

pub use sha256::{hex, sha256};

use std::time::Instant;

/// Measurement of repeated runs (seconds).
#[derive(Debug, Clone)]
pub struct Sample {
    pub reps: usize,
    pub min: f64,
    pub median: f64,
    pub mean: f64,
    pub max: f64,
}

/// Run `f` `reps` times after `warmup` runs; report statistics.
pub fn measure(warmup: usize, reps: usize, mut f: impl FnMut()) -> Sample {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = times.iter().sum::<f64>() / reps as f64;
    Sample { reps, min: times[0], median: times[reps / 2], mean, max: times[reps - 1] }
}

impl Sample {
    /// Throughput in MiB/s for `bytes` processed per rep (median-based).
    pub fn mib_per_s(&self, bytes: u64) -> f64 {
        bytes as f64 / (1024.0 * 1024.0) / self.median
    }
}

/// Simple fixed-width table printer (markdown-flavored) so bench output
/// can be pasted into EXPERIMENTS.md verbatim.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!(" {c:>w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Workload corpora shared by the compression/precondition benches; each
/// is (name, bytes) with deterministic contents.
pub fn corpus(len: usize) -> Vec<(&'static str, Vec<u8>)> {
    use crate::mesh::{fields, ring_mesh};
    use crate::testutil::Rng;
    let mut rng = Rng::new(0xC0FFEE);
    let mut out = Vec::new();
    out.push(("zeros", vec![0u8; len]));
    out.push(("random", rng.bytes(len, 256)));
    out.push(("text", {
        let phrase = b"The scda format is serial-equivalent by design. ";
        phrase.iter().cycle().take(len).copied().collect()
    }));
    // Smooth AMR f64 field bytes — the paper's target workload.
    let mesh = ring_mesh(5, 8, (0.5, 0.5), 0.3);
    let mut amr = Vec::with_capacity(len);
    'outer: loop {
        for q in &mesh {
            amr.extend_from_slice(&fields::fixed_payload(q, 5));
            if amr.len() >= len {
                break 'outer;
            }
        }
    }
    amr.truncate(len);
    out.push(("amr-f64", amr));
    out
}

/// `SCDA_BENCH_QUICK=1` shrinks workloads for CI-style smoke runs.
pub fn quick() -> bool {
    std::env::var_os("SCDA_BENCH_QUICK").is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_reports_sane_stats() {
        let s = measure(1, 5, || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert_eq!(s.reps, 5);
        assert!(s.min >= 0.001);
        assert!(s.min <= s.median && s.median <= s.max);
        assert!(s.mib_per_s(1024 * 1024) > 0.0);
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new(&["a", "bee"]);
        t.row(&["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("| a | bee |") || r.contains("|   a | bee |") || r.contains("| a |"));
        assert!(r.lines().count() == 3);
    }

    #[test]
    fn corpus_is_deterministic_and_sized() {
        let a = corpus(10_000);
        let b = corpus(10_000);
        assert_eq!(a.len(), 4);
        for ((n1, d1), (n2, d2)) in a.iter().zip(b.iter()) {
            assert_eq!(n1, n2);
            assert_eq!(d1, d2);
            assert_eq!(d1.len(), 10_000);
        }
    }
}
