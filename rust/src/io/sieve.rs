//! Read sieving: the read-side dual of write aggregation. A section read
//! touches a handful of small, nearby regions — the 64-byte type row, one
//! or two 32-byte count rows, per-element size rows, small payloads. The
//! sieve fetches one large aligned window with a single `pread` and
//! serves those small reads from the buffer; only genuinely large payload
//! reads go to the file directly.
//!
//! The sieve is only attached to read-mode files, which cannot change
//! underneath it (scda files are create-once: "the only possibility to
//! write to a file is to create a new one", §A.3) — so the window and the
//! cached file length never go stale.
//!
//! # Window adaptivity
//!
//! The window size adapts to the observed access pattern with hysteresis:
//!
//! * **Sequential scans** (toc-style: every refill starts right after the
//!   previous window) double the window after [`GROW_AFTER`] consecutive
//!   sequential refills, up to [`MAX_GROWTH`]× the configured size — a
//!   long metadata scan converges to `log` many refills instead of
//!   `bytes / window`.
//! * **Non-contiguous seeks** (random section access) halve the window
//!   after [`SHRINK_AFTER`] consecutive jumps, down to the 4 KiB
//!   alignment — a random-access reader stops paying for window bytes it
//!   never uses.
//!
//! The streak counters mean one stray seek inside a scan (or one local
//! run inside random access) never flips the window — that is the
//! hysteresis `grow_and_shrink_have_hysteresis` asserts.
//!
//! # Shared-cache backing
//!
//! A sieve built with [`ReadSieve::shared`] keeps all of the above —
//! the window, the adaptivity, the local assembly buffer — but sources
//! its refills from a shared [`PageCache`] instead of a private
//! `pread`: concurrent sessions reading the same archive then share one
//! page pool under one budget, overlapping refills dedupe to one fill
//! `pread` (single-flight), and large payload reads route through the
//! cache too (up to the cache's bypass bound). The *adaptive state*
//! stays strictly per sieve, i.e. per session stream: one client's
//! random access can never shrink another client's sequential-scan
//! window, because only the page pool is shared — never the hysteresis
//! counters ([`ReadSieve::reset_adaptivity`] re-arms a stream that is
//! handed to a new client).

use std::sync::Arc;

use crate::error::{corrupt, Result, ScdaError};
use crate::io::cache::{CacheAccess, PageCache};
use crate::par::pfile::ParallelFile;

/// Window alignment: refills start on a 4 KiB boundary so the buffered
/// range also covers bytes shortly *before* the requested offset (the V
/// pattern: size rows just behind a payload read).
const WINDOW_ALIGN: u64 = 4096;

/// Consecutive sequential refills before the window doubles.
const GROW_AFTER: u32 = 2;

/// Consecutive non-contiguous refills before the window halves.
const SHRINK_AFTER: u32 = 2;

/// The window never grows past this multiple of the configured size.
const MAX_GROWTH: usize = 8;

/// A buffered window over a read-only [`ParallelFile`].
#[derive(Debug)]
pub struct ReadSieve {
    buf: Vec<u8>,
    /// Absolute file offset of `buf[0]`.
    buf_off: u64,
    /// Current (adaptive) window size; refills read at least this much
    /// when the file has it.
    window: usize,
    /// The configured window size the adaptivity is anchored to.
    base: usize,
    /// File length, fixed at open (read-only files cannot grow).
    file_len: u64,
    /// Number of window refills issued (observability).
    refills: u64,
    seq_streak: u32,
    jump_streak: u32,
    grows: u64,
    shrinks: u64,
    /// Shared-cache backing plus this stream's accounting; `None` means
    /// the classic private-window sieve.
    shared: Option<SharedStream>,
}

/// One session stream's view of the shared page pool.
#[derive(Debug)]
struct SharedStream {
    cache: Arc<PageCache>,
    stats: CacheAccess,
}

impl ReadSieve {
    pub fn new(window: usize, file_len: u64) -> Self {
        assert!(window > 0, "a zero sieve window means 'no sieve' (use None)");
        ReadSieve {
            buf: Vec::new(),
            buf_off: 0,
            window,
            base: window,
            file_len,
            refills: 0,
            seq_streak: 0,
            jump_streak: 0,
            grows: 0,
            shrinks: 0,
            shared: None,
        }
    }

    /// A sieve whose refills are served from a shared [`PageCache`]
    /// instead of private `pread`s. The window and its adaptivity are
    /// unchanged (they now govern per-refill readahead *through* the
    /// cache); only the backing store is pooled.
    pub fn shared(window: usize, file_len: u64, cache: Arc<PageCache>) -> Self {
        let mut s = Self::new(window, file_len);
        s.shared = Some(SharedStream { cache, stats: CacheAccess::default() });
        s
    }

    /// Whether refills route through a shared page cache.
    pub fn is_shared(&self) -> bool {
        self.shared.is_some()
    }

    /// This stream's hit/miss/wait accounting against the shared cache
    /// (all zero for a private sieve).
    pub fn stream_stats(&self) -> CacheAccess {
        self.shared.as_ref().map(|s| s.stats).unwrap_or_default()
    }

    /// Evictions of the backing shared cache (pool-global; 0 private).
    pub fn cache_evictions(&self) -> u64 {
        self.shared.as_ref().map(|s| s.cache.stats().evictions).unwrap_or(0)
    }

    /// Re-arm the adaptive window for a fresh client stream: window back
    /// to the configured base, streak counters cleared. Session-oriented
    /// callers (the archive read service) invoke this when a sieve-backed
    /// handle is handed to a new client, so one client's access pattern
    /// never leaks hysteresis into the next one's.
    pub fn reset_adaptivity(&mut self) {
        self.window = self.base;
        self.seq_streak = 0;
        self.jump_streak = 0;
    }

    /// The current window size (what the next refill fetches).
    pub fn window(&self) -> usize {
        self.window
    }

    /// The configured window size. Payload-read routing gates on this,
    /// not the adaptive current size: window growth should amortize
    /// *metadata* refills, never pull large payload reads (one exact
    /// pread each) through the window's extra copy.
    pub fn base_window(&self) -> usize {
        self.base
    }

    pub fn refills(&self) -> u64 {
        self.refills
    }

    /// How often the window doubled (sequential-scan adaptivity).
    pub fn grows(&self) -> u64 {
        self.grows
    }

    /// How often the window halved (random-access adaptivity).
    pub fn shrinks(&self) -> u64 {
        self.shrinks
    }

    /// Classify a refill against the current window and adapt the window
    /// size; see the module docs for the hysteresis rules.
    fn adapt(&mut self, off: u64) {
        if self.buf.is_empty() {
            return; // first refill: no pattern yet
        }
        let prev_end = self.buf_off + self.buf.len() as u64;
        // Sequential = forward progress within reach of the window. The
        // triggering read of a dense scan usually *starts* inside the
        // current window (the boundary falls mid-read), so any `off >=
        // buf_off` short of a window-sized leap counts as sequential;
        // only backward seeks and far-forward leaps are jumps.
        let sequential = off >= self.buf_off && off < prev_end + self.window as u64;
        if sequential {
            self.seq_streak += 1;
            self.jump_streak = 0;
            if self.seq_streak >= GROW_AFTER {
                let grown = (self.window * 2).min(self.base * MAX_GROWTH);
                if grown > self.window {
                    self.window = grown;
                    self.grows += 1;
                }
                self.seq_streak = 0;
            }
        } else {
            self.jump_streak += 1;
            self.seq_streak = 0;
            if self.jump_streak >= SHRINK_AFTER {
                let shrunk = (self.window / 2).max(WINDOW_ALIGN as usize);
                if shrunk < self.window {
                    self.window = shrunk;
                    self.shrinks += 1;
                }
                self.jump_streak = 0;
            }
        }
    }

    /// A view of `len` bytes at absolute `off`, refilling the window from
    /// `file` if the range is not buffered. Errors with the same corrupt
    /// kind as a direct short read if the range exceeds the file.
    pub fn view(&mut self, file: &ParallelFile, off: u64, len: usize) -> Result<&[u8]> {
        let end = off
            .checked_add(len as u64)
            .ok_or_else(|| ScdaError::corrupt(corrupt::COUNT_OVERFLOW, "read range overflows u64"))?;
        if end > self.file_len {
            return Err(ScdaError::corrupt(
                corrupt::TRUNCATED,
                format!("file ends before {len} bytes at offset {off}"),
            ));
        }
        let cached = off >= self.buf_off && end <= self.buf_off + self.buf.len() as u64;
        if !cached {
            self.adapt(off);
            let start = (off / WINDOW_ALIGN) * WINDOW_ALIGN;
            let win_end = (start + self.window as u64).max(end).min(self.file_len);
            let take = (win_end - start) as usize;
            self.buf.resize(take, 0);
            match &mut self.shared {
                Some(s) => {
                    let acc = s.cache.read_into(file, start, &mut self.buf)?;
                    s.stats.absorb(acc);
                }
                None => file.read_at(start, &mut self.buf)?,
            }
            self.buf_off = start;
            self.refills += 1;
        }
        let rel = (off - self.buf_off) as usize;
        Ok(&self.buf[rel..rel + len])
    }

    /// [`Self::view`] into a fresh buffer.
    pub fn read_vec(&mut self, file: &ParallelFile, off: u64, len: usize) -> Result<Vec<u8>> {
        Ok(self.view(file, off, len)?.to_vec())
    }

    /// The large-read route of a shared sieve: fill `buf` straight from
    /// the page cache (no window, no assembly copy into `self.buf`), so
    /// overlapping payload reads across sessions still dedupe to one
    /// fill. Reads at or past the cache's bypass bound — payloads big
    /// enough to churn the whole budget — go direct, exactly like the
    /// private sieve's large-read bypass. On a private sieve this is a
    /// plain direct read.
    pub fn shared_read_into(&mut self, file: &ParallelFile, off: u64, buf: &mut [u8]) -> Result<()> {
        match &mut self.shared {
            Some(s) if buf.len() < s.cache.bypass_bytes() => {
                let acc = s.cache.read_into(file, off, buf)?;
                s.stats.absorb(acc);
                Ok(())
            }
            _ => crate::io::fault::retry_transient(|| file.read_at(off, buf)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::{Communicator, SerialComm};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("scda-sieve");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    fn file_with(n: usize, name: &str) -> (ParallelFile, PathBuf) {
        let path = tmp(name);
        let c = SerialComm::new();
        assert_eq!(c.rank(), 0);
        let f = ParallelFile::create(&c, &path).unwrap();
        let data: Vec<u8> = (0..n).map(|i| (i % 251) as u8).collect();
        f.write_at(0, &data).unwrap();
        (f, path)
    }

    #[test]
    fn serves_many_small_reads_from_one_window() {
        let (f, path) = file_with(64 * 1024, "small");
        let before = f.io_stats().read_calls;
        let mut s = ReadSieve::new(16 * 1024, 64 * 1024);
        for off in (0..8 * 1024u64).step_by(32) {
            let v = s.view(&f, off, 32).unwrap().to_vec();
            let expect: Vec<u8> = (off..off + 32).map(|i| (i % 251) as u8).collect();
            assert_eq!(v, expect, "off {off}");
        }
        assert_eq!(s.refills(), 1);
        assert_eq!(f.io_stats().read_calls - before, 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn window_slides_forward_and_clamps_to_eof() {
        let (f, path) = file_with(10_000, "slide");
        let mut s = ReadSieve::new(4096, 10_000);
        assert_eq!(s.view(&f, 0, 10).unwrap()[0], 0);
        // Past the first window: refill, aligned down.
        let v = s.view(&f, 9_990, 10).unwrap().to_vec();
        let expect: Vec<u8> = (9_990..10_000u64).map(|i| (i % 251) as u8).collect();
        assert_eq!(v, expect);
        assert_eq!(s.refills(), 2);
        // Request larger than the window still works.
        let big = s.view(&f, 100, 8000).unwrap().to_vec();
        assert_eq!(big.len(), 8000);
        assert_eq!(big[0], 100 % 251);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn past_eof_is_corrupt_error() {
        let (f, path) = file_with(100, "eof");
        let mut s = ReadSieve::new(4096, 100);
        let err = s.view(&f, 90, 20).unwrap_err();
        assert_eq!(err.kind(), crate::error::ScdaErrorKind::CorruptFile);
        // In-bounds still fine afterwards.
        assert_eq!(s.view(&f, 90, 10).unwrap().len(), 10);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn sequential_scan_doubles_window() {
        let len = 512 * 1024;
        let (f, path) = file_with(len, "grow");
        let base = 8 * 1024;
        let mut s = ReadSieve::new(base, len as u64);
        // Walk the file forward in small steps: every refill is
        // sequential, so the window doubles every GROW_AFTER refills up
        // to the 8x cap.
        for off in (0..len as u64).step_by(1024) {
            s.view(&f, off, 512).unwrap();
        }
        assert!(s.grows() >= 3, "only {} grows over a long scan", s.grows());
        assert_eq!(s.window(), base * MAX_GROWTH, "long scan converges to the cap");
        assert_eq!(s.shrinks(), 0);
        // Growth pays: far fewer refills than bytes/base.
        assert!(s.refills() < (len / base) as u64, "{} refills", s.refills());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn random_seeks_shrink_window() {
        let len = 512 * 1024;
        let (f, path) = file_with(len, "shrink");
        let base = 64 * 1024;
        let mut s = ReadSieve::new(base, len as u64);
        // Alternate between two far-apart regions: every refill is a
        // jump, so the window halves every SHRINK_AFTER refills down to
        // the 4 KiB alignment floor.
        for i in 0..16u64 {
            let off = if i % 2 == 0 { 0 } else { 400 * 1024 };
            s.view(&f, off + i, 16).unwrap();
        }
        assert!(s.shrinks() >= 3, "only {} shrinks under random access", s.shrinks());
        assert_eq!(s.window(), WINDOW_ALIGN as usize);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn grow_and_shrink_have_hysteresis() {
        let len = 1024 * 1024;
        let (f, path) = file_with(len, "hysteresis");
        let base = 8 * 1024;
        let mut s = ReadSieve::new(base, len as u64);
        s.view(&f, 0, 16).unwrap(); // first refill: neutral
        // One sequential refill alone must not grow the window...
        s.view(&f, base as u64 + 16, 16).unwrap();
        assert_eq!((s.window(), s.grows()), (base, 0));
        // ...and one jump resets the streak without shrinking.
        s.view(&f, 900 * 1024, 16).unwrap();
        assert_eq!((s.window(), s.shrinks()), (base, 0));
        // A second consecutive jump is a pattern: shrink.
        s.view(&f, 16, 16).unwrap();
        assert_eq!((s.window(), s.shrinks()), (base / 2, 1));
        // Two consecutive sequential refills after the shrink: grow once.
        let e1 = s.buf_off + s.buf.len() as u64;
        s.view(&f, e1, 16).unwrap();
        assert_eq!(s.grows(), 0, "one sequential refill is not yet a pattern");
        let e2 = s.buf_off + s.buf.len() as u64;
        s.view(&f, e2, 16).unwrap();
        assert_eq!(s.grows(), 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn shared_sieves_dedupe_refills_through_one_cache() {
        use crate::io::cache::PageCache;
        use std::sync::Arc;
        let len = 256 * 1024;
        let (f, path) = file_with(len, "shared-dedupe");
        let cache = Arc::new(PageCache::new(4096, 1 << 20));
        let mut a = ReadSieve::shared(16 * 1024, len as u64, Arc::clone(&cache));
        let mut b = ReadSieve::shared(16 * 1024, len as u64, Arc::clone(&cache));
        let before = f.io_stats().read_calls;
        // Session A fills its window; session B's identical window is
        // then served entirely from the shared pages — zero syscalls.
        let va = a.view(&f, 100, 64).unwrap().to_vec();
        let after_a = f.io_stats().read_calls;
        let vb = b.view(&f, 100, 64).unwrap().to_vec();
        assert_eq!(va, vb);
        assert_eq!(after_a - before, 1, "A's refill is one gather pread");
        assert_eq!(f.io_stats().read_calls, after_a, "B refilled without a syscall");
        assert!(b.stream_stats().hits > 0 && b.stream_stats().misses == 0, "{:?}", b.stream_stats());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn adaptive_state_is_per_stream_even_with_a_shared_cache() {
        use crate::io::cache::PageCache;
        use std::sync::Arc;
        let len = 1024 * 1024;
        let (f, path) = file_with(len, "shared-isolated");
        let cache = Arc::new(PageCache::new(4096, 64 << 10));
        let base = 8 * 1024;
        let mut seq = ReadSieve::shared(base, len as u64, Arc::clone(&cache));
        let mut rnd = ReadSieve::shared(base, len as u64, Arc::clone(&cache));
        // Interleave a sequential scanner with a random-access client on
        // the SAME cache: the scanner's window still grows to the cap and
        // the random client's still shrinks to the floor — hysteresis
        // never crosses streams.
        let mut off = 0u64;
        for i in 0..64u64 {
            seq.view(&f, off, 512).unwrap();
            off += 9 * 1024;
            let r = if i % 2 == 0 { 16 } else { 900 * 1024 };
            rnd.view(&f, r + i, 16).unwrap();
        }
        assert_eq!(seq.window(), base * MAX_GROWTH, "scanner reached the cap");
        assert_eq!(seq.shrinks(), 0, "the random client never shrank the scanner");
        assert_eq!(rnd.window(), WINDOW_ALIGN as usize, "random client at the floor");
        assert_eq!(rnd.grows(), 0);
        // Re-arming a stream for a new client restores the base window.
        seq.reset_adaptivity();
        assert_eq!(seq.window(), base);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn shared_large_reads_route_through_the_cache_with_bypass() {
        use crate::io::cache::PageCache;
        use std::sync::Arc;
        let len = 512 * 1024;
        let (f, path) = file_with(len, "shared-large");
        let cache = Arc::new(PageCache::new(4096, 128 << 10));
        let mut s = ReadSieve::shared(8 * 1024, len as u64, Arc::clone(&cache));
        // 32 KiB < bypass (64 KiB): cached.
        let mut buf = vec![0u8; 32 * 1024];
        s.shared_read_into(&f, 1000, &mut buf).unwrap();
        let expect: Vec<u8> = (1000..1000 + 32 * 1024u64).map(|i| (i % 251) as u8).collect();
        assert_eq!(buf, expect);
        assert!(cache.stats().fill_preads >= 1);
        let fills = cache.stats().fill_preads;
        // Same range again: pure hits.
        s.shared_read_into(&f, 1000, &mut buf).unwrap();
        assert_eq!(cache.stats().fill_preads, fills);
        // 128 KiB >= bypass: direct, cache untouched.
        let preads = f.io_stats().read_calls;
        let mut big = vec![0u8; 128 * 1024];
        s.shared_read_into(&f, 0, &mut big).unwrap();
        assert_eq!(f.io_stats().read_calls, preads + 1);
        assert_eq!(cache.stats().fill_preads, fills);
        std::fs::remove_file(&path).unwrap();
    }
}
