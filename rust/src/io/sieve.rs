//! Read sieving: the read-side dual of write aggregation. A section read
//! touches a handful of small, nearby regions — the 64-byte type row, one
//! or two 32-byte count rows, per-element size rows, small payloads. The
//! sieve fetches one large aligned window with a single `pread` and
//! serves those small reads from the buffer; only genuinely large payload
//! reads go to the file directly.
//!
//! The sieve is only attached to read-mode files, which cannot change
//! underneath it (scda files are create-once: "the only possibility to
//! write to a file is to create a new one", §A.3) — so the window and the
//! cached file length never go stale.

use crate::error::{corrupt, Result, ScdaError};
use crate::par::pfile::ParallelFile;

/// Window alignment: refills start on a 4 KiB boundary so the buffered
/// range also covers bytes shortly *before* the requested offset (the V
/// pattern: size rows just behind a payload read).
const WINDOW_ALIGN: u64 = 4096;

/// A buffered window over a read-only [`ParallelFile`].
#[derive(Debug)]
pub struct ReadSieve {
    buf: Vec<u8>,
    /// Absolute file offset of `buf[0]`.
    buf_off: u64,
    /// Nominal window size; refills read at least this much when the file
    /// has it.
    window: usize,
    /// File length, fixed at open (read-only files cannot grow).
    file_len: u64,
    /// Number of window refills issued (observability).
    refills: u64,
}

impl ReadSieve {
    pub fn new(window: usize, file_len: u64) -> Self {
        assert!(window > 0, "a zero sieve window means 'no sieve' (use None)");
        ReadSieve { buf: Vec::new(), buf_off: 0, window, file_len, refills: 0 }
    }

    /// The nominal window size (callers route reads >= this directly).
    pub fn window(&self) -> usize {
        self.window
    }

    pub fn refills(&self) -> u64 {
        self.refills
    }

    /// A view of `len` bytes at absolute `off`, refilling the window from
    /// `file` if the range is not buffered. Errors with the same corrupt
    /// kind as a direct short read if the range exceeds the file.
    pub fn view(&mut self, file: &ParallelFile, off: u64, len: usize) -> Result<&[u8]> {
        let end = off
            .checked_add(len as u64)
            .ok_or_else(|| ScdaError::corrupt(corrupt::COUNT_OVERFLOW, "read range overflows u64"))?;
        if end > self.file_len {
            return Err(ScdaError::corrupt(
                corrupt::TRUNCATED,
                format!("file ends before {len} bytes at offset {off}"),
            ));
        }
        let cached = off >= self.buf_off && end <= self.buf_off + self.buf.len() as u64;
        if !cached {
            let start = (off / WINDOW_ALIGN) * WINDOW_ALIGN;
            let win_end = (start + self.window as u64).max(end).min(self.file_len);
            let take = (win_end - start) as usize;
            self.buf.resize(take, 0);
            file.read_at(start, &mut self.buf)?;
            self.buf_off = start;
            self.refills += 1;
        }
        let rel = (off - self.buf_off) as usize;
        Ok(&self.buf[rel..rel + len])
    }

    /// [`Self::view`] into a fresh buffer.
    pub fn read_vec(&mut self, file: &ParallelFile, off: u64, len: usize) -> Result<Vec<u8>> {
        Ok(self.view(file, off, len)?.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::{Communicator, SerialComm};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("scda-sieve");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    fn file_with(n: usize, name: &str) -> (ParallelFile, PathBuf) {
        let path = tmp(name);
        let c = SerialComm::new();
        assert_eq!(c.rank(), 0);
        let f = ParallelFile::create(&c, &path).unwrap();
        let data: Vec<u8> = (0..n).map(|i| (i % 251) as u8).collect();
        f.write_at(0, &data).unwrap();
        (f, path)
    }

    #[test]
    fn serves_many_small_reads_from_one_window() {
        let (f, path) = file_with(64 * 1024, "small");
        let before = f.io_stats().read_calls;
        let mut s = ReadSieve::new(16 * 1024, 64 * 1024);
        for off in (0..8 * 1024u64).step_by(32) {
            let v = s.view(&f, off, 32).unwrap().to_vec();
            let expect: Vec<u8> = (off..off + 32).map(|i| (i % 251) as u8).collect();
            assert_eq!(v, expect, "off {off}");
        }
        assert_eq!(s.refills(), 1);
        assert_eq!(f.io_stats().read_calls - before, 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn window_slides_forward_and_clamps_to_eof() {
        let (f, path) = file_with(10_000, "slide");
        let mut s = ReadSieve::new(4096, 10_000);
        assert_eq!(s.view(&f, 0, 10).unwrap()[0], 0);
        // Past the first window: refill, aligned down.
        let v = s.view(&f, 9_990, 10).unwrap().to_vec();
        let expect: Vec<u8> = (9_990..10_000u64).map(|i| (i % 251) as u8).collect();
        assert_eq!(v, expect);
        assert_eq!(s.refills(), 2);
        // Request larger than the window still works.
        let big = s.view(&f, 100, 8000).unwrap().to_vec();
        assert_eq!(big.len(), 8000);
        assert_eq!(big[0], 100 % 251);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn past_eof_is_corrupt_error() {
        let (f, path) = file_with(100, "eof");
        let mut s = ReadSieve::new(4096, 100);
        let err = s.view(&f, 90, 20).unwrap_err();
        assert_eq!(err.kind(), crate::error::ScdaErrorKind::CorruptFile);
        // In-bounds still fine afterwards.
        assert_eq!(s.view(&f, 90, 10).unwrap().len(), 10);
        std::fs::remove_file(&path).unwrap();
    }
}
