//! Two-phase collective buffering (MPI-IO style) behind the
//! [`IoEngine`] trait: ranks stage their small writes locally and, at
//! collective points, ship them over [`Communicator::alltoall_bytes`] to
//! the *aggregator rank* owning each file stripe. Phase one is the
//! exchange; phase two is each aggregator replaying the fragments it
//! received and issuing one `pwrite` per contiguous run of its stripes.
//!
//! # Why this helps
//!
//! Per-rank aggregation (PR 2) merges a rank's *own* extents, but a
//! rank's extents in an interleaved section stream are separated by the
//! other ranks' windows — so its run count grows with P × section
//! interleaving. After the exchange, each stripe's bytes live on exactly
//! one rank, so the run count per stripe is 1 no matter how sections
//! interleave ranks: write syscalls become a function of *file size*,
//! not of *access pattern* (`rust/tests/io_engines.rs` asserts this).
//!
//! # Correctness
//!
//! Stripe `s` (bytes `[s·S, (s+1)·S)`) is owned by rank `s mod P`; the
//! ownership map is a pure function of collective inputs, so all ranks
//! agree on it without communication. Serial equivalence survives the
//! re-homing because (a) the section paths write every file byte exactly
//! once, and a rank's staged extents lie in its own disjoint windows, so
//! fragments from different sources never overlap; (b) fragments from
//! one source replay in that source's stage order; and (c) which rank
//! issues a `pwrite` is invisible in the bytes — the same §2 argument
//! that makes the format partition-independent. The engine is
//! property-tested byte-identical to [`DirectEngine`] at 1/2/4/8 ranks.
//!
//! Large writes (≥ the staging capacity) bypass the exchange: they are
//! already one syscall, and shipping them would only move bytes. The
//! bypass drains this rank's staged extents locally first, preserving
//! stage order without a collective.

use std::sync::Arc;

use crate::error::{Result, ScdaError};
use crate::io::aggregate::WriteAggregator;
use crate::io::engine::{
    dispatch_runs, route_read_into, route_read_vec, route_view, AsyncFlusher, EngineStats, IoEngine,
};
use crate::io::sieve::ReadSieve;
use crate::par::comm::Communicator;
use crate::par::pfile::ParallelFile;

#[cfg(doc)]
use crate::io::engine::DirectEngine;

/// The collective two-phase engine; see the module docs.
pub struct CollectiveEngine {
    /// This rank's staged extents, in stage order.
    agg: WriteAggregator,
    /// Exchange threshold: a section boundary triggers the collective
    /// exchange once any rank has staged at least half of this. Also the
    /// large-write bypass bound.
    capacity: usize,
    /// Stripe size in bytes; stripe `s` is owned by rank `s % P`.
    stripe: u64,
    sieve: Option<ReadSieve>,
    scratch: Vec<u8>,
    flusher: Option<AsyncFlusher>,
    shipped_bytes: u64,
    exchanges: u64,
    flush_batches: u64,
    /// Bytes shipped in each exchange, in exchange order (ROADMAP's
    /// stripe-ownership follow-up wants this shape, not just the
    /// total). Bounded at [`SHIPPED_HISTORY_CAP`] most-recent entries so
    /// a long-lived file cannot grow it without limit.
    shipped_history: std::collections::VecDeque<u64>,
}

/// Most-recent exchanges kept in [`EngineStats::shipped_per_exchange`];
/// older entries are dropped (the running totals in `shipped_bytes` /
/// `exchanges` are never truncated).
pub const SHIPPED_HISTORY_CAP: usize = 1024;

impl CollectiveEngine {
    pub fn new(capacity: usize, stripe_size: usize, sieve: Option<ReadSieve>, async_flush: bool) -> Self {
        CollectiveEngine {
            agg: WriteAggregator::new(),
            capacity,
            stripe: (stripe_size.max(1)) as u64,
            sieve,
            scratch: Vec::new(),
            flusher: async_flush.then(AsyncFlusher::new),
            shipped_bytes: 0,
            exchanges: 0,
            flush_batches: 0,
            shipped_history: std::collections::VecDeque::new(),
        }
    }

    /// Write this rank's staged extents itself (merged runs), skipping the
    /// exchange. Used for the large-write bypass and the drop path — both
    /// byte-correct, since staged extents are this rank's own windows.
    fn drain_staged_locally(&mut self, file: &Arc<ParallelFile>) -> Result<()> {
        if self.agg.is_empty() {
            return Ok(());
        }
        let runs = self.agg.take_runs();
        self.flush_batches += 1;
        dispatch_runs(&mut self.flusher, file, runs)
    }

    /// Phase one + two: split staged extents at stripe boundaries, ship
    /// each fragment to its stripe's owner, replay what this rank
    /// received (own fragments included, in source-rank order) and write
    /// one syscall per contiguous run. Collective.
    fn exchange(&mut self, file: &Arc<ParallelFile>, comm: &dyn Communicator) -> Result<()> {
        let p = comm.size();
        let me = comm.rank();
        self.exchanges += 1;
        let shipped_before = self.shipped_bytes;
        let extents = self.agg.take_extents();
        let mut outgoing: Vec<Vec<u8>> = vec![Vec::new(); p];
        // This rank's fragments for its own stripes skip the wire — and
        // the copy: they stay borrowed views into `extents` until the
        // replay below.
        let mut mine: Vec<(u64, &[u8])> = Vec::new();
        for (off, buf) in &extents {
            let mut at = 0usize;
            while at < buf.len() {
                let o = off + at as u64;
                let stripe_idx = o / self.stripe;
                let stripe_end = (stripe_idx + 1) * self.stripe;
                let take = ((stripe_end - o) as usize).min(buf.len() - at);
                let dest = (stripe_idx as usize) % p;
                let frag = &buf[at..at + take];
                if dest == me {
                    mine.push((o, frag));
                } else {
                    let out = &mut outgoing[dest];
                    out.extend_from_slice(&o.to_le_bytes());
                    out.extend_from_slice(&(take as u64).to_le_bytes());
                    out.extend_from_slice(frag);
                    self.shipped_bytes += take as u64;
                }
                at += take;
            }
        }
        if self.shipped_history.len() >= SHIPPED_HISTORY_CAP {
            self.shipped_history.pop_front();
        }
        self.shipped_history.push_back(self.shipped_bytes - shipped_before);
        let incoming = comm.alltoall_bytes(outgoing);
        // Replay in source-rank order (fragments from different sources
        // are disjoint; within a source the wire preserves stage order).
        let mut recv = WriteAggregator::new();
        for (src, payload) in incoming.iter().enumerate() {
            if src == me {
                for (o, b) in &mine {
                    recv.stage(*o, b);
                }
                continue;
            }
            let mut at = 0usize;
            while at < payload.len() {
                if at + 16 > payload.len() {
                    return Err(ScdaError::corrupt(
                        crate::error::corrupt::TRUNCATED,
                        "malformed collective extent frame",
                    ));
                }
                let o = u64::from_le_bytes(payload[at..at + 8].try_into().unwrap());
                let len = u64::from_le_bytes(payload[at + 8..at + 16].try_into().unwrap()) as usize;
                at += 16;
                if at + len > payload.len() {
                    return Err(ScdaError::corrupt(
                        crate::error::corrupt::TRUNCATED,
                        "collective extent frame shorter than its length field",
                    ));
                }
                recv.stage(o, &payload[at..at + len]);
                at += len;
            }
        }
        let runs = recv.take_runs();
        if !runs.is_empty() {
            self.flush_batches += 1;
        }
        dispatch_runs(&mut self.flusher, file, runs)
    }
}

impl IoEngine for CollectiveEngine {
    fn name(&self) -> &'static str {
        "collective"
    }

    fn write(&mut self, file: &Arc<ParallelFile>, offset: u64, data: &[u8]) -> Result<()> {
        let cap = self.capacity;
        if cap == 0 || data.len() >= cap {
            self.drain_staged_locally(file)?;
            return file.write_at(offset, data);
        }
        // The exchange needs a collective point, which the middle of a
        // section is not — but staging must not grow with the section
        // size. At the capacity (a hard cap, same policy as the
        // aggregating engine), drain this rank's extents locally
        // (own-window writes, always byte-correct): a giant section
        // degrades to per-rank aggregation instead of unbounded memory,
        // and normal sections still ship whole at the next boundary.
        if self.agg.staged_bytes() + data.len() > cap {
            self.drain_staged_locally(file)?;
        }
        self.agg.stage(offset, data);
        Ok(())
    }

    fn view(&mut self, file: &Arc<ParallelFile>, offset: u64, len: usize) -> Result<&[u8]> {
        route_view(self.sieve.as_mut(), &mut self.scratch, file, offset, len)
    }

    fn read_vec(&mut self, file: &Arc<ParallelFile>, offset: u64, len: usize) -> Result<Vec<u8>> {
        route_read_vec(&mut self.sieve, file, offset, len)
    }

    fn read_into(&mut self, file: &Arc<ParallelFile>, offset: u64, buf: &mut [u8]) -> Result<()> {
        route_read_into(&mut self.sieve, file, offset, buf)
    }

    fn section_end(&mut self, file: &Arc<ParallelFile>, comm: &dyn Communicator) -> Result<bool> {
        // Collective agreement on whether to exchange: all ranks see the
        // same maximum, so either every rank enters the alltoall or none
        // does — the collective call discipline is preserved by
        // construction.
        let staged = self.agg.staged_bytes() as u64;
        let max = comm.allgather_u64(staged).into_iter().max().unwrap_or(0);
        if max >= (self.capacity as u64 / 2).max(1) {
            self.exchange(file, comm)?;
        }
        // The allgather above already synchronized every rank; the
        // caller's section barrier would be a second round for nothing.
        Ok(true)
    }

    fn flush(&mut self, file: &Arc<ParallelFile>, comm: &dyn Communicator) -> Result<()> {
        // Cheap collective agreement first: when no rank staged anything
        // (close after an explicit flush, read-mode retune), one
        // allgather replaces the pointless empty alltoall — and keeps
        // the `exchanges` counter honest.
        let max = comm.allgather_u64(self.agg.staged_bytes() as u64).into_iter().max().unwrap_or(0);
        if max > 0 {
            self.exchange(file, comm)?;
        }
        match &mut self.flusher {
            Some(fl) => fl.wait(),
            None => Ok(()),
        }
    }

    fn drain_local(&mut self, file: &Arc<ParallelFile>) -> Result<()> {
        self.drain_staged_locally(file)?;
        match &mut self.flusher {
            Some(fl) => fl.wait(),
            None => Ok(()),
        }
    }

    fn take_error(&mut self) -> Option<ScdaError> {
        self.flusher.as_ref().and_then(|fl| fl.try_take_error())
    }

    fn stats(&self) -> EngineStats {
        EngineStats {
            engine: "collective",
            shipped_bytes: self.shipped_bytes,
            exchanges: self.exchanges,
            flush_batches: self.flush_batches,
            sieve_refills: self.sieve.as_ref().map(|s| s.refills()).unwrap_or(0),
            shipped_per_exchange: self.shipped_history.iter().copied().collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::{run_parallel, SerialComm};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("scda-collective");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    #[test]
    fn serial_collective_matches_direct_bytes() {
        let path = tmp("serial");
        let f = Arc::new(ParallelFile::create(&SerialComm::new(), &path).unwrap());
        let mut e = CollectiveEngine::new(1 << 20, 4096, None, false);
        let mut expect = vec![0u8; 300];
        for i in 0..10u64 {
            let b = vec![(i + 1) as u8; 30];
            expect[(i as usize) * 30..(i as usize + 1) * 30].copy_from_slice(&b);
            e.write(&f, i * 30, &b).unwrap();
        }
        e.flush(&f, &SerialComm::new()).unwrap();
        assert_eq!(f.read_vec(0, 300).unwrap(), expect);
        // One rank owns every stripe: everything merged to one pwrite.
        assert_eq!(f.io_stats().write_calls, 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn interleaved_ranks_collapse_to_one_run_per_stripe() {
        // 4 ranks write 64-byte extents round-robin across a 64 KiB file
        // (1024 extents, 16 stripes of 4 KiB): per-rank runs would be
        // 1024/4 = 256 each; collectively, each rank owns 4 of the 16
        // stripes (non-adjacent at P = 4) and issues exactly 4 pwrites.
        let path = Arc::new(tmp("interleave"));
        let p = Arc::clone(&path);
        let stats = run_parallel(4, move |comm| {
            let f = Arc::new(ParallelFile::create(&comm, &*p).unwrap());
            let mut e = CollectiveEngine::new(1 << 20, 4096, None, false);
            let me = comm.rank();
            for i in 0..1024u64 {
                if (i as usize) % 4 == me {
                    e.write(&f, i * 64, &[me as u8; 64]).unwrap();
                }
            }
            e.flush(&f, &comm).unwrap();
            comm.barrier();
            let st = e.stats();
            // The per-exchange history tiles the shipped total (this
            // run stays far under SHIPPED_HISTORY_CAP).
            assert_eq!(st.shipped_per_exchange.len() as u64, st.exchanges);
            assert_eq!(st.shipped_per_exchange.iter().sum::<u64>(), st.shipped_bytes);
            (f.io_stats().write_calls, st.shipped_bytes)
        });
        for (r, (writes, shipped)) in stats.iter().enumerate() {
            assert_eq!(*writes, 4, "rank {r}: one pwrite per owned stripe");
            // 3/4 of a rank's 256 x 64 B extents land on other ranks'
            // stripes.
            assert_eq!(*shipped, 256 * 64 * 3 / 4, "rank {r} shipped bytes");
        }
        let data = std::fs::read(&*path).unwrap();
        assert_eq!(data.len(), 64 * 1024);
        for (i, chunk) in data.chunks(64).enumerate() {
            assert!(chunk.iter().all(|&b| b as usize == i % 4), "extent {i}");
        }
        std::fs::remove_file(&*path).unwrap();
    }

    #[test]
    fn large_writes_bypass_the_exchange() {
        let path = tmp("bypass");
        let f = Arc::new(ParallelFile::create(&SerialComm::new(), &path).unwrap());
        let mut e = CollectiveEngine::new(1024, 4096, None, false);
        e.write(&f, 0, &[7u8; 16]).unwrap(); // staged
        e.write(&f, 16, &[8u8; 2048]).unwrap(); // bypass: drains + direct
        assert_eq!(f.io_stats().write_calls, 2);
        e.flush(&f, &SerialComm::new()).unwrap();
        let got = f.read_vec(0, 2064).unwrap();
        assert!(got[..16].iter().all(|&b| b == 7));
        assert!(got[16..].iter().all(|&b| b == 8));
        std::fs::remove_file(&path).unwrap();
    }
}
