//! Two-phase collective buffering (MPI-IO style) behind the
//! [`IoEngine`] trait: ranks stage their small writes locally and, at
//! collective points, ship them over [`Communicator::alltoall_bytes`] to
//! the *aggregator rank* owning each file stripe. Phase one is the
//! exchange; phase two is each aggregator replaying the fragments it
//! received and issuing one `pwrite` per contiguous run within each of
//! its stripes (runs never span a stripe boundary at P > 1, so every
//! touched stripe is exactly one syscall no matter who owns it).
//!
//! # Why this helps
//!
//! Per-rank aggregation (PR 2) merges a rank's *own* extents, but a
//! rank's extents in an interleaved section stream are separated by the
//! other ranks' windows — so its run count grows with P × section
//! interleaving. After the exchange, each stripe's bytes live on exactly
//! one rank, so the run count per stripe is 1 no matter how sections
//! interleave ranks: write syscalls become a function of *file size*,
//! not of *access pattern* (`rust/tests/io_engines.rs` asserts this).
//!
//! # Stripe ownership: staging affinity
//!
//! Stripe `s` (bytes `[s·S, (s+1)·S)`) needs exactly one owner per
//! exchange. A uniform `s mod P` map is correct but oblivious: when one
//! rank staged nearly all of a stripe, a uniform map usually ships those
//! bytes to a different rank anyway. Each exchange therefore *elects*
//! owners from the staging pattern itself: every rank announces its
//! per-stripe staged byte counts with one allgather, and all ranks
//! deterministically pick, per stripe, the rank that staged the most
//! bytes of it (on a tie, `s mod P` if it is among the tied maxima, else
//! the lowest tied rank — so balanced interleavings keep the uniform
//! map's spread instead of piling onto rank 0). The map is a pure
//! function of collective inputs, so all ranks agree on it; stripes no
//! rank staged simply have no fragments. The read gather below keeps the
//! plain `s mod P` map: readers cannot know who staged what at write
//! time, and the file bytes don't depend on it.
//!
//! # Correctness
//!
//! Serial equivalence survives the
//! re-homing because (a) the section paths write every file byte exactly
//! once, and a rank's staged extents lie in its own disjoint windows, so
//! fragments from different sources never overlap; (b) fragments from
//! one source replay in that source's stage order; and (c) which rank
//! issues a `pwrite` is invisible in the bytes — the same §2 argument
//! that makes the format partition-independent. The engine is
//! property-tested byte-identical to [`DirectEngine`] at 1/2/4/8 ranks.
//!
//! Large writes (≥ the staging capacity) bypass the exchange: they are
//! already one syscall, and shipping them would only move bytes. The
//! bypass drains this rank's staged extents locally first, preserving
//! stage order without a collective.
//!
//! # The read gather (the write path's dual)
//!
//! Reads re-home the same way, in the opposite direction: at each
//! collective data read ([`IoEngine::read_window`]) every rank announces
//! its `(offset, length)` window with one allgather, the rank owning
//! stripe `s = s mod P` issues **one `pread` per contiguous run of
//! requested stripes** it owns, and the fragments scatter back to the
//! requesting ranks over [`Communicator::alltoall_bytes`]. Read syscalls
//! therefore track the *bytes touched* — the union of the requested
//! windows — never the rank count or the section interleaving
//! (`rust/tests/io_read_gather.rs` asserts the invariance, mirroring the
//! write side). Identical requests from many ranks (catalog range reads,
//! size-row windows) dedupe to a single owner-side read. A lone request
//! of at least the staging capacity bypasses the exchange — the
//! requester is already one syscall — and when an owner's `pread` fails,
//! the failure ships in-band (a status byte ahead of the fragments), so
//! the error surfaces on every rank instead of splitting the collective.

use std::sync::Arc;

use crate::error::{corrupt, Result, ScdaError};
use crate::io::aggregate::{Payload, WriteAggregator};
use crate::io::engine::{dispatch_runs, EngineStats, IoEngine, StagedCore};
use crate::io::fault::retry_transient;
use crate::io::sieve::ReadSieve;
use crate::obs::trace::{SpanGuard, SpanKind, Tracer};
use crate::par::comm::Communicator;
use crate::par::pfile::ParallelFile;

#[cfg(doc)]
use crate::io::engine::DirectEngine;

/// The collective two-phase engine; see the module docs.
pub struct CollectiveEngine {
    /// The shared staging/routing core ([`StagedCore`]): this rank's
    /// staged extents, the capacity (exchange threshold: a section
    /// boundary triggers the exchange once any rank has staged at least
    /// half of it; also the large-access bypass bound), the read sieve
    /// and the optional background flusher.
    core: StagedCore,
    /// Stripe size in bytes. Write-side ownership is elected per
    /// exchange from staged-byte counts (module docs); the read gather
    /// uses the uniform `s % P` map.
    stripe: u64,
    shipped_bytes: u64,
    exchanges: u64,
    /// Bytes shipped in each exchange, in exchange order (ROADMAP's
    /// stripe-ownership follow-up wants this shape, not just the
    /// total). Bounded at [`SHIPPED_HISTORY_CAP`] most-recent entries so
    /// a long-lived file cannot grow it without limit.
    shipped_history: std::collections::VecDeque<u64>,
    /// Read-gather counters (see [`EngineStats`]).
    read_exchanges: u64,
    gathered_bytes: u64,
    gather_preads: u64,
}

/// Most-recent exchanges kept in [`EngineStats::shipped_per_exchange`];
/// older entries are dropped (the running totals in `shipped_bytes` /
/// `exchanges` are never truncated).
pub const SHIPPED_HISTORY_CAP: usize = 1024;

impl CollectiveEngine {
    pub fn new(capacity: usize, stripe_size: usize, sieve: Option<ReadSieve>, async_flush: bool) -> Self {
        CollectiveEngine {
            core: StagedCore::new(capacity, sieve, async_flush),
            stripe: (stripe_size.max(1)) as u64,
            shipped_bytes: 0,
            exchanges: 0,
            shipped_history: std::collections::VecDeque::new(),
            read_exchanges: 0,
            gathered_bytes: 0,
            gather_preads: 0,
        }
    }

    /// Builder: run async flush on `pool` instead of the shared codec
    /// pool (the per-file flush pool; `None` keeps the shared pool).
    pub fn with_flush_pool(mut self, pool: Option<Arc<crate::par::pool::CodecPool>>) -> Self {
        self.core.set_flush_pool(pool);
        self
    }

    /// Builder: record stage/exchange/gather spans on `tracer` (`None`
    /// disables). Tracing never changes which syscalls or collectives
    /// run — the pinned pwrite/pread/shipped counts are untouched.
    pub fn with_tracer(mut self, tracer: Option<Arc<Tracer>>) -> Self {
        self.core.set_tracer(tracer);
        self
    }

    /// Open a span of `kind` on the installed tracer (one branch when
    /// tracing is off).
    fn span(&self, kind: SpanKind) -> Option<SpanGuard> {
        self.core.tracer.as_ref().map(|t| Tracer::start(t, kind))
    }

    /// All ranks' per-stripe staged byte counts → the elected owner map
    /// for this exchange (module docs, "staging affinity"). One
    /// allgather; every rank computes the same map because it is a pure
    /// function of the gathered counts.
    fn elect_owners(
        &self,
        counts: &std::collections::BTreeMap<u64, u64>,
        comm: &dyn Communicator,
    ) -> std::collections::BTreeMap<u64, usize> {
        let p = comm.size();
        let mut wire = Vec::with_capacity(counts.len() * 16);
        for (&s, &b) in counts {
            wire.extend_from_slice(&s.to_le_bytes());
            wire.extend_from_slice(&b.to_le_bytes());
        }
        // (best bytes, best rank) per stripe; ranks iterate in ascending
        // order and only strictly-greater counts replace, so ties keep
        // the lowest rank here — the `s mod P` preference applies below.
        let mut best: std::collections::BTreeMap<u64, (u64, usize)> =
            std::collections::BTreeMap::new();
        let mut default_count: std::collections::BTreeMap<u64, u64> =
            std::collections::BTreeMap::new();
        for (rank, payload) in comm.allgather_bytes(wire).into_iter().enumerate() {
            for pair in payload.chunks_exact(16) {
                let s = u64::from_le_bytes(pair[..8].try_into().unwrap());
                let b = u64::from_le_bytes(pair[8..].try_into().unwrap());
                let e = best.entry(s).or_insert((0, rank));
                if b > e.0 {
                    *e = (b, rank);
                }
                if rank == (s as usize) % p {
                    default_count.insert(s, b);
                }
            }
        }
        best.into_iter()
            .map(|(s, (b, r))| {
                let default = (s as usize) % p;
                let owner = if default_count.get(&s) == Some(&b) { default } else { r };
                (s, owner)
            })
            .collect()
    }

    /// Phase one + two: split staged extents at stripe boundaries, ship
    /// each fragment to its stripe's elected owner, replay what this
    /// rank received (own fragments included, in source-rank order) and
    /// write one syscall per contiguous run. Collective.
    fn exchange(&mut self, file: &Arc<ParallelFile>, comm: &dyn Communicator) -> Result<()> {
        let mut span = self.span(SpanKind::Exchange);
        let p = comm.size();
        let me = comm.rank();
        self.exchanges += 1;
        let shipped_before = self.shipped_bytes;
        let extents = self.core.agg.take_extents();
        // Per-stripe staged byte counts feed the ownership election.
        let mut counts: std::collections::BTreeMap<u64, u64> = std::collections::BTreeMap::new();
        for (off, buf) in &extents {
            let mut at = 0usize;
            while at < buf.len() {
                let o = off + at as u64;
                let stripe_idx = o / self.stripe;
                let take = (((stripe_idx + 1) * self.stripe - o) as usize).min(buf.len() - at);
                *counts.entry(stripe_idx).or_insert(0) += take as u64;
                at += take;
            }
        }
        let owners = self.elect_owners(&counts, comm);
        let mut outgoing: Vec<Vec<u8>> = vec![Vec::new(); p];
        // This rank's fragments for its own stripes skip the wire — and
        // the copy: they stay borrowed views into `extents` until the
        // replay below.
        let mut mine: Vec<(u64, &[u8])> = Vec::new();
        for (off, payload) in &extents {
            let buf = payload.as_slice();
            let mut at = 0usize;
            while at < buf.len() {
                let o = off + at as u64;
                let stripe_idx = o / self.stripe;
                let stripe_end = (stripe_idx + 1) * self.stripe;
                let take = ((stripe_end - o) as usize).min(buf.len() - at);
                // Every staged stripe was counted above, so the elected
                // map always has an entry here.
                let dest = owners[&stripe_idx];
                let frag = &buf[at..at + take];
                if dest == me {
                    mine.push((o, frag));
                } else {
                    let out = &mut outgoing[dest];
                    out.extend_from_slice(&o.to_le_bytes());
                    out.extend_from_slice(&(take as u64).to_le_bytes());
                    out.extend_from_slice(frag);
                    self.shipped_bytes += take as u64;
                }
                at += take;
            }
        }
        if self.shipped_history.len() >= SHIPPED_HISTORY_CAP {
            self.shipped_history.pop_front();
        }
        self.shipped_history.push_back(self.shipped_bytes - shipped_before);
        if let Some(s) = span.as_mut() {
            s.set_bytes(self.shipped_bytes - shipped_before);
        }
        let incoming = comm.alltoall_bytes(outgoing);
        // Replay in source-rank order (fragments from different sources
        // are disjoint; within a source the wire preserves stage order).
        let mut recv = WriteAggregator::new();
        for (src, payload) in incoming.iter().enumerate() {
            if src == me {
                for (o, b) in &mine {
                    recv.stage(*o, b);
                }
                continue;
            }
            let mut at = 0usize;
            while at < payload.len() {
                if at + 16 > payload.len() {
                    return Err(ScdaError::corrupt(
                        crate::error::corrupt::TRUNCATED,
                        "malformed collective extent frame",
                    ));
                }
                let o = u64::from_le_bytes(payload[at..at + 8].try_into().unwrap());
                let len = u64::from_le_bytes(payload[at + 8..at + 16].try_into().unwrap()) as usize;
                at += 16;
                if at + len > payload.len() {
                    return Err(ScdaError::corrupt(
                        crate::error::corrupt::TRUNCATED,
                        "collective extent frame shorter than its length field",
                    ));
                }
                recv.stage(o, &payload[at..at + len]);
                at += len;
            }
        }
        let runs = recv.take_runs();
        let runs = if p > 1 { self.split_runs_at_stripes(runs) } else { runs };
        if !runs.is_empty() {
            self.core.flush_batches += 1;
        }
        let tracer = self.core.tracer.clone();
        dispatch_runs(&mut self.core.flusher, file, runs, tracer.as_ref())
    }

    /// Splits replayed runs at stripe boundaries so each touched stripe
    /// stays exactly one `pwrite` — the invariant `io_engines.rs` pins.
    /// Under the uniform map adjacent stripes never shared an owner and
    /// runs could not cross a boundary; the affinity election can hand
    /// one rank adjacent stripes, so the split (and its copy) only ever
    /// triggers on those elected adjacencies.
    fn split_runs_at_stripes(&self, runs: Vec<(u64, Payload)>) -> Vec<(u64, Payload)> {
        let mut out = Vec::with_capacity(runs.len());
        for (off, payload) in runs {
            if payload.is_empty() {
                continue;
            }
            let end = off + payload.len() as u64 - 1;
            if off / self.stripe == end / self.stripe {
                out.push((off, payload));
                continue;
            }
            let buf = payload.as_slice();
            let mut at = 0usize;
            while at < buf.len() {
                let o = off + at as u64;
                let take = (((o / self.stripe + 1) * self.stripe - o) as usize).min(buf.len() - at);
                out.push((o, Payload::Owned(buf[at..at + take].to_vec())));
                at += take;
            }
        }
        out
    }

    /// The collective read gather; see the module docs. Every rank's
    /// request is known to all after one allgather, so every branch
    /// below is a pure function of collective inputs — the alltoall runs
    /// on every rank or on none, and the returned synced flag is
    /// identical everywhere.
    fn read_gather(
        &mut self,
        file: &Arc<ParallelFile>,
        offset: u64,
        buf: &mut [u8],
        comm: &dyn Communicator,
    ) -> Result<bool> {
        let mut gspan = self.span(SpanKind::ReadGather);
        if let Some(s) = gspan.as_mut() {
            s.set_bytes(buf.len() as u64);
        }
        let p = comm.size();
        let me = comm.rank();
        if p == 1 {
            // One rank owns every stripe: the gather degenerates to the
            // local read (all requested stripes merge into one run).
            if !buf.is_empty() {
                self.gather_preads += 1;
                let mut pspan = self.span(SpanKind::GatherPread);
                if let Some(s) = pspan.as_mut() {
                    s.set_bytes(buf.len() as u64);
                }
                retry_transient(|| file.read_at(offset, buf))?;
            }
            return Ok(false);
        }
        // Phase 0: announce every rank's request window.
        let reqs = comm.allgather_u64_pair(offset, buf.len() as u64);
        self.read_exchanges += 1;
        let live: Vec<usize> = reqs.iter().enumerate().filter(|(_, r)| r.1 > 0).map(|(i, _)| i).collect();
        if live.is_empty() {
            // Nothing to read anywhere; the allgather already synced.
            return Ok(true);
        }
        // Direct bypass: a lone large request gains nothing from
        // re-homing — the requester is already one syscall. The outcome
        // still crosses ranks (one flag allgather): a failed pread must
        // error on *every* rank, exactly like the in-band status byte of
        // the exchange path, or the collective would split.
        if live.len() == 1 && reqs[live[0]].1 >= self.core.capacity as u64 {
            let mut my_err: Option<ScdaError> = None;
            if live[0] == me {
                match retry_transient(|| file.read_at(offset, buf)) {
                    Ok(()) => self.gather_preads += 1,
                    Err(e) => my_err = Some(e),
                }
            }
            let any_failed =
                comm.allgather_u64(u64::from(my_err.is_some())).into_iter().any(|v| v != 0);
            if let Some(e) = my_err {
                return Err(e);
            }
            if any_failed {
                return Err(ScdaError::io(
                    std::io::Error::other("peer pread failed"),
                    "collective read gather failed on the bypassing requester rank",
                ));
            }
            return Ok(true);
        }
        // Phase 1: this rank serves every request fragment falling in
        // its owned stripes. Fragment spans merge into maximal
        // contiguous runs — one `pread` each. Requests are usually
        // disjoint rank windows, but overlapping ones (every rank asking
        // for the same size-row window or catalog range) merge here too,
        // which is exactly the P-fold read dedup.
        let mut frags: Vec<(u64, u64, usize)> = Vec::new(); // (abs offset, len, requester)
        for (r, &(ro, rl)) in reqs.iter().enumerate() {
            let end = ro + rl;
            let mut at = ro;
            while at < end {
                let stripe_idx = at / self.stripe;
                let stripe_end = (stripe_idx + 1) * self.stripe;
                let take = stripe_end.min(end) - at;
                if (stripe_idx as usize) % p == me {
                    frags.push((at, take, r));
                }
                at += take;
            }
        }
        let mut spans: Vec<(u64, u64)> = frags.iter().map(|&(o, l, _)| (o, l)).collect();
        spans.sort_unstable();
        let mut merged: Vec<(u64, u64)> = Vec::new(); // (start, end)
        for (o, l) in spans {
            match merged.last_mut() {
                Some((_, e)) if o <= *e => *e = (*e).max(o + l),
                _ => merged.push((o, o + l)),
            }
        }
        let mut runs: Vec<(u64, Vec<u8>)> = Vec::with_capacity(merged.len());
        let mut read_err: Option<ScdaError> = None;
        for (s, e) in &merged {
            let mut b = vec![0u8; (e - s) as usize];
            if read_err.is_none() {
                let mut pspan = self.span(SpanKind::GatherPread);
                if let Some(sp) = pspan.as_mut() {
                    sp.set_bytes(b.len() as u64);
                }
                match retry_transient(|| file.read_at(*s, &mut b)) {
                    Ok(()) => self.gather_preads += 1,
                    Err(err) => read_err = Some(err),
                }
            }
            runs.push((*s, b));
        }
        // Phase 2: scatter the fragments. The leading status byte keeps
        // a failed pread collective: every rank still enters the
        // alltoall and the error surfaces everywhere afterwards.
        let status = u8::from(read_err.is_some());
        let mut outgoing: Vec<Vec<u8>> = (0..p).map(|_| vec![status]).collect();
        if read_err.is_none() {
            for &(o, l, dest) in &frags {
                let run = runs.partition_point(|(s, _)| *s <= o) - 1;
                let (run_start, run_buf) = &runs[run];
                let rel = (o - run_start) as usize;
                let bytes = &run_buf[rel..rel + l as usize];
                if dest == me {
                    // Own fragments skip the wire.
                    let at = (o - offset) as usize;
                    buf[at..at + l as usize].copy_from_slice(bytes);
                } else {
                    let out = &mut outgoing[dest];
                    out.extend_from_slice(&o.to_le_bytes());
                    out.extend_from_slice(&l.to_le_bytes());
                    out.extend_from_slice(bytes);
                    self.gathered_bytes += l;
                }
            }
        }
        let incoming = {
            let mut sspan = self.span(SpanKind::Scatter);
            if let Some(s) = sspan.as_mut() {
                s.set_bytes(outgoing.iter().map(|o| o.len() as u64).sum());
            }
            comm.alltoall_bytes(outgoing)
        };
        if let Some(err) = read_err {
            return Err(err);
        }
        for (src, payload) in incoming.iter().enumerate() {
            if src == me {
                continue;
            }
            let Some((&status, rest)) = payload.split_first() else {
                return Err(ScdaError::corrupt(corrupt::TRUNCATED, "read-gather frame missing status byte"));
            };
            if status != 0 {
                return Err(ScdaError::io(
                    std::io::Error::other("peer pread failed"),
                    "collective read gather failed on a stripe-owner rank",
                ));
            }
            let mut at = 0usize;
            while at < rest.len() {
                if at + 16 > rest.len() {
                    return Err(ScdaError::corrupt(corrupt::TRUNCATED, "malformed read-gather fragment frame"));
                }
                let o = u64::from_le_bytes(rest[at..at + 8].try_into().unwrap());
                let l = u64::from_le_bytes(rest[at + 8..at + 16].try_into().unwrap()) as usize;
                at += 16;
                if at + l > rest.len() {
                    return Err(ScdaError::corrupt(
                        corrupt::TRUNCATED,
                        "read-gather fragment shorter than its length field",
                    ));
                }
                let rel = o.checked_sub(offset).map(|r| r as usize);
                match rel {
                    Some(rel) if rel + l <= buf.len() => {
                        buf[rel..rel + l].copy_from_slice(&rest[at..at + l]);
                    }
                    _ => {
                        return Err(ScdaError::corrupt(
                            corrupt::TRUNCATED,
                            "read-gather fragment outside the requested window",
                        ))
                    }
                }
                at += l;
            }
        }
        Ok(true)
    }
}

impl IoEngine for CollectiveEngine {
    fn name(&self) -> &'static str {
        "collective"
    }

    fn write(&mut self, file: &Arc<ParallelFile>, offset: u64, data: &[u8]) -> Result<()> {
        // The exchange needs a collective point, which the middle of a
        // section is not — so mid-section policy is [`StagedCore`]'s:
        // large writes bypass (staged extents drain locally first,
        // preserving stage order without a collective), a write past the
        // capacity spills locally (a giant section degrades to per-rank
        // aggregation instead of unbounded memory), everything else
        // stages until the next boundary ships it whole.
        let mut span = self.span(SpanKind::Stage);
        if let Some(s) = span.as_mut() {
            s.set_bytes(data.len() as u64);
        }
        self.core.stage_write(file, offset, data)
    }

    fn write_owned(&mut self, file: &Arc<ParallelFile>, offset: u64, data: Vec<u8>) -> Result<()> {
        // Same policy as `write`, minus the staging memcpy: the owned
        // buffer parks in the aggregator until the exchange slices it
        // (own-stripe fragments are then borrowed straight from it).
        let mut span = self.span(SpanKind::Stage);
        if let Some(s) = span.as_mut() {
            s.set_bytes(data.len() as u64);
        }
        self.core.stage_write_owned(file, offset, data)
    }

    fn view(&mut self, file: &Arc<ParallelFile>, offset: u64, len: usize) -> Result<&[u8]> {
        self.core.view(file, offset, len)
    }

    fn read_vec(&mut self, file: &Arc<ParallelFile>, offset: u64, len: usize) -> Result<Vec<u8>> {
        self.core.read_vec(file, offset, len)
    }

    fn read_into(&mut self, file: &Arc<ParallelFile>, offset: u64, buf: &mut [u8]) -> Result<()> {
        self.core.read_into(file, offset, buf)
    }

    fn read_window(
        &mut self,
        file: &Arc<ParallelFile>,
        offset: u64,
        buf: &mut [u8],
        comm: &dyn Communicator,
    ) -> Result<bool> {
        self.read_gather(file, offset, buf, comm)
    }

    fn section_end(&mut self, file: &Arc<ParallelFile>, comm: &dyn Communicator) -> Result<bool> {
        // Collective agreement on whether to exchange: all ranks see the
        // same maximum, so either every rank enters the alltoall or none
        // does — the collective call discipline is preserved by
        // construction.
        let staged = self.core.agg.staged_bytes() as u64;
        let max = comm.allgather_u64(staged).into_iter().max().unwrap_or(0);
        if max >= (self.core.capacity as u64 / 2).max(1) {
            self.exchange(file, comm)?;
        }
        // The allgather above already synchronized every rank; the
        // caller's section barrier would be a second round for nothing.
        Ok(true)
    }

    fn flush(&mut self, file: &Arc<ParallelFile>, comm: &dyn Communicator) -> Result<()> {
        // Cheap collective agreement first: when no rank staged anything
        // (close after an explicit flush, read-mode retune), one
        // allgather replaces the pointless empty alltoall — and keeps
        // the `exchanges` counter honest.
        let max =
            comm.allgather_u64(self.core.agg.staged_bytes() as u64).into_iter().max().unwrap_or(0);
        if max > 0 {
            self.exchange(file, comm)?;
        }
        match &mut self.core.flusher {
            Some(fl) => fl.wait(),
            None => Ok(()),
        }
    }

    fn drain_local(&mut self, file: &Arc<ParallelFile>) -> Result<()> {
        self.core.drain_local(file)
    }

    fn take_error(&mut self) -> Option<ScdaError> {
        self.core.take_error()
    }

    fn stats(&self) -> EngineStats {
        let mut st = EngineStats {
            engine: "collective",
            shipped_bytes: self.shipped_bytes,
            exchanges: self.exchanges,
            flush_batches: self.core.flush_batches,
            shipped_per_exchange: self.shipped_history.iter().copied().collect(),
            read_exchanges: self.read_exchanges,
            gathered_bytes: self.gathered_bytes,
            gather_preads: self.gather_preads,
            ..EngineStats::default()
        };
        self.core.fill_read_stats(&mut st);
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::{run_parallel, SerialComm};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("scda-collective");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    #[test]
    fn serial_collective_matches_direct_bytes() {
        let path = tmp("serial");
        let f = Arc::new(ParallelFile::create(&SerialComm::new(), &path).unwrap());
        let mut e = CollectiveEngine::new(1 << 20, 4096, None, false);
        let mut expect = vec![0u8; 300];
        for i in 0..10u64 {
            let b = vec![(i + 1) as u8; 30];
            expect[(i as usize) * 30..(i as usize + 1) * 30].copy_from_slice(&b);
            e.write(&f, i * 30, &b).unwrap();
        }
        e.flush(&f, &SerialComm::new()).unwrap();
        assert_eq!(f.read_vec(0, 300).unwrap(), expect);
        // One rank owns every stripe: everything merged to one pwrite.
        assert_eq!(f.io_stats().write_calls, 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn interleaved_ranks_collapse_to_one_run_per_stripe() {
        // 4 ranks write 64-byte extents round-robin across a 64 KiB file
        // (1024 extents, 16 stripes of 4 KiB): per-rank runs would be
        // 1024/4 = 256 each; collectively, each rank owns 4 of the 16
        // stripes (non-adjacent at P = 4) and issues exactly 4 pwrites.
        let path = Arc::new(tmp("interleave"));
        let p = Arc::clone(&path);
        let stats = run_parallel(4, move |comm| {
            let f = Arc::new(ParallelFile::create(&comm, &*p).unwrap());
            let mut e = CollectiveEngine::new(1 << 20, 4096, None, false);
            let me = comm.rank();
            for i in 0..1024u64 {
                if (i as usize) % 4 == me {
                    e.write(&f, i * 64, &[me as u8; 64]).unwrap();
                }
            }
            e.flush(&f, &comm).unwrap();
            comm.barrier();
            let st = e.stats();
            // The per-exchange history tiles the shipped total (this
            // run stays far under SHIPPED_HISTORY_CAP).
            assert_eq!(st.shipped_per_exchange.len() as u64, st.exchanges);
            assert_eq!(st.shipped_per_exchange.iter().sum::<u64>(), st.shipped_bytes);
            (f.io_stats().write_calls, st.shipped_bytes)
        });
        for (r, (writes, shipped)) in stats.iter().enumerate() {
            assert_eq!(*writes, 4, "rank {r}: one pwrite per owned stripe");
            // 3/4 of a rank's 256 x 64 B extents land on other ranks'
            // stripes.
            assert_eq!(*shipped, 256 * 64 * 3 / 4, "rank {r} shipped bytes");
        }
        let data = std::fs::read(&*path).unwrap();
        assert_eq!(data.len(), 64 * 1024);
        for (i, chunk) in data.chunks(64).enumerate() {
            assert!(chunk.iter().all(|&b| b as usize == i % 4), "extent {i}");
        }
        std::fs::remove_file(&*path).unwrap();
    }

    #[test]
    fn affinity_election_keeps_majority_stripes_local() {
        // Rank r writes almost all of stripe (r+1)%4 (bytes [64, 4096))
        // and a 64-byte sliver at the start of stripe r. Under the old
        // uniform map every rank would ship its 4032-byte majority
        // fragment to rank (r+1)%4; under staging-affinity election the
        // majority writer owns the stripe, so only the slivers travel.
        let path = Arc::new(tmp("affinity"));
        let p = Arc::clone(&path);
        let stats = run_parallel(4, move |comm| {
            let f = Arc::new(ParallelFile::create(&comm, &*p).unwrap());
            let mut e = CollectiveEngine::new(1 << 20, 4096, None, false);
            let me = comm.rank() as u64;
            let big = (me + 1) % 4;
            e.write(&f, big * 4096 + 64, &[me as u8; 4032]).unwrap();
            e.write(&f, me * 4096, &[me as u8; 64]).unwrap();
            e.flush(&f, &comm).unwrap();
            comm.barrier();
            (f.io_stats().write_calls, e.stats().shipped_bytes)
        });
        for (r, (writes, shipped)) in stats.iter().enumerate() {
            assert_eq!(*shipped, 64, "rank {r}: only the sliver ships");
            // The sliver received from rank (r+1)%4 lands flush against
            // this rank's own majority fragment: one run, one pwrite.
            assert_eq!(*writes, 1, "rank {r}: one merged pwrite");
        }
        let data = std::fs::read(&*path).unwrap();
        assert_eq!(data.len(), 4 * 4096);
        for s in 0..4usize {
            let stripe = &data[s * 4096..(s + 1) * 4096];
            assert!(stripe[..64].iter().all(|&b| b as usize == s), "stripe {s} sliver");
            let writer = (s + 3) % 4;
            assert!(stripe[64..].iter().all(|&b| b as usize == writer), "stripe {s} body");
        }
        std::fs::remove_file(&*path).unwrap();
    }

    #[test]
    fn elected_adjacent_stripes_still_write_one_pwrite_each() {
        // Rank 0 stages both 4 KiB stripes of an 8 KiB span; the
        // election hands it both (rank 1 staged nothing), and the replay
        // must still split at the stripe boundary — one pwrite per
        // touched stripe, the invariant `io_engines.rs` builds on.
        let path = Arc::new(tmp("adjacent"));
        let p = Arc::clone(&path);
        let stats = run_parallel(2, move |comm| {
            let f = Arc::new(ParallelFile::create(&comm, &*p).unwrap());
            let mut e = CollectiveEngine::new(1 << 20, 4096, None, false);
            if comm.rank() == 0 {
                e.write(&f, 0, &[0xABu8; 8192]).unwrap();
            }
            e.flush(&f, &comm).unwrap();
            comm.barrier();
            (f.io_stats().write_calls, e.stats().shipped_bytes)
        });
        assert_eq!(stats[0], (2, 0), "two stripes, two pwrites, nothing shipped");
        assert_eq!(stats[1], (0, 0), "rank 1 neither wrote nor shipped");
        let data = std::fs::read(&*path).unwrap();
        assert_eq!(data.len(), 8192);
        assert!(data.iter().all(|&b| b == 0xAB));
        std::fs::remove_file(&*path).unwrap();
    }

    #[test]
    fn owned_writes_stage_without_copy_and_match() {
        let path = tmp("owned");
        let f = Arc::new(ParallelFile::create(&SerialComm::new(), &path).unwrap());
        let mut e = CollectiveEngine::new(1 << 20, 4096, None, false);
        let a: Vec<u8> = (0..9000u32).map(|i| (i % 251) as u8).collect();
        let expect = a.clone();
        e.write_owned(&f, 0, a).unwrap();
        e.write(&f, 9000, &[0xEEu8; 40]).unwrap();
        assert_eq!(f.io_stats().write_calls, 0, "both staged");
        e.flush(&f, &SerialComm::new()).unwrap();
        let got = f.read_vec(0, 9040).unwrap();
        assert_eq!(&got[..9000], &expect[..]);
        assert!(got[9000..].iter().all(|&b| b == 0xEE));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn read_gather_serial_degenerates_to_local_read() {
        let path = tmp("gather-serial");
        let f = Arc::new(ParallelFile::create(&SerialComm::new(), &path).unwrap());
        let data: Vec<u8> = (0..256u32).map(|i| (i % 251) as u8).collect();
        f.write_at(0, &data).unwrap();
        let mut e = CollectiveEngine::new(1 << 20, 64, None, false);
        let mut buf = vec![0u8; 100];
        let synced = e.read_window(&f, 50, &mut buf, &SerialComm::new()).unwrap();
        assert!(!synced, "no collective ran on one rank");
        assert_eq!(buf, &data[50..150]);
        let st = e.stats();
        assert_eq!(st.gather_preads, 1);
        assert_eq!((st.read_exchanges, st.gathered_bytes), (0, 0));
        // An empty request issues nothing.
        e.read_window(&f, 0, &mut [], &SerialComm::new()).unwrap();
        assert_eq!(e.stats().gather_preads, 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn read_gather_scatters_windows_and_counts_stripes() {
        // 4 ranks, 4 KiB file of 256-byte stripes: each rank requests a
        // disjoint 1 KiB window. The union touches all 16 stripes, and
        // at P = 4 adjacent stripes never share an owner, so the summed
        // owner-side preads equal the touched-stripe count — while every
        // rank still receives exactly its own window's bytes.
        let path = Arc::new(tmp("gather-par"));
        let data: Vec<u8> = (0..4096u32).map(|i| (i * 7 % 251) as u8).collect();
        {
            let f = ParallelFile::create(&SerialComm::new(), &*path).unwrap();
            f.write_at(0, &data).unwrap();
        }
        let p = Arc::clone(&path);
        let d = data.clone();
        let stats = run_parallel(4, move |comm| {
            let f = Arc::new(ParallelFile::open_read(&comm, &*p).unwrap());
            let mut e = CollectiveEngine::new(1 << 20, 256, None, false);
            let me = comm.rank();
            let mut buf = vec![0u8; 1024];
            let synced = e.read_window(&f, me as u64 * 1024, &mut buf, &comm).unwrap();
            assert!(synced, "the gather's collectives synchronized the ranks");
            assert_eq!(buf, &d[me * 1024..(me + 1) * 1024], "rank {me} window");
            comm.barrier();
            e.stats()
        });
        let preads: u64 = stats.iter().map(|s| s.gather_preads).sum();
        assert_eq!(preads, 16, "one pread per touched 256-byte stripe");
        // 3 of each rank's 4 owned stripes serve other ranks' windows.
        let gathered: u64 = stats.iter().map(|s| s.gathered_bytes).sum();
        assert_eq!(gathered, 4096 * 3 / 4);
        assert!(stats.iter().all(|s| s.read_exchanges == 1));
        std::fs::remove_file(&*path).unwrap();
    }

    #[test]
    fn read_gather_dedupes_identical_requests() {
        // Every rank requests the same 2 KiB window: owners read each
        // touched stripe once and fan the copies out, so the summed
        // preads stay the touched-stripe count — not P times it.
        let path = Arc::new(tmp("gather-dedup"));
        let data: Vec<u8> = (0..4096u32).map(|i| (i * 13 % 251) as u8).collect();
        {
            let f = ParallelFile::create(&SerialComm::new(), &*path).unwrap();
            f.write_at(0, &data).unwrap();
        }
        let p = Arc::clone(&path);
        let d = data.clone();
        let stats = run_parallel(4, move |comm| {
            let f = Arc::new(ParallelFile::open_read(&comm, &*p).unwrap());
            let mut e = CollectiveEngine::new(1 << 20, 512, None, false);
            let mut buf = vec![0u8; 2048];
            e.read_window(&f, 1024, &mut buf, &comm).unwrap();
            assert_eq!(buf, &d[1024..3072]);
            comm.barrier();
            e.stats()
        });
        let preads: u64 = stats.iter().map(|s| s.gather_preads).sum();
        assert_eq!(preads, 4, "stripes touched by [1024, 3072) at 512-byte stripes");
        std::fs::remove_file(&*path).unwrap();
    }

    #[test]
    fn read_gather_lone_large_request_bypasses() {
        let path = Arc::new(tmp("gather-bypass"));
        let data = vec![0x5Au8; 8192];
        {
            let f = ParallelFile::create(&SerialComm::new(), &*path).unwrap();
            f.write_at(0, &data).unwrap();
        }
        let p = Arc::clone(&path);
        let stats = run_parallel(2, move |comm| {
            let f = Arc::new(ParallelFile::open_read(&comm, &*p).unwrap());
            // Capacity 1 KiB: rank 0's lone 8 KiB request is "large".
            let mut e = CollectiveEngine::new(1024, 256, None, false);
            let mut buf = vec![0u8; if comm.rank() == 0 { 8192 } else { 0 }];
            let synced = e.read_window(&f, 0, &mut buf, &comm).unwrap();
            assert!(synced);
            if comm.rank() == 0 {
                assert!(buf.iter().all(|&b| b == 0x5A));
            }
            comm.barrier();
            (e.stats(), f.io_stats().read_calls)
        });
        assert_eq!(stats[0].0.gather_preads, 1, "one direct pread on the requester");
        assert_eq!(stats[1].0.gather_preads, 0);
        assert_eq!(stats[1].1, 0, "the non-requesting rank touched the file not at all");
        assert!(stats.iter().all(|(s, _)| s.gathered_bytes == 0), "nothing shipped");
        std::fs::remove_file(&*path).unwrap();
    }

    #[test]
    fn large_writes_bypass_the_exchange() {
        let path = tmp("bypass");
        let f = Arc::new(ParallelFile::create(&SerialComm::new(), &path).unwrap());
        let mut e = CollectiveEngine::new(1024, 4096, None, false);
        e.write(&f, 0, &[7u8; 16]).unwrap(); // staged
        e.write(&f, 16, &[8u8; 2048]).unwrap(); // bypass: drains + direct
        assert_eq!(f.io_stats().write_calls, 2);
        e.flush(&f, &SerialComm::new()).unwrap();
        let got = f.read_vec(0, 2064).unwrap();
        assert!(got[..16].iter().all(|&b| b == 7));
        assert!(got[16..].iter().all(|&b| b == 8));
        std::fs::remove_file(&path).unwrap();
    }
}
