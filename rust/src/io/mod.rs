//! I/O aggregation: coalescing the section paths' many small positional
//! accesses into few large ones.
//!
//! The serial-equivalence invariant of the format (§2) constrains the
//! *file bytes*, not the *syscall shape*: a section may be materialized
//! by any sequence of positional writes as long as the final bytes are
//! those of the serial write. This module exploits that freedom:
//!
//! * [`aggregate::WriteAggregator`] — a per-rank staging buffer of
//!   `(offset, bytes)` extents. The API writer stages every header row,
//!   count row, data window and padding extent instead of issuing a
//!   `pwrite` each; at flush time adjacent/overlapping extents merge into
//!   contiguous runs and each run is written with a single `write_at`
//!   (a `pwritev`-style gather: scattered in-memory element lists become
//!   one syscall per contiguous file run).
//! * [`sieve::ReadSieve`] — the read-side dual ("data sieving"): one
//!   large aligned window read covers a section's prefix, count rows and
//!   small payloads; subsequent small reads are served from the buffer.
//! * [`IoTuning`] — the per-file knobs, settable via
//!   [`crate::api::ScdaFile::set_io_tuning`].
//!
//! Correctness argument: every staged extent is a write the direct path
//! would have issued, runs replay their extents in stage order (so
//! overlaps resolve exactly like direct `pwrite`s), and ranks only ever
//! stage extents inside their own disjoint windows — so the flushed file
//! bytes are identical to the unaggregated path at any flush schedule,
//! buffer size, and rank count. `rust/tests/io_coalescing.rs` asserts
//! byte-identity against the direct path at 1, 2 and 4 ranks.

pub mod aggregate;
pub mod sieve;

pub use aggregate::{WriteAggregator, WriteCoalescer};
pub use sieve::ReadSieve;

/// Per-file I/O aggregation knobs (the `ScdaFile` tuning surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IoTuning {
    /// Write-side staging capacity in bytes. Extents accumulate until the
    /// buffer would overflow (or the file is flushed/closed), then merge
    /// into contiguous runs written with one syscall each. Writes of at
    /// least this size bypass staging (they are already one syscall).
    /// `0` disables aggregation: every write goes straight to the file
    /// (the reference path aggregation must be byte-identical to).
    pub aggregation_buffer: usize,
    /// Read-side sieve window in bytes. Reads smaller than this are
    /// served from one window-sized buffered read; reads of at least
    /// this size go straight to the file into an exactly-sized buffer.
    /// `0` disables the sieve.
    pub sieve_window: usize,
}

impl Default for IoTuning {
    fn default() -> Self {
        IoTuning { aggregation_buffer: 4 << 20, sieve_window: 128 << 10 }
    }
}

impl IoTuning {
    /// No aggregation, no sieving: one syscall per logical access. This
    /// is the reference path the aggregated one is asserted against.
    pub fn direct() -> Self {
        IoTuning { aggregation_buffer: 0, sieve_window: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tuning_defaults_are_sane() {
        let t = IoTuning::default();
        assert!(t.aggregation_buffer >= 1 << 20);
        assert!(t.sieve_window >= 4 << 10);
        let d = IoTuning::direct();
        assert_eq!(d.aggregation_buffer, 0);
        assert_eq!(d.sieve_window, 0);
    }
}
