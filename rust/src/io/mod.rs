//! I/O engines: pluggable write/read transports under the section paths.
//!
//! The serial-equivalence invariant of the format (§2) constrains the
//! *file bytes*, not the *syscall shape*: a section may be materialized
//! by any sequence of positional writes — issued by any rank — as long
//! as the final bytes are those of the serial write. This module turns
//! that freedom into a policy choice, the [`IoEngine`] trait
//! ([`engine`]), with three implementations:
//!
//! * [`DirectEngine`] — the reference path: one syscall per logical
//!   access. Everything else is property-tested byte-identical to it.
//! * [`AggregatingEngine`] — per-rank staging ([`WriteAggregator`]) and
//!   read sieving ([`ReadSieve`]): adjacent extents merge into contiguous
//!   runs, one `pwrite` per run; one aligned window `pread` serves the
//!   many small metadata reads, with the window adapting to the access
//!   pattern (sequential scans grow it, random seeks shrink it).
//! * [`CollectiveEngine`] — two-phase collective buffering
//!   ([`collective`]): staged extents ship over
//!   `Communicator::alltoall_bytes` to the aggregator rank owning each
//!   file stripe, so each stripe is written by exactly one rank with one
//!   syscall per contiguous run, regardless of section interleaving.
//!   Reads run the same re-homing in reverse ([`IoEngine::read_window`],
//!   the collective *read gather*): ranks announce their windows, stripe
//!   owners `pread` one contiguous run of requested stripes each, and
//!   fragments scatter back over the alltoall — read syscalls track
//!   bytes touched, not rank count or interleaving.
//!
//! Any engine can additionally run its drains on the shared codec pool
//! (`async_flush`): `pwrite`s overlap encoding, and errors surface at
//! the next `flush`/`close` — or via [`take_drop_error`] if the file is
//! dropped first. [`IoTuning`] selects and parameterizes the engine per
//! file ([`crate::api::ScdaFile::set_io_tuning`]).

pub mod aggregate;
pub mod cache;
pub mod collective;
pub mod engine;
pub mod fault;
pub mod sieve;

pub use aggregate::{Payload, WriteAggregator, WriteCoalescer};
pub use cache::{CacheAccess, CacheStats, PageCache};
pub use collective::CollectiveEngine;
pub use engine::{
    drop_error_stats, take_drop_error, AggregatingEngine, DirectEngine, DropErrorStats,
    EngineStats, IoEngine,
};
pub use fault::{retry_transient, FaultKind, FaultOp, FaultPlan};
pub use sieve::ReadSieve;

/// Which transport an [`IoTuning`] selects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IoEngineKind {
    /// One syscall per logical access; no staging, no sieve. The
    /// reference path every other engine is asserted against.
    Direct,
    /// Per-rank write aggregation + read sieving (the default).
    Aggregating,
    /// Two-phase collective buffering over stripe-owning aggregator
    /// ranks.
    Collective,
}

/// Per-file I/O engine knobs (the `ScdaFile` tuning surface). The file
/// bytes are identical under every tuning; only the syscall shape, the
/// memory footprint and who issues the writes change.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IoTuning {
    /// Which transport to route reads and writes through.
    pub engine: IoEngineKind,
    /// Write-side staging capacity in bytes. Extents accumulate until the
    /// buffer would overflow (or the file is flushed/closed), then merge
    /// into contiguous runs written with one syscall each. Writes of at
    /// least this size bypass staging (they are already one syscall).
    /// `0` disables staging: every write goes straight to the file.
    pub aggregation_buffer: usize,
    /// Read-side sieve window in bytes (the *initial* window: it adapts
    /// within [4 KiB, 8x] to the observed access pattern). Reads smaller
    /// than the current window are served from one buffered window read;
    /// larger reads go straight to the file into an exactly-sized buffer.
    /// `0` disables the sieve.
    pub sieve_window: usize,
    /// Collective engine: the file-stripe size. Stripe `s` (bytes
    /// `[s*stripe_size, (s+1)*stripe_size)`) is written exclusively by
    /// rank `s % P` after the extent exchange.
    pub stripe_size: usize,
    /// Drain staged runs on the shared codec pool so `pwrite`s overlap
    /// codec work; errors surface at the next `flush`/`close`, never
    /// dropped (see [`take_drop_error`] for the drop path).
    ///
    /// Background flush rides the process-wide shared pool
    /// ([`crate::par::pool::CodecPool::global`]) unless the file was
    /// given its own pool (`ScdaFile::set_flush_pool`), which keeps
    /// flush `pwrite`s from queueing behind codec jobs.
    ///
    /// Caveat: background runs execute in no particular order relative
    /// to each other or to bypass writes, so the async path assumes a
    /// *write-once* stream — every file byte written at most once
    /// between flushes. The section paths guarantee this by
    /// construction; engine users re-writing a range must flush between
    /// the writes or keep `async_flush` off (the sync path replays
    /// overlaps in stage order).
    pub async_flush: bool,
}

impl Default for IoTuning {
    fn default() -> Self {
        IoTuning {
            engine: IoEngineKind::Aggregating,
            aggregation_buffer: 4 << 20,
            sieve_window: 128 << 10,
            stripe_size: 1 << 20,
            async_flush: false,
        }
    }
}

impl IoTuning {
    /// No staging, no sieving: one syscall per logical access. This is
    /// the reference path the other engines must be byte-identical to.
    pub fn direct() -> Self {
        IoTuning {
            engine: IoEngineKind::Direct,
            aggregation_buffer: 0,
            sieve_window: 0,
            ..IoTuning::default()
        }
    }

    /// Two-phase collective buffering with the default knobs: writes
    /// ship staged extents to stripe-owner ranks, reads run the
    /// stripe-owner gather — both syscall shapes track bytes touched,
    /// not rank count. The file bytes are identical to every other
    /// tuning.
    ///
    /// ```
    /// use scda::api::IoTuning;
    /// use scda::io::IoEngineKind;
    ///
    /// let t = IoTuning::collective().with_stripe_size(64 << 10).with_async_flush(true);
    /// assert_eq!(t.engine, IoEngineKind::Collective);
    /// assert_eq!(t.stripe_size, 64 << 10);
    /// assert!(t.async_flush);
    /// // Apply per file: `ScdaFile::set_io_tuning(t)`.
    /// ```
    pub fn collective() -> Self {
        IoTuning { engine: IoEngineKind::Collective, ..IoTuning::default() }
    }

    /// Builder: toggle the overlapped (codec-pool) flush.
    pub fn with_async_flush(mut self, on: bool) -> Self {
        self.async_flush = on;
        self
    }

    /// Builder: set the collective stripe size.
    pub fn with_stripe_size(mut self, bytes: usize) -> Self {
        self.stripe_size = bytes;
        self
    }

    /// Builder: set the write-staging capacity.
    pub fn with_aggregation_buffer(mut self, bytes: usize) -> Self {
        self.aggregation_buffer = bytes;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tuning_defaults_are_sane() {
        let t = IoTuning::default();
        assert_eq!(t.engine, IoEngineKind::Aggregating);
        assert!(t.aggregation_buffer >= 1 << 20);
        assert!(t.sieve_window >= 4 << 10);
        assert!(t.stripe_size >= 64 << 10);
        assert!(!t.async_flush);
        let d = IoTuning::direct();
        assert_eq!(d.engine, IoEngineKind::Direct);
        assert_eq!(d.aggregation_buffer, 0);
        assert_eq!(d.sieve_window, 0);
    }

    #[test]
    fn tuning_builders_compose() {
        let t = IoTuning::collective().with_async_flush(true).with_stripe_size(64 << 10);
        assert_eq!(t.engine, IoEngineKind::Collective);
        assert!(t.async_flush);
        assert_eq!(t.stripe_size, 64 << 10);
        assert_eq!(t.with_aggregation_buffer(123).aggregation_buffer, 123);
    }
}
