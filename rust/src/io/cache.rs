//! The shared page cache: one pool of refcounted, evictable file pages
//! serving any number of concurrent reader sessions of one archive.
//!
//! The per-handle [`crate::io::ReadSieve`] amortizes *one* reader's small
//! metadata reads into window `pread`s — but every `ScdaFile` owns its
//! sieve, so N concurrent readers of the same file pay N× the cache
//! memory and N× the syscalls for the same hot bytes. This module is the
//! read path's shared dual: the file is cut into fixed-size pages, pages
//! live in one process-wide (per-service) pool under a single byte
//! budget, and sessions borrow pages by `Arc` — eviction drops the pool's
//! reference while in-flight readers keep theirs, so a page is never
//! freed under a copy.
//!
//! # Coalesced misses (single-flight)
//!
//! Concurrent misses on the same page collapse to **one** `pread`: the
//! first misser marks the slot `Filling` and issues the read; later
//! requesters of that page block on a condvar until the slot is `Ready`
//! (counted as `single_flight_waits`, the in-process analogue of the
//! P-fold dedup in the collective read gather). A miss that spans
//! several absent pages claims the whole contiguous run and fills it
//! with a single gather `pread`, so sequential windows cost one syscall
//! regardless of the page size.
//!
//! # Eviction
//!
//! Clock (second-chance) over the resident pages: pages enter the ring
//! *unreferenced* and every hit sets the reference bit, so a page must
//! be touched again after its fill to earn a second chance — one-touch
//! scan pages leave before hot pages instead of aging the whole ring
//! into FIFO. The evictor clears bits on its first pass and evicts on
//! the second.
//! Eviction runs under the fill lock whenever `resident_bytes` exceeds
//! the budget — the budget bounds *resident* bytes; borrowed `Arc`s on
//! in-flight reads may briefly exceed it, exactly like an OS page cache
//! under pinned pages.
//!
//! A cache serves exactly one underlying file (pages are keyed by file
//! offset only); the owner — [`crate::runtime::ArchiveReadService`] —
//! guarantees every session passes the same [`ParallelFile`] handle.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::error::{corrupt, Result, ScdaError};
use crate::io::fault::retry_transient;
use crate::obs::trace::{SpanKind, Tracer};
use crate::par::pfile::ParallelFile;

/// Default page size: large enough that a section's metadata rows fit in
/// one page, small enough that a zipfian tail does not drag whole
/// megabytes in per key.
pub const DEFAULT_PAGE_BYTES: usize = 64 << 10;

/// Default budget: a few hot datasets' worth of pages.
pub const DEFAULT_BUDGET_BYTES: usize = 32 << 20;

/// Per-call / per-stream cache accounting, accumulated by each session's
/// sieve so [`crate::io::EngineStats`] can report session-local hit
/// rates while [`CacheStats`] reports the pool-global view.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheAccess {
    /// Pages served from a resident slot.
    pub hits: u64,
    /// Pages this caller filled itself (it issued or joined the pread).
    pub misses: u64,
    /// Times this caller blocked on another caller's in-flight fill.
    pub waits: u64,
}

impl CacheAccess {
    /// Fold another accounting delta into this one.
    pub fn absorb(&mut self, o: CacheAccess) {
        self.hits += o.hits;
        self.misses += o.misses;
        self.waits += o.waits;
    }
}

/// Pool-global counters ([`PageCache::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Page lookups served from a resident page (all sessions).
    pub hits: u64,
    /// Page lookups that had to fill the page.
    pub misses: u64,
    /// Pages evicted under the budget.
    pub evictions: u64,
    /// Times a caller blocked on another caller's in-flight fill — each
    /// one is a `pread` the single-flight dedup saved.
    pub single_flight_waits: u64,
    /// `pread`s issued to fill pages: one per contiguous run of missing
    /// pages, however many sessions missed concurrently. Under a hot
    /// workload this tracks *unique bytes touched*, never session count.
    pub fill_preads: u64,
    /// Bytes fetched by fill `pread`s.
    pub filled_bytes: u64,
    /// Bytes currently resident (always `<=` budget after each fill).
    pub resident_bytes: u64,
    /// Pages currently resident.
    pub resident_pages: u64,
}

#[derive(Debug)]
enum Slot {
    /// A fill `pread` is in flight; waiters block on the condvar.
    Filling,
    /// Resident page. `referenced` is the clock's second-chance bit.
    Ready { data: Arc<Vec<u8>>, referenced: bool },
}

#[derive(Debug, Default)]
struct Inner {
    slots: HashMap<u64, Slot>,
    /// Clock ring over resident pages: exactly one entry per `Ready`
    /// slot (`Filling` slots are not evictable and carry no entry).
    clock: VecDeque<u64>,
    resident_bytes: usize,
}

/// The shared, thread-safe page pool. Cheap to clone behind an `Arc`;
/// every reader session of one [`crate::runtime::ArchiveReadService`]
/// holds the same instance.
#[derive(Debug)]
pub struct PageCache {
    page_bytes: usize,
    budget_bytes: usize,
    inner: Mutex<Inner>,
    cv: Condvar,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
    waits: AtomicU64,
    fill_preads: AtomicU64,
    filled_bytes: AtomicU64,
    /// Span recorder for fill/wait attribution (`cache_fill` spans carry
    /// the gather-pread bytes; `cache_wait` spans cover the condvar
    /// block on another session's fill). `None` costs one branch.
    tracer: Option<Arc<Tracer>>,
}

impl PageCache {
    /// A cache of `page_bytes`-sized pages under a `budget_bytes` total.
    /// Both are clamped to sane floors (a 0-page cache is a bug, not a
    /// policy — use `None` at the tuning layer to disable sharing).
    pub fn new(page_bytes: usize, budget_bytes: usize) -> Self {
        let page_bytes = page_bytes.max(512);
        PageCache {
            page_bytes,
            budget_bytes: budget_bytes.max(page_bytes),
            inner: Mutex::new(Inner::default()),
            cv: Condvar::new(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            waits: AtomicU64::new(0),
            fill_preads: AtomicU64::new(0),
            filled_bytes: AtomicU64::new(0),
            tracer: None,
        }
    }

    /// The defaults ([`DEFAULT_PAGE_BYTES`], [`DEFAULT_BUDGET_BYTES`]).
    pub fn with_defaults() -> Self {
        Self::new(DEFAULT_PAGE_BYTES, DEFAULT_BUDGET_BYTES)
    }

    /// Builder: record fill/wait spans on `tracer` (`None` disables).
    /// Constructor-time only — `read_into` takes `&self`, so the tracer
    /// is immutable for the cache's whole life.
    pub fn with_tracer(mut self, tracer: Option<Arc<Tracer>>) -> Self {
        self.tracer = tracer;
        self
    }

    pub fn page_bytes(&self) -> usize {
        self.page_bytes
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget_bytes
    }

    /// Reads at least this large bypass the cache entirely: caching a
    /// payload comparable to the whole budget would evict every hot page
    /// for one streaming consumer.
    pub fn bypass_bytes(&self) -> usize {
        (self.budget_bytes / 2).max(self.page_bytes)
    }

    /// Pool-global counter snapshot.
    pub fn stats(&self) -> CacheStats {
        let inner = self.inner.lock().unwrap();
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            single_flight_waits: self.waits.load(Ordering::Relaxed),
            fill_preads: self.fill_preads.load(Ordering::Relaxed),
            filled_bytes: self.filled_bytes.load(Ordering::Relaxed),
            resident_bytes: inner.resident_bytes as u64,
            resident_pages: inner.clock.len() as u64,
        }
    }

    /// Fill `dst` with the bytes at absolute `off`, serving every
    /// overlapped page from the pool (filling absent runs with one
    /// gather `pread` each, single-flight per page). Errors with the
    /// same corrupt kind as a direct short read past EOF. Returns this
    /// call's hit/miss/wait accounting for the caller's stream counters.
    pub fn read_into(&self, file: &ParallelFile, off: u64, dst: &mut [u8]) -> Result<CacheAccess> {
        let mut acc = CacheAccess::default();
        if dst.is_empty() {
            return Ok(acc);
        }
        let end = off
            .checked_add(dst.len() as u64)
            .ok_or_else(|| ScdaError::corrupt(corrupt::COUNT_OVERFLOW, "read range overflows u64"))?;
        let file_len = file.len()?;
        if end > file_len {
            return Err(ScdaError::corrupt(
                corrupt::TRUNCATED,
                format!("file ends before {} bytes at offset {off}", dst.len()),
            ));
        }
        let pb = self.page_bytes as u64;
        let mut page = off / pb;
        let last = (end - 1) / pb;
        let mut inner = self.inner.lock().unwrap();
        while page <= last {
            // Re-borrow per iteration: fills drop the lock for the pread.
            let slot = inner.slots.get_mut(&page);
            match slot {
                Some(Slot::Ready { data, referenced }) => {
                    *referenced = true;
                    let data = Arc::clone(data);
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    acc.hits += 1;
                    copy_page_span(page, pb, &data, off, dst);
                    page += 1;
                }
                Some(Slot::Filling) => {
                    // Another session is filling this very page: block
                    // until it lands instead of issuing a duplicate
                    // pread, then re-examine (the fill may have failed
                    // and been retracted, in which case we claim it).
                    self.waits.fetch_add(1, Ordering::Relaxed);
                    acc.waits += 1;
                    let _span =
                        self.tracer.as_ref().map(|t| Tracer::start(t, SpanKind::CacheWait));
                    inner = self.cv.wait(inner).unwrap();
                }
                None => {
                    // Claim the maximal contiguous run of absent pages
                    // and fill it with ONE pread (the coalesced miss).
                    let mut run_end = page + 1;
                    while run_end <= last && !inner.slots.contains_key(&run_end) {
                        run_end += 1;
                    }
                    for p in page..run_end {
                        inner.slots.insert(p, Slot::Filling);
                    }
                    drop(inner);
                    let fill = self.fill_run(file, page, run_end, file_len);
                    inner = self.inner.lock().unwrap();
                    match fill {
                        Err(e) => {
                            // Retract the claims so waiters can retry
                            // (one of them becomes the new filler).
                            for p in page..run_end {
                                inner.slots.remove(&p);
                            }
                            self.cv.notify_all();
                            return Err(e);
                        }
                        Ok(pages) => {
                            let n = pages.len() as u64;
                            self.misses.fetch_add(n, Ordering::Relaxed);
                            acc.misses += n;
                            let Inner { slots, clock, resident_bytes } = &mut *inner;
                            for (p, data) in &pages {
                                *resident_bytes += data.len();
                                // Unreferenced on entry (scan resistance):
                                // only a *re*-touch earns a second chance.
                                slots.insert(
                                    *p,
                                    Slot::Ready { data: Arc::clone(data), referenced: false },
                                );
                                clock.push_back(*p);
                            }
                            self.evict_to_budget(&mut inner);
                            self.cv.notify_all();
                            // Copy from our own Arcs: eviction above may
                            // already have dropped the pool's reference,
                            // but ours keeps the bytes alive.
                            for (p, data) in &pages {
                                copy_page_span(*p, pb, data, off, dst);
                            }
                            page = run_end;
                        }
                    }
                }
            }
        }
        Ok(acc)
    }

    /// One gather `pread` over pages `[first, run_end)` (clamped to
    /// EOF), split into per-page refcounted buffers.
    fn fill_run(
        &self,
        file: &ParallelFile,
        first: u64,
        run_end: u64,
        file_len: u64,
    ) -> Result<Vec<(u64, Arc<Vec<u8>>)>> {
        let pb = self.page_bytes as u64;
        let start = first * pb;
        let end = (run_end * pb).min(file_len);
        let mut buf = vec![0u8; (end - start) as usize];
        let mut span = self.tracer.as_ref().map(|t| Tracer::start(t, SpanKind::CacheFill));
        if let Some(s) = span.as_mut() {
            s.set_bytes(buf.len() as u64);
        }
        retry_transient(|| file.read_at(start, &mut buf))?;
        drop(span);
        self.fill_preads.fetch_add(1, Ordering::Relaxed);
        self.filled_bytes.fetch_add(buf.len() as u64, Ordering::Relaxed);
        let mut out = Vec::with_capacity((run_end - first) as usize);
        for (i, p) in (first..run_end).enumerate() {
            let s = i * self.page_bytes;
            let e = ((i + 1) * self.page_bytes).min(buf.len());
            out.push((p, Arc::new(buf[s..e].to_vec())));
        }
        Ok(out)
    }

    /// Clock second-chance sweep until resident bytes fit the budget.
    /// `Filling` slots carry no clock entry and are never evicted.
    fn evict_to_budget(&self, inner: &mut Inner) {
        let Inner { slots, clock, resident_bytes } = inner;
        // Two full passes bound the sweep: pass one clears reference
        // bits, pass two evicts — after that every page was evictable.
        let mut budget_iters = clock.len() * 2 + 1;
        while *resident_bytes > self.budget_bytes && budget_iters > 0 {
            budget_iters -= 1;
            let Some(p) = clock.pop_front() else { break };
            match slots.get_mut(&p) {
                Some(Slot::Ready { referenced, data }) => {
                    if *referenced {
                        *referenced = false;
                        clock.push_back(p);
                    } else {
                        *resident_bytes -= data.len();
                        slots.remove(&p);
                        self.evictions.fetch_add(1, Ordering::Relaxed);
                    }
                }
                // Unreachable by the one-entry-per-Ready-slot invariant;
                // dropping a stale entry is the safe recovery either way.
                _ => {}
            }
        }
    }
}

/// Copy the overlap of page `page` (bytes `[page*pb, page*pb+len)`) and
/// the request window `[req_off, req_off + dst.len())` into `dst`.
fn copy_page_span(page: u64, pb: u64, data: &[u8], req_off: u64, dst: &mut [u8]) {
    let pstart = page * pb;
    let pend = pstart + data.len() as u64;
    let req_end = req_off + dst.len() as u64;
    let lo = pstart.max(req_off);
    let hi = pend.min(req_end);
    if lo >= hi {
        return;
    }
    dst[(lo - req_off) as usize..(hi - req_off) as usize]
        .copy_from_slice(&data[(lo - pstart) as usize..(hi - pstart) as usize]);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::{Communicator, SerialComm};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("scda-cache");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    fn file_with(n: usize, name: &str) -> (Arc<ParallelFile>, PathBuf) {
        let path = tmp(name);
        let c = SerialComm::new();
        assert_eq!(c.rank(), 0);
        let f = ParallelFile::create(&c, &path).unwrap();
        let data: Vec<u8> = (0..n).map(|i| (i % 251) as u8).collect();
        f.write_at(0, &data).unwrap();
        drop(f);
        (Arc::new(ParallelFile::open_read(&c, &path).unwrap()), path)
    }

    fn expect(off: u64, len: usize) -> Vec<u8> {
        (off..off + len as u64).map(|i| (i % 251) as u8).collect()
    }

    #[test]
    fn pages_fill_once_and_hit_after() {
        let (f, path) = file_with(64 * 1024, "fill-once");
        let c = PageCache::new(4096, 1 << 20);
        let mut buf = vec![0u8; 100];
        let a = c.read_into(&f, 10, &mut buf).unwrap();
        assert_eq!(buf, expect(10, 100));
        assert_eq!((a.hits, a.misses, a.waits), (0, 1, 0));
        let a = c.read_into(&f, 50, &mut buf).unwrap();
        assert_eq!(buf, expect(50, 100));
        assert_eq!((a.hits, a.misses), (1, 0));
        let st = c.stats();
        assert_eq!(st.fill_preads, 1);
        assert_eq!(st.resident_pages, 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn run_of_missing_pages_is_one_gather_pread() {
        let (f, path) = file_with(256 * 1024, "run");
        let before = f.io_stats().read_calls;
        let c = PageCache::new(4096, 1 << 20);
        // 40 KiB spanning 11 pages: one coalesced pread, 11 pages.
        let mut buf = vec![0u8; 40 * 1024];
        let a = c.read_into(&f, 100, &mut buf).unwrap();
        assert_eq!(buf, expect(100, 40 * 1024));
        assert_eq!(a.misses, 11);
        assert_eq!(c.stats().fill_preads, 1);
        assert_eq!(f.io_stats().read_calls - before, 1);
        // A second overlapping read is all hits, zero syscalls.
        let a = c.read_into(&f, 4096, &mut buf).unwrap();
        assert_eq!(a.misses, 0);
        assert_eq!(f.io_stats().read_calls - before, 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn eviction_keeps_resident_bytes_under_budget() {
        let (f, path) = file_with(512 * 1024, "evict");
        let c = PageCache::new(4096, 8 * 4096);
        let mut buf = vec![0u8; 4096];
        for i in 0..64u64 {
            c.read_into(&f, i * 4096, &mut buf).unwrap();
            assert_eq!(buf, expect(i * 4096, 4096), "page {i}");
            assert!(c.stats().resident_bytes <= 8 * 4096);
        }
        let st = c.stats();
        assert!(st.evictions >= 64 - 8, "evictions {}", st.evictions);
        assert_eq!(st.misses, 64, "a pure scan never re-hits");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn hot_page_survives_a_scan() {
        let (f, path) = file_with(512 * 1024, "clock");
        let c = PageCache::new(4096, 4 * 4096);
        let mut buf = vec![0u8; 16];
        // Touch the hot page, then keep re-touching it between scan
        // steps: its reference bit stays set, so the clock evicts the
        // one-touch scan pages first.
        c.read_into(&f, 0, &mut buf).unwrap();
        for i in 1..32u64 {
            c.read_into(&f, i * 4096, &mut buf).unwrap();
            c.read_into(&f, 8, &mut buf).unwrap();
            assert_eq!(buf, expect(8, 16));
        }
        let st = c.stats();
        // Page 0 was filled exactly once: 1 miss for it + 31 scan misses.
        assert_eq!(st.misses, 32, "hot page never refilled: {st:?}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn concurrent_hot_reads_collapse_to_one_pread() {
        let (f, path) = file_with(64 * 1024, "single-flight");
        let c = Arc::new(PageCache::new(4096, 1 << 20));
        let before = f.io_stats().read_calls;
        let barrier = Arc::new(std::sync::Barrier::new(8));
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = Arc::clone(&c);
                let f = Arc::clone(&f);
                let barrier = Arc::clone(&barrier);
                s.spawn(move || {
                    barrier.wait();
                    let mut buf = vec![0u8; 256];
                    c.read_into(&f, 1000, &mut buf).unwrap();
                    assert_eq!(buf, expect(1000, 256));
                });
            }
        });
        // All eight sessions touched the same page: exactly one pread,
        // regardless of who waited and who hit after the fill.
        assert_eq!(f.io_stats().read_calls - before, 1);
        assert_eq!(c.stats().fill_preads, 1);
        let st = c.stats();
        assert!(st.hits + st.misses + st.single_flight_waits >= 8, "{st:?}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn past_eof_is_corrupt_error() {
        let (f, path) = file_with(1000, "eof");
        let c = PageCache::new(4096, 1 << 20);
        let mut buf = vec![0u8; 100];
        let err = c.read_into(&f, 950, &mut buf).unwrap_err();
        assert_eq!(err.kind(), crate::error::ScdaErrorKind::CorruptFile);
        // In-bounds read afterwards is fine (claims were not leaked).
        let mut buf = vec![0u8; 50];
        c.read_into(&f, 950, &mut buf).unwrap();
        assert_eq!(buf, expect(950, 50));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn short_tail_page_clamps_to_eof() {
        let (f, path) = file_with(5000, "tail");
        let c = PageCache::new(4096, 1 << 20);
        let mut buf = vec![0u8; 900];
        c.read_into(&f, 4100, &mut buf).unwrap();
        assert_eq!(buf, expect(4100, 900));
        let st = c.stats();
        // Page 1 is the 904-byte tail, not a full page.
        assert_eq!(st.resident_bytes, 4096 + 904);
        std::fs::remove_file(&path).unwrap();
    }
}
