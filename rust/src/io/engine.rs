//! The pluggable I/O engine: one write/read transport behind all section
//! paths.
//!
//! The serial-equivalence invariant (§2) pins down the *file bytes*, not
//! the *syscall schedule* — any agent may issue any positional write as
//! long as the final bytes equal the serial write's. An [`IoEngine`] is
//! one policy for exploiting that freedom. Three ship with the crate:
//!
//! * [`DirectEngine`] — the reference path: one syscall per logical
//!   access, nothing buffered. Every other engine is asserted
//!   byte-identical to it.
//! * [`AggregatingEngine`] — PR 2's per-rank staging
//!   ([`crate::io::WriteAggregator`]) and read sieving
//!   ([`crate::io::ReadSieve`]) rehomed behind the trait: extents merge
//!   into contiguous runs, one `pwrite` per run.
//! * [`crate::io::CollectiveEngine`] — two-phase collective buffering:
//!   staged extents ship over [`Communicator::alltoall_bytes`] to the
//!   aggregator rank owning each file stripe, so each stripe is written
//!   by exactly one rank regardless of how sections interleave ranks.
//!
//! # Contract
//!
//! * `write` may stage or issue the bytes; after a successful collective
//!   `flush` every staged byte is in the file.
//! * A rank only writes inside its own disjoint windows (the partition
//!   arithmetic guarantees this), and the section paths write every file
//!   byte **exactly once** — which is what lets engines reorder, merge,
//!   re-home (collective) and background (async) the writes without the
//!   bytes ever depending on the schedule.
//! * `flush` is collective (every rank, same order, like any other scda
//!   call); `drain_local` is the per-rank fallback used on drop, correct
//!   because staged extents are always the rank's own window writes.
//! * With `async_flush`, staged runs execute on the shared
//!   [`CodecPool`] so `pwrite`s overlap codec work; errors are recorded,
//!   never dropped, and re-surface at the next `flush`/`close` — or, if
//!   the file is dropped without either, through [`take_drop_error`].

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::error::{Result, ScdaError};
use crate::io::aggregate::{Payload, WriteAggregator};
use crate::io::fault::retry_transient;
use crate::io::sieve::ReadSieve;
use crate::io::{IoEngineKind, IoTuning};
use crate::obs::trace::{SpanKind, Tracer};
use crate::par::comm::Communicator;
use crate::par::pfile::ParallelFile;
use crate::par::pool::{CodecPool, ParJob, Step, SUBMITTER};

/// Per-engine observability counters ([`crate::api::ScdaFile::engine_stats`]).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct EngineStats {
    /// The engine's name: "direct", "aggregated" or "collective".
    pub engine: &'static str,
    /// Bytes this rank shipped to other ranks' stripes (collective
    /// two-phase exchange; 0 for per-rank engines).
    pub shipped_bytes: u64,
    /// Collective extent exchanges performed.
    pub exchanges: u64,
    /// Staged-run drain batches issued (sync or async).
    pub flush_batches: u64,
    /// Read-sieve window refills.
    pub sieve_refills: u64,
    /// Bytes this rank shipped in *each* collective exchange, in
    /// exchange order — the most recent
    /// [`crate::io::collective::SHIPPED_HISTORY_CAP`] of them (while
    /// under the cap, `len == exchanges` and the entries sum to
    /// `shipped_bytes`; empty for per-rank engines). The per-exchange
    /// shape is what the smarter-stripe-ownership work needs: a uniform
    /// `s mod P` map shows up as consistently high per-exchange volume.
    pub shipped_per_exchange: Vec<u64>,
    /// Collective read gathers performed — the read-side dual of
    /// `exchanges` (0 for per-rank engines).
    pub read_exchanges: u64,
    /// Bytes this rank served to *other* ranks' read requests as a
    /// stripe owner (read-side dual of `shipped_bytes`; 0 for per-rank
    /// engines).
    pub gathered_bytes: u64,
    /// `pread`s this rank issued while serving collective read gathers:
    /// one per contiguous run of requested stripes it owns, plus
    /// single-requester bypass reads. Summed over ranks, this is a pure
    /// function of the *bytes touched* — never of the rank count or the
    /// section interleaving (`rust/tests/io_read_gather.rs` asserts
    /// this, mirroring the write-side syscall invariant).
    pub gather_preads: u64,
    /// Times the sieve's adaptive window doubled (sequential scans).
    pub sieve_grows: u64,
    /// Times the sieve's adaptive window halved (random access).
    pub sieve_shrinks: u64,
    /// Pages this handle's stream served from the shared page cache
    /// (0 when the sieve is private — see [`crate::io::PageCache`]).
    pub cache_hits: u64,
    /// Pages this handle's stream had to fill itself.
    pub cache_misses: u64,
    /// Times this stream blocked on another session's in-flight fill
    /// (each one is a pread the single-flight dedup saved).
    pub cache_waits: u64,
    /// Evictions of the backing shared cache. Pool-global (all sessions
    /// of the service), snapshot at [`IoEngine::stats`] time.
    pub cache_evictions: u64,
}

/// One write/read transport for an open scda file; see the module docs
/// for the contract. Object-safe: `ScdaFile` holds a `Box<dyn IoEngine>`
/// and communicators cross as `&dyn Communicator`.
pub trait IoEngine: Send {
    /// The engine's stable name (for stats, benches, reports).
    fn name(&self) -> &'static str;

    /// Stage or issue `data` at absolute `offset` (this rank's window).
    fn write(&mut self, file: &Arc<ParallelFile>, offset: u64, data: &[u8]) -> Result<()>;

    /// Like [`Self::write`], but the caller relinquishes the buffer —
    /// staging engines move it into the aggregator as its own extent
    /// instead of memcpy'ing it (the zero-copy path for
    /// codec-materialized frames). The default delegates to `write`,
    /// so the byte semantics are identical on every engine.
    fn write_owned(&mut self, file: &Arc<ParallelFile>, offset: u64, data: Vec<u8>) -> Result<()> {
        self.write(file, offset, &data)
    }

    /// A borrowed view of `len` bytes at `offset` — the metadata read
    /// primitive (section prefixes, count rows). Sieved engines serve it
    /// from the window; the direct engine reads into scratch.
    fn view(&mut self, file: &Arc<ParallelFile>, offset: u64, len: usize) -> Result<&[u8]>;

    /// Read `len` bytes at `offset` into a fresh buffer; engines route
    /// small reads through the sieve and large ones straight to the file.
    fn read_vec(&mut self, file: &Arc<ParallelFile>, offset: u64, len: usize) -> Result<Vec<u8>>;

    /// Read exactly `buf.len()` bytes at `offset` into a caller buffer
    /// (no allocation on the direct route).
    fn read_into(&mut self, file: &Arc<ParallelFile>, offset: u64, buf: &mut [u8]) -> Result<()>;

    /// Collective window read: every rank of `comm` passes its own
    /// request window (`buf` may be empty on ranks reading nothing —
    /// they still participate, exactly like a skipped `want = false`
    /// data call). The default is the per-rank [`Self::read_into`]
    /// route; the collective engine overrides it with the stripe-owner
    /// gather — the read-side dual of the two-phase write. Returns
    /// whether the engine's own collectives already synchronized every
    /// rank (so the caller may skip its section barrier); the value is
    /// a pure function of collective inputs and therefore identical on
    /// all ranks.
    fn read_window(
        &mut self,
        file: &Arc<ParallelFile>,
        offset: u64,
        buf: &mut [u8],
        _comm: &dyn Communicator,
    ) -> Result<bool> {
        if !buf.is_empty() {
            self.read_into(file, offset, buf)?;
        }
        Ok(false)
    }

    /// Collective hook invoked by every rank at each section boundary.
    /// Two-phase engines use it to agree — collectively — when to
    /// exchange staged extents. Returns whether the hook itself already
    /// synchronized all ranks (a collective ran), letting the caller
    /// skip the section barrier instead of paying two rounds.
    fn section_end(&mut self, _file: &Arc<ParallelFile>, _comm: &dyn Communicator) -> Result<bool> {
        Ok(false)
    }

    /// Collective full drain: after it returns on all ranks, every staged
    /// byte is in the file and any deferred background-flush error has
    /// been surfaced (returned here, not dropped).
    fn flush(&mut self, file: &Arc<ParallelFile>, comm: &dyn Communicator) -> Result<()>;

    /// Per-rank drain (no communicator): writes this rank's staged
    /// extents locally and waits out background work. Always
    /// byte-correct — staged extents are the rank's own window writes —
    /// but skips the collective re-homing. Used by drop paths.
    fn drain_local(&mut self, file: &Arc<ParallelFile>) -> Result<()>;

    /// Take a recorded-but-unsurfaced deferred error (background flush),
    /// if any. Once taken it is considered reported.
    fn take_error(&mut self) -> Option<ScdaError> {
        None
    }

    /// Snapshot of the engine's counters.
    fn stats(&self) -> EngineStats;
}

/// Build the engine an [`IoTuning`] selects. `read_mode` files get the
/// sieve (when the tuning has one); write-mode files get staging state.
/// With `cache`, the sieve of either staged engine sources its refills
/// (and sub-bypass payload reads) from that shared page pool instead of
/// private preads — the multi-session read-service path. With
/// `flush_pool`, async background flush runs on the given pool instead
/// of borrowing the process-wide shared codec pool.
pub(crate) fn build_engine(
    tuning: &IoTuning,
    read_mode: bool,
    file: &Arc<ParallelFile>,
    cache: Option<&Arc<crate::io::cache::PageCache>>,
    flush_pool: Option<&Arc<CodecPool>>,
    tracer: Option<&Arc<Tracer>>,
) -> Result<Box<dyn IoEngine>> {
    let sieve = if read_mode && tuning.sieve_window > 0 && tuning.engine != IoEngineKind::Direct {
        Some(match cache {
            Some(c) => ReadSieve::shared(tuning.sieve_window, file.len()?, Arc::clone(c)),
            None => ReadSieve::new(tuning.sieve_window, file.len()?),
        })
    } else {
        None
    };
    let pool = flush_pool.cloned();
    let tracer = tracer.cloned();
    Ok(match tuning.engine {
        // The direct engine stays untraced: it is the one-syscall
        // reference path, and keeping it bare preserves the "zero
        // overhead when disabled" baseline the property tests compare
        // staged engines against.
        IoEngineKind::Direct => Box::new(DirectEngine::new()),
        IoEngineKind::Aggregating => Box::new(
            AggregatingEngine::new(tuning.aggregation_buffer, sieve, tuning.async_flush)
                .with_flush_pool(pool)
                .with_tracer(tracer),
        ),
        IoEngineKind::Collective => Box::new(
            crate::io::collective::CollectiveEngine::new(
                tuning.aggregation_buffer,
                tuning.stripe_size,
                sieve,
                tuning.async_flush,
            )
            .with_flush_pool(pool)
            .with_tracer(tracer),
        ),
    })
}

// ---------------------------------------------------------------------
// Dropped-flush error sink
// ---------------------------------------------------------------------

static DROP_ERRORS: Mutex<Vec<ScdaError>> = Mutex::new(Vec::new());
static DROP_EVICTIONS: AtomicU64 = AtomicU64::new(0);

/// Bound on the sink: it is an escape hatch for a polling error sweep,
/// not a log — a process that never polls must not grow it forever.
const DROP_ERRORS_CAP: usize = 64;

/// Observability for the drop-error sink. §A.6 promises file errors are
/// never *silently* lost; the eviction counter is what keeps the capped
/// sink honest about that promise — an evicted error can no longer be
/// taken, but its loss is at least counted.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct DropErrorStats {
    /// Errors currently recorded and not yet taken.
    pub pending: usize,
    /// Errors evicted past the sink's capacity since process start.
    pub evicted: u64,
}

/// Snapshot the drop-error sink's counters (process-wide).
pub fn drop_error_stats() -> DropErrorStats {
    DropErrorStats {
        pending: DROP_ERRORS.lock().unwrap().len(),
        evicted: DROP_EVICTIONS.load(Ordering::Relaxed),
    }
}

/// Record a flush error detected on a drop path (no `Result` channel left
/// to return it through), attributed to the file it happened on.
/// Surfaced later via [`take_drop_error`]. Oldest entries are evicted
/// past [`DROP_ERRORS_CAP`], counted by [`drop_error_stats`].
pub(crate) fn record_drop_error(path: &std::path::Path, e: ScdaError) {
    let mut g = DROP_ERRORS.lock().unwrap();
    if g.len() >= DROP_ERRORS_CAP {
        g.remove(0);
        DROP_EVICTIONS.fetch_add(1, Ordering::Relaxed);
    }
    g.push(ScdaError::io(
        std::io::Error::other(e.to_string()),
        format!("flush on drop of {}", path.display()),
    ));
}

/// Take the most recent flush error recorded by a drop path
/// (`ScdaFile`/`WriteCoalescer` dropped with staged or in-flight writes
/// that then failed). Drop paths cannot return a `Result`, but per §A.6
/// file errors must never be silently lost — this is the escape hatch a
/// runtime's error sweep polls. Returns `None` when nothing failed.
pub fn take_drop_error() -> Option<ScdaError> {
    DROP_ERRORS.lock().unwrap().pop()
}

// ---------------------------------------------------------------------
// Shared sieve-or-direct read routing
// ---------------------------------------------------------------------

pub(crate) fn route_view<'a>(
    sieve: Option<&'a mut ReadSieve>,
    scratch: &'a mut Vec<u8>,
    file: &ParallelFile,
    offset: u64,
    len: usize,
) -> Result<&'a [u8]> {
    match sieve {
        Some(s) => s.view(file, offset, len),
        None => {
            scratch.clear();
            scratch.resize(len, 0);
            retry_transient(|| file.read_at(offset, scratch))?;
            Ok(&scratch[..])
        }
    }
}

pub(crate) fn route_read_vec(
    sieve: &mut Option<ReadSieve>,
    file: &ParallelFile,
    offset: u64,
    len: usize,
) -> Result<Vec<u8>> {
    if let Some(s) = sieve {
        if len < s.base_window() {
            return s.read_vec(file, offset, len);
        }
        if s.is_shared() {
            let mut out = vec![0u8; len];
            s.shared_read_into(file, offset, &mut out)?;
            return Ok(out);
        }
    }
    retry_transient(|| file.read_vec(offset, len))
}

pub(crate) fn route_read_into(
    sieve: &mut Option<ReadSieve>,
    file: &ParallelFile,
    offset: u64,
    buf: &mut [u8],
) -> Result<()> {
    if let Some(s) = sieve {
        if buf.len() < s.base_window() {
            buf.copy_from_slice(s.view(file, offset, buf.len())?);
            return Ok(());
        }
        if s.is_shared() {
            return s.shared_read_into(file, offset, buf);
        }
    }
    retry_transient(|| file.read_at(offset, buf))
}

// ---------------------------------------------------------------------
// StagedCore: the staging state shared by the buffering engines
// ---------------------------------------------------------------------

/// The write-staging and read-routing core shared near-verbatim by
/// [`AggregatingEngine`] and [`crate::io::CollectiveEngine`], factored
/// into one composed struct (the ROADMAP's consolidation item): staging
/// capacity + [`WriteAggregator`] + optional background [`AsyncFlusher`]
/// on the write side, sieve-or-direct routing on the read side. The
/// aggregating engine is little more than this struct behind the trait;
/// the collective engine composes it with the two-phase extent exchange
/// (writes) and the stripe-owner gather (reads), so the staging policy
/// and the sieve routing exist exactly once.
pub(crate) struct StagedCore {
    pub(crate) agg: WriteAggregator,
    /// Staging capacity; 0 disables staging (direct writes, but sieved
    /// reads — the two sides are independent). Also the large-access
    /// bypass bound: accesses of at least this size are already one
    /// syscall.
    pub(crate) capacity: usize,
    pub(crate) sieve: Option<ReadSieve>,
    scratch: Vec<u8>,
    pub(crate) flusher: Option<AsyncFlusher>,
    /// Staged-run drain batches issued (sync or async).
    pub(crate) flush_batches: u64,
    /// Span recorder for the drain paths (`pwrite` spans) and whatever
    /// the owning engine instruments on top. `None` costs one branch.
    pub(crate) tracer: Option<Arc<Tracer>>,
}

impl StagedCore {
    pub(crate) fn new(capacity: usize, sieve: Option<ReadSieve>, async_flush: bool) -> Self {
        StagedCore {
            agg: WriteAggregator::new(),
            capacity,
            sieve,
            scratch: Vec::new(),
            flusher: async_flush.then(AsyncFlusher::new),
            flush_batches: 0,
            tracer: None,
        }
    }

    /// Install (or clear) the span recorder; background flush batches
    /// pick it up on their next submit.
    pub(crate) fn set_tracer(&mut self, tracer: Option<Arc<Tracer>>) {
        if let Some(fl) = &mut self.flusher {
            fl.set_tracer(tracer.clone());
        }
        self.tracer = tracer;
    }

    /// Write this rank's staged extents itself (merged runs, stage
    /// order), skipping any collective. Used for capacity spills, the
    /// large-write bypass and the drop path — all byte-correct, since
    /// staged extents are the rank's own window writes.
    pub(crate) fn drain_staged_locally(&mut self, file: &Arc<ParallelFile>) -> Result<()> {
        if self.agg.is_empty() {
            return Ok(());
        }
        let runs = self.agg.take_runs();
        self.flush_batches += 1;
        dispatch_runs(&mut self.flusher, file, runs, self.tracer.as_ref())
    }

    /// The shared write policy: writes of at least the capacity bypass
    /// staging (they are already one syscall; staged extents drain first
    /// to preserve stage order), a write that would overflow the buffer
    /// spills it, everything else stages. For the collective engine the
    /// spill means a giant section degrades to per-rank aggregation
    /// instead of unbounded memory — normal sections still ship whole at
    /// the next boundary.
    pub(crate) fn stage_write(&mut self, file: &Arc<ParallelFile>, offset: u64, data: &[u8]) -> Result<()> {
        let cap = self.capacity;
        if cap == 0 || data.len() >= cap {
            self.drain_staged_locally(file)?;
            return retry_transient(|| file.write_at(offset, data));
        }
        if self.agg.staged_bytes() + data.len() > cap {
            self.drain_staged_locally(file)?;
        }
        self.agg.stage(offset, data);
        Ok(())
    }

    /// [`Self::stage_write`] for an owned buffer: same spill/bypass
    /// policy, but the staged path *moves* the buffer into the
    /// aggregator (no memcpy), and the bypass writes straight from it.
    pub(crate) fn stage_write_owned(
        &mut self,
        file: &Arc<ParallelFile>,
        offset: u64,
        data: Vec<u8>,
    ) -> Result<()> {
        let cap = self.capacity;
        if cap == 0 || data.len() >= cap {
            self.drain_staged_locally(file)?;
            return retry_transient(|| file.write_at(offset, &data));
        }
        if self.agg.staged_bytes() + data.len() > cap {
            self.drain_staged_locally(file)?;
        }
        self.agg.stage_owned(offset, data);
        Ok(())
    }

    pub(crate) fn view(&mut self, file: &ParallelFile, offset: u64, len: usize) -> Result<&[u8]> {
        route_view(self.sieve.as_mut(), &mut self.scratch, file, offset, len)
    }

    pub(crate) fn read_vec(&mut self, file: &ParallelFile, offset: u64, len: usize) -> Result<Vec<u8>> {
        route_read_vec(&mut self.sieve, file, offset, len)
    }

    pub(crate) fn read_into(&mut self, file: &ParallelFile, offset: u64, buf: &mut [u8]) -> Result<()> {
        route_read_into(&mut self.sieve, file, offset, buf)
    }

    /// Drain staged extents and wait out background work (the shared
    /// `drain_local` of both staged engines).
    pub(crate) fn drain_local(&mut self, file: &Arc<ParallelFile>) -> Result<()> {
        self.drain_staged_locally(file)?;
        match &mut self.flusher {
            Some(fl) => fl.wait(),
            None => Ok(()),
        }
    }

    pub(crate) fn take_error(&self) -> Option<ScdaError> {
        self.flusher.as_ref().and_then(|fl| fl.try_take_error())
    }

    pub(crate) fn sieve_refills(&self) -> u64 {
        self.sieve.as_ref().map(|s| s.refills()).unwrap_or(0)
    }

    /// Point background flush at a dedicated pool (`None` restores the
    /// shared codec pool). No-op without `async_flush`.
    pub(crate) fn set_flush_pool(&mut self, pool: Option<Arc<CodecPool>>) {
        if let Some(fl) = &mut self.flusher {
            fl.set_pool(pool);
        }
    }

    /// Copy the read-side counters (sieve adaptivity + shared-cache
    /// accounting) into a stats snapshot — shared by both staged
    /// engines' [`IoEngine::stats`].
    pub(crate) fn fill_read_stats(&self, st: &mut EngineStats) {
        if let Some(s) = &self.sieve {
            st.sieve_refills = s.refills();
            st.sieve_grows = s.grows();
            st.sieve_shrinks = s.shrinks();
            let acc = s.stream_stats();
            st.cache_hits = acc.hits;
            st.cache_misses = acc.misses;
            st.cache_waits = acc.waits;
            st.cache_evictions = s.cache_evictions();
        }
    }
}

// ---------------------------------------------------------------------
// DirectEngine
// ---------------------------------------------------------------------

/// The reference transport: every logical access is one syscall, nothing
/// is staged or buffered. All other engines are property-tested
/// byte-identical to this one.
#[derive(Debug, Default)]
pub struct DirectEngine {
    scratch: Vec<u8>,
}

impl DirectEngine {
    pub fn new() -> Self {
        DirectEngine { scratch: Vec::new() }
    }
}

impl IoEngine for DirectEngine {
    fn name(&self) -> &'static str {
        "direct"
    }

    fn write(&mut self, file: &Arc<ParallelFile>, offset: u64, data: &[u8]) -> Result<()> {
        retry_transient(|| file.write_at(offset, data))
    }

    fn view(&mut self, file: &Arc<ParallelFile>, offset: u64, len: usize) -> Result<&[u8]> {
        route_view(None, &mut self.scratch, file, offset, len)
    }

    fn read_vec(&mut self, file: &Arc<ParallelFile>, offset: u64, len: usize) -> Result<Vec<u8>> {
        retry_transient(|| file.read_vec(offset, len))
    }

    fn read_into(&mut self, file: &Arc<ParallelFile>, offset: u64, buf: &mut [u8]) -> Result<()> {
        retry_transient(|| file.read_at(offset, buf))
    }

    fn flush(&mut self, _file: &Arc<ParallelFile>, _comm: &dyn Communicator) -> Result<()> {
        Ok(())
    }

    fn drain_local(&mut self, _file: &Arc<ParallelFile>) -> Result<()> {
        Ok(())
    }

    fn stats(&self) -> EngineStats {
        EngineStats { engine: "direct", ..EngineStats::default() }
    }
}

// ---------------------------------------------------------------------
// Background flush on the codec pool
// ---------------------------------------------------------------------

struct FlushCtl {
    /// Runs submitted and not yet completed (success or failure).
    outstanding: Mutex<usize>,
    cv: Condvar,
    /// First error observed by any background write; taken exactly once.
    error: Mutex<Option<ScdaError>>,
}

/// One drained batch of merged runs, executed cooperatively on the codec
/// pool: each unit is one `pwrite`. Runs within and across batches are
/// disjoint byte ranges (the section paths write each byte exactly once),
/// so any execution order produces the same file.
struct FlushBatch {
    file: Arc<ParallelFile>,
    runs: Vec<(u64, Payload)>,
    next: AtomicUsize,
    done: AtomicUsize,
    ctl: Arc<FlushCtl>,
    /// Span recorder for the background `pwrite`s. Pool workers have no
    /// span context, so these spans are roots (parent 0) — the rank tag
    /// still places them on the right timeline row.
    tracer: Option<Arc<Tracer>>,
}

impl ParJob for FlushBatch {
    fn step(&self, _worker: usize) -> Step {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        if i >= self.runs.len() {
            self.next.store(self.runs.len(), Ordering::Relaxed);
            return if self.done.load(Ordering::Acquire) == self.runs.len() {
                Step::Done
            } else {
                Step::Idle
            };
        }
        let (off, buf) = &self.runs[i];
        let mut span = self.tracer.as_ref().map(|t| Tracer::start(t, SpanKind::Pwrite));
        if let Some(s) = span.as_mut() {
            s.set_bytes(buf.as_slice().len() as u64);
        }
        if let Err(e) = retry_transient(|| self.file.write_at(*off, buf.as_slice())) {
            let mut g = self.ctl.error.lock().unwrap();
            if g.is_none() {
                *g = Some(e);
            }
        }
        self.done.fetch_add(1, Ordering::AcqRel);
        let mut out = self.ctl.outstanding.lock().unwrap();
        *out -= 1;
        if *out == 0 {
            self.ctl.cv.notify_all();
        }
        Step::Ran
    }
}

/// Overlapped flush: merged runs are handed to the shared [`CodecPool`]
/// as owned background jobs and execute while the submitting rank keeps
/// staging/encoding. `wait` drains everything (helping) and returns the
/// first recorded error.
pub(crate) struct AsyncFlusher {
    ctl: Arc<FlushCtl>,
    /// Live batches, kept so `wait` can help execute them.
    batches: Vec<Arc<FlushBatch>>,
    /// Dedicated pool for this file's background writes; `None` borrows
    /// the process-wide shared [`CodecPool`]. A file with its own pool
    /// never steals workers from (or queues behind) codec jobs.
    pool: Option<Arc<CodecPool>>,
    /// Span recorder handed to each submitted batch.
    tracer: Option<Arc<Tracer>>,
}

impl AsyncFlusher {
    pub(crate) fn new() -> Self {
        AsyncFlusher {
            ctl: Arc::new(FlushCtl {
                outstanding: Mutex::new(0),
                cv: Condvar::new(),
                error: Mutex::new(None),
            }),
            batches: Vec::new(),
            pool: None,
            tracer: None,
        }
    }

    pub(crate) fn set_pool(&mut self, pool: Option<Arc<CodecPool>>) {
        self.pool = pool;
    }

    pub(crate) fn set_tracer(&mut self, tracer: Option<Arc<Tracer>>) {
        self.tracer = tracer;
    }

    pub(crate) fn submit(&mut self, file: &Arc<ParallelFile>, runs: Vec<(u64, Payload)>) {
        if runs.is_empty() {
            return;
        }
        // Prune batches whose every run has completed, releasing their
        // buffers: live memory stays proportional to in-flight writes,
        // not to the total bytes ever written between flushes.
        self.batches.retain(|b| b.done.load(Ordering::Acquire) < b.runs.len());
        {
            let mut out = self.ctl.outstanding.lock().unwrap();
            *out += runs.len();
        }
        let batch = Arc::new(FlushBatch {
            file: Arc::clone(file),
            runs,
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            ctl: Arc::clone(&self.ctl),
            tracer: self.tracer.clone(),
        });
        self.batches.push(Arc::clone(&batch));
        match &self.pool {
            Some(p) => p.spawn(batch),
            None => CodecPool::global().spawn(batch),
        }
    }

    /// Block until every submitted run has executed, helping from the
    /// calling thread, and surface the first recorded error.
    pub(crate) fn wait(&mut self) -> Result<()> {
        for b in self.batches.drain(..) {
            loop {
                match b.step(SUBMITTER) {
                    Step::Ran => {}
                    Step::Idle | Step::Done => break,
                }
            }
        }
        let mut out = self.ctl.outstanding.lock().unwrap();
        while *out > 0 {
            out = self.ctl.cv.wait(out).unwrap();
        }
        drop(out);
        match self.ctl.error.lock().unwrap().take() {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Take a recorded error without waiting (drop-path polling).
    pub(crate) fn try_take_error(&self) -> Option<ScdaError> {
        self.ctl.error.lock().unwrap().take()
    }
}

/// Write `runs` now (sync) or hand them to the background flusher.
pub(crate) fn dispatch_runs(
    flusher: &mut Option<AsyncFlusher>,
    file: &Arc<ParallelFile>,
    runs: Vec<(u64, Payload)>,
    tracer: Option<&Arc<Tracer>>,
) -> Result<()> {
    match flusher {
        Some(fl) => {
            fl.submit(file, runs);
            Ok(())
        }
        None => {
            for (off, buf) in runs {
                let mut span = tracer.map(|t| Tracer::start(t, SpanKind::Pwrite));
                if let Some(s) = span.as_mut() {
                    s.set_bytes(buf.as_slice().len() as u64);
                }
                retry_transient(|| file.write_at(off, buf.as_slice()))?;
            }
            Ok(())
        }
    }
}

// ---------------------------------------------------------------------
// AggregatingEngine
// ---------------------------------------------------------------------

/// Per-rank write aggregation + read sieving (PR 2's transport) behind
/// the engine trait: extents stage until the buffer would overflow, then
/// merge into contiguous runs written with one syscall each — on the
/// calling thread, or on the codec pool with `async_flush`. This is
/// [`StagedCore`]'s policy verbatim; the struct only adds the trait
/// plumbing.
pub struct AggregatingEngine {
    core: StagedCore,
}

impl AggregatingEngine {
    pub fn new(capacity: usize, sieve: Option<ReadSieve>, async_flush: bool) -> Self {
        AggregatingEngine { core: StagedCore::new(capacity, sieve, async_flush) }
    }

    /// Builder: run async flush on `pool` instead of the shared codec
    /// pool (the per-file flush pool; `None` keeps the shared pool).
    pub fn with_flush_pool(mut self, pool: Option<Arc<CodecPool>>) -> Self {
        self.core.set_flush_pool(pool);
        self
    }

    /// Builder: record `pwrite` spans on `tracer` (`None` disables).
    pub fn with_tracer(mut self, tracer: Option<Arc<Tracer>>) -> Self {
        self.core.set_tracer(tracer);
        self
    }
}

impl IoEngine for AggregatingEngine {
    fn name(&self) -> &'static str {
        "aggregated"
    }

    fn write(&mut self, file: &Arc<ParallelFile>, offset: u64, data: &[u8]) -> Result<()> {
        self.core.stage_write(file, offset, data)
    }

    fn write_owned(&mut self, file: &Arc<ParallelFile>, offset: u64, data: Vec<u8>) -> Result<()> {
        self.core.stage_write_owned(file, offset, data)
    }

    fn view(&mut self, file: &Arc<ParallelFile>, offset: u64, len: usize) -> Result<&[u8]> {
        self.core.view(file, offset, len)
    }

    fn read_vec(&mut self, file: &Arc<ParallelFile>, offset: u64, len: usize) -> Result<Vec<u8>> {
        self.core.read_vec(file, offset, len)
    }

    fn read_into(&mut self, file: &Arc<ParallelFile>, offset: u64, buf: &mut [u8]) -> Result<()> {
        self.core.read_into(file, offset, buf)
    }

    fn flush(&mut self, file: &Arc<ParallelFile>, _comm: &dyn Communicator) -> Result<()> {
        self.core.drain_local(file)
    }

    fn drain_local(&mut self, file: &Arc<ParallelFile>) -> Result<()> {
        self.core.drain_local(file)
    }

    fn take_error(&mut self) -> Option<ScdaError> {
        self.core.take_error()
    }

    fn stats(&self) -> EngineStats {
        let mut st = EngineStats {
            engine: "aggregated",
            flush_batches: self.core.flush_batches,
            ..EngineStats::default()
        };
        self.core.fill_read_stats(&mut st);
        st
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::SerialComm;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("scda-engine");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    fn create(name: &str) -> (Arc<ParallelFile>, PathBuf) {
        let path = tmp(name);
        (Arc::new(ParallelFile::create(&SerialComm::new(), &path).unwrap()), path)
    }

    #[test]
    fn direct_engine_is_one_syscall_per_access() {
        let (f, path) = create("direct");
        let mut e = DirectEngine::new();
        e.write(&f, 0, b"abcd").unwrap();
        e.write(&f, 4, b"efgh").unwrap();
        assert_eq!(f.io_stats().write_calls, 2);
        assert_eq!(e.read_vec(&f, 2, 4).unwrap(), b"cdef");
        assert_eq!(e.view(&f, 0, 3).unwrap(), b"abc");
        let mut buf = [0u8; 4];
        e.read_into(&f, 4, &mut buf).unwrap();
        assert_eq!(&buf, b"efgh");
        e.flush(&f, &SerialComm::new()).unwrap();
        assert_eq!(e.stats().engine, "direct");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn aggregating_engine_merges_and_flushes() {
        let (f, path) = create("agg");
        let mut e = AggregatingEngine::new(1 << 20, None, false);
        for i in 0..50u64 {
            e.write(&f, i * 4, &[i as u8; 4]).unwrap();
        }
        assert_eq!(f.io_stats().write_calls, 0, "everything staged");
        e.flush(&f, &SerialComm::new()).unwrap();
        assert_eq!(f.io_stats().write_calls, 1, "one merged run");
        let got = f.read_vec(0, 200).unwrap();
        for i in 0..50usize {
            assert!(got[i * 4..(i + 1) * 4].iter().all(|&b| b == i as u8));
        }
        assert_eq!(e.stats().flush_batches, 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn async_flush_overlaps_and_surfaces_errors_at_flush() {
        let (f, path) = create("async-err");
        let mut e = AggregatingEngine::new(1 << 20, None, true);
        e.write(&f, 0, &[1u8; 128]).unwrap();
        f.inject_write_failure(0);
        let err = e.flush(&f, &SerialComm::new()).unwrap_err();
        assert_eq!(err.kind(), crate::error::ScdaErrorKind::Io);
        // Error was surfaced at the barrier, not left behind.
        assert!(e.take_error().is_none());
        f.inject_write_failure(u64::MAX);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn async_flush_writes_the_same_bytes() {
        let (f, path) = create("async-ok");
        let mut e = AggregatingEngine::new(4096, None, true);
        let mut expect = vec![0u8; 64 * 113];
        for i in 0..64u64 {
            let b = vec![(i % 251) as u8; 113];
            expect[(i as usize) * 113..(i as usize + 1) * 113].copy_from_slice(&b);
            e.write(&f, i * 113, &b).unwrap();
        }
        e.flush(&f, &SerialComm::new()).unwrap();
        assert_eq!(f.read_vec(0, expect.len()).unwrap(), expect);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn drop_error_sink_attributes_and_drains() {
        let p = std::path::Path::new("/tmp/sink-test.scda");
        record_drop_error(p, ScdaError::io(std::io::Error::other("x"), "sink test"));
        let e = take_drop_error().expect("recorded error present");
        assert_eq!(e.kind(), crate::error::ScdaErrorKind::Io);
        assert!(e.message().contains("sink-test.scda"), "error names the file: {e}");
    }
}
