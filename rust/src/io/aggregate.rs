//! Write aggregation: coalescing a rank's many small positional writes
//! (section header rows, per-element count rows, data windows, padding)
//! into few large ones before they hit the file. On a parallel file
//! system each `pwrite` is a round-trip; on the local substrate it is a
//! syscall — either way, batching adjacent extents is the classic MPI-IO
//! "collective buffering" optimization, scoped per rank.
//!
//! Two layers:
//!
//! * [`WriteAggregator`] — file-less staging state. The API writer owns
//!   one per open file (it cannot borrow the file it lives next to), and
//!   callers flush explicitly with [`WriteAggregator::flush_to`].
//! * [`WriteCoalescer`] — the borrowing convenience wrapper used by the
//!   coordinator layer and ablation benches: holds `&ParallelFile`,
//!   auto-flushes at a high-water mark and on drop.

use std::sync::Arc;

use crate::error::Result;
use crate::par::pfile::ParallelFile;

/// One staged extent's bytes: owned outright, or a pinned shared buffer
/// staged without copying. A pinned payload keeps its producer's
/// allocation alive (via the `Arc`) until the flush that writes it —
/// that is the whole point: codec output and large caller windows flow
/// from producer to `pwrite` (or to the collective wire) with zero
/// staging memcpy.
#[derive(Debug, Clone)]
pub enum Payload {
    Owned(Vec<u8>),
    Pinned(Arc<[u8]>),
}

impl Payload {
    #[inline]
    pub fn as_slice(&self) -> &[u8] {
        match self {
            Payload::Owned(v) => v,
            Payload::Pinned(a) => a,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.as_slice().len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.as_slice().is_empty()
    }
}

/// Staging an owned buffer below this size copies it into the previous
/// extent when contiguous (tiny header/count rows keep coalescing);
/// larger owned buffers always stage by move. Pinned buffers never copy.
const APPEND_MAX: usize = 4 * 1024;

/// Staged positional writes, merged into contiguous runs at flush time.
///
/// Extents are recorded in stage order. A *run* is a maximal group of
/// extents whose byte ranges touch or overlap; flushing materializes each
/// run by replaying its extents **in stage order** into one buffer and
/// issuing a single `write_at` — so overlapping stages resolve exactly
/// like the equivalent sequence of direct `pwrite`s (last writer wins),
/// and the file bytes never depend on the flush schedule. Single-extent
/// runs (the common case for staged-by-move codec output) skip the
/// replay buffer entirely: the staged payload is handed onward as-is.
#[derive(Debug, Default)]
pub struct WriteAggregator {
    /// Staged extents in stage order.
    extents: Vec<(u64, Payload)>,
    staged_bytes: usize,
}

impl WriteAggregator {
    pub fn new() -> Self {
        WriteAggregator { extents: Vec::new(), staged_bytes: 0 }
    }

    /// Total staged payload bytes.
    pub fn staged_bytes(&self) -> usize {
        self.staged_bytes
    }

    pub fn is_empty(&self) -> bool {
        self.extents.is_empty()
    }

    /// Stage `data` at absolute `offset`, copying. Contiguous with the
    /// previously staged owned extent, the bytes append in place (the
    /// common pattern: header row, count rows, data window of one
    /// section in file order).
    pub fn stage(&mut self, offset: u64, data: &[u8]) {
        if data.is_empty() {
            return;
        }
        if let Some((o, Payload::Owned(buf))) = self.extents.last_mut() {
            if *o + buf.len() as u64 == offset {
                buf.extend_from_slice(data);
                self.staged_bytes += data.len();
                return;
            }
        }
        self.extents.push((offset, Payload::Owned(data.to_vec())));
        self.staged_bytes += data.len();
    }

    /// Stage an owned buffer by move — the zero-copy staging path for
    /// codec-materialized output. Small buffers contiguous with the
    /// previous owned extent still append (coalescing beats saving a
    /// tiny memcpy); everything else becomes its own extent, no copy.
    pub fn stage_owned(&mut self, offset: u64, data: Vec<u8>) {
        if data.is_empty() {
            return;
        }
        if data.len() <= APPEND_MAX {
            if let Some((o, Payload::Owned(buf))) = self.extents.last_mut() {
                if *o + buf.len() as u64 == offset {
                    self.staged_bytes += data.len();
                    buf.extend_from_slice(&data);
                    return;
                }
            }
        }
        self.staged_bytes += data.len();
        self.extents.push((offset, Payload::Owned(data)));
    }

    /// Stage a pinned shared buffer — never copied; the `Arc` keeps the
    /// bytes alive until the flush (or exchange) that consumes them.
    pub fn stage_pinned(&mut self, offset: u64, data: Arc<[u8]>) {
        if data.is_empty() {
            return;
        }
        self.staged_bytes += data.len();
        self.extents.push((offset, Payload::Pinned(data)));
    }

    /// Drain the raw staged extents in stage order, unmerged — the
    /// collective engine ships these to stripe owners, who merge on
    /// arrival (merging before the split would only be undone by the
    /// stripe boundaries).
    pub fn take_extents(&mut self) -> Vec<(u64, Payload)> {
        self.staged_bytes = 0;
        std::mem::take(&mut self.extents)
    }

    /// Drain the staged extents into merged contiguous runs, each run a
    /// single `(offset, payload)` ready for one `write_at`.
    pub fn take_runs(&mut self) -> Vec<(u64, Payload)> {
        let mut staged = std::mem::take(&mut self.extents);
        self.staged_bytes = 0;
        if staged.is_empty() {
            return Vec::new();
        }
        // Sort extent indices by offset (stable: equal offsets keep stage
        // order) to find runs; replay each run's members in stage order.
        let mut order: Vec<usize> = (0..staged.len()).collect();
        order.sort_by_key(|&i| staged[i].0);
        let mut out: Vec<(u64, Payload)> = Vec::new();
        let mut i = 0usize;
        while i < order.len() {
            let start = staged[order[i]].0;
            let mut end = start + staged[order[i]].1.len() as u64;
            let mut j = i + 1;
            while j < order.len() {
                let (o, b) = &staged[order[j]];
                if *o <= end {
                    end = end.max(*o + b.len() as u64);
                    j += 1;
                } else {
                    break;
                }
            }
            if j == i + 1 {
                // Single-extent run: move the staged payload out, no copy
                // (for pinned payloads this is the zero-copy promise).
                let (o, b) = &mut staged[order[i]];
                out.push((*o, std::mem::replace(b, Payload::Owned(Vec::new()))));
            } else {
                // Every byte of [start, end) is covered: a run only grows
                // while the next extent starts at or before its end.
                let mut buf = vec![0u8; (end - start) as usize];
                let mut members: Vec<usize> = order[i..j].to_vec();
                members.sort_unstable(); // back to stage order
                for m in members {
                    let (o, b) = &staged[m];
                    let rel = (*o - start) as usize;
                    buf[rel..rel + b.len()].copy_from_slice(b.as_slice());
                }
                out.push((start, Payload::Owned(buf)));
            }
            i = j;
        }
        out
    }

    /// Flush all staged extents to `file`, one `write_at` per merged run.
    /// Returns the number of writes issued.
    pub fn flush_to(&mut self, file: &ParallelFile) -> Result<u64> {
        let mut writes = 0u64;
        for (o, buf) in self.take_runs() {
            file.write_at(o, buf.as_slice())?;
            writes += 1;
        }
        Ok(writes)
    }
}

/// A buffered, offset-addressed writer over a borrowed [`ParallelFile`]:
/// [`WriteAggregator`] plus the file handle, a high-water auto-flush, and
/// a best-effort flush on drop. The staging/merge semantics (stage-order
/// replay, last-writer-wins on overlap) are the aggregator's.
pub struct WriteCoalescer<'a> {
    file: &'a ParallelFile,
    agg: WriteAggregator,
    /// Flush automatically when staged bytes reach this.
    pub high_water: usize,
    /// Number of `write_at` calls issued (observability for benches).
    pub flushes: u64,
}

impl<'a> WriteCoalescer<'a> {
    pub fn new(file: &'a ParallelFile) -> Self {
        WriteCoalescer { file, agg: WriteAggregator::new(), high_water: 8 * 1024 * 1024, flushes: 0 }
    }

    /// Stage `data` at absolute `offset`; auto-flush past the high-water
    /// mark. Equivalent to a direct `file.write_at` stream: the bytes on
    /// disk after `flush` match issuing the same writes directly in order.
    pub fn write_at(&mut self, offset: u64, data: &[u8]) -> Result<()> {
        self.agg.stage(offset, data);
        if self.agg.staged_bytes() >= self.high_water {
            self.flush()?;
        }
        Ok(())
    }

    /// Merge adjacent staged extents and issue the minimal set of writes.
    pub fn flush(&mut self) -> Result<()> {
        self.flushes += self.agg.flush_to(self.file)?;
        Ok(())
    }
}

impl Drop for WriteCoalescer<'_> {
    fn drop(&mut self) {
        // Callers should flush explicitly to observe errors in-band; a
        // failure here is recorded for `crate::io::take_drop_error` so it
        // is never silently swallowed (§A.6).
        if let Err(e) = self.flush() {
            crate::io::engine::record_drop_error(self.file.path(), e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::{Communicator, SerialComm};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("scda-ioagg");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    fn comm() -> SerialComm {
        let c = SerialComm::new();
        assert_eq!(c.size(), 1);
        c
    }

    #[test]
    fn contiguous_writes_merge_into_one() {
        let path = tmp("contig");
        let f = ParallelFile::create(&comm(), &path).unwrap();
        let mut w = WriteCoalescer::new(&f);
        for i in 0..100u64 {
            w.write_at(i * 10, &[i as u8; 10]).unwrap();
        }
        w.flush().unwrap();
        assert_eq!(w.flushes, 1);
        let data = f.read_vec(0, 1000).unwrap();
        for i in 0..100 {
            assert!(data[i * 10..(i + 1) * 10].iter().all(|&b| b == i as u8));
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn out_of_order_and_gapped_writes() {
        let path = tmp("gaps");
        let f = ParallelFile::create(&comm(), &path).unwrap();
        f.write_at(0, &[0u8; 64]).unwrap(); // pre-extend
        let mut w = WriteCoalescer::new(&f);
        w.write_at(40, b"dd").unwrap();
        w.write_at(0, b"aa").unwrap();
        w.write_at(2, b"bb").unwrap();
        w.write_at(20, b"cc").unwrap();
        w.flush().unwrap();
        assert_eq!(w.flushes, 3); // [0..4), [20..22), [40..42)
        let data = f.read_vec(0, 42).unwrap();
        assert_eq!(&data[0..4], b"aabb");
        assert_eq!(&data[20..22], b"cc");
        assert_eq!(&data[40..42], b"dd");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn overlapping_writes_latest_wins() {
        let path = tmp("overlap");
        let f = ParallelFile::create(&comm(), &path).unwrap();
        let mut w = WriteCoalescer::new(&f);
        w.write_at(0, b"xxxxxxxx").unwrap();
        w.write_at(2, b"YY").unwrap();
        w.flush().unwrap();
        let data = f.read_vec(0, 8).unwrap();
        assert_eq!(&data, b"xxYYxxxx");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn overlap_replay_is_stage_ordered_across_runs() {
        // Three mutually overlapping extents staged out of offset order:
        // the merged run must equal the direct pwrite sequence.
        let path = tmp("replay");
        let f = ParallelFile::create(&comm(), &path).unwrap();
        let mut w = WriteCoalescer::new(&f);
        w.write_at(4, b"BBBB").unwrap();
        w.write_at(0, b"AAAAAA").unwrap(); // overwrites 4..6
        w.write_at(2, b"CC").unwrap(); // overwrites 2..4
        w.flush().unwrap();
        assert_eq!(w.flushes, 1);
        let data = f.read_vec(0, 8).unwrap();
        assert_eq!(&data, b"AACCAABB");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn high_water_triggers_flush() {
        let path = tmp("hiwater");
        let f = ParallelFile::create(&comm(), &path).unwrap();
        let mut w = WriteCoalescer::new(&f);
        w.high_water = 100;
        w.write_at(0, &[1u8; 60]).unwrap();
        assert_eq!(w.flushes, 0);
        w.write_at(60, &[2u8; 60]).unwrap();
        assert!(w.flushes >= 1); // crossed high water
        w.flush().unwrap();
        assert_eq!(f.read_vec(0, 120).unwrap().len(), 120);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn take_runs_drains_and_merges() {
        let mut a = WriteAggregator::new();
        assert!(a.is_empty());
        a.stage(10, b"cc");
        a.stage(0, b"aa");
        a.stage(2, b"bb");
        assert_eq!(a.staged_bytes(), 6);
        let runs = a.take_runs();
        assert!(a.is_empty());
        assert_eq!(a.staged_bytes(), 0);
        assert_eq!(runs.len(), 2);
        assert_eq!((runs[0].0, runs[0].1.as_slice()), (0, &b"aabb"[..]));
        assert_eq!((runs[1].0, runs[1].1.as_slice()), (10, &b"cc"[..]));
    }

    #[test]
    fn empty_stage_is_a_no_op() {
        let mut a = WriteAggregator::new();
        a.stage(5, b"");
        a.stage_owned(6, Vec::new());
        a.stage_pinned(7, Vec::new().into());
        assert!(a.is_empty());
        assert!(a.take_runs().is_empty());
    }

    #[test]
    fn owned_staging_moves_large_buffers_without_copy() {
        let mut a = WriteAggregator::new();
        let big: Vec<u8> = (0..2 * APPEND_MAX).map(|i| (i % 251) as u8).collect();
        let expect = big.clone();
        let ptr = big.as_ptr();
        a.stage_owned(100, big);
        let runs = a.take_runs();
        assert_eq!(runs.len(), 1);
        let Payload::Owned(out) = &runs[0].1 else { panic!("owned run") };
        assert_eq!(out.as_ptr(), ptr, "buffer moved, never copied");
        assert_eq!(out, &expect);
    }

    #[test]
    fn small_owned_buffers_still_coalesce() {
        let mut a = WriteAggregator::new();
        a.stage(0, b"head");
        a.stage_owned(4, b"tail".to_vec());
        let runs = a.take_runs();
        assert_eq!(runs.len(), 1, "contiguous small owned write appended");
        assert_eq!(runs[0].1.as_slice(), b"headtail");
    }

    #[test]
    fn pinned_staging_shares_and_merges_with_neighbors() {
        let shared: std::sync::Arc<[u8]> = b"SHARED".to_vec().into();
        let mut a = WriteAggregator::new();
        a.stage(0, b"<<");
        a.stage_pinned(2, std::sync::Arc::clone(&shared));
        a.stage(8, b">>");
        assert_eq!(a.staged_bytes(), 10);
        let runs = a.take_runs();
        assert_eq!(runs.len(), 1);
        assert_eq!(runs[0].1.as_slice(), b"<<SHARED>>");
        // A lone pinned extent comes back out as the same allocation.
        a.stage_pinned(50, std::sync::Arc::clone(&shared));
        let runs = a.take_runs();
        let Payload::Pinned(p) = &runs[0].1 else { panic!("pinned run") };
        assert!(std::sync::Arc::ptr_eq(p, &shared));
    }
}
