//! Deterministic fault plane: seedable fault plans injected at the
//! positional-I/O layer ([`crate::par::ParallelFile`]).
//!
//! The plan generalizes the original `inject_write_failure` countdown
//! hook into the fault vocabulary the crash-consistency subsystem needs:
//!
//! * **transient-then-succeed** — the triggering operation fails with a
//!   retryable (`EINTR`-shaped) error a fixed number of times, then
//!   succeeds; the engines absorb these with bounded backoff
//!   ([`retry_transient`]) and the caller never sees them.
//! * **persistent** — the triggering operation and every one after it
//!   fails; surfaces collectively at `flush`/`section_end`/`close`.
//! * **torn write** — the triggering write puts only its first `keep`
//!   bytes on disk and then fails, modeling a short `pwrite`.
//! * **crash point** — a torn write followed by a process-local "power
//!   cut": the file is truncated at exactly the torn byte and every
//!   later operation on the handle fails. What remains on disk is the
//!   byte prefix a real crash would leave, which is what
//!   `Archive::recover` / `scda recover` is tested against.
//!
//! Plans are deterministic: the trigger is a per-handle operation
//! countdown (exactly the old hook's semantics), and seeded plans derive
//! their parameters from a tiny xorshift generator so a soak sweep can
//! replay any failure by seed. Per-rank faults either target the handle
//! of one rank ([`FaultPlan::on_rank`]) or are simply armed on a single
//! rank's handle — the hook is per handle, never global.

use crate::error::{Result, ScdaError};
use std::time::Duration;

/// What happens when a [`FaultPlan`]'s countdown reaches its trigger.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Fail the triggering operation (and the next `times - 1`) with a
    /// retryable `EINTR` error, then let everything succeed.
    Transient { times: u32 },
    /// Fail the triggering operation and every one after it.
    Persistent,
    /// Write only the first `keep` bytes of the triggering write (clamped
    /// to the buffer), then fail it and every write after it.
    Torn { keep: u64 },
    /// [`FaultKind::Torn`] followed by a process-local power cut: the
    /// file is truncated at exactly the torn byte, and every later
    /// operation on the handle fails.
    Crash { keep: u64 },
}

/// The operation class a plan counts and fires on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultOp {
    Write,
    Read,
}

/// A deterministic fault plan: fire [`FaultKind`] after `after` more
/// successful operations of class `op` on the armed handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultPlan {
    pub after: u64,
    pub kind: FaultKind,
    pub op: FaultOp,
    /// Restrict the plan to the handle of one rank (`None` = any handle
    /// the plan is armed on). Lets a soak driver hand the *same* plan
    /// value to every rank and still fault exactly one of them.
    pub rank: Option<usize>,
}

impl FaultPlan {
    /// Retryable `EINTR` failures for the `after+1`-th write and the
    /// `times - 1` attempts after it, then success.
    pub fn transient(after: u64, times: u32) -> Self {
        FaultPlan { after, kind: FaultKind::Transient { times }, op: FaultOp::Write, rank: None }
    }

    /// The original `inject_write_failure` semantics: `after` more writes
    /// succeed, every write after that fails.
    pub fn persistent(after: u64) -> Self {
        FaultPlan { after, kind: FaultKind::Persistent, op: FaultOp::Write, rank: None }
    }

    /// A short write: the trigger write keeps only `keep` bytes.
    pub fn torn(after: u64, keep: u64) -> Self {
        FaultPlan { after, kind: FaultKind::Torn { keep }, op: FaultOp::Write, rank: None }
    }

    /// A torn write plus power cut truncating the file at the torn byte.
    pub fn crash(after: u64, keep: u64) -> Self {
        FaultPlan { after, kind: FaultKind::Crash { keep }, op: FaultOp::Write, rank: None }
    }

    /// Count and fire on reads instead of writes (torn/crash kinds
    /// degrade to persistent read errors: reads cannot tear the file).
    pub fn on_reads(mut self) -> Self {
        self.op = FaultOp::Read;
        self
    }

    /// Fire only on the handle of `rank`; other ranks' handles ignore
    /// the plan entirely (no ticks consumed).
    pub fn on_rank(mut self, rank: usize) -> Self {
        self.rank = Some(rank);
        self
    }

    /// Derive a crash plan from a seed: trigger write in
    /// `[0, max_trigger)`, torn byte count in `[0, 4096)`. Two calls with
    /// the same arguments produce the same plan.
    pub fn seeded_crash(seed: u64, max_trigger: u64) -> Self {
        let mut rng = FaultRng::new(seed);
        let after = rng.below(max_trigger.max(1));
        let keep = rng.below(4096);
        FaultPlan::crash(after, keep)
    }
}

/// The per-handle armed state of a plan (lives on `ParallelFile`).
#[derive(Debug)]
pub struct FaultState {
    plan: FaultPlan,
    remaining: u64,
    transient_left: u32,
    /// A persistent/torn/crash fault already fired: every later matching
    /// operation fails.
    tripped: bool,
}

impl FaultState {
    pub fn new(plan: FaultPlan) -> Self {
        let transient_left = match plan.kind {
            FaultKind::Transient { times } => times,
            _ => 0,
        };
        FaultState { plan, remaining: plan.after, transient_left, tripped: false }
    }

    /// Consult the plan for one operation of class `op` on `rank`'s
    /// handle writing (or reading) at `offset`. Returns:
    ///
    /// * `Ok(None)` — no fault; perform the operation normally;
    /// * `Ok(Some((keep, cut)))` — torn write: the caller must write only
    ///   the first `keep` bytes, truncate the file to `offset + keep` if
    ///   `cut`, and return [`injected_error`] with `torn = true`;
    /// * `Err(e)` — the operation fails with `e` outright.
    ///
    /// Exhausted transient plans report themselves via `Ok(None)` after
    /// their last failure; the caller may drop the state then (checked
    /// with [`FaultState::exhausted`]).
    pub fn check(&mut self, op: FaultOp, rank: usize, offset: u64, len: u64) -> Result<Option<(u64, bool)>> {
        if self.plan.op != op {
            return Ok(None);
        }
        if self.plan.rank.is_some_and(|r| r != rank) {
            return Ok(None);
        }
        if self.tripped {
            return Err(injected_error(self.plan.kind, op, offset, len, false));
        }
        if self.remaining > 0 {
            self.remaining -= 1;
            return Ok(None);
        }
        match self.plan.kind {
            FaultKind::Transient { .. } => {
                if self.transient_left > 0 {
                    self.transient_left -= 1;
                    Err(transient_error(op, offset, len))
                } else {
                    // Exhausted: the operation (a retry) now succeeds.
                    Ok(None)
                }
            }
            FaultKind::Persistent => {
                self.tripped = true;
                Err(injected_error(self.plan.kind, op, offset, len, false))
            }
            FaultKind::Torn { keep } => {
                self.tripped = true;
                if op == FaultOp::Read {
                    return Err(injected_error(self.plan.kind, op, offset, len, false));
                }
                Ok(Some((keep.min(len), false)))
            }
            FaultKind::Crash { keep } => {
                self.tripped = true;
                if op == FaultOp::Read {
                    return Err(injected_error(self.plan.kind, op, offset, len, false));
                }
                Ok(Some((keep.min(len), true)))
            }
        }
    }

    /// True once a transient plan has delivered all its failures (the
    /// state can be dropped — the handle is healthy again).
    pub fn exhausted(&self) -> bool {
        matches!(self.plan.kind, FaultKind::Transient { .. }) && self.transient_left == 0 && self.remaining == 0
    }
}

/// `errno` of the injected transient failures: `EINTR`, the canonical
/// retry-me error (its `ScdaError` code is therefore `2000 + 4`).
pub const TRANSIENT_ERRNO: i32 = 4;

fn transient_error(op: FaultOp, offset: u64, len: u64) -> ScdaError {
    let verb = if op == FaultOp::Write { "writing" } else { "reading" };
    ScdaError::io(
        std::io::Error::from_raw_os_error(TRANSIENT_ERRNO),
        format!("injected transient fault {verb} {len} bytes at offset {offset}"),
    )
}

/// The error a fired (non-transient) fault reports. Indistinguishable
/// from a real `pwrite`/`pread` failure to everything above the file
/// layer.
pub fn injected_error(kind: FaultKind, op: FaultOp, offset: u64, len: u64, torn: bool) -> ScdaError {
    let verb = if op == FaultOp::Write { "writing" } else { "reading" };
    let what = match (kind, torn) {
        (FaultKind::Crash { .. }, _) => "simulated power cut",
        (FaultKind::Torn { .. }, true) => "injected torn write",
        _ => "injected write failure",
    };
    ScdaError::io(std::io::Error::other(what), format!("{verb} {len} bytes at offset {offset}"))
}

/// Bounded-backoff retry of transient I/O faults — the engines wrap
/// every positional read/write in this, so `EINTR`-shaped errors
/// (injected or real) are absorbed up to [`RETRY_LIMIT`] times and never
/// reach the API surface. Anything non-transient passes through on the
/// first failure.
pub fn retry_transient<T>(mut f: impl FnMut() -> Result<T>) -> Result<T> {
    let mut attempt = 0u32;
    loop {
        match f() {
            Err(e) if e.is_transient_io() && attempt < RETRY_LIMIT => {
                attempt += 1;
                // Deterministic bounded backoff: 100 µs, 200, 400, 800.
                std::thread::sleep(Duration::from_micros(50u64 << attempt));
            }
            other => return other,
        }
    }
}

/// Retries per operation before a transient fault is treated as
/// persistent.
pub const RETRY_LIMIT: u32 = 4;

/// Tiny deterministic xorshift64* generator for seeded plans — fault
/// schedules must replay exactly, so no OS entropy is involved.
#[derive(Debug, Clone)]
pub struct FaultRng(u64);

impl FaultRng {
    pub fn new(seed: u64) -> Self {
        FaultRng(seed.wrapping_mul(2685821657736338717).max(1))
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform-ish value in `[0, n)` (`n > 0`).
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transient_plan_fails_then_succeeds() {
        let mut st = FaultState::new(FaultPlan::transient(1, 2));
        assert!(st.check(FaultOp::Write, 0, 0, 8).unwrap().is_none()); // countdown
        assert!(st.check(FaultOp::Write, 0, 8, 8).is_err());
        assert!(st.check(FaultOp::Write, 0, 8, 8).is_err());
        assert!(st.check(FaultOp::Write, 0, 8, 8).unwrap().is_none());
        assert!(st.exhausted());
        // Transient errors are recognizably retryable.
        let e = FaultState::new(FaultPlan::transient(0, 1)).check(FaultOp::Write, 0, 0, 1).unwrap_err();
        assert!(e.is_transient_io());
        assert_eq!(e.code(), 2000 + TRANSIENT_ERRNO);
    }

    #[test]
    fn persistent_plan_trips_and_stays_tripped() {
        let mut st = FaultState::new(FaultPlan::persistent(0));
        assert!(st.check(FaultOp::Write, 0, 0, 4).is_err());
        assert!(st.check(FaultOp::Write, 0, 4, 4).is_err());
        assert!(!st.check(FaultOp::Write, 0, 0, 4).unwrap_err().is_transient_io());
        // Reads are not the planned op: untouched.
        assert!(st.check(FaultOp::Read, 0, 0, 4).unwrap().is_none());
    }

    #[test]
    fn torn_and_crash_report_keep_and_cut() {
        let mut st = FaultState::new(FaultPlan::torn(0, 3));
        assert_eq!(st.check(FaultOp::Write, 0, 10, 8).unwrap(), Some((3, false)));
        assert!(st.check(FaultOp::Write, 0, 18, 8).is_err());
        let mut st = FaultState::new(FaultPlan::crash(0, 100));
        // keep clamps to the buffer length.
        assert_eq!(st.check(FaultOp::Write, 0, 10, 8).unwrap(), Some((8, true)));
    }

    #[test]
    fn per_rank_plans_ignore_other_ranks() {
        let mut st = FaultState::new(FaultPlan::persistent(0).on_rank(2));
        assert!(st.check(FaultOp::Write, 0, 0, 4).unwrap().is_none());
        assert!(st.check(FaultOp::Write, 1, 0, 4).unwrap().is_none());
        assert!(st.check(FaultOp::Write, 2, 0, 4).is_err());
    }

    #[test]
    fn seeded_plans_replay() {
        let a = FaultPlan::seeded_crash(42, 1000);
        let b = FaultPlan::seeded_crash(42, 1000);
        assert_eq!(a, b);
        assert!(a.after < 1000);
        let c = FaultPlan::seeded_crash(43, 1000);
        assert!(a != c || FaultPlan::seeded_crash(44, 1000) != a);
    }

    #[test]
    fn retry_absorbs_bounded_transients() {
        let mut st = FaultState::new(FaultPlan::transient(0, 3));
        let out = retry_transient(|| match st.check(FaultOp::Write, 0, 0, 1)? {
            None => Ok(7u32),
            Some(_) => unreachable!(),
        });
        assert_eq!(out.unwrap(), 7);
        // More transient failures than the retry budget: the error escapes.
        let mut st = FaultState::new(FaultPlan::transient(0, RETRY_LIMIT + 1));
        let out: Result<u32> = retry_transient(|| st.check(FaultOp::Write, 0, 0, 1).map(|_| 7));
        assert!(out.unwrap_err().is_transient_io());
    }
}
