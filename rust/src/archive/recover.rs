//! Torn-tail archive recovery: make a crashed append readable again.
//!
//! A crash (or torn write) during an archive append damages exactly the
//! *tail* of the file: sections are written front-to-back, inline
//! sections are unpadded, and the catalog/footer-index trailer is always
//! the last thing written ([`crate::archive::index`]). Everything before
//! the torn byte is a verify-clean prefix of ordinary sections — the
//! datasets committed before the crash. Recovery therefore:
//!
//! 1. walks the file with the *same* strict walker `scda verify` uses
//!    ([`crate::api::verified_prefix_file`]), finding the last offset up
//!    to which every section is byte-valid;
//! 2. drops trailing sections that cannot stand on their own: stale
//!    trailer sections (`scda:catalog` / `scda:index` — they describe a
//!    file that no longer exists past the tear) and a dangling
//!    compression-convention leader (an `I "B/A compressed scda 00"` or
//!    `A "V compressed scda 00"` section whose trailing partner was
//!    torn off — half a logical section is unreadable);
//! 3. truncates the file after the last surviving section, rescans the
//!    surviving sections into a fresh catalog, and appends a consistent
//!    catalog + footer-index trailer;
//! 4. re-verifies the result end to end — recovery *never* reports
//!    success on a file `scda verify` would reject.
//!
//! The result contains exactly the datasets whose sections were fully
//! committed before the crash, and restores by name on any rank count
//! (partition independence is the format's, not the catalog's). A file
//! that is already intact — verify-clean with a consistent trailer — is
//! reported [`RecoveryAction::Intact`] and left untouched.
//!
//! Recovery is a local filesystem repair, not a collective call: run it
//! from one process (the `scda recover` CLI) before reopening the
//! archive in parallel.

use std::path::Path;
use std::sync::Arc;

use crate::api::query::{verified_prefix_file, RawSection};
use crate::api::ScdaFile;
use crate::archive::dataset::render_catalog;
use crate::archive::index::{self, encode_index_payload, CATALOG_USER, INDEX_USER};
use crate::error::{corrupt, Result, ScdaError};
use crate::format::limits::{CONV_ARRAY, CONV_BLOCK, CONV_VARRAY, FILE_HEADER_BYTES};
use crate::format::padding::{pad_data, LineStyle};
use crate::format::section::{encode_section_header, SectionKind, SectionMeta};
use crate::obs::trace::{SpanKind, Tracer};
use crate::par::SerialComm;

/// What [`recover`] did to the file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecoveryAction {
    /// The file was already verify-clean with a consistent trailer; it
    /// was not modified.
    Intact,
    /// The torn tail was truncated and a fresh trailer appended.
    Rebuilt,
}

/// The outcome of a successful [`recover`] run.
#[derive(Debug, Clone)]
pub struct RecoveryReport {
    /// File length before recovery.
    pub original_len: u64,
    /// File length after recovery (trailer included).
    pub recovered_len: u64,
    /// Bytes of torn tail dropped (before the new trailer was appended).
    pub truncated_bytes: u64,
    /// Names of the surviving datasets, in file order.
    pub datasets: Vec<String>,
    pub action: RecoveryAction,
}

/// True for trailing sections recovery must drop: stale trailer
/// sections, and a compression-convention leader whose partner section
/// is gone (conventions 8/9/10 pair a leading `I`/`A` magic section
/// with a trailing data section — half a pair is unreadable).
fn must_drop_from_tail(s: &RawSection) -> bool {
    s.user == CATALOG_USER
        || s.user == INDEX_USER
        || (s.kind == SectionKind::Inline && (s.user == CONV_BLOCK || s.user == CONV_ARRAY))
        || (s.kind == SectionKind::Array && s.user == CONV_VARRAY)
}

/// Whether an intact file's trailer is consistent: the footer index
/// loads, and its catalog entries tile the section region exactly — the
/// shape `Archive::finish` always writes.
fn trailer_consistent(path: &Path) -> bool {
    let Ok(mut file) = ScdaFile::open(SerialComm::new(), path) else { return false };
    let Ok(Some(loaded)) = index::load(&mut file) else { return false };
    let mut at = FILE_HEADER_BYTES as u64;
    for d in &loaded.datasets {
        if d.offset != at {
            return false;
        }
        at = match at.checked_add(d.byte_len) {
            Some(v) => v,
            None => return false,
        };
    }
    at == loaded.catalog_off
}

/// Recover an archive with a torn tail; see the module docs for the
/// algorithm and guarantees. Returns the report on success; errors are
/// [`crate::error::corrupt`]-coded when the file is damaged beyond the
/// 128-byte header (no valid prefix to salvage) or when the rebuilt
/// file fails re-verification.
pub fn recover(path: impl AsRef<Path>) -> Result<RecoveryReport> {
    recover_with(path, None)
}

/// [`recover`] with an optional span recorder: the walk, rebuild and
/// re-verify phases each record one span (`recover_walk`,
/// `recover_rebuild`, `recover_verify`) so a recovery run shows up on
/// the same timeline as the workload around it. `tracer = None` is
/// exactly [`recover`].
pub fn recover_with(
    path: impl AsRef<Path>,
    tracer: Option<&Arc<Tracer>>,
) -> Result<RecoveryReport> {
    let path = path.as_ref();
    let mut walk_span = tracer.map(|t| Tracer::start(t, SpanKind::RecoverWalk));
    let prefix = verified_prefix_file(path)?;
    let original_len = prefix
        .sections
        .last()
        .map(|s| s.end)
        .max(Some(prefix.good_end))
        .unwrap_or(FILE_HEADER_BYTES as u64);
    let file_len = std::fs::metadata(path).map_err(|e| ScdaError::io(e, "stat"))?.len();
    debug_assert!(original_len <= file_len);
    if let Some(s) = walk_span.as_mut() {
        s.set_bytes(original_len);
    }
    drop(walk_span);

    // Intact means: verify-clean, and either no trailer at all (a plain
    // scda file is not damaged — recovery repairs, it does not convert)
    // or a trailer whose catalog tiles the sections it claims.
    if prefix.error.is_none() {
        let has_trailer =
            prefix.sections.iter().any(|s| s.user == CATALOG_USER || s.user == INDEX_USER);
        if !has_trailer || trailer_consistent(path) {
            let mut file = ScdaFile::open(SerialComm::new(), path)?;
            let datasets = match index::load(&mut file)? {
                Some(l) => l.datasets,
                None => index::scan(&mut file)?,
            };
            return Ok(RecoveryReport {
                original_len: file_len,
                recovered_len: file_len,
                truncated_bytes: 0,
                datasets: datasets.into_iter().map(|d| d.name).collect(),
                action: RecoveryAction::Intact,
            });
        }
    }

    // Drop what cannot stand on its own at the tail, then truncate.
    let mut rebuild_span = tracer.map(|t| Tracer::start(t, SpanKind::RecoverRebuild));
    let mut sections = prefix.sections;
    while sections.last().is_some_and(must_drop_from_tail) {
        sections.pop();
    }
    let good_end = sections.last().map(|s| s.end).unwrap_or(FILE_HEADER_BYTES as u64);
    let file = std::fs::OpenOptions::new()
        .write(true)
        .open(path)
        .map_err(|e| ScdaError::io(e, format!("opening {} for recovery", path.display())))?;
    file.set_len(good_end).map_err(|e| ScdaError::io(e, "truncating the torn tail"))?;

    // Rescan the surviving sections into a fresh catalog. The prefix is
    // verify-clean up to `good_end`, so the scan sees only whole
    // sections; convention pairs regroup into logical datasets exactly
    // as the original writer's catalog recorded them (the advisory
    // precondition marker is not recoverable from headers — frames
    // still self-describe).
    let mut sfile = ScdaFile::open(SerialComm::new(), path)?;
    let entries = index::scan(&mut sfile)?;
    drop(sfile);

    // Render the trailer by hand (there is no write-mode reopen for an
    // existing scda file): the catalog block section, then the 96-byte
    // footer index — byte-identical to what `Archive::finish` writes.
    let text = render_catalog(&entries);
    let meta = SectionMeta::block(CATALOG_USER, text.len() as u128);
    let mut trailer = encode_section_header(&meta, None, LineStyle::Unix)?;
    trailer.extend_from_slice(&text);
    pad_data(&mut trailer, text.len() as u128, text.last().copied(), LineStyle::Unix);
    let index_meta = SectionMeta::inline(INDEX_USER);
    trailer.extend_from_slice(&encode_section_header(&index_meta, None, LineStyle::Unix)?);
    trailer.extend_from_slice(&encode_index_payload(good_end));
    {
        use std::os::unix::fs::FileExt;
        file.write_all_at(&trailer, good_end)
            .map_err(|e| ScdaError::io(e, "writing the recovered trailer"))?;
        file.sync_all().map_err(|e| ScdaError::io(e, "syncing the recovered file"))?;
    }
    if let Some(s) = rebuild_span.as_mut() {
        s.set_bytes(file_len - good_end);
    }
    drop(rebuild_span);

    // The gate: a recovered file must pass the same strict verification
    // as any other scda file, or recovery itself failed.
    let mut verify_span = tracer.map(|t| Tracer::start(t, SpanKind::RecoverVerify));
    if let Some(s) = verify_span.as_mut() {
        s.set_bytes(good_end + trailer.len() as u64);
    }
    crate::api::verify_file(path).map_err(|e| {
        ScdaError::corrupt(
            corrupt::TRUNCATED,
            format!("recovered file fails verification ({e}); the archive is damaged beyond the tail"),
        )
    })?;

    Ok(RecoveryReport {
        original_len: file_len,
        recovered_len: good_end + trailer.len() as u64,
        truncated_bytes: file_len - good_end,
        datasets: entries.into_iter().map(|d| d.name).collect(),
        action: RecoveryAction::Rebuilt,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::DataSrc;
    use crate::archive::Archive;
    use crate::par::Partition;
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("scda-recover");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}.scda", std::process::id()))
    }

    fn build(path: &Path) -> Vec<u8> {
        let part = Partition::uniform(1, 16);
        let data: Vec<u8> = (0..16 * 8u32).map(|i| (i % 251) as u8).collect();
        let mut ar = Archive::create(SerialComm::new(), path, b"recover-test").unwrap();
        ar.write_array("a", DataSrc::Contiguous(&data), &part, 8, false).unwrap();
        ar.write_block_from("b", 0, Some(b"hello recovery"), 14, false).unwrap();
        ar.finish().unwrap();
        data
    }

    #[test]
    fn intact_archive_is_left_untouched() {
        let path = tmp("intact");
        build(&path);
        let before = std::fs::read(&path).unwrap();
        let r = recover(&path).unwrap();
        assert_eq!(r.action, RecoveryAction::Intact);
        assert_eq!(r.truncated_bytes, 0);
        assert_eq!(r.datasets, ["a", "b"]);
        assert_eq!(std::fs::read(&path).unwrap(), before, "intact file unmodified");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn torn_trailer_is_rebuilt_with_all_datasets() {
        let path = tmp("torn-trailer");
        let data = build(&path);
        let len = std::fs::metadata(&path).unwrap().len();
        // Tear off the last 40 bytes: the index section (96 B) is torn.
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(len - 40).unwrap();
        drop(f);
        let r = recover(&path).unwrap();
        assert_eq!(r.action, RecoveryAction::Rebuilt);
        assert_eq!(r.datasets, ["a", "b"]);
        crate::api::verify_file(&path).unwrap();
        let mut ar = Archive::open(SerialComm::new(), &path).unwrap();
        assert!(ar.is_indexed());
        let part = Partition::uniform(1, 16);
        assert_eq!(ar.read_array("a", &part, 8).unwrap(), data);
        ar.close().unwrap();
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn tear_inside_a_dataset_salvages_the_prefix() {
        let path = tmp("torn-data");
        build(&path);
        // Find dataset "b"'s offset and tear inside it: only "a" survives.
        let b_off = {
            let mut ar = Archive::open(SerialComm::new(), &path).unwrap();
            let off = ar.get("b").unwrap().offset;
            ar.close().unwrap();
            off
        };
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(b_off + 70).unwrap();
        drop(f);
        let r = recover(&path).unwrap();
        assert_eq!(r.action, RecoveryAction::Rebuilt);
        assert_eq!(r.datasets, ["a"]);
        crate::api::verify_file(&path).unwrap();
        // Recovery is idempotent: a second run reports Intact.
        let again = recover(&path).unwrap();
        assert_eq!(again.action, RecoveryAction::Intact);
        assert_eq!(again.datasets, ["a"]);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn file_shorter_than_header_is_unrecoverable() {
        let path = tmp("stub");
        std::fs::write(&path, b"scda").unwrap();
        let err = recover(&path).unwrap_err();
        assert_eq!(err.code(), 1000 + corrupt::TRUNCATED);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn header_only_file_is_plain_and_intact() {
        let path = tmp("empty");
        build(&path);
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(FILE_HEADER_BYTES as u64).unwrap();
        drop(f);
        // Truncating to the bare header leaves a verify-clean plain scda
        // file with zero sections: nothing is torn, so recovery reports
        // it intact rather than appending a trailer.
        let r = recover(&path).unwrap();
        assert_eq!(r.action, RecoveryAction::Intact);
        assert!(r.datasets.is_empty());
        let ar = Archive::open(SerialComm::new(), &path).unwrap();
        assert!(ar.datasets().is_empty());
        ar.close().unwrap();
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn tear_inside_the_first_dataset_rebuilds_empty() {
        let path = tmp("first-torn");
        build(&path);
        let f = std::fs::OpenOptions::new().write(true).open(&path).unwrap();
        f.set_len(FILE_HEADER_BYTES as u64 + 17).unwrap();
        drop(f);
        let r = recover(&path).unwrap();
        assert_eq!(r.action, RecoveryAction::Rebuilt);
        assert!(r.datasets.is_empty());
        crate::api::verify_file(&path).unwrap();
        let ar = Archive::open(SerialComm::new(), &path).unwrap();
        assert!(ar.is_indexed());
        assert!(ar.datasets().is_empty());
        ar.close().unwrap();
        std::fs::remove_file(&path).unwrap();
    }
}
