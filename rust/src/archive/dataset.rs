//! Named, typed datasets: the descriptor each catalog entry carries and
//! the ASCII serialization of the catalog itself.
//!
//! A *dataset* is one logical scda section (a compression-convention pair
//! counts as one dataset) addressed by a name instead of a position. The
//! name is exactly the section's user string, so the catalog never says
//! anything the sections don't already say — it only says it in one
//! place. The catalog text is plain ASCII, line-oriented like the
//! checkpoint manifest, so a catalog-bearing file is ASCII wherever its
//! data is ASCII and any scda reader can inspect the catalog with
//! `scda cat`.

use crate::error::{corrupt, usage, Result, ScdaError};
use crate::format::limits::USER_STRING_MAX;
use crate::format::section::SectionKind;

/// The logical section type of a dataset (the letter a reader sees after
/// convention resolution).
pub type DatasetKind = SectionKind;

/// One catalog entry: everything needed to seek to the dataset and read
/// it without scanning the sections before it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DatasetInfo {
    /// The dataset name == the section's user string (validated by
    /// [`validate_name`]).
    pub name: String,
    /// Logical section kind (`A` for an encoded fixed-size array even
    /// though its raw carrier is a `V` pair).
    pub kind: DatasetKind,
    /// Absolute offset of the first raw section byte.
    pub offset: u64,
    /// Total file bytes of the logical section (both raw sections of a
    /// convention pair).
    pub byte_len: u64,
    /// Element count (`N`); 0 for inline/block datasets.
    pub elem_count: u64,
    /// Bytes per element for arrays (uncompressed when encoded), total
    /// block bytes for blocks; 0 for inline/varray.
    pub elem_size: u64,
    /// Whether the dataset was written with the §3 compression
    /// convention.
    pub encoded: bool,
    /// The shuffle/delta preconditioning stage the dataset's encoded
    /// frames carry (SPEC §5.4), if any. Advisory: the frames are
    /// self-describing, so this only saves tools a data read.
    pub precondition: Option<crate::codec::Precond>,
}

impl DatasetInfo {
    /// Validate that `[first, first + count)` lies inside this dataset's
    /// element range — the cheap catalog-side gate of
    /// [`crate::archive::Archive::read_range`] (the section header,
    /// which stays authoritative, re-checks on the seeked read).
    pub fn check_range(&self, first: u64, count: u64) -> Result<()> {
        let end = first.checked_add(count).ok_or_else(|| {
            ScdaError::usage(usage::BAD_RANGE, format!("element range {first}+{count} overflows"))
        })?;
        if end > self.elem_count {
            return Err(ScdaError::usage(
                usage::BAD_RANGE,
                format!(
                    "element range [{first}, {end}) outside dataset {:?}'s {} elements",
                    self.name, self.elem_count
                ),
            ));
        }
        Ok(())
    }
}

/// Names the archive layer claims for its own sections; user datasets
/// cannot use them.
pub const RESERVED_NAMES: [&str; 2] = ["scda:catalog", "scda:index"];

/// Validate a dataset name: 1..=58 bytes (the user-string limit) of
/// printable non-space ASCII, not one of the reserved archive names.
/// Spaces are excluded because the catalog is token-oriented ASCII text.
pub fn validate_name(name: &str) -> Result<()> {
    if name.is_empty() || name.len() > USER_STRING_MAX {
        return Err(ScdaError::usage(
            usage::BAD_DATASET_NAME,
            format!("dataset name must be 1..={USER_STRING_MAX} bytes, got {}", name.len()),
        ));
    }
    if !name.bytes().all(|b| (0x21..=0x7e).contains(&b)) {
        return Err(ScdaError::usage(
            usage::BAD_DATASET_NAME,
            format!("dataset name {name:?} contains whitespace or non-printable-ASCII bytes"),
        ));
    }
    if RESERVED_NAMES.contains(&name) {
        return Err(ScdaError::usage(
            usage::BAD_DATASET_NAME,
            format!("dataset name {name:?} is reserved for the archive layer"),
        ));
    }
    Ok(())
}

fn kind_letter(kind: DatasetKind) -> char {
    kind.letter() as char
}

fn kind_from_str(s: &str) -> Option<DatasetKind> {
    let [b] = s.as_bytes() else { return None };
    SectionKind::from_letter(*b)
}

/// Render the catalog text: a version line, an entry count (integrity
/// check), then one `dataset` line per entry in file order. Every field
/// is a pure function of collective inputs, so the text — and therefore
/// the catalog section's bytes — is identical on every rank and at every
/// writer rank count.
pub fn render_catalog(entries: &[DatasetInfo]) -> Vec<u8> {
    let mut s = String::new();
    s.push_str("scda-catalog 1\n");
    s.push_str(&format!("count {}\n", entries.len()));
    for e in entries {
        s.push_str(&format!(
            "dataset name={} kind={} off={} len={} n={} e={} z={}",
            e.name,
            kind_letter(e.kind),
            e.offset,
            e.byte_len,
            e.elem_count,
            e.elem_size,
            e.encoded as u8
        ));
        // Optional key, omitted when absent: catalogs without it parse
        // under this reader and catalogs with it parse under older
        // readers (unknown keys are skipped).
        if let Some(p) = e.precondition {
            s.push_str(&format!(" p={p}"));
        }
        s.push('\n');
    }
    s.into_bytes()
}

fn bad(msg: impl Into<String>) -> ScdaError {
    ScdaError::corrupt(corrupt::BAD_CATALOG, msg)
}

/// Parse a catalog rendered by [`render_catalog`]. Any malformed line,
/// missing field, or count mismatch is a [`corrupt::BAD_CATALOG`] error
/// (the catalog is authoritative once the footer index names it —
/// disagreement means the file is damaged, never a panic).
pub fn parse_catalog(bytes: &[u8]) -> Result<Vec<DatasetInfo>> {
    let text = std::str::from_utf8(bytes).map_err(|_| bad("catalog is not UTF-8"))?;
    let mut lines = text.lines();
    let head = lines.next().unwrap_or("");
    if head != "scda-catalog 1" {
        return Err(bad(format!("bad catalog head {head:?}")));
    }
    let count_line = lines.next().unwrap_or("");
    let declared: usize = count_line
        .strip_prefix("count ")
        .and_then(|v| v.parse().ok())
        .ok_or_else(|| bad(format!("bad catalog count line {count_line:?}")))?;
    let mut entries = Vec::with_capacity(declared.min(1 << 16));
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let body = line
            .strip_prefix("dataset ")
            .ok_or_else(|| bad(format!("unexpected catalog line {line:?}")))?;
        let mut name = None;
        let mut kind = None;
        let mut off = None;
        let mut len = None;
        let mut n = None;
        let mut e = None;
        let mut z = None;
        let mut precondition = None;
        for tok in body.split_whitespace() {
            let (k, val) = tok.split_once('=').ok_or_else(|| bad(format!("bad catalog token {tok:?}")))?;
            let parse_u64 = |what: &str| -> Result<u64> {
                val.parse().map_err(|_| bad(format!("bad {what} value {val:?} in catalog")))
            };
            match k {
                "name" => name = Some(val.to_string()),
                "kind" => {
                    kind = Some(kind_from_str(val).ok_or_else(|| bad(format!("bad dataset kind {val:?}")))?)
                }
                "off" => off = Some(parse_u64("off")?),
                "len" => len = Some(parse_u64("len")?),
                "n" => n = Some(parse_u64("n")?),
                "e" => e = Some(parse_u64("e")?),
                "z" => {
                    z = Some(match val {
                        "0" => false,
                        "1" => true,
                        _ => return Err(bad(format!("bad z value {val:?} in catalog"))),
                    })
                }
                "p" => {
                    precondition = Some(
                        val.parse()
                            .map_err(|_| bad(format!("bad p value {val:?} in catalog")))?,
                    )
                }
                _ => {} // forward compatibility: unknown keys are ignored
            }
        }
        let (Some(name), Some(kind), Some(off), Some(len), Some(n), Some(e), Some(z)) =
            (name, kind, off, len, n, e, z)
        else {
            return Err(bad(format!("catalog entry missing fields: {line:?}")));
        };
        validate_name(&name).map_err(|err| bad(format!("catalog names invalid dataset: {err}")))?;
        entries.push(DatasetInfo {
            name,
            kind,
            offset: off,
            byte_len: len,
            elem_count: n,
            elem_size: e,
            encoded: z,
            precondition,
        });
    }
    if entries.len() != declared {
        return Err(bad(format!("catalog declares {declared} datasets but lists {}", entries.len())));
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Vec<DatasetInfo> {
        vec![
            DatasetInfo {
                name: "rho:f64".into(),
                kind: SectionKind::Array,
                offset: 128,
                byte_len: 4096,
                elem_count: 100,
                elem_size: 40,
                encoded: true,
                precondition: Some(crate::codec::Precond::new(8, true).unwrap()),
            },
            DatasetInfo {
                name: "ckpt/7/hp".into(),
                kind: SectionKind::Varray,
                offset: 4224,
                byte_len: 999,
                elem_count: 3,
                elem_size: 0,
                encoded: false,
                precondition: None,
            },
        ]
    }

    #[test]
    fn catalog_roundtrips() {
        let entries = sample();
        let text = render_catalog(&entries);
        assert!(text.is_ascii());
        assert_eq!(parse_catalog(&text).unwrap(), entries);
        assert_eq!(parse_catalog(&render_catalog(&[])).unwrap(), Vec::new());
    }

    #[test]
    fn catalog_rejects_garbage_with_the_catalog_code() {
        let entries = sample();
        let text = render_catalog(&entries);
        for bad_bytes in [
            b"not a catalog".to_vec(),
            b"scda-catalog 1\ncount x".to_vec(),
            b"scda-catalog 1\ncount 1\n".to_vec(),
            b"scda-catalog 1\ncount 0\ndataset name=a kind=A off=1 len=1 n=1 e=1 z=1\n".to_vec(),
            b"scda-catalog 1\ncount 1\ndataset name=a kind=Q off=1 len=1 n=1 e=1 z=1\n".to_vec(),
            b"scda-catalog 1\ncount 1\ndataset kind=A off=1 len=1 n=1 e=1 z=1\n".to_vec(),
            vec![0xff, 0xfe],
        ] {
            let err = parse_catalog(&bad_bytes).unwrap_err();
            assert_eq!(err.code(), 1000 + crate::error::corrupt::BAD_CATALOG, "{bad_bytes:?}");
        }
        // Flipping any single byte of a real catalog must parse-fail or
        // parse to something different — never panic.
        for pos in 0..text.len() {
            let mut t = text.clone();
            t[pos] ^= 0x20;
            match parse_catalog(&t) {
                Ok(parsed) => assert_ne!(parsed, entries, "flip at {pos} invisible"),
                Err(e) => assert_eq!(e.kind(), crate::ScdaErrorKind::CorruptFile),
            }
        }
    }

    #[test]
    fn name_validation() {
        validate_name("rho:f64x5").unwrap();
        validate_name("ckpt/12/hp-coeffs_v2.1").unwrap();
        assert!(validate_name("").is_err());
        assert!(validate_name(&"x".repeat(59)).is_err());
        assert!(validate_name("has space").is_err());
        assert!(validate_name("tab\there").is_err());
        assert!(validate_name("ümlaut").is_err());
        assert!(validate_name("scda:catalog").is_err());
        assert!(validate_name("scda:index").is_err());
        let err = validate_name("nope nope").unwrap_err();
        assert_eq!(err.code(), 3000 + crate::error::usage::BAD_DATASET_NAME);
    }
}
