//! The [`Archive`] context: named, typed datasets over one scda file.
//!
//! Writing appends ordinary sections through the [`crate::api`] writers
//! — the archive only *records* what it wrote — and [`Archive::finish`]
//! serializes that record as the catalog block plus the footer index
//! ([`crate::archive::index`]). Reading loads the catalog in O(1) header
//! reads and [`Archive::open_dataset`] seeks straight to a named
//! section, after which the ordinary collective read calls apply under
//! *any* reading partition: the catalog adds addressing, not a new data
//! path, so partition independence is inherited from the format layer.

use std::collections::BTreeMap;
use std::path::Path;

use crate::api::{DataSrc, ScdaFile, SectionHeader};
use crate::archive::dataset::{parse_catalog, render_catalog, validate_name, DatasetInfo};
use crate::archive::index::{self, encode_index_payload, CATALOG_USER, INDEX_USER};
use crate::error::{corrupt, usage, Result, ScdaError};
use crate::io::IoTuning;
use crate::par::comm::Communicator;
use crate::par::partition::Partition;

/// A named-dataset archive over one scda file (all calls collective,
/// like the `ScdaFile` they wrap).
pub struct Archive<C: Communicator> {
    file: ScdaFile<C>,
    entries: Vec<DatasetInfo>,
    by_name: BTreeMap<String, usize>,
    /// Whether the catalog came from the footer index (false: linear
    /// scan fallback on a file without one).
    indexed: bool,
    writing: bool,
}

impl<C: Communicator> Archive<C> {
    // ------------------------------------------------------------------
    // Open / create / finish
    // ------------------------------------------------------------------

    /// Collectively create an archive for writing (wraps
    /// [`ScdaFile::create`]).
    pub fn create(comm: C, path: impl AsRef<Path>, user: &[u8]) -> Result<Self> {
        let file = ScdaFile::create(comm, path, user)?;
        Ok(Archive { file, entries: Vec::new(), by_name: BTreeMap::new(), indexed: false, writing: true })
    }

    /// Collectively open an archive for reading. Files with a footer
    /// index load their catalog in a constant number of header reads;
    /// plain scda files fall back to a linear section scan, so any scda
    /// file is a (possibly anonymous) archive.
    pub fn open(comm: C, path: impl AsRef<Path>) -> Result<Self> {
        Self::open_inner(ScdaFile::open(comm, path)?, true)
    }

    /// [`Archive::open`] with explicit I/O engine knobs (applied before
    /// the catalog loads, so index reads themselves route through the
    /// chosen engine) and an `use_index` switch — `false` forces the
    /// linear scan, the reference path the index is benchmarked against.
    pub fn open_with(comm: C, path: impl AsRef<Path>, tuning: IoTuning, use_index: bool) -> Result<Self> {
        let mut file = ScdaFile::open(comm, path)?;
        file.set_io_tuning(tuning)?;
        Self::open_inner(file, use_index)
    }

    fn open_inner(mut file: ScdaFile<C>, use_index: bool) -> Result<Self> {
        let loaded = if use_index { Self::load_collective(&mut file)? } else { None };
        let (entries, indexed) = match loaded {
            Some(datasets) => (datasets, true),
            None => (index::scan(&mut file)?, false),
        };
        Self::from_parts(file, entries, indexed)
    }

    /// Assemble a read-mode archive from an already-open file and an
    /// already-parsed catalog — no footer read, no scan. The archive
    /// read service builds per-client sessions this way: the catalog is
    /// parsed once at service open, then every session adopts a clone of
    /// the entries over a [`ScdaFile`] sharing the service's file handle.
    pub(crate) fn from_parts(
        file: ScdaFile<C>,
        entries: Vec<DatasetInfo>,
        indexed: bool,
    ) -> Result<Self> {
        let mut by_name = BTreeMap::new();
        for (i, e) in entries.iter().enumerate() {
            if by_name.insert(e.name.clone(), i).is_some() {
                return Err(ScdaError::corrupt(
                    corrupt::BAD_CATALOG,
                    format!("catalog lists dataset {:?} twice", e.name),
                ));
            }
        }
        Ok(Archive { file, entries, by_name, indexed, writing: false })
    }

    /// Load the catalog with rank 0 doing the footer/catalog reads and
    /// everyone else receiving the parsed datasets (re-rendered as the
    /// catalog's own ASCII form) over one broadcast: metadata I/O stays
    /// O(1) in the rank count — the scalable-metadata shape the index
    /// exists for. Rank 0's outcome (catalog / no index / error) ships
    /// in-band so the collective never splits. `None` means no index.
    fn load_collective(file: &mut ScdaFile<C>) -> Result<Option<Vec<DatasetInfo>>> {
        if file.comm().size() == 1 {
            return Ok(index::load(file)?.map(|l| l.datasets));
        }
        // Rank 0 keeps the datasets `index::load` already parsed and
        // reuses them after the broadcast instead of re-parsing its own
        // wire payload (the PR 4 cleanup debt): the broadcast still
        // carries the raw on-disk catalog text — the file bytes stay the
        // single authority on every *other* rank — but the root parses
        // exactly once.
        let mut parsed_root: Option<Vec<DatasetInfo>> = None;
        let wire: Option<Vec<u8>> = if file.comm().rank() == 0 {
            Some(match index::load(file) {
                Ok(Some(l)) => {
                    let mut w = vec![1u8];
                    w.extend_from_slice(&l.payload);
                    parsed_root = Some(l.datasets);
                    w
                }
                Ok(None) => vec![0u8],
                Err(e) => {
                    let mut w = vec![2u8];
                    w.extend_from_slice(&e.code().to_le_bytes());
                    w.extend_from_slice(e.message().as_bytes());
                    w
                }
            })
        } else {
            None
        };
        let wire = file.comm().bcast_bytes(0, wire);
        match wire.first().copied() {
            Some(0) => Ok(None),
            Some(1) => match parsed_root {
                Some(datasets) => Ok(Some(datasets)),
                None => Ok(Some(parse_catalog(&wire[1..])?)),
            },
            Some(2) if wire.len() >= 5 => {
                let code = i32::from_le_bytes(wire[1..5].try_into().unwrap());
                let msg = String::from_utf8_lossy(&wire[5..]).into_owned();
                Err(rebuild_error(code, msg))
            }
            _ => Err(ScdaError::corrupt(corrupt::BAD_CATALOG, "malformed catalog broadcast")),
        }
    }

    /// Write the catalog block and footer index, then close the file.
    /// Write-mode archives must end with this call (a bare drop loses
    /// the catalog, leaving a valid but index-less scda file).
    pub fn finish(mut self) -> Result<()> {
        debug_assert!(self.writing, "finish is a write-mode call");
        let text = render_catalog(&self.entries);
        let catalog_off = self.file.position();
        self.file.write_block_from(0, Some(&text), text.len() as u64, Some(CATALOG_USER), false)?;
        let payload = encode_index_payload(catalog_off);
        self.file.write_inline_from(0, Some(&payload), Some(INDEX_USER))?;
        self.file.close()
    }

    /// Close without writing a catalog: the read-mode close, also usable
    /// by a writer that decided against an index (the file stays plain
    /// scda and reopens through the scan fallback).
    pub fn close(self) -> Result<()> {
        self.file.close()
    }

    // ------------------------------------------------------------------
    // Introspection and escape hatches
    // ------------------------------------------------------------------

    /// The datasets in file order.
    pub fn datasets(&self) -> &[DatasetInfo] {
        &self.entries
    }

    /// Look up one dataset's catalog entry.
    pub fn get(&self, name: &str) -> Option<&DatasetInfo> {
        self.by_name.get(name).map(|&i| &self.entries[i])
    }

    /// Whether the catalog came from the O(1) footer index rather than a
    /// linear scan.
    pub fn is_indexed(&self) -> bool {
        self.indexed
    }

    /// The wrapped file, for calls the archive does not mirror (tuning,
    /// stats, style, or the raw section API after [`Self::open_dataset`]).
    pub fn file_mut(&mut self) -> &mut ScdaFile<C> {
        &mut self.file
    }

    pub fn file(&self) -> &ScdaFile<C> {
        &self.file
    }

    // ------------------------------------------------------------------
    // Writing named datasets
    // ------------------------------------------------------------------

    fn begin_dataset(&mut self, name: &str) -> Result<u64> {
        validate_name(name)?;
        if self.by_name.contains_key(name) {
            return Err(ScdaError::usage(
                usage::BAD_DATASET_NAME,
                format!("archive already has a dataset named {name:?}"),
            ));
        }
        Ok(self.file.position())
    }

    fn end_dataset(&mut self, info: DatasetInfo) {
        self.by_name.insert(info.name.clone(), self.entries.len());
        self.entries.push(info);
    }

    /// Write a named 32-byte inline dataset (data on `root`).
    pub fn write_inline_from(&mut self, name: &str, root: usize, data: Option<&[u8]>) -> Result<()> {
        let offset = self.begin_dataset(name)?;
        self.file.write_inline_from(root, data, Some(name.as_bytes()))?;
        self.end_dataset(DatasetInfo {
            name: name.to_string(),
            kind: crate::format::section::SectionKind::Inline,
            offset,
            byte_len: self.file.position() - offset,
            elem_count: 0,
            elem_size: 0,
            encoded: false,
            precondition: None,
        });
        Ok(())
    }

    /// Write a named block dataset of `len` bytes (data on `root`).
    pub fn write_block_from(
        &mut self,
        name: &str,
        root: usize,
        data: Option<&[u8]>,
        len: u64,
        encode: bool,
    ) -> Result<()> {
        let offset = self.begin_dataset(name)?;
        self.file.write_block_from(root, data, len, Some(name.as_bytes()), encode)?;
        self.end_dataset(DatasetInfo {
            name: name.to_string(),
            kind: crate::format::section::SectionKind::Block,
            offset,
            byte_len: self.file.position() - offset,
            elem_count: 0,
            elem_size: len,
            encoded: encode,
            precondition: if encode { self.file.precondition() } else { None },
        });
        Ok(())
    }

    /// Write a named fixed-size array dataset; this rank contributes its
    /// partition window, exactly like [`ScdaFile::write_array`].
    pub fn write_array(
        &mut self,
        name: &str,
        data: DataSrc<'_>,
        part: &Partition,
        elem_size: u64,
        encode: bool,
    ) -> Result<()> {
        let offset = self.begin_dataset(name)?;
        self.file.write_array(data, part, elem_size, Some(name.as_bytes()), encode)?;
        self.end_dataset(DatasetInfo {
            name: name.to_string(),
            kind: crate::format::section::SectionKind::Array,
            offset,
            byte_len: self.file.position() - offset,
            elem_count: part.total(),
            elem_size,
            encoded: encode,
            precondition: if encode { self.file.precondition() } else { None },
        });
        Ok(())
    }

    /// Write a named variable-size array dataset; `local_sizes` are this
    /// rank's element byte sizes, like [`ScdaFile::write_varray`].
    pub fn write_varray(
        &mut self,
        name: &str,
        data: DataSrc<'_>,
        part: &Partition,
        local_sizes: &[u64],
        encode: bool,
    ) -> Result<()> {
        let offset = self.begin_dataset(name)?;
        self.file.write_varray(data, part, local_sizes, Some(name.as_bytes()), encode)?;
        self.end_dataset(DatasetInfo {
            name: name.to_string(),
            kind: crate::format::section::SectionKind::Varray,
            offset,
            byte_len: self.file.position() - offset,
            elem_count: part.total(),
            elem_size: 0,
            encoded: encode,
            precondition: if encode { self.file.precondition() } else { None },
        });
        Ok(())
    }

    // ------------------------------------------------------------------
    // Reading named datasets
    // ------------------------------------------------------------------

    /// Seek to a named dataset and read its logical section header —
    /// O(1) in the number of sections when the catalog is indexed. After
    /// this, the ordinary data calls on [`Self::file_mut`] apply (or use
    /// the typed read helpers below). The header's user string must
    /// equal the name; a catalog that points elsewhere is corrupt (the
    /// sections are authoritative, the catalog merely addresses them).
    pub fn open_dataset(&mut self, name: &str) -> Result<SectionHeader> {
        let entry = self.get(name).ok_or_else(|| no_such_dataset(name))?;
        let offset = entry.offset;
        self.file.seek_section(offset)?;
        let header = self.file.read_section_header(true)?;
        if header.user != name.as_bytes() {
            return Err(ScdaError::corrupt(
                corrupt::BAD_CATALOG,
                format!(
                    "catalog maps {name:?} to offset {offset}, but the section there is named {:?}",
                    String::from_utf8_lossy(&header.user)
                ),
            ));
        }
        Ok(header)
    }

    /// Read a named inline dataset's 32 bytes on `root`.
    pub fn read_inline(&mut self, name: &str, root: usize) -> Result<Option<[u8; 32]>> {
        let h = self.open_dataset(name)?;
        expect_kind(name, h.kind, crate::format::section::SectionKind::Inline)?;
        self.file.read_inline_data(root, true)
    }

    /// Read a named block dataset's bytes on `root` (decoded if it was
    /// written encoded).
    pub fn read_block(&mut self, name: &str, root: usize) -> Result<Option<Vec<u8>>> {
        let h = self.open_dataset(name)?;
        expect_kind(name, h.kind, crate::format::section::SectionKind::Block)?;
        self.file.read_block_data(root, true)
    }

    /// Read this rank's window of a named fixed-size array dataset under
    /// any reading partition with the right total (partition-independent
    /// random access: the writer's rank count is invisible).
    pub fn read_array(&mut self, name: &str, part: &Partition, elem_size: u64) -> Result<Vec<u8>> {
        let h = self.open_dataset(name)?;
        expect_kind(name, h.kind, crate::format::section::SectionKind::Array)?;
        Ok(self.file.read_array_data(part, elem_size, true)?.unwrap_or_default())
    }

    /// Read this rank's element sizes and payload window of a named
    /// variable-size array dataset under any reading partition.
    pub fn read_varray(&mut self, name: &str, part: &Partition) -> Result<(Vec<u64>, Vec<u8>)> {
        let h = self.open_dataset(name)?;
        expect_kind(name, h.kind, crate::format::section::SectionKind::Varray)?;
        let sizes = self.file.read_varray_sizes(part)?;
        let data = self.file.read_varray_data(part, &sizes, true)?.unwrap_or_default();
        Ok((sizes, data))
    }

    // ------------------------------------------------------------------
    // Catalog-seeded range reads
    // ------------------------------------------------------------------

    /// Read elements `[first, first + count)` of a named fixed-size
    /// array dataset — delivered to *every* rank of the reading
    /// communicator — seeding the read window straight from the catalog
    /// entry instead of replaying the section stream. A raw array
    /// touches no size rows at all (the window is `offset + first · E`);
    /// an encoded (convention-9) dataset reads only the compressed-size
    /// rows `[0, first + count)` that the locating prefix sum requires —
    /// never a row at or past the range end, never payload bytes outside
    /// the window (`rust/tests/archive_range.rs` asserts both through
    /// `IoStats`). Equivalent to a full [`Self::read_array`] followed by
    /// slicing, under any writer/reader partition combination.
    ///
    /// Collective like every archive call; under
    /// [`crate::io::IoTuning::collective`] the identical per-rank
    /// requests dedupe into one stripe-owner read set (the collective
    /// read gather).
    ///
    /// ```
    /// use scda::api::DataSrc;
    /// use scda::archive::Archive;
    /// use scda::par::{Partition, SerialComm};
    ///
    /// let path = std::env::temp_dir().join(format!("scda-doc-range-{}.scda", std::process::id()));
    /// let part = Partition::uniform(1, 100);
    /// let data: Vec<u8> = (0..800u32).map(|i| (i % 251) as u8).collect();
    /// let mut ar = Archive::create(SerialComm::new(), &path, b"doc").unwrap();
    /// ar.write_array("temps", DataSrc::Contiguous(&data), &part, 8, false).unwrap();
    /// ar.finish().unwrap();
    ///
    /// let mut ar = Archive::open(SerialComm::new(), &path).unwrap();
    /// // Elements 10..14, straight out of the middle of the section:
    /// let got = ar.read_range("temps", 10, 4).unwrap();
    /// assert_eq!(got, &data[80..112]);
    /// ar.close().unwrap();
    /// # std::fs::remove_file(&path).unwrap();
    /// ```
    pub fn read_range(&mut self, name: &str, first: u64, count: u64) -> Result<Vec<u8>> {
        let entry = self.get(name).ok_or_else(|| no_such_dataset(name))?;
        entry.check_range(first, count)?;
        let section_end = entry.offset + entry.byte_len;
        let h = self.open_dataset(name)?;
        expect_kind(name, h.kind, crate::format::section::SectionKind::Array)?;
        self.file.read_array_range_data(first, count, section_end)
    }

    /// The varray counterpart of [`Self::read_range`]: elements
    /// `[first, first + count)` of a named variable-size array dataset,
    /// returned as `(element sizes, concatenated payloads)` on every
    /// rank. Size rows are read only as far as the locating prefix sum
    /// requires (`[0, first + count)`); rows at or past the range end
    /// and payload bytes outside the window are never touched.
    pub fn read_varray_range(&mut self, name: &str, first: u64, count: u64) -> Result<(Vec<u64>, Vec<u8>)> {
        let entry = self.get(name).ok_or_else(|| no_such_dataset(name))?;
        entry.check_range(first, count)?;
        let section_end = entry.offset + entry.byte_len;
        let h = self.open_dataset(name)?;
        expect_kind(name, h.kind, crate::format::section::SectionKind::Varray)?;
        self.file.read_varray_range_data(first, count, section_end)
    }

    /// The partitioned form of [`Self::read_range`]: the global element
    /// range `[first, first + count)` is divided over the reading
    /// communicator by `part` — a partition of exactly `count` elements
    /// over exactly the communicator's ranks — and each rank receives
    /// only its own sub-window's bytes, instead of every rank receiving
    /// the whole range. This is the restore-shaped access pattern: P
    /// readers each pull their slice of a named dataset without
    /// materializing `count · E` bytes per rank.
    ///
    /// Collective, and equivalent on every rank to
    /// `read_range(name, first, count)` sliced to
    /// `[part.offset(rank) · E, (part.offset(rank) + part.count(rank)) · E)`
    /// — under any writer rank count (`rust/tests/archive_range.rs`
    /// asserts the equivalence).
    pub fn read_range_partitioned(
        &mut self,
        name: &str,
        first: u64,
        count: u64,
        part: &Partition,
    ) -> Result<Vec<u8>> {
        let entry = self.get(name).ok_or_else(|| no_such_dataset(name))?;
        entry.check_range(first, count)?;
        let section_end = entry.offset + entry.byte_len;
        let h = self.open_dataset(name)?;
        expect_kind(name, h.kind, crate::format::section::SectionKind::Array)?;
        self.file.read_array_range_data_part(first, count, section_end, part)
    }

    /// The varray counterpart of [`Self::read_range_partitioned`]: each
    /// rank receives its own sub-window's `(element sizes, payload)`
    /// under `part`.
    pub fn read_varray_range_partitioned(
        &mut self,
        name: &str,
        first: u64,
        count: u64,
        part: &Partition,
    ) -> Result<(Vec<u64>, Vec<u8>)> {
        let entry = self.get(name).ok_or_else(|| no_such_dataset(name))?;
        entry.check_range(first, count)?;
        let section_end = entry.offset + entry.byte_len;
        let h = self.open_dataset(name)?;
        expect_kind(name, h.kind, crate::format::section::SectionKind::Varray)?;
        self.file.read_varray_range_data_part(first, count, section_end, part)
    }
}

impl Archive<crate::par::SerialComm> {
    /// Repair an archive with a torn tail (crash or torn write during an
    /// append): truncate the damage, rebuild a consistent trailer over
    /// the surviving sections, and report what survived. A local,
    /// non-collective filesystem repair — run it from one process (or
    /// `scda recover`) before reopening the archive in parallel. Thin
    /// delegate to [`crate::archive::recover::recover`], which documents
    /// the algorithm and guarantees.
    pub fn recover(path: impl AsRef<Path>) -> Result<crate::archive::recover::RecoveryReport> {
        crate::archive::recover::recover(path)
    }
}

/// Rebuild a broadcast error on the receiving ranks (code ranges are the
/// §A.6 groups; the message is carried verbatim). Every group
/// round-trips its detail code, so all ranks report the same stable
/// `code()` for one collective failure — io errors reconstruct their
/// errno from the detail.
fn rebuild_error(code: i32, msg: String) -> ScdaError {
    ScdaError::rebuild(code, msg)
}

fn no_such_dataset(name: &str) -> ScdaError {
    ScdaError::usage(usage::NO_SUCH_DATASET, format!("archive has no dataset named {name:?}"))
}

fn expect_kind(
    name: &str,
    got: crate::format::section::SectionKind,
    want: crate::format::section::SectionKind,
) -> Result<()> {
    if got != want {
        return Err(ScdaError::usage(
            usage::WRONG_SECTION,
            format!("dataset {name:?} is a {got} section, this call reads {want}"),
        ));
    }
    Ok(())
}
