//! Checkpoint/restart on the archive layer: every checkpoint artifact is
//! a *named dataset*, versioned by step — `ckpt/<n>.info` (32-byte step
//! record), `ckpt/<n>.manifest` (the text manifest), and one
//! `ckpt/<n>/<field>` dataset per field. Restart therefore addresses
//! fields *by name* through the catalog instead of replaying the section
//! stream: any field of any step, restored under any reading partition
//! (and hence any rank count), in O(1) header reads per field.
//!
//! One archive can hold several steps (written in one create session —
//! scda files are write-once, §A.3), which is what the versioned names
//! buy: `list_steps` enumerates them, `read_step(None)` restores the
//! latest. Files written by the pre-archive checkpoint writer (sections
//! `scda:ckpt` / `scda:manifest` / bare field names) restore through the
//! same calls: the scan fallback names their sections, and field lookup
//! falls back from `ckpt/<n>/<field>` to the bare field name.

use crate::api::DataSrc;
use crate::archive::Archive;
use crate::coordinator::checkpoint::{
    invert_elements, parse_manifest, precondition_elements, render_manifest, CheckpointInfo, Field,
    FieldInfo, FieldPayload,
};
use crate::coordinator::metrics::Metrics;
use crate::error::{corrupt, Result, ScdaError};
use crate::par::comm::Communicator;
use crate::par::partition::Partition;
use crate::runtime::service::Transform;

/// Prefix shared by all checkpoint dataset names.
pub const STEP_PREFIX: &str = "ckpt/";

/// Name of a step's 32-byte info record.
pub fn info_name(step: u64) -> String {
    format!("{STEP_PREFIX}{step}.info")
}

/// Name of a step's manifest dataset. The '.' separator keeps meta
/// datasets out of the `ckpt/<n>/<field>` namespace, so no field name
/// can collide with them.
pub fn manifest_name(step: u64) -> String {
    format!("{STEP_PREFIX}{step}.manifest")
}

/// Name of one field's dataset within a step.
pub fn field_name(step: u64, field: &str) -> String {
    format!("{STEP_PREFIX}{step}/{field}")
}

/// Collectively write one checkpoint step into an open write-mode
/// archive. All ranks pass the same `app`, `step`, field specs and
/// `part`; payloads are each rank's partition window. May be called
/// repeatedly with distinct steps before [`Archive::finish`].
///
/// Field names live inside the section user string together with the
/// `ckpt/<n>/` prefix, so their budget is `58 - len("ckpt/<n>/")` bytes
/// (51 for single-digit steps) — tighter than the bare 58 of the
/// pre-archive layout. Every dataset name of the step is validated *up
/// front*, before any section is written, so an over-long or invalid
/// field name fails cleanly instead of leaving a partial step behind.
pub fn write_step<C: Communicator>(
    ar: &mut Archive<C>,
    app: &str,
    step: u64,
    part: &Partition,
    fields: &[Field],
    pre: &dyn Transform,
    metrics: &Metrics,
) -> Result<()> {
    let mut names = std::collections::BTreeSet::new();
    for f in fields {
        let name = field_name(step, &f.name);
        crate::archive::dataset::validate_name(&name)?;
        // Duplicates — within this step's field list or against datasets
        // already in the archive (a rerun of the same step) — must also
        // fail before anything is written: begin_dataset would reject
        // them mid-step otherwise, stranding a manifest whose fields
        // have no backing datasets.
        if !names.insert(name.clone()) || ar.get(&name).is_some() {
            return Err(ScdaError::usage(
                crate::error::usage::BAD_DATASET_NAME,
                format!("checkpoint step {step} would write dataset {name:?} twice"),
            ));
        }
    }
    if ar.get(&info_name(step)).is_some() || ar.get(&manifest_name(step)).is_some() {
        return Err(ScdaError::usage(
            crate::error::usage::BAD_DATASET_NAME,
            format!("archive already holds checkpoint step {step}"),
        ));
    }
    let info = CheckpointInfo {
        app: app.to_string(),
        step,
        fields: fields
            .iter()
            .map(|f| FieldInfo {
                name: f.name.clone(),
                fixed_elem: match &f.payload {
                    FieldPayload::Fixed { elem_size, .. } => Some(*elem_size),
                    FieldPayload::Var { .. } => None,
                },
                elem_count: part.total(),
                encode: f.encode,
                precondition: f.precondition,
            })
            .collect(),
    };
    // 32-byte human-readable step record.
    let mut inline = format!("step {step:>20} ok");
    inline.truncate(31);
    let mut inline = inline.into_bytes();
    inline.resize(31, b' ');
    inline.push(b'\n');
    ar.write_inline_from(&info_name(step), 0, Some(&inline))?;
    let manifest = render_manifest(&info);
    ar.write_block_from(&manifest_name(step), 0, Some(&manifest), manifest.len() as u64, false)?;
    for f in fields {
        let name = field_name(step, &f.name);
        match &f.payload {
            FieldPayload::Fixed { elem_size, data } => {
                Metrics::add(&metrics.bytes_in, data.len() as u64);
                let np = data.len() as u64 / (*elem_size).max(1);
                let owned;
                let src = if f.precondition {
                    owned = precondition_elements(
                        pre,
                        data,
                        std::iter::repeat(*elem_size).take(np as usize),
                        metrics,
                    )?;
                    DataSrc::Contiguous(&owned)
                } else {
                    DataSrc::Contiguous(data)
                };
                Metrics::timed(&metrics.ns_write, || ar.write_array(&name, src, part, *elem_size, f.encode))?;
            }
            FieldPayload::Var { sizes, data } => {
                Metrics::add(&metrics.bytes_in, data.len() as u64);
                let owned;
                let src = if f.precondition {
                    owned = precondition_elements(pre, data, sizes.iter().copied(), metrics)?;
                    DataSrc::Contiguous(&owned)
                } else {
                    DataSrc::Contiguous(data)
                };
                Metrics::timed(&metrics.ns_write, || ar.write_varray(&name, src, part, sizes, f.encode))?;
            }
        }
        Metrics::add(&metrics.sections_written, 1);
        Metrics::add(&metrics.elements_written, part.count(ar.file().comm().rank()));
    }
    Ok(())
}

/// The steps recorded in an archive, ascending.
pub fn list_steps<C: Communicator>(ar: &Archive<C>) -> Vec<u64> {
    let mut steps: Vec<u64> = ar
        .datasets()
        .iter()
        .filter_map(|d| {
            d.name
                .strip_prefix(STEP_PREFIX)
                .and_then(|rest| rest.strip_suffix(".manifest"))
                .and_then(|mid| mid.parse().ok())
        })
        .collect();
    steps.sort_unstable();
    steps.dedup();
    steps
}

/// Read one step's manifest by name — or, with `step = None`, the
/// latest step's (falling back to a legacy `scda:manifest` section for
/// pre-archive checkpoint files). Errors with a corrupt-file code when
/// the file holds no checkpoint at all.
pub fn read_manifest<C: Communicator>(ar: &mut Archive<C>, step: Option<u64>) -> Result<CheckpointInfo> {
    let name = match step {
        Some(s) => {
            let name = manifest_name(s);
            // A missing *requested* step in an intact archive is a
            // caller error, not file damage.
            if ar.get(&name).is_none() {
                return Err(ScdaError::usage(
                    crate::error::usage::NO_SUCH_DATASET,
                    format!("archive has no checkpoint step {s}"),
                ));
            }
            name
        }
        None => match list_steps(ar).last() {
            Some(&s) => manifest_name(s),
            None if ar.get("scda:manifest").is_some() => "scda:manifest".to_string(),
            None => {
                return Err(ScdaError::corrupt(
                    corrupt::BAD_CONVENTION,
                    "not an scda checkpoint (no ckpt/<n>.manifest dataset and no scda:manifest section)",
                ))
            }
        },
    };
    let bytes = ar.read_block(&name, 0)?;
    let bytes = ar.file().comm().bcast_bytes(0, bytes);
    parse_manifest(&bytes)
}

/// Wrap a freshly read payload as the manifest field's [`Field`],
/// inverting the preconditioner per element when the manifest says so —
/// the restore tail shared by the named (catalog) and legacy
/// (sequential) paths, factored here so the Fixed/Var inversion logic
/// exists exactly once. `sizes` is `Some` exactly when the field is
/// variable-size; `np` is this rank's element count under the reading
/// partition.
fn finish_field(
    fi: &FieldInfo,
    pre: &dyn Transform,
    np: usize,
    sizes: Option<Vec<u64>>,
    data: Vec<u8>,
) -> Result<Field> {
    let payload = match (fi.fixed_elem, sizes) {
        (Some(e), None) => {
            let data = if fi.precondition {
                invert_elements(pre, &data, std::iter::repeat(e).take(np))?
            } else {
                data
            };
            FieldPayload::Fixed { elem_size: e, data }
        }
        (None, Some(sizes)) => {
            let data = if fi.precondition {
                invert_elements(pre, &data, sizes.iter().copied())?
            } else {
                data
            };
            FieldPayload::Var { sizes, data }
        }
        _ => unreachable!("callers read sizes exactly when the field is variable-size"),
    };
    Ok(Field { name: fi.name.clone(), encode: fi.encode, precondition: fi.precondition, payload })
}

/// Restore one manifest field by name under any reading partition,
/// inverting the preconditioner when the manifest says so.
pub fn read_field<C: Communicator>(
    ar: &mut Archive<C>,
    step: u64,
    fi: &FieldInfo,
    part: &Partition,
    pre: &dyn Transform,
) -> Result<Field> {
    part.check_total(fi.elem_count)?;
    let versioned = field_name(step, &fi.name);
    let name = if ar.get(&versioned).is_some() {
        versioned
    } else if ar.get(&manifest_name(step)).is_none() && ar.get(&fi.name).is_some() {
        // Legacy layout only: the step has no versioned manifest dataset
        // (its manifest was the pre-archive scda:manifest section), so
        // fields live under bare names. A *versioned* step missing a
        // field dataset must NOT resolve through an unrelated bare-named
        // dataset — that is damage, reported below.
        fi.name.clone()
    } else {
        return Err(ScdaError::corrupt(
            corrupt::BAD_CONVENTION,
            format!("manifest names field {:?} but the archive has no such dataset", fi.name),
        ));
    };
    let (sizes, data) = match fi.fixed_elem {
        Some(e) => (None, ar.read_array(&name, part, e)?),
        None => {
            let (sizes, data) = ar.read_varray(&name, part)?;
            (Some(sizes), data)
        }
    };
    let np = part.count(ar.file().comm().rank()) as usize;
    finish_field(fi, pre, np, sizes, data)
}

/// Restore a whole step (the latest with `step = None`): manifest first,
/// then every field by name, in manifest order.
pub fn read_step<C: Communicator>(
    ar: &mut Archive<C>,
    step: Option<u64>,
    part: &Partition,
    pre: &dyn Transform,
) -> Result<(CheckpointInfo, Vec<Field>)> {
    let info = read_manifest(ar, step)?;
    let fields = read_fields(ar, &info, part, pre)?;
    Ok((info, fields))
}

/// Restore every field of an already-read manifest. Versioned steps
/// restore by name through the catalog; legacy pre-archive checkpoints
/// (no `ckpt/<n>.manifest` dataset) replay the section stream
/// sequentially like the original reader did — which also preserves the
/// old reader's tolerance for duplicate or non-conforming field names
/// that the catalog scan cannot represent.
pub fn read_fields<C: Communicator>(
    ar: &mut Archive<C>,
    info: &CheckpointInfo,
    part: &Partition,
    pre: &dyn Transform,
) -> Result<Vec<Field>> {
    if ar.get(&manifest_name(info.step)).is_none() {
        return read_legacy_fields(ar, info, part, pre);
    }
    let mut fields = Vec::with_capacity(info.fields.len());
    for fi in &info.fields {
        fields.push(read_field(ar, info.step, fi, part, pre)?);
    }
    Ok(fields)
}

/// The pre-archive sequential restore: seek to the section after the
/// legacy `scda:manifest` block and read each field's own section in
/// manifest order, verifying user strings as the original reader did.
fn read_legacy_fields<C: Communicator>(
    ar: &mut Archive<C>,
    info: &CheckpointInfo,
    part: &Partition,
    pre: &dyn Transform,
) -> Result<Vec<Field>> {
    let manifest = ar.get("scda:manifest").ok_or_else(|| {
        ScdaError::corrupt(corrupt::BAD_CONVENTION, "legacy checkpoint without scda:manifest section")
    })?;
    let start = manifest.offset + manifest.byte_len;
    let file = ar.file_mut();
    file.seek_section(start)?;
    let mut fields = Vec::with_capacity(info.fields.len());
    for fi in &info.fields {
        let h = file.read_section_header(true)?;
        if h.user != fi.name.as_bytes() {
            return Err(ScdaError::corrupt(
                corrupt::BAD_CONVENTION,
                format!(
                    "manifest names field {:?} but section is {:?}",
                    fi.name,
                    String::from_utf8_lossy(&h.user)
                ),
            ));
        }
        part.check_total(h.elem_count)?;
        let (sizes, data) = match fi.fixed_elem {
            Some(e) => (None, file.read_array_data(part, e, true)?.unwrap_or_default()),
            None => {
                let sizes = file.read_varray_sizes(part)?;
                let data = file.read_varray_data(part, &sizes, true)?.unwrap_or_default();
                (Some(sizes), data)
            }
        };
        let np = part.count(file.comm().rank()) as usize;
        fields.push(finish_field(fi, pre, np, sizes, data)?);
    }
    Ok(fields)
}
