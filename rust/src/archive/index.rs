//! The persisted footer index: O(1) location of the catalog section.
//!
//! A catalog-bearing archive ends with two ordinary scda sections,
//! written after all user datasets:
//!
//! 1. a `B` section with user string `scda:catalog` whose payload is the
//!    ASCII catalog text ([`crate::archive::dataset`]), and
//! 2. an `I` section with user string `scda:index` whose 32 data bytes
//!    are the catalog section's absolute offset, printed as
//!    right-aligned ASCII decimal with a trailing newline.
//!
//! An inline section is exactly 96 bytes and is never padded (§2.3), so
//! the index is always the *last 96 bytes of the file* — one positional
//! read finds it, independent of how many sections precede it. That is
//! the whole trick: the file stays pure scda (both trailer sections are
//! ordinary sections that `query::verify_bytes` validates like any
//! other), yet `Archive::open` needs a constant number of header reads
//! where `toc()` pays a full linear scan.
//!
//! # Trust model
//!
//! The index is *advisory*, the catalog section is *authoritative*: if
//! the last 96 bytes do not parse as an `scda:index` inline section the
//! file simply has no index and readers fall back to the linear scan
//! ([`scan`]) — plain scda files remain first-class. But once the footer
//! declares itself, everything it points at must hold: a payload that is
//! not a decimal offset, an offset that does not land on a well-formed
//! `scda:catalog` block, or catalog text that fails to parse is a
//! [`corrupt::BAD_CATALOG`] error, never a silent fallback (a damaged
//! archive must be reported, not reinterpreted).

use crate::api::ScdaFile;
use crate::archive::dataset::{parse_catalog, DatasetInfo};
use crate::error::{corrupt, Result, ScdaError};
use crate::format::limits::{
    FILE_HEADER_BYTES, INLINE_DATA_BYTES, INLINE_SECTION_BYTES, SECTION_HEADER_BYTES,
};
use crate::format::number::count_to_usize;
use crate::format::section::{parse_section_prefix, parse_type_row, SectionKind, SECTION_PREFIX_MAX};
use crate::par::comm::Communicator;

/// User string of the catalog block section.
pub const CATALOG_USER: &[u8] = b"scda:catalog";
/// User string of the footer index inline section.
pub const INDEX_USER: &[u8] = b"scda:index";

/// Encode the 32-byte index payload: the catalog offset as right-aligned
/// ASCII decimal plus a trailing newline (human-readable, pure ASCII).
pub fn encode_index_payload(catalog_off: u64) -> [u8; 32] {
    let s = format!("{catalog_off:>31}\n");
    debug_assert_eq!(s.len(), INLINE_DATA_BYTES);
    let mut out = [0u8; 32];
    out.copy_from_slice(s.as_bytes());
    out
}

/// Parse the payload written by [`encode_index_payload`].
pub fn parse_index_payload(payload: &[u8]) -> Result<u64> {
    let text = std::str::from_utf8(payload)
        .map_err(|_| ScdaError::corrupt(corrupt::BAD_CATALOG, "index payload is not ASCII"))?;
    text.trim().parse().map_err(|_| {
        ScdaError::corrupt(corrupt::BAD_CATALOG, format!("index payload {text:?} is not an offset"))
    })
}

/// Everything the footer index locates, as loaded by [`load`].
#[derive(Debug, Clone)]
pub struct LoadedCatalog {
    pub datasets: Vec<DatasetInfo>,
    /// Absolute offset of the catalog block section.
    pub catalog_off: u64,
    /// Byte length of the catalog text (the block's `E`).
    pub catalog_bytes: u64,
    /// The raw catalog text `datasets` was parsed from — what a
    /// collective open broadcasts, so the on-disk bytes stay the single
    /// authority on every rank.
    pub payload: Vec<u8>,
}

/// Try to load the catalog through the footer index: `Ok(None)` when the
/// file has no index (fall back to [`scan`]), `Err` when it has one that
/// is inconsistent (see the module's trust model), `Ok(Some(..))` after
/// a constant number of reads regardless of section count.
pub fn load<C: Communicator>(file: &mut ScdaFile<C>) -> Result<Option<LoadedCatalog>> {
    let flen = file.file_len()?;
    // Smallest possible catalog-bearing file: header + catalog + index.
    if flen < (FILE_HEADER_BYTES + INLINE_SECTION_BYTES) as u64 {
        return Ok(None);
    }
    let tail_off = flen - INLINE_SECTION_BYTES as u64;
    let tail = file.engine_read(tail_off, INLINE_SECTION_BYTES)?;
    let Ok((kind, user)) = parse_type_row(&tail[..SECTION_HEADER_BYTES]) else {
        return Ok(None);
    };
    if kind != SectionKind::Inline || user != INDEX_USER {
        return Ok(None);
    }
    // From here on the footer is authoritative: inconsistency is
    // corruption, not absence.
    let catalog_off = parse_index_payload(&tail[SECTION_HEADER_BYTES..])?;
    if catalog_off < FILE_HEADER_BYTES as u64 || catalog_off >= tail_off {
        return Err(ScdaError::corrupt(
            corrupt::BAD_CATALOG,
            format!("index points at {catalog_off}, outside the section region"),
        ));
    }
    let take = (tail_off - catalog_off).min(SECTION_PREFIX_MAX as u64) as usize;
    // A parse failure here is the *index's* fault (it named this offset),
    // so it reports as catalog corruption, not as a bad section — the
    // sections themselves may be fine.
    let (meta, prefix_len) = parse_section_prefix(&file.engine_read(catalog_off, take)?).map_err(|e| {
        ScdaError::corrupt(
            corrupt::BAD_CATALOG,
            format!("index points at {catalog_off}, which is not a section header: {e}"),
        )
    })?;
    if meta.kind != SectionKind::Block || meta.user != CATALOG_USER {
        return Err(ScdaError::corrupt(
            corrupt::BAD_CATALOG,
            format!("index points at a {} {:?} section, expected the catalog block", meta.kind,
                String::from_utf8_lossy(&meta.user)),
        ));
    }
    let catalog_bytes = meta.elem_size;
    // Compare in u128: a corrupt E count near 2^64 must fail *here*,
    // not wrap around and pass into an impossible read/allocation.
    if catalog_off as u128 + meta.total_len(None) != tail_off as u128 {
        return Err(ScdaError::corrupt(
            corrupt::BAD_CATALOG,
            "catalog section does not reach the footer index",
        ));
    }
    let payload =
        file.engine_read(catalog_off + prefix_len as u64, count_to_usize(catalog_bytes, "catalog")?)?;
    let datasets = parse_catalog(&payload)?;
    Ok(Some(LoadedCatalog { datasets, catalog_off, catalog_bytes: catalog_bytes as u64, payload }))
}

/// The linear fallback for files without a footer index: walk every
/// section header (`toc`) and name each logical section by its user
/// string. Sections whose user string is not a valid dataset name, the
/// archive's own trailer sections, and repeated names (first wins) are
/// skipped — the result is best-effort discovery, not an error.
pub fn scan<C: Communicator>(file: &mut ScdaFile<C>) -> Result<Vec<DatasetInfo>> {
    let toc = file.toc_scan(true)?;
    let mut out: Vec<DatasetInfo> = Vec::with_capacity(toc.len());
    let mut seen = std::collections::BTreeSet::new();
    for e in &toc {
        let Ok(name) = std::str::from_utf8(&e.header.user) else { continue };
        // Rejects anonymous/unnameable user strings and the archive's
        // own trailer names (they are reserved).
        if super::dataset::validate_name(name).is_err() {
            continue;
        }
        if !seen.insert(name.to_string()) {
            continue;
        }
        out.push(DatasetInfo {
            name: name.to_string(),
            kind: e.header.kind,
            offset: e.offset,
            byte_len: e.byte_len,
            elem_count: e.header.elem_count,
            elem_size: e.header.elem_size,
            encoded: e.header.decoded,
            // Headers don't carry the frame marker; scan discovery leaves
            // the advisory field unset (frames still self-describe).
            precondition: None,
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_payload_roundtrips() {
        for off in [0u64, 128, 12345, u64::MAX] {
            let p = encode_index_payload(off);
            assert_eq!(p.len(), 32);
            assert!(p.is_ascii());
            assert_eq!(p[31], b'\n');
            assert_eq!(parse_index_payload(&p).unwrap(), off);
        }
    }

    #[test]
    fn index_payload_rejects_garbage() {
        for bad in [&b"not a number at all, not even  "[..], &[0xffu8; 32][..], b""] {
            let err = parse_index_payload(bad).unwrap_err();
            assert_eq!(err.code(), 1000 + corrupt::BAD_CATALOG);
        }
    }
}
