//! The archive catalog layer: named, typed datasets over plain scda.
//!
//! The paper places scda "one layer below … the definition of variables
//! … and self-describing headers, which may all be specified on top of
//! scda". This module is that layer-on-top for this crate: it adds
//! *addressing* — a dataset name per logical section, a catalog that
//! maps names to `{offset, byte_len, kind, elem_count, elem_size}`, and
//! a footer index that finds the catalog in O(1) — while changing
//! nothing about the format underneath:
//!
//! * **Pure scda.** The catalog is the payload of an ordinary `B`
//!   section (`scda:catalog`, ASCII text), the index an ordinary `I`
//!   section (`scda:index`, ASCII decimal). A catalog-bearing file
//!   passes `query::verify_bytes` unchanged and any scda reader — the
//!   Python implementation, `scda cat` — sees two more sections.
//! * **Serial-equivalent.** Every catalog field is a pure function of
//!   collective inputs (names, section offsets, counts), so archive
//!   bytes are identical at any writer rank count, like every other
//!   section.
//! * **O(1) random access.** An inline section is exactly 96 unpadded
//!   bytes, so the index is always the last 96 bytes of the file:
//!   [`Archive::open`] reads footer → catalog and
//!   [`Archive::open_dataset`] seeks straight to the named section — a
//!   constant number of header reads where `toc()` scans linearly
//!   (`BENCH_archive.json` tracks the gap).
//! * **Partition-independent.** After `open_dataset`, the ordinary
//!   collective read calls apply under any reading partition: the
//!   catalog adds addressing, not a data path, so readers on any rank
//!   count agree on any partition of the named dataset's elements.
//!
//! Trust model ([`index`]): the footer index is advisory — absent or
//! unrecognizable, readers fall back to a linear scan, so any scda file
//! is an (anonymous) archive — but once present, the catalog section it
//! names is authoritative, and disagreement between catalog and sections
//! is a [`crate::error::corrupt::BAD_CATALOG`] error.
//!
//! Crash consistency ([`recover`]): because sections are appended
//! front-to-back and the trailer is written last, a crash mid-append
//! damages only the tail. [`recover::recover`] truncates the torn tail
//! and rebuilds a consistent trailer over the surviving sections, so
//! every dataset committed before the crash restores by name on any
//! rank count.
//!
//! [`restart`] builds versioned checkpoints on top: datasets named
//! `ckpt/<n>/<field>` restore by name on any rank count, several steps
//! per archive.

pub mod catalog;
pub mod dataset;
pub mod index;
pub mod recover;
pub mod restart;

pub use catalog::Archive;
pub use dataset::{DatasetInfo, DatasetKind};
pub use recover::{recover, recover_with, RecoveryAction, RecoveryReport};
