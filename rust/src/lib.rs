//! # scda — a minimal, serial-equivalent format for parallel I/O
//!
//! Rust implementation of the scda file format (Griesbach & Burstedde,
//! 2023): a file-oriented container for parallel, partition-independent
//! disk I/O. The file contents are invariant under linear repartition of
//! the data before writing — indistinguishable from writing in serial —
//! and a file can be read on any number of processes agreeing on any
//! partition of the stored element counts.
//!
//! The crate is layered exactly like the specification:
//!
//! * [`format`] — the byte-level layout of §2 (padding, count entries, the
//!   file header `F`, and the `I`/`B`/`A`/`V` data sections);
//! * [`codec`] — the optional per-element compression convention of §3
//!   (zlib/deflate + 76-column base64), built from scratch;
//! * [`par`] — the parallel substrate: partitions (§A.1), an MPI-like
//!   communicator abstraction, and a single shared file with positional
//!   window I/O;
//! * [`api`] — the functional interface of Appendix A
//!   (`fopen`/`fwrite_*`/`fread_*`/`fclose` with collective semantics);
//! * [`coordinator`] — checkpoint/restart management, a staged streaming
//!   write pipeline with backpressure, partition rebalancing, and metrics;
//! * [`runtime`] — the PJRT bridge that executes the AOT-compiled JAX/
//!   Pallas preconditioning graphs from `artifacts/*.hlo.txt` on the I/O
//!   hot path (with a bit-exact native fallback);
//! * [`mesh`] — a Morton-order AMR workload generator used by examples,
//!   tests and benchmarks.

pub mod api;
pub mod codec;
pub mod coordinator;
pub mod error;
pub mod format;
pub mod mesh;
pub mod par;
pub mod runtime;

pub mod bench_support;
pub mod capi;
pub mod cli;
pub mod testutil;

pub use error::{ferror_string, Result, ScdaError, ScdaErrorKind};
