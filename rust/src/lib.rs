//! # scda — a minimal, serial-equivalent format for parallel I/O
//!
//! Rust implementation of the scda file format (Griesbach & Burstedde,
//! 2023): a file-oriented container for parallel, partition-independent
//! disk I/O. The file contents are invariant under linear repartition of
//! the data before writing — indistinguishable from writing in serial —
//! and a file can be read on any number of processes agreeing on any
//! partition of the stored element counts.
//!
//! The crate is layered exactly like the specification:
//!
//! * [`format`] — the byte-level layout of §2 (padding, count entries, the
//!   file header `F`, and the `I`/`B`/`A`/`V` data sections);
//! * [`codec`] — the optional per-element compression convention of §3
//!   (zlib/deflate + 76-column base64), built from scratch;
//! * [`par`] — the parallel substrate: partitions (§A.1), an MPI-like
//!   communicator abstraction, and a single shared file with positional
//!   window I/O;
//! * [`api`] — the functional interface of Appendix A
//!   (`fopen`/`fwrite_*`/`fread_*`/`fclose` with collective semantics);
//! * [`coordinator`] — checkpoint/restart management, a staged streaming
//!   write pipeline with backpressure, partition rebalancing, and metrics;
//! * [`runtime`] — the PJRT bridge that executes the AOT-compiled JAX/
//!   Pallas preconditioning graphs from `artifacts/*.hlo.txt` on the I/O
//!   hot path (with a bit-exact native fallback);
//! * [`mesh`] — a Morton-order AMR workload generator used by examples,
//!   tests and benchmarks.
//!
//! # Codec pipeline
//!
//! The compression convention of §3.1 is *per element*: every element is
//! an independent `size + 'z' + zlib` frame, base64-armored. That makes
//! the codec the one embarrassingly parallel stage of the I/O path, and
//! this crate runs it on a shared worker pool
//! ([`par::pool::CodecPool`]):
//!
//! * **Architecture.** One persistent pool per process (lazily created,
//!   sized by `SCDA_CODEC_WORKERS` or the machine). Encoded writes
//!   ([`api::ScdaFile::write_array`] / `write_varray`), decoded reads,
//!   and the coordinator's streaming stage
//!   ([`coordinator::pipeline::map_ordered`]) all publish *jobs* of
//!   claimable element batches; idle workers steal batches from any
//!   published job, and the submitting thread always participates, so
//!   nested or concurrent submissions cannot deadlock. Per-file policy
//!   is [`api::CodecParallel`] (serial / shared pool / caller-owned
//!   pool).
//! * **Buffer-reuse contract.** Every codec stage has a `*_into`
//!   variant — [`codec::frame::encode_element_into`] /
//!   [`codec::frame::decode_element_into`],
//!   [`codec::zlib::zlib_compress_into`] /
//!   [`codec::zlib::zlib_decompress_into`],
//!   [`codec::deflate::deflate_into`], [`codec::inflate::inflate_into`],
//!   [`codec::base64::encode_lines_into`] — that appends to a
//!   caller-supplied buffer instead of allocating. Per-worker
//!   [`codec::frame::CodecScratch`] (LZ77 matcher + stage-1 buffer,
//!   thread-local on the persistent workers) makes the steady-state
//!   per-element allocation count zero; output bytes are a pure function
//!   of `(data, options)`, never of scratch history.
//! * **Serial equivalence.** Batches are formed in element order and
//!   their outputs stitched back in element order into a buffer sized
//!   once at its exact total. Since each element's encoding depends only
//!   on that element's bytes and the codec options, the concatenation is
//!   bit-identical to the serial loop at any worker count — and because
//!   a rank's elements are a contiguous range of the global element
//!   order, the same argument that makes the *format*
//!   partition-independent (offsets are pure functions of collective
//!   inputs, §2) extends to the codec layer: worker count and partition
//!   both drop out of the file bytes. `rust/tests/pipeline_equivalence.rs`
//!   asserts this property; `BENCH_codec.json` (emitted by the f1/t4
//!   benches and the ignored smoke test) tracks the throughput it buys.
//!
//! # I/O aggregation
//!
//! Serial equivalence constrains the *file bytes*, not the *syscall
//! shape*: a section may reach the file through any sequence of
//! positional writes, as long as the final bytes equal the serial
//! write's. The [`io`] subsystem exploits that freedom on both paths:
//!
//! * **Staging/flush contract (writes).** Every write the section paths
//!   issue — header rows, count rows, per-element data windows, padding
//!   — is *staged* as an `(offset, bytes)` extent in a per-rank
//!   [`io::WriteAggregator`] instead of hitting the file. Extents drain
//!   when the staging buffer would overflow, on [`api::ScdaFile::flush`],
//!   and on `close`; at drain time extents merge into maximal contiguous
//!   runs and each run is one `write_at`. Indirectly addressed element
//!   lists ([`api::DataSrc::Indirect`]) thereby gather into one syscall
//!   per contiguous file run — the `pwritev` effect. Writes at least as
//!   large as the buffer bypass staging (they are already one syscall),
//!   after draining the staged extents to keep write order.
//! * **Why serial equivalence is preserved.** Each staged extent is
//!   exactly a write the direct path would have issued; runs replay
//!   their extents in stage order, so overlaps resolve like direct
//!   `pwrite`s; and a rank only stages extents inside its own disjoint
//!   windows, so no cross-rank order exists to violate. The flushed file
//!   is therefore byte-identical to the unaggregated path at any buffer
//!   size, flush schedule and rank count
//!   (`rust/tests/io_coalescing.rs` asserts this at 1, 2 and 4 ranks).
//! * **Read sieving.** Read-mode files attach an [`io::ReadSieve`]: one
//!   large aligned `pread` fills a window that serves the many small
//!   section reads (prefixes, count rows, small payloads); large payload
//!   reads bypass it into exactly-sized buffers — or into a caller-owned
//!   buffer with no allocation at all via
//!   `api::ScdaFile::read_array_data_into` — and the file length is
//!   cached at open (read-only files cannot grow), eliminating the
//!   per-section `fstat`.
//! * **Tuning & observability.** [`io::IoTuning`] on
//!   [`api::ScdaFile::set_io_tuning`] sets the staging capacity and
//!   sieve window (`IoTuning::direct()` is the reference path);
//!   [`api::ScdaFile::io_stats`] exposes per-rank syscall counters, and
//!   `BENCH_io.json` (f1/t2 benches, ignored smoke test) tracks
//!   aggregated-vs-direct syscall counts and MiB/s.

pub mod api;
pub mod codec;
pub mod coordinator;
pub mod error;
pub mod format;
pub mod io;
pub mod mesh;
pub mod par;
pub mod runtime;

pub mod bench_support;
pub mod capi;
pub mod cli;
pub mod testutil;

pub use error::{ferror_string, Result, ScdaError, ScdaErrorKind};
