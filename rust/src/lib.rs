//! # scda — a minimal, serial-equivalent format for parallel I/O
//!
//! Rust implementation of the scda file format (Griesbach & Burstedde,
//! 2023): a file-oriented container for parallel, partition-independent
//! disk I/O. The file contents are invariant under linear repartition of
//! the data before writing — indistinguishable from writing in serial —
//! and a file can be read on any number of processes agreeing on any
//! partition of the stored element counts.
//!
//! The byte-level format itself — the grammar every section obeys, the
//! archive trailer conventions, and the invariants the tests assert — is
//! specified implementation-independently in `SPEC.md` at the repository
//! root; this crate documentation describes the *implementation* layered
//! on top of it. The command-line tool is documented in `docs/cli.md`.
//!
//! The crate is layered exactly like the specification:
//!
//! * [`format`] — the byte-level layout of §2 (padding, count entries, the
//!   file header `F`, and the `I`/`B`/`A`/`V` data sections);
//! * [`codec`] — the optional per-element compression convention of §3
//!   (zlib/deflate + 76-column base64), built from scratch;
//! * [`par`] — the parallel substrate: partitions (§A.1), an MPI-like
//!   communicator abstraction, and a single shared file with positional
//!   window I/O;
//! * [`api`] — the functional interface of Appendix A
//!   (`fopen`/`fwrite_*`/`fread_*`/`fclose` with collective semantics);
//! * [`coordinator`] — checkpoint/restart management, a staged streaming
//!   write pipeline with backpressure, partition rebalancing, and metrics;
//! * [`runtime`] — the PJRT bridge that executes the AOT-compiled JAX/
//!   Pallas preconditioning graphs from `artifacts/*.hlo.txt` on the I/O
//!   hot path (with a bit-exact native fallback);
//! * [`mesh`] — a Morton-order AMR workload generator used by examples,
//!   tests and benchmarks.
//!
//! # Codec pipeline
//!
//! The compression convention of §3.1 is *per element*: every element is
//! an independent `size + 'z' + zlib` frame, base64-armored. That makes
//! the codec the one embarrassingly parallel stage of the I/O path, and
//! this crate runs it on a shared worker pool
//! ([`par::pool::CodecPool`]):
//!
//! * **Architecture.** One persistent pool per process (lazily created,
//!   sized by `SCDA_CODEC_WORKERS` or the machine). Encoded writes
//!   ([`api::ScdaFile::write_array`] / `write_varray`), decoded reads,
//!   and the coordinator's streaming stage
//!   ([`coordinator::pipeline::map_ordered`]) all publish *jobs* of
//!   claimable element batches; idle workers steal batches from any
//!   published job, and the submitting thread always participates, so
//!   nested or concurrent submissions cannot deadlock. Per-file policy
//!   is [`api::CodecParallel`] (serial / shared pool / caller-owned
//!   pool).
//! * **Buffer-reuse contract.** Every codec stage has a `*_into`
//!   variant — [`codec::frame::encode_element_into`] /
//!   [`codec::frame::decode_element_into`],
//!   [`codec::zlib::zlib_compress_into`] /
//!   [`codec::zlib::zlib_decompress_into`],
//!   [`codec::deflate::deflate_into`], [`codec::inflate::inflate_into`],
//!   [`codec::base64::encode_lines_into`] — that appends to a
//!   caller-supplied buffer instead of allocating. Per-worker
//!   [`codec::frame::CodecScratch`] (LZ77 matcher + stage-1 buffer,
//!   thread-local on the persistent workers) makes the steady-state
//!   per-element allocation count zero; output bytes are a pure function
//!   of `(data, options)`, never of scratch history.
//! * **Serial equivalence.** Batches are formed in element order and
//!   their outputs stitched back in element order into a buffer sized
//!   once at its exact total. Since each element's encoding depends only
//!   on that element's bytes and the codec options, the concatenation is
//!   bit-identical to the serial loop at any worker count — and because
//!   a rank's elements are a contiguous range of the global element
//!   order, the same argument that makes the *format*
//!   partition-independent (offsets are pure functions of collective
//!   inputs, §2) extends to the codec layer: worker count and partition
//!   both drop out of the file bytes. `rust/tests/pipeline_equivalence.rs`
//!   asserts this property; `BENCH_codec.json` (emitted by the f1/t4
//!   benches and the ignored smoke test) tracks the throughput it buys.
//!
//! # I/O engines
//!
//! Serial equivalence constrains the *file bytes*, not the *syscall
//! shape*: a section may reach the file through any sequence of
//! positional writes — issued by any rank — as long as the final bytes
//! equal the serial write's. The [`io`] subsystem makes that freedom a
//! pluggable policy: every positional access of the section paths routes
//! through one [`io::IoEngine`] per open file, selected and parameterized
//! by [`io::IoTuning`] on [`api::ScdaFile::set_io_tuning`].
//!
//! * **Trait contract.** `write` may stage, ship or issue the bytes;
//!   after a collective `flush` (every rank, same order — `close` implies
//!   it) every staged byte is in the file and any deferred error has
//!   surfaced. Engines get a collective hook at each section boundary
//!   (`section_end`) — the natural synchronization points the API already
//!   has. Reads route through `view`/`read_vec`/`read_into` so one
//!   engine owns both directions of the transport.
//! * **[`io::DirectEngine`]** is the reference path: one syscall per
//!   logical access. Every other engine is property-tested byte-identical
//!   to it (`rust/tests/io_engines.rs`, at 1/2/4/8 ranks).
//! * **[`io::AggregatingEngine`]** (default) stages every write — header
//!   rows, count rows, element windows, padding — as an `(offset,
//!   bytes)` extent in a per-rank [`io::WriteAggregator`]; at drain time
//!   extents merge into maximal contiguous runs, one `write_at` each
//!   (indirect element lists gather into the `pwritev` effect). Reads
//!   attach an [`io::ReadSieve`]: one aligned window `pread` serves the
//!   many small metadata reads, and the window *adapts* — sequential
//!   scans double it (up to 8x), non-contiguous seeks halve it, with
//!   streak hysteresis so one stray access never flips it. Caller-buffer
//!   reads (`read_array_data_into` / `read_varray_data_into`) skip
//!   allocation entirely on the raw route.
//! * **[`io::CollectiveEngine`]** is two-phase collective buffering: the
//!   file is cut into stripes (stripe `s` owned by rank `s mod P`), and
//!   at collective points ranks ship staged extents over
//!   `Communicator::alltoall_bytes` to each stripe's owner, which merges
//!   all ranks' fragments and issues one syscall per contiguous run. Who
//!   writes a byte is invisible in the bytes (the same §2 argument that
//!   makes the format partition-independent), fragments of different
//!   ranks never overlap (disjoint windows), and one rank's fragments
//!   replay in stage order — so the re-homing is exact. Payoff: write
//!   syscalls become a function of file size, not of section
//!   interleaving (asserted in `rust/tests/io_engines.rs`).
//! * **Async (overlapped) flush.** With `IoTuning::async_flush`, drained
//!   runs execute as owned background jobs on the shared codec pool
//!   ([`par::pool::CodecPool::spawn`]), so `pwrite`s overlap encoding.
//!   Safe because the section paths write every byte exactly once, so
//!   concurrent runs are disjoint. Errors are recorded, never dropped:
//!   they surface at the next `flush`/`close`, via
//!   [`api::ScdaFile::take_error`], or — if the file is dropped first —
//!   through [`io::take_drop_error`] (§A.6: file errors must never be
//!   silently lost).
//! * **Observability.** [`api::ScdaFile::io_stats`] counts this rank's
//!   syscalls; [`api::ScdaFile::engine_stats`] adds shipped bytes (total
//!   and per exchange), exchanges, drain batches and sieve refills;
//!   `BENCH_io.json` (f1/t2/t3 benches, smoke tests) tracks MiB/s and
//!   syscall counts for all three engines, sync and async.
//!
//! # Collective reads & range reads
//!
//! Since PR 5 the freedom the I/O engines exploit is symmetric: *who
//! issues a `pread` is as invisible in the returned bytes as who issued
//! the `pwrite`*.
//!
//! * **The collective read gather** ([`io::IoEngine::read_window`],
//!   implemented by [`io::CollectiveEngine`]) is the read-side dual of
//!   the two-phase write. At every collective data read — array
//!   windows, varray payloads, compressed blobs, size-row windows of
//!   range reads — each rank announces its `(offset, length)` request
//!   with one allgather; the rank owning stripe `s = s mod P` issues
//!   **one `pread` per contiguous run of requested stripes** and
//!   scatters the fragments to the requesting ranks over
//!   `Communicator::alltoall_bytes`. Read syscalls therefore track the
//!   *bytes touched* (the union of requested windows), never the rank
//!   count or the section interleaving — `rust/tests/io_read_gather.rs`
//!   asserts the invariance at P = 2/4/8, mirroring the write-side
//!   syscall invariant. Skipped reads (`want = false`) participate with
//!   empty requests, so the collective discipline is preserved; lone
//!   large requests bypass the exchange (they are already one syscall);
//!   identical requests from many ranks dedupe to a single owner-side
//!   read; and a failed owner `pread` ships in-band so the error
//!   surfaces on every rank. Per-rank engines serve the same hook
//!   through their sieve routing — the file bytes returned are
//!   identical under every engine (property-tested at 1/2/4/8 ranks).
//! * **Catalog-seeded range reads**
//!   ([`archive::Archive::read_range`] /
//!   [`archive::Archive::read_varray_range`], CLI
//!   `scda cat --range <name> <first> <count>`) read elements
//!   `[first, first + count)` of a named dataset by seeding the window
//!   from the catalog entry's `offset`/`byte_len` instead of replaying
//!   the section stream: a raw fixed-size array touches *no size rows
//!   at all* (the window is `payload + first·E`), and variable or
//!   encoded datasets read only the size rows `[0, first + count)` that
//!   the locating prefix sum requires — never a row at or past the
//!   range end, never payload outside the window
//!   (`rust/tests/archive_range.rs` asserts the byte counts via
//!   [`par::pfile::IoStats`]). Every rank receives the range, and under
//!   [`io::IoTuning::collective`] the identical per-rank requests
//!   collapse into one stripe-owner read set.
//! * **Observability.** [`io::EngineStats`] gains `read_exchanges`,
//!   `gathered_bytes` and `gather_preads`; `BENCH_io.json` adds a
//!   read-side engine sweep (`read_engine_*` entries), and restore
//!   paths can record reads via
//!   [`coordinator::checkpoint::read_checkpoint_tuned`]
//!   (`Metrics::{read_calls, bytes_read, bytes_gathered}`).
//!
//! # Data-plane speed
//!
//! The data plane — the bytes' path from caller memory through the
//! codec into the engines — is tuned end to end, always under the same
//! non-negotiable: file bytes stay bit-identical at any rank and worker
//! count. `BENCH_codec.json` / `BENCH_io.json` track what each layer
//! buys.
//!
//! * **Wide LZ77 match loop** ([`codec::lz77`]): candidate matches
//!   extend by `u64` block compares (one XOR + trailing-zero count per
//!   8 bytes), candidates come from a 4-byte rolling hash chain with
//!   head-only insertion inside matches — the classic "lazy but not
//!   quadratic" shape, with identical token output to the byte-at-a-time
//!   loop (pinned by `rust/tests/compression_conformance.rs`).
//! * **Multi-symbol Huffman decode** ([`codec::huffman`],
//!   [`codec::bitio`]): inflate decodes through a two-level
//!   lookup-table (a root table indexed by the next ~10 bits resolving
//!   short codes in one probe, overflow sub-tables for long codes) fed
//!   by a ≥32-bit bit reservoir refilled in one unaligned load —
//!   differential-tested against the canonical tree walk over random
//!   and adversarial code sets.
//! * **Preconditioning stage** ([`codec::Precond`], SPEC §5.4): an
//!   optional, format-visible byte-shuffle (by element width) plus
//!   per-plane delta ahead of deflate, carried per frame by the `'p'`
//!   marker + descriptor byte. Self-describing on the wire (readers
//!   auto-decode; the Python reference implementation interoperates
//!   both directions), surfaced via [`api::ScdaFile::set_precondition`],
//!   [`coordinator::checkpoint::CheckpointOptions`] and the CLI
//!   (`demo-write --frame-precond`, `ls --json`), and recorded as the
//!   advisory catalog token `p=<w>[d]`.
//! * **Zero-copy extent staging** ([`io::Payload`]): staged extents are
//!   `Owned` (encoded buffers move, never copy, into the aggregator via
//!   the `write_owned` route) or `Pinned` (stable caller bytes), so the
//!   write path's steady-state copy count drops to the one unavoidable
//!   kernel copy; drains and the collective exchange borrow payload
//!   slices instead of materializing runs.
//! * **Staging-affinity stripe ownership** ([`io::CollectiveEngine`]):
//!   each exchange elects every stripe's owner as the rank that staged
//!   the most bytes for it (ties prefer the uniform `s mod P` owner),
//!   so shipped bytes track actual misalignment instead of the worst
//!   case — majority-local workloads keep their bytes on-rank. The
//!   election is deterministic from collective inputs, and owner-side
//!   runs still split at stripe boundaries, preserving the engine's
//!   syscall-count invariants.
//! * **Lockstep scan dedup** ([`api::ScdaFile::toc`]): table-of-contents
//!   scans mark their header reads as lockstep-identical across ranks,
//!   so under the collective engine `P` identical metadata `pread`s
//!   dedupe to one owner-side read — scan syscalls no longer scale with
//!   the rank count (`rust/tests/io_read_gather.rs`).
//!
//! # Archive layer
//!
//! The paper leaves "the definition of variables … and self-describing
//! headers" to a layer *on top of* scda; [`archive`] is that layer. An
//! [`archive::Archive`] names each logical section (the dataset name is
//! exactly the section's user string) and, at
//! [`archive::Archive::finish`], appends two ordinary sections: a `B`
//! section `scda:catalog` whose payload is an ASCII table mapping each
//! name to `{offset, byte_len, kind, elem_count, elem_size, encoded}`,
//! and an `I` section `scda:index` whose 32 data bytes are the catalog's
//! offset in ASCII decimal.
//!
//! * **Encoding rule.** Catalog and index are ASCII text inside ordinary
//!   sections, so the file stays pure, verifiable scda
//!   ([`api::verify_bytes`] accepts it unchanged; foreign readers see
//!   two more sections) and stays ASCII wherever its data is ASCII.
//! * **Why O(1).** An inline section is exactly 96 unpadded bytes, so
//!   the index is always the file's last 96 bytes: open reads footer →
//!   catalog, and [`archive::Archive::open_dataset`] seeks straight to
//!   the named section — a constant number of header reads where
//!   [`api::ScdaFile::toc`] scans every section (`toc` itself takes the
//!   catalog fast path when an index is present). Reads on any rank
//!   count then agree on any partition of the dataset's elements — the
//!   catalog adds addressing, not a data path.
//! * **Trust model.** The index is *advisory*: if the last 96 bytes are
//!   not an `scda:index` section, readers fall back to a linear scan
//!   (any scda file is an anonymous archive). Once the footer names a
//!   catalog, the catalog section is *authoritative*, and catalog ↔
//!   section disagreement is a `corrupt::BAD_CATALOG` error — never a
//!   silent fallback, never a panic.
//! * **Checkpoints.** [`archive::restart`] versions checkpoints as
//!   named datasets (`ckpt/<n>/<field>`, several steps per archive);
//!   [`coordinator::checkpoint`] writes and restores through it, so
//!   restart addresses fields by name on any rank count.
//!   `BENCH_archive.json` (t3 bench) tracks indexed-vs-scan access.
//!
//! # Crash consistency
//!
//! scda writers only append, so a crash damages only a suffix of the
//! file (SPEC Appendix A); the crash-consistency subsystem turns that
//! byte-level fact into operational guarantees:
//!
//! * **Deterministic fault plane** ([`io::FaultPlan`]): seedable
//!   injected faults — short/torn writes, transient-then-succeed
//!   errors, per-rank persistent failures, and in-engine power cuts
//!   (`FaultPlan::seeded_crash`, which truncates the file at the torn
//!   byte) — armed per file via [`api::ScdaFile::set_fault_plan`], so
//!   every failure scenario in the test suite is replayable from a
//!   seed.
//! * **Collective error agreement**: transient (`EINTR`-class) faults
//!   are absorbed by bounded retry inside the engines; persistent
//!   faults are exchanged at the next collective boundary so every rank
//!   surfaces the *same* [`ScdaError`] from `flush`/`close` — no rank
//!   returns success while another fails, and a sticky prior failure
//!   re-surfaces at `close` (`rust/tests/io_faults.rs` asserts the
//!   agreement at 2 and 4 ranks). Errors from dropped files land in the
//!   bounded drop sink ([`io::take_drop_error`],
//!   [`io::drop_error_stats`] for eviction accounting).
//! * **Torn-tail recovery** ([`archive::recover`], CLI `scda recover`):
//!   walk the longest verify-clean prefix, drop the stale trailer and
//!   any dangling convention-pair half, truncate, rebuild a fresh
//!   catalog + footer index over the survivors, and gate on
//!   re-verification; intact files (archives *and* plain scda) are left
//!   byte-identical. The soak suite (`rust/tests/recover_soak.rs`)
//!   sweeps bisected truncation offsets at 1/2/4/8 writer ranks plus
//!   seeded in-engine crashes, asserting every crash point recovers to
//!   exactly the committed-prefix dataset set, restorable on a
//!   different rank count; `BENCH_recover.json` tracks the sweep.
//!
//! # Read service
//!
//! One archive, many readers: [`runtime::ArchiveReadService`] opens an
//! archive once and mints independent [`runtime::ServiceSession`]s —
//! full read-mode [`archive::Archive`]s over shared plumbing, so every
//! range-read guarantee above applies verbatim to served responses.
//!
//! * **Shared catalog.** Header and catalog are read and parsed once at
//!   service open; minting a session costs *zero* syscalls (no open, no
//!   header read, no footer read — asserted in
//!   `rust/tests/serve.rs`).
//! * **Shared page cache** ([`io::PageCache`]): one refcounted pool of
//!   fixed-size pages under a global memory budget, clock (second
//!   chance) eviction with scan resistance — pages enter the ring
//!   unreferenced; only a re-touch earns a second pass. Each session
//!   keeps its own [`io::ReadSieve`] — window size and adaptivity
//!   hysteresis are strictly per session — but refills route through
//!   the shared pool, so overlapping requests across sessions hit
//!   resident pages instead of the disk.
//! * **Coalesced misses.** Concurrent misses on the same page collapse
//!   to one fill (single-flight: the first toucher claims, the rest
//!   wait on the filled page), and a run of absent pages fills with one
//!   gather `pread` — the in-process analogue of the collective read
//!   gather's P-fold dedup. `rust/tests/serve.rs` pins the hot-page
//!   case: 8 concurrent sessions, one page, exactly one `pread`.
//! * **Protocol.** [`runtime::ReadRequest`] names a dataset and an
//!   element range; [`runtime::ServiceSession::serve`] dispatches on
//!   the catalog kind to [`archive::Archive::read_range`] /
//!   `read_varray_range` (partitioned form:
//!   [`runtime::ServiceSession::serve_partitioned`]), so served bytes
//!   are identical to direct archive reads *by construction* — and
//!   `rust/tests/serve.rs` asserts the identity at 1/2/4/8 concurrent
//!   sessions under eviction-forcing budgets.
//! * **Observability & bench.** [`io::CacheStats`] (hits, misses,
//!   evictions, single-flight waits) surfaces through
//!   [`runtime::ArchiveReadService::cache_stats`],
//!   [`io::EngineStats`] and [`coordinator::Metrics`]; the t5 bench and
//!   `scda serve-bench` sweep sessions x budget over a zipfian mix
//!   against the per-session-sieve baseline, tracking req/s, p50/p99
//!   latency and pread counts in `BENCH_serve.json` (shared preads
//!   track the workload's *unique bytes*, not the session count).
//! * **Async-flush isolation.** Writers can hand a private
//!   [`par::pool::CodecPool`] to [`api::ScdaFile::set_flush_pool`], so
//!   a file's background flush jobs stop competing with the shared
//!   codec pool that read sessions and encoders draw from.
//!
//! # Observability
//!
//! Serial equivalence guarantees the *what* (file bytes), never the
//! *where* (wall time); the [`obs`] subsystem attributes time to the
//! pipeline's phases without perturbing a single file byte
//! (`rust/tests/obs_trace.rs` asserts byte identity with the tracer
//! enabled).
//!
//! * **Span tracing** ([`obs::Tracer`]): a lock-free per-rank recorder —
//!   RAII guards stamp a monotonic clock into a fixed-capacity
//!   drop-oldest ring (dropped spans are counted, never silently lost),
//!   and a disabled tracer costs one `Option` branch per site. Installed
//!   via [`api::ScdaFile::set_tracer`] and
//!   [`runtime::ReadServiceConfig`]; instrumented phases span the whole
//!   pipeline: section writes/reads, collective stage/exchange/pwrite
//!   and gather/scatter, page-cache fills and single-flight waits,
//!   served requests, and recovery phases (the [`obs::SpanKind`]
//!   registry).
//! * **Cross-rank merge.** At `close`, ranks exchange their span frames
//!   over the existing communicator collectives and rank 0 holds one
//!   time-ordered timeline ([`obs::Tracer::merged`]) — the collective
//!   discipline the format already imposes is exactly what makes the
//!   merge safe.
//! * **Latency histograms** ([`obs::Hist`]): HDR-style log-bucketed
//!   (2^k) buckets with p50/p90/p99/max readout, accumulated per span
//!   kind — and the *same* implementation computes the serve bench's
//!   p50/p99 columns, so there is one definition of "p99" in the tree.
//! * **Timeline export** ([`obs::export`], CLI `scda trace`): the merged
//!   timeline renders as Chrome trace-event JSON (one row per rank in
//!   the trace viewer); `scda stats --json` and the `--stats-json`
//!   flags dump the flat counters machine-readably. See
//!   `docs/observability.md` for setup and the span-kind registry.
//!
//! # AMR scenario
//!
//! [`runtime::scenario`] closes the loop: a deterministic, seedable AMR
//! churn driver that runs the whole stack the way the paper's motivating
//! applications do — N cycles of refine ([`mesh::ring_mesh`] around a
//! golden-angle moving front) → byte-balanced rebalance
//! ([`coordinator::rebalance::by_bytes`] + `exchange`, verified against
//! a direct recomputation) → versioned checkpoint
//! ([`archive::restart`]) — then a seeded mid-write crash replayed
//! serially into a sacrificial sibling (serial equivalence makes the
//! serial torn prefix stand for any writer count's), recovery, and
//! restore-by-name on a *different* rank count with every byte compared
//! to an independently recomputed reference. Phases record
//! refine/rebalance/restore spans; `scda amr-bench` is the CLI face and
//! `BENCH_amr.json` the committed snapshot (`bench_support::amr_bench`).
//! The soak (`rust/tests/amr_scenario.rs`) sweeps writer ranks 1/2/4/8 ×
//! bisected crash points × restore-P' ≠ P; see `docs/amr.md`.

pub mod api;
pub mod archive;
pub mod codec;
pub mod coordinator;
pub mod error;
pub mod format;
pub mod io;
pub mod mesh;
pub mod obs;
pub mod par;
pub mod runtime;

pub mod bench_support;
pub mod capi;
pub mod cli;
pub mod testutil;

pub use error::{ferror_string, Result, ScdaError, ScdaErrorKind};
