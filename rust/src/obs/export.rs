//! Chrome trace-event JSON export of a span timeline.
//!
//! Emits the trace-event format the Chrome/Chromium trace viewer
//! (`chrome://tracing`, or <https://ui.perfetto.dev> in legacy mode)
//! loads directly: an object with a `traceEvents` array of complete
//! (`"ph": "X"`) events. Each span becomes one event with
//! `pid` 0 and `tid` = the recording rank, so the viewer shows one row
//! per rank; timestamps and durations are microseconds (floats), as the
//! format requires. Complete events are self-balanced — no B/E pairing
//! to mismatch — which is what `tools/check_trace.py` verifies in CI.

use std::path::Path;

use crate::obs::trace::Span;

fn push_event(out: &mut String, s: &Span) {
    // Span names are the fixed kind registry — no escaping needed.
    out.push_str(&format!(
        "    {{\"name\": \"{}\", \"cat\": \"scda\", \"ph\": \"X\", \"pid\": 0, \"tid\": {}, \
         \"ts\": {:.3}, \"dur\": {:.3}, \"args\": {{\"id\": {}, \"parent\": {}, \"bytes\": {}, \
         \"detail\": {}}}}}",
        s.kind.name(),
        s.rank,
        s.t_start_ns as f64 / 1e3,
        s.duration_ns() as f64 / 1e3,
        s.id,
        s.parent,
        s.bytes,
        s.detail,
    ));
}

/// Render a span list as Chrome trace-event JSON.
pub fn chrome_trace_json(spans: &[Span]) -> String {
    let mut out = String::from("{\n  \"displayTimeUnit\": \"ms\",\n  \"traceEvents\": [\n");
    for (i, s) in spans.iter().enumerate() {
        push_event(&mut out, s);
        if i + 1 < spans.len() {
            out.push(',');
        }
        out.push('\n');
    }
    out.push_str("  ]\n}\n");
    out
}

/// Write a span list to `path` as Chrome trace-event JSON, creating
/// parent directories as needed.
pub fn write_chrome_trace(path: &Path, spans: &[Span]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, chrome_trace_json(spans))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::SpanKind;

    fn span(rank: u32, id: u64, kind: SpanKind) -> Span {
        Span {
            id,
            parent: 0,
            rank,
            kind,
            t_start_ns: 1_500,
            t_end_ns: 4_000,
            bytes: 64,
            detail: 2,
        }
    }

    #[test]
    fn renders_complete_events_with_rank_rows() {
        let spans = [span(0, 1, SpanKind::Exchange), span(3, 1, SpanKind::Pwrite)];
        let json = chrome_trace_json(&spans);
        assert!(json.contains("\"traceEvents\""));
        assert!(json.contains("\"name\": \"exchange\""));
        assert!(json.contains("\"name\": \"pwrite\""));
        assert!(json.contains("\"ph\": \"X\""));
        assert!(json.contains("\"tid\": 3"));
        assert!(json.contains("\"ts\": 1.500"));
        assert!(json.contains("\"dur\": 2.500"));
        // Structural sanity: balanced braces/brackets, no trailing comma.
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        assert!(!json.contains(",\n  ]"));
    }

    #[test]
    fn empty_timeline_is_valid_json() {
        let json = chrome_trace_json(&[]);
        assert!(json.contains("\"traceEvents\": [\n  ]"));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
    }
}
