//! Observability: span tracing, latency histograms and timeline export.
//!
//! The serial-equivalence guarantee means file bytes never tell you
//! *where* time went — a collective write serialized behind one slow
//! stripe owner is bit-identical to a perfectly overlapped one. This
//! subsystem attributes wall time to the pipeline's phases without
//! perturbing those bytes:
//!
//! * [`trace`] — the lock-free per-rank span recorder ([`Tracer`],
//!   RAII [`SpanGuard`]s, drop-oldest ring, the [`SpanKind`] registry)
//!   plus the close-time cross-rank merge helpers;
//! * [`hist`] — HDR-style log-bucketed latency histograms
//!   ([`Hist`]) with p50/p90/p99/max readout, accumulated per span
//!   kind and shared with the serve bench (one definition of "p99");
//! * [`export`] — the Chrome trace-event JSON timeline exporter.
//!
//! Instrumentation hangs off an `Arc<Tracer>` installed via
//! `ScdaFile::set_tracer` or `ReadServiceConfig::tracer`; with no
//! tracer installed every site is a single `Option` branch. See
//! `docs/observability.md` for setup, the span-kind registry and the
//! trace-viewer howto, and the `scda trace` CLI subcommand for a
//! one-shot instrumented demo workload.

pub mod export;
pub mod hist;
pub mod trace;

pub use export::{chrome_trace_json, write_chrome_trace};
pub use hist::Hist;
pub use trace::{histogram_table, Span, SpanGuard, SpanKind, Tracer};
