//! Log-bucketed latency histograms: HDR-style power-of-two buckets with
//! p50/p90/p99/max readout.
//!
//! A [`Hist`] is a fixed array of 65 atomic counters. Bucket `b >= 1`
//! covers the value octave `[2^(b-1), 2^b - 1]`; bucket 0 holds exact
//! zeros. Recording is one `leading_zeros` plus two relaxed atomic
//! increments — cheap enough for per-request hot paths — and the
//! structure is wait-free for concurrent writers, so one histogram can
//! be shared by every session thread of the read service.
//!
//! **Readout semantics:** [`Hist::percentile`] returns the *upper edge*
//! of the bucket containing the requested rank, clamped to the largest
//! value actually observed. The reported quantile is therefore an upper
//! bound on the true quantile and lies within one octave (a factor of
//! two) of it. That is the precision/footprint trade every log-bucketed
//! histogram makes; it is plenty to drive tail-latency tripwires (the
//! serve bench's p99 column) while keeping the recorder allocation-free.
//! Concurrent readers see a consistent-enough view: counters are read
//! relaxed, so a percentile taken mid-run may lag in-flight records by
//! a few samples.

use std::sync::atomic::{AtomicU64, Ordering};

/// Bucket count: one per octave of `u64` plus the zero bucket.
pub const BUCKETS: usize = 65;

/// The bucket index covering `v`: 0 for 0, otherwise `1 + floor(log2 v)`.
pub fn bucket_of(v: u64) -> usize {
    (u64::BITS - v.leading_zeros()) as usize
}

/// The largest value bucket `b` can hold (`2^b - 1`; `u64::MAX` for the
/// top bucket).
pub fn bucket_upper(b: usize) -> u64 {
    if b == 0 {
        0
    } else if b >= 64 {
        u64::MAX
    } else {
        (1u64 << b) - 1
    }
}

/// A log-bucketed histogram of `u64` samples (nanoseconds, byte counts —
/// any nonnegative magnitude). See the module docs for the readout
/// semantics.
#[derive(Debug)]
pub struct Hist {
    buckets: Box<[AtomicU64]>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Hist {
    fn default() -> Self {
        Hist::new()
    }
}

impl Hist {
    pub fn new() -> Hist {
        Hist {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one sample (wait-free; relaxed ordering).
    pub fn record(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Fold another histogram's counts into this one.
    pub fn merge_from(&self, other: &Hist) {
        for (mine, theirs) in self.buckets.iter().zip(other.buckets.iter()) {
            mine.fetch_add(theirs.load(Ordering::Relaxed), Ordering::Relaxed);
        }
        self.count.fetch_add(other.count.load(Ordering::Relaxed), Ordering::Relaxed);
        self.sum.fetch_add(other.sum.load(Ordering::Relaxed), Ordering::Relaxed);
        self.max.fetch_max(other.max.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Arithmetic mean of the recorded samples (exact, not bucketed).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// The quantile-`q` readout: the upper edge of the bucket holding the
    /// `ceil(q * count)`-th smallest sample, clamped to the observed max.
    /// Returns 0 on an empty histogram.
    pub fn percentile(&self, q: f64) -> u64 {
        let count = self.count();
        if count == 0 {
            return 0;
        }
        let target = ((count as f64) * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (b, bucket) in self.buckets.iter().enumerate() {
            seen += bucket.load(Ordering::Relaxed);
            if seen >= target {
                return bucket_upper(b).min(self.max());
            }
        }
        self.max()
    }

    /// p50 shorthand in microseconds (samples recorded as nanoseconds).
    pub fn p50_us(&self) -> f64 {
        self.percentile(0.50) as f64 / 1e3
    }

    /// p99 shorthand in microseconds (samples recorded as nanoseconds).
    pub fn p99_us(&self) -> f64 {
        self.percentile(0.99) as f64 / 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges_are_octaves() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), 64);
        for b in 1..64 {
            assert_eq!(bucket_of(bucket_upper(b)), b);
            assert_eq!(bucket_of(bucket_upper(b) + 1), b + 1);
        }
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(64), u64::MAX);
    }

    #[test]
    fn percentile_is_an_upper_bound_within_one_octave() {
        let h = Hist::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.max(), 1000);
        let p50 = h.percentile(0.50);
        // True p50 is 500; the bucketed readout must bound it from above
        // within one octave.
        assert!(p50 >= 500, "p50 {p50} under-reports");
        assert!(p50 < 1000, "p50 {p50} not within an octave of 500");
        // The top quantiles clamp to the observed max.
        assert_eq!(h.percentile(1.0), 1000);
        assert_eq!(h.percentile(0.999), 1000);
    }

    #[test]
    fn empty_and_zero_samples() {
        let h = Hist::new();
        assert_eq!(h.percentile(0.99), 0);
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        h.record(0);
        assert_eq!(h.percentile(0.5), 0);
        assert_eq!(h.max(), 0);
    }

    #[test]
    fn merge_accumulates() {
        let a = Hist::new();
        let b = Hist::new();
        for v in [10u64, 20, 30] {
            a.record(v);
        }
        for v in [1000u64, 2000] {
            b.record(v);
        }
        a.merge_from(&b);
        assert_eq!(a.count(), 5);
        assert_eq!(a.max(), 2000);
        assert!(a.percentile(0.99) >= 2000);
        assert!((a.mean() - (10.0 + 20.0 + 30.0 + 1000.0 + 2000.0) / 5.0).abs() < 1e-9);
    }

    #[test]
    fn concurrent_recording_counts_everything() {
        use std::sync::Arc;
        let h = Arc::new(Hist::new());
        std::thread::scope(|sc| {
            for t in 0..4 {
                let h = Arc::clone(&h);
                sc.spawn(move || {
                    for i in 0..1000u64 {
                        h.record(t * 1000 + i);
                    }
                });
            }
        });
        assert_eq!(h.count(), 4000);
        assert_eq!(h.max(), 3999);
    }
}
