//! Lock-free per-rank span recorder.
//!
//! A [`Tracer`] owns a fixed-capacity ring of completed [`Span`]s.
//! Recording is RAII: [`Tracer::start`] reads the monotonic clock,
//! allocates an id and pushes the span onto a per-thread parent stack;
//! dropping the returned [`SpanGuard`] reads the clock again and
//! publishes the finished span into the ring with a per-slot seqlock —
//! no mutex anywhere on the record path. When the ring is full the
//! oldest spans are overwritten (drop-oldest); [`Tracer::dropped`]
//! counts the casualties so a truncated timeline is never mistaken for
//! a complete one.
//!
//! **Zero overhead when disabled.** The tracer is installed as an
//! `Option<Arc<Tracer>>` (see `ScdaFile::set_tracer` and
//! `ReadServiceConfig`); every instrumentation site is
//! `tracer.as_ref().map(|t| Tracer::start(t, kind))`, which with `None`
//! is a branch on a discriminant — no clock read, no allocation, no
//! atomic.
//!
//! **Clock.** All tracers in a process share one monotonic epoch
//! (first use of [`now_ns`]), so spans from the in-process rank
//! simulation substrate land on one comparable timeline. Across real
//! machines the per-rank clocks would be skewed; the merged timeline is
//! then per-rank-ordered only, which the Chrome trace viewer renders
//! fine (one row per rank).
//!
//! **Cross-rank merge.** Span ids are unique per rank, not globally:
//! `(rank, id)` is the key of a merged timeline. `ScdaFile::close`
//! allgathers every rank's [`encode_spans`] frame and deposits the
//! decoded, time-ordered union on rank 0's tracer
//! ([`Tracer::set_merged`]/[`Tracer::merged`]). Installing a tracer is
//! therefore collective: every rank of a communicator installs one, or
//! none does.

use std::cell::{Cell, UnsafeCell};
use std::sync::atomic::{fence, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::obs::hist::Hist;

/// What an instrumented region is; the span-kind registry (also
/// documented in `docs/observability.md`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum SpanKind {
    /// One logical section write (`api/writer.rs`); bytes = payload.
    SectionWrite = 0,
    /// One logical section data read (`api/reader.rs`); bytes = payload.
    SectionRead = 1,
    /// Staging one write extent into an engine buffer (`io/engine.rs`).
    Stage = 2,
    /// One two-phase collective write exchange (`io/collective.rs`);
    /// bytes = extents shipped off-rank by this rank.
    Exchange = 3,
    /// One positioned write syscall dispatched by an engine (sync drain
    /// or async flush batch); bytes = run length.
    Pwrite = 4,
    /// One collective read gather (`io/collective.rs`); bytes = window.
    ReadGather = 5,
    /// One owner-side pread serving gathered stripes; bytes read.
    GatherPread = 6,
    /// The fragment scatter (`alltoall`) phase of a read gather.
    Scatter = 7,
    /// One page-cache fill pread (`io/cache.rs`); bytes filled.
    CacheFill = 8,
    /// Blocking on another thread's in-flight fill (`io/cache.rs`).
    CacheWait = 9,
    /// One `ReadRequest` served by a service session
    /// (`runtime/service.rs`); bytes = response payload, detail =
    /// session id.
    Serve = 10,
    /// Recovery phase: the verified-prefix walk (`archive/recover.rs`).
    RecoverWalk = 11,
    /// Recovery phase: truncate + rescan + fresh trailer append.
    RecoverRebuild = 12,
    /// Recovery phase: the gating end-to-end re-verification.
    RecoverVerify = 13,
    /// AMR scenario phase: mesh refinement (`runtime/scenario.rs`);
    /// bytes = element count of the refined mesh.
    Refine = 14,
    /// AMR scenario phase: byte-balanced repartition + payload
    /// exchange (`coordinator/rebalance.rs`); bytes = payload moved
    /// through the exchange by this rank.
    Rebalance = 15,
    /// AMR scenario phase: restore-by-name of one checkpoint step on
    /// the reader rank count; bytes = restored payload, detail = step.
    Restore = 16,
}

impl SpanKind {
    pub const ALL: [SpanKind; 17] = [
        SpanKind::SectionWrite,
        SpanKind::SectionRead,
        SpanKind::Stage,
        SpanKind::Exchange,
        SpanKind::Pwrite,
        SpanKind::ReadGather,
        SpanKind::GatherPread,
        SpanKind::Scatter,
        SpanKind::CacheFill,
        SpanKind::CacheWait,
        SpanKind::Serve,
        SpanKind::RecoverWalk,
        SpanKind::RecoverRebuild,
        SpanKind::RecoverVerify,
        SpanKind::Refine,
        SpanKind::Rebalance,
        SpanKind::Restore,
    ];
    pub const COUNT: usize = SpanKind::ALL.len();

    pub fn name(self) -> &'static str {
        match self {
            SpanKind::SectionWrite => "section_write",
            SpanKind::SectionRead => "section_read",
            SpanKind::Stage => "stage",
            SpanKind::Exchange => "exchange",
            SpanKind::Pwrite => "pwrite",
            SpanKind::ReadGather => "read_gather",
            SpanKind::GatherPread => "gather_pread",
            SpanKind::Scatter => "scatter",
            SpanKind::CacheFill => "cache_fill",
            SpanKind::CacheWait => "cache_wait",
            SpanKind::Serve => "serve",
            SpanKind::RecoverWalk => "recover_walk",
            SpanKind::RecoverRebuild => "recover_rebuild",
            SpanKind::RecoverVerify => "recover_verify",
            SpanKind::Refine => "refine",
            SpanKind::Rebalance => "rebalance",
            SpanKind::Restore => "restore",
        }
    }

    pub fn from_u8(b: u8) -> Option<SpanKind> {
        SpanKind::ALL.get(b as usize).copied()
    }
}

/// One completed instrumented region.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// Nonzero, unique within one rank's tracer.
    pub id: u64,
    /// Id of the enclosing span on the same thread, 0 for roots.
    pub parent: u64,
    /// The recording rank's tag (one tracer per rank).
    pub rank: u32,
    pub kind: SpanKind,
    /// Monotonic nanoseconds since the process trace epoch.
    pub t_start_ns: u64,
    pub t_end_ns: u64,
    /// Payload bytes the region moved (0 where not meaningful).
    pub bytes: u64,
    /// Free-form numeric detail (session id, request index, offset...).
    pub detail: u64,
}

impl Span {
    fn zero() -> Span {
        Span {
            id: 0,
            parent: 0,
            rank: 0,
            kind: SpanKind::SectionWrite,
            t_start_ns: 0,
            t_end_ns: 0,
            bytes: 0,
            detail: 0,
        }
    }

    pub fn duration_ns(&self) -> u64 {
        self.t_end_ns.saturating_sub(self.t_start_ns)
    }
}

/// Monotonic nanoseconds since the (lazily pinned) process trace epoch.
pub fn now_ns() -> u64 {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

thread_local! {
    /// The innermost open span id on this thread (parent for new spans).
    static CURRENT_PARENT: Cell<u64> = const { Cell::new(0) };
}

/// One ring slot: a per-slot seqlock. `seq == 2n + 1` marks the write of
/// record number `n` in progress; `seq == 2n + 2` marks it published.
struct Slot {
    seq: AtomicU64,
    span: UnsafeCell<Span>,
}

/// Fixed-capacity drop-oldest span ring with seqlock publication:
/// writers reserve a monotonically increasing record number with one
/// `fetch_add`, readers ([`SpanRing::snapshot`]) skip slots whose
/// sequence shows a concurrent overwrite. No locks on either side.
struct SpanRing {
    slots: Box<[Slot]>,
    /// Total records ever pushed (the next record number).
    next: AtomicU64,
}

// SAFETY: the only access to `Slot::span` is under the per-slot seqlock
// protocol — writers bracket the write with odd/even `seq` stores
// (Release), readers validate `seq` is the published even value for the
// exact record number both before and after copying (Acquire + fence),
// discarding torn reads. Two writers can only collide on a slot if the
// ring laps itself within one push, which would need `capacity`
// concurrent recorders.
unsafe impl Sync for SpanRing {}
unsafe impl Send for SpanRing {}

impl SpanRing {
    fn new(capacity: usize) -> SpanRing {
        let cap = capacity.max(1);
        let slots: Box<[Slot]> = (0..cap)
            .map(|_| Slot { seq: AtomicU64::new(0), span: UnsafeCell::new(Span::zero()) })
            .collect();
        SpanRing { slots, next: AtomicU64::new(0) }
    }

    fn push(&self, span: Span) {
        let n = self.next.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(n % self.slots.len() as u64) as usize];
        slot.seq.store(2 * n + 1, Ordering::Relaxed);
        fence(Ordering::Release);
        // SAFETY: see the `Sync` impl — this write is bracketed by the
        // odd/even sequence stores and readers reject torn copies.
        unsafe {
            *slot.span.get() = span;
        }
        slot.seq.store(2 * n + 2, Ordering::Release);
    }

    fn recorded(&self) -> u64 {
        self.next.load(Ordering::Acquire)
    }

    /// Every still-resident published span, oldest first. Slots being
    /// overwritten concurrently are skipped, never torn.
    fn snapshot(&self) -> Vec<Span> {
        let end = self.recorded();
        let cap = self.slots.len() as u64;
        let start = end.saturating_sub(cap);
        let mut out = Vec::with_capacity((end - start) as usize);
        for n in start..end {
            let slot = &self.slots[(n % cap) as usize];
            let seq = slot.seq.load(Ordering::Acquire);
            if seq != 2 * n + 2 {
                continue;
            }
            // SAFETY: seqlock read protocol (see the `Sync` impl).
            let span = unsafe { *slot.span.get() };
            fence(Ordering::Acquire);
            if slot.seq.load(Ordering::Relaxed) == seq {
                out.push(span);
            }
        }
        out
    }
}

/// The per-rank span recorder; see the module docs. Shared as
/// `Arc<Tracer>` between a `ScdaFile`, its engine, its page cache and
/// any service sessions.
pub struct Tracer {
    rank: u32,
    ring: SpanRing,
    ids: AtomicU64,
    /// Per-[`SpanKind`] duration histograms (nanoseconds), fed as spans
    /// complete.
    hists: Vec<Hist>,
    /// Rank 0's cross-rank merged timeline, deposited at `close()`.
    merged: Mutex<Option<Vec<Span>>>,
}

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Tracer")
            .field("rank", &self.rank)
            .field("recorded", &self.recorded())
            .field("dropped", &self.dropped())
            .finish()
    }
}

impl Default for Tracer {
    fn default() -> Self {
        Tracer::new()
    }
}

impl Tracer {
    /// Default ring capacity: 64 Ki spans (~3.4 MiB resident).
    pub const DEFAULT_CAPACITY: usize = 64 * 1024;

    pub fn new() -> Tracer {
        Tracer::with_capacity(0, Tracer::DEFAULT_CAPACITY)
    }

    /// A tracer tagging its spans with `rank` (one tracer per rank).
    pub fn for_rank(rank: usize) -> Tracer {
        Tracer::with_capacity(rank, Tracer::DEFAULT_CAPACITY)
    }

    pub fn with_capacity(rank: usize, capacity: usize) -> Tracer {
        Tracer {
            rank: rank as u32,
            ring: SpanRing::new(capacity),
            ids: AtomicU64::new(0),
            hists: (0..SpanKind::COUNT).map(|_| Hist::new()).collect(),
            merged: Mutex::new(None),
        }
    }

    pub fn rank(&self) -> u32 {
        self.rank
    }

    /// Open a span; it records itself when the guard drops. An
    /// associated function (not a method) so call sites can write
    /// `tracer.as_ref().map(|t| Tracer::start(t, kind))` — the disabled
    /// path is a single `Option` branch.
    pub fn start(this: &Arc<Tracer>, kind: SpanKind) -> SpanGuard {
        let id = this.ids.fetch_add(1, Ordering::Relaxed) + 1;
        let parent = CURRENT_PARENT.with(|c| c.replace(id));
        SpanGuard {
            tracer: Arc::clone(this),
            id,
            parent,
            kind,
            t_start_ns: now_ns(),
            bytes: 0,
            detail: 0,
        }
    }

    fn record(&self, span: Span) {
        self.hists[span.kind as usize].record(span.duration_ns());
        self.ring.push(span);
    }

    /// Total spans ever recorded (including overwritten ones).
    pub fn recorded(&self) -> u64 {
        self.ring.recorded()
    }

    /// Spans lost to drop-oldest overwriting.
    pub fn dropped(&self) -> u64 {
        self.ring.recorded().saturating_sub(self.ring.slots.len() as u64)
    }

    /// The resident local spans, oldest first (completion order).
    pub fn snapshot(&self) -> Vec<Span> {
        self.ring.snapshot()
    }

    /// The duration histogram accumulated for `kind` (nanoseconds).
    pub fn hist(&self, kind: SpanKind) -> &Hist {
        &self.hists[kind as usize]
    }

    /// Deposit the cross-rank merged timeline (rank 0, at close).
    pub fn set_merged(&self, spans: Vec<Span>) {
        *self.merged.lock().unwrap() = Some(spans);
    }

    /// The merged timeline, if this tracer's rank received one.
    pub fn merged(&self) -> Option<Vec<Span>> {
        self.merged.lock().unwrap().clone()
    }
}

/// RAII handle for an open span (see [`Tracer::start`]). Dropping it
/// stamps the end time and publishes the span.
pub struct SpanGuard {
    tracer: Arc<Tracer>,
    id: u64,
    parent: u64,
    kind: SpanKind,
    t_start_ns: u64,
    bytes: u64,
    detail: u64,
}

impl SpanGuard {
    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn set_bytes(&mut self, n: u64) {
        self.bytes = n;
    }

    pub fn add_bytes(&mut self, n: u64) {
        self.bytes += n;
    }

    pub fn set_detail(&mut self, d: u64) {
        self.detail = d;
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let t_end_ns = now_ns();
        CURRENT_PARENT.with(|c| c.set(self.parent));
        self.tracer.record(Span {
            id: self.id,
            parent: self.parent,
            rank: self.tracer.rank,
            kind: self.kind,
            t_start_ns: self.t_start_ns,
            t_end_ns,
            bytes: self.bytes,
            detail: self.detail,
        });
    }
}

/// Wire size of one encoded span (the cross-rank merge frame format).
pub const SPAN_WIRE_BYTES: usize = 53;

/// Serialize spans for the close-time cross-rank allgather: fixed
/// 53-byte little-endian records, no header.
pub fn encode_spans(spans: &[Span]) -> Vec<u8> {
    let mut out = Vec::with_capacity(spans.len() * SPAN_WIRE_BYTES);
    for s in spans {
        out.extend_from_slice(&s.id.to_le_bytes());
        out.extend_from_slice(&s.parent.to_le_bytes());
        out.extend_from_slice(&s.rank.to_le_bytes());
        out.push(s.kind as u8);
        out.extend_from_slice(&s.t_start_ns.to_le_bytes());
        out.extend_from_slice(&s.t_end_ns.to_le_bytes());
        out.extend_from_slice(&s.bytes.to_le_bytes());
        out.extend_from_slice(&s.detail.to_le_bytes());
    }
    out
}

/// Decode an [`encode_spans`] frame; `None` on a malformed frame (wrong
/// framing or an unknown kind byte).
pub fn decode_spans(bytes: &[u8]) -> Option<Vec<Span>> {
    if bytes.len() % SPAN_WIRE_BYTES != 0 {
        return None;
    }
    let u64_at = |rec: &[u8], at: usize| u64::from_le_bytes(rec[at..at + 8].try_into().unwrap());
    let mut out = Vec::with_capacity(bytes.len() / SPAN_WIRE_BYTES);
    for rec in bytes.chunks_exact(SPAN_WIRE_BYTES) {
        out.push(Span {
            id: u64_at(rec, 0),
            parent: u64_at(rec, 8),
            rank: u32::from_le_bytes(rec[16..20].try_into().unwrap()),
            kind: SpanKind::from_u8(rec[20])?,
            t_start_ns: u64_at(rec, 21),
            t_end_ns: u64_at(rec, 29),
            bytes: u64_at(rec, 37),
            detail: u64_at(rec, 45),
        });
    }
    Some(out)
}

/// Merge per-rank frames into one time-ordered timeline (ties broken by
/// rank, then id, so the order is deterministic). Malformed frames are
/// skipped — a lossy merge beats a lost one.
pub fn merge_frames(frames: &[Vec<u8>]) -> Vec<Span> {
    let mut merged = Vec::new();
    for f in frames {
        if let Some(spans) = decode_spans(f) {
            merged.extend(spans);
        }
    }
    merged.sort_by_key(|s| (s.t_start_ns, s.rank, s.id));
    merged
}

/// Per-kind duration histograms rebuilt from a span list (used for the
/// merged, cross-rank table — the live [`Tracer::hist`] set only covers
/// local spans).
pub fn kind_histograms(spans: &[Span]) -> Vec<Hist> {
    let hists: Vec<Hist> = (0..SpanKind::COUNT).map(|_| Hist::new()).collect();
    for s in spans {
        hists[s.kind as usize].record(s.duration_ns());
    }
    hists
}

/// Render the per-kind latency table (count, p50/p90/p99/max in
/// microseconds, total bytes) for a span list; kinds with no spans are
/// omitted.
pub fn histogram_table(spans: &[Span]) -> String {
    let hists = kind_histograms(spans);
    let mut bytes = vec![0u64; SpanKind::COUNT];
    for s in spans {
        bytes[s.kind as usize] += s.bytes;
    }
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:>8} {:>10} {:>10} {:>10} {:>10} {:>12}\n",
        "span kind", "count", "p50 us", "p90 us", "p99 us", "max us", "bytes"
    ));
    for kind in SpanKind::ALL {
        let h = &hists[kind as usize];
        if h.count() == 0 {
            continue;
        }
        let us = |v: u64| v as f64 / 1e3;
        out.push_str(&format!(
            "{:<16} {:>8} {:>10.1} {:>10.1} {:>10.1} {:>10.1} {:>12}\n",
            kind.name(),
            h.count(),
            us(h.percentile(0.50)),
            us(h.percentile(0.90)),
            us(h.percentile(0.99)),
            us(h.max()),
            bytes[kind as usize],
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn guard_records_nesting_and_parentage() {
        let t = Arc::new(Tracer::with_capacity(3, 64));
        {
            let outer = Tracer::start(&t, SpanKind::Exchange);
            let outer_id = outer.id();
            {
                let mut inner = Tracer::start(&t, SpanKind::Pwrite);
                inner.set_bytes(512);
                assert_ne!(inner.id(), outer_id);
            }
            drop(outer);
            // A sibling opened after both closed is a root again.
            let _sib = Tracer::start(&t, SpanKind::Stage);
        }
        let spans = t.snapshot();
        assert_eq!(spans.len(), 3);
        // Completion order: inner, outer, sibling.
        let (inner, outer, sib) = (&spans[0], &spans[1], &spans[2]);
        assert_eq!(inner.kind, SpanKind::Pwrite);
        assert_eq!(inner.parent, outer.id);
        assert_eq!(inner.bytes, 512);
        assert_eq!(outer.parent, 0);
        assert_eq!(sib.parent, 0);
        for s in &spans {
            assert_eq!(s.rank, 3);
            assert!(s.t_end_ns >= s.t_start_ns);
        }
        assert!(inner.t_start_ns >= outer.t_start_ns);
        assert!(inner.t_end_ns <= outer.t_end_ns);
        assert_eq!(t.dropped(), 0);
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let t = Arc::new(Tracer::with_capacity(0, 8));
        for i in 0..20u64 {
            let mut g = Tracer::start(&t, SpanKind::Serve);
            g.set_detail(i);
        }
        assert_eq!(t.recorded(), 20);
        assert_eq!(t.dropped(), 12);
        let spans = t.snapshot();
        assert_eq!(spans.len(), 8);
        // The survivors are exactly the newest 8, oldest first.
        let details: Vec<u64> = spans.iter().map(|s| s.detail).collect();
        assert_eq!(details, (12..20).collect::<Vec<u64>>());
        // Histograms saw every span, resident or dropped.
        assert_eq!(t.hist(SpanKind::Serve).count(), 20);
    }

    #[test]
    fn spans_roundtrip_the_wire_format() {
        let t = Arc::new(Tracer::with_capacity(2, 16));
        for _ in 0..5 {
            let mut g = Tracer::start(&t, SpanKind::CacheFill);
            g.set_bytes(4096);
        }
        let spans = t.snapshot();
        let wire = encode_spans(&spans);
        assert_eq!(wire.len(), 5 * SPAN_WIRE_BYTES);
        assert_eq!(decode_spans(&wire).unwrap(), spans);
        // Malformed frames are rejected, not mis-parsed.
        assert!(decode_spans(&wire[1..]).is_none());
        let mut bad_kind = wire.clone();
        bad_kind[20] = 0xff;
        assert!(decode_spans(&bad_kind).is_none());
    }

    #[test]
    fn merge_orders_by_time_then_rank() {
        let mk = |rank: u32, id: u64, start: u64| Span {
            id,
            parent: 0,
            rank,
            kind: SpanKind::Serve,
            t_start_ns: start,
            t_end_ns: start + 10,
            bytes: 0,
            detail: 0,
        };
        let f0 = encode_spans(&[mk(0, 1, 50), mk(0, 2, 10)]);
        let f1 = encode_spans(&[mk(1, 1, 30)]);
        let merged = merge_frames(&[f0, f1]);
        let order: Vec<(u64, u32)> = merged.iter().map(|s| (s.t_start_ns, s.rank)).collect();
        assert_eq!(order, vec![(10, 0), (30, 1), (50, 0)]);
        // A torn frame drops, the rest still merge.
        let f_torn = vec![0u8; SPAN_WIRE_BYTES - 1];
        assert_eq!(merge_frames(&[encode_spans(&[mk(2, 1, 5)]), f_torn]).len(), 1);
    }

    #[test]
    fn histogram_table_lists_only_recorded_kinds() {
        let t = Arc::new(Tracer::new());
        {
            let mut g = Tracer::start(&t, SpanKind::Exchange);
            g.set_bytes(100);
        }
        let table = histogram_table(&t.snapshot());
        assert!(table.contains("exchange"));
        assert!(!table.contains("cache_fill"));
        assert!(table.contains("span kind"));
    }

    #[test]
    fn concurrent_recorders_never_tear() {
        let t = Arc::new(Tracer::with_capacity(0, 1024));
        std::thread::scope(|sc| {
            for _ in 0..4 {
                let t = Arc::clone(&t);
                sc.spawn(move || {
                    for _ in 0..500 {
                        let mut g = Tracer::start(&t, SpanKind::Serve);
                        g.set_bytes(7);
                        g.set_detail(9);
                    }
                });
            }
        });
        assert_eq!(t.recorded(), 2000);
        for s in t.snapshot() {
            // Published slots carry consistent contents, never a torn mix.
            assert_eq!((s.bytes, s.detail), (7, 9));
            assert!(s.id >= 1);
        }
    }
}
