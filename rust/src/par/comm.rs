//! The communicator abstraction: the small set of collectives scda needs.
//!
//! The paper implements its API over MPI (broadcast / allgather semantics,
//! §A.4). This trait captures exactly that surface so the format layer is
//! oblivious to the transport; implementations are [`crate::par::serial`]
//! (one process) and [`crate::par::thread`] (in-process ranks — the
//! simulation substrate standing in for MPI, per DESIGN.md §1).
//!
//! All collective calls must be invoked by *every* rank of the
//! communicator in the same order — exactly the MPI contract. As in the
//! paper ("it is an unchecked runtime error if they are indeed not
//! collective"), mismatched use is undefined (here: deadlock or panic,
//! never memory unsafety).

/// Collectives over a fixed group of `size()` ranks.
pub trait Communicator: Send {
    fn rank(&self) -> usize;
    fn size(&self) -> usize;

    /// Synchronize all ranks.
    fn barrier(&self);

    /// Broadcast `data` from `root` (which must pass `Some`) to all ranks.
    fn bcast_bytes(&self, root: usize, data: Option<Vec<u8>>) -> Vec<u8>;

    /// Gather one `u64` from every rank, delivered to all (MPI_Allgather).
    fn allgather_u64(&self, value: u64) -> Vec<u64>;

    /// Gather a byte buffer from every rank, delivered to all
    /// (MPI_Allgatherv).
    fn allgather_bytes(&self, data: Vec<u8>) -> Vec<Vec<u8>>;

    /// Logical AND reduction delivered to all ranks (used to agree on
    /// error state before touching the file, keeping failures collective).
    fn alland(&self, value: bool) -> bool {
        self.allgather_u64(value as u64).iter().all(|&v| v != 0)
    }

    /// Minimum reduction delivered to all ranks.
    fn allmin_u64(&self, value: u64) -> u64 {
        self.allgather_u64(value).into_iter().min().unwrap_or(u64::MAX)
    }

    /// Sum reduction delivered to all ranks.
    fn allsum_u64(&self, value: u64) -> u64 {
        self.allgather_u64(value).into_iter().sum()
    }

    /// Broadcast a `u64` from `root`.
    fn bcast_u64(&self, root: usize, value: Option<u64>) -> u64 {
        let bytes = self.bcast_bytes(root, value.map(|v| v.to_le_bytes().to_vec()));
        u64::from_le_bytes(bytes.try_into().expect("bcast_u64 payload"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::serial::SerialComm;

    #[test]
    fn default_reductions_on_serial() {
        let c = SerialComm::new();
        assert!(c.alland(true));
        assert!(!c.alland(false));
        assert_eq!(c.allmin_u64(17), 17);
        assert_eq!(c.allsum_u64(17), 17);
        assert_eq!(c.bcast_u64(0, Some(5)), 5);
    }
}
