//! The communicator abstraction: the small set of collectives scda needs.
//!
//! The paper implements its API over MPI (broadcast / allgather semantics,
//! §A.4). This trait captures exactly that surface so the format layer is
//! oblivious to the transport; implementations are [`crate::par::serial`]
//! (one process) and [`crate::par::thread`] (in-process ranks — the
//! simulation substrate standing in for MPI, per DESIGN.md §1).
//!
//! All collective calls must be invoked by *every* rank of the
//! communicator in the same order — exactly the MPI contract. As in the
//! paper ("it is an unchecked runtime error if they are indeed not
//! collective"), mismatched use is undefined (here: deadlock or panic,
//! never memory unsafety).

/// Collectives over a fixed group of `size()` ranks.
pub trait Communicator: Send {
    fn rank(&self) -> usize;
    fn size(&self) -> usize;

    /// Synchronize all ranks.
    fn barrier(&self);

    /// Broadcast `data` from `root` (which must pass `Some`) to all ranks.
    fn bcast_bytes(&self, root: usize, data: Option<Vec<u8>>) -> Vec<u8>;

    /// Gather one `u64` from every rank, delivered to all (MPI_Allgather).
    fn allgather_u64(&self, value: u64) -> Vec<u64>;

    /// Gather a byte buffer from every rank, delivered to all
    /// (MPI_Allgatherv).
    fn allgather_bytes(&self, data: Vec<u8>) -> Vec<Vec<u8>>;

    /// Logical AND reduction delivered to all ranks (used to agree on
    /// error state before touching the file, keeping failures collective).
    fn alland(&self, value: bool) -> bool {
        self.allgather_u64(value as u64).iter().all(|&v| v != 0)
    }

    /// Minimum reduction delivered to all ranks.
    fn allmin_u64(&self, value: u64) -> u64 {
        self.allgather_u64(value).into_iter().min().unwrap_or(u64::MAX)
    }

    /// Sum reduction delivered to all ranks.
    fn allsum_u64(&self, value: u64) -> u64 {
        self.allgather_u64(value).into_iter().sum()
    }

    /// Broadcast a `u64` from `root`.
    fn bcast_u64(&self, root: usize, value: Option<u64>) -> u64 {
        let bytes = self.bcast_bytes(root, value.map(|v| v.to_le_bytes().to_vec()));
        u64::from_le_bytes(bytes.try_into().expect("bcast_u64 payload"))
    }

    /// Gather one `(u64, u64)` pair from every rank, delivered to all —
    /// the request-announcement primitive of the collective *read*
    /// gather (`crate::io::collective`): each rank announces its
    /// `(offset, length)` window, and every rank derives the same
    /// stripe-serving plan from the identical gathered vector, so the
    /// follow-up `alltoall_bytes` either runs on every rank or on none.
    fn allgather_u64_pair(&self, a: u64, b: u64) -> Vec<(u64, u64)> {
        let mut wire = Vec::with_capacity(16);
        wire.extend_from_slice(&a.to_le_bytes());
        wire.extend_from_slice(&b.to_le_bytes());
        self.allgather_bytes(wire)
            .into_iter()
            .map(|v| {
                let a = u64::from_le_bytes(v[..8].try_into().expect("u64-pair frame"));
                let b = u64::from_le_bytes(v[8..16].try_into().expect("u64-pair frame"));
                (a, b)
            })
            .collect()
    }

    /// Personalized exchange (MPI_Alltoallv): `outgoing[d]` is delivered
    /// to rank `d`; returns `incoming`, where `incoming[s]` is the payload
    /// rank `s` addressed to this rank. `outgoing.len()` must equal
    /// `size()`. This is the transport of the two-phase collective I/O
    /// engine (`crate::io::collective`): ranks ship staged file extents to
    /// the aggregator rank owning each file stripe.
    ///
    /// The default implementation frames the per-destination payloads into
    /// one buffer and allgathers it; substrates with a cheaper transport
    /// override it (the thread substrate copies only the fragments
    /// addressed to the caller out of the shared deposit slots).
    fn alltoall_bytes(&self, outgoing: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        assert_eq!(outgoing.len(), self.size(), "one outgoing payload per destination rank");
        let me = self.rank();
        let size = self.size();
        self.allgather_bytes(frame_alltoall(&outgoing))
            .into_iter()
            .map(|src| extract_alltoall_fragment(&src, me, size))
            .collect()
    }
}

/// Wire format shared by every `alltoall_bytes` implementation: for each
/// destination rank in order, an 8-byte LE length followed by the payload.
pub(crate) fn frame_alltoall(outgoing: &[Vec<u8>]) -> Vec<u8> {
    let mut framed = Vec::with_capacity(outgoing.iter().map(|d| d.len() + 8).sum());
    for d in outgoing {
        framed.extend_from_slice(&(d.len() as u64).to_le_bytes());
        framed.extend_from_slice(d);
    }
    framed
}

/// Pull the fragment addressed to `dest` out of one source's framed
/// deposit (see [`frame_alltoall`]); only that fragment is copied.
pub(crate) fn extract_alltoall_fragment(framed: &[u8], dest: usize, size: usize) -> Vec<u8> {
    let mut at = 0usize;
    for d in 0..size {
        let len =
            u64::from_le_bytes(framed[at..at + 8].try_into().expect("alltoall frame header")) as usize;
        at += 8;
        if d == dest {
            return framed[at..at + len].to_vec();
        }
        at += len;
    }
    panic!("alltoall frame missing destination {dest}");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::serial::SerialComm;

    #[test]
    fn default_reductions_on_serial() {
        let c = SerialComm::new();
        assert!(c.alland(true));
        assert!(!c.alland(false));
        assert_eq!(c.allmin_u64(17), 17);
        assert_eq!(c.allsum_u64(17), 17);
        assert_eq!(c.bcast_u64(0, Some(5)), 5);
    }

    #[test]
    fn alltoall_frame_roundtrips() {
        let outgoing = vec![vec![1u8, 2], vec![], vec![3u8, 4, 5]];
        let framed = frame_alltoall(&outgoing);
        for (d, expect) in outgoing.iter().enumerate() {
            assert_eq!(&extract_alltoall_fragment(&framed, d, 3), expect);
        }
    }

    #[test]
    fn alltoall_on_serial_is_identity() {
        let c = SerialComm::new();
        assert_eq!(c.alltoall_bytes(vec![vec![9, 8, 7]]), vec![vec![9, 8, 7]]);
    }

    #[test]
    fn u64_pair_allgather_roundtrips() {
        let c = SerialComm::new();
        assert_eq!(c.allgather_u64_pair(12345, u64::MAX), vec![(12345, u64::MAX)]);
    }
}
