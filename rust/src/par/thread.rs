//! In-process rank simulation: `P` OS threads sharing a lock-and-condvar
//! collective state. This is the MPI stand-in substrate (DESIGN.md §1):
//! each thread behaves exactly like an MPI rank — same collective call
//! discipline, same partition arithmetic, same positional file windows —
//! so the format code above it cannot tell the difference.

use std::sync::{Arc, Condvar, Mutex};

use crate::par::comm::Communicator;

struct Shared {
    size: usize,
    state: Mutex<CollectiveState>,
    cv: Condvar,
}

struct CollectiveState {
    // Generation-counting barrier.
    arrived: usize,
    generation: u64,
    // Deposit slots for gather/bcast payloads. Each rank only ever writes
    // its own slot, so no clearing between collectives is needed: stale
    // values are overwritten by the next deposit before the barrier.
    //
    // Slots hold `Arc<[u8]>` so that reading the collective view clones
    // P reference counts, not P payload vectors: the previous
    // `Vec<Vec<u8>>` snapshot copied every rank's bytes on every rank —
    // O(P²) payload copying per allgather under the lock.
    slots: Vec<Option<Arc<[u8]>>>,
}

/// Handle owned by one rank.
pub struct ThreadComm {
    rank: usize,
    shared: Arc<Shared>,
}

impl ThreadComm {
    /// Create handles for all ranks of a group of `size`.
    pub fn group(size: usize) -> Vec<ThreadComm> {
        assert!(size >= 1);
        let shared = Arc::new(Shared {
            size,
            state: Mutex::new(CollectiveState { arrived: 0, generation: 0, slots: vec![None; size] }),
            cv: Condvar::new(),
        });
        (0..size).map(|rank| ThreadComm { rank, shared: Arc::clone(&shared) }).collect()
    }

    fn barrier_impl(&self) {
        let mut st = self.shared.state.lock().unwrap();
        let gen = st.generation;
        st.arrived += 1;
        if st.arrived == self.shared.size {
            st.arrived = 0;
            st.generation += 1;
            self.shared.cv.notify_all();
        } else {
            while st.generation == gen {
                st = self.shared.cv.wait(st).unwrap();
            }
        }
    }

    /// Deposit this rank's payload, wait for all, and read all slots.
    ///
    /// Two barriers delimit the collective: the first guarantees every
    /// deposit happened before any read; the second guarantees every read
    /// happened before any rank can deposit into the *next* collective.
    /// Because a rank only writes its own slot, stale values never leak.
    /// The returned view shares the deposited buffers (`Arc` clones);
    /// ranks copy only the slots they actually consume.
    fn exchange(&self, payload: Option<Vec<u8>>) -> Vec<Option<Arc<[u8]>>> {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.slots[self.rank] = payload.map(|v| Arc::<[u8]>::from(v));
        }
        self.barrier_impl();
        let view = {
            let st = self.shared.state.lock().unwrap();
            st.slots.clone()
        };
        self.barrier_impl();
        view
    }
}

impl Communicator for ThreadComm {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.shared.size
    }

    fn barrier(&self) {
        self.barrier_impl();
    }

    fn bcast_bytes(&self, root: usize, data: Option<Vec<u8>>) -> Vec<u8> {
        assert!(root < self.shared.size);
        if self.rank == root {
            assert!(data.is_some(), "broadcast root must provide data");
        }
        // Only the root slot is read; the other ranks' deposits (all
        // `None` here) are never copied.
        let view = self.exchange(if self.rank == root { data } else { None });
        view[root].as_ref().expect("root deposited broadcast payload").to_vec()
    }

    fn allgather_u64(&self, value: u64) -> Vec<u64> {
        let view = self.exchange(Some(value.to_le_bytes().to_vec()));
        view.into_iter()
            .map(|s| u64::from_le_bytes(s.expect("all ranks deposit").as_ref().try_into().unwrap()))
            .collect()
    }

    fn allgather_bytes(&self, data: Vec<u8>) -> Vec<Vec<u8>> {
        let view = self.exchange(Some(data));
        view.into_iter().map(|s| s.expect("all ranks deposit").to_vec()).collect()
    }

    fn alltoall_bytes(&self, outgoing: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        assert_eq!(outgoing.len(), self.shared.size, "one outgoing payload per destination rank");
        // One framed deposit per rank; each reader copies only the
        // fragment addressed to it out of the shared `Arc` slots — the
        // full P x P payload matrix is never materialized anywhere.
        let view = self.exchange(Some(crate::par::comm::frame_alltoall(&outgoing)));
        view.into_iter()
            .map(|s| {
                crate::par::comm::extract_alltoall_fragment(
                    s.expect("all ranks deposit").as_ref(),
                    self.rank,
                    self.shared.size,
                )
            })
            .collect()
    }
}

/// Run `f(comm)` on `ranks` threads, one rank each; returns the per-rank
/// results in rank order. Panics in any rank propagate.
pub fn run_parallel<R, F>(ranks: usize, f: F) -> Vec<R>
where
    R: Send + 'static,
    F: Fn(ThreadComm) -> R + Send + Sync + 'static,
{
    let f = Arc::new(f);
    let comms = ThreadComm::group(ranks);
    let mut handles = Vec::with_capacity(ranks);
    for comm in comms {
        let f = Arc::clone(&f);
        handles.push(
            std::thread::Builder::new()
                .name(format!("scda-rank-{}", comm.rank()))
                .spawn(move || f(comm))
                .expect("spawn rank thread"),
        );
    }
    handles
        .into_iter()
        .map(|h| h.join().unwrap_or_else(|e| std::panic::resume_unwind(e)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn barrier_synchronizes() {
        static BEFORE: AtomicUsize = AtomicUsize::new(0);
        let results = run_parallel(8, |comm| {
            BEFORE.fetch_add(1, Ordering::SeqCst);
            comm.barrier();
            // After the barrier every rank observes all 8 arrivals.
            BEFORE.load(Ordering::SeqCst)
        });
        assert!(results.iter().all(|&r| r == 8), "{results:?}");
    }

    #[test]
    fn bcast_delivers_to_all() {
        let results = run_parallel(5, |comm| {
            let data = if comm.rank() == 2 { Some(vec![42, 43]) } else { None };
            comm.bcast_bytes(2, data)
        });
        assert!(results.iter().all(|r| r == &[42, 43]));
    }

    #[test]
    fn allgather_orders_by_rank() {
        let results = run_parallel(6, |comm| comm.allgather_u64(comm.rank() as u64 * 10));
        for r in &results {
            assert_eq!(r, &[0, 10, 20, 30, 40, 50]);
        }
        let results = run_parallel(3, |comm| comm.allgather_bytes(vec![comm.rank() as u8; comm.rank() + 1]));
        for r in &results {
            assert_eq!(r, &vec![vec![0u8], vec![1, 1], vec![2, 2, 2]]);
        }
    }

    #[test]
    fn repeated_collectives_do_not_cross_talk() {
        let results = run_parallel(4, |comm| {
            let mut acc = Vec::new();
            for round in 0..50u64 {
                let g = comm.allgather_u64(round * 100 + comm.rank() as u64);
                acc.push(g);
                comm.barrier();
                let b = comm.bcast_u64(round as usize % 4, if comm.rank() == round as usize % 4 { Some(round) } else { None });
                assert_eq!(b, round);
            }
            acc
        });
        for r in &results {
            for (round, g) in r.iter().enumerate() {
                let round = round as u64;
                assert_eq!(g, &[round * 100, round * 100 + 1, round * 100 + 2, round * 100 + 3]);
            }
        }
    }

    #[test]
    fn alltoall_delivers_personalized_payloads() {
        // Rank r sends the payload [r, d] to destination d; every rank
        // must receive [s, me] from each source s.
        let results = run_parallel(4, |comm| {
            let me = comm.rank();
            let outgoing: Vec<Vec<u8>> = (0..4).map(|d| vec![me as u8, d as u8]).collect();
            comm.alltoall_bytes(outgoing)
        });
        for (me, incoming) in results.iter().enumerate() {
            for (s, payload) in incoming.iter().enumerate() {
                assert_eq!(payload, &vec![s as u8, me as u8]);
            }
        }
        // Empty payloads are legal (ranks with nothing to ship).
        let results = run_parallel(3, |comm| comm.alltoall_bytes(vec![Vec::new(); 3]));
        for incoming in results {
            assert!(incoming.iter().all(|p| p.is_empty()));
        }
    }

    #[test]
    fn single_rank_group_works() {
        let results = run_parallel(1, |comm| {
            comm.barrier();
            comm.allgather_u64(7)
        });
        assert_eq!(results, vec![vec![7]]);
    }
}
