//! One big parallel file (feature (1) of the paper): all ranks address the
//! same file through positional reads/writes on disjoint windows. This is
//! the POSIX stand-in for MPI I/O — `pwrite`/`pread` never touch a shared
//! cursor, so concurrent rank windows compose without locks, and because
//! the windows are disjoint by the partition arithmetic, the resulting
//! bytes equal the serial write.

use std::fs::{File, OpenOptions};
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use crate::error::{Result, ScdaError};
use crate::io::fault::{injected_error, FaultKind, FaultOp, FaultPlan, FaultState};
use crate::par::comm::Communicator;

/// Syscall-level instrumentation of one [`ParallelFile`] handle (i.e. of
/// one rank): every positional read/write and every `fstat` counts. The
/// I/O aggregation layer (`crate::io`) is tuned and tested against these
/// numbers, and `BENCH_io.json` reports them.
#[derive(Debug, Default)]
struct IoCounters {
    writes: AtomicU64,
    write_bytes: AtomicU64,
    reads: AtomicU64,
    read_bytes: AtomicU64,
    stats: AtomicU64,
}

/// Snapshot of a handle's [`IoCounters`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct IoStats {
    pub write_calls: u64,
    pub write_bytes: u64,
    pub read_calls: u64,
    pub read_bytes: u64,
    pub stat_calls: u64,
}

impl IoStats {
    /// Counter deltas since an earlier snapshot of the same handle.
    pub fn since(&self, earlier: &IoStats) -> IoStats {
        IoStats {
            write_calls: self.write_calls - earlier.write_calls,
            write_bytes: self.write_bytes - earlier.write_bytes,
            read_calls: self.read_calls - earlier.read_calls,
            read_bytes: self.read_bytes - earlier.read_bytes,
            stat_calls: self.stat_calls - earlier.stat_calls,
        }
    }
}

/// A shared file handle for collective window I/O.
#[derive(Debug)]
pub struct ParallelFile {
    file: File,
    path: PathBuf,
    writable: bool,
    /// The rank this handle belongs to (per-rank fault plans key on it).
    rank: usize,
    /// Length cached at open for read-only handles (read-only scda files
    /// cannot grow, §A.3), so `len()` needs no per-section `fstat`.
    cached_len: Option<u64>,
    counters: IoCounters,
    /// Armed fault plan (see [`Self::set_fault_plan`]); the atomic flag
    /// keeps the disarmed fast path lock-free.
    fault_armed: AtomicBool,
    faults: Mutex<Option<FaultState>>,
}

impl ParallelFile {
    /// Collectively create (truncate) the file for writing. Rank 0 creates;
    /// the others open after the barrier. Mirrors `scda_fopen(..., 'w')`:
    /// "the only possibility to write to a file is to create a new one or
    /// to overwrite an existing one" (§A.3).
    pub fn create<C: Communicator>(comm: &C, path: &Path) -> Result<Self> {
        let file = if comm.rank() == 0 {
            let f = OpenOptions::new()
                .read(true)
                .write(true)
                .create(true)
                .truncate(true)
                .open(path)
                .map_err(|e| ScdaError::io(e, format!("creating {}", path.display())));
            // Propagate create success/failure collectively before anyone
            // opens, so all ranks agree on the error.
            let ok = comm.alland(f.is_ok());
            if !ok {
                return Err(f.err().unwrap_or_else(|| {
                    ScdaError::io(std::io::Error::other("peer failed"), "collective create failed")
                }));
            }
            f?
        } else {
            let ok = comm.alland(true);
            if !ok {
                return Err(ScdaError::io(
                    std::io::Error::other("root failed to create file"),
                    format!("creating {}", path.display()),
                ));
            }
            OpenOptions::new()
                .read(true)
                .write(true)
                .open(path)
                .map_err(|e| ScdaError::io(e, format!("opening {}", path.display())))?
        };
        Ok(ParallelFile {
            file,
            path: path.to_path_buf(),
            writable: true,
            rank: comm.rank(),
            cached_len: None,
            counters: IoCounters::default(),
            fault_armed: AtomicBool::new(false),
            faults: Mutex::new(None),
        })
    }

    /// Collectively open an existing file read-only.
    pub fn open_read<C: Communicator>(comm: &C, path: &Path) -> Result<Self> {
        let f = OpenOptions::new().read(true).open(path);
        let ok = comm.alland(f.is_ok());
        if !ok {
            return Err(match f {
                Err(e) => ScdaError::io(e, format!("opening {}", path.display())),
                Ok(_) => ScdaError::io(std::io::Error::other("peer failed"), "collective open failed"),
            });
        }
        let file = f.unwrap();
        // One fstat for the whole life of the handle: read-only files
        // cannot grow, so every later `len()` is served from the cache.
        let counters = IoCounters::default();
        counters.stats.fetch_add(1, Ordering::Relaxed);
        let cached_len = file.metadata().map_err(|e| ScdaError::io(e, "stat")).map(|m| m.len())?;
        Ok(ParallelFile {
            file,
            path: path.to_path_buf(),
            writable: false,
            rank: comm.rank(),
            cached_len: Some(cached_len),
            counters,
            fault_armed: AtomicBool::new(false),
            faults: Mutex::new(None),
        })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Arm a deterministic [`FaultPlan`] on this handle (fault drills,
    /// the crash/restore soak, and tests of the staged / background
    /// flush error paths). `None` disarms. The hook is per handle (never
    /// global) and an injected failure is indistinguishable from a real
    /// `pwrite`/`pread` error to everything above the file layer —
    /// except transient plans, whose `EINTR`-shaped errors the engines'
    /// bounded retry absorbs by design.
    pub fn set_fault_plan(&self, plan: Option<FaultPlan>) {
        let mut g = self.faults.lock().unwrap();
        self.fault_armed.store(plan.is_some(), Ordering::SeqCst);
        *g = plan.map(FaultState::new);
    }

    /// Compatibility shim for the original hook: after `after` more
    /// successful `write_at` calls, every subsequent write fails
    /// (a [`FaultPlan::persistent`]); `u64::MAX` disarms.
    pub fn inject_write_failure(&self, after: u64) {
        self.set_fault_plan((after != u64::MAX).then(|| FaultPlan::persistent(after)));
    }

    /// Consult the armed plan for one operation; shared by the write and
    /// read paths. `Ok(None)` = proceed normally; `Ok(Some((keep, cut)))`
    /// = torn write of `keep` bytes (power cut truncating there if
    /// `cut`); `Err` = the operation fails outright.
    fn fault_check(&self, op: FaultOp, offset: u64, len: u64) -> Result<Option<(u64, bool)>> {
        let mut g = self.faults.lock().unwrap();
        let Some(st) = g.as_mut() else { return Ok(None) };
        let verdict = st.check(op, self.rank, offset, len);
        if st.exhausted() {
            *g = None;
            self.fault_armed.store(false, Ordering::SeqCst);
        }
        verdict
    }

    /// Write `buf` at absolute `offset` (this rank's window).
    pub fn write_at(&self, offset: u64, buf: &[u8]) -> Result<()> {
        debug_assert!(self.writable);
        self.counters.writes.fetch_add(1, Ordering::Relaxed);
        self.counters.write_bytes.fetch_add(buf.len() as u64, Ordering::Relaxed);
        if self.fault_armed.load(Ordering::Relaxed) {
            if let Some((keep, cut)) = self.fault_check(FaultOp::Write, offset, buf.len() as u64)? {
                // Realize the torn write / power cut, then report it.
                let _ = self.file.write_all_at(&buf[..keep as usize], offset);
                if cut {
                    let _ = self.file.set_len(offset + keep);
                    let _ = self.file.sync_all();
                }
                let kind = if cut { FaultKind::Crash { keep } } else { FaultKind::Torn { keep } };
                return Err(injected_error(kind, FaultOp::Write, offset, buf.len() as u64, true));
            }
        }
        self.file
            .write_all_at(buf, offset)
            .map_err(|e| ScdaError::io(e, format!("writing {} bytes at offset {offset}", buf.len())))
    }

    /// Read exactly `buf.len()` bytes at absolute `offset`.
    pub fn read_at(&self, offset: u64, buf: &mut [u8]) -> Result<()> {
        self.counters.reads.fetch_add(1, Ordering::Relaxed);
        self.counters.read_bytes.fetch_add(buf.len() as u64, Ordering::Relaxed);
        if self.fault_armed.load(Ordering::Relaxed) {
            self.fault_check(FaultOp::Read, offset, buf.len() as u64)?;
        }
        self.file.read_exact_at(buf, offset).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                ScdaError::corrupt(
                    crate::error::corrupt::TRUNCATED,
                    format!("file ends before {} bytes at offset {offset}", buf.len()),
                )
            } else {
                ScdaError::io(e, format!("reading {} bytes at offset {offset}", buf.len()))
            }
        })
    }

    /// Read `len` bytes at `offset` into a fresh exactly-sized buffer.
    ///
    /// The `vec![0; len]` allocation is `alloc_zeroed` under the hood —
    /// for large buffers the zeroed pages come straight from the kernel
    /// and are first touched by the read itself, so there is no
    /// double-write. (Reading into genuinely uninitialized memory is
    /// documented UB for the `Read` family; for a caller-owned buffer
    /// with no allocation at all, use [`Self::read_at`] or the API
    /// layer's `read_array_data_into`.)
    pub fn read_vec(&self, offset: u64, len: usize) -> Result<Vec<u8>> {
        let mut v = vec![0u8; len];
        self.read_at(offset, &mut v)?;
        Ok(v)
    }

    /// File size in bytes (cached for read-only handles).
    pub fn len(&self) -> Result<u64> {
        if let Some(l) = self.cached_len {
            return Ok(l);
        }
        self.counters.stats.fetch_add(1, Ordering::Relaxed);
        Ok(self.file.metadata().map_err(|e| ScdaError::io(e, "stat"))?.len())
    }

    /// Snapshot of this handle's syscall counters.
    pub fn io_stats(&self) -> IoStats {
        IoStats {
            write_calls: self.counters.writes.load(Ordering::Relaxed),
            write_bytes: self.counters.write_bytes.load(Ordering::Relaxed),
            read_calls: self.counters.reads.load(Ordering::Relaxed),
            read_bytes: self.counters.read_bytes.load(Ordering::Relaxed),
            stat_calls: self.counters.stats.load(Ordering::Relaxed),
        }
    }

    pub fn is_empty(&self) -> Result<bool> {
        Ok(self.len()? == 0)
    }

    /// Flush file contents to stable storage (collective close path; only
    /// rank 0 needs to call it since all ranks share the same inode).
    pub fn sync(&self) -> Result<()> {
        self.file.sync_all().map_err(|e| ScdaError::io(e, "fsync"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::par::serial::SerialComm;
    use crate::par::thread::run_parallel;
    use std::sync::Arc;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("scda-pfile-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(format!("{name}-{}", std::process::id()))
    }

    #[test]
    fn serial_write_read() {
        let path = tmp("serial");
        let c = SerialComm::new();
        let f = ParallelFile::create(&c, &path).unwrap();
        f.write_at(0, b"hello").unwrap();
        f.write_at(5, b" world").unwrap();
        assert_eq!(f.read_vec(0, 11).unwrap(), b"hello world");
        assert_eq!(f.len().unwrap(), 11);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn disjoint_parallel_windows_compose() {
        let path = Arc::new(tmp("parallel"));
        let p = Arc::clone(&path);
        run_parallel(8, move |comm| {
            let f = ParallelFile::create(&comm, &p).unwrap();
            // Each rank writes 100 bytes of its rank id at its window.
            let buf = vec![comm.rank() as u8; 100];
            f.write_at(comm.rank() as u64 * 100, &buf).unwrap();
            comm.barrier();
        });
        let data = std::fs::read(&*path).unwrap();
        assert_eq!(data.len(), 800);
        for (i, &b) in data.iter().enumerate() {
            assert_eq!(b as usize, i / 100);
        }
        std::fs::remove_file(&*path).unwrap();
    }

    #[test]
    fn read_past_end_is_corrupt_error() {
        let path = tmp("short");
        let c = SerialComm::new();
        let f = ParallelFile::create(&c, &path).unwrap();
        f.write_at(0, b"xy").unwrap();
        let err = f.read_vec(0, 10).unwrap_err();
        assert_eq!(err.kind(), crate::error::ScdaErrorKind::CorruptFile);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn counters_and_cached_len() {
        let path = tmp("counters");
        let c = SerialComm::new();
        let f = ParallelFile::create(&c, &path).unwrap();
        f.write_at(0, b"0123456789").unwrap();
        assert_eq!(f.read_vec(2, 5).unwrap(), b"23456");
        let st = f.io_stats();
        assert_eq!((st.write_calls, st.write_bytes), (1, 10));
        assert_eq!((st.read_calls, st.read_bytes), (1, 5));
        // Writable handles stat on every len().
        f.len().unwrap();
        assert_eq!(f.io_stats().since(&st).stat_calls, 1);
        // Read-only handles serve len() from the open-time cache: exactly
        // the one fstat issued at open, no matter how often len() runs.
        let r = ParallelFile::open_read(&c, &path).unwrap();
        assert_eq!(r.io_stats().stat_calls, 1);
        assert_eq!(r.len().unwrap(), 10);
        r.len().unwrap();
        assert_eq!(r.io_stats().stat_calls, 1);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn injected_write_failure_fires_after_n_writes() {
        let path = tmp("inject");
        let c = SerialComm::new();
        let f = ParallelFile::create(&c, &path).unwrap();
        f.inject_write_failure(2);
        f.write_at(0, b"ok").unwrap();
        f.write_at(2, b"ok").unwrap();
        let err = f.write_at(4, b"boom").unwrap_err();
        assert_eq!(err.kind(), crate::error::ScdaErrorKind::Io);
        // Stays failed until disarmed.
        assert!(f.write_at(4, b"boom").is_err());
        f.inject_write_failure(u64::MAX);
        f.write_at(4, b"ok").unwrap();
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn open_missing_file_is_io_error() {
        let c = SerialComm::new();
        let err = ParallelFile::open_read(&c, Path::new("/nonexistent/scda")).unwrap_err();
        assert_eq!(err.kind(), crate::error::ScdaErrorKind::Io);
    }

    #[test]
    fn collective_open_failure_agrees_across_ranks() {
        let results = run_parallel(4, |comm| {
            ParallelFile::open_read(&comm, Path::new("/nonexistent/scda")).is_err()
        });
        assert!(results.iter().all(|&e| e));
    }
}
