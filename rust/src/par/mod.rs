//! Parallel substrate: partitions (§A.1), the communicator abstraction,
//! in-process rank simulation, and the single shared file with positional
//! window I/O. Together these stand in for MPI + MPI I/O (see DESIGN.md
//! §1 for why the substitution preserves the paper's claims).

pub mod comm;
pub mod partition;
pub mod pfile;
pub mod pool;
pub mod serial;
pub mod thread;

pub use comm::Communicator;
pub use partition::Partition;
pub use pfile::{IoStats, ParallelFile};
pub use pool::{CodecPool, ParJob, Step};
pub use serial::SerialComm;
pub use thread::{run_parallel, ThreadComm};
