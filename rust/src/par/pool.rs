//! Persistent codec worker pool: the compute substrate of the per-element
//! codec pipeline (ISSUE: parallel per-element codec with zero-copy buffer
//! reuse).
//!
//! The paper's compression convention (§3.1) is per-element by design —
//! every element is an independent deflate+base64 stream — so the codec
//! hot path is embarrassingly parallel *within* a rank. This module
//! provides the small persistent pool that `encode_local_elements`
//! (writer), the decoded-array/varray read paths (reader), and the
//! coordinator's streaming pipeline all fan element batches out to.
//!
//! Design:
//!
//! * **Jobs, not threads.** A job ([`ParJob`]) is a bag of claimable work
//!   units; `CodecPool::run` publishes it to the pool, and every idle
//!   worker *steals* units from any published job (`step`). Units are
//!   claimed with an atomic cursor inside the job, so load balance is
//!   dynamic: a worker that finishes its unit early immediately claims
//!   the next one, wherever it lives.
//! * **The submitter helps.** `run` blocks, but the submitting thread
//!   executes units itself while it waits. This makes the pool
//!   deadlock-free under nesting and under concurrent submissions from
//!   many rank threads: a job never waits for a worker, because its own
//!   submitter is always a worker of last resort.
//! * **Scoped borrows without scoped threads.** Jobs may borrow the
//!   caller's stack (element slices, scratch tables). `run` erases the
//!   lifetime to publish the job, and guarantees before returning that
//!   the job is unpublished and no worker is still inside `step` (a
//!   per-job stepper count, waited on after removal). Workers only obtain
//!   the job reference under the pool lock while it is published, so no
//!   reference outlives `run`.
//! * **Per-worker scratch.** Workers are persistent OS threads, so
//!   thread-local codec scratch ([`crate::codec::frame::with_scratch`])
//!   is per-worker state that survives across jobs — the matcher hash
//!   chains, bit writer, and stage buffers are allocated once per worker,
//!   not once per element.
//!
//! Serial equivalence: the pool never reorders *results* — batch jobs
//! stitch per-unit outputs back by index ([`CodecPool::run_ordered`]), so
//! the bytes produced are identical to the serial path at any worker
//! count. The property test `rust/tests/pipeline_equivalence.rs` asserts
//! this for the full writer/reader paths.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

/// Worker id passed to [`ParJob::step`] for the submitting thread.
pub const SUBMITTER: usize = usize::MAX;

/// Outcome of one [`ParJob::step`] call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Step {
    /// A unit was claimed and executed; call again immediately.
    Ran,
    /// Nothing claimable right now, but the job is not finished (units in
    /// flight elsewhere, or a streaming source is momentarily empty).
    Idle,
    /// Every unit is finished; the job can be retired.
    Done,
}

/// A bag of claimable work units executed cooperatively by the pool.
///
/// Implementations own their claiming state (typically an atomic cursor)
/// and their completion accounting. `step` must be safe to call from many
/// threads concurrently and must not panic on data errors — report those
/// through the job's own result slots instead.
pub trait ParJob: Sync {
    /// Claim and execute at most one unit.
    fn step(&self, worker: usize) -> Step;

    /// Block briefly until the job's state may have advanced; called by
    /// the submitter when `step` returns [`Step::Idle`]. Implementations
    /// with a completion condvar should wait on it here.
    fn park(&self) {
        std::thread::sleep(Duration::from_micros(50));
    }
}

#[derive(Default)]
struct SlotCtl {
    /// Workers currently inside `step` for this job.
    steppers: Mutex<usize>,
    cv: Condvar,
}

/// How a published job is held by the pool.
enum SlotJob {
    /// Lifetime-erased borrow; valid exactly while the slot is published
    /// (the submitter removes it and drains steppers before its `run`
    /// call returns).
    Borrowed(&'static (dyn ParJob + 'static)),
    /// Pool-owned background job ([`CodecPool::spawn`]): workers retire
    /// the slot themselves once `step` reports [`Step::Done`].
    Owned(Arc<dyn ParJob + Send + Sync>),
}

impl SlotJob {
    fn clone_ref(&self) -> SlotJob {
        match self {
            SlotJob::Borrowed(j) => SlotJob::Borrowed(*j),
            SlotJob::Owned(a) => SlotJob::Owned(Arc::clone(a)),
        }
    }

    fn job(&self) -> &dyn ParJob {
        match self {
            SlotJob::Borrowed(j) => *j,
            SlotJob::Owned(a) => a.as_ref(),
        }
    }

    fn is_owned(&self) -> bool {
        matches!(self, SlotJob::Owned(_))
    }
}

struct Slot {
    job: SlotJob,
    id: u64,
    ctl: Arc<SlotCtl>,
}

struct PoolState {
    slots: Vec<Slot>,
    next_id: u64,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
}

/// Decrements the per-job stepper count on drop (panic-safe).
struct StepTicket(Arc<SlotCtl>);

impl Drop for StepTicket {
    fn drop(&mut self) {
        let mut g = self.0.steppers.lock().unwrap();
        *g -= 1;
        if *g == 0 {
            self.0.cv.notify_all();
        }
    }
}

/// A persistent pool of codec workers; see the module docs.
pub struct CodecPool {
    shared: Arc<PoolShared>,
    lanes: usize,
    handles: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl CodecPool {
    /// A pool with `lanes` concurrent codec lanes. The submitting thread
    /// always participates, so `lanes.saturating_sub(1)` helper threads
    /// are spawned; `lanes <= 1` spawns none (serial execution with the
    /// same code path — the serial-equivalence baseline).
    pub fn new(lanes: usize) -> Self {
        let lanes = lanes.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState { slots: Vec::new(), next_id: 0, shutdown: false }),
            work_cv: Condvar::new(),
        });
        let mut handles = Vec::new();
        for w in 0..lanes - 1 {
            let shared = Arc::clone(&shared);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("scda-codec-{w}"))
                    .spawn(move || worker_loop(&shared, w))
                    .expect("spawn codec worker"),
            );
        }
        CodecPool { shared, lanes, handles: Mutex::new(handles) }
    }

    /// Maximum concurrent codec lanes per job (helpers + submitter).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// The process-wide shared pool, sized by `SCDA_CODEC_WORKERS` or the
    /// machine's parallelism (capped at 8 — the codec saturates memory
    /// bandwidth before it saturates very wide machines). Created lazily
    /// on first use; its threads park on a condvar when idle.
    pub fn global() -> &'static CodecPool {
        static POOL: OnceLock<CodecPool> = OnceLock::new();
        POOL.get_or_init(|| CodecPool::new(default_lanes()))
    }

    /// Publish `job`, execute it cooperatively, and return once every
    /// unit is finished and no worker still holds a reference to it.
    pub fn run(&self, job: &dyn ParJob) {
        // Lifetime erasure: sound because this function does not return
        // until the slot is removed and its stepper count has drained —
        // see the module docs.
        let job_static: &'static (dyn ParJob + 'static) =
            unsafe { std::mem::transmute::<&dyn ParJob, &'static (dyn ParJob + 'static)>(job) };
        let ctl = Arc::new(SlotCtl::default());
        let id = {
            let mut st = self.shared.state.lock().unwrap();
            let id = st.next_id;
            st.next_id += 1;
            st.slots.push(Slot { job: SlotJob::Borrowed(job_static), id, ctl: Arc::clone(&ctl) });
            id
        };
        self.shared.work_cv.notify_all();
        loop {
            match job.step(SUBMITTER) {
                Step::Ran => {}
                Step::Idle => job.park(),
                Step::Done => break,
            }
        }
        {
            let mut st = self.shared.state.lock().unwrap();
            st.slots.retain(|s| s.id != id);
        }
        let mut g = ctl.steppers.lock().unwrap();
        while *g > 0 {
            g = ctl.cv.wait(g).unwrap();
        }
    }

    /// Publish an *owned* job and return immediately: the pool's workers
    /// drain it like any published job and retire the slot once `step`
    /// reports [`Step::Done`]. This is the fire-and-forget primitive the
    /// async I/O flush rides on (`crate::io::engine`): staged `pwrite`
    /// runs execute on the codec workers while the submitting rank keeps
    /// encoding. Completion and errors are the job's own business —
    /// implementations expose a handle the submitter can wait on.
    ///
    /// With no helper threads (`lanes <= 1`) the job executes
    /// synchronously on the caller before returning, so background work
    /// degrades to the serial path instead of stalling forever.
    pub fn spawn(&self, job: Arc<dyn ParJob + Send + Sync + 'static>) {
        if self.lanes <= 1 {
            loop {
                match job.step(SUBMITTER) {
                    Step::Ran => {}
                    Step::Idle => job.park(),
                    Step::Done => break,
                }
            }
            return;
        }
        {
            let mut st = self.shared.state.lock().unwrap();
            let id = st.next_id;
            st.next_id += 1;
            st.slots.push(Slot { job: SlotJob::Owned(job), id, ctl: Arc::new(SlotCtl::default()) });
        }
        self.shared.work_cv.notify_all();
    }

    /// Run `f(0..n)` across the pool and return the results in index
    /// order — the ordered-stitch primitive underlying the codec
    /// pipeline's serial-equivalence guarantee.
    pub fn run_ordered<U, F>(&self, n: usize, f: F) -> Vec<U>
    where
        U: Send,
        F: Fn(usize) -> U + Sync,
    {
        if n == 0 {
            return Vec::new();
        }
        let job = BatchJob {
            f,
            n,
            next: AtomicUsize::new(0),
            results: (0..n).map(|_| Mutex::new(None)).collect(),
            finished: Mutex::new(0),
            done_cv: Condvar::new(),
        };
        self.run(&job);
        job.results
            .into_iter()
            .map(|m| match m.into_inner().unwrap().expect("batch unit completed") {
                Ok(u) => u,
                // Re-raise the first (in index order) unit panic here, on
                // the submitting thread.
                Err(panic) => std::panic::resume_unwind(panic),
            })
            .collect()
    }
}

impl Drop for CodecPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn default_lanes() -> usize {
    if let Some(v) = std::env::var_os("SCDA_CODEC_WORKERS") {
        if let Some(n) = v.to_str().and_then(|s| s.trim().parse::<usize>().ok()) {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8)
}

fn worker_loop(shared: &PoolShared, worker: usize) {
    let mut rr = worker; // stagger the first pick across workers
    let mut dry = 0usize;
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.shutdown {
            return;
        }
        if st.slots.is_empty() {
            dry = 0;
            st = shared.work_cv.wait(st).unwrap();
            continue;
        }
        let n = st.slots.len();
        let slot = &st.slots[rr % n];
        rr = rr.wrapping_add(1);
        let job = slot.job.clone_ref();
        let id = slot.id;
        let ctl = Arc::clone(&slot.ctl);
        *ctl.steppers.lock().unwrap() += 1;
        let ticket = StepTicket(ctl);
        drop(st);
        let mut any = false;
        let mut finished = false;
        loop {
            match job.job().step(worker) {
                Step::Ran => any = true,
                Step::Idle => break,
                Step::Done => {
                    finished = true;
                    break;
                }
            }
        }
        drop(ticket);
        st = shared.state.lock().unwrap();
        if finished && job.is_owned() {
            // Owned jobs have no submitter to retire them; the worker that
            // observes completion removes the slot (idempotent by id).
            st.slots.retain(|s| s.id != id);
        }
        if any {
            dry = 0;
            continue;
        }
        dry += 1;
        if dry >= st.slots.len().max(1) {
            // Every published job is momentarily idle (streaming sources
            // refill without notifying the pool), so park with a timeout
            // rather than spinning.
            dry = 0;
            let (g, _) = shared.work_cv.wait_timeout(st, Duration::from_millis(1)).unwrap();
            st = g;
        }
    }
}

/// Fixed-size job: `n` independent units, results stitched by index.
/// Unit panics are caught and re-raised on the submitting thread (so a
/// bug in a codec closure propagates instead of hanging the pool or
/// killing a worker thread).
struct BatchJob<U, F> {
    f: F,
    n: usize,
    next: AtomicUsize,
    results: Vec<Mutex<Option<std::thread::Result<U>>>>,
    finished: Mutex<usize>,
    done_cv: Condvar,
}

impl<U, F> ParJob for BatchJob<U, F>
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    fn step(&self, _worker: usize) -> Step {
        let i = self.next.fetch_add(1, Ordering::Relaxed);
        if i >= self.n {
            // Avoid cursor overflow under pathological re-polling.
            self.next.store(self.n, Ordering::Relaxed);
            let done = *self.finished.lock().unwrap() == self.n;
            return if done { Step::Done } else { Step::Idle };
        }
        let u = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (self.f)(i)));
        *self.results[i].lock().unwrap() = Some(u);
        let mut fin = self.finished.lock().unwrap();
        *fin += 1;
        if *fin == self.n {
            self.done_cv.notify_all();
        }
        Step::Ran
    }

    fn park(&self) {
        let fin = self.finished.lock().unwrap();
        if *fin < self.n {
            // Woken by the last unit's completion (every unit finishes:
            // panics are caught into result slots); the timeout is pure
            // defense in depth.
            let _ = self.done_cv.wait_timeout(fin, Duration::from_millis(10)).unwrap();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_ordered_preserves_index_order() {
        let pool = CodecPool::new(4);
        let out = pool.run_ordered(100, |i| {
            if i % 13 == 0 {
                std::thread::sleep(Duration::from_micros(200));
            }
            i * 3
        });
        assert_eq!(out, (0..100).map(|i| i * 3).collect::<Vec<_>>());
    }

    #[test]
    fn serial_pool_runs_on_submitter() {
        let pool = CodecPool::new(1);
        let me = std::thread::current().id();
        let out = pool.run_ordered(10, move |i| {
            assert_eq!(std::thread::current().id(), me);
            i
        });
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn empty_job_returns_immediately() {
        let pool = CodecPool::new(2);
        let out: Vec<usize> = pool.run_ordered(0, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn concurrent_submitters_share_the_pool() {
        let pool = Arc::new(CodecPool::new(4));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let pool = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                pool.run_ordered(50, move |i| t * 1000 + i as u64)
            }));
        }
        for (t, h) in handles.into_iter().enumerate() {
            let got = h.join().unwrap();
            assert_eq!(got, (0..50).map(|i| t as u64 * 1000 + i).collect::<Vec<_>>());
        }
    }

    #[test]
    fn nested_submission_does_not_deadlock() {
        // A unit of an outer job submits an inner job to the same pool.
        // The helping scheduler guarantees progress even when every
        // worker is parked inside the outer job.
        let pool = Arc::new(CodecPool::new(2));
        let p2 = Arc::clone(&pool);
        let out = pool.run_ordered(4, move |i| p2.run_ordered(8, |j| j).len() + i);
        assert_eq!(out, vec![8, 9, 10, 11]);
    }

    #[test]
    fn unit_panic_propagates_and_pool_survives() {
        let pool = CodecPool::new(4);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run_ordered(16, |i| {
                if i == 7 {
                    panic!("unit bug");
                }
                i
            })
        }));
        assert!(r.is_err());
        // The panic was caught in the worker and re-raised here; every
        // pool thread is still alive and the pool stays usable.
        let out = pool.run_ordered(8, |i| i * 2);
        assert_eq!(out, (0..8).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn borrowed_state_is_safe_across_run() {
        let pool = CodecPool::new(4);
        let data: Vec<u64> = (0..1000).collect();
        let sums = pool.run_ordered(10, |i| data[i * 100..(i + 1) * 100].iter().sum::<u64>());
        assert_eq!(sums.iter().sum::<u64>(), data.iter().sum::<u64>());
    }

    /// Minimal owned job for spawn tests: `n` units bump a counter.
    struct CountJob {
        n: usize,
        next: AtomicUsize,
        done: AtomicUsize,
    }

    impl CountJob {
        fn new(n: usize) -> Self {
            CountJob { n, next: AtomicUsize::new(0), done: AtomicUsize::new(0) }
        }
    }

    impl ParJob for CountJob {
        fn step(&self, _worker: usize) -> Step {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                self.next.store(self.n, Ordering::Relaxed);
                return if self.done.load(Ordering::Acquire) == self.n { Step::Done } else { Step::Idle };
            }
            self.done.fetch_add(1, Ordering::AcqRel);
            Step::Ran
        }
    }

    #[test]
    fn spawned_job_runs_in_background_and_slot_retires() {
        let pool = CodecPool::new(4);
        let job = Arc::new(CountJob::new(64));
        pool.spawn(Arc::clone(&job) as Arc<dyn ParJob + Send + Sync>);
        let t0 = std::time::Instant::now();
        while job.done.load(Ordering::Acquire) < 64 {
            assert!(t0.elapsed() < Duration::from_secs(10), "spawned job never completed");
            std::thread::sleep(Duration::from_millis(1));
        }
        // The pool stays fully usable afterwards (the owned slot retires).
        let out = pool.run_ordered(8, |i| i + 1);
        assert_eq!(out, (1..=8).collect::<Vec<_>>());
    }

    #[test]
    fn spawn_on_serial_pool_executes_inline() {
        let pool = CodecPool::new(1);
        let job = Arc::new(CountJob::new(16));
        pool.spawn(Arc::clone(&job) as Arc<dyn ParJob + Send + Sync>);
        // No helpers: spawn must have completed the job before returning.
        assert_eq!(job.done.load(Ordering::Acquire), 16);
    }

    #[test]
    fn global_pool_is_shared_and_sized() {
        let p1 = CodecPool::global();
        let p2 = CodecPool::global();
        assert!(std::ptr::eq(p1, p2));
        assert!(p1.lanes() >= 1);
    }
}
