//! The trivial single-process communicator. Writing through it is the
//! literal "writing in serial" of the paper's serial-equivalence claim;
//! the T1 experiment compares its output byte-for-byte against every
//! parallel partition.

use crate::par::comm::Communicator;

/// One rank, no synchronization.
#[derive(Debug, Default, Clone)]
pub struct SerialComm;

impl SerialComm {
    pub fn new() -> Self {
        SerialComm
    }
}

impl Communicator for SerialComm {
    fn rank(&self) -> usize {
        0
    }

    fn size(&self) -> usize {
        1
    }

    fn barrier(&self) {}

    fn bcast_bytes(&self, root: usize, data: Option<Vec<u8>>) -> Vec<u8> {
        assert_eq!(root, 0, "serial communicator has only rank 0");
        data.expect("root must provide broadcast data")
    }

    fn allgather_u64(&self, value: u64) -> Vec<u64> {
        vec![value]
    }

    fn allgather_bytes(&self, data: Vec<u8>) -> Vec<Vec<u8>> {
        vec![data]
    }

    fn alltoall_bytes(&self, outgoing: Vec<Vec<u8>>) -> Vec<Vec<u8>> {
        assert_eq!(outgoing.len(), 1, "serial communicator has only rank 0");
        outgoing
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collectives_are_identity() {
        let c = SerialComm::new();
        assert_eq!(c.rank(), 0);
        assert_eq!(c.size(), 1);
        c.barrier();
        assert_eq!(c.bcast_bytes(0, Some(vec![1, 2, 3])), vec![1, 2, 3]);
        assert_eq!(c.allgather_u64(9), vec![9]);
        assert_eq!(c.allgather_bytes(vec![7]), vec![vec![7]]);
    }
}
