//! The parallel partition of §A.1: a disjoint, ordered, rank-monotone
//! assignment of `N` array elements to `P` processes, encoded as
//! per-process counts `(N_q)_{<P}` with offsets `C_p = sum_{q<p} N_q`
//! (so `C_0 = 0` and `C_P = N`), and the derived byte sizes `S_p` for
//! variable element sizes `(E_i)`.

use crate::error::{usage, Result, ScdaError};

/// Per-process element counts plus precomputed offsets.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Partition {
    counts: Vec<u64>,
    /// `offsets[p] = C_p`; length `P + 1`, `offsets[P] = N`.
    offsets: Vec<u64>,
}

impl Partition {
    /// Build from per-process counts `(N_q)_{<P}` (collective input — all
    /// ranks must pass identical arrays; see §A.2).
    pub fn from_counts(counts: &[u64]) -> Self {
        let mut offsets = Vec::with_capacity(counts.len() + 1);
        let mut acc = 0u64;
        offsets.push(0);
        for &c in counts {
            acc += c;
            offsets.push(acc);
        }
        Partition { counts: counts.to_vec(), offsets }
    }

    /// The canonical balanced partition of `total` over `ranks` processes:
    /// the first `total % ranks` ranks receive one extra element. This is
    /// the partition p4est-style SFC codes use for uniform element data.
    pub fn uniform(ranks: usize, total: u64) -> Self {
        assert!(ranks >= 1);
        let base = total / ranks as u64;
        let extra = (total % ranks as u64) as usize;
        let counts: Vec<u64> =
            (0..ranks).map(|p| base + if p < extra { 1 } else { 0 }).collect();
        Partition::from_counts(&counts)
    }

    /// Everything on one rank (rank 0 of `ranks`).
    pub fn root_only(ranks: usize, total: u64) -> Self {
        let mut counts = vec![0u64; ranks];
        counts[0] = total;
        Partition::from_counts(&counts)
    }

    pub fn num_ranks(&self) -> usize {
        self.counts.len()
    }

    /// Global element count `N`.
    pub fn total(&self) -> u64 {
        *self.offsets.last().unwrap()
    }

    /// `N_p`.
    pub fn count(&self, rank: usize) -> u64 {
        self.counts[rank]
    }

    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// `C_p`.
    pub fn offset(&self, rank: usize) -> u64 {
        self.offsets[rank]
    }

    /// The element index range `[C_p, C_{p+1})` owned by `rank`.
    pub fn local_range(&self, rank: usize) -> std::ops::Range<u64> {
        self.offsets[rank]..self.offsets[rank + 1]
    }

    /// Owner of the global element `idx` (binary search over offsets;
    /// when several empty ranks share an offset, the owner is the one
    /// whose half-open range contains `idx`).
    pub fn owner_of(&self, idx: u64) -> usize {
        debug_assert!(idx < self.total());
        // partition_point: first p with offsets[p+1] > idx.
        let mut lo = 0usize;
        let mut hi = self.counts.len() - 1;
        while lo < hi {
            let mid = (lo + hi) / 2;
            if self.offsets[mid + 1] > idx {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        lo
    }

    /// Validate against a global element count (usage error group: the
    /// reading partition "must satisfy `sum N_q = N`", §A.5.4).
    pub fn check_total(&self, n: u64) -> Result<()> {
        if self.total() != n {
            return Err(ScdaError::usage(
                usage::PARTITION_MISMATCH,
                format!("partition sums to {} but the section holds {} elements", self.total(), n),
            ));
        }
        Ok(())
    }

    /// Per-process byte counts `S_p` for fixed element size `E`:
    /// `S_p = N_p * E` (13).
    pub fn byte_counts_fixed(&self, elem_size: u64) -> Vec<u64> {
        self.counts.iter().map(|&n| n * elem_size).collect()
    }

    /// Per-process byte counts `S_p = sum_{i in [C_p, C_{p+1})} E_i` (12),
    /// computed from the *global* size array.
    pub fn byte_counts_var(&self, elem_sizes: &[u64]) -> Result<Vec<u64>> {
        if elem_sizes.len() as u64 != self.total() {
            return Err(ScdaError::usage(
                usage::PARTITION_MISMATCH,
                format!("{} element sizes for {} elements", elem_sizes.len(), self.total()),
            ));
        }
        Ok((0..self.num_ranks())
            .map(|p| {
                let r = self.local_range(p);
                elem_sizes[r.start as usize..r.end as usize].iter().sum()
            })
            .collect())
    }
}

/// A rebalancing *plan*: for each destination rank, the list of
/// `(source_rank, first_global_elem, count)` transfers that assemble its
/// new local range from the old partition. Pure index arithmetic — the
/// coordinator uses it both for in-memory repartitioning and to derive
/// read windows when restarting on a different process count.
pub fn transfer_plan(old: &Partition, new: &Partition) -> Vec<Vec<(usize, u64, u64)>> {
    assert_eq!(old.total(), new.total());
    let mut plan = vec![Vec::new(); new.num_ranks()];
    for dst in 0..new.num_ranks() {
        let range = new.local_range(dst);
        let mut at = range.start;
        while at < range.end {
            let src = old.owner_of(at);
            let src_end = old.local_range(src).end;
            let take = (range.end - at).min(src_end - at);
            plan[dst].push((src, at, take));
            at += take;
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn offsets_satisfy_eleven() {
        // (11): C_0 = 0, C_P = N.
        let p = Partition::from_counts(&[3, 0, 5, 2]);
        assert_eq!(p.offset(0), 0);
        assert_eq!(p.total(), 10);
        assert_eq!(p.offset(3), 8);
        assert_eq!(p.local_range(2), 3..8);
    }

    #[test]
    fn uniform_balances() {
        let p = Partition::uniform(4, 10);
        assert_eq!(p.counts(), &[3, 3, 2, 2]);
        assert_eq!(p.total(), 10);
        let p = Partition::uniform(3, 0);
        assert_eq!(p.counts(), &[0, 0, 0]);
    }

    #[test]
    fn owner_lookup_with_empty_ranks() {
        let p = Partition::from_counts(&[2, 0, 0, 3, 0, 1]);
        assert_eq!(p.owner_of(0), 0);
        assert_eq!(p.owner_of(1), 0);
        assert_eq!(p.owner_of(2), 3);
        assert_eq!(p.owner_of(4), 3);
        assert_eq!(p.owner_of(5), 5);
    }

    #[test]
    fn byte_counts_match_twelve_and_thirteen() {
        let p = Partition::from_counts(&[2, 1, 0]);
        assert_eq!(p.byte_counts_fixed(8), vec![16, 8, 0]);
        let sizes = vec![5u64, 7, 100];
        assert_eq!(p.byte_counts_var(&sizes).unwrap(), vec![12, 100, 0]);
        assert!(p.byte_counts_var(&[1, 2]).is_err());
    }

    #[test]
    fn check_total_is_usage_error() {
        let p = Partition::from_counts(&[1, 2]);
        assert!(p.check_total(3).is_ok());
        let err = p.check_total(4).unwrap_err();
        assert_eq!(err.kind(), crate::error::ScdaErrorKind::Usage);
    }

    #[test]
    fn transfer_plan_covers_every_destination_exactly_once() {
        let mut rng = Rng::new(99);
        for _ in 0..100 {
            let total = rng.range(0, 500);
            let old_ranks = rng.range(1, 8) as usize;
            let new_ranks = rng.range(1, 8) as usize;
            let old = Partition::from_counts(&rng.partition(total, old_ranks));
            let new = Partition::from_counts(&rng.partition(total, new_ranks));
            let plan = transfer_plan(&old, &new);
            for dst in 0..new.num_ranks() {
                let mut covered = new.local_range(dst).start;
                for &(src, start, count) in &plan[dst] {
                    assert_eq!(start, covered);
                    assert!(count > 0);
                    // Every transferred element belongs to src in `old`.
                    let sr = old.local_range(src);
                    assert!(start >= sr.start && start + count <= sr.end);
                    covered += count;
                }
                assert_eq!(covered, new.local_range(dst).end);
            }
        }
    }
}
