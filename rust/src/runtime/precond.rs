//! The preconditioning transform on the I/O hot path: tile-local XOR
//! delta + byte-plane shuffle (see python/compile/kernels/shuffle_delta.py
//! for the specification and DESIGN.md §Hardware-Adaptation for why).
//!
//! Two interchangeable backends produce bit-identical bytes:
//! * [`Backend::Pjrt`] executes the AOT-compiled JAX/Pallas graphs;
//! * [`Backend::Native`] is the hand-written Rust fallback (also used for
//!   sub-chunk tails and when `artifacts/` is absent).
//!
//! Canonical stream layout for arbitrary byte payloads: the payload is
//! split into spans of up to [`CHUNK`] u32 words; each span contributes
//! its four byte planes (plane-major), and a trailing `len % 4` raw bytes
//! pass through untouched. Output length always equals input length.

use std::path::Path;

use crate::error::Result;
use crate::runtime::engine::Engine;

/// Tile length in u32 words — must match `shuffle_delta.TILE`.
pub const TILE: usize = 2048;
/// Steady-state span length in u32 words — the largest AOT chunk.
pub const CHUNK: usize = 65536;

/// Execution backend for the transform.
pub enum Backend {
    Pjrt(Engine),
    Native,
}

/// The preconditioner applied by the coordinator before per-element
/// compression (and after decompression, inverted).
pub struct Preconditioner {
    backend: Backend,
}

impl Preconditioner {
    /// Load the PJRT backend from `artifacts/`, falling back to the
    /// native implementation when artifacts are missing.
    pub fn auto(artifacts_dir: &Path) -> Self {
        match Engine::load(artifacts_dir) {
            Ok(engine) => Preconditioner { backend: Backend::Pjrt(engine) },
            Err(_) => Preconditioner { backend: Backend::Native },
        }
    }

    pub fn native() -> Self {
        Preconditioner { backend: Backend::Native }
    }

    pub fn pjrt(artifacts_dir: &Path) -> Result<Self> {
        Ok(Preconditioner { backend: Backend::Pjrt(Engine::load(artifacts_dir)?) })
    }

    pub fn backend_name(&self) -> &'static str {
        match self.backend {
            Backend::Pjrt(_) => "pjrt",
            Backend::Native => "native",
        }
    }

    /// Forward transform of an arbitrary byte payload. Returns the
    /// transformed bytes (same length) and the byte-entropy estimate of
    /// the first span (bits/byte; 8.0 = incompressible).
    pub fn forward(&self, data: &[u8]) -> Result<(Vec<u8>, f32)> {
        let words = data.len() / 4;
        let tail = &data[words * 4..];
        let mut out = Vec::with_capacity(data.len());
        let mut entropy = None;
        let mut at = 0usize;
        while at < words {
            let span = (words - at).min(CHUNK);
            let src = &data[at * 4..(at + span) * 4];
            let x: Vec<u32> = src.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();
            let (planes, ent) = match &self.backend {
                Backend::Pjrt(engine) if span == CHUNK => engine.forward_chunk(&x)?,
                // Sub-chunk spans: PJRT would pad to a compiled shape and
                // burn interpret-mode cycles on padding; only worthwhile
                // when the span fills most of the smallest graph.
                Backend::Pjrt(engine) if 2 * span >= engine.pick_chunk(span) => {
                    let n = engine.pick_chunk(span);
                    let mut padded = x.clone();
                    padded.resize(n, 0);
                    let (full, ent) = engine.forward_chunk(&padded)?;
                    let mut planes = Vec::with_capacity(4 * span);
                    for k in 0..4 {
                        planes.extend_from_slice(&full[k * n..k * n + span]);
                    }
                    (planes, ent)
                }
                _ => native_forward(&x),
            };
            if entropy.is_none() {
                entropy = Some(ent);
            }
            out.extend_from_slice(&planes);
            at += span;
        }
        out.extend_from_slice(tail);
        debug_assert_eq!(out.len(), data.len());
        Ok((out, entropy.unwrap_or(8.0)))
    }

    /// Exact inverse of [`Self::forward`].
    pub fn inverse(&self, data: &[u8]) -> Result<Vec<u8>> {
        let words = data.len() / 4;
        let tail = &data[words * 4..];
        let mut out = Vec::with_capacity(data.len());
        let mut at = 0usize;
        while at < words {
            let span = (words - at).min(CHUNK);
            let planes = &data[at * 4..(at + span) * 4];
            let x: Vec<u32> = match &self.backend {
                Backend::Pjrt(engine) if span == CHUNK => engine.inverse_chunk(planes)?,
                Backend::Pjrt(engine) if 2 * span >= engine.pick_chunk(span) => {
                    let n = engine.pick_chunk(span);
                    // Re-pad plane-major columns with zeros.
                    let mut padded = vec![0u8; 4 * n];
                    for k in 0..4 {
                        padded[k * n..k * n + span].copy_from_slice(&planes[k * span..(k + 1) * span]);
                    }
                    let mut full = engine.inverse_chunk(&padded)?;
                    full.truncate(span);
                    full
                }
                _ => native_inverse(planes, span),
            };
            for v in &x {
                out.extend_from_slice(&v.to_le_bytes());
            }
            at += span;
        }
        out.extend_from_slice(tail);
        debug_assert_eq!(out.len(), data.len());
        Ok(out)
    }
}

/// Native forward: tile-local XOR delta + plane split over one span.
/// Bit-identical to the Pallas kernel (`_fwd_kernel`).
pub fn native_forward(x: &[u32]) -> (Vec<u8>, f32) {
    let n = x.len();
    let mut planes = vec![0u8; 4 * n];
    let (p0, rest) = planes.split_at_mut(n);
    let (p1, rest) = rest.split_at_mut(n);
    let (p2, p3) = rest.split_at_mut(n);
    let mut prev = 0u32;
    for (i, &v) in x.iter().enumerate() {
        if i % TILE == 0 {
            prev = 0;
        }
        let d = v ^ prev;
        prev = v;
        p0[i] = d as u8;
        p1[i] = (d >> 8) as u8;
        p2[i] = (d >> 16) as u8;
        p3[i] = (d >> 24) as u8;
    }
    let ent = entropy_estimate(&planes);
    (planes, ent)
}

/// Native inverse: plane merge + tile-local prefix-XOR scan.
pub fn native_inverse(planes: &[u8], n: usize) -> Vec<u32> {
    debug_assert_eq!(planes.len(), 4 * n);
    let mut out = Vec::with_capacity(n);
    let mut acc = 0u32;
    for i in 0..n {
        if i % TILE == 0 {
            acc = 0;
        }
        let d = planes[i] as u32
            | (planes[n + i] as u32) << 8
            | (planes[2 * n + i] as u32) << 16
            | (planes[3 * n + i] as u32) << 24;
        acc ^= d;
        out.push(acc);
    }
    out
}

/// Shannon entropy (bits/byte) over a leading sample — the native analog
/// of `model.byte_entropy_estimate` (decision heuristic; approximate
/// equality with the PJRT value is sufficient).
pub fn entropy_estimate(bytes: &[u8]) -> f32 {
    const SAMPLE: usize = 8192;
    let s = &bytes[..bytes.len().min(SAMPLE)];
    if s.is_empty() {
        return 0.0;
    }
    let mut counts = [0u32; 256];
    for &b in s {
        counts[b as usize] += 1;
    }
    let total = s.len() as f32;
    let mut ent = 0.0f32;
    for &c in &counts {
        if c > 0 {
            let p = c as f32 / total;
            ent -= p * p.log2();
        }
    }
    ent
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::Rng;

    #[test]
    fn native_roundtrips_all_lengths() {
        let mut rng = Rng::new(11);
        for n in [0usize, 1, 2, TILE - 1, TILE, TILE + 1, 3 * TILE + 17] {
            let x: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
            let (planes, _) = native_forward(&x);
            assert_eq!(planes.len(), 4 * n);
            assert_eq!(native_inverse(&planes, n), x);
        }
    }

    #[test]
    fn preconditioner_native_roundtrips_bytes() {
        let p = Preconditioner::native();
        let mut rng = Rng::new(5);
        for len in [0usize, 1, 3, 4, 5, 8191, 8192, 8193, 4 * CHUNK + 7, 4 * CHUNK * 2 + 13] {
            let data = rng.bytes(len, 256);
            let (t, ent) = p.forward(&data).unwrap();
            assert_eq!(t.len(), len);
            assert!((0.0..=8.01).contains(&ent));
            assert_eq!(p.inverse(&t).unwrap(), data, "len {len}");
        }
    }

    #[test]
    fn smooth_data_transforms_compressible() {
        // A smooth f32 field: after delta+shuffle the high-significance
        // planes are near-constant, so deflate does strictly better than
        // on the raw float bytes. (The entropy estimate samples the low
        // plane and is only a go/no-go heuristic, not asserted here.)
        let vals: Vec<f32> = (0..CHUNK).map(|i| (i as f32 * 1e-4).sin() + 10.0).collect();
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let p = Preconditioner::native();
        let (t, _ent) = p.forward(&bytes).unwrap();
        let z_raw = crate::codec::zlib_compress(&bytes, 6).len();
        let z_t = crate::codec::zlib_compress(&t, 6).len();
        assert!(
            (z_t as f64) < 0.9 * z_raw as f64,
            "shuffled {z_t} vs raw {z_raw} of {} input bytes",
            bytes.len()
        );
    }

    #[test]
    fn tile_locality_makes_output_chunking_invariant() {
        // The span decomposition must not change the bytes: transform of
        // a 2.5-chunk payload equals concatenation of per-span transforms.
        let mut rng = Rng::new(9);
        let words = 2 * CHUNK + CHUNK / 2;
        let x: Vec<u32> = (0..words).map(|_| rng.next_u64() as u32).collect();
        let bytes: Vec<u8> = x.iter().flat_map(|v| v.to_le_bytes()).collect();
        let p = Preconditioner::native();
        let (whole, _) = p.forward(&bytes).unwrap();
        let mut parts = Vec::new();
        for span in [CHUNK, CHUNK, CHUNK / 2] {
            let at = parts.len() / 4;
            let (t, _) = native_forward(&x[at..at + span]);
            parts.extend_from_slice(&t);
        }
        assert_eq!(whole, parts);
    }

    #[test]
    fn entropy_estimate_extremes() {
        assert_eq!(entropy_estimate(&[]), 0.0);
        assert_eq!(entropy_estimate(&[7u8; 4096]), 0.0);
        let uniform: Vec<u8> = (0..8192u32).map(|i| (i % 256) as u8).collect();
        let e = entropy_estimate(&uniform);
        assert!((e - 8.0).abs() < 1e-3);
    }
}
