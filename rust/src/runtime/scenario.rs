//! End-to-end AMR churn scenario: refine → rebalance → checkpoint →
//! crash → restore-on-a-different-rank-count, as one deterministic,
//! seedable driver.
//!
//! The paper's claim is that scda files are invariant under linear
//! repartition; this module is the workload that *exercises* the claim
//! with every layer the crate has. Each cycle moves a ring-shaped
//! refinement front across the unit square ([`mesh_at`]), rebalances
//! the Morton-ordered leaves by payload bytes
//! ([`crate::coordinator::rebalance::by_bytes`] + `exchange`), writes a
//! versioned checkpoint of one fixed-size field (`rho`) and one
//! variable-size hp field (`hp`) through [`crate::archive::restart`],
//! and — when a crash seed is armed — replays the same deterministic
//! write stream into a sacrificial sibling file under
//! [`FaultPlan::seeded_crash`], recovers the torn tail, and restores
//! every surviving step on a *different* rank count, comparing restored
//! bytes against an independently recomputed reference.
//!
//! Two properties make the cross-P verification honest:
//!
//! * the global element stream of a cycle is a pure function of
//!   `(seed, cycle)`, so any rank on any partition can recompute its
//!   window of the reference bytes without talking to the writer;
//! * serial equivalence means the crash replay may run at P = 1: a torn
//!   prefix of the serial file *is* a torn prefix of the P-rank file,
//!   byte for byte (asserted by `tests/amr_scenario.rs`).
//!
//! Phases are traced ([`SpanKind::Refine`], [`SpanKind::Rebalance`],
//! [`SpanKind::Restore`] plus the existing write/recover spans) when
//! [`ScenarioConfig::traced`] is set, and I/O counters fold into one
//! [`Metrics`] exactly once per handle. `scda amr-bench` and
//! `bench_support::amr_bench` wrap this module; `BENCH_amr.json` is the
//! committed snapshot.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use crate::api::IoTuning;
use crate::archive::{recover_with, restart, Archive, RecoveryAction};
use crate::coordinator::rebalance::{by_bytes, by_count, exchange};
use crate::coordinator::{Field, FieldPayload, Metrics};
use crate::error::{corrupt, usage, Result, ScdaError};
use crate::io::FaultPlan;
use crate::mesh::fields::{hp_payload_size, local_fixed_field, local_hp_field};
use crate::mesh::{check_mesh, ring_mesh, Quadrant};
use crate::obs::{Span, SpanKind, Tracer};
use crate::par::{run_parallel, Communicator, Partition, SerialComm};
use crate::runtime::Identity;

/// Application string stamped into every scenario checkpoint manifest.
pub const APP_NAME: &str = "amr";
/// Fixed-size field name (`ckpt/<n>/rho`).
pub const FIXED_FIELD: &str = "rho";
/// Variable-size hp field name (`ckpt/<n>/hp`).
pub const HP_FIELD: &str = "hp";

/// Knobs of one scenario run. `Copy` on purpose: the driver shares the
/// config across writer/reader threads by value, which keeps every
/// closure trivially `Send + Sync`.
#[derive(Clone, Copy, Debug)]
pub struct ScenarioConfig {
    /// Checkpoint steps written (steps are numbered `1..=cycles`).
    pub cycles: u32,
    /// Uniform refinement floor of the ring mesh.
    pub base_level: u8,
    /// Refinement cap at the moving front.
    pub max_level: u8,
    /// Writer rank count P.
    pub writers: usize,
    /// Restore rank count P' (the interesting case is P' ≠ P).
    pub restore_ranks: usize,
    /// Doubles per element of the fixed field.
    pub fixed_k: usize,
    /// Polynomial degree cap of the hp field (payload grows with level).
    pub max_degree: u32,
    /// Compress field payloads.
    pub encode: bool,
    /// Seed of the moving refinement front (mesh shape per cycle).
    pub seed: u64,
    /// `Some(seed)` arms the crash replay leg.
    pub crash_seed: Option<u64>,
    /// Upper bound on the seeded crash trigger (write ops before the
    /// power cut), forwarded to [`FaultPlan::seeded_crash`].
    pub crash_max_trigger: u64,
    /// Record per-phase spans and merge them cross-rank.
    pub traced: bool,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            cycles: 3,
            base_level: 2,
            max_level: 5,
            writers: 2,
            restore_ranks: 3,
            fixed_k: 5,
            max_degree: 6,
            encode: true,
            seed: 0x5cda,
            crash_seed: None,
            crash_max_trigger: 64,
            traced: false,
        }
    }
}

impl ScenarioConfig {
    fn validate(&self) -> Result<()> {
        let bad = |msg: String| Err(ScdaError::usage(usage::BAD_CONFIG, msg));
        if self.cycles == 0 {
            return bad("scenario needs at least one cycle".into());
        }
        if self.writers == 0 || self.restore_ranks == 0 {
            return bad("writer and restore rank counts must be >= 1".into());
        }
        if self.base_level > self.max_level {
            return bad(format!(
                "base_level {} exceeds max_level {}",
                self.base_level, self.max_level
            ));
        }
        if self.fixed_k == 0 {
            return bad("fixed_k must be >= 1".into());
        }
        Ok(())
    }

    fn fixed_elem_size(&self) -> u64 {
        (self.fixed_k * 8) as u64
    }
}

/// Wall time and volume of one cycle (rank 0's clock).
#[derive(Clone, Copy, Debug, Default)]
pub struct CycleStats {
    /// Step number (1-based).
    pub cycle: u64,
    /// Leaves in this cycle's mesh.
    pub elements: u64,
    /// Field payload bytes checkpointed (both fields, all ranks).
    pub payload_bytes: u64,
    /// Payload bytes whose owning rank changed in the rebalance.
    pub moved_bytes: u64,
    /// Seconds in refine (mesh build + validity check).
    pub refine_s: f64,
    /// Seconds in rebalance (weights, partition, exchange, verify).
    pub rebalance_s: f64,
    /// Seconds in checkpoint write (`write_step` + flush).
    pub write_s: f64,
}

/// Outcome of the crash replay + recovery leg.
#[derive(Clone, Copy, Debug)]
pub struct RecoverStats {
    /// Seconds spent in [`crate::archive::recover_with`].
    pub seconds: f64,
    /// Recovery rebuilt the trailer (vs found the file intact).
    pub rebuilt: bool,
    /// Torn bytes dropped from the tail.
    pub truncated_bytes: u64,
    /// Datasets that survived recovery.
    pub datasets: u64,
    /// Steps whose *complete* dataset set (info, manifest, both
    /// fields) survived — these restored byte-identically on
    /// [`ScenarioConfig::restore_ranks`].
    pub steps_survived: u64,
}

/// Outcome of the restore-by-name verification leg.
#[derive(Clone, Copy, Debug)]
pub struct RestoreStats {
    /// Reader rank count P'.
    pub ranks: usize,
    /// Steps restored and verified.
    pub steps: u64,
    /// Field payload bytes restored (all ranks).
    pub payload_bytes: u64,
    /// Wall seconds for the whole restore sweep.
    pub seconds: f64,
}

/// Everything one [`run_scenario`] call produced.
#[derive(Debug)]
pub struct ScenarioReport {
    /// Per-cycle phase timings (rank 0).
    pub cycles: Vec<CycleStats>,
    /// Final size of the (uncrashed) archive.
    pub file_bytes: u64,
    /// Crash/recover leg, present when a crash seed was armed.
    pub recover: Option<RecoverStats>,
    /// Restore-by-name verification on `restore_ranks`.
    pub restore: RestoreStats,
    /// Merged spans from every traced leg (empty when untraced).
    pub spans: Vec<Span>,
    /// Folded I/O + pipeline counters (write + restore legs).
    pub metrics: Arc<Metrics>,
}

// ---------------------------------------------------------------------
// Deterministic workload shape
// ---------------------------------------------------------------------

fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Center and radius of the refinement front at `cycle`. The center
/// orbits the domain midpoint on a golden-angle schedule so successive
/// cycles never overlap, and the radius breathes with the seed — every
/// value is a pure function of `(seed, cycle)`.
pub fn front(seed: u64, cycle: u64) -> ((f64, f64), f64) {
    let h = splitmix(seed ^ cycle.wrapping_mul(0x9e37_79b9_7f4a_7c15));
    let unit = (h >> 11) as f64 / (1u64 << 53) as f64;
    let turns = (unit + 0.618_033_988_749_895 * cycle as f64).fract();
    let theta = std::f64::consts::TAU * turns;
    let radius = 0.12 + 0.10 * (((h >> 16) & 0xffff) as f64 / 65536.0);
    ((0.5 + 0.2 * theta.cos(), 0.5 + 0.2 * theta.sin()), radius)
}

/// The cycle's mesh: a ring of max-level refinement around the moving
/// front over a uniform base. Deterministic — every rank (and the
/// restore leg, on a different rank count) recomputes the same leaves.
pub fn mesh_at(cfg: &ScenarioConfig, cycle: u64) -> Vec<Quadrant> {
    let (center, radius) = front(cfg.seed, cycle);
    ring_mesh(cfg.base_level, cfg.max_level, center, radius)
}

/// Checkpoint bytes each leaf contributes (fixed + hp payload) — the
/// weights `by_bytes` balances.
pub fn element_weights(leaves: &[Quadrant], fixed_k: usize, max_degree: u32) -> Vec<u64> {
    leaves.iter().map(|q| (fixed_k * 8) as u64 + hp_payload_size(q, max_degree)).collect()
}

/// Path of the sacrificial crash-replay sibling (`<file>.crash`).
pub fn crash_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".crash");
    PathBuf::from(os)
}

fn usize_range(r: std::ops::Range<u64>) -> std::ops::Range<usize> {
    r.start as usize..r.end as usize
}

fn mismatch(detail: i32, what: String) -> ScdaError {
    ScdaError::corrupt(detail, what)
}

/// Collective OR of a local failure flag, so a rank that *would* bail
/// out early instead fails in lockstep with its peers (a lone early
/// return would strand the others in the next barrier).
fn agree_ok<C: Communicator>(comm: &C, local_ok: bool, what: &str) -> Result<()> {
    let votes = comm.allgather_bytes(vec![local_ok as u8]);
    if votes.iter().all(|v| v == &[1u8]) {
        Ok(())
    } else {
        Err(mismatch(corrupt::SCENARIO_MISMATCH, format!("scenario verification failed: {what}")))
    }
}

// ---------------------------------------------------------------------
// Cycle body shared by the parallel write leg and the serial crash leg
// ---------------------------------------------------------------------

/// The deterministic per-cycle element stream: leaves, byte weights and
/// the byte-balanced target partition for `ranks` writers.
fn cycle_shape(cfg: &ScenarioConfig, cycle: u64, ranks: usize) -> (Vec<Quadrant>, Vec<u64>, Partition) {
    let leaves = mesh_at(cfg, cycle);
    let weights = element_weights(&leaves, cfg.fixed_k, cfg.max_degree);
    let part = by_bytes(&weights, ranks);
    (leaves, weights, part)
}

/// Build this rank's two checkpoint fields over `range` of `leaves`.
fn make_fields(
    cfg: &ScenarioConfig,
    leaves: &[Quadrant],
    range: std::ops::Range<usize>,
    fixed: Vec<u8>,
    hp_sizes: Vec<u64>,
    hp: Vec<u8>,
) -> [Field; 2] {
    debug_assert_eq!(fixed.len() as u64, range.len() as u64 * cfg.fixed_elem_size());
    debug_assert_eq!(hp_sizes.len(), leaves[range].len());
    [
        Field {
            name: FIXED_FIELD.to_string(),
            encode: cfg.encode,
            precondition: false,
            payload: FieldPayload::Fixed { elem_size: cfg.fixed_elem_size(), data: fixed },
        },
        Field {
            name: HP_FIELD.to_string(),
            encode: cfg.encode,
            precondition: false,
            payload: FieldPayload::Var { sizes: hp_sizes, data: hp },
        },
    ]
}

// ---------------------------------------------------------------------
// Write leg (P writer ranks, one archive, `cycles` steps)
// ---------------------------------------------------------------------

fn write_leg(
    path: &Path,
    cfg: &ScenarioConfig,
    metrics: &Arc<Metrics>,
) -> Result<(Vec<CycleStats>, Vec<Span>)> {
    let cfg = *cfg;
    let path = path.to_path_buf();
    let metrics = Arc::clone(metrics);
    let legs = run_parallel(cfg.writers, move |comm| -> Result<(Vec<CycleStats>, Vec<Span>)> {
        let rank = comm.rank();
        let size = comm.size();
        let tracer = cfg.traced.then(|| Arc::new(Tracer::for_rank(rank)));
        let mut ar = Archive::create(comm, &path, b"scda amr churn scenario")?;
        ar.file_mut().set_io_tuning(IoTuning::collective())?;
        if let Some(t) = &tracer {
            ar.file_mut().set_tracer(Some(Arc::clone(t)))?;
        }
        let mut stats = Vec::with_capacity(cfg.cycles as usize);
        for cycle in 1..=cfg.cycles as u64 {
            // --- refine: build this cycle's mesh and validate it.
            let t0 = Instant::now();
            let mut span = tracer.as_ref().map(|t| Tracer::start(t, SpanKind::Refine));
            let (leaves, weights, part_new) = cycle_shape(&cfg, cycle, size);
            let n = leaves.len() as u64;
            let mesh_ok = check_mesh(&leaves);
            if let Some(s) = span.as_mut() {
                s.set_bytes(n);
                s.set_detail(cycle);
            }
            drop(span);
            let refine_s = t0.elapsed().as_secs_f64();
            agree_ok(ar.file().comm(), mesh_ok, "refine produced an invalid mesh")?;

            // --- rebalance: naive uniform ownership → byte-balanced
            // ownership, payloads moved through the allgather exchange,
            // then checked against a direct recomputation of the new
            // window (the exchange must be a pure relabeling).
            let t1 = Instant::now();
            let mut span = tracer.as_ref().map(|t| Tracer::start(t, SpanKind::Rebalance));
            let part_old = by_count(n, size);
            let old = usize_range(part_old.local_range(rank));
            let new = usize_range(part_new.local_range(rank));
            let fixed_old = local_fixed_field(&leaves, old.clone(), cfg.fixed_k);
            let (hp_sizes_old, hp_old) = local_hp_field(&leaves, old.clone(), cfg.max_degree);
            let fixed_sizes_old = vec![cfg.fixed_elem_size(); old.len()];
            let (_, fixed_new) =
                exchange(ar.file().comm(), &part_old, &part_new, &fixed_sizes_old, &fixed_old);
            let (hp_sizes_new, hp_new) =
                exchange(ar.file().comm(), &part_old, &part_new, &hp_sizes_old, &hp_old);
            let fixed_ref = local_fixed_field(&leaves, new.clone(), cfg.fixed_k);
            let (hp_sizes_ref, hp_ref) = local_hp_field(&leaves, new.clone(), cfg.max_degree);
            let exchange_ok =
                fixed_new == fixed_ref && hp_sizes_new == hp_sizes_ref && hp_new == hp_ref;
            let moved_bytes: u64 = weights
                .iter()
                .enumerate()
                .filter(|&(i, _)| part_old.owner_of(i as u64) != part_new.owner_of(i as u64))
                .map(|(_, w)| *w)
                .sum();
            if let Some(s) = span.as_mut() {
                s.set_bytes((fixed_new.len() + hp_new.len()) as u64);
                s.set_detail(cycle);
            }
            drop(span);
            let rebalance_s = t1.elapsed().as_secs_f64();
            agree_ok(
                ar.file().comm(),
                exchange_ok,
                "exchanged payload differs from the recomputed reference",
            )?;

            // --- checkpoint: one versioned step under the balanced
            // partition; the flush lands the cycle's sections on disk
            // so write_s measures real I/O, not staging.
            let t2 = Instant::now();
            let fields = make_fields(&cfg, &leaves, new, fixed_new, hp_sizes_new, hp_new);
            restart::write_step(&mut ar, APP_NAME, cycle, &part_new, &fields, &Identity, &metrics)?;
            Metrics::timed(&metrics.ns_write, || ar.file_mut().flush())?;
            let write_s = t2.elapsed().as_secs_f64();

            stats.push(CycleStats {
                cycle,
                elements: n,
                payload_bytes: weights.iter().sum(),
                moved_bytes,
                refine_s,
                rebalance_s,
                write_s,
            });
        }
        metrics.absorb_io_write(&ar.file().io_stats());
        metrics.absorb_engine(&ar.file().engine_stats());
        ar.finish()?;
        let spans = tracer.and_then(|t| t.merged()).unwrap_or_default();
        Ok((stats, spans))
    });
    let mut out = None;
    for (rank, leg) in legs.into_iter().enumerate() {
        let (stats, spans) = leg?;
        if rank == 0 {
            out = Some((stats, spans));
        }
    }
    Ok(out.expect("run_parallel returns one leg per rank"))
}

// ---------------------------------------------------------------------
// Restore leg (P' reader ranks, every step verified against recompute)
// ---------------------------------------------------------------------

fn restore_leg(
    path: &Path,
    cfg: &ScenarioConfig,
    steps: &[u64],
    ranks: usize,
    metrics: &Arc<Metrics>,
) -> Result<(RestoreStats, Vec<Span>)> {
    let cfg = *cfg;
    let path = path.to_path_buf();
    let steps: Vec<u64> = steps.to_vec();
    let metrics = Arc::clone(metrics);
    let t = Instant::now();
    let legs = run_parallel(ranks, move |comm| -> Result<(u64, Vec<Span>)> {
        let rank = comm.rank();
        let tracer = cfg.traced.then(|| Arc::new(Tracer::for_rank(rank)));
        let mut ar = Archive::open(comm, &path)?;
        if let Some(t) = &tracer {
            ar.file_mut().set_tracer(Some(Arc::clone(t)))?;
        }
        let mut bytes = 0u64;
        for &step in &steps {
            let leaves = mesh_at(&cfg, step);
            let n = leaves.len() as u64;
            let part = Partition::uniform(ranks, n);
            let window = usize_range(part.local_range(rank));
            let mut span = tracer.as_ref().map(|t| Tracer::start(t, SpanKind::Restore));
            let (info, fields) = restart::read_step(&mut ar, Some(step), &part, &Identity)?;
            let fixed_ref = local_fixed_field(&leaves, window.clone(), cfg.fixed_k);
            let (hp_sizes_ref, hp_ref) = local_hp_field(&leaves, window.clone(), cfg.max_degree);
            let mut ok = info.step == step && fields.len() == 2;
            for f in &fields {
                ok &= match (&*f.name, &f.payload) {
                    (FIXED_FIELD, FieldPayload::Fixed { elem_size, data }) => {
                        *elem_size == cfg.fixed_elem_size() && *data == fixed_ref
                    }
                    (HP_FIELD, FieldPayload::Var { sizes, data }) => {
                        *sizes == hp_sizes_ref && *data == hp_ref
                    }
                    _ => false,
                };
            }
            bytes += (fixed_ref.len() + hp_ref.len()) as u64;
            if let Some(s) = span.as_mut() {
                s.set_bytes((fixed_ref.len() + hp_ref.len()) as u64);
                s.set_detail(step);
            }
            drop(span);
            agree_ok(
                ar.file().comm(),
                ok,
                "restored field bytes differ from the recomputed reference",
            )?;
        }
        metrics.absorb_io_read(&ar.file().io_stats());
        metrics.absorb_engine(&ar.file().engine_stats());
        ar.close()?;
        let spans = tracer.and_then(|t| t.merged()).unwrap_or_default();
        Ok((bytes, spans))
    });
    let seconds = t.elapsed().as_secs_f64();
    let mut payload_bytes = 0;
    let mut spans = Vec::new();
    for leg in legs {
        let (b, s) = leg?;
        payload_bytes += b;
        spans.extend(s);
    }
    Ok((RestoreStats { ranks, steps: steps.len() as u64, payload_bytes, seconds }, spans))
}

// ---------------------------------------------------------------------
// Crash replay leg (serial by serial-equivalence) + recovery
// ---------------------------------------------------------------------

fn crash_leg(
    main_path: &Path,
    cfg: &ScenarioConfig,
    crash_seed: u64,
    metrics: &Arc<Metrics>,
) -> Result<(RecoverStats, Vec<Span>)> {
    let path = crash_path(main_path);
    // Replay the identical element stream serially: serial equivalence
    // means this file's bytes match the P-rank archive, so a torn
    // prefix here stands for a torn prefix of any writer rank count.
    // The seeded trigger may land past the end of a small workload's
    // write-op count, in which case no crash fires — derive a new seed
    // and replay (deterministic given `crash_seed`).
    let replay_metrics = Metrics::new();
    let mut attempt_seed = crash_seed;
    let mut fired = false;
    for _ in 0..8 {
        let _ = std::fs::remove_file(&path);
        let mut ar = Archive::create(SerialComm::new(), &path, b"scda amr churn scenario")?;
        ar.file_mut().set_io_tuning(IoTuning::direct())?;
        // Armed only after create: the 128-byte file header is already
        // on disk, so recovery always has a valid prefix to stand on.
        ar.file_mut()
            .set_fault_plan(Some(FaultPlan::seeded_crash(attempt_seed, cfg.crash_max_trigger)));
        let mut write_errs = 0usize;
        for cycle in 1..=cfg.cycles as u64 {
            let (leaves, _, _) = cycle_shape(cfg, cycle, 1);
            let n = leaves.len();
            let part = Partition::uniform(1, n as u64);
            let fixed = local_fixed_field(&leaves, 0..n, cfg.fixed_k);
            let (hp_sizes, hp) = local_hp_field(&leaves, 0..n, cfg.max_degree);
            let fields = make_fields(cfg, &leaves, 0..n, fixed, hp_sizes, hp);
            write_errs +=
                restart::write_step(&mut ar, APP_NAME, cycle, &part, &fields, &Identity, &replay_metrics)
                    .is_err() as usize;
        }
        let finished = ar.finish();
        if write_errs > 0 || finished.is_err() {
            fired = true;
            break;
        }
        attempt_seed = splitmix(attempt_seed);
    }
    if !fired {
        return Err(ScdaError::usage(
            usage::BAD_CONFIG,
            format!("seeded crash (seed {crash_seed:#x}) never fired; raise crash_max_trigger"),
        ));
    }

    // Recover the torn tail, then account for what survived.
    let tracer = cfg.traced.then(|| Arc::new(Tracer::for_rank(0)));
    let t = Instant::now();
    let report = recover_with(&path, tracer.as_ref())?;
    let seconds = t.elapsed().as_secs_f64();

    let ar = Archive::open(SerialComm::new(), &path)?;
    let complete: Vec<u64> = restart::list_steps(&ar)
        .into_iter()
        .filter(|&s| {
            ar.get(&restart::info_name(s)).is_some()
                && ar.get(&restart::field_name(s, FIXED_FIELD)).is_some()
                && ar.get(&restart::field_name(s, HP_FIELD)).is_some()
        })
        .collect();
    ar.close()?;

    // Every complete surviving step must restore byte-identically on
    // the (different) restore rank count.
    let (_, restore_spans) = restore_leg(&path, cfg, &complete, cfg.restore_ranks, metrics)?;

    let mut spans = tracer.map(|t| t.snapshot()).unwrap_or_default();
    spans.extend(restore_spans);
    Ok((
        RecoverStats {
            seconds,
            rebuilt: report.action == RecoveryAction::Rebuilt,
            truncated_bytes: report.truncated_bytes,
            datasets: report.datasets.len() as u64,
            steps_survived: complete.len() as u64,
        },
        spans,
    ))
}

// ---------------------------------------------------------------------
// Orchestration
// ---------------------------------------------------------------------

/// Run the full scenario against `path`: write `cfg.cycles` checkpoint
/// steps with `cfg.writers` ranks, optionally crash-replay + recover a
/// sacrificial sibling (`<path>.crash`), then restore and verify every
/// step on `cfg.restore_ranks` ranks.
pub fn run_scenario(path: impl AsRef<Path>, cfg: &ScenarioConfig) -> Result<ScenarioReport> {
    cfg.validate()?;
    let path = path.as_ref();
    let metrics = Arc::new(Metrics::new());

    let (cycles, mut spans) = write_leg(path, cfg, &metrics)?;
    let file_bytes = std::fs::metadata(path)
        .map_err(|e| ScdaError::io(e, "stat scenario archive"))?
        .len();

    let recover = match cfg.crash_seed {
        Some(seed) => {
            let (stats, crash_spans) = crash_leg(path, cfg, seed, &metrics)?;
            spans.extend(crash_spans);
            Some(stats)
        }
        None => None,
    };

    let steps: Vec<u64> = (1..=cfg.cycles as u64).collect();
    let (restore, restore_spans) = restore_leg(path, cfg, &steps, cfg.restore_ranks, &metrics)?;
    spans.extend(restore_spans);

    Ok(ScenarioReport { cycles, file_bytes, recover, restore, spans, metrics })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("scda-scenario-{}-{}", std::process::id(), name));
        p
    }

    #[test]
    fn front_is_deterministic_and_in_domain() {
        for cycle in 1..=16 {
            let (a, ra) = front(7, cycle);
            let (b, rb) = front(7, cycle);
            assert_eq!((a, ra), (b, rb));
            assert!((0.0..=1.0).contains(&a.0) && (0.0..=1.0).contains(&a.1));
            assert!(ra > 0.0 && ra < 0.5);
            // A different seed moves the front.
            assert_ne!(front(8, cycle), (a, ra));
        }
    }

    #[test]
    fn mesh_at_is_valid_and_churns() {
        let cfg = ScenarioConfig::default();
        let mut shapes = std::collections::BTreeSet::new();
        for cycle in 1..=cfg.cycles as u64 {
            let leaves = mesh_at(&cfg, cycle);
            assert!(check_mesh(&leaves), "cycle {cycle} mesh invalid");
            assert!(leaves.len() > (1 << (2 * cfg.base_level)), "cycle {cycle} never refined");
            shapes.insert(leaves.len());
        }
        assert!(shapes.len() > 1, "front never moved: {shapes:?}");
    }

    #[test]
    fn weights_match_field_payloads() {
        let cfg = ScenarioConfig::default();
        let leaves = mesh_at(&cfg, 1);
        let weights = element_weights(&leaves, cfg.fixed_k, cfg.max_degree);
        let fixed = local_fixed_field(&leaves, 0..leaves.len(), cfg.fixed_k);
        let (_, hp) = local_hp_field(&leaves, 0..leaves.len(), cfg.max_degree);
        assert_eq!(weights.iter().sum::<u64>(), (fixed.len() + hp.len()) as u64);
    }

    #[test]
    fn rejects_bad_configs() {
        let path = tmp("bad-cfg.scda");
        for cfg in [
            ScenarioConfig { cycles: 0, ..Default::default() },
            ScenarioConfig { writers: 0, ..Default::default() },
            ScenarioConfig { restore_ranks: 0, ..Default::default() },
            ScenarioConfig { base_level: 6, max_level: 5, ..Default::default() },
            ScenarioConfig { fixed_k: 0, ..Default::default() },
        ] {
            let err = run_scenario(&path, &cfg).unwrap_err();
            assert_eq!(err.code(), 3000 + usage::BAD_CONFIG, "cfg {cfg:?}");
        }
    }

    #[test]
    fn tiny_scenario_round_trips() {
        let path = tmp("tiny.scda");
        let cfg = ScenarioConfig {
            cycles: 2,
            base_level: 1,
            max_level: 3,
            writers: 2,
            restore_ranks: 3,
            ..Default::default()
        };
        let report = run_scenario(&path, &cfg).unwrap();
        assert_eq!(report.cycles.len(), 2);
        assert!(report.cycles.iter().all(|c| c.elements > 0 && c.payload_bytes > 0));
        assert!(report.recover.is_none());
        assert_eq!(report.restore.steps, 2);
        assert!(report.restore.payload_bytes > 0);
        assert!(report.file_bytes > 128);
        assert!(report.spans.is_empty(), "untraced run recorded spans");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn traced_crash_scenario_recovers_and_spans_cover_phases() {
        let path = tmp("crash.scda");
        let cfg = ScenarioConfig {
            cycles: 2,
            base_level: 1,
            max_level: 3,
            writers: 2,
            restore_ranks: 3,
            crash_seed: Some(0xC4A5),
            traced: true,
            ..Default::default()
        };
        let report = run_scenario(&path, &cfg).unwrap();
        let rec = report.recover.expect("crash leg ran");
        assert!(rec.rebuilt || rec.truncated_bytes == 0);
        assert!(rec.steps_survived <= cfg.cycles as u64);
        let kinds: std::collections::BTreeSet<&str> =
            report.spans.iter().map(|s| s.kind.name()).collect();
        for want in ["refine", "rebalance", "restore", "section_write"] {
            assert!(kinds.contains(want), "missing {want} span in {kinds:?}");
        }
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(crash_path(&path));
    }
}
