//! Runtime services: the bridge between the rust coordinator and the
//! AOT-compiled JAX/Pallas graphs (a PJRT CPU engine plus a
//! bit-identical native fallback for the preconditioning transform),
//! the [`ArchiveReadService`] — the shared-cache multi-session read
//! server over one archive — and the [`scenario`] AMR churn driver
//! that exercises the whole stack end to end.

pub mod engine;
pub mod precond;
pub mod scenario;
pub mod service;

pub use engine::Engine;
pub use precond::{entropy_estimate, native_forward, native_inverse, Preconditioner, CHUNK, TILE};
pub use scenario::{
    run_scenario, CycleStats, RecoverStats, RestoreStats, ScenarioConfig, ScenarioReport,
};
pub use service::{
    ArchiveReadService, Identity, NativeTransform, PrecondService, ReadRequest,
    ReadResponse, ReadServiceConfig, ServiceSession, Transform,
};
