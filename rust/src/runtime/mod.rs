//! Runtime services: the bridge between the rust coordinator and the
//! AOT-compiled JAX/Pallas graphs (a PJRT CPU engine plus a
//! bit-identical native fallback for the preconditioning transform),
//! and the [`ArchiveReadService`] — the shared-cache multi-session read
//! server over one archive.

pub mod engine;
pub mod precond;
pub mod service;

pub use engine::Engine;
pub use precond::{entropy_estimate, native_forward, native_inverse, Preconditioner, CHUNK, TILE};
pub use service::{
    ArchiveReadService, Identity, NativeTransform, PrecondService, ReadRequest,
    ReadResponse, ReadServiceConfig, ServiceSession, Transform,
};
